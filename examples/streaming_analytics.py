"""Streaming analytics: the paper's §7.3 experiment as an application.

Concurrent writer (edge stream) + reader (BFS queries) on one
AspenStream, then the SAME analytics (BFS / PageRank / CC) through the
backend-unified traversal engine on both substrates — the numpy
FlatSnapshot engine and the jit-compiled FlatGraph engine — with a
parity + speed report.

    PYTHONPATH=src python examples/streaming_analytics.py
"""
import time

import numpy as np

from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
from repro.core.traversal import make_engine
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize

n = 4096
edges = symmetrize(rmat_edges(12, 80_000, seed=0))
keep, stream_updates = make_update_stream(edges, 5_000, seed=1)

# --- faithful level: concurrent updates + global queries -------------------
# mirror=False isolates the paper's tree-level experiment; the resident
# FlatGraph mirror is demonstrated below.
g0 = G.build_graph(n, keep)
s = AspenStream(g0, mirror=False)
src = int(edges[0, 0])
stats = run_concurrent(
    s, stream_updates,
    query_fn=lambda snap: talg.bfs(make_engine(snap), src),
    duration_s=3.0, batch_size=10,
)
print("== faithful (tree-of-C-trees) level ==")
print(f"update throughput : {stats.updates_per_sec:,.0f} directed edges/s")
print(f"update latency    : {stats.mean_update_latency_s * 1e6:.1f} us/batch")
print(f"query latency     : {stats.query_latency_concurrent_s * 1e3:.2f} ms concurrent "
      f"vs {stats.query_latency_isolated_s * 1e3:.2f} ms isolated "
      f"({100 * (stats.query_latency_concurrent_s / stats.query_latency_isolated_s - 1):+.1f}%)")

# --- TPU-native level: jit streaming step --------------------------------
import jax

gf = fg.from_edges(n, keep)
ins_np = stream_updates[stream_updates[:, 2] == 0][:1024, :2]
# both directions, matching AspenStream.insert_edges(symmetric=True)
batch_np = np.concatenate([ins_np, ins_np[:, ::-1]])
batch = fg.batch_from_edges(batch_np)
cap = gf.edge_capacity * 2
ins = jax.jit(lambda g, b: fg.insert_edges(g, b, cap))
gf2 = jax.block_until_ready(ins(gf, batch))  # compile
t0 = time.perf_counter()
for _ in range(20):
    gf2 = ins(gf, batch)
jax.block_until_ready(gf2)
dt = (time.perf_counter() - t0) / 20
print("\n== TPU-native (flat pool) level ==")
print(f"batch insert      : {batch_np.shape[0] / dt:,.0f} edges/s (jit rank-merge)")

# --- dual representation: resident mirror, version-pinned engines ---------
# Every version the stream publishes pairs the tree with a FlatGraph
# mirror kept current by the same jit rank-merge — so the time-to-first-
# query after a batch is the merge + one jit engine refresh, not an O(m)
# host rebuild (DESIGN.md §6).
sd = AspenStream(g0)  # mirror=True: every version carries the flat pool
ins_all = stream_updates[stream_updates[:, 2] == 0]
warm, batch2 = ins_all[1024:1124, :2], ins_all[1124:1224, :2]
sd.insert_edges(warm)  # warm: compile merge + engine refresh at this shape
talg.bfs(sd.engine("jax"), src)
t0 = time.perf_counter()
sd.insert_edges(batch2)
talg.bfs(sd.engine("jax"), src)
ttfq = time.perf_counter() - t0
e_cached = sd.engine("jax")
print(f"time-to-first-query after a {batch2.shape[0]}-edge batch: {ttfq * 1e3:.1f} ms "
      f"(engine cached per version: {e_cached is sd.engine('jax')})")

# --- unified traversal engine: same algorithms, both backends -------------
# Callers pick the backend at snapshot time: ``AspenStream.engine("numpy")``
# (or "jax") on the stream, or ``make_engine(FlatGraph)`` on the flat
# pool.  Parity is checked on one shared snapshot (the post-insert pool).
eng_jx = make_engine(gf2)
eng_np = make_engine(G.flat_snapshot(G.build_graph(n, fg.to_edge_array(gf2))))

print("\n== unified edgeMap engine: numpy vs jax parity + speed ==")
print(f"{'algorithm':<12}{'numpy ms':>10}{'jax ms':>10}  parity")
for name, run, check in [
    ("bfs", lambda e: talg.bfs(e, src),
     lambda a, b: np.array_equal(talg.bfs_depths(a, src), talg.bfs_depths(b, src))),
    ("pagerank", lambda e: talg.pagerank(e, iters=5),
     lambda a, b: np.allclose(a, b, atol=1e-5)),
    ("cc", lambda e: talg.connected_components(e), np.array_equal),
]:
    run(eng_jx)  # warm the jit cache
    run(eng_np)  # warm the CSR caches (symmetric warm-up for fair timing)
    t0 = time.perf_counter(); out_j = run(eng_jx); t_j = time.perf_counter() - t0
    t0 = time.perf_counter(); out_n = run(eng_np); t_n = time.perf_counter() - t0
    print(f"{name:<12}{t_n * 1e3:>10.1f}{t_j * 1e3:>10.1f}  {bool(check(out_n, out_j))}")

# --- property graph: weighted streaming + weighted analytics --------------
# Per-edge values are first-class (DESIGN.md §8): the stream carries a
# weight per inserted edge through BOTH substrates (tree weight-map +
# mirror value array, published atomically), and the same algorithm
# texts run weighted — SSSP over the (min, +) semiring, PageRank over
# the weighted (+, x) semiring — on either backend.
lo, hi = np.minimum(keep[:, 0], keep[:, 1]), np.maximum(keep[:, 0], keep[:, 1])
wk = ((lo * 1000003 + hi) % 7 + 1).astype(np.float64)  # symmetric, integer
sw = AspenStream(G.build_graph(n, keep, weights=wk))
ins_w = stream_updates[stream_updates[:, 2] == 0][:200, :2]
sw.insert_edges(ins_w, weights=np.ones(ins_w.shape[0]))  # unit-weight batch
print("\n== weighted serve path (SSSP / weighted PageRank) ==")
d_batch = sw.query_batch(np.array([src, int(keep[1, 0])]), kind="sssp")
d_np = talg.sssp(sw.engine("numpy"), src)
print(f"sssp: batched-jax == serial-numpy: {np.array_equal(d_batch[0], d_np)} "
      f"(reached {np.isfinite(d_np).sum()} vertices, "
      f"max dist {d_np[np.isfinite(d_np)].max():g})")
wpr_j = talg.weighted_pagerank(sw.engine("jax"), iters=5)
wpr_n = talg.weighted_pagerank(sw.engine("numpy"), iters=5)
print(f"weighted pagerank: parity {np.allclose(wpr_j, wpr_n, atol=1e-5)}, "
      f"mass {wpr_n.sum():.6f}")
