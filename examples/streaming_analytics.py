"""Streaming analytics: the paper's §7.3 experiment as an application.

Concurrent writer (edge stream) + reader (BFS/connectivity queries) on
one AspenStream, then the same workload on the TPU-native flat level
(jit-compiled rank-merge updates + while-loop BFS).

    PYTHONPATH=src python examples/streaming_analytics.py
"""
import time

import numpy as np

from repro.core import algorithms as alg
from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
from repro.data.rmat import rmat_edges, symmetrize

n = 4096
edges = symmetrize(rmat_edges(12, 80_000, seed=0))
keep, stream_updates = make_update_stream(edges, 5_000, seed=1)

# --- faithful level: concurrent updates + global queries -------------------
s = AspenStream(G.build_graph(n, keep))
src = int(edges[0, 0])
stats = run_concurrent(
    s, stream_updates, query_fn=lambda snap: alg.bfs(snap, src),
    duration_s=3.0, batch_size=10,
)
print("== faithful (tree-of-C-trees) level ==")
print(f"update throughput : {stats.updates_per_sec:,.0f} directed edges/s")
print(f"update latency    : {stats.mean_update_latency_s * 1e6:.1f} us/batch")
print(f"query latency     : {stats.query_latency_concurrent_s * 1e3:.2f} ms concurrent "
      f"vs {stats.query_latency_isolated_s * 1e3:.2f} ms isolated "
      f"({100 * (stats.query_latency_concurrent_s / stats.query_latency_isolated_s - 1):+.1f}%)")

# --- TPU-native level: jit streaming step + jit BFS -------------------------
import jax

gf = fg.from_edges(n, keep)
batch_np = stream_updates[stream_updates[:, 2] == 0][:2048, :2]
batch = fg.batch_from_edges(batch_np)
cap = gf.edge_capacity * 2
ins = jax.jit(lambda g, b: fg.insert_edges(g, b, cap))
gf2 = jax.block_until_ready(ins(gf, batch))  # compile
t0 = time.perf_counter()
for _ in range(20):
    gf2 = ins(gf, batch)
jax.block_until_ready(gf2)
dt = (time.perf_counter() - t0) / 20
print("\n== TPU-native (flat pool) level ==")
print(f"batch insert      : {batch_np.shape[0] / dt:,.0f} edges/s (jit rank-merge)")
t0 = time.perf_counter()
levels = jax.block_until_ready(fg.bfs(gf2, src))
print(f"jit BFS           : {(time.perf_counter() - t0) * 1e3:.1f} ms, "
      f"reached {(np.asarray(levels) >= 0).sum()} vertices")
cc = np.asarray(fg.connected_components(gf2))
print(f"components        : {len(np.unique(cc))}")
