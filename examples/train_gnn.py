"""End-to-end driver: train GraphSAGE on a streaming graph.

Demonstrates the full stack working together:
  * Aspen flat graph as the storage layer (streaming inserts mid-training)
  * the REAL neighbor sampler reading the live CSR pool
  * train loop with AdamW + WSD schedule + checkpoint/restore
  * deterministic restart (kill it mid-run and re-run: it resumes)

    PYTHONPATH=src python examples/train_gnn.py [--steps 300]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat_graph as fg
from repro.data.pipeline import NeighborSampler, power_law_graph
from repro.dist.fault_tolerance import ResumableRun
from repro.models.gnn import graphsage
from repro.optim import adamw
from repro.train import train_step as TS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--m", type=int, default=120_000)
    ap.add_argument("--d-feat", type=int, default=64)
    ap.add_argument("--d-hidden", type=int, default=128)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--fanout", type=int, nargs=2, default=(15, 10))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_gnn")
    ap.add_argument("--stream-every", type=int, default=50,
                    help="insert a batch of new edges every K steps")
    args = ap.parse_args()

    # --- storage layer: an Aspen flat graph we keep streaming into ---------
    offsets, nbrs = power_law_graph(args.n, args.m, seed=0)
    edges = np.stack([np.repeat(np.arange(args.n), np.diff(offsets)), nbrs], 1)
    graph = fg.from_edges(args.n, edges)
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((args.n, args.d_feat)).astype(np.float32)
    # labels correlated with features so training learns something real
    w_true = rng.standard_normal((args.d_feat, args.classes))
    labels = (feats @ w_true).argmax(1)

    params = graphsage.init(jax.random.PRNGKey(0), args.d_feat, args.d_hidden, args.classes)
    step_fn = jax.jit(TS.make_train_step(
        TS.sage_sampled_loss(), adamw.wsd_schedule(20, args.steps, 50, 1e-2)
    ))

    run = ResumableRun(args.ckpt_dir, make_state=lambda: TS.init_state(params),
                       save_every=100)
    start, state = run.restore_or_init()
    if start:
        print(f"[restore] resuming from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        if step % args.stream_every == 0 and step > 0:
            # live streaming insert: the sampler sees the new edges because
            # it reads the (immutable) new snapshot's CSR arrays
            new = np.stack([rng.integers(0, args.n, 512), rng.integers(0, args.n, 512)], 1)
            graph = fg.insert_edges_host(graph, new)
        csr_off = np.asarray(graph.offsets)
        csr_nbr = (np.asarray(graph.keys)[: int(graph.m)] & 0xFFFFFFFF)
        sampler = NeighborSampler(csr_off, csr_nbr, feats)
        sb = sampler.sample_batch(0, step, args.batch, tuple(args.fanout))
        batch = {
            "x_self": jnp.asarray(sb["x_self"]),
            "neigh_feats": [jnp.asarray(f) for f in sb["neigh_feats"]],
            "neigh_masks": [jnp.asarray(m) for m in sb["neigh_masks"]],
            "labels": jnp.asarray(labels[sb["seeds"]]),
        }
        state, metrics = step_fn(state, batch)
        run.maybe_save(step, state)
        if step % 25 == 0:
            acc = _eval_acc(state.params, sampler, labels, args)
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"acc {acc:.3f}  edges {int(graph.m)}  "
                  f"({(time.time() - t0) / max(step - start + 1, 1):.3f} s/step)")
    run.finish()
    acc = _eval_acc(state.params, sampler, labels, args)
    print(f"done. final accuracy {acc:.3f} (chance {1 / args.classes:.3f})")


def _eval_acc(params, sampler, labels, args) -> float:
    sb = sampler.sample_batch(1, 999, 512, tuple(args.fanout))
    logits = graphsage.forward_sampled(
        params, jnp.asarray(sb["x_self"]),
        [jnp.asarray(f) for f in sb["neigh_feats"]],
        [jnp.asarray(m) for m in sb["neigh_masks"]],
    )
    return float((np.asarray(logits).argmax(1) == labels[sb["seeds"]]).mean())


if __name__ == "__main__":
    main()
