"""Serving walkthrough: GraphQueryService with two tenants and a
pinned session (DESIGN.md §13).

    PYTHONPATH=src python examples/serve_graph.py

The service turns one live AspenStream into a multi-tenant query
server: a writer thread publishes batched edge updates while client
queries coalesce into per-kind lanes, flush as power-of-two batches
against the freshest version, and tenants share throughput by weight.
A Session pins the version current at open time, so a sequence of
reads is strictly serializable — the paper's snapshot guarantee,
stretched across multiple queries.
"""
import threading
import time

import numpy as np

from repro.core import graph as G
from repro.core.streaming import AspenStream
from repro.data.rmat import rmat_edges, symmetrize
from repro.serve.graph import GraphQueryService

# --- 1. A graph, a stream, a service ---------------------------------------
n = 1 << 10
edges = symmetrize(rmat_edges(10, 15_000, seed=7))
stream = AspenStream(G.build_graph(n, edges))

# alice pays for 3x bob's share; lanes coalesce up to 16 queries;
# work_conserving flushes whatever is pending whenever the executor
# frees up (continuous batching), with the 250ms SLO as the backstop
service = GraphQueryService(
    stream,
    backend="jax",
    max_batch=16,
    default_deadline_s=0.25,
    tenant_weights={"alice": 3.0, "bob": 1.0},
    work_conserving=True,
)
service.start()
service.warmup(kinds=("bfs", "sssp"))  # pre-compile the pow2 trace ladder
print(f"service up: backend={service.backend}, version {stream.vg.current_stamp}")

# --- 2. A continuous update stream on the writer thread --------------------
stop = threading.Event()


def update_feed():
    rng = np.random.default_rng(1)
    while not stop.is_set():
        for _ in range(20):  # bursts amortize into one publish each
            service.enqueue_update(int(rng.integers(n)), int(rng.integers(n)))
        time.sleep(0.05)


feeder = threading.Thread(target=update_feed)
feeder.start()

# --- 3. Two tenants querying concurrently ----------------------------------
rng = np.random.default_rng(2)
tickets = []
for i in range(60):
    tenant = "alice" if i % 4 else "bob"
    kind = "bfs" if i % 2 else "sssp"
    tickets.append(service.submit(kind, source=int(rng.integers(n)), tenant=tenant))
answers = [t.result(timeout=30) for t in tickets]
lat = sorted(t.latency_s for t in tickets)
print(f"60 mixed queries served: p50 {lat[30] * 1e3:.1f} ms, "
      f"p99 {lat[-1] * 1e3:.1f} ms, "
      f"largest flush {max(t.batch_size for t in tickets)} requests")

# --- 4. A pinned session: strictly-serializable multi-query reads ----------
with service.session(tenant="alice") as sess:
    print(f"session pinned at version {sess.stamp}")
    bfs_before = sess.query("bfs", source=5).result(timeout=30)
    # the writer keeps publishing underneath...
    time.sleep(0.3)
    service.flush_updates()
    bfs_after = sess.query("bfs", source=5).result(timeout=30)
    fresh = service.submit("bfs", source=5, tenant="alice").result(timeout=30)
    print(f"  session reads identical across publishes: "
          f"{np.array_equal(bfs_before, bfs_after)}")
    print(f"  freshest read sees {stream.vg.current_stamp - sess.stamp} "
          f"newer versions (answers differ: {not np.array_equal(bfs_after, fresh)})")

# --- 5. The result cache: hot repeats are free, publishes warm-start -------
# (DESIGN.md §14) Queries on one version are pure functions of
# (kind, params, source), so exact repeats answer from memory without
# touching admission, and on each publish a promotion thread carries
# the hot entries to the new version through the incremental paths.
zrng = np.random.default_rng(3)
t0 = time.perf_counter()
replay = []
for i in range(400):  # Zipf-skewed two-tenant replay: mostly repeats
    src = int(min(zrng.zipf(2.0) - 1, n - 1))
    kind = "bfs" if zrng.random() < 0.8 else "sssp"
    t = service.submit(kind, source=src, tenant=f"t{i % 2}")
    t.result(timeout=30)  # closed loop: each repeat sees the last fill
    replay.append(t)
service.flush_updates()      # the live writer kept publishing...
service.flush_promotions()   # ...and carry-forward kept up
cst = service.stats()["cache"]
warm = [t.latency_s for t in replay if t.cached]
print(f"replay: {len(warm)}/400 served from cache in "
      f"{time.perf_counter() - t0:.2f}s "
      f"(hit rate {100 * len(warm) / 400:.0f}%, "
      f"promoted {cst['promoted_incremental']} incremental / "
      f"{cst['promoted_full']} full)")

# the cache NEVER leaks a newer version's answer into a pinned session:
# entries live on the version itself, so a session lookup can only see
# results computed against its exact snapshot
with service.session(tenant="alice") as sess:
    pinned = sess.query("bfs", source=0).result(timeout=30)
    # publish under the session's feet, promotion and all
    service.enqueue_update(0, int(rng.integers(1, n)))
    service.flush_updates()
    service.flush_promotions()
    again = sess.query("bfs", source=0).result(timeout=30)  # cached, pinned
    print(f"  pinned session repeat is cached AND identical across a "
          f"publish: {np.array_equal(pinned, again)}")

# --- 6. Observability + clean shutdown -------------------------------------
stop.set()
feeder.join()
st = service.stats()
print(f"stats: {st['publishes']} publishes, "
      f"tenants alice/bob completed "
      f"{st['tenants']['alice']['completed']}/{st['tenants']['bob']['completed']}, "
      f"bfs lane hist {st['lanes']['bfs']['batch_size_hist']}, "
      f"retraces after warmup {sum(l['retraces'] for l in st['lanes'].values())}")
service.stop()
print(f"shut down cleanly; live versions: {stream.vg.live_versions()}")
