"""Quickstart: C-trees and Aspen in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import algorithms as alg
from repro.core import ctree as ct
from repro.core import graph as G
from repro.core.streaming import AspenStream
from repro.data.rmat import rmat_edges, symmetrize

# --- 1. A C-tree is a compressed purely-functional ordered set ------------
rng = np.random.default_rng(0)
values = np.unique(rng.integers(0, 1 << 20, 50_000))
c = ct.build(values, b=256)
print(f"C-tree: {ct.ctree_size(c)} elements, "
      f"{ct.nbytes(c) / ct.ctree_size(c):.2f} B/elem compressed "
      f"(vs {ct.UNCOMPRESSED_NODE_BYTES} B/elem as a plain functional tree)")

# updates are functional: the old version is untouched
c2 = ct.multi_insert(c, rng.integers(0, 1 << 20, 1000))
print(f"after insert: new={ct.ctree_size(c2)}, old still={ct.ctree_size(c)}")

# --- 2. A graph is a tree of C-trees --------------------------------------
n = 4096
edges = symmetrize(rmat_edges(12, 60_000, seed=1))
g = G.build_graph(n, edges)
print(f"graph: {G.num_vertices(g)} vertices, {G.num_edges(g)} edges "
      f"({G.graph_nbytes(g) / G.num_edges(g):.2f} B/edge)")

# --- 3. Snapshots + queries ------------------------------------------------
snap = G.flat_snapshot(g)  # O(n): array of edge-tree pointers (paper §5.1)
src = int(edges[0, 0])
parents = alg.bfs(snap, src)
print(f"BFS from {src}: reached {(parents >= 0).sum()} vertices")

# --- 4. Streaming: concurrent-safe updates via versioning ------------------
stream = AspenStream(g)
v0 = stream.acquire()  # a reader pins version 0
stream.insert_edges(rmat_edges(12, 500, seed=2))  # writer publishes v1
v1 = stream.acquire()
print(f"reader v0 sees {G.num_edges(v0.graph)} edges; "
      f"v1 sees {G.num_edges(v1.graph)} (serializable snapshots)")
stream.release(v0), stream.release(v1)

# --- 5. Property graphs: per-edge values, weighted traversal ---------------
# insert_edges(weights=...) attaches one value per edge (both directions
# of a symmetric insert); re-inserting an edge overwrites its weight.
from repro.core.traversal import algorithms as talg

wedges = np.array([[0, 1], [1, 2], [0, 2]])
wstream = AspenStream(G.build_graph(3, np.empty((0, 2), np.int64)))
wstream.insert_edges(wedges, weights=np.array([1.0, 1.0, 10.0]))
dist = talg.sssp(wstream.engine("numpy"), 0)  # Bellman-Ford (min, +)
print(f"SSSP 0->2: {dist[2]:g} (2-hop cheap path beats the 10.0 edge)")
wstream.insert_edges(wedges[2:], weights=np.array([0.5]))  # overwrite
print(f"after overwrite: {talg.sssp(wstream.engine('numpy'), 0)[2]:g} "
      f"(direct edge now wins)")
