#!/usr/bin/env bash
# Smoke target: tier-1 suite + a ~2s traversal-engine parity probe.
#
#   scripts/smoke.sh              # full tier-1 + parity probe
#   scripts/smoke.sh --fast       # skip slow-marked tests (quick iteration)
#   scripts/smoke.sh --probe-only # just the parity probe (CI runs the
#                                 # suite as its own step; don't pay it twice)
#
# The parity probe catches benchmark-only regressions (e.g. a kernel or
# engine change that still passes unit tests but breaks numpy-vs-jax
# agreement at the integration level) before a full benchmark run.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

MARK=()
if [[ "${1:-}" == "--fast" ]]; then
  MARK=(-m "not slow")
fi

if [[ "${1:-}" != "--probe-only" ]]; then
  # ${MARK[@]+...} guard: empty-array expansion trips `set -u` on bash < 4.4
  python -m pytest -x -q ${MARK[@]+"${MARK[@]}"}
fi

echo "== engine parity probe (numpy vs jax vs sharded traversal) =="
python - <<'EOF'
import time

import numpy as np

from repro.core import compressed as cz
from repro.core import flat_graph as fg, graph as G
from repro.core import sharded_pool as sp
from repro.core.streaming import MIRROR, AspenStream
from repro.core.traversal import NumpyEngine, make_engine
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize

t0 = time.time()
edges = symmetrize(rmat_edges(9, 4000, seed=3))
n = 1 << 9
eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
eng_jx = make_engine(fg.from_edges(n, edges))
eng_sh = make_engine(sp.graph_from_edges(n, edges, n_shards=4))
src = int(edges[0, 0])

p_np, p_jx = talg.bfs(eng_np, src), talg.bfs(eng_jx, src)
assert np.array_equal(talg.bfs_depths(p_np, src), talg.bfs_depths(p_jx, src)), "BFS depths diverge"
assert np.allclose(talg.pagerank(eng_np, iters=5), talg.pagerank(eng_jx, iters=5), atol=1e-5), "PageRank diverges"
assert np.array_equal(talg.connected_components(eng_np), talg.connected_components(eng_jx)), "CC labels diverge"
assert np.array_equal(p_np, talg.bfs(eng_sh, src)), "sharded BFS parents diverge"
assert np.array_equal(
    talg.connected_components(eng_np), talg.connected_components(eng_sh)
), "sharded CC labels diverge"

# adaptive-width compressed mirror (compressed=True streams, DESIGN.md §12):
# the resident pool must carry width tags, decode exactly, and cost no more
# bytes than the fixed int16 layout
stream = AspenStream(G.build_graph(n, edges), compressed=True)
v = stream.acquire()
cg = v.aux[MIRROR]  # the RESIDENT mirror (flat_graph() would decompress)
stream.release(v)
assert cg.dst.adaptive, "compressed stream mirror is not adaptive-width"
assert not bool(np.asarray(cg.dst.spill)), "adaptive mirror spilled"
assert cz.stream_nbytes(cg.dst) <= cz.stream_nbytes(
    fg.compress_host(fg.from_edges(n, edges), width=2).dst
), "adaptive pool larger than fixed int16"
eng_cz = stream.engine("jax")
assert np.array_equal(p_np, talg.bfs(eng_cz, src)), "compressed BFS parents diverge"
assert np.allclose(
    talg.pagerank(eng_np, iters=5), talg.pagerank(eng_cz, iters=5), atol=1e-5
), "compressed PageRank diverges"
print(f"parity OK (bfs/pagerank/cc x 3 backends + adaptive compressed, n={n}, m={edges.shape[0]}) in {time.time() - t0:.1f}s")
EOF

echo "== graph-query service probe (live writer + 100 mixed queries) =="
python - <<'EOF'
import threading
import time

import numpy as np

from repro.core import graph as G
from repro.core.streaming import AspenStream
from repro.data.rmat import rmat_edges, symmetrize
from repro.serve.graph import GraphQueryService

t0 = time.time()
n = 1 << 9
edges = symmetrize(rmat_edges(9, 4000, seed=3))
stream = AspenStream(G.build_graph(n, edges))
svc = GraphQueryService(stream, backend="jax", max_batch=8,
                        default_deadline_s=1.0, work_conserving=True)
svc.start()
svc.warmup(kinds=("bfs", "sssp"))

# a live writer races 100 mixed queries from two tenants
stop = threading.Event()
def writer():
    rng = np.random.default_rng(4)
    while not stop.is_set():
        for _ in range(10):
            svc.enqueue_update(int(rng.integers(n)), int(rng.integers(n)), block=False)
        time.sleep(0.05)
wt = threading.Thread(target=writer)
wt.start()

rng = np.random.default_rng(5)
with svc.session(tenant="alice") as sess:
    pinned = sess.query("bfs", source=3).result(timeout=30)
    tickets = []
    for i in range(100):
        kind = "bfs" if i % 2 else "sssp"
        tenant = "alice" if i % 3 else "bob"
        tickets.append(svc.submit(kind, source=int(rng.integers(n)), tenant=tenant))
    results = [t.result(timeout=60) for t in tickets]
    # the pinned session still answers from its open-time version
    assert np.array_equal(sess.query("bfs", source=3).result(timeout=30), pinned), \
        "session answer drifted across publishes"
stop.set()
wt.join()
svc.flush_updates()
st = svc.stats()
svc.stop()

assert len(results) == 100 and all(r.shape == (n,) for r in results), "lost answers"
assert st["publishes"] >= 1, "writer never published"
assert sum(v["completed"] for v in st["tenants"].values()) >= 101, st["tenants"]
assert st["admission"]["backlog"] == 0 and st["admission"]["in_flight"] == 0
assert all(l["retraces"] == 0 for k, l in st["lanes"].items() if k in ("bfs", "sssp")), \
    "serving retraced after warmup"
assert st["sessions_open"] == 0 and stream.vg.live_versions() == 1, "leaked version refs"
print(f"service OK (100 queries, {st['publishes']} publishes, "
      f"mean batch {sum(l['flushed_requests'] for l in st['lanes'].values()) / max(sum(l['flushed_batches'] for l in st['lanes'].values()), 1):.1f}) "
      f"in {time.time() - t0:.1f}s")
EOF
