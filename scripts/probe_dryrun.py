"""Derisk probe: 512 host devices, multi-pod mesh, lower/compile, analyses."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time
import re
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

t0 = time.time()
print("devices:", len(jax.devices()))

mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("mesh:", mesh.shape, time.time() - t0)

D = 1024
FF = 4096


def step(w1, w2, x):
    # toy 2-layer mlp with psum-style data parallel grad
    h = jnp.einsum("bd,df->bf", x, w1)
    h = jax.nn.gelu(h)
    o = jnp.einsum("bf,fd->bd", h, w2)
    loss = jnp.mean(o * o)
    g1, g2 = jax.grad(lambda a, b: jnp.mean(jax.nn.gelu(x @ a) @ b), argnums=(0, 1))(w1, w2)
    return loss, (w1 - 1e-3 * g1, w2 - 1e-3 * g2)


w1_s = NamedSharding(mesh, P(None, "model"))
w2_s = NamedSharding(mesh, P("model", None))
x_s = NamedSharding(mesh, P(("pod", "data"), None))

w1 = jax.ShapeDtypeStruct((D, FF), jnp.bfloat16, sharding=w1_s)
w2 = jax.ShapeDtypeStruct((FF, D), jnp.bfloat16, sharding=w2_s)
x = jax.ShapeDtypeStruct((256, D), jnp.bfloat16, sharding=x_s)

t1 = time.time()
lowered = jax.jit(step, in_shardings=(w1_s, w2_s, x_s),
                  out_shardings=(NamedSharding(mesh, P()), (w1_s, w2_s))).lower(w1, w2, x)
print("lower ok", time.time() - t1)
t2 = time.time()
compiled = lowered.compile()
print("compile ok", time.time() - t2)

ma = compiled.memory_analysis()
print("memory_analysis:", ma)
ca = compiled.cost_analysis()
print("cost keys:", {k: v for k, v in list(ca.items())[:10] if isinstance(v, float)})
print("flops:", ca.get("flops"), "bytes accessed:", ca.get("bytes accessed"))

hlo = compiled.as_text()
colls = re.findall(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)[^\n]*", hlo)
print("n collective lines:", len(colls))
for c in colls[:5]:
    print("  ", c[:160])
print("total probe time:", time.time() - t0)
