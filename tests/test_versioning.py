"""VersionedGraph under concurrency + the version-pinned cache lifecycle.

The paper's version-maintenance guarantees, stress-tested: a held
version is never garbage-collected out from under a reader, the live
list drains back to exactly the current version, and version-pinned
cache entries (traversal engines) die with their version.
"""
import gc
import threading
import weakref

import numpy as np

from repro.core import graph as G
from repro.core.streaming import AspenStream
from repro.core.traversal import algorithms as talg
from repro.core.versioning import VersionedGraph
from repro.data.rmat import rmat_edges, symmetrize


def test_writer_reader_stress_refcount_gc():
    vg = VersionedGraph({"stamp": 0})
    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            for _ in range(50):
                v = vg.acquire()
                try:
                    # held => must still be on the live list (not collected)
                    if v.stamp not in vg._versions:
                        errors.append(f"held version {v.stamp} collected")
                    if v.graph["stamp"] != v.stamp:
                        errors.append("version/graph mismatch")
                finally:
                    vg.release(v)

    def writer():
        for i in range(300):
            vg.set({"stamp": i + 1})
        stop.set()

    threads = [threading.Thread(target=reader) for _ in range(4)] + [
        threading.Thread(target=writer)
    ]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert not errors, errors[:5]
    assert vg.current_stamp == 300
    # all readers drained: only the current version survives
    assert vg.live_versions() == 1
    assert vg.collected_versions() >= 299


def test_held_version_survives_writer_churn():
    vg = VersionedGraph("v0")
    held = vg.acquire()
    for i in range(20):
        vg.set(f"v{i + 1}")
    # the held (now-old) version is pinned by its refcount
    assert held.stamp in vg._versions
    assert held.graph == "v0"
    assert vg.live_versions() == 2  # held + current
    assert vg.release(held)  # last release collects it
    assert vg.live_versions() == 1


def test_engine_cache_dies_with_version():
    edges = symmetrize(rmat_edges(6, 300, seed=21))
    s = AspenStream(G.build_graph(64, edges[:-50]))

    eng = s.engine("numpy")
    src = int(edges[0, 0])
    assert (talg.bfs(eng, src) >= 0).any()
    v = s.acquire()
    assert v.cache[("engine", "numpy")] is eng
    wr_eng = weakref.ref(eng)
    wr_ver = weakref.ref(v)
    s.release(v)

    # supersede the version; drop our strong refs; the version-pinned
    # cache (and the engine in it) must be collectable
    s.insert_edges(edges[-50:])
    del eng, v
    gc.collect()
    assert wr_ver() is None, "superseded version leaked"
    assert wr_eng() is None, "engine-cache entry outlived its version"
    assert s.vg.live_versions() == 1


def test_stream_concurrent_mirror_consistency():
    """One writer + query readers over the dual-representation stream:
    refcount GC never breaks a reader, versions drain to 1, and the
    final mirror matches the tree."""
    from repro.core import flat_graph as fg
    from repro.core import traversal

    edges = symmetrize(rmat_edges(7, 800, seed=22))
    n = 128
    s = AspenStream(G.build_graph(n, edges[:400]))
    s.engine("jax")  # warm compile outside the threads
    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            try:
                eng = s.engine("jax")
                labels = talg.connected_components(eng)
                if labels.shape[0] != eng.n:
                    errors.append("bad result shape")
            except Exception as e:  # noqa: BLE001
                errors.append(repr(e))

    def writer():
        for i in range(400, len(edges), 40):
            s.insert_edges(edges[i : i + 40])
        stop.set()

    threads = [threading.Thread(target=reader) for _ in range(2)] + [
        threading.Thread(target=writer)
    ]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert not errors, errors[:3]
    assert s.vg.live_versions() == 1
    snap = s.flat_snapshot()
    np.testing.assert_array_equal(
        fg.to_edge_array(s.flat_graph()),
        fg.to_edge_array(traversal.flat_graph_of(snap)),
    )


def test_release_idempotent_past_zero():
    """Double-release regression: a stale release must not drive the
    refcount negative (which would let a later acquire/release pair
    collect a version someone still holds)."""
    vg = VersionedGraph("v0")
    v = vg.acquire()
    vg.set("v1")
    assert vg.release(v) is True  # last ref: collected
    assert vg.release(v) is False  # stale double-release: no-op
    assert vg.release(v) is False
    # the clamp keeps a subsequent acquire/release pair coherent
    cur = vg.acquire()
    assert cur._refcount == 1
    vg.release(cur)
    assert cur._refcount == 0
    assert vg.live_versions() == 1


def test_aux_gc_under_live_subscription():
    """1k publishes against a live subscription: collected versions drop
    their delta records and cached engines (no monotonic growth of
    retained arrays), and the live-version count stays bounded by the
    held set, not the publish count."""
    from repro.core.versioning import DELTA

    edges = symmetrize(rmat_edges(6, 300, seed=23))
    n = 64
    s = AspenStream(G.build_graph(n, edges), mirror=False)
    sub = s.subscribe("cc", backend="numpy")

    rng = np.random.default_rng(5)
    delta_refs, engine_refs = [], []
    for i in range(1000):
        e = rng.integers(0, n, size=(1, 2)).astype(np.int64)
        if e[0, 0] == e[0, 1]:
            e[0, 1] = (e[0, 1] + 1) % n
        s.insert_edges(e)
        if i % 100 == 0:
            v = s.acquire()
            delta_refs.append(weakref.ref(v.aux[DELTA]))
            engine_refs.append(weakref.ref(s._engine_for(v, "numpy")))
            s.release(v)
        sub.refresh()  # every hop: the chain is always intact
        # subscription + current is the whole live set
        assert s.vg.live_versions() <= 3

    assert sub.n_incremental >= 999  # one-hop refreshes ride the delta
    sub.refresh()
    labels = np.asarray(talg.connected_components(s.engine("numpy")), np.int64)
    np.testing.assert_array_equal(sub.value, labels)

    sub.close()
    gc.collect()
    # every sampled delta record and engine died with its version
    assert all(r() is None for r in delta_refs[:-1])
    assert all(r() is None for r in engine_refs[:-1])
    assert s.vg.live_versions() == 1
    assert s.vg.collected_versions() >= 999
