"""GraphQueryService: the serving front end (DESIGN.md §13).

Pins the PR's contracts:

  (1) served answers are bit-identical to ``query_batch`` against the
      same version, per kind and backend;
  (2) empty request sets are no-ops: ``query_batch`` returns ``[]``
      (the lane-collapse regression);
  (3) admission is weighted-fair (stride scheduling ~ weight ratio
      under saturation) and respects per-tenant in-flight caps and
      backlog backpressure (``QueueFull``);
  (4) the flush policy: deadline (half-budget) flushes go out before
      the SLO, full lanes flush at ``max_batch``, both visible in
      ``stats()``;
  (5) ``Session`` pinning is strictly serializable: a pinned session
      interleaved with live publishes returns bit-identical answers
      across every read, on numpy / jax (and sharded under an 8-device
      mesh), and sessions never leak version refs (1k publishes);
  (6) steady-state serving never retraces after ``warmup()`` — pinned
      by BOTH the service's trace-key accounting and the jit-body
      ``TRACES`` spy;
  (7) ``drain_updates`` / ``UpdateQueue`` semantics shared with
      ``run_concurrent``: batching, insert-before-delete, the weight
      lane, backpressure counts, and publish listeners.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import graph as G
from repro.core.streaming import AspenStream, UpdateQueue, drain_updates
from repro.core.traversal import TRACES
from repro.data.rmat import rmat_edges, symmetrize
from repro.serve.graph import GraphQueryService, QueueFull

N = 256


@pytest.fixture(scope="module")
def rmat_edge_list():
    return symmetrize(rmat_edges(8, 2000, seed=11))  # 256 vertices


def make_stream(edges, **kw):
    return AspenStream(G.build_graph(N, edges), **kw)


def make_service(edges, **kw):
    kw.setdefault("backend", "jax")
    kw.setdefault("max_batch", 8)
    kw.setdefault("default_deadline_s", 0.25)
    stream = make_stream(edges)
    return stream, GraphQueryService(stream, **kw)


# ---------------------------------------------------------------------------
# (1) served answers == query_batch answers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_served_answers_match_query_batch(rmat_edge_list, backend):
    stream, svc = make_service(rmat_edge_list, backend=backend)
    with svc:
        tickets = {
            "bfs": svc.submit("bfs", source=3),
            "sssp": svc.submit("sssp", source=5),
            "pagerank": svc.submit("pagerank"),
            "cc": svc.submit("cc"),
        }
        got = {k: t.result(timeout=30) for k, t in tickets.items()}
    ref_bfs = stream.query_batch([3], kind="bfs", backend=backend)[0]
    ref_sssp = stream.query_batch([5], kind="sssp", backend=backend)[0]
    assert np.array_equal(got["bfs"], ref_bfs)
    assert np.array_equal(got["sssp"], ref_sssp)
    assert got["pagerank"].shape == (N,)
    assert abs(float(np.asarray(got["pagerank"]).sum()) - 1.0) < 1e-3
    labels = np.asarray(got["cc"])
    assert labels.shape == (N,)
    # cc labels agree with the traversal layer's own answer
    from repro.core.traversal import algorithms as talg

    assert np.array_equal(labels, np.asarray(talg.connected_components(
        stream.engine(backend)), np.int64))


def test_duplicate_sources_one_compute_fan_out(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, max_batch=8,
                               default_deadline_s=0.5)
    with svc:
        ts = [svc.submit("bfs", source=7) for _ in range(6)]
        rows = [t.result(timeout=30) for t in ts]
    ref = stream.query_batch([7], kind="bfs", backend="jax")[0]
    for r in rows:
        assert np.array_equal(r, ref)


def test_ticket_validation():
    stream, svc = make_service(symmetrize(rmat_edges(8, 2000, seed=11)))
    with svc:
        with pytest.raises(ValueError):
            svc.submit("bfs")  # source required
        with pytest.raises(ValueError):
            svc.submit("nope", source=0)
    with pytest.raises(RuntimeError):
        svc.submit("bfs", source=0)  # stopped service rejects


# ---------------------------------------------------------------------------
# (2) empty request set -> [] (regression: used to raise)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_query_batch_empty_returns_empty_list(rmat_edge_list, backend):
    stream = make_stream(rmat_edge_list)
    for kind in ("bfs", "distances", "bc", "sssp"):
        assert stream.query_batch(None, kind=kind, backend=backend) == []
        assert stream.query_batch([], kind=kind, backend=backend) == []
        assert (
            stream.query_batch(np.empty(0, np.int64), kind=kind, backend=backend)
            == []
        )
    assert (
        stream.query_batch(
            kind="pagerank", backend=backend, resets=np.zeros((0, N))
        )
        == []
    )
    # unknown kinds still raise, even on empty request sets
    with pytest.raises(ValueError):
        stream.query_batch(None, kind="nope", backend=backend)


# ---------------------------------------------------------------------------
# (3) weighted fairness, in-flight caps, backpressure
# ---------------------------------------------------------------------------


def test_weighted_fair_admission(rmat_edge_list):
    """Under a saturated backlog, admissions track tenant weights:
    with caps forcing one flush at a time, a 3:1 weight split admits
    ~3x the requests for the heavy tenant over any window."""
    stream, svc = make_service(
        rmat_edge_list,
        tenant_weights={"heavy": 3.0, "light": 1.0},
        max_batch=4,
        max_inflight_total=4,
        default_deadline_s=10.0,  # no deadline flush: admission decides order
    )
    from repro.serve.graph.admission import AdmissionQueue
    from repro.serve.graph.request import QueryTicket

    # unit-test the scheduler itself (deterministic, no threads)
    q = AdmissionQueue(weights={"heavy": 3.0, "light": 1.0},
                       max_inflight_per_tenant=100, max_inflight_total=1000)
    for i in range(40):
        q.submit(QueryTicket("heavy", "bfs", i, {}, deadline=1e18))
        q.submit(QueryTicket("light", "bfs", i, {}, deadline=1e18))
    first = q.admit(max_n=20)
    heavy = sum(1 for t in first if t.tenant == "heavy")
    light = sum(1 for t in first if t.tenant == "light")
    assert heavy == 15 and light == 5  # exact 3:1 stride split

    # and end-to-end: everything completes despite the contention
    with svc:
        ts = [svc.submit("bfs", source=i % N, tenant="heavy") for i in range(12)]
        ts += [svc.submit("bfs", source=i % N, tenant="light") for i in range(12)]
        for t in ts:
            t.result(timeout=60)
        st = svc.stats()
    assert st["tenants"]["heavy"]["completed"] == 12
    assert st["tenants"]["light"]["completed"] == 12


def test_inflight_caps_and_backpressure(rmat_edge_list):
    from repro.serve.graph.admission import AdmissionQueue
    from repro.serve.graph.request import QueryTicket

    q = AdmissionQueue(max_inflight_per_tenant=2, max_inflight_total=3,
                       max_backlog=4)
    for i in range(4):
        q.submit(QueryTicket("a", "bfs", i, {}, deadline=1e18))
    with pytest.raises(QueueFull):
        q.submit(QueryTicket("a", "bfs", 9, {}, deadline=1e18))
    for i in range(2):
        q.submit(QueryTicket("b", "bfs", i, {}, deadline=1e18))
    admitted = q.admit()
    # per-tenant cap (2) binds for a; global cap (3) leaves b one slot
    assert sum(1 for t in admitted if t.tenant == "a") == 2
    assert sum(1 for t in admitted if t.tenant == "b") == 1
    assert q.admit() == []  # everything capped
    q.complete(admitted[0])
    assert len(q.admit()) == 1  # a completion frees exactly one slot


# ---------------------------------------------------------------------------
# (4) flush policy: deadline vs full-lane flushes
# ---------------------------------------------------------------------------


def test_full_lane_flushes_at_max_batch(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, max_batch=4,
                               default_deadline_s=30.0)
    with svc:
        svc.warmup(kinds=("bfs",))
        ts = [svc.submit("bfs", source=i) for i in range(8)]
        for t in ts:
            t.result(timeout=30)
        st = svc.stats()
    lane = st["lanes"]["bfs"]
    # 30s budgets mean nothing flushed early: both batches went out full
    assert lane["full_flushes"] >= 2
    assert lane["batch_size_hist"].get(4, 0) >= 2
    for t in ts:
        assert t.batch_size == 4
        assert t.deadline_missed is False


def test_work_conserving_flushes_idle_executor(rmat_edge_list):
    """With work_conserving=True a lone request flushes as soon as the
    executor is free — well before the half-budget instant — and the
    flush is accounted as an idle flush."""
    stream, svc = make_service(rmat_edge_list, max_batch=64,
                               default_deadline_s=30.0, work_conserving=True)
    with svc:
        svc.warmup(kinds=("bfs",))
        t = svc.submit("bfs", source=1)
        t.result(timeout=30)
        st = svc.stats()
    assert t.latency_s < 5.0  # nowhere near the 15s half-budget mark
    assert st["lanes"]["bfs"]["idle_flushes"] >= 1


def test_deadline_flush_before_slo(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, max_batch=64,
                               default_deadline_s=0.3)
    with svc:
        svc.warmup(kinds=("bfs",))
        t = svc.submit("bfs", source=1)  # alone in its lane: never fills
        r = t.result(timeout=30)
        st = svc.stats()
    assert r.shape == (N,)
    assert st["lanes"]["bfs"]["deadline_flushes"] >= 1
    # the half-budget rule waited ~>= 0.15s but answered within the SLO
    assert t.deadline_missed is False


# ---------------------------------------------------------------------------
# (5) session pinning: strict serializability + ref hygiene
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_session_strictly_serializable(rmat_edge_list, backend):
    """A pinned session interleaved with publishes answers every read
    from its open-time version, bit-identical across kinds — while
    unpinned reads see the new edges."""
    stream, svc = make_service(rmat_edge_list, backend=backend)
    with svc:
        with svc.session(tenant="t") as sess:
            stamp0 = sess.stamp
            bfs0 = sess.query("bfs", source=3).result(timeout=30)
            sssp0 = sess.query("sssp", source=3).result(timeout=30)
            pr0 = sess.query("pagerank").result(timeout=30)
            # publish between every pair of session reads
            for i in range(3):
                svc.insert_edges(np.array([[3, 200 + i], [200 + i, 210 + i]]))
                svc.flush_updates()
                assert np.array_equal(
                    sess.query("bfs", source=3).result(timeout=30), bfs0
                )
                assert np.array_equal(
                    sess.query("sssp", source=3).result(timeout=30), sssp0
                )
                assert np.array_equal(
                    sess.query("pagerank").result(timeout=30), pr0
                )
            assert sess.stamp == stamp0
            fresh = svc.submit("bfs", source=3).result(timeout=30)
        assert stream.vg.current_stamp > stamp0
        assert not np.array_equal(fresh, bfs0)  # unpinned reads advanced


@pytest.mark.multidevice
def test_session_strictly_serializable_sharded(rmat_edge_list):
    stream = AspenStream(G.build_graph(N, rmat_edge_list), mirror="sharded",
                         n_shards=8)
    svc = GraphQueryService(stream, backend="sharded", max_batch=4)
    with svc:
        with svc.session(tenant="t") as sess:
            bfs0 = sess.query("bfs", source=3).result(timeout=60)
            svc.insert_edges(np.array([[3, 200], [200, 210]]))
            svc.flush_updates()
            assert np.array_equal(
                sess.query("bfs", source=3).result(timeout=60), bfs0
            )
            fresh = svc.submit("bfs", source=3).result(timeout=60)
        assert not np.array_equal(fresh, bfs0)


def test_sessions_do_not_leak_versions(rmat_edge_list):
    """1k publishes with sessions opened/closed throughout leave no
    extra live versions once closed (GC reclaims everything behind the
    current version)."""
    stream, svc = make_service(rmat_edge_list, backend="numpy")
    with svc:
        for i in range(1000):
            stream.insert_edges(
                np.array([[i % N, (i * 7 + 1) % N]]), symmetric=False
            )
            if i % 100 == 0:
                with svc.session(tenant="t") as s:
                    s.query("bfs", source=0).result(timeout=30)
        assert svc.stats()["sessions_open"] == 0
    assert stream.vg.live_versions() == 1  # only current survives


def test_session_close_is_idempotent_and_blocks_new_queries(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, backend="numpy")
    with svc:
        sess = svc.session(tenant="t")
        sess.query("bfs", source=0).result(timeout=30)
        sess.close()
        sess.close()  # idempotent
        with pytest.raises(RuntimeError):
            sess.query("bfs", source=0)


# ---------------------------------------------------------------------------
# (6) zero retraces after warmup
# ---------------------------------------------------------------------------


def test_zero_retraces_after_warmup(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, max_batch=8)
    with svc:
        svc.warmup()
        before = TRACES.count
        rng = np.random.default_rng(0)
        tickets = []
        for _ in range(40):
            tickets.append(svc.submit("bfs", source=int(rng.integers(N))))
            tickets.append(svc.submit("sssp", source=int(rng.integers(N))))
        tickets.append(svc.submit("pagerank"))
        tickets.append(svc.submit("cc"))
        for t in tickets:
            t.result(timeout=60)
        st = svc.stats()
    # both spies agree: nothing compiled in steady state
    assert TRACES.count == before, "jit drivers retraced after warmup"
    for kind, lane in st["lanes"].items():
        assert lane["retraces"] == 0, (kind, lane)


def test_capacity_growth_is_a_legitimate_retrace(rmat_edge_list):
    """A pool-capacity-growing publish changes array shapes, so the
    NEXT flush traces fresh code — the trace-key accounting must call
    that out (retraces > 0) rather than hide it."""
    stream, svc = make_service(rmat_edge_list, max_batch=4)
    cap0 = stream.flat_graph().edge_capacity
    with svc:
        svc.warmup(kinds=("bfs",))
        # bulk insert until the pool capacity actually grows
        rng = np.random.default_rng(1)
        while stream.flat_graph().edge_capacity == cap0:
            stream.insert_edges(rng.integers(0, N, (512, 2)))
        svc.submit("bfs", source=0).result(timeout=60)
        st = svc.stats()
    assert st["lanes"]["bfs"]["retraces"] >= 1


# ---------------------------------------------------------------------------
# (7) drain_updates / UpdateQueue shared writer-loop semantics
# ---------------------------------------------------------------------------


def test_drain_updates_batches_and_orders(rmat_edge_list):
    stream = make_stream(rmat_edge_list)
    v0 = stream.acquire()
    m0 = G.num_edges(v0.graph)
    stream.release(v0)
    q = UpdateQueue()
    # interleaved: insert applies before the delete within one drain,
    # so the pair cancels — edge count is back where it started
    q.put(1, 240)
    q.put(1, 240, delete=True)
    stamp0 = stream.vg.current_stamp
    assert drain_updates(q, stream, max_batch=10) == 2
    v1 = stream.acquire()
    m1 = G.num_edges(v1.graph)
    stream.release(v1)
    assert m1 == m0
    assert stream.vg.current_stamp > stamp0
    assert drain_updates(q, stream, max_batch=10) == 0  # empty: no-op


def test_drain_updates_weight_lane():
    stream = AspenStream(G.build_graph(8, np.array([[0, 1]])))
    q = UpdateQueue()
    q.put(2, 3, weight=2.5)
    q.put(4, 5)  # weight-less row in a mixed batch rides with unit fill
    assert drain_updates(q, stream, max_batch=10) == 2
    eng = stream.engine("numpy")
    assert eng.weighted
    dist = stream.query_batch([2], kind="sssp", backend="numpy")[0]
    assert dist[3] == 2.5
    dist = stream.query_batch([4], kind="sssp", backend="numpy")[0]
    assert dist[5] == 1.0


def test_update_queue_backpressure_and_stats():
    q = UpdateQueue(maxsize=2)
    assert q.put(0, 1, block=False)
    assert q.put(1, 2, block=False)
    assert not q.put(2, 3, block=False)  # full: rejected, counted
    st = q.stats()
    assert st["rejected"] == 1 and st["depth"] == 2 and st["high_water"] == 2
    rows = q.pop_batch(10)
    assert len(rows) == 2 and len(q) == 0


def test_publish_listener_fires_and_unsubscribes():
    stream = AspenStream(G.build_graph(8, np.array([[0, 1]])))
    stamps = []
    unsub = stream.on_publish(lambda v: stamps.append(v.stamp))
    stream.insert_edges(np.array([[1, 2]]))
    assert stamps == [1]
    unsub()
    stream.insert_edges(np.array([[2, 3]]))
    assert stamps == [1]  # unsubscribed: no further calls


def test_service_under_live_writer(rmat_edge_list):
    """The integration shape the smoke script uses: mixed queries from
    two tenants racing a continuous writer, everything completes, clean
    shutdown, coherent stats."""
    stream, svc = make_service(rmat_edge_list, max_batch=8,
                               default_deadline_s=1.0)
    rng = np.random.default_rng(7)
    with svc:
        svc.warmup(kinds=("bfs", "sssp"))
        stop = threading.Event()

        def writer():
            i = 0
            while not stop.is_set():
                svc.enqueue_update(int(rng.integers(N)), int(rng.integers(N)),
                                   delete=(i % 5 == 4), block=False)
                i += 1
                time.sleep(0.001)

        wt = threading.Thread(target=writer)
        wt.start()
        try:
            tickets = []
            for i in range(60):
                kind = "bfs" if i % 2 else "sssp"
                tenant = "a" if i % 3 else "b"
                tickets.append(
                    svc.submit(kind, source=int(rng.integers(N)), tenant=tenant)
                )
            results = [t.result(timeout=60) for t in tickets]
        finally:
            stop.set()
            wt.join()
        svc.flush_updates()
        st = svc.stats()
    assert len(results) == 60 and all(r.shape == (N,) for r in results)
    assert st["publishes"] >= 1
    assert st["admission"]["in_flight"] == 0 and st["admission"]["backlog"] == 0
    done = sum(v["completed"] for v in st["tenants"].values())
    assert done == 60
