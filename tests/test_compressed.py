"""Compressed device pool (DESIGN.md §10): codec roundtrips, the
chunk_stats host reference vs the real builder, flat + sharded
compressed-vs-raw engine parity, and the streaming compressed mirrors.

All compressed queries must be BIT-IDENTICAL to their raw counterparts
for integer-state algorithms (BFS / CC / SSSP-with-integer-weights) and
float32-close for PageRank — the compression is a layout change, never a
semantics change.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compressed as cz
from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core import sharded_pool as sp
from repro.core.streaming import AspenStream, make_update_stream
from repro.core.traversal import make_engine
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize

N_SHARDS = 4


def _weights_for(edges):
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    return ((lo * 1000003 + hi) % 7 + 1).astype(np.float64)  # symmetric, integer


@pytest.fixture(scope="module")
def rmat_graph():
    edges = symmetrize(rmat_edges(8, 2000, seed=11))  # 256 vertices
    return 256, edges


@pytest.fixture(scope="module")
def flat_engines(rmat_graph):
    n, edges = rmat_graph
    w = _weights_for(edges)
    g = fg.from_edges(n, edges, weights=w)
    return make_engine(g), make_engine(fg.compress_host(g))


@pytest.fixture(scope="module")
def sharded_engines(rmat_graph):
    n, edges = rmat_graph
    w = _weights_for(edges)
    sg = sp.graph_from_edges(n, edges, n_shards=N_SHARDS, weights=w)
    return make_engine(sg), make_engine(sp.compress_sharded(sg))


@pytest.fixture(scope="module")
def sources(rmat_graph):
    n, _ = rmat_graph
    return np.random.default_rng(3).integers(0, n, 8)


# ---------------------------------------------------------------------------
# (1) codec: encode/decode roundtrips, escapes, spill detection
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [1, 2])
@pytest.mark.parametrize("L", [1, 100, cz.CHUNK, cz.CHUNK * 3 + 17])
def test_codec_roundtrip_small_deltas(width, L):
    """Deltas within the lane limit: exact roundtrip at any length,
    including non-multiple-of-CHUNK tails."""
    rng = np.random.default_rng(0)
    lim = 127 if width == 1 else 32767
    vals = np.cumsum(rng.integers(-lim // 2, lim // 2, L)).astype(np.int32)
    c = cz.encode_stream(jnp.asarray(vals), width=width)
    assert not bool(c.spill)
    assert c.width == width and c.k == cz.OVF_SLOTS
    np.testing.assert_array_equal(
        np.asarray(cz.decode_stream(c, length=L)), vals
    )


def test_codec_roundtrip_with_escapes():
    """Deltas past the int16 lane go through the escape lane and still
    roundtrip exactly (up to k per chunk)."""
    rng = np.random.default_rng(1)
    deltas = rng.integers(0, 100, 3 * cz.CHUNK)
    # drop k overflow deltas into each chunk, scattered columns
    for r in range(3):
        cols = rng.choice(np.arange(1, cz.CHUNK), cz.OVF_SLOTS, replace=False)
        deltas[r * cz.CHUNK + cols] = rng.integers(40_000, 1 << 20, cz.OVF_SLOTS)
    vals = np.cumsum(deltas).astype(np.int32)
    c = cz.encode_stream(jnp.asarray(vals), width=2)
    assert not bool(c.spill)
    assert int(np.asarray(c.ovf_pos < cz.CHUNK).sum()) == 3 * cz.OVF_SLOTS
    np.testing.assert_array_equal(
        np.asarray(cz.decode_stream(c, length=vals.size)), vals
    )


def test_codec_spill_flag():
    """> k escapes in one chunk sets the spill flag (decode is unsound)."""
    deltas = np.full(cz.CHUNK, 40_000, np.int64)  # every delta escapes
    vals = np.cumsum(deltas).astype(np.int32)
    c = cz.encode_stream(jnp.asarray(vals), width=2)
    assert bool(c.spill)


def test_decode_rows_batched_matches_per_row():
    """decode_rows is ndim-aware: an (S, R, CHUNK) batch decodes exactly
    as S independent streams (the sharded engines rely on this)."""
    rng = np.random.default_rng(2)
    streams = [
        cz.encode_stream(
            jnp.asarray(np.cumsum(rng.integers(0, 500, 2 * cz.CHUNK)), jnp.int32),
            width=2,
        )
        for _ in range(3)
    ]
    batched = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *streams)
    got = np.asarray(cz.decode_rows(batched))
    for s_i, c in enumerate(streams):
        np.testing.assert_array_equal(got[s_i], np.asarray(cz.decode_rows(c)))


# ---------------------------------------------------------------------------
# (2) chunk_stats host reference vs the real compressed builder
# ---------------------------------------------------------------------------


def test_chunk_stats_matches_builder(rmat_graph):
    n, edges = rmat_graph
    g = fg.from_edges(n, edges)
    stats = fg.chunk_stats(g)
    cg = fg.compress(g, width=2)
    # same fixed chunk geometry
    assert stats["fixed_chunks"] == cg.dst.anchors.shape[0]
    # escape counts / spill must agree with what the device encoder built
    used = int(np.asarray(cg.dst.ovf_pos < cz.CHUNK).sum())
    assert stats["escapes_i16"] == used
    assert stats["spill_i16"] == bool(cg.dst.spill)
    # the fixed-width byte model is exactly the resident stream size
    assert stats["bytes_fixed"][2] == cz.stream_nbytes(cg.dst)
    cg8 = fg.compress(g, width=1)
    assert stats["spill_i8"] == bool(cg8.dst.spill)
    if not stats["spill_i8"]:
        assert stats["escapes_i8"] == int(np.asarray(cg8.dst.ovf_pos < cz.CHUNK).sum())
        assert stats["bytes_fixed"][1] == cz.stream_nbytes(cg8.dst)
    # canonical (hash-head) chunking exists and is no coarser than 1/b
    assert 0 < stats["canonical_chunks"] <= int(g.m)
    assert stats["bytes_ideal"] <= stats["bytes_fixed"][2]


def test_compress_roundtrip_exact(rmat_graph):
    n, edges = rmat_graph
    w = _weights_for(edges)
    g = fg.from_edges(n, edges, weights=w)
    g2 = fg.decompress(fg.compress_host(g))
    np.testing.assert_array_equal(np.asarray(g.keys), np.asarray(g2.keys))
    np.testing.assert_array_equal(np.asarray(g.offsets), np.asarray(g2.offsets))
    assert int(g.m) == int(g2.m)
    np.testing.assert_array_equal(
        np.asarray(g.weights), np.asarray(g2.weights)[: g.edge_capacity]
    )


def test_sharded_compress_roundtrip_exact(rmat_graph):
    n, edges = rmat_graph
    w = _weights_for(edges)
    sg = sp.graph_from_edges(n, edges, n_shards=N_SHARDS, weights=w)
    sg2 = sp.decompress_sharded(sp.compress_sharded(sg))
    np.testing.assert_array_equal(
        np.asarray(sg.pool.data), np.asarray(sg2.pool.data)
    )
    np.testing.assert_array_equal(np.asarray(sg.pool.n), np.asarray(sg2.pool.n))
    np.testing.assert_array_equal(
        np.asarray(sg.pool.vals),
        np.asarray(sg2.pool.vals)[:, : sg.pool.data.shape[1]],
    )


# ---------------------------------------------------------------------------
# (3) engine parity: compressed == raw, flat + sharded backends
# ---------------------------------------------------------------------------


def _parity_suite(raw, comp, edges, sources):
    src = int(edges[0, 0])
    np.testing.assert_array_equal(talg.bfs(raw, src), talg.bfs(comp, src))
    np.testing.assert_array_equal(
        talg.bfs_multi(raw, sources), talg.bfs_multi(comp, sources)
    )
    np.testing.assert_array_equal(
        talg.connected_components(raw), talg.connected_components(comp)
    )
    # integer weights -> identical path sums -> exact SSSP equality
    np.testing.assert_array_equal(
        np.asarray(talg.sssp(raw, src)), np.asarray(talg.sssp(comp, src))
    )
    np.testing.assert_array_equal(
        talg.sssp_multi(raw, sources), talg.sssp_multi(comp, sources)
    )
    assert np.allclose(
        talg.pagerank(raw, iters=5), talg.pagerank(comp, iters=5), atol=1e-5
    )


def test_flat_parity(rmat_graph, flat_engines, sources):
    _, edges = rmat_graph
    _parity_suite(*flat_engines, edges, sources)


def test_sharded_parity(rmat_graph, sharded_engines, sources):
    _, edges = rmat_graph
    _parity_suite(*sharded_engines, edges, sources)


def test_weighted_degrees_parity(flat_engines, sharded_engines):
    for raw, comp in (flat_engines, sharded_engines):
        np.testing.assert_allclose(
            np.asarray(raw.weighted_degrees), np.asarray(comp.weighted_degrees)
        )
        np.testing.assert_array_equal(
            np.asarray(raw.degrees), np.asarray(comp.degrees)
        )
        assert raw.m == comp.m and raw.n == comp.n


def test_edge_map_reduce_parity(rmat_graph, flat_engines, sharded_engines):
    n, _ = rmat_graph
    vals = np.random.default_rng(5).random((4, n))
    for raw, comp in (flat_engines, sharded_engines):
        got = np.asarray(comp.edge_map_reduce_batch(comp.ops.xp.asarray(vals)))
        want = np.asarray(raw.edge_map_reduce_batch(raw.ops.xp.asarray(vals)))
        np.testing.assert_allclose(got, want, atol=1e-5)


def test_resident_bytes_reduction(flat_engines, sharded_engines):
    """The headline claim: >= 2x whole-engine resident-bytes reduction
    (paper T2 reports 4.7-11.3x on bytes/edge with variable-width chunks;
    the fixed-width device layout clears 2x on RMAT comfortably)."""
    for raw, comp in (flat_engines, sharded_engines):
        assert raw.resident_nbytes / comp.resident_nbytes >= 2.0


def test_spilled_stream_is_rejected():
    """A graph whose delta profile overflows the escape lane: the host
    builder raises, and an engine over a force-built spilled pool raises
    rather than serving unsound decodes."""
    # one src, 9+ consecutive gaps just past the int16 limit -> one chunk
    # with > OVF_SLOTS escapes
    dsts = np.arange(cz.OVF_SLOTS + 2, dtype=np.int64) * 32_768
    edges = np.stack([np.zeros_like(dsts), dsts], axis=1)
    n = int(dsts.max()) + 1
    g = fg.from_edges(n, edges)
    with pytest.raises(ValueError, match="escape"):
        fg.compress_host(g)
    cg = fg.compress(g, width=2)  # jit path: no host check, flag set
    assert bool(cg.dst.spill)
    with pytest.raises(ValueError, match="spill"):
        make_engine(cg)


# ---------------------------------------------------------------------------
# (4) streaming: compressed mirrors under interleaved insert/delete
# ---------------------------------------------------------------------------


def _stream_pair(n, keep, mirror):
    raw = AspenStream(G.build_graph(n, keep), mirror=mirror)
    com = AspenStream(G.build_graph(n, keep), mirror=mirror, compressed=True)
    return raw, com


def _assert_stream_parity(raw, com, mirror):
    if mirror == "sharded":
        a, b = raw.sharded_graph(), com.sharded_graph()
        np.testing.assert_array_equal(
            np.asarray(a.pool.data), np.asarray(b.pool.data)
        )
        np.testing.assert_array_equal(np.asarray(a.pool.n), np.asarray(b.pool.n))
    else:
        a, b = raw.flat_graph(), com.flat_graph()
        np.testing.assert_array_equal(fg.to_edge_array(a), fg.to_edge_array(b))
        assert int(a.m) == int(b.m)


@pytest.mark.parametrize("mirror", ["flat", "sharded"])
def test_stream_interleaved_parity(mirror):
    edges = symmetrize(rmat_edges(7, 900, seed=13))  # 128 vertices
    keep, stream = make_update_stream(edges, 400, seed=3)
    raw, com = _stream_pair(128, keep, mirror)
    for i in range(0, stream.shape[0], 100):
        batch = stream[i : i + 100]
        ins = batch[batch[:, 2] == 0][:, :2]
        dels = batch[batch[:, 2] == 1][:, :2]
        if ins.size:
            raw.insert_edges(ins)
            com.insert_edges(ins)
        if dels.size:
            raw.delete_edges(dels)
            com.delete_edges(dels)
        _assert_stream_parity(raw, com, mirror)
    # the compressed stream's engine dispatches to the compressed backend
    backend = "sharded" if mirror == "sharded" else "jax"
    eng_raw, eng_com = raw.engine(backend), com.engine(backend)
    assert type(eng_raw) is not type(eng_com)
    src = int(edges[0, 0])
    np.testing.assert_array_equal(talg.bfs(eng_raw, src), talg.bfs(eng_com, src))
    np.testing.assert_array_equal(
        talg.connected_components(eng_raw), talg.connected_components(eng_com)
    )


def test_stream_weighted_inserts_compressed():
    edges = symmetrize(rmat_edges(7, 700, seed=5))
    w = _weights_for(edges)
    half = len(edges) // 2
    raw = AspenStream(G.build_graph(128, edges[:half], weights=w[:half]))
    com = AspenStream(
        G.build_graph(128, edges[:half], weights=w[:half]), compressed=True
    )
    raw.insert_edges(edges[half:], weights=w[half:])
    com.insert_edges(edges[half:], weights=w[half:])
    a, b = raw.flat_graph(), com.flat_graph()
    np.testing.assert_array_equal(fg.to_edge_array(a), fg.to_edge_array(b))
    src = int(edges[0, 0])
    np.testing.assert_array_equal(
        np.asarray(talg.sssp(raw.engine("jax"), src)),
        np.asarray(talg.sssp(com.engine("jax"), src)),
    )


# ---------------------------------------------------------------------------
# (5) adaptive per-chunk widths (DESIGN.md §12)
# ---------------------------------------------------------------------------


def test_adaptive_codec_roundtrip_mixed_widths():
    """Chunks with int8-sized deltas stay narrow, chunks with int16-sized
    deltas go wide (hi plane), escapes still handle the >int16 outliers —
    and the decode is exact through all three regimes."""
    rng = np.random.default_rng(7)
    R = 6
    deltas = rng.integers(0, 100, R * cz.CHUNK)  # narrow by default
    deltas[2 * cz.CHUNK : 3 * cz.CHUNK] = rng.integers(200, 30_000, cz.CHUNK)
    deltas[4 * cz.CHUNK : 5 * cz.CHUNK] = rng.integers(200, 30_000, cz.CHUNK)
    cols = rng.choice(np.arange(1, cz.CHUNK), 4, replace=False)
    deltas[cols] = rng.integers(40_000, 1 << 20, 4)  # escapes in chunk 0
    vals = np.cumsum(deltas).astype(np.int32)
    c = cz.encode_stream_adaptive(jnp.asarray(vals), hi_cap=R)
    assert not bool(c.spill)
    assert c.adaptive
    wide = np.asarray(c.wide)
    assert wide[2] and wide[4] and not wide[0]
    np.testing.assert_array_equal(
        np.asarray(cz.decode_stream(c, length=vals.size)), vals
    )


def test_adaptive_narrow_graph_has_empty_hi_plane():
    """An all-narrow graph pays zero hi-plane bytes: compress_host slices
    the plane to the exact wide-row count (here 0)."""
    edges = np.stack([np.repeat(np.arange(32), 8), np.tile(np.arange(8), 32)], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    g = fg.from_edges(64, edges)
    cg = fg.compress_host(g)
    assert cg.dst.hi is not None and cg.dst.hi.shape[-2] == 0
    np.testing.assert_array_equal(
        np.asarray(fg.decompress(cg).keys), np.asarray(g.keys)
    )


@pytest.mark.parametrize("seed,log_n,m", [(11, 8, 2000), (23, 9, 4000), (5, 10, 9000)])
def test_adaptive_bytes_ideal_is_exact_on_rmat(seed, log_n, m):
    """Satellite (c): the resident byte count of the adaptive pool equals
    ``chunk_stats.bytes_ideal`` EXACTLY on random RMAT streams, and never
    exceeds the fixed int16-wide layout."""
    n = 1 << log_n
    edges = symmetrize(rmat_edges(log_n, m, seed=seed))
    g = fg.from_edges(n, edges)
    cg = fg.compress_host(g)
    stats = fg.chunk_stats(g)
    resident = cz.stream_nbytes(cg.dst)
    assert resident == stats["bytes_ideal"]
    cg2 = fg.compress_host(g, width=2)
    assert resident <= cz.stream_nbytes(cg2.dst)
    # and the layout change is still semantics-free
    np.testing.assert_array_equal(
        np.asarray(fg.decompress(cg).keys), np.asarray(g.keys)
    )


def test_adaptive_sharded_bytes_not_worse_than_fixed(rmat_graph):
    n, edges = rmat_graph
    sg = sp.graph_from_edges(n, edges, n_shards=N_SHARDS)
    ca = sp.compress_sharded(sg)
    c2 = sp.compress_sharded(sg, width=2)
    assert ca.pool.dst.adaptive
    assert cz.stream_nbytes(ca.pool.dst) <= cz.stream_nbytes(c2.pool.dst)
    np.testing.assert_array_equal(
        np.asarray(sp.decompress_sharded(ca).pool.data), np.asarray(sg.pool.data)
    )


def test_adaptive_insert_delete_keeps_widths(rmat_graph):
    """The decompress->merge->recompress step re-selects widths under the
    inherited hi capacity; the result decodes exactly after both an
    insert and a delete batch."""
    n, edges = rmat_graph
    half = len(edges) // 2
    want = fg.from_edges(n, edges)
    cap = want.edge_capacity
    g = fg.from_edges(n, edges[:half], edge_capacity=cap)
    # hi_headroom=1.0 -> full hi capacity: any chunk may turn wide later
    cg = fg.compress_host(g, hi_headroom=1.0)
    cg2 = fg.insert_edges_compressed(cg, fg.batch_from_edges(edges[half:]), cap)
    assert not bool(cg2.dst.spill)
    assert cg2.dst.hi.shape[-2] == cg.dst.hi.shape[-2]  # capacity inherited
    np.testing.assert_array_equal(
        fg.to_edge_array(fg.decompress(cg2)), fg.to_edge_array(want)
    )
    cg3 = fg.delete_edges_compressed(cg2, fg.batch_from_edges(edges[:100]), cap)
    want2 = fg.delete_edges_host(want, edges[:100])
    np.testing.assert_array_equal(
        fg.to_edge_array(fg.decompress(cg3)), fg.to_edge_array(want2)
    )


def test_bc_parity_compressed(flat_engines, sources):
    raw, comp = flat_engines
    np.testing.assert_allclose(
        np.asarray(talg.bc_multi(raw, sources[:4])),
        np.asarray(talg.bc_multi(comp, sources[:4])),
        rtol=1e-4, atol=1e-4,
    )


def test_stream_compressed_requires_mirror():
    with pytest.raises(ValueError, match="mirror"):
        AspenStream(G.build_graph(8, np.array([[0, 1], [1, 0]])), mirror=False,
                    compressed=True)
