"""Sharded traversal engine (DESIGN.md §9): the backend-generic parity
suite over the range-sharded pool.

Pins the PR's contract:

  (1) ``ShardedEngine`` passes the backend parity suite — BFS
      parents/levels, CC labels, PageRank, SSSP distances match the
      numpy engine on RMAT graphs (BFS/CC/SSSP exactly; PageRank to
      float tolerance, summation order differs);
  (2) parity holds THROUGH the streaming path: interleaved
      insert/delete batches, a mid-stream weight upgrade and a forced
      rebalance, served by ``AspenStream(mirror="sharded")``;
  (3) per-round collective traffic is O(frontier + batch), never
      O(pool) — asserted on the jaxpr via the collective-bytes spy;
  (4) the in-trace batched drivers keep the O(1)-host-syncs contract;
  (5) ``engine("sharded")`` is version-pinned-cached and
      ``query_batch`` routes to it on sharded streams.

Non-``multidevice`` tests run the same shard_map code on a 1-device
mesh with multi-row blocks (n_shards=4); ``multidevice``-marked tests
need ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (a
separate pytest process — see conftest) and pin the acceptance
criterion on a real 8-way mesh, one shard row per device.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.core import sharded_pool as sp
from repro.core.streaming import AspenStream, make_update_stream
from repro.core.traversal import HOST_SYNCS, NumpyEngine, make_engine
from repro.core.traversal import algorithms as talg
from repro.core.traversal import sharded_backend as sb
from repro.data.rmat import rmat_edges, symmetrize

N_SHARDS = 4  # divisible block layout even on a 1-device mesh


def _weights_for(edges):
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    return ((lo * 1000003 + hi) % 7 + 1).astype(np.float64)  # symmetric, integer


@pytest.fixture(scope="module")
def rmat_graph():
    edges = symmetrize(rmat_edges(8, 2000, seed=11))  # 256 vertices
    return 256, edges


@pytest.fixture(scope="module")
def engines(rmat_graph):
    n, edges = rmat_graph
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
    eng_sh = make_engine(sp.graph_from_edges(n, edges, n_shards=N_SHARDS))
    return eng_np, eng_sh


@pytest.fixture(scope="module")
def weighted_engines(rmat_graph):
    n, edges = rmat_graph
    w = _weights_for(edges)
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges, weights=w)))
    eng_sh = make_engine(
        sp.graph_from_edges(n, edges, n_shards=N_SHARDS, weights=w)
    )
    return eng_np, eng_sh


@pytest.fixture(scope="module")
def sources(rmat_graph):
    n, _ = rmat_graph
    return np.random.default_rng(3).integers(0, n, 16)


# ---------------------------------------------------------------------------
# (1) backend-generic parity suite
# ---------------------------------------------------------------------------


def test_bfs_parity(rmat_graph, engines):
    n, edges = rmat_graph
    eng_np, eng_sh = engines
    src = int(edges[0, 0])
    p_np = talg.bfs(eng_np, src)
    p_sh = talg.bfs(eng_sh, src)
    np.testing.assert_array_equal(p_np, p_sh)  # same max-parent rule
    np.testing.assert_array_equal(
        talg.bfs_depths(p_np, src), talg.bfs_depths(p_sh, src)
    )


def test_bfs_multi_parity(engines, sources):
    eng_np, eng_sh = engines
    p_np, d_np = talg.bfs_multi(eng_np, sources)
    p_sh, d_sh = talg.bfs_multi(eng_sh, sources)  # in-trace sharded driver
    np.testing.assert_array_equal(p_np, p_sh)
    np.testing.assert_array_equal(d_np, d_sh)


def test_cc_parity(engines):
    eng_np, eng_sh = engines
    np.testing.assert_array_equal(
        talg.connected_components(eng_np), talg.connected_components(eng_sh)
    )


def test_pagerank_parity(engines):
    eng_np, eng_sh = engines
    pr_np = talg.pagerank(eng_np, iters=5)
    pr_sh = talg.pagerank(eng_sh, iters=5)
    assert np.allclose(pr_np, pr_sh, atol=1e-5)
    prm_np = talg.pagerank_multi(eng_np, iters=5)
    prm_sh = talg.pagerank_multi(eng_sh, iters=5)
    assert np.allclose(prm_np, prm_sh, atol=1e-5)


def test_sssp_parity_exact(rmat_graph, weighted_engines, sources):
    """Integer weights: every candidate path sum is computed identically
    and min is order-insensitive, so distances match EXACTLY."""
    n, edges = rmat_graph
    eng_np, eng_sh = weighted_engines
    src = int(edges[0, 0])
    d_np = np.asarray(talg.sssp(eng_np, src), np.float64)
    d_sh = np.asarray(talg.sssp(eng_sh, src), np.float64)
    np.testing.assert_array_equal(d_np, d_sh)
    np.testing.assert_array_equal(
        talg.sssp_multi(eng_np, sources), talg.sssp_multi(eng_sh, sources)
    )


def test_sssp_unweighted_hop_distances(engines, sources):
    """On an unweighted engine sssp runs unit weights = BFS hop metric."""
    eng_np, eng_sh = engines
    np.testing.assert_array_equal(
        talg.sssp_multi(eng_np, sources[:4]), talg.sssp_multi(eng_sh, sources[:4])
    )


def test_bc_parity(rmat_graph, engines):
    n, edges = rmat_graph
    eng_np, eng_sh = engines
    src = int(edges[0, 0])
    assert np.allclose(talg.bc(eng_np, src), talg.bc(eng_sh, src), atol=1e-4)


def test_bc_batch_sharded_parity(engines, sources):
    """bc_multi routes through the in-trace ``bc_batch_sharded`` driver
    (one psum per BFS level instead of per-source generic rounds); f32
    sum order differs across shards, so tolerance not bit-equality."""
    eng_np, eng_sh = engines
    assert hasattr(eng_sh, "bc_batch")
    got = np.asarray(talg.bc_multi(eng_sh, sources[:6]))
    want = np.asarray(talg.bc_multi(eng_np, sources[:6]))
    assert np.allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bc_batch_sharded_compressed_parity(rmat_graph, sources):
    n, edges = rmat_graph
    sg = sp.graph_from_edges(n, edges, n_shards=N_SHARDS)
    eng_raw = make_engine(sg)
    eng_cmp = make_engine(sp.compress_sharded(sg))
    got = np.asarray(talg.bc_multi(eng_cmp, sources[:6]))
    want = np.asarray(talg.bc_multi(eng_raw, sources[:6]))
    assert np.allclose(got, want, rtol=1e-4, atol=1e-4)


def test_weighted_pagerank_parity(weighted_engines):
    eng_np, eng_sh = weighted_engines
    assert np.allclose(
        talg.weighted_pagerank(eng_np, iters=5),
        talg.weighted_pagerank(eng_sh, iters=5),
        atol=1e-5,
    )


def test_edge_map_reduce_parity(rmat_graph, weighted_engines):
    n, _ = rmat_graph
    eng_np, eng_sh = weighted_engines
    vals = np.random.default_rng(0).standard_normal(n)
    out_np = eng_np.edge_map_reduce(vals)
    out_sh = eng_sh.edge_map_reduce(jnp.asarray(vals, jnp.float32))
    assert np.allclose(out_np, np.asarray(out_sh), atol=1e-4)
    batch = np.random.default_rng(1).standard_normal((4, n))
    out_npb = np.stack([eng_np.edge_map_reduce(v) for v in batch])
    out_shb = eng_sh.edge_map_reduce_batch(jnp.asarray(batch, jnp.float32))
    assert np.allclose(out_npb, np.asarray(out_shb), atol=1e-4)


def test_weighted_degrees_parity(weighted_engines):
    eng_np, eng_sh = weighted_engines
    assert np.allclose(
        eng_np.weighted_degrees, np.asarray(eng_sh.weighted_degrees), atol=1e-4
    )
    assert np.asarray(eng_sh.degrees).sum() == eng_sh.m


@pytest.mark.parametrize("frontier", ["small", "large"])
def test_modes_agree(rmat_graph, engines, frontier):
    """Forced dense == forced sparse == auto on the sharded engine (the
    jax-backend invariant, ported)."""
    n, edges = rmat_graph
    _, eng_sh = engines
    from repro.core.traversal.algorithms import _bfs_relax, _bfs_unvisited

    ids = [int(edges[0, 0])] if frontier == "small" else list(range(0, n, 2))
    outs = {}
    for mode in ("dense", "sparse", "auto"):
        U = eng_sh.frontier_from_ids(ids)
        parents = jnp.full(n, -1, jnp.int64).at[jnp.asarray(ids)].set(
            jnp.asarray(ids, jnp.int64)
        )
        U2, parents2 = eng_sh.edge_map(U, _bfs_relax, _bfs_unvisited, parents, mode=mode)
        outs[mode] = (np.asarray(U2.to_dense()), np.asarray(parents2))
    for mode in ("sparse", "auto"):
        np.testing.assert_array_equal(outs["dense"][0], outs[mode][0])
        np.testing.assert_array_equal(outs["dense"][1], outs[mode][1])


# ---------------------------------------------------------------------------
# (3) wire contract: collective traffic is O(frontier + batch), not O(pool)
# ---------------------------------------------------------------------------


def test_edge_map_collectives_vertex_sized(rmat_graph, weighted_engines):
    """Every collective in one auto-mode edgeMap round moves vertex-state
    (O(n) words) — never pool-sized operands."""
    n, _ = rmat_graph
    _, eng = weighted_engines
    from repro.core.traversal.algorithms import _bfs_relax, _bfs_unvisited

    U = jnp.zeros(n, bool).at[0].set(True)
    state = jnp.full(n, -1, jnp.int64).at[0].set(0)
    colls = sb.collective_operand_bytes(
        lambda U, s: sb._sharded_edge_map_step(
            eng.aux.offsets, eng.sg.pool.data, eng.aux.src_c, eng.aux.dst_c,
            eng.aux.evalid, eng.aux.degrees, jnp.int32(eng.m), eng.sg.pool.vals,
            U, s,
            F=_bfs_relax, C=_bfs_unvisited, mode="auto", n=n,
            ids_budget=eng._auto_ids_budget, edge_budget=eng._auto_edge_budget,
            ops=eng.ops, mesh=eng.mesh, weighted=True,
        ),
        U, state,
    )
    assert colls, "expected cross-shard merges in the edgeMap step"
    pool_bytes = eng.sg.pool.data.size * 8
    biggest = max(b for _, b in colls)
    assert biggest <= 4 * n * 8, f"collective moves {biggest}B — not vertex-sized"
    assert biggest * 4 <= pool_bytes, "collective traffic within O(pool) of the pool"


def test_bfs_batch_collectives_vertex_sized(rmat_graph, engines):
    n, _ = rmat_graph
    _, eng = engines
    B = 8
    srcs = jnp.zeros(B, jnp.int32)
    colls = sb.collective_operand_bytes(
        lambda s: sb.bfs_batch_sharded(
            eng.aux.offsets, eng.sg.pool.data, eng.aux.src_c, eng.aux.dst_c,
            eng.aux.evalid, eng.aux.degrees, eng.aux.src_by_dst,
            eng.aux.valid_by_dst, eng.aux.dst_offsets, jnp.int32(eng.m), s,
            n=n, ids_budget=eng._auto_ids_budget,
            edge_budget=eng._auto_edge_budget, mesh=eng.mesh,
        ),
        srcs,
    )
    assert colls
    pool_bytes = eng.sg.pool.data.size * 8
    biggest = max(b for _, b in colls)
    assert biggest <= 8 * B * n, f"collective moves {biggest}B — not frontier-sized"
    assert biggest < pool_bytes


def test_insert_step_collectives_batch_sized():
    """The sharded update step never all-gathers the pool: the only
    replicated operand is the batch itself (no collective in the step
    jaxpr may exceed the batch size)."""
    rng = np.random.default_rng(0)
    v = np.unique(rng.integers(0, 1 << 30, 4000))
    pool = sp.from_array(v, N_SHARDS)
    mesh = sp.pool_mesh(N_SHARDS)
    step = sp.make_insert_step(mesh, ("shard",))
    batch = jnp.asarray(np.full(256, sp.SENT, np.int64))
    colls = sb.collective_operand_bytes(lambda p, b: step(p, b), pool, batch)
    batch_bytes = batch.size * 8
    for name, nbytes in colls:
        assert nbytes <= batch_bytes, f"{name} moves {nbytes}B > batch {batch_bytes}B"


# ---------------------------------------------------------------------------
# (4) in-trace drivers: O(1) host syncs
# ---------------------------------------------------------------------------


def test_bfs_batch_constant_syncs(engines, sources):
    _, eng_sh = engines
    talg.bfs_multi(eng_sh, sources)  # warm the jit at B=16
    talg.bfs_multi(eng_sh, sources[:8])  # ... and at B=8
    base = HOST_SYNCS.count
    talg.bfs_multi(eng_sh, sources[:8])
    syncs_b8 = HOST_SYNCS.count - base
    base = HOST_SYNCS.count
    talg.bfs_multi(eng_sh, sources)
    syncs_b16 = HOST_SYNCS.count - base
    assert syncs_b16 == syncs_b8 <= 4  # O(1), not O(D * B)


# ---------------------------------------------------------------------------
# (2) + (5) streaming: sharded mirror parity through interleaved updates,
# rebalance, weight upgrade; version-pinned engine; query_batch routing
# ---------------------------------------------------------------------------


def _parity_stream_scenario(n_shards):
    """Interleaved insert/delete batches + a weighted batch (mid-stream
    upgrade) + a bulk insert sized to force a rebalance, applied through
    AspenStream(mirror='sharded'); returns (stream, numpy reference)."""
    n = 256
    edges = symmetrize(rmat_edges(8, 1500, seed=3))
    keep, updates = make_update_stream(edges, 600, seed=4)
    g0 = G.build_graph(n, keep)
    s = AspenStream(g0, mirror="sharded", n_shards=n_shards)
    for i in range(0, 600, 150):
        b = updates[i : i + 150]
        ins = b[b[:, 2] == 0][:, :2]
        dels = b[b[:, 2] == 1][:, :2]
        if ins.size:
            s.insert_edges(ins)
        if dels.size:
            s.delete_edges(dels)
    # mid-stream weight upgrade
    wedges = edges[:64]
    s.insert_edges(wedges, weights=_weights_for(wedges))
    # bulk insert that must grow capacity -> rebalance path
    bulk = symmetrize(rmat_edges(8, 2500, seed=9))
    s.insert_edges(bulk)
    return s


@pytest.mark.parametrize("n_shards", [1, N_SHARDS])
def test_stream_sharded_mirror_parity(n_shards):
    s = _parity_stream_scenario(n_shards)
    eng_sh = s.engine("sharded")
    eng_np = NumpyEngine(s.flat_snapshot())
    assert eng_sh.m == eng_np.m
    assert eng_sh.weighted  # the upgrade stuck
    src = 0
    p_np, p_sh = talg.bfs(eng_np, src), talg.bfs(eng_sh, src)
    np.testing.assert_array_equal(p_np, p_sh)
    np.testing.assert_array_equal(
        talg.connected_components(eng_np), talg.connected_components(eng_sh)
    )
    d_np = np.asarray(talg.sssp(eng_np, src), np.float64)
    d_sh = np.asarray(talg.sssp(eng_sh, src), np.float64)
    np.testing.assert_array_equal(d_np, d_sh)
    assert np.allclose(
        talg.pagerank(eng_np, iters=4), talg.pagerank(eng_sh, iters=4), atol=1e-5
    )


def test_engine_version_pinned_cache(rmat_graph):
    n, edges = rmat_graph
    s = AspenStream(G.build_graph(n, edges[:1000]), mirror="sharded", n_shards=N_SHARDS)
    e1 = s.engine("sharded")
    assert s.engine("sharded") is e1  # O(1) dict hit on unchanged version
    s.insert_edges(edges[1000:1010])
    e2 = s.engine("sharded")
    assert e2 is not e1  # new version, new engine
    assert e2.m >= e1.m


def test_query_batch_routes_to_sharded_mirror(rmat_graph):
    n, edges = rmat_graph
    s = AspenStream(G.build_graph(n, edges), mirror="sharded", n_shards=N_SHARDS)
    srcs = np.random.default_rng(2).integers(0, n, 8)
    out = s.query_batch(srcs, kind="bfs")  # backend=None -> sharded
    v = s.acquire()
    try:
        assert ("engine", "sharded") in v.cache
        assert ("engine", "jax") not in v.cache
    finally:
        s.release(v)
    eng_np = NumpyEngine(s.flat_snapshot())
    np.testing.assert_array_equal(out, talg.bfs_multi(eng_np, srcs)[0])
    # distances + sssp ride the same router
    np.testing.assert_array_equal(
        s.query_batch(srcs, kind="distances"),
        talg.landmark_distances(eng_np, srcs),
    )


def test_make_engine_dispatch(rmat_graph):
    n, edges = rmat_graph
    sg = sp.graph_from_edges(n, edges, n_shards=N_SHARDS)
    eng = make_engine(sg)
    assert type(eng).__name__ == "ShardedEngine"
    with pytest.raises(TypeError):
        make_engine(sg, backend="jax")
    with pytest.raises(ValueError):
        make_engine(sg, backend="nope")
    # snapshot -> sharded conversion path
    eng2 = make_engine(G.flat_snapshot(G.build_graph(n, edges)), backend="sharded")
    np.testing.assert_array_equal(
        talg.bfs(eng, int(edges[0, 0])), talg.bfs(eng2, int(edges[0, 0]))
    )


def test_mesh_divisibility_guard(rmat_graph):
    n, edges = rmat_graph
    sg = sp.graph_from_edges(n, edges, n_shards=3)
    mesh2 = None
    if jax.device_count() >= 2:
        mesh2 = jax.make_mesh((2,), ("shard",))
        with pytest.raises(ValueError):
            sb.ShardedEngine(sg, mesh=mesh2)
    else:
        # 1-device mesh divides everything; construction must succeed
        assert sb.ShardedEngine(sg).mesh.shape["shard"] == 1


# ---------------------------------------------------------------------------
# multidevice: the acceptance criterion on a real 8-way mesh
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_multidevice_mesh_really_sharded():
    assert jax.device_count() >= 8
    sg = sp.graph_from_edges(64, symmetrize(rmat_edges(6, 300, seed=0)), n_shards=8)
    eng = make_engine(sg)
    assert eng.mesh.shape["shard"] == 8  # one shard row per device


@pytest.mark.multidevice
def test_multidevice_full_parity(rmat_graph, sources):
    """BFS parents/levels, CC labels, PageRank and SSSP distances match
    the numpy engine under the host-count-forced 8-device CPU mesh."""
    n, edges = rmat_graph
    w = _weights_for(edges)
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges, weights=w)))
    eng_sh = make_engine(sp.graph_from_edges(n, edges, n_shards=8, weights=w))
    assert eng_sh.mesh.shape["shard"] == 8
    src = int(edges[0, 0])
    np.testing.assert_array_equal(talg.bfs(eng_np, src), talg.bfs(eng_sh, src))
    p_np, d_np = talg.bfs_multi(eng_np, sources)
    p_sh, d_sh = talg.bfs_multi(eng_sh, sources)
    np.testing.assert_array_equal(p_np, p_sh)
    np.testing.assert_array_equal(d_np, d_sh)
    np.testing.assert_array_equal(
        talg.connected_components(eng_np), talg.connected_components(eng_sh)
    )
    assert np.allclose(
        talg.pagerank(eng_np, iters=5), talg.pagerank(eng_sh, iters=5), atol=1e-5
    )
    np.testing.assert_array_equal(
        talg.sssp_multi(eng_np, sources), talg.sssp_multi(eng_sh, sources)
    )


@pytest.mark.multidevice
def test_multidevice_stream_parity_after_rebalance():
    """The full acceptance scenario: interleaved insert/delete batches,
    a mid-stream weight upgrade and a forced rebalance, on 8 devices."""
    s = _parity_stream_scenario(8)
    eng_sh = s.engine("sharded")
    assert eng_sh.mesh.shape["shard"] == 8
    eng_np = NumpyEngine(s.flat_snapshot())
    assert eng_sh.m == eng_np.m
    src = 0
    np.testing.assert_array_equal(talg.bfs(eng_np, src), talg.bfs(eng_sh, src))
    np.testing.assert_array_equal(
        talg.connected_components(eng_np), talg.connected_components(eng_sh)
    )
    np.testing.assert_array_equal(
        np.asarray(talg.sssp(eng_np, src), np.float64),
        np.asarray(talg.sssp(eng_sh, src), np.float64),
    )
    assert np.allclose(
        talg.pagerank(eng_np, iters=4), talg.pagerank(eng_sh, iters=4), atol=1e-5
    )


# ---------------------------------------------------------------------------
# incremental (delta-aware) queries on the sharded backend
# ---------------------------------------------------------------------------


def _incremental_scenario(n_shards):
    """Two held versions one weighted insert + one delete batch apart,
    streamed through the sharded mirror; returns everything the
    incremental parity checks need."""
    n = 256
    edges = symmetrize(rmat_edges(8, 2000, seed=11))
    w = _weights_for(edges)
    s = AspenStream(G.build_graph(n, edges, weights=w), mirror="sharded", n_shards=n_shards)
    v1 = s.vg.acquire()
    rng = np.random.default_rng(13)
    batch = rng.integers(0, n, size=(40, 2)).astype(np.int64)
    batch = batch[batch[:, 0] != batch[:, 1]][:24]
    s.insert_edges(batch, weights=_weights_for(batch))
    vmid = s.vg.acquire()
    s.delete_edges(edges[:20], symmetric=False)
    v2 = s.vg.acquire()
    delta = s.vg.delta_between(v1, v2)
    assert delta is not None and delta.has_deletions
    s.vg.release(vmid)
    return s, v1, v2, delta


def _check_incremental_parity(n_shards):
    s, v1, v2, delta = _incremental_scenario(n_shards)
    e1 = s._engine_for(v1, "sharded")
    e2 = s._engine_for(v2, "sharded")
    e2_np = NumpyEngine(G.flat_snapshot(v2.graph))
    src = np.array([0, 31, 128], np.int64)

    # incremental BFS: depths and parents bit-identical to full, and to numpy
    p1, d1 = talg.bfs_multi(e1, src)
    ip, idp = talg.incremental_bfs(e2, src, p1, d1, delta)
    fp, fd = talg.bfs_multi(e2, src)
    np.testing.assert_array_equal(idp, fd)
    np.testing.assert_array_equal(ip, fp)
    np.testing.assert_array_equal(idp, talg.bfs_multi(e2_np, src)[1])

    # incremental SSSP: exact against full on both substrates
    dist1 = np.asarray(talg.sssp_multi(e1, src), np.float64)
    tree1 = talg.shortest_path_parents(e1, dist1, src)
    idist = talg.incremental_sssp(e2, src, dist1, tree1, delta)
    np.testing.assert_array_equal(idist, talg.sssp_multi(e2, src))
    np.testing.assert_array_equal(idist, talg.sssp_multi(e2_np, src))

    # incremental CC (deletions downgrade to full internally): exact
    prev = np.asarray(talg.connected_components(e1), np.int64)
    got = talg.incremental_connected_components(e2, prev, delta)
    np.testing.assert_array_equal(got, talg.connected_components(e2_np))

    # warm-start PageRank hits the same fixed point on the sharded mesh
    pr_prev = talg.pagerank(e1, tol=1e-6)
    cold = np.asarray(talg.pagerank(e2, tol=1e-6))
    warm = np.asarray(talg.pagerank(e2, tol=1e-6, init=pr_prev))
    assert np.abs(warm - cold).max() <= 2e-6
    s.vg.release(v1)
    s.vg.release(v2)


def test_incremental_parity_sharded():
    _check_incremental_parity(N_SHARDS)


@pytest.mark.multidevice
def test_incremental_parity_sharded_multidevice():
    """The acceptance criterion on the host-count-forced 8-device mesh."""
    assert jax.device_count() >= 8
    _check_incremental_parity(8)
