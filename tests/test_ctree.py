"""C-tree core tests: chunk codecs, treap, and Algorithms 1-3 vs oracles."""
import numpy as np
import pytest

from repro.core import chunks as ck
from repro.core import ctree as ct
from repro.core import pam
from repro.core.hash import hash32_jnp, hash32_np, is_head_np

from proptest import given, st

B_VALUES = [2, 8, 64, 256]


def sets(max_value=1 << 20, max_size=400):
    return st.lists(
        st.integers(min_value=0, max_value=max_value), min_size=0, max_size=max_size
    )


# ---------------------------------------------------------------------------
# hash + chunk codecs
# ---------------------------------------------------------------------------


def test_hash_np_jnp_agree():
    x = np.arange(0, 100000, 37, dtype=np.int64)
    a = hash32_np(x)
    b = np.asarray(hash32_jnp(x.astype(np.uint32)))
    assert (a == b.astype(np.uint32)).all()


@given(sets())
def test_vbyte_roundtrip_and_matches_scalar(xs):
    v = np.unique(np.asarray(xs, dtype=np.int64))
    enc = ck.vbyte_encode(v)
    assert enc == ck.vbyte_encode_scalar(v)
    dec = ck.vbyte_decode(enc)
    np.testing.assert_array_equal(dec, v)
    np.testing.assert_array_equal(ck.vbyte_decode_scalar(enc), v)


def test_vbyte_large_deltas():
    v = np.array([0, 1, 2**20, 2**35, 2**35 + 1, 2**62], dtype=np.int64)
    np.testing.assert_array_equal(ck.vbyte_decode(ck.vbyte_encode(v)), v)


@given(sets(max_size=200), st.integers(min_value=0, max_value=1 << 20))
def test_split_chunk(xs, k):
    v = np.unique(np.asarray(xs, dtype=np.int64))
    c = ck.Chunk.from_values(v)
    l, found, r = ck.split_chunk(c, int(k))
    lv, rv = ck.chunk_values(l), ck.chunk_values(r)
    np.testing.assert_array_equal(lv, v[v < k])
    np.testing.assert_array_equal(rv, v[v > k])
    assert found == bool((v == k).any())


def test_pack_deltas_roundtrip():
    rng = np.random.default_rng(0)
    data = np.unique(rng.integers(0, 1 << 40, size=5000))
    offs = np.array([0, 17, 17, 1000, 2500, data.size], dtype=np.int64)
    for width in ("uint8", "uint16"):
        p = ck.pack_deltas(data, offs, width)
        np.testing.assert_array_equal(ck.unpack_deltas(p), data)


# ---------------------------------------------------------------------------
# pam treap
# ---------------------------------------------------------------------------

MOD = pam.TreeModule(aug_of=lambda k, v: 1)


@given(sets(max_size=300))
def test_treap_build_canonical_and_invariant(xs):
    ks = sorted(set(xs))
    t = MOD.build_sorted([(k, None) for k in ks])
    assert MOD.check_invariants(t)
    assert MOD.keys(t) == ks
    assert pam.size(t) == len(ks)
    # canonical: insert-one-at-a-time yields the identical structure
    t2 = None
    for k in ks:
        t2 = MOD.insert(t2, k, None)
    assert t2 == t


@given(sets(max_size=200), sets(max_size=200))
def test_treap_set_algebra(a, b):
    sa, sb = set(a), set(b)
    ta = MOD.build_sorted([(k, None) for k in sorted(sa)])
    tb = MOD.build_sorted([(k, None) for k in sorted(sb)])
    assert MOD.keys(MOD.union(ta, tb)) == sorted(sa | sb)
    assert MOD.keys(MOD.difference(ta, tb)) == sorted(sa - sb)
    assert MOD.keys(MOD.intersect(ta, tb)) == sorted(sa & sb)
    # canonical form: union equals direct build
    assert MOD.union(ta, tb) == MOD.build_sorted([(k, None) for k in sorted(sa | sb)])


@given(sets(max_size=200), st.integers(min_value=0, max_value=1 << 20))
def test_treap_split_rank_select(xs, k):
    ks = sorted(set(xs))
    t = MOD.build_sorted([(x, None) for x in ks])
    l, m, r = MOD.split(t, k)
    assert MOD.keys(l) == [x for x in ks if x < k]
    assert MOD.keys(r) == [x for x in ks if x > k]
    assert (m is not None) == (k in set(ks))
    assert MOD.rank(t, k) == len([x for x in ks if x < k])
    for i in [0, len(ks) // 2, len(ks) - 1]:
        if 0 <= i < len(ks):
            assert MOD.select(t, i)[0] == ks[i]


def test_treap_augmentation_tracks_values():
    mod = pam.TreeModule(aug_of=lambda k, v: v, combine=lambda a, b: a + b, zero=0)
    t = None
    total = 0
    for k in range(100):
        v = (k * 7) % 13
        t = mod.insert(t, k, v)
        total += v
    assert mod.aug(t) == total
    t = mod.delete(t, 50)
    assert mod.aug(t) == total - (50 * 7) % 13


# ---------------------------------------------------------------------------
# C-tree structure
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b", B_VALUES)
def test_build_roundtrip_and_invariants(b):
    rng = np.random.default_rng(1)
    v = np.unique(rng.integers(0, 1 << 24, size=3000))
    c = ct.build(v, b=b)
    assert ct.check_invariants(c)
    np.testing.assert_array_equal(ct.to_array(c), v)
    assert ct.ctree_size(c) == v.size


@pytest.mark.parametrize("b", B_VALUES)
def test_find(b):
    rng = np.random.default_rng(2)
    v = np.unique(rng.integers(0, 1 << 16, size=500))
    c = ct.build(v, b=b)
    present = set(v.tolist())
    for e in range(0, 1 << 16, 97):
        assert ct.find(c, e) == (e in present)
    for e in v[:50].tolist():
        assert ct.find(c, e)


@given(sets(), st.integers(min_value=0, max_value=1 << 20), st.sampled_from(B_VALUES))
def test_split_property(xs, k, b):
    v = np.unique(np.asarray(xs, dtype=np.int64))
    c = ct.build(v, b=b)
    l, found, r = ct.split(c, int(k))
    np.testing.assert_array_equal(ct.to_array(l), v[v < k])
    np.testing.assert_array_equal(ct.to_array(r), v[v > k])
    assert found == bool((v == k).any())
    assert ct.check_invariants(l) and ct.check_invariants(r)


@given(sets(), sets(), st.sampled_from(B_VALUES))
def test_union_property(a, bs, b):
    va = np.unique(np.asarray(a, dtype=np.int64))
    vb = np.unique(np.asarray(bs, dtype=np.int64))
    cu = ct.union(ct.build(va, b=b), ct.build(vb, b=b))
    np.testing.assert_array_equal(ct.to_array(cu), np.union1d(va, vb))
    assert ct.check_invariants(cu)
    assert ct.ctree_size(cu) == np.union1d(va, vb).size


@given(sets(), sets(), st.sampled_from(B_VALUES))
def test_difference_property(a, bs, b):
    va = np.unique(np.asarray(a, dtype=np.int64))
    vb = np.unique(np.asarray(bs, dtype=np.int64))
    cd = ct.difference(ct.build(va, b=b), ct.build(vb, b=b))
    np.testing.assert_array_equal(ct.to_array(cd), np.setdiff1d(va, vb))
    assert ct.check_invariants(cd)


@given(sets(), sets(), st.sampled_from(B_VALUES))
def test_intersect_property(a, bs, b):
    va = np.unique(np.asarray(a, dtype=np.int64))
    vb = np.unique(np.asarray(bs, dtype=np.int64))
    ci = ct.intersect(ct.build(va, b=b), ct.build(vb, b=b))
    np.testing.assert_array_equal(ct.to_array(ci), np.intersect1d(va, vb))
    assert ct.check_invariants(ci)


@given(sets(max_size=150), sets(max_size=150), st.sampled_from([8, 256]))
def test_multi_insert_delete(a, bs, b):
    va = np.unique(np.asarray(a, dtype=np.int64))
    vb = np.asarray(bs, dtype=np.int64)
    c = ct.build(va, b=b)
    ci = ct.multi_insert(c, vb)
    np.testing.assert_array_equal(ct.to_array(ci), np.union1d(va, vb))
    cd = ct.multi_delete(ci, vb)
    np.testing.assert_array_equal(ct.to_array(cd), np.setdiff1d(np.union1d(va, vb), vb))
    # persistence: original snapshot untouched
    np.testing.assert_array_equal(ct.to_array(c), va)


def test_union_canonical_form():
    """Hash-chunking makes C-trees history-independent: union order must
    not matter and must equal a direct build (strong structural check)."""
    rng = np.random.default_rng(3)
    a = np.unique(rng.integers(0, 1 << 20, size=800))
    b = np.unique(rng.integers(0, 1 << 20, size=800))
    u1 = ct.union(ct.build(a, b=64), ct.build(b, b=64))
    u2 = ct.union(ct.build(b, b=64), ct.build(a, b=64))
    direct = ct.build(np.union1d(a, b), b=64)
    assert ct.to_array(u1).tolist() == ct.to_array(direct).tolist()
    # heads + chunk contents identical regardless of history
    assert u1.tree == direct.tree == u2.tree
    assert ck.chunk_values(u1.prefix).tolist() == ck.chunk_values(direct.prefix).tolist()


def test_chunk_size_distribution():
    """Lemma 3.1: expected chunk size b, O(n/b) heads."""
    rng = np.random.default_rng(4)
    v = np.unique(rng.integers(0, 1 << 32, size=200_000))
    for b in (64, 256):
        c = ct.build(v, b=b)
        n_heads = pam.size(c.tree)
        expect = v.size / b
        assert 0.8 * expect < n_heads < 1.25 * expect
        assert ct.ctree_size(c) == v.size


def test_memory_model_compression_wins():
    """Table 2 direction: C-tree (DE) much smaller than uncompressed tree."""
    rng = np.random.default_rng(5)
    # power-law-ish neighbor ids in a 1M range, like a real adjacency list
    v = np.unique((rng.pareto(1.5, size=100_000) * 1000).astype(np.int64))
    c = ct.build(v, b=256)
    de = ct.nbytes(c, compressed=True)
    node_based = ct.uncompressed_tree_bytes(c)
    assert de < node_based / 4  # paper reports 4.7-11.3x
    assert ct.nbytes(c, compressed=False) > de


def test_snapshot_persistence_under_updates():
    """Purely-functional property: old versions remain intact (paper §1)."""
    rng = np.random.default_rng(6)
    base = np.unique(rng.integers(0, 1 << 20, size=2000))
    c0 = ct.build(base, b=64)
    versions = [c0]
    cur = c0
    for i in range(5):
        batch = rng.integers(0, 1 << 20, size=300)
        cur = ct.multi_insert(cur, batch)
        versions.append(cur)
    # every snapshot still decodes to what it was
    np.testing.assert_array_equal(ct.to_array(versions[0]), base)
    assert ct.ctree_size(versions[-1]) >= ct.ctree_size(versions[0])
