"""Result cache + batch=1 fast path (DESIGN.md §14).

Pins the PR's contracts:

  (1) the cache is VERSION-keyed: payloads live on ``Version.cache`` and
      die with the version (weakref-verified); capacity eviction deletes
      from the owning live version; a new version never sees an old
      version's entry;
  (2) submit-time exact hits bypass admission entirely — the tenant
      ledger identities stay snapshot-exact (``cached`` counted, WFQ
      pass NOT advanced: admission meters misses only);
  (3) a pinned ``Session`` can never be served a newer version's cached
      result, while repeated identical session queries hit its own;
  (4) delta carry-forward promotes hot entries across a publish through
      the exact incremental paths (bfs / sssp / cc; tol-pagerank
      warm-starts; fixed-iter pagerank recomputes) and falls back to a
      full recompute on a broken delta chain — never a wrong answer;
  (5) lifecycle under a live writer: publishes leave ``live_versions``
      bounded (anchor rotation), early versions and their payloads are
      collected, and the Zipf replay still hits;
  (6) end-to-end answers with the cache ON are bit-identical to the
      cache-OFF run, across a publish, on numpy / jax (and sharded
      under an 8-device mesh);
  (7) ``stats()`` is one consistent snapshot under the lock even while
      a reader hammers it against live traffic;
  (8) ``query_multi`` serves a mixed-kind batch off ONE version with
      ONE engine build (``ENGINE_BUILDS`` spy), answers matching
      ``query_batch``;
  (9) the opt-in ``fastpath`` serves an idle singleton miss on the
      caller thread, fully metered.
 (10) promotion capture: a post-publish miss on an anchor-hot key
      parks on the in-flight carry-forward pass and lands as a hit
      (``capture_hits``) instead of recomputing through dispatch.
"""
import gc
import threading
import weakref

import numpy as np
import pytest

from repro.core import graph as G
from repro.core.streaming import AspenStream
from repro.core.traversal import ENGINE_BUILDS
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize
from repro.serve.graph import GraphQueryService, ResultCache
from repro.serve.graph.request import params_key

N = 256
NP = 32  # path-graph vertex count


@pytest.fixture(scope="module")
def rmat_edge_list():
    return symmetrize(rmat_edges(8, 2000, seed=11))


def path_edges(n):
    e = np.array([[i, i + 1] for i in range(n - 1)], dtype=np.int64)
    return np.concatenate([e, e[:, ::-1]])


def make_stream(edges, n=N, **kw):
    return AspenStream(G.build_graph(n, edges), **kw)


def make_service(edges, n=N, **kw):
    kw.setdefault("backend", "numpy")
    kw.setdefault("max_batch", 8)
    kw.setdefault("default_deadline_s", 0.25)
    stream = make_stream(edges, n=n)
    return stream, GraphQueryService(stream, **kw)


# ---------------------------------------------------------------------------
# (1) version keying, eviction, payload lifecycle
# ---------------------------------------------------------------------------


def test_cache_version_keyed_get_put():
    stream = make_stream(path_edges(NP), n=NP)
    cache = ResultCache(capacity=8)
    v1 = stream.acquire()
    val = np.arange(NP)
    cache.put(v1, "bfs", (), 3, val)
    ent = cache.get(v1, "bfs", (), 3)
    assert ent is not None and ent.value is val and ent.hits == 1
    # different source / params / kind miss
    assert cache.get(v1, "bfs", (), 4) is None
    assert cache.get(v1, "bfs", params_key({"x": 1}), 3) is None
    assert cache.get(v1, "sssp", (), 3) is None
    # a NEW version never sees the old version's entry
    stream.insert_edges(np.array([[0, 5]]))
    v2 = stream.acquire()
    assert cache.get(v2, "bfs", (), 3) is None
    snap = cache.snapshot()
    assert snap["fills"] == 1 and snap["hits"] == 1 and snap["misses"] == 4
    stream.release(v2)
    stream.release(v1)


def test_cache_capacity_eviction_deletes_from_live_version():
    stream = make_stream(path_edges(NP), n=NP)
    cache = ResultCache(capacity=4)
    v1 = stream.acquire()
    for s in range(6):
        cache.put(v1, "bfs", (), s, np.arange(NP) + s)
    assert cache.snapshot()["entries"] == 4
    assert cache.evictions == 2
    # the two oldest are gone from the version's payload dict too
    assert cache.get(v1, "bfs", (), 0) is None
    assert cache.get(v1, "bfs", (), 1) is None
    assert cache.get(v1, "bfs", (), 5) is not None
    stream.release(v1)


def test_cache_payload_dies_with_version():
    stream = make_stream(path_edges(NP), n=NP)
    cache = ResultCache()
    v1 = stream.acquire()
    cache.put(v1, "bfs", (), 1, np.zeros(NP))
    ref = weakref.ref(v1)
    stream.release(v1)
    del v1
    stream.insert_edges(np.array([[0, 9]]))  # supersede: refcount 0 -> GC
    gc.collect()
    assert ref() is None  # version AND its resident payload collected
    # the stale index slot is pruned (not counted as an eviction) once
    # capacity pressure walks past it
    small = ResultCache(capacity=1)
    v = stream.acquire()
    sref = weakref.ref(v)
    small.put(v, "bfs", (), 0, np.zeros(NP))
    stream.release(v)
    del v
    stream.insert_edges(np.array([[0, 11]]))
    gc.collect()
    assert sref() is None
    v2 = stream.acquire()
    small.put(v2, "bfs", (), 1, np.ones(NP))
    small.put(v2, "bfs", (), 2, np.ones(NP))
    assert small.evictions == 1  # only the live-owner eviction counted
    stream.release(v2)


# ---------------------------------------------------------------------------
# (2) submit-time hits: metering without admission
# ---------------------------------------------------------------------------


def test_submit_hit_bypasses_admission_but_meters_ledger(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list)
    with svc:
        first = svc.query("bfs", source=3, tenant="a", timeout=30)
        vpass_after_miss = svc._admission.tenant("a").vpass
        t2 = svc.submit("bfs", source=3, tenant="a")
        assert t2.cached and t2.fastpath and t2.batch_size == 0
        assert np.array_equal(t2.result(timeout=5), first)
        # the hit advanced the ledger but NOT the WFQ pass
        assert svc._admission.tenant("a").vpass == vpass_after_miss
        st = svc.stats()
        ta = st["tenants"]["a"]
        assert ta["cached"] == 1
        assert ta["submitted"] == ta["completed"] == 2
        assert ta["submitted"] == ta["admitted"] + ta["rejected"] + ta["backlog"]
        assert st["lanes"]["bfs"]["cache_hits"] >= 1
        assert st["lanes"]["bfs"]["fastpath_hits"] == 1
        assert st["cache"]["hits"] >= 1 and st["cache"]["fills"] >= 1


def test_cc_and_pagerank_hit_on_repeat(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list)
    with svc:
        cc1 = svc.query("cc", timeout=30)
        pr1 = svc.query("pagerank", timeout=30)
        t_cc = svc.submit("cc")
        t_pr = svc.submit("pagerank")
        assert t_cc.cached and t_pr.cached
        assert np.array_equal(t_cc.result(timeout=5), cc1)
        assert np.array_equal(t_pr.result(timeout=5), pr1)


# ---------------------------------------------------------------------------
# (3) pinned sessions never see a newer version's cached result
# ---------------------------------------------------------------------------


def test_pinned_session_never_served_newer_cached_result():
    stream, svc = make_service(path_edges(NP), n=NP)
    with svc:
        with svc.session(tenant="t") as sess:
            first = sess.query("bfs", source=0).result(timeout=30)
            # publish a shortcut and warm the NEW version's cache
            svc.insert_edges(np.array([[0, 20]]))
            svc.flush_updates()
            svc.flush_promotions()
            fresh = svc.query("bfs", source=0, timeout=30)
            assert not np.array_equal(fresh, first)  # graph really changed
            # the session repeat hits its OWN version's entry: identical
            # to the first answer, never the fresh one
            tk = sess.query("bfs", source=0)
            again = tk.result(timeout=30)
            assert tk.cached
            assert np.array_equal(again, first)
            # and the freshest path never resurrects the pinned answer
            tk2 = svc.submit("bfs", source=0)
            assert np.array_equal(tk2.result(timeout=30), fresh)


# ---------------------------------------------------------------------------
# (4) carry-forward: incremental exactness + full fallback
# ---------------------------------------------------------------------------


def test_carry_forward_promotes_hot_entries_exactly():
    stream = make_stream(path_edges(NP), n=NP)
    cache = ResultCache()
    v1 = stream.acquire()
    eng1 = stream._engine_for(v1, "numpy")

    p, d = talg.bfs_multi(eng1, [0])
    cache.put(v1, "bfs", (), 0, np.asarray(p[0]), state=np.asarray(d[0]))
    dist = talg.sssp_multi(eng1, [0])
    cache.put(v1, "sssp", (), 0, np.asarray(dist[0], np.float64))
    labels = talg.connected_components(eng1)
    cache.put(v1, "cc", (), None, np.asarray(labels, np.int64))
    pr_pkey = params_key({"tol": 1e-12, "max_iters": 500})
    pr = talg.pagerank_multi(
        eng1, resets=np.full((1, NP), 1.0 / NP), tol=1e-12, max_iters=500
    )
    cache.put(v1, "pagerank", pr_pkey, None, np.asarray(pr[0]))
    # only HOT entries promote: touch all four
    for kind, pkey, src in [("bfs", (), 0), ("sssp", (), 0),
                            ("cc", (), None), ("pagerank", pr_pkey, None)]:
        assert cache.get(v1, kind, pkey, src) is not None

    stream.insert_edges(np.array([[0, 20]]))
    v2 = stream.acquire()
    assert cache.carry_forward(stream, v1, v2, "numpy") == 4
    assert cache.promoted_incremental >= 3  # bfs, sssp, cc (insert-only)

    eng2 = stream._engine_for(v2, "numpy")
    ref_p, ref_d = talg.bfs_multi(eng2, [0])
    ent = cache.get(v2, "bfs", (), 0)
    assert np.array_equal(ent.value, np.asarray(ref_p[0]))
    assert np.array_equal(ent.state, np.asarray(ref_d[0]))
    ref_dist = talg.sssp_multi(eng2, [0])
    assert np.array_equal(cache.get(v2, "sssp", (), 0).value,
                          np.asarray(ref_dist[0], np.float64))
    ref_cc = talg.connected_components(eng2)
    assert np.array_equal(cache.get(v2, "cc", (), None).value,
                          np.asarray(ref_cc, np.int64))
    ref_pr = talg.pagerank_multi(
        eng2, resets=np.full((1, NP), 1.0 / NP), tol=1e-12, max_iters=500
    )
    np.testing.assert_allclose(
        cache.get(v2, "pagerank", pr_pkey, None).value, ref_pr[0], atol=1e-9
    )
    stream.release(v2)
    stream.release(v1)


def test_carry_forward_cold_entries_stay_behind():
    stream = make_stream(path_edges(NP), n=NP)
    cache = ResultCache()
    v1 = stream.acquire()
    cache.put(v1, "bfs", (), 0, np.arange(NP), state=np.arange(NP))
    # never read -> not hot -> nothing to promote (and no engine work)
    stream.insert_edges(np.array([[0, 20]]))
    v2 = stream.acquire()
    builds = ENGINE_BUILDS.count
    assert cache.carry_forward(stream, v1, v2, "numpy") == 0
    assert ENGINE_BUILDS.count == builds
    stream.release(v2)
    stream.release(v1)


def test_carry_forward_full_fallback_on_broken_chain():
    stream = make_stream(path_edges(NP), n=NP)
    cache = ResultCache()
    v1 = stream.acquire()
    eng1 = stream._engine_for(v1, "numpy")
    p, d = talg.bfs_multi(eng1, [0])
    cache.put(v1, "bfs", (), 0, np.asarray(p[0]), state=np.asarray(d[0]))
    assert cache.get(v1, "bfs", (), 0) is not None
    # a vertex op publishes WITHOUT a delta record: chain broken
    stream.insert_vertices(np.array([NP + 8]))
    v2 = stream.acquire()
    assert stream.vg.delta_between(v1, v2) is None
    assert cache.carry_forward(stream, v1, v2, "numpy") == 1
    assert cache.promoted_full == 1 and cache.promoted_incremental == 0
    eng2 = stream._engine_for(v2, "numpy")
    ref_p, _ = talg.bfs_multi(eng2, [0])
    assert np.array_equal(cache.get(v2, "bfs", (), 0).value,
                          np.asarray(ref_p[0]))
    stream.release(v2)
    stream.release(v1)


def test_carry_forward_drops_unknown_params():
    stream = make_stream(path_edges(NP), n=NP)
    cache = ResultCache()
    v1 = stream.acquire()
    pkey = params_key({"mystery": 1})
    cache.put(v1, "bfs", pkey, 0, np.arange(NP), state=np.arange(NP))
    cache.get(v1, "bfs", pkey, 0)
    stream.insert_edges(np.array([[0, 20]]))
    v2 = stream.acquire()
    assert cache.carry_forward(stream, v1, v2, "numpy") == 0
    assert cache.promoted_dropped == 1
    assert cache.get(v2, "bfs", pkey, 0) is None  # never promoted wrong
    stream.release(v2)
    stream.release(v1)


# ---------------------------------------------------------------------------
# (5) lifecycle under a live writer: bounded versions, no leaks, hits
# ---------------------------------------------------------------------------


def test_cache_lifecycle_1k_publishes_no_leaks(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list)
    rng = np.random.default_rng(3)
    version_refs = []
    with svc:
        for i in range(1000):
            stream.insert_edges(
                np.array([[int(rng.integers(N)), int(rng.integers(N))]])
            )
            if i % 10 == 0:
                src = int(min(rng.zipf(2.0) - 1, N - 1))
                # twice: the second is a same-version hit, marking the
                # entry hot so carry-forward keeps it warm
                svc.query("bfs", source=src, timeout=30)
                svc.query("bfs", source=src, timeout=30)
            if i % 100 == 0:
                v = stream.acquire()
                version_refs.append(weakref.ref(v))
                stream.release(v)
        svc.flush_promotions()
        st = svc.stats()
        assert st["live_versions"] <= 3
        assert st["cache"]["hits"] > 0
        assert st["cache"]["hit_rate"] > 0
    gc.collect()
    dead = sum(1 for r in version_refs if r() is None)
    assert dead >= len(version_refs) - 2  # only the newest may survive
    assert stream.vg.live_versions() == 1  # anchor released on stop


def test_carry_forward_keeps_hot_entry_warm_across_publishes(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list)
    with svc:
        svc.query("bfs", source=3, timeout=30)
        svc.query("bfs", source=3, timeout=30)  # hot
        for _ in range(5):
            stream.insert_edges(np.array([[7, 11]]))
        svc.flush_promotions()
        before = svc.stats()["cache"]["hits"]
        t = svc.submit("bfs", source=3)
        t.result(timeout=30)
        assert t.cached  # promoted entry served the post-publish repeat
        assert svc.stats()["cache"]["promoted_incremental"] >= 1
        assert svc.stats()["cache"]["hits"] == before + 1


# ---------------------------------------------------------------------------
# (6) cache on == cache off, bit-identical, across a publish
# ---------------------------------------------------------------------------

REPLAY = [
    ("bfs", 3), ("sssp", 5), ("bfs", 3), ("cc", None),
    ("pagerank", None), ("bfs", 3), ("sssp", 5), ("pagerank", None),
]


def _run_replay(svc, publish_edges):
    out = []
    for kind, src in REPLAY:
        out.append(np.asarray(svc.query(kind, source=src, timeout=60)))
    svc.insert_edges(publish_edges)
    svc.flush_updates()
    svc.flush_promotions()
    for kind, src in REPLAY:
        out.append(np.asarray(svc.query(kind, source=src, timeout=60)))
    return out


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_cached_bit_identical_to_uncached(rmat_edge_list, backend):
    publish = np.array([[3, 200], [200, 210]])
    got = {}
    for cache_on in (False, True):
        stream = make_stream(rmat_edge_list)
        svc = GraphQueryService(
            stream, backend=backend, max_batch=8,
            result_cache=cache_on, fastpath=cache_on,
        )
        with svc:
            got[cache_on] = _run_replay(svc, publish)
            if cache_on:
                assert svc.stats()["cache"]["hits"] > 0
    for a, b in zip(got[False], got[True]):
        assert a.dtype == b.dtype and np.array_equal(a, b)


@pytest.mark.multidevice
def test_cached_bit_identical_sharded(rmat_edge_list):
    publish = np.array([[3, 200], [200, 210]])
    got = {}
    for cache_on in (False, True):
        stream = AspenStream(
            G.build_graph(N, rmat_edge_list), mirror="sharded", n_shards=8
        )
        svc = GraphQueryService(
            stream, backend="sharded", max_batch=4, result_cache=cache_on
        )
        with svc:
            got[cache_on] = _run_replay(svc, publish)
    for a, b in zip(got[False], got[True]):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# (7) stats() is one consistent snapshot
# ---------------------------------------------------------------------------


def test_stats_consistent_snapshot_under_hammering_reader(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, max_batch=4)
    bad = []
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            st = svc.stats()
            for name, t in st["tenants"].items():
                if t["submitted"] != t["admitted"] + t["rejected"] + t["backlog"]:
                    bad.append(("ledger", name, t))
                if t["admitted"] != t["completed"] + t["in_flight"]:
                    bad.append(("inflight", name, t))
            for k, m in st["lanes"].items():
                if m["flushed_requests"] != sum(
                    s * c for s, c in m["batch_size_hist"].items()
                ):
                    bad.append(("hist", k, m))

    with svc:
        th = threading.Thread(target=hammer)
        th.start()
        rng = np.random.default_rng(7)
        tickets = []
        for i in range(300):
            src = int(rng.integers(0, 16))  # tight range: repeats -> hits
            tickets.append(svc.submit("bfs", source=src, tenant=f"t{i % 3}"))
        for t in tickets:
            t.result(timeout=30)
        svc.wait_idle()
        stop.set()
        th.join(timeout=10)
        assert svc.stats()["cache"]["hits"] > 0  # the mix exercised hits
    assert not bad, bad[:3]


# ---------------------------------------------------------------------------
# (8) query_multi: one version, one engine build, query_batch parity
# ---------------------------------------------------------------------------


def test_query_multi_single_engine_build_and_parity(rmat_edge_list):
    stream = make_stream(rmat_edge_list)
    resets = np.zeros((2, N))
    resets[0, :] = 1.0 / N
    resets[1, 7] = 1.0
    reqs = [
        {"kind": "bfs", "sources": [3, 9, 3]},
        {"kind": "sssp", "sources": [5]},
        {"kind": "bfs", "sources": []},  # empty stays a no-op in place
        {"kind": "pagerank", "resets": resets},
        {"kind": "distances", "sources": [2]},
    ]
    before = ENGINE_BUILDS.count
    got = stream.query_multi(reqs, backend="numpy")
    assert ENGINE_BUILDS.count == before + 1  # one build for the whole batch
    ref_stream = make_stream(rmat_edge_list)
    assert np.array_equal(
        got[0], ref_stream.query_batch([3, 9, 3], kind="bfs", backend="numpy")
    )
    assert np.array_equal(
        got[1], ref_stream.query_batch([5], kind="sssp", backend="numpy")
    )
    assert got[2] == []
    assert np.array_equal(
        got[3],
        ref_stream.query_batch(kind="pagerank", backend="numpy", resets=resets),
    )
    assert np.array_equal(
        got[4], ref_stream.query_batch([2], kind="distances", backend="numpy")
    )
    # a second mixed batch on the unchanged version: zero new builds
    before = ENGINE_BUILDS.count
    stream.query_multi(reqs[:2], backend="numpy")
    assert ENGINE_BUILDS.count == before


def test_query_multi_all_empty_never_builds(rmat_edge_list):
    stream = make_stream(rmat_edge_list)
    before = ENGINE_BUILDS.count
    got = stream.query_multi(
        [{"kind": "bfs", "sources": []},
         {"kind": "pagerank", "resets": np.zeros((0, N))}],
        backend="numpy",
    )
    assert got == [[], []]
    assert ENGINE_BUILDS.count == before
    with pytest.raises(ValueError):
        stream.query_multi([{"kind": "nope", "sources": [1]}], backend="numpy")


# ---------------------------------------------------------------------------
# (9) opt-in sync fast path
# ---------------------------------------------------------------------------


def test_fastpath_serves_idle_singleton_on_caller_thread(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, fastpath=True)
    with svc:
        first = svc.query("bfs", source=3, timeout=30)
        st = svc.stats()
        assert st["lanes"]["bfs"]["fastpath_syncs"] == 1
        assert st["lanes"]["bfs"]["flushed_batches"] == 0  # no executor hop
        # the sync miss was fully metered
        t = st["tenants"]["default"]
        assert t["submitted"] == t["admitted"] == t["completed"] == 1
        # and it filled the cache: the repeat is a submit-time hit
        tk = svc.submit("bfs", source=3)
        assert tk.cached
        assert np.array_equal(tk.result(timeout=5), first)
    assert np.array_equal(
        first, stream.query_batch([3], kind="bfs", backend="numpy")[0]
    )


def test_capture_rides_inflight_promotion(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list)
    svc.CAPTURE_WAIT_S = 10.0
    gate = threading.Event()      # holds the promotion pass open
    entered = threading.Event()   # the pass is in flight
    parked = threading.Event()    # the miss chose the capture path
    orig_carry = svc._cache.carry_forward

    def slow_carry(*a, **kw):
        entered.set()
        gate.wait(30.0)
        return orig_carry(*a, **kw)

    svc._cache.carry_forward = slow_carry
    orig_wait = svc._capture_wait

    def spy_wait(ticket, session, stamp):
        parked.set()
        return orig_wait(ticket, session, stamp)

    svc._capture_wait = spy_wait
    with svc:
        svc.query("bfs", source=3, timeout=30)
        svc.query("bfs", source=3, timeout=30)  # hot on the anchor
        vpass_before = svc._admission.tenant("default").vpass
        stream.insert_edges(np.array([[3, 40]]))  # publish -> pass wakes
        assert entered.wait(10.0)  # promotion now held open at the gate
        out = {}

        def go():
            t = svc.submit("bfs", source=3, deadline_s=20.0)
            out["value"] = t.result(timeout=30)
            out["ticket"] = t

        th = threading.Thread(target=go)
        th.start()
        assert parked.wait(10.0)  # the miss is riding the pass, not a lane
        gate.set()
        th.join(timeout=30)
        assert "value" in out
        tk = out["ticket"]
        assert tk.cached and tk.fastpath and tk.batch_size == 0
        st = svc.stats()
        assert st["lanes"]["bfs"]["capture_hits"] == 1
        assert st["cache"]["promoted_incremental"] >= 1
        # a captured hit meters the ledger but never the WFQ pass
        assert svc._admission.tenant("default").vpass == vpass_before
        assert st["tenants"]["default"]["cached"] >= 2
    ref = stream.query_batch([3], kind="bfs", backend="numpy")[0]
    assert np.array_equal(out["value"], ref)


def test_fastpath_jax_zero_retraces_after_warmup(rmat_edge_list):
    stream, svc = make_service(rmat_edge_list, backend="jax", max_batch=4,
                               fastpath=True)
    with svc:
        svc.warmup()
        from repro.core.traversal import TRACES

        before = TRACES.count
        for src in (3, 5, 3, 9):
            svc.query("bfs", source=src, timeout=30)
        st = svc.stats()
        assert st["lanes"]["bfs"]["retraces"] == 0
        assert TRACES.count == before  # pow2=1 covered by the warmup ladder
        assert st["lanes"]["bfs"]["fastpath_syncs"] >= 1
        assert st["lanes"]["bfs"]["cache_hits"] >= 1
