"""Property-graph API v2: weighted edgeMap end to end (DESIGN.md §8).

Pins the PR's contract:
  (1) backend-generic ``sssp`` / ``weighted_pagerank`` with EXACT
      numpy-vs-jax parity on random weighted RMAT graphs (integer
      weights: every (min, +) distance is exact in f32), plus a scipy
      ``csgraph.bellman_ford`` cross-check;
  (2) value-array storage semantics: insert overwrites the weight of a
      duplicate key, delete drops it, through the flat rank-merge AND
      the tree-side weight map, published atomically by the stream;
  (3) the unweighted path is untouched: no value array is allocated
      anywhere and the weighted segment-sum kernel is never dispatched
      (spy), while weighted engines DO dispatch it;
  (4) ``sssp_batch`` keeps the O(1)-host-syncs contract (HOST_SYNCS
      spy) and matches serial ``sssp`` on both backends;
  (5) the ``Counter`` spy is thread-safe (bump() from reader threads).
"""
import threading

import numpy as np
import pytest

from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core.streaming import AspenStream
from repro.core.traversal import HOST_SYNCS, Counter, NumpyEngine, make_engine
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize


def _pair_weights(edges: np.ndarray, mod: int = 7) -> np.ndarray:
    """Deterministic symmetric integer weights in [1, mod]: both
    directions of an undirected pair get the same value, and integer
    weights keep every shortest-path sum exact in float32 (so the f32
    jax backend and the f64 numpy backend agree EXACTLY)."""
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    return ((lo * 1000003 + hi) % mod + 1).astype(np.float64)


@pytest.fixture(scope="module")
def weighted_graph():
    edges = symmetrize(rmat_edges(8, 2000, seed=21))  # 256 vertices
    return 256, edges, _pair_weights(edges)


@pytest.fixture(scope="module")
def engines(weighted_graph):
    n, edges, w = weighted_graph
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges, weights=w)))
    eng_jx = make_engine(fg.from_edges(n, edges, weights=w))
    return eng_np, eng_jx


@pytest.fixture(scope="module")
def sources(weighted_graph):
    n, _, _ = weighted_graph
    return np.random.default_rng(5).integers(0, n, 8)


# ---------------------------------------------------------------------------
# backend parity: SSSP and weighted PageRank (one text, two substrates)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("diropt", [False, True])
def test_sssp_parity_exact(weighted_graph, engines, diropt):
    n, edges, w = weighted_graph
    eng_np, eng_jx = engines
    src = int(edges[0, 0])
    d_np = talg.sssp(eng_np, src, direction_optimize=diropt)
    d_jx = talg.sssp(eng_jx, src, direction_optimize=diropt)
    # integer weights: f32 sums are exact -> parity is EXACT, not approx
    np.testing.assert_array_equal(d_np, np.asarray(d_jx, np.float64))
    assert d_np[src] == 0.0
    # unreachable vertices are +inf on both
    np.testing.assert_array_equal(np.isinf(d_np), np.isinf(np.asarray(d_jx)))


def test_sssp_scipy_bellman_ford_cross_check(weighted_graph, engines):
    scipy = pytest.importorskip("scipy")
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import bellman_ford

    n, edges, w = weighted_graph
    eng_np, eng_jx = engines
    src = int(edges[0, 0])
    # duplicate directed edges would sum in the COO->CSR conversion;
    # build from the deduped pool (the graph the engines actually see)
    ea = fg.to_edge_array(eng_jx.g)
    wa = fg.to_weight_array(eng_jx.g)
    A = csr_matrix((wa, (ea[:, 0], ea[:, 1])), shape=(n, n))
    d_ref = bellman_ford(A, directed=True, indices=src)
    np.testing.assert_allclose(talg.sssp(eng_np, src), d_ref)
    np.testing.assert_allclose(
        np.asarray(talg.sssp(eng_jx, src), np.float64), d_ref
    )


def test_sssp_respects_weights_not_hops(engines):
    """A 2-hop cheap path must beat a 1-hop expensive edge."""
    gf = fg.from_edges(
        4,
        np.array([[0, 1], [1, 2], [0, 2]]),
        weights=np.array([1.0, 1.0, 10.0]),
    )
    for eng in (make_engine(gf), ):
        d = talg.sssp(eng, 0)
        assert d[2] == 2.0  # via 0->1->2, not the direct 10.0 edge
    # numpy engine over the weighted tree agrees
    gt = G.build_graph(
        4, np.array([[0, 1], [1, 2], [0, 2]]),
        weights=np.array([1.0, 1.0, 10.0]),
    )
    assert talg.sssp(NumpyEngine(G.flat_snapshot(gt)), 0)[2] == 2.0


def test_weighted_pagerank_parity(engines):
    eng_np, eng_jx = engines
    pr_np = talg.weighted_pagerank(eng_np, iters=12)
    pr_jx = talg.weighted_pagerank(eng_jx, iters=12)
    np.testing.assert_allclose(pr_np.sum(), 1.0, rtol=1e-6)  # mass conserved
    np.testing.assert_allclose(pr_np, pr_jx, atol=1e-6)
    # weights matter: the unweighted ranking differs
    pr_unw = talg.pagerank(
        NumpyEngine(G.flat_snapshot(G.build_graph(eng_np.n, fg.to_edge_array(eng_jx.g)))),
        iters=12,
    )
    assert not np.allclose(pr_np, pr_unw, atol=1e-6)


def test_weighted_pagerank_equals_pagerank_when_unweighted(weighted_graph):
    n, edges, _ = weighted_graph
    eng = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
    np.testing.assert_array_equal(
        talg.weighted_pagerank(eng, iters=8), talg.pagerank(eng, iters=8)
    )


def test_weighted_degrees(weighted_graph, engines):
    n, edges, w = weighted_graph
    eng_np, eng_jx = engines
    ea = fg.to_edge_array(eng_jx.g)
    wa = fg.to_weight_array(eng_jx.g)
    expect = np.zeros(n)
    np.add.at(expect, ea[:, 0], wa)
    np.testing.assert_allclose(eng_np.weighted_degrees, expect)
    np.testing.assert_allclose(
        np.asarray(eng_jx.weighted_degrees, np.float64), expect, rtol=1e-6
    )
    # unweighted engines fall back to plain degrees (as float)
    eng_u = make_engine(fg.from_edges(n, edges))
    np.testing.assert_array_equal(
        np.asarray(eng_u.weighted_degrees), np.asarray(eng_u.degrees, np.float32)
    )


def test_edge_map_reduce_weighted_semiring(weighted_graph, engines):
    """out[v] = sum w(u,v) * values[u] on both backends."""
    n, edges, w = weighted_graph
    eng_np, eng_jx = engines
    vals = np.random.default_rng(0).standard_normal(n)
    ea = fg.to_edge_array(eng_jx.g)
    wa = fg.to_weight_array(eng_jx.g)
    expect = np.zeros(n)
    np.add.at(expect, ea[:, 1], wa * vals[ea[:, 0]])
    np.testing.assert_allclose(eng_np.edge_map_reduce(vals), expect)
    np.testing.assert_allclose(
        np.asarray(eng_jx.edge_map_reduce(vals.astype(np.float32)), np.float64),
        expect, rtol=1e-4, atol=1e-4,
    )
    # batched form agrees row-wise with the scalar form
    rows = np.stack([vals, -vals, np.ones(n)])
    out_b = eng_np.edge_map_reduce_batch(rows)
    for i in range(3):
        np.testing.assert_allclose(out_b[i], eng_np.edge_map_reduce(rows[i]))


# ---------------------------------------------------------------------------
# batched SSSP: O(1) syncs + parity with serial on both backends
# ---------------------------------------------------------------------------


def test_sssp_multi_matches_serial_both_backends(engines, sources):
    eng_np, eng_jx = engines
    d_jx = talg.sssp_multi(eng_jx, sources)  # in-trace driver
    d_np = talg.sssp_multi(eng_np, sources)  # serial-loop fallback
    assert d_jx.shape == d_np.shape == (len(sources), eng_np.n)
    np.testing.assert_array_equal(d_np, d_jx)  # integer weights: exact
    for i, s in enumerate(sources[:3]):  # and against serial on jax itself
        np.testing.assert_array_equal(talg.sssp(eng_jx, int(s)), d_jx[i].astype(np.float32))


def test_sssp_batch_constant_syncs(engines, sources):
    _, eng_jx = engines
    talg.sssp_multi(eng_jx, sources)  # warm the jit at B=8
    talg.sssp_multi(eng_jx, sources[:4])  # ... and at B=4
    base = HOST_SYNCS.count
    talg.sssp_multi(eng_jx, sources[:4])
    syncs_b4 = HOST_SYNCS.count - base
    base = HOST_SYNCS.count
    talg.sssp_multi(eng_jx, sources)
    syncs_b8 = HOST_SYNCS.count - base
    assert syncs_b8 == syncs_b4 <= 2  # O(1), independent of B
    base = HOST_SYNCS.count
    for s in sources[:4]:
        talg.sssp(eng_jx, int(s))
    assert HOST_SYNCS.count - base > 4 * syncs_b4  # the loop the batch kills


def test_stream_query_batch_sssp(weighted_graph):
    n, edges, w = weighted_graph
    s = AspenStream(G.build_graph(n, edges, weights=w))
    srcs = np.random.default_rng(2).integers(0, n, 4)
    d_j = s.query_batch(srcs, kind="sssp", backend="jax")
    d_n = s.query_batch(srcs, kind="sssp", backend="numpy")
    np.testing.assert_array_equal(d_j, d_n)


# ---------------------------------------------------------------------------
# storage semantics: overwrite on insert, drop on delete, mirror parity
# ---------------------------------------------------------------------------


def test_insert_overwrites_duplicate_key_weight():
    g = fg.from_edges(4, np.array([[0, 1], [1, 2]]), weights=np.array([1.0, 2.0]))
    g2 = fg.insert_edges_host(
        g, np.array([[0, 1], [2, 3]]), weights=np.array([7.0, 3.0])
    )
    ea, wa = fg.to_edge_array(g2), fg.to_weight_array(g2)
    got = {tuple(e): float(x) for e, x in zip(ea.tolist(), wa)}
    assert got == {(0, 1): 7.0, (1, 2): 2.0, (2, 3): 3.0}
    # baseline sort-union implements the same overwrite semantics
    from repro.core import flat_ctree as fct

    pool = fct.FlatCTree(g.keys, g.m, g.weights)
    batch = fg.batch_from_edges(np.array([[0, 1]]), weights=np.array([7.0]))
    merged = fct.union_sort(pool, batch, g.edge_capacity)
    assert float(fct.to_val_array(merged)[0]) == 7.0


def test_delete_drops_weight_and_stream_publishes_atomically(weighted_graph):
    n, edges, w = weighted_graph
    s = AspenStream(G.build_graph(n, edges[:1000], weights=w[:1000]))
    s.insert_edges(edges[1000:], symmetric=False, weights=w[1000:])
    # mirror == tree weights, edge for edge
    mirror = s.flat_graph()
    ea, wa = fg.to_edge_array(mirror), fg.to_weight_array(mirror)
    np.testing.assert_allclose(
        wa, s.flat_snapshot().edge_weights(ea[:, 0], ea[:, 1])
    )
    # overwrite through the stream, both substrates see the new value
    e0 = edges[:1]
    s.insert_edges(e0, symmetric=False, weights=np.array([42.0]))
    snap = s.flat_snapshot()
    assert snap.edge_weights(e0[:, 0], e0[:, 1])[0] == 42.0
    m2 = s.flat_graph()
    ea2, wa2 = fg.to_edge_array(m2), fg.to_weight_array(m2)
    hit = (ea2[:, 0] == e0[0, 0]) & (ea2[:, 1] == e0[0, 1])
    assert wa2[hit][0] == 42.0
    # delete drops the key AND the value from both substrates
    s.delete_edges(e0, symmetric=False)
    ea3 = fg.to_edge_array(s.flat_graph())
    assert not ((ea3[:, 0] == e0[0, 0]) & (ea3[:, 1] == e0[0, 1])).any()


def test_weighted_upgrade_mid_stream():
    """The first weighted batch upgrades an unweighted stream: existing
    edges read as unit weight, new edges carry their values."""
    s = AspenStream(G.build_graph(4, np.array([[0, 1], [1, 0]])))
    assert s.flat_graph().weights is None
    s.insert_edges(np.array([[1, 2]]), symmetric=False, weights=np.array([5.0]))
    m = s.flat_graph()
    assert m.weights is not None
    got = {
        tuple(e): float(x)
        for e, x in zip(fg.to_edge_array(m).tolist(), fg.to_weight_array(m))
    }
    assert got == {(0, 1): 1.0, (1, 0): 1.0, (1, 2): 5.0}
    snap = s.flat_snapshot()
    np.testing.assert_allclose(
        snap.edge_weights(np.array([0, 1, 1]), np.array([1, 0, 2])),
        [1.0, 1.0, 5.0],
    )


def test_symmetric_insert_carries_weight_both_directions(weighted_graph):
    n, _, _ = weighted_graph
    s = AspenStream(G.build_graph(n, np.empty((0, 2), np.int64)))
    s.insert_edges(np.array([[3, 9]]), weights=np.array([2.5]))  # symmetric
    snap = s.flat_snapshot()
    np.testing.assert_allclose(
        snap.edge_weights(np.array([3, 9]), np.array([9, 3])), [2.5, 2.5]
    )


def test_mirrorless_weighted_rebuild_path(weighted_graph):
    """mirror=False streams rebuild the FlatGraph per engine request;
    the rebuild must carry the weights."""
    n, edges, w = weighted_graph
    s = AspenStream(G.build_graph(n, edges, weights=w), mirror=False)
    eng = s.engine("jax")
    assert eng.weights is not None
    src = int(edges[0, 0])
    np.testing.assert_array_equal(
        np.asarray(talg.sssp(eng, src), np.float64),
        talg.sssp(s.engine("numpy"), src),
    )


# ---------------------------------------------------------------------------
# the unweighted path is untouched (no value array, no weighted kernel)
# ---------------------------------------------------------------------------


def test_unweighted_path_allocates_no_value_array(weighted_graph, monkeypatch):
    n, edges, _ = weighted_graph
    s = AspenStream(G.build_graph(n, edges[:1500]))
    s.insert_edges(edges[1500:], symmetric=False)
    s.delete_edges(edges[:10], symmetric=False)
    mirror = s.flat_graph()
    assert mirror.weights is None  # storage: no value array
    eng = s.engine("jax")
    assert eng.weights is None and not eng.weighted
    assert eng.aux.w_by_dst is None  # aux: no extra leaves

    # kernels: the weighted segment-sum is NEVER dispatched unweighted
    import repro.core.traversal.jax_backend as jb

    def _trap(*a, **k):
        raise AssertionError("weighted kernel dispatched on unweighted path")

    with monkeypatch.context() as mp:
        mp.setattr(jb.kops, "segment_sum_weighted", _trap)
        talg.pagerank(eng, iters=2)
        talg.pagerank_multi(eng, iters=2)
    # ... while a weighted engine DOES dispatch it
    eng_w = make_engine(
        fg.from_edges(n, edges, weights=_pair_weights(edges))
    )
    calls = {"n": 0}
    real = jb.kops.segment_sum_weighted

    def spy(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    with monkeypatch.context() as mp:
        mp.setattr(jb.kops, "segment_sum_weighted", spy)
        talg.weighted_pagerank(eng_w, iters=3)
    assert calls["n"] == 3  # one weighted kernel reduce per iteration


def test_unweighted_tree_has_no_weight_state(weighted_graph):
    n, edges, _ = weighted_graph
    g = G.build_graph(n, edges)
    assert g.wtree is None
    g2 = G.insert_edges(g, edges[:5])
    assert g2.wtree is None  # unweighted insert stays value-free
    assert not G.flat_snapshot(g2).weighted


# ---------------------------------------------------------------------------
# Counter spy thread-safety (satellite)
# ---------------------------------------------------------------------------


def test_counter_bump_is_thread_safe():
    c = Counter()
    per_thread, n_threads = 5_000, 8

    def worker():
        for _ in range(per_thread):
            c.bump()

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.count == per_thread * n_threads  # racy += would undercount
