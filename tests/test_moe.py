"""MoE dispatch equivalence: einsum vs hierarchical vs shard_map paths."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import moe as MOE
from repro.models.transformer import LMConfig, MoEFields


def _setup(capacity_factor=16.0, dispatch_shards=0):
    m = MoEFields(n_experts=8, top_k=2, capacity_factor=capacity_factor,
                  dispatch_shards=dispatch_shards)
    cfg = LMConfig("m", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                   d_ff=16, vocab=64, moe=m)
    key = jax.random.PRNGKey(0)
    p = MOE.moe_init(key, cfg, dtype=jnp.float32)
    x = jax.random.normal(key, (8, 4, 32), jnp.float32)
    return cfg, p, x


def test_hierarchical_dispatch_matches_baseline():
    cfg0, p, x = _setup()
    ref = MOE.moe_apply(p, cfg0, x)
    cfg1, _, _ = _setup(dispatch_shards=4)
    out = MOE.moe_apply(p, cfg1, x)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-6)


def test_moe_conserves_tokens_under_huge_capacity():
    """With capacity >> needed, every token is processed exactly top_k ways."""
    cfg, p, x = _setup(capacity_factor=32.0)
    out = MOE.moe_apply(p, cfg, x)
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())


def test_capacity_drops_are_bounded():
    """Tiny capacity drops tokens but never corrupts others."""
    cfg, p, x = _setup(capacity_factor=0.25)
    out = MOE.moe_apply(p, cfg, x)
    assert bool(jnp.isfinite(out).all())


def test_shardmap_moe_matches_einsum_moe():
    """The explicit-collective MoE (B3 in §Perf) is numerically identical."""
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices for a (data, model) mesh")
    from repro.models.moe_shardmap import moe_apply_shardmap

    data_dim = min(4, jax.device_count() // 2)  # batch=8 must divide
    mesh = jax.make_mesh((data_dim, 2), ("data", "model"))
    cfg, p, x = _setup()
    ref = MOE.moe_apply(p, cfg, x)
    with mesh:
        out = jax.jit(
            lambda p, x: moe_apply_shardmap(p, cfg, x, mesh),
            in_shardings=(
                jax.tree.map(lambda _: NamedSharding(mesh, P()), p),
                NamedSharding(mesh, P("data", None, None)),
            ),
        )(p, x)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-5)


def test_load_balance_loss_positive():
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (64, 8))
    _, top_e = jax.lax.top_k(jax.nn.softmax(logits), 2)
    l = MOE.load_balance_loss(logits, top_e, 8)
    assert float(l) >= 1.0 - 1e-3  # >= 1 at/near balance, > 1 when skewed
