"""Flat (TPU-native) C-tree vs numpy oracles and the faithful C-tree."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ctree as ct
from repro.core import flat_ctree as fct
from repro.core import pam
from repro.core.hash import is_head_np

from proptest import given, st


def sets(max_value=1 << 20, max_size=300):
    return st.lists(
        st.integers(min_value=0, max_value=max_value), min_size=0, max_size=max_size
    )


@given(sets())
def test_from_to_array(xs):
    v = np.unique(np.asarray(xs, dtype=np.int64)).astype(np.int32)
    t = fct.from_array(v)
    np.testing.assert_array_equal(fct.to_array(t), v)


@given(sets(max_size=120), sets(max_size=120))
def test_member(a, q):
    va = np.unique(np.asarray(a, dtype=np.int64)).astype(np.int32)
    vq = np.asarray(sorted(set(q)), dtype=np.int32)
    t = fct.from_array(va)
    if vq.size == 0:
        return
    got = np.asarray(fct.member(t, jnp.asarray(vq)))
    np.testing.assert_array_equal(got, np.isin(vq, va))


@given(sets(max_size=200), sets(max_size=200), st.booleans())
def test_union_matches_oracle(a, b, optimized):
    va = np.unique(np.asarray(a, dtype=np.int64)).astype(np.int32)
    vb = np.unique(np.asarray(b, dtype=np.int64)).astype(np.int32)
    ta, tb = fct.from_array(va), fct.from_array(vb)
    cap = fct.grown_capacity(va.size + vb.size)
    fn = fct.union_merge if optimized else fct.union_sort
    out = fn(ta, tb, cap)
    np.testing.assert_array_equal(fct.to_array(out), np.union1d(va, vb))
    # padding intact
    assert (np.asarray(out.data)[int(out.n):] == fct.sentinel_for(out.data.dtype)).all()


@given(sets(max_size=200), sets(max_size=200))
def test_union_merge_equals_union_sort(a, b):
    va = np.unique(np.asarray(a, dtype=np.int64)).astype(np.int32)
    vb = np.unique(np.asarray(b, dtype=np.int64)).astype(np.int32)
    ta, tb = fct.from_array(va), fct.from_array(vb)
    cap = fct.grown_capacity(va.size + vb.size)
    s = fct.union_sort(ta, tb, cap)
    m = fct.union_merge(ta, tb, cap)
    np.testing.assert_array_equal(np.asarray(s.data), np.asarray(m.data))
    assert int(s.n) == int(m.n)


@given(sets(max_size=200), sets(max_size=200))
def test_difference_intersect(a, b):
    va = np.unique(np.asarray(a, dtype=np.int64)).astype(np.int32)
    vb = np.unique(np.asarray(b, dtype=np.int64)).astype(np.int32)
    ta, tb = fct.from_array(va), fct.from_array(vb)
    d = fct.difference(ta, tb, fct.capacity(ta))
    np.testing.assert_array_equal(fct.to_array(d), np.setdiff1d(va, vb))
    i = fct.intersect(ta, tb, fct.capacity(ta))
    np.testing.assert_array_equal(fct.to_array(i), np.intersect1d(va, vb))


def test_multi_insert_delete_host_api():
    rng = np.random.default_rng(0)
    t = fct.from_array(rng.integers(0, 1 << 20, 1000).astype(np.int32))
    base = fct.to_array(t).copy()
    batch = rng.integers(0, 1 << 20, 500).astype(np.int32)
    t2 = fct.multi_insert(t, batch)
    np.testing.assert_array_equal(fct.to_array(t2), np.union1d(base, batch))
    t3 = fct.multi_delete(t2, batch)
    np.testing.assert_array_equal(fct.to_array(t3), np.setdiff1d(np.union1d(base, batch), batch))
    # persistence: t unchanged (immutability of jax arrays)
    np.testing.assert_array_equal(fct.to_array(t), base)


def test_flat_heads_agree_with_faithful_ctree():
    """The two levels chunk identically: same head set, same chunk sizes."""
    rng = np.random.default_rng(1)
    v = np.unique(rng.integers(0, 1 << 20, 5000)).astype(np.int32)
    b, seed = 64, ct.DEFAULT_SEED
    flat = fct.from_array(v)
    hm = np.asarray(fct.head_mask(flat, b, seed))[: v.size]
    np.testing.assert_array_equal(hm, is_head_np(v.astype(np.int64), b, np.uint32(seed)))
    faithful = ct.build(v.astype(np.int64), b=b, seed=seed)
    heads_faithful = [k for k, _ in pam.TreeModule().iter_entries(faithful.tree)] if faithful.tree else []
    np.testing.assert_array_equal(v[hm], np.asarray(heads_faithful, dtype=np.int32))


def test_capacity_growth_policy():
    assert fct.grown_capacity(0) == 8
    assert fct.grown_capacity(8) == 16
    assert fct.grown_capacity(1000) == 1024
    # powers of two quantize recompiles
    caps = {fct.grown_capacity(n) for n in range(1, 10000)}
    assert len(caps) <= 12
