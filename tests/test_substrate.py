"""Substrate tests: optimizer, data determinism, checkpoint/restore,
fault tolerance, straggler policy, sharding rules."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import NeighborSampler, power_law_graph, recsys_batch, token_batch
from repro.dist.fault_tolerance import HeartbeatMonitor, ResumableRun, StragglerPolicy
from repro.optim import adamw


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw.update(state, grads, params, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert int(state.step) == 200


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_wsd_schedule_shape():
    lr = adamw.wsd_schedule(10, 100, 50, 1.0, floor=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(50)) == pytest.approx(1.0)
    assert float(lr(110 + 50)) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# data determinism (the fault-tolerance contract)
# ---------------------------------------------------------------------------


def test_token_batch_deterministic_and_host_sharded():
    a = token_batch(1, 7, 8, 32, 100)
    b = token_batch(1, 7, 8, 32, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = token_batch(1, 8, 8, 32, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])
    h0 = token_batch(1, 7, 8, 32, 100, host_id=0, n_hosts=2)
    h1 = token_batch(1, 7, 8, 32, 100, host_id=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_recsys_batch_deterministic():
    a = recsys_batch(0, 3, 16)
    b = recsys_batch(0, 3, 16)
    np.testing.assert_array_equal(a["sparse_ids"], b["sparse_ids"])


def test_neighbor_sampler_deterministic_and_valid():
    offs, nbrs = power_law_graph(256, 5000, seed=0)
    feats = np.zeros((256, 4), np.float32)
    s = NeighborSampler(offs, nbrs, feats)
    a = s.sample_batch(0, 5, 32, (5, 3))
    b = s.sample_batch(0, 5, 32, (5, 3))
    np.testing.assert_array_equal(a["seeds"], b["seeds"])
    np.testing.assert_array_equal(a["neigh_masks"][1], b["neigh_masks"][1])
    assert a["neigh_feats"][0].shape == (32, 5, 4)
    assert a["neigh_feats"][1].shape == (32, 5, 3, 4)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 4))}}
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.list_steps(str(tmp_path)) == [5]
    step, restored = ckpt.restore(str(tmp_path), template=tree)
    assert step == 5
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_uncommitted_ignored(tmp_path):
    tree = {"x": jnp.zeros(3)}
    p = ckpt.save(str(tmp_path), 1, tree)
    os.remove(os.path.join(p, "COMMITTED"))
    assert ckpt.list_steps(str(tmp_path)) == []
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), template=tree)


def test_async_checkpointer_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.arange(5)}
    for s in [10, 20, 30, 40]:
        saver.save_async(s, tree)
    saver.wait()
    assert ckpt.list_steps(str(tmp_path)) == [30, 40]


def test_checkpoint_elastic_remesh(tmp_path):
    """Restore a checkpoint onto a different mesh (elastic re-shard)."""
    devs = jax.devices()
    mesh1 = jax.sharding.Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    specs = {"w": P(None, "model")}
    ckpt.save(str(tmp_path), 3, tree, specs)
    step, restored = ckpt.restore(
        str(tmp_path), mesh=mesh1, target_specs=specs, template=tree
    )
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.spec == P(None, "model")


def test_resumable_run_resumes(tmp_path):
    make = lambda: {"w": jnp.zeros(4)}  # noqa: E731
    run = ResumableRun(str(tmp_path), make, save_every=10)
    step0, state = run.restore_or_init()
    assert step0 == 0
    state = {"w": jnp.full(4, 7.0)}
    run.maybe_save(10, state)
    run.finish()
    run2 = ResumableRun(str(tmp_path), make, save_every=10)
    step1, state1 = run2.restore_or_init()
    assert step1 == 10
    np.testing.assert_array_equal(np.asarray(state1["w"]), 7.0 * np.ones(4))


# ---------------------------------------------------------------------------
# fault tolerance policies
# ---------------------------------------------------------------------------


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(n_hosts=3, timeout_s=10)
    now = 100.0
    hb.beat(0, now), hb.beat(1, now), hb.beat(2, now)
    assert hb.dead_hosts(now + 5) == []
    hb.beat(0, now + 12), hb.beat(1, now + 12)
    assert hb.dead_hosts(now + 15) == [2]


def test_straggler_policy_accepts_and_reassigns():
    sp = StragglerPolicy(n_shards=8, min_shards=6, deadline_s=10, strikes_out=2)
    # shard 7 persistently late
    r1 = sp.step({s: (30.0 if s == 7 else 1.0) for s in range(8)})
    assert r1["accepted"] and r1["late"] == [7]
    assert r1["grad_scale"] == pytest.approx(8 / 7)
    r2 = sp.step({s: (30.0 if s == 7 else 1.0) for s in range(8)})
    assert r2["reassign"] == [7]
    # catastrophic step: too few shards
    r3 = sp.step({s: 30.0 for s in range(8)})
    assert not r3["accepted"] and r3["grad_scale"] == 0.0


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def test_zero1_specs_shards_largest_free_dim():
    from repro.dist import shardings as SH

    devs = jax.devices()
    mesh = jax.sharding.Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 4, "model": 2}

    p = {"w": jnp.zeros((8, 6)), "b": jnp.zeros((3,))}
    specs = {"w": P(None, "model"), "b": P(None)}
    z = SH.zero1_specs(specs, p, FakeMesh())
    assert z["w"] == P("data", "model")  # dim0=8 divisible by 4
    assert z["b"] == P(None)  # 3 not divisible by 4 -> untouched


def test_lm_param_specs_divisibility_guards():
    from repro.configs import registry
    from repro.dist import shardings as SH

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = registry.get("smollm-360m").full  # 15 heads: not divisible
    specs = SH.lm_param_specs(cfg, FakeMesh())
    assert specs["layers"]["attn"]["wq"] == P(None, None, None, None)  # replicated
    cfg2 = registry.get("qwen2.5-3b").full  # 16 heads: divisible
    specs2 = SH.lm_param_specs(cfg2, FakeMesh())
    assert specs2["layers"]["attn"]["wq"] == P(None, None, "model", None)
    assert specs2["layers"]["attn"]["wk"] == P(None, None, None, None)  # kv=2
