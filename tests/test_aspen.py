"""Aspen layer: graph-of-C-trees, versioning, edgeMap, algorithms,
streaming, flat TPU graph — vs. scipy-free numpy oracles."""
import threading

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import baselines as bl
from repro.core import ctree as ct
from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core.traversal import from_ids, edge_map
from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
from repro.core.versioning import VersionedGraph
from repro.data.rmat import rmat_edges, symmetrize


@pytest.fixture(scope="module")
def small_graph():
    edges = symmetrize(rmat_edges(8, 2000, seed=7))  # 256 vertices
    n = 256
    return n, edges


def ref_bfs_levels(n, edges, src):
    """Oracle BFS levels via adjacency dict."""
    adj = {}
    for u, v in edges:
        adj.setdefault(int(u), []).append(int(v))
    lev = np.full(n, -1, dtype=np.int64)
    lev[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in adj.get(u, []):
                if lev[v] == -1:
                    lev[v] = d + 1
                    nxt.append(v)
        frontier = nxt
        d += 1
    return lev


# ---------------------------------------------------------------------------
# graph of C-trees
# ---------------------------------------------------------------------------


def test_build_and_counts(small_graph):
    n, edges = small_graph
    g = G.build_graph(n, edges)
    assert G.num_vertices(g) == n
    assert G.num_edges(g) == edges.shape[0]
    # neighbor correctness per vertex
    for v in range(0, n, 17):
        expect = np.sort(edges[edges[:, 0] == v][:, 1])
        got = ct.to_array(G.find_vertex(g, v))
        np.testing.assert_array_equal(got, expect)


def test_insert_delete_edges_functional(small_graph):
    n, edges = small_graph
    keep, batch = edges[:-500], edges[-500:]
    g0 = G.build_graph(n, keep)
    g1 = G.insert_edges(g0, batch)
    assert G.num_edges(g1) == edges.shape[0]
    assert G.num_edges(g0) == keep.shape[0]  # old snapshot untouched
    g2 = G.delete_edges(g1, batch)
    assert G.num_edges(g2) == keep.shape[0]
    for v in np.unique(batch[:, 0])[:10]:
        np.testing.assert_array_equal(
            ct.to_array(G.find_vertex(g2, int(v))),
            np.sort(keep[keep[:, 0] == v][:, 1]),
        )


def test_flat_snapshot(small_graph):
    n, edges = small_graph
    g = G.build_graph(n, edges)
    snap = G.flat_snapshot(g)
    assert snap.n == n
    degs = np.zeros(n, dtype=np.int64)
    np.add.at(degs, edges[:, 0], 1)
    for v in range(0, n, 13):
        assert snap.degree(v) == degs[v]


def test_memory_model_ordering(small_graph):
    n, edges = small_graph
    g = G.build_graph(n, edges)
    de = G.graph_nbytes(g, compressed=True)
    node = G.graph_nbytes(g, compressed=False)
    unc = G.graph_nbytes(g, chunked=False)
    assert de <= node < unc  # Table 2 ordering: DE <= NoDE < Uncompressed


# ---------------------------------------------------------------------------
# versioning
# ---------------------------------------------------------------------------


def test_versioning_refcounts():
    vg = VersionedGraph("v0")
    a = vg.acquire()
    vg.set("v1")
    b = vg.acquire()
    assert a.graph == "v0" and b.graph == "v1"
    assert vg.live_versions() == 2
    assert vg.release(a)  # old version collected on last release
    assert vg.live_versions() == 1
    vg.release(b)
    assert vg.live_versions() == 1  # current stays


def test_versioning_concurrent_readers_writer():
    vg = VersionedGraph(0)
    errors = []

    def reader():
        for _ in range(200):
            v = vg.acquire()
            if not isinstance(v.graph, int):
                errors.append("bad graph")
            vg.release(v)

    def writer():
        for i in range(200):
            vg.set(i + 1)

    threads = [threading.Thread(target=reader) for _ in range(4)] + [
        threading.Thread(target=writer)
    ]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert not errors
    assert vg.current_stamp == 200


# ---------------------------------------------------------------------------
# edgeMap + algorithms vs oracles
# ---------------------------------------------------------------------------


def test_edge_map_one_hop(small_graph):
    n, edges = small_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    src = int(edges[0, 0])
    out = edge_map(
        snap,
        from_ids(n, [src]),
        F=lambda us, vs: np.ones(us.shape, dtype=bool),
        C=lambda vs: np.ones(vs.shape, dtype=bool),
        direction_optimize=False,
    )
    np.testing.assert_array_equal(out.to_sparse(), np.unique(edges[edges[:, 0] == src][:, 1]))


@pytest.mark.parametrize("diropt", [False, True])
def test_bfs_matches_oracle(small_graph, diropt):
    n, edges = small_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    src = int(edges[0, 0])
    parents = alg.bfs(snap, src, direction_optimize=diropt)
    ref = ref_bfs_levels(n, edges, src)
    # same reachability
    np.testing.assert_array_equal(parents >= 0, ref >= 0)
    # parents form valid BFS tree: level(parent(v)) == level(v) - 1
    edge_set = set((int(u), int(v)) for u, v in edges)
    for v in range(n):
        if parents[v] >= 0 and v != src:
            assert (int(parents[v]), v) in edge_set
            assert ref[parents[v]] == ref[v] - 1


def test_bc_sums_match_brandes_oracle(small_graph):
    n, edges = small_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    src = int(edges[0, 0])
    dep = alg.bc(snap, src)
    # oracle: textbook Brandes from single source
    adj = {}
    for u, v in edges:
        adj.setdefault(int(u), []).append(int(v))
    import collections

    sigma = collections.defaultdict(float)
    sigma[src] = 1.0
    dist = {src: 0}
    order = [src]
    q = collections.deque([src])
    while q:
        u = q.popleft()
        for v in adj.get(u, []):
            if v not in dist:
                dist[v] = dist[u] + 1
                q.append(v)
                order.append(v)
            if dist[v] == dist[u] + 1:
                sigma[v] += sigma[u]
    delta = collections.defaultdict(float)
    for v in reversed(order):
        for w in adj.get(v, []):
            if dist.get(w, -2) == dist[v] + 1:
                delta[v] += sigma[v] / sigma[w] * (1 + delta[w])
    delta[src] = 0.0  # Brandes: the source accumulates no dependency
    for v in range(n):
        np.testing.assert_allclose(dep[v], delta.get(v, 0.0), rtol=1e-9, atol=1e-9)


def test_mis_valid(small_graph):
    n, edges = small_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    s = alg.mis(snap)
    assert alg.verify_mis(snap, s)


def test_two_hop_and_local_cluster(small_graph):
    n, edges = small_graph
    g = G.build_graph(n, edges)
    src = int(edges[0, 0])
    th = alg.two_hop(g, src)
    # oracle
    adj = {}
    for u, v in edges:
        adj.setdefault(int(u), set()).add(int(v))
    one = adj.get(src, set())
    two = set(one)
    for u in one:
        two |= adj.get(u, set())
    two.discard(src)
    np.testing.assert_array_equal(th, np.asarray(sorted(two)))
    cluster = alg.local_cluster(g, src)
    assert src in cluster.tolist()


def test_pagerank_cc(small_graph):
    n, edges = small_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    pr = alg.pagerank(snap, iters=20)
    np.testing.assert_allclose(pr.sum(), 1.0, rtol=1e-6)
    cc = alg.connected_components(snap)
    # endpoints of every edge share a component
    assert (cc[edges[:, 0]] == cc[edges[:, 1]]).all()


# ---------------------------------------------------------------------------
# streaming: concurrent updates + queries
# ---------------------------------------------------------------------------


def test_make_update_stream_properties(small_graph):
    n, edges = small_graph
    keep, stream = make_update_stream(edges, 400, seed=3)
    assert stream.shape[1] == 3
    ins = stream[stream[:, 2] == 0]
    # insertions were removed from the kept graph
    kept_keys = set((keep[:, 0] << 32 | keep[:, 1]).tolist())
    for u, v, _ in ins[:50]:
        assert (int(u) << 32 | int(v)) not in kept_keys


def test_concurrent_updates_and_queries(small_graph):
    n, edges = small_graph
    keep, stream = make_update_stream(edges, 200, seed=4)
    s = AspenStream(G.build_graph(n, keep))
    stats = run_concurrent(
        s,
        stream,
        query_fn=lambda snap: alg.bfs(snap, int(edges[0, 0])),
        duration_s=1.0,
        batch_size=10,
    )
    assert stats.n_updates > 0 and stats.n_queries > 0
    assert stats.updates_per_sec > 0
    # serializability sanity: final edge count consistent with the updates
    v = s.acquire()
    assert G.num_edges(v.graph) > 0
    s.release(v)


# ---------------------------------------------------------------------------
# flat (TPU) graph equivalence
# ---------------------------------------------------------------------------


def test_flat_graph_matches_tree_graph(small_graph):
    n, edges = small_graph
    gt = G.build_graph(n, edges)
    gf = fg.from_edges(n, edges)
    assert int(gf.m) == G.num_edges(gt)
    degs = np.asarray(fg.degrees(gf))
    snap = G.flat_snapshot(gt)
    for v in range(0, n, 11):
        assert degs[v] == snap.degree(v)
    np.testing.assert_array_equal(fg.to_edge_array(gf), edges)


def test_flat_graph_insert_delete(small_graph):
    n, edges = small_graph
    keep, batch = edges[:-300], edges[-300:]
    gf = fg.from_edges(n, keep)
    gf2 = fg.insert_edges_host(gf, batch)
    np.testing.assert_array_equal(fg.to_edge_array(gf2), edges)
    assert int(gf.m) == keep.shape[0]  # snapshot persistence
    gf3 = fg.delete_edges_host(gf2, batch)
    np.testing.assert_array_equal(fg.to_edge_array(gf3), keep)
    # baseline sort-union agrees with optimized rank-merge
    gf2s = fg.insert_edges_host(gf, batch, optimized=False)
    np.testing.assert_array_equal(np.asarray(gf2s.keys), np.asarray(gf2.keys))


def test_flat_bfs_matches_oracle(small_graph):
    from repro.core.traversal.jax_backend import bfs_levels

    n, edges = small_graph
    gf = fg.from_edges(n, edges)
    src = int(edges[0, 0])
    levels = np.asarray(bfs_levels(gf, src))
    ref = ref_bfs_levels(n, edges, src)
    np.testing.assert_array_equal(levels, ref)


def test_flat_cc_matches_oracle(small_graph):
    from repro.core.traversal.jax_backend import cc_labels

    n, edges = small_graph
    gf = fg.from_edges(n, edges)
    cc = np.asarray(cc_labels(gf))
    assert (cc[edges[:, 0]] == cc[edges[:, 1]]).all()


# ---------------------------------------------------------------------------
# baselines behave
# ---------------------------------------------------------------------------


def test_baselines_agree_with_aspen(small_graph):
    n, edges = small_graph
    st = bl.StingerLike(n)
    st.insert_edges(edges)
    csr = bl.StaticCSR(n, edges)
    ll = bl.LlamaLike(n, edges)
    for v in range(0, n, 29):
        expect = np.unique(edges[edges[:, 0] == v][:, 1])
        np.testing.assert_array_equal(np.sort(st.neighbors(v)), expect)
        np.testing.assert_array_equal(csr.neighbors(v), expect)
        np.testing.assert_array_equal(ll.neighbors(v), expect)
    src = int(edges[0, 0])
    p1 = bl.bfs_adjacency(st, src)
    p2 = bl.bfs_adjacency(csr, src)
    assert ((p1 >= 0) == (p2 >= 0)).all()
