"""Shared pytest config.

NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
smoke tests and benches must see the 1 real CPU device; only
launch/dryrun.py requests 512 placeholder devices (and only in its own
process).
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))  # for `proptest` import

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro",
        deadline=None,  # jit compilation makes first examples slow
        max_examples=40,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("repro")
except ImportError:
    pass
