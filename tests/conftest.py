"""Shared pytest config.

NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
smoke tests and benches must see the 1 real CPU device; only
launch/dryrun.py requests 512 placeholder devices (and only in its own
process).
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))  # for `proptest` import


def pytest_collection_modifyitems(config, items):
    """CPU-safe marker defaults: ``tpu``-marked tests auto-skip unless a
    real TPU backend is present (Pallas kernels otherwise run under
    interpret=True, which the non-marked tests already cover), and
    ``multidevice``-marked tests auto-skip unless the process sees >= 8
    devices — run them on CPU via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (a separate
    process: the flag must be set before jax initializes, which is why
    it is NOT set here — smoke tests and benches must see the 1 real
    CPU device)."""
    import jax

    if jax.device_count() < 8:
        skip_md = pytest.mark.skip(
            reason="needs >= 8 devices "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
        for item in items:
            if "multidevice" in item.keywords:
                item.add_marker(skip_md)
    if jax.default_backend() == "tpu":
        return
    skip_tpu = pytest.mark.skip(reason="requires TPU hardware (CPU run)")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro",
        deadline=None,  # jit compilation makes first examples slow
        max_examples=40,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("repro")
except ImportError:
    pass
