"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.core import chunks as ck


# ---------------------------------------------------------------------------
# delta decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_chunks,max_len", [(8, 128), (3, 40), (17, 300), (64, 256)])
def test_delta_decode_shapes(n_chunks, max_len):
    rng = np.random.default_rng(0)
    deltas = rng.integers(0, 100, size=(n_chunks, max_len)).astype(np.int32)
    deltas[:, 0] = 0
    anchors = rng.integers(0, 1 << 20, size=n_chunks).astype(np.int32)
    got = ops.decode_chunks(jnp.asarray(anchors), jnp.asarray(deltas))
    want = ref.delta_decode_ref(jnp.asarray(anchors), jnp.asarray(deltas))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_pool_roundtrip():
    """Kernel decode of a PackedDeltas pool reproduces the original data."""
    rng = np.random.default_rng(1)
    data = np.unique(rng.integers(0, 1 << 30, size=20_000))
    # chunk boundaries from hash heads, as the flat C-tree produces them
    from repro.core.hash import is_head_np

    heads = np.flatnonzero(is_head_np(data, 128))
    offs = np.concatenate([[0], heads, [data.size]])
    offs = np.unique(offs)
    packed = ck.pack_deltas(data, offs, width="uint16")
    out = ops.decode_pool(packed)
    np.testing.assert_array_equal(out, data)


# ---------------------------------------------------------------------------
# segment sum (one-hot MXU formulation)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,D,n_out", [(512, 128, 128), (2048, 64, 300), (100, 32, 50)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_sorted(E, D, n_out, dtype):
    rng = np.random.default_rng(2)
    dst = np.sort(rng.integers(0, n_out, size=E)).astype(np.int32)
    msg = rng.standard_normal((E, D)).astype(np.float32)
    msg_q = jnp.asarray(msg, dtype=dtype)
    got = ops.segment_sum(jnp.asarray(dst), msg_q, n_out)
    # ground truth: exact fp32 sum of the quantized inputs (kernel
    # accumulates fp32; only the final store is in `dtype`)
    want = ref.segment_sum_sorted_ref(jnp.asarray(dst), msg_q.astype(jnp.float32), n_out)
    rtol = 1e-6 if dtype == jnp.float32 else 1e-2
    atol = 1e-3 if dtype == jnp.float32 else 0.08
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=rtol, atol=atol
    )


def test_segment_sum_empty_segments():
    dst = jnp.asarray(np.array([5, 5, 9], dtype=np.int32))
    msg = jnp.ones((3, 8), jnp.float32)
    out = np.asarray(ops.segment_sum(dst, msg, 16))
    assert out[5].sum() == 16.0 and out[9].sum() == 8.0
    assert out.sum() == 24.0


# ---------------------------------------------------------------------------
# fanout aggregate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["mean", "sum", "max"])
@pytest.mark.parametrize("B,K,D", [(16, 10, 64), (5, 25, 128)])
def test_fanout_aggregate(op, B, K, D):
    rng = np.random.default_rng(3)
    feats = rng.standard_normal((B, K, D)).astype(np.float32)
    mask = (rng.random((B, K)) < 0.7).astype(np.float32)
    mask[:, 0] = 1.0  # no fully-empty bags
    got = ops.fanout_aggregate(jnp.asarray(feats), jnp.asarray(mask), op)
    want = ref.fanout_aggregate_ref(jnp.asarray(feats), jnp.asarray(mask), op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# flash decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("BH,Q,S,d", [(4, 8, 1024, 64), (2, 4, 2048, 128), (1, 8, 640, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(BH, Q, S, d, dtype):
    rng = np.random.default_rng(4)
    q = rng.standard_normal((BH, Q, d)).astype(np.float32)
    k = rng.standard_normal((BH, S, d)).astype(np.float32)
    v = rng.standard_normal((BH, S, d)).astype(np.float32)
    lengths = rng.integers(S // 2, S + 1, size=BH).astype(np.int32)
    qj, kj, vj = (jnp.asarray(x, dtype=dtype) for x in (q, k, v))
    got = ops.flash_decode_attn(qj, kj, vj, jnp.asarray(lengths))
    want = ref.flash_decode_ref(qj, kj, vj, jnp.asarray(lengths))
    rtol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=rtol, atol=2e-2
    )


def test_flash_decode_short_length():
    """Cache much shorter than padded S: masked blocks contribute nothing."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2048, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2048, 64)), jnp.float32)
    lengths = jnp.asarray([7], jnp.int32)
    got = ops.flash_decode_attn(q, k, v, lengths)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# block SpMM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,E,D", [(256, 2000, 64), (300, 5000, 128)])
def test_block_spmm_vs_dense(n, E, D):
    rng = np.random.default_rng(6)
    src = rng.integers(0, n, size=E)
    dst = rng.integers(0, n, size=E)
    x = rng.standard_normal((n, D)).astype(np.float32)
    got = np.asarray(ops.spmm_from_edges(n, src, dst, jnp.asarray(x)))
    a = np.zeros((n, n), dtype=np.float32)
    np.add.at(a, (dst, src), 1.0)
    want = a @ x
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_block_spmm_matches_segment_sum():
    """Two TPU-native aggregation routes agree: SpMM and sorted segsum."""
    rng = np.random.default_rng(7)
    n, E, D = 128, 1000, 32
    src = rng.integers(0, n, size=E)
    dst = np.sort(rng.integers(0, n, size=E))
    x = rng.standard_normal((n, D)).astype(np.float32)
    via_spmm = np.asarray(ops.spmm_from_edges(n, src, dst, jnp.asarray(x)))
    msg = x[src]
    via_seg = np.asarray(ops.segment_sum(jnp.asarray(dst, dtype=jnp.int32), jnp.asarray(msg), n))
    np.testing.assert_allclose(via_spmm, via_seg, rtol=1e-5, atol=1e-4)
