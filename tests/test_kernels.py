"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.core import chunks as ck


# ---------------------------------------------------------------------------
# delta decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_chunks,max_len", [(8, 128), (3, 40), (17, 300), (64, 256)])
def test_delta_decode_shapes(n_chunks, max_len):
    rng = np.random.default_rng(0)
    deltas = rng.integers(0, 100, size=(n_chunks, max_len)).astype(np.int32)
    deltas[:, 0] = 0
    anchors = rng.integers(0, 1 << 20, size=n_chunks).astype(np.int32)
    got = ops.decode_chunks(jnp.asarray(anchors), jnp.asarray(deltas))
    want = ref.delta_decode_ref(jnp.asarray(anchors), jnp.asarray(deltas))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_pool_roundtrip():
    """Kernel decode of a PackedDeltas pool reproduces the original data."""
    rng = np.random.default_rng(1)
    data = np.unique(rng.integers(0, 1 << 30, size=20_000))
    # chunk boundaries from hash heads, as the flat C-tree produces them
    from repro.core.hash import is_head_np

    heads = np.flatnonzero(is_head_np(data, 128))
    offs = np.concatenate([[0], heads, [data.size]])
    offs = np.unique(offs)
    packed = ck.pack_deltas(data, offs, width="uint16")
    out = ops.decode_pool(packed)
    np.testing.assert_array_equal(out, data)


def test_decode_chunks_normalizes_anchor_column():
    """decode_chunks defines column 0 as the anchor position and
    NORMALIZES whatever the caller left there to zero (the documented
    ``deltas[:, 0] == 0`` invariant): garbage in that slot must not leak
    into the decode."""
    rng = np.random.default_rng(7)
    deltas = rng.integers(0, 50, size=(6, 96)).astype(np.int32)
    deltas[:, 0] = rng.integers(1, 1000, 6)  # scatter artifacts in col 0
    anchors = rng.integers(0, 1 << 20, size=6).astype(np.int32)
    got = ops.decode_chunks(jnp.asarray(anchors), jnp.asarray(deltas))
    clean = deltas.copy()
    clean[:, 0] = 0
    want = ref.delta_decode_ref(jnp.asarray(anchors), jnp.asarray(clean))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got)[:, 0], anchors)


def _random_chunked_stream(rng, R, L, k=8, n_esc=3):
    """Raw escape-lane chunk arrays (the ChunkedStream layout) with
    ``n_esc`` escapes per row at ascending columns, int16 lanes."""
    deltas = rng.integers(0, 100, size=(R, L)).astype(np.int16)
    deltas[:, 0] = 0
    ovf_pos = np.full((R, k), L, np.int32)
    ovf_add = np.zeros((R, k), np.int32)
    for r in range(R):
        cols = np.sort(rng.choice(np.arange(1, L), n_esc, replace=False))
        ovf_pos[r, :n_esc] = cols
        ovf_add[r, :n_esc] = rng.integers(40_000, 1 << 20, n_esc)
        deltas[r, cols] = 0  # escaped slots store 0 in the lane
    anchors = rng.integers(0, 1 << 10, size=R).astype(np.int32)
    return anchors, deltas, ovf_pos, ovf_add


@pytest.mark.parametrize("R,L", [(4, 128), (7, 128), (1, 128), (13, 128)])
def test_decode_chunked_stream_vs_ref(R, L):
    """Escape-lane kernel decode == oracle, incl. row counts that are
    NOT a multiple of the kernel's row block."""
    rng = np.random.default_rng(8)
    a, d, p, v = _random_chunked_stream(rng, R, L)
    got = ops.decode_chunked_stream(
        jnp.asarray(a), jnp.asarray(d), jnp.asarray(p), jnp.asarray(v)
    )
    want = ref.delta_decode_chunked_ref(
        jnp.asarray(a), jnp.asarray(d), jnp.asarray(p), jnp.asarray(v)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_chunked_stream_matches_core_codec():
    """The kernel decode agrees with core/compressed's pure-jnp decode on
    a stream the real encoder built (the cross-layer contract)."""
    from repro.core import compressed as cz

    rng = np.random.default_rng(9)
    deltas = rng.integers(0, 200, 5 * cz.CHUNK)
    deltas[rng.choice(deltas.size, 10, replace=False)] = 50_000
    vals = np.cumsum(deltas).astype(np.int32)
    c = cz.encode_stream(jnp.asarray(vals), width=2)
    assert not bool(c.spill)
    got = ops.decode_chunked_stream(c.anchors, c.deltas, c.ovf_pos, c.ovf_add)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(cz.decode_rows(c))
    )


# ---------------------------------------------------------------------------
# segment sum (one-hot MXU formulation)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,D,n_out", [(512, 128, 128), (2048, 64, 300), (100, 32, 50)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_sorted(E, D, n_out, dtype):
    rng = np.random.default_rng(2)
    dst = np.sort(rng.integers(0, n_out, size=E)).astype(np.int32)
    msg = rng.standard_normal((E, D)).astype(np.float32)
    msg_q = jnp.asarray(msg, dtype=dtype)
    got = ops.segment_sum(jnp.asarray(dst), msg_q, n_out)
    # ground truth: exact fp32 sum of the quantized inputs (kernel
    # accumulates fp32; only the final store is in `dtype`)
    want = ref.segment_sum_sorted_ref(jnp.asarray(dst), msg_q.astype(jnp.float32), n_out)
    rtol = 1e-6 if dtype == jnp.float32 else 1e-2
    atol = 1e-3 if dtype == jnp.float32 else 0.08
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=rtol, atol=atol
    )


def test_segment_sum_empty_segments():
    dst = jnp.asarray(np.array([5, 5, 9], dtype=np.int32))
    msg = jnp.ones((3, 8), jnp.float32)
    out = np.asarray(ops.segment_sum(dst, msg, 16))
    assert out[5].sum() == 16.0 and out[9].sum() == 8.0
    assert out.sum() == 24.0


def _sorted_chunked_dst(rng, E, n_out):
    """A sorted dst stream encoded through the real codec (carry-forward
    pad convention), plus the raw sorted array it encodes."""
    from repro.core import compressed as cz

    dst = np.sort(rng.integers(0, n_out, E)).astype(np.int32)
    c = cz.encode_stream(jnp.asarray(dst), width=2)
    assert not bool(c.spill)
    return dst, c


@pytest.mark.parametrize("E,D,n_out", [(512, 32, 128), (700, 16, 300)])
def test_segment_sum_chunked_vs_raw(E, D, n_out):
    """Fused-decode chunked segment-sum == raw segment-sum on the same
    edges, incl. an edge count that is NOT a multiple of EDGE_BLOCK
    (the builder's carry-forward pads must contribute nothing)."""
    rng = np.random.default_rng(4)
    dst, c = _sorted_chunked_dst(rng, E, n_out)
    msg = rng.standard_normal((c.length, D)).astype(np.float32)
    msg[E:] = 0.0  # rows past the valid prefix must be masked to zero
    got = np.asarray(
        ops.segment_sum_chunked(
            c.anchors, c.deltas, c.ovf_pos, c.ovf_add, jnp.asarray(msg), n_out
        )
    )
    want = np.asarray(
        ref.segment_sum_sorted_ref(jnp.asarray(dst), jnp.asarray(msg[:E]), n_out)
    )
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_segment_sum_weighted_chunked_vs_raw():
    rng = np.random.default_rng(6)
    E, D, n_out = 600, 16, 200
    dst, c = _sorted_chunked_dst(rng, E, n_out)
    w = np.zeros(c.length, np.float32)
    w[:E] = rng.random(E).astype(np.float32) + 0.5
    msg = rng.standard_normal((c.length, D)).astype(np.float32)
    msg[E:] = 0.0
    got = np.asarray(
        ops.segment_sum_weighted_chunked(
            c.anchors, c.deltas, c.ovf_pos, c.ovf_add,
            jnp.asarray(w), jnp.asarray(msg), n_out,
        )
    )
    want = np.asarray(
        ref.segment_sum_sorted_ref(
            jnp.asarray(dst), jnp.asarray(w[:E, None] * msg[:E]), n_out
        )
    )
    np.testing.assert_allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# fanout aggregate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["mean", "sum", "max"])
@pytest.mark.parametrize("B,K,D", [(16, 10, 64), (5, 25, 128)])
def test_fanout_aggregate(op, B, K, D):
    rng = np.random.default_rng(3)
    feats = rng.standard_normal((B, K, D)).astype(np.float32)
    mask = (rng.random((B, K)) < 0.7).astype(np.float32)
    mask[:, 0] = 1.0  # no fully-empty bags
    got = ops.fanout_aggregate(jnp.asarray(feats), jnp.asarray(mask), op)
    want = ref.fanout_aggregate_ref(jnp.asarray(feats), jnp.asarray(mask), op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# flash decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("BH,Q,S,d", [(4, 8, 1024, 64), (2, 4, 2048, 128), (1, 8, 640, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(BH, Q, S, d, dtype):
    rng = np.random.default_rng(4)
    q = rng.standard_normal((BH, Q, d)).astype(np.float32)
    k = rng.standard_normal((BH, S, d)).astype(np.float32)
    v = rng.standard_normal((BH, S, d)).astype(np.float32)
    lengths = rng.integers(S // 2, S + 1, size=BH).astype(np.int32)
    qj, kj, vj = (jnp.asarray(x, dtype=dtype) for x in (q, k, v))
    got = ops.flash_decode_attn(qj, kj, vj, jnp.asarray(lengths))
    want = ref.flash_decode_ref(qj, kj, vj, jnp.asarray(lengths))
    rtol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=rtol, atol=2e-2
    )


def test_flash_decode_short_length():
    """Cache much shorter than padded S: masked blocks contribute nothing."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2048, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2048, 64)), jnp.float32)
    lengths = jnp.asarray([7], jnp.int32)
    got = ops.flash_decode_attn(q, k, v, lengths)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# block SpMM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,E,D", [(256, 2000, 64), (300, 5000, 128)])
def test_block_spmm_vs_dense(n, E, D):
    rng = np.random.default_rng(6)
    src = rng.integers(0, n, size=E)
    dst = rng.integers(0, n, size=E)
    x = rng.standard_normal((n, D)).astype(np.float32)
    got = np.asarray(ops.spmm_from_edges(n, src, dst, jnp.asarray(x)))
    a = np.zeros((n, n), dtype=np.float32)
    np.add.at(a, (dst, src), 1.0)
    want = a @ x
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_block_spmm_matches_segment_sum():
    """Two TPU-native aggregation routes agree: SpMM and sorted segsum."""
    rng = np.random.default_rng(7)
    n, E, D = 128, 1000, 32
    src = rng.integers(0, n, size=E)
    dst = np.sort(rng.integers(0, n, size=E))
    x = rng.standard_normal((n, D)).astype(np.float32)
    via_spmm = np.asarray(ops.spmm_from_edges(n, src, dst, jnp.asarray(x)))
    msg = x[src]
    via_seg = np.asarray(ops.segment_sum(jnp.asarray(dst, dtype=jnp.int32), jnp.asarray(msg), n))
    np.testing.assert_allclose(via_spmm, via_seg, rtol=1e-5, atol=1e-4)
