"""Kernel block-shape autotuner (kernels/autotune.py, DESIGN.md §12):
winner-cache hit/miss semantics, sweep determinism under a pinned
candidate grid, the consult-once-per-shape-bucket contract dispatch
relies on, and the opt-in on-disk table."""
import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import autotune  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402


@pytest.fixture(autouse=True)
def _clean_table(monkeypatch):
    """Every test starts from an empty memo, the built-in candidate
    grids, and no disk table / forced sweeping."""
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    autotune.reset()
    autotune.set_candidates(None)
    yield
    autotune.reset()
    autotune.set_candidates(None)


# -- cache key ---------------------------------------------------------------


def test_bucket_rounds_up_to_power_of_two():
    assert [autotune._bucket(x) for x in (1, 2, 3, 1000, 1024, 1025)] == [
        1, 2, 4, 1024, 1024, 2048,
    ]


def test_cache_key_buckets_shapes_together():
    a = autotune.cache_key("segment_sum", "cpu", {"E": 900, "n": 500})
    b = autotune.cache_key("segment_sum", "cpu", {"E": 1024, "n": 512})
    c = autotune.cache_key("segment_sum", "cpu", {"E": 1025, "n": 512})
    assert a == b != c
    assert a[0] == autotune.TABLE_VERSION
    # backend is part of the key: a TPU winner never leaks onto CPU
    assert a != autotune.cache_key("segment_sum", "tpu", {"E": 900, "n": 500})


# -- memo hit/miss -----------------------------------------------------------


def test_winner_cache_miss_then_hit():
    shape = {"E": 4096, "n": 512}
    p1 = autotune.get_params("segment_sum", shape, backend="cpu")
    key = autotune.cache_key("segment_sum", "cpu", shape)
    assert autotune.CONSULTS[key] == 1  # cold consult
    p2 = autotune.get_params("segment_sum", shape, backend="cpu")
    assert p2 == p1
    assert autotune.CONSULTS[key] == 1  # memo hit: no second consult
    # a different bucket is a different entry -> one more cold consult
    autotune.get_params("segment_sum", {"E": 9000, "n": 512}, backend="cpu")
    assert sum(autotune.CONSULTS.values()) == 2


def test_defaults_when_sweeping_disabled():
    # CPU without REPRO_AUTOTUNE=1: sweep_fn must NOT be invoked
    def boom(params):  # pragma: no cover - the point is it never runs
        raise AssertionError("sweep ran with sweeping disabled")

    p = autotune.get_params(
        "segment_sum_chunked", {"R": 64, "n": 256}, sweep_fn=boom, backend="cpu"
    )
    assert p == autotune.DEFAULTS["segment_sum_chunked"]


# -- sweep -------------------------------------------------------------------


def test_sweep_determinism_under_pinned_grid(monkeypatch):
    """With a single-candidate grid the sweep must return that candidate,
    every time, and the veto path must fall through to the survivor."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    pinned = {"edge_block": 256, "dst_block": 128}
    autotune.set_candidates({"segment_sum": [pinned]})
    calls = []

    def make(params):
        calls.append(dict(params))
        return lambda: jnp.zeros(())

    for _ in range(2):
        autotune.reset()
        p = autotune.get_params(
            "segment_sum", {"E": 2048, "n": 256}, sweep_fn=make, backend="cpu"
        )
        assert p == pinned
    assert calls == [pinned, pinned]  # exactly one candidate per sweep


def test_sweep_vetoes_infeasible_candidates(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    good = {"edge_block": 512, "dst_block": 128}
    autotune.set_candidates(
        {"segment_sum": [{"edge_block": 99999, "dst_block": 128}, good]}
    )

    def make(params):
        if params["edge_block"] > 2048:
            raise ValueError("block larger than problem")
        return lambda: jnp.zeros(())

    p = autotune.get_params(
        "segment_sum", {"E": 2048, "n": 256}, sweep_fn=make, backend="cpu"
    )
    assert p == good


def test_sweep_all_vetoed_falls_back_to_defaults(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    autotune.set_candidates({"segment_sum": [{"edge_block": 1, "dst_block": 1}]})

    def make(params):
        raise ValueError("nope")

    p = autotune.get_params(
        "segment_sum", {"E": 128, "n": 64}, sweep_fn=make, backend="cpu"
    )
    assert p == autotune.DEFAULTS["segment_sum"]


# -- on-disk table -----------------------------------------------------------


def test_disk_table_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    pinned = {"edge_block": 1024, "dst_block": 256}
    autotune.set_candidates({"segment_sum": [pinned]})
    shape = {"E": 4096, "n": 1024}
    p = autotune.get_params(
        "segment_sum", shape, sweep_fn=lambda _: (lambda: jnp.zeros(())),
        backend="cpu",
    )
    assert p == pinned
    table = json.loads(path.read_text())
    key_s = autotune._key_str(autotune.cache_key("segment_sum", "cpu", shape))
    assert table[key_s] == pinned
    # a fresh process (reset memo) reads the winner back WITHOUT sweeping
    autotune.reset()
    autotune.set_candidates({"segment_sum": []})  # sweep would return defaults
    p2 = autotune.get_params("segment_sum", shape, backend="cpu")
    assert p2 == pinned


def test_no_disk_writes_without_env(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    autotune.get_params("segment_sum", {"E": 256, "n": 64}, backend="cpu")
    assert list(tmp_path.iterdir()) == []  # table is process-local only


def test_corrupt_disk_table_is_empty_table(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    path.write_text("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    p = autotune.get_params("segment_sum", {"E": 256, "n": 64}, backend="cpu")
    assert p == autotune.DEFAULTS["segment_sum"]


# -- dispatch integration ----------------------------------------------------


def test_dispatch_consults_once_per_shape_bucket():
    """ops.segment_sum with default blocks consults the table exactly
    once per (kernel, backend, bucket) — repeated dispatches are memo
    hits, a new bucket is one more cold consult."""
    autotune.reset()
    rng = np.random.default_rng(0)

    def run(E, n):
        dst = jnp.asarray(np.sort(rng.integers(0, n, E)), jnp.int32)
        msg = jnp.ones((E, 4), jnp.float32)
        return np.asarray(kops.segment_sum(dst, msg, n))

    run(1000, 256)
    seg_keys = [k for k in autotune.CONSULTS if k[1] == "segment_sum"]
    assert len(seg_keys) == 1 and autotune.CONSULTS[seg_keys[0]] == 1
    run(1000, 256)  # same bucket: still exactly one cold consult
    run(990, 250)   # same bucket after pow2 rounding: still one
    assert sum(v for k, v in autotune.CONSULTS.items() if k[1] == "segment_sum") == 1
    run(5000, 256)  # E buckets to 8192 != 1024: second cold consult
    assert sum(v for k, v in autotune.CONSULTS.items() if k[1] == "segment_sum") == 2


def test_dispatch_result_matches_explicit_blocks():
    rng = np.random.default_rng(1)
    E, n = 2000, 300
    dst = jnp.asarray(np.sort(rng.integers(0, n, E)), jnp.int32)
    msg = jnp.asarray(rng.standard_normal((E, 4)), jnp.float32)
    auto = np.asarray(kops.segment_sum(dst, msg, n))
    manual = np.asarray(kops.segment_sum(dst, msg, n, edge_block=512, dst_block=128))
    np.testing.assert_allclose(auto, manual, rtol=1e-6)
