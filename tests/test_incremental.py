"""Incremental computation across versions (DESIGN.md §11).

Pins the PR's contract:

  (1) delta capture — every edge-batch publish records the applied
      batch in ``Version.aux["delta"]``, and ``vg.delta_between``
      composes records across live hops (None — the full-recompute
      signal — when a hop was collected or published without one);
  (2) warm-start PageRank — seeded from the previous version's scores
      it reaches the full-recompute fixed point (f32 tolerance) in
      <= half the spy-counted rounds after a 1%-of-edges batch;
  (3) incremental CC / BFS / SSSP match a full recompute EXACTLY on
      the numpy and jax backends (the sharded backend is pinned in
      test_sharded_engine.py, including the 8-device mesh);
  (4) subscriptions stay fresh through the incremental path when the
      delta chain is intact and fall back to a full recompute — never
      a wrong answer — when it is not;
  (5) ``query_batch`` computes each unique source once and fans the
      row back out to every duplicate request.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.streaming import AspenStream
from repro.core.traversal import algorithms as talg
from repro.core.versioning import DELTA, Delta
from repro.data.rmat import rmat_edges, symmetrize

N = 256


def _weights_for(edges):
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    return ((lo * 1000003 + hi) % 7 + 1).astype(np.float64)  # symmetric, integer


@pytest.fixture(scope="module")
def base_edges():
    return symmetrize(rmat_edges(8, 2000, seed=7))  # 256 vertices


@pytest.fixture(scope="module")
def batch(base_edges):
    """~1% of directed edges, self-loop-free, deterministic."""
    k = max(1, base_edges.shape[0] // 100)
    rng = np.random.default_rng(3)
    b = rng.integers(0, N, size=(4 * k, 2)).astype(np.int64)
    return b[b[:, 0] != b[:, 1]][:k]


def _hold_after(stream):
    """Acquire the just-published version so the delta chain to it
    stays intact while later hops are published."""
    return stream.vg.acquire()


# ---------------------------------------------------------------------------
# delta capture + delta_between
# ---------------------------------------------------------------------------


def test_publish_records_delta(base_edges, batch):
    s = AspenStream(G.build_graph(N, base_edges))
    s.insert_edges(batch)
    v = s.vg.acquire()
    d = v.aux.get(DELTA)
    assert isinstance(d, Delta)
    # symmetric insert records both directions, exactly as applied
    assert d.ins.shape == (2 * batch.shape[0], 2)
    assert not d.has_deletions and d.ins_w is None
    applied = np.concatenate([batch, batch[:, ::-1]])
    np.testing.assert_array_equal(
        d.ins[np.lexsort(d.ins.T)], applied[np.lexsort(applied.T)]
    )
    s.delete_edges(base_edges[:10], symmetric=False)
    v2 = s.vg.acquire()
    d2 = v2.aux.get(DELTA)
    assert d2.has_deletions and d2.ins.shape[0] == 0
    np.testing.assert_array_equal(d2.dels, base_edges[:10])
    s.vg.release(v)
    s.vg.release(v2)


def test_publish_records_weighted_delta(batch):
    s = AspenStream()
    w = _weights_for(batch).astype(np.float32)
    s.insert_edges(batch, weights=w)
    v = s.vg.acquire()
    d = v.aux[DELTA]
    assert d.ins_w is not None and d.ins_w.shape[0] == d.ins.shape[0]
    # the recorded lane matches the applied (symmetrized) batch
    assert d.nbytes >= d.ins.nbytes
    s.vg.release(v)


def test_delta_between_identity_and_reverse(base_edges):
    s = AspenStream(G.build_graph(N, base_edges))
    v = s.vg.acquire()
    same = s.vg.delta_between(v, v)
    assert isinstance(same, Delta) and same.empty
    s.insert_edges(base_edges[:2])
    v2 = s.vg.acquire()
    assert s.vg.delta_between(v2, v) is None  # backwards: underivable
    s.vg.release(v)
    s.vg.release(v2)


def test_delta_between_concatenates_live_hops(base_edges, batch):
    s = AspenStream(G.build_graph(N, base_edges))
    v0 = s.vg.acquire()
    held = []
    for i in range(3):
        s.insert_edges(batch[i : i + 1])
        held.append(_hold_after(s))
    s.delete_edges(base_edges[:2], symmetric=False)
    vend = s.vg.acquire()
    d = s.vg.delta_between(v0, vend)
    assert d.ins.shape[0] == 6  # 3 symmetric single-edge inserts
    assert d.dels.shape[0] == 2
    for v in [v0, vend] + held:
        s.vg.release(v)


def test_delta_between_none_when_hop_collected(base_edges, batch):
    s = AspenStream(G.build_graph(N, base_edges))
    v0 = s.vg.acquire()
    s.insert_edges(batch[:1])  # nobody holds this hop ...
    s.insert_edges(batch[1:2])  # ... so this publish collects it
    vend = s.vg.acquire()
    assert s.vg.delta_between(v0, vend) is None
    s.vg.release(v0)
    s.vg.release(vend)


def test_delta_between_none_without_delta_record(base_edges):
    s = AspenStream(G.build_graph(N, base_edges), mirror=False)
    v0 = s.vg.acquire()
    s.vg.set(v0.graph)  # raw write: no delta record on the hop
    vend = s.vg.acquire()
    assert s.vg.delta_between(v0, vend) is None
    s.vg.release(v0)
    s.vg.release(vend)


def test_delta_concat_mixed_weight_lanes():
    a = Delta(ins=np.array([[0, 1]]), ins_w=np.array([3.0], np.float32))
    b = Delta(ins=np.array([[1, 2]]))  # unweighted hop: ones-filled
    c = Delta.concat([a, b])
    np.testing.assert_array_equal(c.ins, [[0, 1], [1, 2]])
    np.testing.assert_allclose(c.ins_w, [3.0, 1.0])


# ---------------------------------------------------------------------------
# warm-start PageRank (the 1%-batch acceptance criterion)
# ---------------------------------------------------------------------------


def _streams_around_batch(base_edges, batch):
    s1 = AspenStream(G.build_graph(N, base_edges))
    new = np.concatenate([base_edges, batch, batch[:, ::-1]])
    s2 = AspenStream(G.build_graph(N, new))
    return s1, s2


def test_warm_pagerank_half_rounds_jax(base_edges, batch):
    """After a 1%-of-edges batch, PageRank warm-started from the prior
    scores is within f32 tolerance of the full-recompute fixed point in
    <= half the rounds the full recompute spent (spy-counted)."""
    s1, s2 = _streams_around_batch(base_edges, batch)
    eng1, eng2 = s1.engine("jax"), s2.engine("jax")
    tol = 1e-6
    prev = np.asarray(talg.pagerank(eng1, tol=tol))
    talg.PAGERANK_ROUNDS.count = 0
    cold = np.asarray(talg.pagerank(eng2, tol=tol))
    cold_rounds = talg.PAGERANK_ROUNDS.count
    assert cold_rounds >= 4  # the spy actually counted a real run

    warm = np.asarray(talg.pagerank(eng2, iters=cold_rounds // 2, init=prev))
    assert np.abs(warm - cold).max() <= 2e-6  # f32 tolerance

    # the early-exit mode converges strictly faster warm than cold too
    talg.PAGERANK_ROUNDS.count = 0
    talg.pagerank(eng2, tol=tol, init=prev)
    assert talg.PAGERANK_ROUNDS.count < cold_rounds


def test_warm_pagerank_fixed_point_numpy(base_edges, batch):
    """Same contract on the f64 numpy engine: warm and cold agree at
    the fixed point regardless of init (damping < 1 => unique)."""
    s1, s2 = _streams_around_batch(base_edges, batch)
    eng1, eng2 = s1.engine("numpy"), s2.engine("numpy")
    prev = np.asarray(talg.pagerank(eng1, tol=1e-10))
    talg.PAGERANK_ROUNDS.count = 0
    cold = np.asarray(talg.pagerank(eng2, tol=1e-10))
    cold_rounds = talg.PAGERANK_ROUNDS.count
    talg.PAGERANK_ROUNDS.count = 0
    warm = np.asarray(talg.pagerank(eng2, tol=1e-10, init=prev))
    assert talg.PAGERANK_ROUNDS.count < cold_rounds
    assert np.abs(warm - cold).max() <= 1e-9


# ---------------------------------------------------------------------------
# incremental CC / BFS / SSSP: exact vs full recompute on numpy and jax
# ---------------------------------------------------------------------------


def _versioned_pair(base_edges, batch, weighted=False):
    """One stream, two held versions one edge batch apart (inserts AND
    deletions), plus the composed delta between them."""
    w = _weights_for(base_edges) if weighted else None
    s = AspenStream(G.build_graph(N, base_edges, weights=w))
    v1 = s.vg.acquire()
    kw = {"weights": _weights_for(batch)} if weighted else {}
    s.insert_edges(batch, **kw)
    vmid = _hold_after(s)
    s.delete_edges(base_edges[:20], symmetric=False)
    v2 = s.vg.acquire()
    d = s.vg.delta_between(v1, v2)
    assert isinstance(d, Delta) and d.has_deletions
    return s, v1, v2, d, [vmid]


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_incremental_cc_exact(base_edges, batch, backend):
    s, v1, v2, d, held = _versioned_pair(base_edges, batch)
    e1, e2 = s._engine_for(v1, backend), s._engine_for(v2, backend)
    prev = np.asarray(talg.connected_components(e1), np.int64)
    # deletions present: downgrades to full recompute, still exact
    got = talg.incremental_connected_components(e2, prev, d)
    np.testing.assert_array_equal(got, talg.connected_components(e2))
    # insert-only hop: the seeded label-prop path, exact
    emid = s._engine_for(held[0], backend)
    dmid = s.vg.delta_between(v1, held[0])
    assert not dmid.has_deletions
    got_mid = talg.incremental_connected_components(emid, prev, dmid)
    np.testing.assert_array_equal(got_mid, talg.connected_components(emid))
    # broken chain (None) is the full-recompute signal, still exact
    got_none = talg.incremental_connected_components(e2, prev, None)
    np.testing.assert_array_equal(got_none, talg.connected_components(e2))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_incremental_bfs_exact(base_edges, batch, backend):
    s, v1, v2, d, held = _versioned_pair(base_edges, batch)
    e1, e2 = s._engine_for(v1, backend), s._engine_for(v2, backend)
    src = np.array([0, 31, 128], np.int64)
    p1, d1 = talg.bfs_multi(e1, src)
    fp, fd = talg.bfs_multi(e2, src)
    ip, idp = talg.incremental_bfs(e2, src, p1, d1, d)
    np.testing.assert_array_equal(idp, fd)  # depths exact
    np.testing.assert_array_equal(ip, fp)  # parents bit-identical

    # pure-insert hop exercises the no-dirty fast frontier too
    emid = s._engine_for(held[0], backend)
    dmid = s.vg.delta_between(v1, held[0])
    mp, md = talg.bfs_multi(emid, src)
    ip2, id2 = talg.incremental_bfs(emid, src, p1, d1, dmid)
    np.testing.assert_array_equal(id2, md)
    np.testing.assert_array_equal(ip2, mp)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_incremental_sssp_exact(base_edges, batch, backend):
    s, v1, v2, d, held = _versioned_pair(base_edges, batch, weighted=True)
    e1, e2 = s._engine_for(v1, backend), s._engine_for(v2, backend)
    src = np.array([0, 31, 128], np.int64)
    dist1 = np.asarray(talg.sssp_multi(e1, src), np.float64)
    tree1 = talg.shortest_path_parents(e1, dist1, src)
    got = talg.incremental_sssp(e2, src, dist1, tree1, d)
    np.testing.assert_array_equal(got, talg.sssp_multi(e2, src))


def test_shortest_path_parents_support(base_edges):
    """The recorded SSSP tree is a valid support: every non-source
    finite vertex has a parent edge with dist[v] == dist[p] + w."""
    w = _weights_for(base_edges)
    s = AspenStream(G.build_graph(N, base_edges, weights=w))
    eng = s.engine("numpy")
    src = np.array([0, 7], np.int64)
    dist = np.asarray(talg.sssp_multi(eng, src), np.float64)
    tree = talg.shortest_path_parents(eng, dist, src)
    for b in range(src.size):
        reached = np.isfinite(dist[b])
        assert tree[b, src[b]] == src[b]
        others = reached & (np.arange(N) != src[b])
        assert (tree[b, others] >= 0).all()
        assert (~reached == (tree[b] == -1))[np.arange(N) != src[b]].all()


# ---------------------------------------------------------------------------
# subscriptions
# ---------------------------------------------------------------------------


def test_subscription_stays_fresh_incrementally(base_edges, batch):
    s = AspenStream(G.build_graph(N, base_edges))
    src = np.array([0, 31], np.int64)
    sub_bfs = s.subscribe("bfs", sources=src, backend="numpy")
    sub_cc = s.subscribe("cc", backend="numpy")
    sub_pr = s.subscribe("pagerank", backend="numpy", tol=1e-10)
    assert (sub_bfs.n_full, sub_bfs.n_incremental) == (1, 0)
    for i in range(3):  # refresh every hop: chain always intact
        s.insert_edges(batch[2 * i : 2 * i + 2])
        for sub in (sub_bfs, sub_cc, sub_pr):
            sub.refresh()
    s.delete_edges(base_edges[:5], symmetric=False)
    for sub in (sub_bfs, sub_cc, sub_pr):
        sub.refresh()
        assert sub.stamp == s.vg.current_stamp
    assert sub_bfs.n_incremental == 4 and sub_bfs.n_full == 1
    assert sub_pr.n_incremental == 4 and sub_pr.n_full == 1
    # cc took the incremental path on inserts, full on the deletion hop
    assert sub_cc.n_incremental == 3 and sub_cc.n_full == 2

    eng = s.engine("numpy")
    fp, fd = talg.bfs_multi(eng, src)
    np.testing.assert_array_equal(sub_bfs.value[0], fp)
    np.testing.assert_array_equal(sub_bfs.value[1], fd)
    np.testing.assert_array_equal(sub_cc.value, talg.connected_components(eng))
    assert np.abs(sub_pr.value - talg.pagerank(eng, tol=1e-10)).max() <= 1e-9
    for sub in (sub_bfs, sub_cc, sub_pr):
        sub.close()


def test_subscription_weighted_sssp(base_edges, batch):
    w = _weights_for(base_edges)
    s = AspenStream(G.build_graph(N, base_edges, weights=w))
    src = np.array([3, 200], np.int64)
    with s.subscribe("sssp", sources=src, backend="jax") as sub:
        s.insert_edges(batch, weights=_weights_for(batch))
        sub.refresh()
        s.delete_edges(base_edges[:10], symmetric=False)
        sub.refresh()
        assert sub.n_incremental == 2
        eng = s.engine("jax")
        np.testing.assert_array_equal(sub.value, talg.sssp_multi(eng, src))


def test_subscription_full_fallback_on_broken_chain(base_edges, batch):
    s = AspenStream(G.build_graph(N, base_edges))
    sub = s.subscribe("bfs", sources=[0], backend="numpy")
    # two hops land before the subscriber catches up; the first is
    # collected immediately => delta chain broken => full recompute
    s.insert_edges(batch[:2])
    s.insert_edges(batch[2:4])
    sub.refresh()
    assert sub.n_full == 2 and sub.n_incremental == 0
    eng = s.engine("numpy")
    np.testing.assert_array_equal(sub.value[1], talg.bfs_multi(eng, [0])[1])
    sub.close()


def test_subscription_close_idempotent_and_guards(base_edges):
    s = AspenStream(G.build_graph(N, base_edges))
    sub = s.subscribe("cc", backend="numpy")
    held_stamp = sub.stamp
    sub.close()
    sub.close()  # idempotent
    with pytest.raises(RuntimeError):
        sub.refresh()
    with pytest.raises(ValueError):
        s.subscribe("nope")
    with pytest.raises(ValueError):
        s.subscribe("bfs")  # sources required
    assert held_stamp == 0


# ---------------------------------------------------------------------------
# query_batch dedup
# ---------------------------------------------------------------------------


def test_query_batch_dedups_identical_sources(base_edges, monkeypatch):
    s = AspenStream(G.build_graph(N, base_edges))
    seen = []
    real = talg.bfs_multi

    def spy(eng, sources, **kw):
        seen.append(np.asarray(sources))
        return real(eng, sources, **kw)

    monkeypatch.setattr(talg, "bfs_multi", spy)
    req = [7, 0, 7, 7, 3, 0]
    rows = s.query_batch(req, kind="bfs", backend="numpy")
    assert len(seen) == 1 and seen[0].shape == (3,)  # unique sources only
    assert rows.shape == (len(req), N)  # ... fanned back out
    monkeypatch.undo()
    full = talg.bfs_multi(s.engine("numpy"), np.asarray(req, np.int64))[0]
    np.testing.assert_array_equal(rows, full)


def test_query_batch_dedup_distances(base_edges):
    s = AspenStream(G.build_graph(N, base_edges))
    rows = s.query_batch([5, 5, 1, 5], kind="distances", backend="numpy")
    np.testing.assert_array_equal(rows[0], rows[1])
    np.testing.assert_array_equal(rows[0], rows[3])
    direct = talg.landmark_distances(s.engine("numpy"), np.array([5, 1]))
    np.testing.assert_array_equal(rows[2], direct[1])
