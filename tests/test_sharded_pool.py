"""Range-sharded pool (the §Perf A1 beyond-paper structure): correctness
on a degenerate 1-device mesh + pure-host properties.

PR 5 additions: boundary invariants (empty-shard ``lo`` monotonicity,
rebalance round-trips exactly), insert-then-rebalance parity against
``flat_ctree.union_merge`` at n_shards ∈ {1, 2, 8}, the ``member``
wire-traffic regression (no cross-shard row gather), the value lane
(insert overwrites / delete drops / rebalance preserves), and the
shard-local delete step."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import sharded_pool as sp

from proptest import given, st


def sets(max_value=1 << 30, max_size=400):
    return st.lists(st.integers(min_value=0, max_value=max_value),
                    min_size=1, max_size=max_size)


@given(sets())
def test_from_to_array_roundtrip(xs):
    v = np.unique(np.asarray(xs, dtype=np.int64))
    p = sp.from_array(v, n_shards=4)
    np.testing.assert_array_equal(sp.to_array(p), v)
    # boundaries are monotone (compare, don't subtract: lo[0] is the
    # int64-min sentinel and np.diff would overflow)
    lo = np.asarray(p.lo)
    assert (lo[1:] >= lo[:-1]).all()


@given(sets(max_size=200), sets(max_size=200))
def test_insert_step_matches_union(a, b):
    """shard_map степ on a 1-device mesh == np.union1d."""
    va = np.unique(np.asarray(a, dtype=np.int64))
    vb = np.unique(np.asarray(b, dtype=np.int64))
    mesh = jax.make_mesh((1,), ("shard",))
    cap_per = sp.from_array(va, 1).data.shape[1]
    need = int(2 ** np.ceil(np.log2(va.size + vb.size + 1)))
    pool = sp.from_array(va, 1, cap_per=max(cap_per, need))
    step = sp.make_insert_step(mesh, ("shard",))
    pad = int(2 ** np.ceil(np.log2(vb.size + 1)))
    batch = jnp.asarray(np.concatenate([vb, np.full(pad - vb.size, sp.SENT)]))
    with mesh:
        out = step(pool, batch)
    np.testing.assert_array_equal(sp.to_array(out), np.union1d(va, vb))


def test_member_queries():
    rng = np.random.default_rng(0)
    v = np.unique(rng.integers(0, 1 << 20, 5000))
    p = sp.from_array(v, n_shards=8)
    q = np.concatenate([v[::7], rng.integers(1 << 21, 1 << 22, 50)])
    got = np.asarray(sp.member(p, jnp.asarray(q)))
    np.testing.assert_array_equal(got, np.isin(q, v))


def test_rebalance_restores_even_counts():
    rng = np.random.default_rng(1)
    # skewed inserts: all new keys land in shard 0's range
    v = np.unique(rng.integers(0, 1 << 20, 4000))
    p = sp.from_array(v, n_shards=4)
    mesh = jax.make_mesh((1,), ("shard",))
    # simulate fill imbalance by rebuilding with a skewed value set
    skew = np.unique(np.concatenate([v, rng.integers(0, 100, 3000)]))
    p2 = sp.from_array(skew, 4, cap_per=p.data.shape[1] * 2)
    r = sp.rebalance(p2)
    counts = np.asarray(r.n)
    assert counts.max() - counts.min() <= 1 + skew.size % 4
    np.testing.assert_array_equal(sp.to_array(r), skew)


def test_needs_rebalance_trigger():
    v = np.arange(100, dtype=np.int64)
    p = sp.from_array(v, n_shards=4, cap_per=32)
    assert not sp.needs_rebalance(p)
    p2 = sp.from_array(v, n_shards=4, cap_per=26)
    assert sp.needs_rebalance(p2, slack=0.9)


# ---------------------------------------------------------------------------
# shard auto-tuning policy (ISSUE 8: imbalance stats -> rebalance trigger)
# ---------------------------------------------------------------------------


def test_imbalance_stats():
    assert sp.imbalance_stats(np.array([100, 100, 100, 100]))["imbalance"] == 1.0
    s = sp.imbalance_stats(np.array([300, 100, 100, 100]))
    assert s["max"] == 300 and s["mean"] == 150 and s["imbalance"] == 2.0
    # degenerate inputs never divide by zero
    assert sp.imbalance_stats(np.zeros(4, np.int64))["imbalance"] == 1.0
    assert sp.imbalance_stats(np.array([], np.int64))["imbalance"] == 1.0


def _skewed_pool(rng, n_shards=4, cap_per=8192):
    """Even pool + an insert batch aimed entirely at shard 0's key range
    (range sharding keeps them there -> genuine occupancy skew)."""
    even = np.unique(rng.integers(0, 1 << 20, 1000))
    p = sp.from_array(even, n_shards=n_shards, cap_per=cap_per)
    extra = np.unique(rng.integers(0, int(np.asarray(p.lo)[1]), 4000))
    step = sp.make_insert_step(sp.pool_mesh(n_shards), ("shard",))
    pad = int(2 ** np.ceil(np.log2(extra.size + 1)))
    batch = np.full(pad, sp.SENT, np.int64)
    batch[: extra.size] = extra
    with sp.pool_mesh(n_shards):
        p2 = step(p, jnp.asarray(batch))
    return p, p2, np.union1d(even, extra)


def test_should_rebalance_on_skew_and_capacity():
    rng = np.random.default_rng(4)
    p, p2, _ = _skewed_pool(rng)
    assert not sp.should_rebalance(p)
    assert sp.imbalance_stats(p2)["imbalance"] > 2.0
    assert sp.should_rebalance(p2)  # skew fires long before capacity
    # near-capacity fires even when perfectly balanced
    v = np.arange(100, dtype=np.int64)
    p3 = sp.from_array(v, n_shards=4, cap_per=26)
    assert sp.imbalance_stats(p3)["imbalance"] <= 2.0
    assert sp.should_rebalance(p3)


def test_should_rebalance_compressed_pool():
    rng = np.random.default_rng(5)
    v = np.unique(rng.integers(0, 1 << 18, 1500))
    sg = sp.ShardedGraph(sp.from_array(v, n_shards=4), 1 << 18)
    csg = sp.compress_sharded(sg)
    # reads capacity off the compressed layout (cap_per property)
    assert sp.should_rebalance(csg.pool) == sp.should_rebalance(sg.pool)


def test_maybe_rebalance_roundtrip():
    rng = np.random.default_rng(6)
    p, p2, all_keys = _skewed_pool(rng)
    same, done = sp.maybe_rebalance(p)
    assert not done and same is p  # balanced pool untouched
    r, done = sp.maybe_rebalance(p2)
    assert done
    np.testing.assert_array_equal(sp.to_array(r), all_keys)  # contents preserved
    assert sp.imbalance_stats(r)["imbalance"] <= 1.5  # and skew repaired


def test_recommend_n_shards():
    nd = jax.device_count()
    assert sp.recommend_n_shards(0) == 1
    assert sp.recommend_n_shards(1 << 16) == 1
    want = sp.recommend_n_shards(10 * (1 << 16))
    assert want >= 10
    assert want <= nd or want % nd == 0  # mesh-friendly when multi-round
    # scales with the per-shard target
    w = sp.recommend_n_shards(1 << 20, target_per_shard=1 << 10)
    assert w >= 1024 and (w <= nd or w % nd == 0)


# ---------------------------------------------------------------------------
# boundary invariants (ISSUE 5 satellites)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_vals", [1, 2, 3, 7])
def test_empty_shard_lo_monotone(n_vals):
    """Fewer distinct keys than shards: trailing shards are empty and
    their ``lo`` boundaries must still be monotone, or the boundary-
    table searchsorted would route queries to the wrong shard."""
    v = np.arange(n_vals, dtype=np.int64) * 1000
    p = sp.from_array(v, n_shards=8)
    lo = np.asarray(p.lo)
    assert (lo[1:] >= lo[:-1]).all()
    assert lo[0] == np.iinfo(np.int64).min
    np.testing.assert_array_equal(sp.to_array(p), v)
    # membership still resolves through the boundary table
    q = np.concatenate([v, v + 1])
    got = np.asarray(sp.member(p, jnp.asarray(q)))
    np.testing.assert_array_equal(got, np.isin(q, v))


def test_insert_boundary_key_into_sparse_pool_no_duplicate():
    """Regression for the empty-shard boundary bug: with duplicated lo
    boundaries, re-inserting the key AT the boundary routed the batch
    row to an empty shard and stored it twice.  After the fix an empty
    shard's range starts strictly past every stored key."""
    v = np.asarray([0, 1000], np.int64)  # 8 shards -> 6 empty
    p = sp.from_array(v, n_shards=8, cap_per=16)
    mesh = sp.pool_mesh(8)
    step = sp.make_insert_step(mesh, ("shard",))
    batch = np.full(8, sp.SENT, np.int64)
    batch[:2] = [500, 1000]  # 1000 already present
    with mesh:
        out = step(p, jnp.asarray(batch))
    np.testing.assert_array_equal(sp.to_array(out), [0, 500, 1000])


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_rebalance_roundtrips_exactly(n_shards):
    rng = np.random.default_rng(7)
    v = np.unique(rng.integers(0, 1 << 40, 3000))
    p = sp.from_array(v, n_shards=n_shards)
    r = sp.rebalance(p)
    np.testing.assert_array_equal(sp.to_array(r), sp.to_array(p))
    counts = np.asarray(r.n)
    # ceil-partitioning: every shard holds ceil(total/S) except the last,
    # which absorbs the remainder (up to S-1 short)
    assert counts.max() - counts.min() <= max(n_shards - 1, 0)
    assert counts.max() == -(-counts.sum() // n_shards)
    lo = np.asarray(r.lo)
    assert (lo[1:] >= lo[:-1]).all()


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_insert_then_rebalance_matches_union_merge(n_shards):
    """Shard-local insert + rebalance == the global flat_ctree rank-merge
    on random batches (the single-chip reference the sharded pool must
    agree with element-for-element)."""
    from repro.core import flat_ctree as fct

    rng = np.random.default_rng(n_shards)
    va = np.unique(rng.integers(0, 1 << 30, 800))
    vb = np.unique(rng.integers(0, 1 << 30, 300))
    cap_per = int(2 ** np.ceil(np.log2((va.size + vb.size) // n_shards + vb.size + 1)))
    pool = sp.from_array(va, n_shards, cap_per=cap_per)
    mesh = sp.pool_mesh(n_shards)
    step = sp.make_insert_step(mesh, ("shard",))
    pad = int(2 ** np.ceil(np.log2(vb.size + 1)))
    batch = jnp.asarray(np.concatenate([vb, np.full(pad - vb.size, sp.SENT)]))
    with mesh:
        out = step(pool, batch)
    ref = fct.union_merge(
        fct.from_array(va, dtype=jnp.int64),
        fct.from_array(vb, dtype=jnp.int64),
        fct.grown_capacity(va.size + vb.size),
    )
    np.testing.assert_array_equal(sp.to_array(out), fct.to_array(ref))
    reb = sp.rebalance(out)
    np.testing.assert_array_equal(sp.to_array(reb), fct.to_array(ref))
    counts = np.asarray(reb.n)
    assert counts.max() - counts.min() <= max(n_shards - 1, 0)
    assert counts.max() == -(-counts.sum() // n_shards)


# ---------------------------------------------------------------------------
# member: wire-traffic regression (no cross-shard row gather)
# ---------------------------------------------------------------------------


def test_member_no_cross_shard_row_gather():
    """``member`` must probe via flat index math — O(queries · log cap)
    scalar gathers — and never materialize a (queries, cap) row-gather
    block (the old ``p.data[s]`` formulation, which under GSPMD put
    O(queries · cap) on the wire).  Pinned on the jaxpr: no intermediate
    anywhere near queries × cap elements."""
    rng = np.random.default_rng(0)
    v = np.unique(rng.integers(0, 1 << 20, 3000))
    p = sp.from_array(v, n_shards=4)
    cap = p.data.shape[1]
    q = jnp.asarray(rng.integers(0, 1 << 21, 256))
    jaxpr = jax.make_jaxpr(lambda p, q: sp.member(p, q))(p, q)

    def max_outvar_size(jx, best=0):
        for eqn in jx.eqns:
            for var in eqn.outvars:
                if hasattr(var.aval, "shape"):
                    best = max(best, int(np.prod(var.aval.shape or (1,))))
            for val in eqn.params.values():
                for item in val if isinstance(val, (list, tuple)) else (val,):
                    inner = getattr(item, "jaxpr", item)
                    if hasattr(inner, "eqns"):
                        best = max(best, max_outvar_size(inner, best))
        return best

    biggest = max_outvar_size(jaxpr.jaxpr)
    assert biggest < q.size * cap, (
        f"member materializes a {biggest}-element intermediate "
        f"(>= queries x cap = {q.size * cap}: the cross-shard row gather)"
    )
    # the flat pool view itself is the largest legal intermediate
    assert biggest <= max(p.data.size, 4 * q.size)


def test_member_boundary_cases():
    rng = np.random.default_rng(5)
    v = np.unique(rng.integers(100, 1 << 16, 500))
    p = sp.from_array(v, n_shards=8)
    q = np.concatenate([
        v[::13],
        [0, 1, int(v.min()) - 1, int(v.max()) + 1, 1 << 60],  # off both ends
        np.asarray(p.lo)[1:],  # exact shard boundaries
    ])
    got = np.asarray(sp.member(p, jnp.asarray(q)))
    np.testing.assert_array_equal(got, np.isin(q, v))


# ---------------------------------------------------------------------------
# value lane: insert overwrites, delete drops, rebalance preserves
# ---------------------------------------------------------------------------


def test_value_lane_roundtrip_and_rebalance():
    rng = np.random.default_rng(2)
    v = np.unique(rng.integers(0, 1 << 20, 1000))
    w = (v % 97 + 1).astype(np.float32)
    p = sp.from_array(v, n_shards=4, vals=w)
    np.testing.assert_array_equal(sp.to_array(p), v)
    np.testing.assert_array_equal(sp.to_val_array(p), w)
    r = sp.rebalance(p)
    np.testing.assert_array_equal(sp.to_array(r), v)
    np.testing.assert_array_equal(sp.to_val_array(r), w)


@pytest.mark.parametrize("n_shards", [1, 4])
def test_insert_step_value_lane_overwrites(n_shards):
    """A batch key that already exists lands its value on the pool slot
    (insert-overwrites, the flat_ctree.union_merge semantics)."""
    va = np.arange(0, 200, 2, dtype=np.int64)  # evens
    wa = np.full(va.size, 1.0, np.float32)
    vb = np.arange(0, 100, 1, dtype=np.int64)  # overlaps the low evens
    wb = np.full(vb.size, 9.0, np.float32)
    pool = sp.from_array(va, n_shards, cap_per=512, vals=wa)
    mesh = sp.pool_mesh(n_shards)
    step = sp.make_insert_step(mesh, ("shard",))
    pad = 128
    batch = np.full(pad, sp.SENT, np.int64)
    batch[: vb.size] = vb
    bvals = np.zeros(pad, np.float32)
    bvals[: vb.size] = wb
    with mesh:
        out = step(pool, jnp.asarray(batch), jnp.asarray(bvals))
    keys = sp.to_array(out)
    vals = sp.to_val_array(out)
    np.testing.assert_array_equal(keys, np.union1d(va, vb))
    ref = {int(k): 1.0 for k in va}
    ref.update({int(k): 9.0 for k in vb})  # batch overwrites
    np.testing.assert_array_equal(vals, [ref[int(k)] for k in keys])


def test_insert_step_upgrades_unweighted_pool():
    """A weighted batch against a plain pool upgrades it to unit values
    (the mid-stream property-graph upgrade, sharded)."""
    va = np.arange(10, dtype=np.int64)
    pool = sp.from_array(va, 2, cap_per=64)
    assert pool.vals is None
    mesh = sp.pool_mesh(2)
    step = sp.make_insert_step(mesh, ("shard",))
    batch = np.full(16, sp.SENT, np.int64)
    batch[:2] = [100, 101]
    bvals = np.zeros(16, np.float32)
    bvals[:2] = [5.0, 6.0]
    with mesh:
        out = step(sp.with_unit_vals(pool), jnp.asarray(batch), jnp.asarray(bvals))
    keys, vals = sp.to_array(out), sp.to_val_array(out)
    ref = {int(k): 1.0 for k in va}
    ref.update({100: 5.0, 101: 6.0})
    np.testing.assert_array_equal(vals, [ref[int(k)] for k in keys])


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_delete_step_matches_setdiff(n_shards):
    rng = np.random.default_rng(3)
    v = np.unique(rng.integers(0, 1 << 20, 1200))
    w = (v % 11 + 1).astype(np.float32)
    dels = np.concatenate([v[::3], rng.integers(1 << 21, 1 << 22, 40)])
    dels = np.unique(dels)
    pool = sp.from_array(v, n_shards, vals=w)
    mesh = sp.pool_mesh(n_shards)
    step = sp.make_delete_step(mesh, ("shard",))
    pad = int(2 ** np.ceil(np.log2(dels.size + 1)))
    batch = np.full(pad, sp.SENT, np.int64)
    batch[: dels.size] = dels
    with mesh:
        out = step(pool, jnp.asarray(batch))
    expect = np.setdiff1d(v, dels)
    np.testing.assert_array_equal(sp.to_array(out), expect)
    keep_vals = w[~np.isin(v, dels)]
    np.testing.assert_array_equal(sp.to_val_array(out), keep_vals)
    # boundaries untouched: deletes never move keys across ranges
    np.testing.assert_array_equal(np.asarray(out.lo), np.asarray(pool.lo))
