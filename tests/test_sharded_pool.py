"""Range-sharded pool (the §Perf A1 beyond-paper structure): correctness
on a degenerate 1-device mesh + pure-host properties."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import sharded_pool as sp

from proptest import given, st


def sets(max_value=1 << 30, max_size=400):
    return st.lists(st.integers(min_value=0, max_value=max_value),
                    min_size=1, max_size=max_size)


@given(sets())
def test_from_to_array_roundtrip(xs):
    v = np.unique(np.asarray(xs, dtype=np.int64))
    p = sp.from_array(v, n_shards=4)
    np.testing.assert_array_equal(sp.to_array(p), v)
    # boundaries are monotone (compare, don't subtract: lo[0] is the
    # int64-min sentinel and np.diff would overflow)
    lo = np.asarray(p.lo)
    assert (lo[1:] >= lo[:-1]).all()


@given(sets(max_size=200), sets(max_size=200))
def test_insert_step_matches_union(a, b):
    """shard_map степ on a 1-device mesh == np.union1d."""
    va = np.unique(np.asarray(a, dtype=np.int64))
    vb = np.unique(np.asarray(b, dtype=np.int64))
    mesh = jax.make_mesh((1,), ("shard",))
    cap_per = sp.from_array(va, 1).data.shape[1]
    need = int(2 ** np.ceil(np.log2(va.size + vb.size + 1)))
    pool = sp.from_array(va, 1, cap_per=max(cap_per, need))
    step = sp.make_insert_step(mesh, ("shard",))
    pad = int(2 ** np.ceil(np.log2(vb.size + 1)))
    batch = jnp.asarray(np.concatenate([vb, np.full(pad - vb.size, sp.SENT)]))
    with mesh:
        out = step(pool, batch)
    np.testing.assert_array_equal(sp.to_array(out), np.union1d(va, vb))


def test_member_queries():
    rng = np.random.default_rng(0)
    v = np.unique(rng.integers(0, 1 << 20, 5000))
    p = sp.from_array(v, n_shards=8)
    q = np.concatenate([v[::7], rng.integers(1 << 21, 1 << 22, 50)])
    got = np.asarray(sp.member(p, jnp.asarray(q)))
    np.testing.assert_array_equal(got, np.isin(q, v))


def test_rebalance_restores_even_counts():
    rng = np.random.default_rng(1)
    # skewed inserts: all new keys land in shard 0's range
    v = np.unique(rng.integers(0, 1 << 20, 4000))
    p = sp.from_array(v, n_shards=4)
    mesh = jax.make_mesh((1,), ("shard",))
    # simulate fill imbalance by rebuilding with a skewed value set
    skew = np.unique(np.concatenate([v, rng.integers(0, 100, 3000)]))
    p2 = sp.from_array(skew, 4, cap_per=p.data.shape[1] * 2)
    r = sp.rebalance(p2)
    counts = np.asarray(r.n)
    assert counts.max() - counts.min() <= 1 + skew.size % 4
    np.testing.assert_array_equal(sp.to_array(r), skew)


def test_needs_rebalance_trigger():
    v = np.arange(100, dtype=np.int64)
    p = sp.from_array(v, n_shards=4, cap_per=32)
    assert not sp.needs_rebalance(p)
    p2 = sp.from_array(v, n_shards=4, cap_per=26)
    assert sp.needs_rebalance(p2, slack=0.9)
