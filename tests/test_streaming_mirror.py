"""Dual-representation streaming: the resident FlatGraph mirror.

Pins the PR's contract: (1) the mirror is *exactly* the flat graph you
would get by rebuilding from the tree snapshot, across interleaved
insert/delete streams with edge-capacity and vertex-count growth;
(2) ``stream.engine("jax")`` after a batch update performs no O(m) host
rebuild (FLAT_REBUILDS spy) and no host argsort (np.argsort trap);
(3) engines are version-pinned: O(1) reuse on an unchanged version,
fresh engine per new version; (4) the mirror-less rebuild path remains
available and correct.
"""
import numpy as np
import pytest

from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core import traversal
from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize


@pytest.fixture(scope="module")
def small_graph():
    edges = symmetrize(rmat_edges(7, 900, seed=13))  # 128 vertices
    return 128, edges


def assert_mirror_parity(s: AspenStream):
    """mirror == from_edges(flat_snapshot): same n, edges, offsets, m.
    (Capacities may differ — the mirror's pool grows monotonically.)"""
    snap = s.flat_snapshot()
    mirror = s.flat_graph()
    rebuilt = traversal.flat_graph_of(snap)
    assert mirror.n == rebuilt.n
    assert int(mirror.m) == int(rebuilt.m) == snap.m
    np.testing.assert_array_equal(fg.to_edge_array(mirror), fg.to_edge_array(rebuilt))
    np.testing.assert_array_equal(
        np.asarray(mirror.offsets), np.asarray(rebuilt.offsets)
    )


def test_mirror_parity_interleaved_stream(small_graph):
    n, edges = small_graph
    keep, stream = make_update_stream(edges, 400, seed=3)
    s = AspenStream(G.build_graph(n, keep))
    assert_mirror_parity(s)
    for i in range(0, stream.shape[0], 40):
        batch = stream[i : i + 40]
        ins = batch[batch[:, 2] == 0][:, :2]
        dels = batch[batch[:, 2] == 1][:, :2]
        if ins.size:
            s.insert_edges(ins)
        if dels.size:
            s.delete_edges(dels)
        assert_mirror_parity(s)


def test_mirror_parity_capacity_growth(small_graph):
    n, edges = small_graph
    s = AspenStream(G.build_graph(n, edges[:100]))
    cap0 = s.flat_graph().edge_capacity
    s.insert_edges(edges[100:])  # force pool growth past the initial capacity
    assert s.flat_graph().edge_capacity > cap0
    assert_mirror_parity(s)
    s.delete_edges(edges[: len(edges) // 2])
    assert_mirror_parity(s)


def test_mirror_parity_vertex_growth(small_graph):
    n, edges = small_graph
    s = AspenStream(G.build_graph(n, edges))
    assert s.flat_graph().n == n
    grow = np.array([[3, n + 70], [n + 70, 3], [n + 10, 4]])
    s.insert_edges(grow, symmetric=False)
    assert s.flat_graph().n == n + 71
    assert_mirror_parity(s)
    s.delete_edges(grow[:1], symmetric=False)
    assert_mirror_parity(s)
    # vertex-set ops take the rebuild path but stay consistent
    s.insert_vertices(np.array([n + 100]))
    assert s.flat_graph().n == n + 101
    assert_mirror_parity(s)


def test_engine_no_rebuild_no_host_argsort(small_graph, monkeypatch):
    n, edges = small_graph
    keep, stream = make_update_stream(edges, 200, seed=5)
    s = AspenStream(G.build_graph(n, keep))
    s.engine("jax")  # warm the jit caches for this shape
    base = traversal.FLAT_REBUILDS.count

    ins = stream[stream[:, 2] == 0][:30, :2]
    dels = stream[stream[:, 2] == 1][:10, :2]
    s.insert_edges(ins)
    s.delete_edges(dels)

    def _trap(*a, **k):  # host argsort = the old O(m log m) precompute
        raise AssertionError("host np.argsort on the mirror engine path")

    with monkeypatch.context() as mp:
        mp.setattr(np, "argsort", _trap)
        eng = s.engine("jax")
    assert traversal.FLAT_REBUILDS.count == base, "mirror engine path rebuilt"

    # and the engine it handed out answers correctly
    src = int(keep[0, 0])
    p_jx = talg.bfs(eng, src)
    p_np = talg.bfs(s.engine("numpy"), src)
    np.testing.assert_array_equal(
        talg.bfs_depths(p_np, src), talg.bfs_depths(p_jx, src)
    )


def test_engine_version_pinned_reuse(small_graph):
    n, edges = small_graph
    s = AspenStream(G.build_graph(n, edges[:-100]))
    e0 = s.engine("jax")
    assert s.engine("jax") is e0  # O(1): same version -> same engine
    assert s.engine("numpy") is s.engine("numpy")
    s.insert_edges(edges[-100:])
    e1 = s.engine("jax")
    assert e1 is not e0  # new version -> new engine
    assert e1.m > e0.m
    assert s.engine("jax") is e1


def test_mirrorless_stream_falls_back_to_rebuild(small_graph):
    n, edges = small_graph
    s = AspenStream(G.build_graph(n, edges), mirror=False)
    base = traversal.FLAT_REBUILDS.count
    eng = s.engine("jax")
    assert traversal.FLAT_REBUILDS.count == base + 1  # the historical path
    assert s.engine("jax") is eng  # still version-cached
    src = int(edges[0, 0])
    p = talg.bfs(eng, src)
    np.testing.assert_array_equal(
        talg.bfs_depths(p, src),
        talg.bfs_depths(talg.bfs(s.engine("numpy"), src), src),
    )


def test_device_update_entry_points(small_graph):
    """insert/delete_edges_device: host-free batches (and the donating
    variant) agree with the host-driven path."""
    import jax.numpy as jnp

    from repro.core import flat_ctree as fct

    n, edges = small_graph
    keep, batch = edges[:-200], edges[-200:]
    gf = fg.from_edges(n, keep)
    keys = (batch[:, 0] << 32) | batch[:, 1]
    dev = fct.from_device(jnp.asarray(keys), fct.grown_capacity(keys.size))
    np.testing.assert_array_equal(fct.to_array(dev), np.unique(keys))

    g_dev = fg.insert_edges_device(gf, dev)
    np.testing.assert_array_equal(fg.to_edge_array(g_dev), edges)
    g_back = fg.delete_edges_device(g_dev, dev)
    np.testing.assert_array_equal(fg.to_edge_array(g_back), keep)

    # donating variant: caller owns the sole reference to its input
    g_own = fg.from_edges(n, keep)
    g_don = fg.insert_edges_device(g_own, dev, donate=True)
    np.testing.assert_array_equal(fg.to_edge_array(g_don), edges)


def test_queries_drop_foreign_dst():
    """Every query direction must DROP a valid edge whose destination is
    outside [0, n) (asymmetric stream naming a never-source vertex),
    not fold it into the clipped vertex n-1 (regression: the jit
    engine_aux once sorted by the clipped dst; the whole-graph loops
    and the sparse branch clipped too)."""
    import jax.numpy as jnp

    from repro.core.traversal import make_engine
    from repro.core.traversal.jax_backend import bfs_levels, cc_labels

    gf = fg.from_edges(4, np.array([[0, 1], [1, 2], [2, 500]]))
    eng = make_engine(gf)
    # reduce: (2,500)'s mass must not land on vertex 3
    out = np.asarray(eng.edge_map_reduce(jnp.ones(4, jnp.float64)))
    np.testing.assert_allclose(out, [0.0, 1.0, 1.0, 0.0])
    # sparse and dense edgeMap: vertex 3 stays unreached
    for mode in ("sparse", "dense"):
        p = talg.bfs(eng, 0, direction_optimize=(mode == "dense"))
        assert p[3] == -1, mode
    # whole-graph jit loops: vertex 3 isolated
    np.testing.assert_array_equal(np.asarray(bfs_levels(gf, 0)), [0, 1, 2, -1])
    np.testing.assert_array_equal(np.asarray(cc_labels(gf)), [0, 0, 0, 3])


def test_publish_self_heals_after_raw_vg_write(small_graph):
    """A version published through the raw vg writer API carries no
    mirror; the next stream update must rebuild it, not KeyError."""
    n, edges = small_graph
    s = AspenStream(G.build_graph(n, edges[:400]))
    s.vg.update(lambda g: G.insert_edges(g, edges[400:500]))  # no aux
    s.insert_edges(edges[500:600])  # heals: rebuild from the new tree
    assert_mirror_parity(s)
    s.delete_edges(edges[:100])  # and is incremental again afterwards
    assert_mirror_parity(s)


def test_run_concurrent_engine_backend(small_graph):
    n, edges = small_graph
    keep, stream = make_update_stream(edges, 150, seed=8)
    s = AspenStream(G.build_graph(n, keep))
    src = int(keep[0, 0])
    stats = run_concurrent(
        s,
        stream,
        query_fn=lambda eng: talg.bfs(eng, src),
        duration_s=1.0,
        batch_size=25,
        engine_backend="jax",
    )
    assert stats.n_updates > 0 and stats.n_queries > 0
    assert_mirror_parity(s)
