"""Property-test harness.

Uses real ``hypothesis`` when installed; otherwise falls back to a tiny
seeded-random compatible subset (``given`` + the strategies our tests use)
so the property tests still execute many randomized cases offline.
The fallback is deliberately deterministic (fixed base seed + case index)
so failures are reproducible.
"""
from __future__ import annotations

import itertools
import random
from functools import wraps

try:  # pragma: no cover - prefer the real thing when available
    from hypothesis import given, settings, HealthCheck  # noqa: F401
    import hypothesis.strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # offline container: seeded fallback
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def map(self, f):
            return _Strategy(lambda rng: f(self.draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    x = self.draw(rng)
                    if pred(x):
                        return x
                raise ValueError("filter failed to find a value")

            return _Strategy(draw)

    class st:  # noqa: N801 - mimic hypothesis.strategies module
        @staticmethod
        def integers(min_value=-(2**31), max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elem, min_size=0, max_size=64, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                if not unique:
                    return [elem.draw(rng) for _ in range(n)]
                seen, out = set(), []
                for _ in range(n * 20):
                    if len(out) >= n:
                        break
                    x = elem.draw(rng)
                    if x not in seen:
                        seen.add(x)
                        out.append(x)
                return out

            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

        @staticmethod
        def just(x):
            return _Strategy(lambda rng: x)

        @staticmethod
        def one_of(*strats):
            return _Strategy(lambda rng: strats[rng.randrange(len(strats))].draw(rng))

    _N_EXAMPLES = 60

    def given(*g_strats, **g_kw):
        def deco(f):
            @wraps(f)
            def wrapper(*args, **kwargs):
                for case in range(_N_EXAMPLES):
                    rng = random.Random(0xC7EE + 7919 * case)
                    drawn = [s.draw(rng) for s in g_strats]
                    drawn_kw = {k: s.draw(rng) for k, s in g_kw.items()}
                    try:
                        f(*args, *drawn, **drawn_kw, **kwargs)
                    except Exception:
                        print(f"[proptest] failing case #{case}: args={drawn} kw={drawn_kw}")
                        raise

            # pytest resolves fixture names through __wrapped__; the
            # drawn parameters are not fixtures, so hide the original
            # signature or collection fails with "fixture 'a' not found"
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(**_kw):  # no-op decorator factory
        def deco(f):
            return f

        return deco

    class HealthCheck:  # noqa: N801
        too_slow = None
        data_too_large = None
        filter_too_much = None
