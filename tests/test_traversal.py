"""Unified traversal engine: numpy-vs-jax backend parity, sparse/dense
direction dispatch, and the Pallas kernel dispatch of the jax dense
PageRank iteration (interpret mode on CPU)."""
import numpy as np
import pytest

from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core.traversal import (
    NumpyEngine,
    dense_threshold,
    make_engine,
)
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize


@pytest.fixture(scope="module")
def rmat_graph():
    edges = symmetrize(rmat_edges(8, 2000, seed=11))  # 256 vertices
    return 256, edges


@pytest.fixture(scope="module")
def engines(rmat_graph):
    n, edges = rmat_graph
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
    eng_jx = make_engine(fg.from_edges(n, edges))
    return eng_np, eng_jx


# ---------------------------------------------------------------------------
# backend parity (same algorithm text, both substrates)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("diropt", [False, True])
def test_bfs_parity(rmat_graph, engines, diropt):
    n, edges = rmat_graph
    eng_np, eng_jx = engines
    src = int(edges[0, 0])
    p_np = talg.bfs(eng_np, src, direction_optimize=diropt)
    p_jx = talg.bfs(eng_jx, src, direction_optimize=diropt)
    # parents may legally differ; reachability and depths may not
    np.testing.assert_array_equal(p_np >= 0, p_jx >= 0)
    np.testing.assert_array_equal(
        talg.bfs_depths(p_np, src), talg.bfs_depths(p_jx, src)
    )
    # every claimed parent is a real in-edge on both backends
    edge_set = set(map(tuple, edges.tolist()))
    for parents in (p_np, p_jx):
        for v in range(n):
            if parents[v] >= 0 and v != src:
                assert (int(parents[v]), v) in edge_set


def test_pagerank_parity(engines):
    eng_np, eng_jx = engines
    pr_np = talg.pagerank(eng_np, iters=15)
    pr_jx = talg.pagerank(eng_jx, iters=15)
    np.testing.assert_allclose(pr_np.sum(), 1.0, rtol=1e-6)
    # jax accumulates the kernel reduce in f32: parity to f32 tolerance
    np.testing.assert_allclose(pr_np, pr_jx, atol=1e-6)


def test_cc_parity(rmat_graph, engines):
    n, edges = rmat_graph
    eng_np, eng_jx = engines
    cc_np = talg.connected_components(eng_np)
    cc_jx = talg.connected_components(eng_jx)
    # min-label propagation converges to the min vertex id per component
    # on both backends: labels agree exactly
    np.testing.assert_array_equal(cc_np, cc_jx)
    assert (cc_np[edges[:, 0]] == cc_np[edges[:, 1]]).all()


def test_bc_parity(rmat_graph, engines):
    n, edges = rmat_graph
    eng_np, eng_jx = engines
    src = int(edges[0, 0])
    np.testing.assert_allclose(
        talg.bc(eng_np, src), talg.bc(eng_jx, src), rtol=1e-6, atol=1e-9
    )


def test_jax_engine_on_updated_snapshot(rmat_graph):
    """Engines bind to immutable snapshots: inserts produce a new graph
    whose engine sees the new edges while the old engine does not."""
    n, edges = rmat_graph
    keep, batch = edges[:-200], edges[-200:]
    g0 = fg.from_edges(n, keep)
    g1 = fg.insert_edges_host(g0, batch)
    e0, e1 = make_engine(g0), make_engine(g1)
    assert e0.m == keep.shape[0]
    assert e1.m == edges.shape[0]
    src = int(edges[0, 0])
    r0 = (talg.bfs(e0, src) >= 0).sum()
    r1 = (talg.bfs(e1, src) >= 0).sum()
    assert r1 >= r0


# ---------------------------------------------------------------------------
# sparse/dense direction-optimized dispatch
# ---------------------------------------------------------------------------


def _count_F(ops, state, us, vs, ws, valid):
    out = ops.scatter_or(ops.xp.zeros(state.shape[0], dtype=bool), vs, valid)
    return state, out


def _all_C(ops, state, vs):
    return ops.xp.ones(vs.shape, dtype=bool)


def test_numpy_dispatch_follows_beamer_rule(rmat_graph, engines):
    n, edges = rmat_graph
    eng_np, _ = engines
    state = np.zeros(n)
    # single vertex: |U| + deg(U) <= m/20 -> sparse
    small = eng_np.frontier_from_ids([int(edges[0, 0])])
    assert small.size + int(eng_np.degrees[small.to_sparse()].sum()) <= dense_threshold(eng_np.m)
    eng_np.edge_map(small, _count_F, _all_C, state)
    assert eng_np.last_mode == "sparse"
    # whole vertex set: way over the threshold -> dense
    eng_np.edge_map(eng_np.frontier_all(), _count_F, _all_C, state)
    assert eng_np.last_mode == "dense"
    # direction_optimize=False forces sparse regardless of size
    eng_np.edge_map(eng_np.frontier_all(), _count_F, _all_C, state,
                    direction_optimize=False)
    assert eng_np.last_mode == "sparse"


@pytest.mark.parametrize("frontier", ["single", "all"])
def test_jax_modes_agree(rmat_graph, engines, frontier):
    """auto (traced lax.cond dispatch), forced sparse, and forced dense
    produce the same U' on the jax backend."""
    import jax.numpy as jnp

    n, edges = rmat_graph
    _, eng_jx = engines
    U = (
        eng_jx.frontier_from_ids([int(edges[0, 0])])
        if frontier == "single"
        else eng_jx.frontier_all()
    )
    state = jnp.zeros(n)
    outs = {}
    for mode in ("auto", "sparse", "dense"):
        out, _ = eng_jx.edge_map(U, _count_F, _all_C, state, mode=mode)
        outs[mode] = np.asarray(out.to_dense())
    np.testing.assert_array_equal(outs["auto"], outs["sparse"])
    np.testing.assert_array_equal(outs["auto"], outs["dense"])
    # and the expansion is the true one-hop neighborhood
    expect = np.zeros(n, dtype=bool)
    srcs = U.to_sparse()
    sel = np.isin(edges[:, 0], srcs)
    expect[edges[sel, 1]] = True
    np.testing.assert_array_equal(outs["auto"], expect)


def test_cc_relaxes_both_edge_directions():
    """A single stored direction still yields one weak component (the
    undirected model: each stored edge carries labels both ways)."""
    snap = G.flat_snapshot(G.build_graph(2, np.asarray([[1, 0]])))
    from repro.core import algorithms as alg

    assert alg.connected_components(snap).tolist() == [0, 0]
    eng_jx = make_engine(fg.from_edges(2, np.asarray([[1, 0]])))
    assert talg.connected_components(eng_jx).tolist() == [0, 0]


def test_engine_cached_on_snapshot(rmat_graph):
    n, edges = rmat_graph
    from repro.core.traversal.numpy_backend import engine_of

    snap = G.flat_snapshot(G.build_graph(n, edges))
    assert engine_of(snap) is engine_of(snap)


def test_legacy_edge_map_accepts_F_dense(rmat_graph):
    """The original custom-dense-direction hook survives the refactor."""
    from repro.core.traversal import edge_map, from_ids

    n, edges = rmat_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    called = {"n": 0}

    def F_dense(candidates, offsets, nbrs, nbr_in_u):
        called["n"] += 1
        out = np.zeros(candidates.size, dtype=bool)
        out[:1] = True
        return out

    out = edge_map(
        snap,
        from_ids(n, np.arange(n)),  # whole vertex set -> dense direction
        F=lambda us, vs: np.ones(us.shape, dtype=bool),
        C=lambda vs: np.ones(vs.shape, dtype=bool),
        F_dense=F_dense,
    )
    assert called["n"] == 1 and out.size == 1


def test_legacy_edge_map_signature(rmat_graph):
    """The original Ligra-signature edge_map still works (now imported
    from the traversal package; the ``repro.core.edgemap`` shim is
    gone)."""
    from repro.core.traversal import edge_map, from_ids

    n, edges = rmat_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    src = int(edges[0, 0])
    out = edge_map(
        snap,
        from_ids(n, [src]),
        F=lambda us, vs: np.ones(us.shape, dtype=bool),
        C=lambda vs: np.ones(vs.shape, dtype=bool),
        direction_optimize=False,
    )
    np.testing.assert_array_equal(
        out.to_sparse(), np.unique(edges[edges[:, 0] == src][:, 1])
    )


# ---------------------------------------------------------------------------
# the jax dense PageRank iteration dispatches through the Pallas kernel
# ---------------------------------------------------------------------------


def test_jax_pagerank_uses_segment_reduce_kernel(rmat_graph, monkeypatch):
    import repro.core.traversal.jax_backend as jb
    from repro.kernels import ops as kops

    n, edges = rmat_graph
    eng = make_engine(fg.from_edges(n, edges))
    calls = {"n": 0}
    real = kops.segment_sum

    def spy(dst, msg, n_out):
        calls["n"] += 1
        return real(dst, msg, n_out)

    monkeypatch.setattr(jb.kops, "segment_sum", spy)
    pr = talg.pagerank(eng, iters=3)
    assert calls["n"] == 3  # one kernel reduce per power iteration
    np.testing.assert_allclose(pr.sum(), 1.0, rtol=1e-5)


def test_edge_map_reduce_parity(rmat_graph, engines):
    n, edges = rmat_graph
    eng_np, eng_jx = engines
    rng = np.random.default_rng(0)
    vals = rng.standard_normal(n)
    out_np = eng_np.edge_map_reduce(vals)
    out_jx = np.asarray(eng_jx.edge_map_reduce(vals.astype(np.float32)))
    np.testing.assert_allclose(out_np, out_jx, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# precision contract: the jax engine computes in an EXPLICIT float dtype
# ---------------------------------------------------------------------------


def test_jax_engine_float_dtype_contract(rmat_graph, engines):
    """Default engine dtype is float32 explicitly (jnp.float64 would
    silently downcast without jax_enable_x64), it is configurable per
    engine, and PageRank agrees with the float64 numpy engine to f32
    tolerance through the kernel reduce."""
    import jax.numpy as jnp

    from repro.core.traversal.jax_backend import JaxEngine

    n, edges = rmat_graph
    eng_np, eng_jx = engines
    assert np.dtype(eng_jx.ops.float_dtype) == np.dtype(np.float32)
    assert np.dtype(eng_np.ops.float_dtype) == np.dtype(np.float64)
    # the reduce path accumulates in the declared engine dtype
    out = eng_jx.edge_map_reduce(jnp.ones(n, jnp.float32))
    assert out.dtype == jnp.float32
    # configurable: an explicit-dtype engine shares the jit cache key
    # with the default (JaxOps hashes by dtype, not identity)
    eng32 = JaxEngine(eng_jx.g, aux=eng_jx.aux, float_dtype=jnp.float32)
    assert eng32.ops == eng_jx.ops and hash(eng32.ops) == hash(eng_jx.ops)
    # numpy (f64) vs jax (f32): parity to f32 tolerance, not f64
    pr_np = talg.pagerank(eng_np, iters=12)
    pr_jx = talg.pagerank(eng32, iters=12)
    np.testing.assert_allclose(pr_np, pr_jx, atol=1e-6)
    assert pr_jx.dtype == np.float32


# ---------------------------------------------------------------------------
# sparse-branch budgets at the direction threshold boundary
# ---------------------------------------------------------------------------


def test_sparse_budget_exact_threshold_boundary():
    """A frontier whose |U| + deg(U) sits EXACTLY at the Beamer cutoff
    m // DENSE_THRESHOLD_DENOM routes sparse (the rule is strict >) and
    must fit the auto-mode ids/edge budgets even when the pool has no
    slack capacity (cap == m).  Overflow would silently truncate the
    expansion, so correctness against forced-dense is the probe."""
    import jax.numpy as jnp

    from repro.core.traversal.base import DENSE_THRESHOLD_DENOM

    rng = np.random.default_rng(42)
    n = 512
    m = 20 * DENSE_THRESHOLD_DENOM * 2  # 800 directed edges
    edges = np.unique(rng.integers(0, n, (4 * m, 2)), axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]][:m]
    assert edges.shape[0] == m
    # no slack: capacity exactly the edge count
    gf = fg.from_edges(n, edges, edge_capacity=m)
    eng = make_engine(gf)
    threshold = eng.m // DENSE_THRESHOLD_DENOM

    # frontier sized so |U| + deg(U) == threshold exactly
    deg = np.asarray(eng.degrees)
    order = np.argsort(-deg)
    ids, total = [], 0
    for v in order:
        if total + 1 + deg[v] <= threshold:
            ids.append(int(v))
            total += 1 + int(deg[v])
        if total == threshold:
            break
    # pad with isolated/low-degree vertices to land exactly on it
    for v in order[::-1]:
        if total == threshold:
            break
        if int(v) not in ids and total + 1 + deg[v] <= threshold:
            ids.append(int(v))
            total += 1 + int(deg[v])
    assert total == threshold, "fixture must hit the boundary exactly"
    assert len(ids) + int(deg[ids].sum()) == threshold

    U = eng.frontier_from_ids(ids)
    state = jnp.zeros(n)
    out_auto, _ = eng.edge_map(U, _count_F, _all_C, state, mode="auto")
    out_dense, _ = eng.edge_map(U, _count_F, _all_C, state, mode="dense")
    np.testing.assert_array_equal(
        np.asarray(out_auto.to_dense()), np.asarray(out_dense.to_dense())
    )
    expect = np.zeros(n, dtype=bool)
    sel = np.isin(edges[:, 0], np.asarray(ids))
    expect[edges[sel, 1]] = True
    np.testing.assert_array_equal(np.asarray(out_auto.to_dense()), expect)
    # one over the boundary routes dense — results must still agree
    assert len(ids) + int(deg[ids].sum()) <= threshold < eng._auto_ids_budget


# ---------------------------------------------------------------------------
# marker-gated variants (tpu auto-skips on CPU; slow deselectable)
# ---------------------------------------------------------------------------


@pytest.mark.tpu
def test_segment_reduce_compiled_on_hardware(rmat_graph):
    """Same kernel path, compiled (interpret=False) — only meaningful on
    a real TPU, hence the marker."""
    import jax.numpy as jnp

    from repro.kernels import segment_reduce

    n, edges = rmat_graph
    dst = jnp.asarray(np.sort(edges[:2048, 1] % 128).astype(np.int32))
    msg = jnp.ones((2048, 128), jnp.float32)
    out = segment_reduce.segment_sum_sorted(dst, msg, 128, interpret=False)
    assert out.shape == (128, 128)


@pytest.mark.slow
def test_parity_at_benchmark_scale():
    edges = symmetrize(rmat_edges(12, 60_000, seed=0))
    n = 1 << 12
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
    eng_jx = make_engine(fg.from_edges(n, edges))
    src = int(edges[0, 0])
    p_np, p_jx = talg.bfs(eng_np, src), talg.bfs(eng_jx, src)
    np.testing.assert_array_equal(
        talg.bfs_depths(p_np, src), talg.bfs_depths(p_jx, src)
    )
    np.testing.assert_allclose(
        talg.pagerank(eng_np, iters=10), talg.pagerank(eng_jx, iters=10), atol=1e-6
    )
    np.testing.assert_array_equal(
        talg.connected_components(eng_np), talg.connected_components(eng_jx)
    )


# ---------------------------------------------------------------------------
# snapshot caching (satellite: vectorized degree sum)
# ---------------------------------------------------------------------------


def test_flat_snapshot_caches_m_and_degrees(rmat_graph):
    n, edges = rmat_graph
    snap = G.flat_snapshot(G.build_graph(n, edges))
    degs = np.zeros(n, dtype=np.int64)
    np.add.at(degs, edges[:, 0], 1)
    np.testing.assert_array_equal(snap.degrees, degs)
    assert snap.m == edges.shape[0]
    assert snap.degrees is snap.degrees  # cached, not recomputed


# ---------------------------------------------------------------------------
# jax frontier: one device->host sync per subset (satellite)
# ---------------------------------------------------------------------------


def test_jax_subset_size_cached_single_sync(engines):
    _, eng_jx = engines
    U = eng_jx.frontier_from_ids([0, 1, 5])
    assert U._size is None  # lazy: no sync until loop control asks
    assert U.size == 3
    assert U._size == 3
    # cached: later accesses never re-sum the device array
    U.dense = None  # a re-sum would now raise
    assert U.size == 3 and not U.empty


def test_jax_engine_aux_device_resident(engines):
    """The per-snapshot precompute is one jit pytree — its arrays live
    on device and match the pool layout."""
    import jax

    _, eng_jx = engines
    aux = eng_jx.aux
    cap = eng_jx.g.edge_capacity
    assert aux.w_by_dst is None  # unweighted graph: no value array
    for arr in aux:
        if arr is None:
            continue
        assert isinstance(arr, jax.Array)
        assert arr.shape[0] in (cap, eng_jx.n, eng_jx.n + 1)
    # dst-major permutation is sorted ascending with padding at the top
    dst_sorted = np.asarray(aux.dst_sorted)
    assert (np.diff(dst_sorted) >= 0).all()
    assert (dst_sorted[int(eng_jx.m):] == eng_jx.n).all()
    # dst_offsets segments the dst-major pool: counts per destination
    # equal the in-degree, and the top bound is the valid edge count
    offs = np.asarray(aux.dst_offsets)
    indeg = np.zeros(eng_jx.n, dtype=np.int64)
    np.add.at(indeg, dst_sorted[: int(eng_jx.m)], 1)
    np.testing.assert_array_equal(np.diff(offs), indeg)
    assert offs[-1] == eng_jx.m
