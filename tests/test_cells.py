"""Launch-layer tests: cell builders produce consistent abstract programs
on a 1x1 mesh (full 256/512-chip lowering is exercised by the dry-run;
here we verify the builder contracts cheaply in-process)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.cells import build_cell
from repro.launch.hlo_analysis import collective_bytes


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


REPRESENTATIVE = [
    ("smollm-360m", "train_4k"),
    ("qwen3-moe-30b-a3b", "decode_32k"),
    ("gcn-cora", "full_graph_sm"),
    ("graphsage-reddit", "minibatch_lg"),
    ("schnet", "molecule"),
    ("graphcast", "molecule"),
    ("dcn-v2", "serve_p99"),
    ("dcn-v2", "retrieval_cand"),
    ("aspen-stream", "update_2m"),
]


@pytest.mark.parametrize("arch,shape", REPRESENTATIVE)
def test_cell_lowers_on_host_mesh(arch, shape, mesh):
    """build + jit-lower (NOT compile: full configs are huge; lowering
    checks shapes, shardings, and tracing end-to-end)."""
    cell = build_cell(arch, shape, mesh)
    with mesh:
        lowered = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        ).lower(*cell.args)
    assert lowered is not None
    assert "model_flops" in cell.meta


def test_all_40_cells_buildable(mesh):
    """Every assigned cell constructs its abstract program."""
    count = 0
    for arch, shape in registry.all_cells():
        cell = build_cell(arch, shape, mesh)
        assert cell.args, (arch, shape)
        count += 1
    assert count == 40


def test_lm_cell_meta_math(mesh):
    cfg = registry.get("qwen2.5-3b").full
    cell = build_cell("qwen2.5-3b", "train_4k", mesh)
    assert cell.meta["model_flops"] == pytest.approx(
        6.0 * cfg.param_count() * 256 * 4096
    )
    mm = cell.meta["mem_model"]
    assert mm["total"] == pytest.approx(
        sum(v for k, v in mm.items() if k != "total")
    )


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[128,256]{1,0} all-gather(%x), replica_groups=...
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %plain = f32[8,8]{1,0} add(%a, %b)
"""
    total, kinds = collective_bytes(hlo)
    assert kinds["all-gather"] == 128 * 256 * 2
    assert kinds["all-reduce"] == 1024 * 4
    assert total == kinds["all-gather"] + kinds["all-reduce"]


def test_decode_cell_seq_sharding_rule(mesh16=None):
    """kv heads that don't divide the model axis -> sequence sharding."""
    from repro.dist import shardings as SH
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = registry.get("smollm-360m").full  # kv=5, no divide
    specs = SH.lm_cache_specs(cfg, FakeMesh(), seq_shard=True, batch_size=128)
    assert specs["k"] == P(None, ("pod", "data")[-1:], ("model",), None, None) or \
        specs["k"][2] == ("model",)
    # B=1 cannot shard over data
    specs1 = SH.lm_cache_specs(cfg, FakeMesh(), seq_shard=True, batch_size=1)
    assert specs1["k"][1] is None
