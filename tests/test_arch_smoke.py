"""Per-architecture smoke tests: REDUCED configs, one real forward/train
step on CPU, asserting output shapes + finiteness (the FULL configs are
exercised only via the dry-run's ShapeDtypeStructs).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import train_step as TS

LM_ARCHS = ["smollm-360m", "qwen2.5-3b", "starcoder2-7b", "qwen3-moe-30b-a3b", "deepseek-moe-16b"]
GNN_ARCHS = ["graphsage-reddit", "gcn-cora", "schnet", "graphcast"]


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = registry.get(arch).reduced
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    state = TS.init_state(params)
    step = jax.jit(TS.make_train_step(TS.lm_loss(cfg), adamw.wsd_schedule(2, 10, 10, 1e-3)))
    B, S = 4, 16
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
    }
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(state.params)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    cfg = registry.get(arch).reduced
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    cache = T.init_kv_cache(cfg, 2, 8, dtype=jnp.float32)
    logits, cache = T.decode_step(params, cfg, cache, jnp.asarray([1, 2]))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["len"][0]) == 1


def test_gcn_smoke():
    from repro.models.gnn import common, gcn

    cfg = registry.get("gcn-cora").reduced
    b = common.random_batch(jax.random.PRNGKey(1), 64, 256, 32)
    p = gcn.init(jax.random.PRNGKey(0), 32, cfg.d_hidden, cfg.n_classes, cfg.n_layers)
    out = gcn.forward(p, b)
    assert out.shape == (64, cfg.n_classes) and bool(jnp.isfinite(out).all())
    loss = gcn.loss_fn(p, b, jnp.zeros(64, jnp.int32), jnp.ones(64, bool))
    assert np.isfinite(float(loss))


def test_graphsage_smoke_both_paths():
    from repro.models.gnn import common, graphsage

    cfg = registry.get("graphsage-reddit").reduced
    b = common.random_batch(jax.random.PRNGKey(1), 64, 256, 32)
    p = graphsage.init(jax.random.PRNGKey(0), 32, cfg.d_hidden, cfg.n_classes, cfg.n_layers)
    out = graphsage.forward_full(p, b)
    assert out.shape == (64, cfg.n_classes) and bool(jnp.isfinite(out).all())
    # sampled path fed by the REAL neighbor sampler
    from repro.data.pipeline import NeighborSampler, power_law_graph

    offs, nbrs = power_law_graph(64, 500, seed=2)
    feats = np.random.default_rng(0).standard_normal((64, 32)).astype(np.float32)
    sampler = NeighborSampler(offs, nbrs, feats)
    sb = sampler.sample_batch(0, 0, 8, cfg.sample_sizes)
    logits = graphsage.forward_sampled(
        p, jnp.asarray(sb["x_self"]),
        [jnp.asarray(f) for f in sb["neigh_feats"]],
        [jnp.asarray(m) for m in sb["neigh_masks"]],
    )
    assert logits.shape == (8, cfg.n_classes) and bool(jnp.isfinite(logits).all())


def test_schnet_smoke():
    from repro.data.pipeline import molecule_batch
    from repro.models.gnn import common, schnet

    cfg = registry.get("schnet").reduced
    mb = molecule_batch(0, 0, n_mols=4, atoms_per_mol=10, edges_per_mol=20, d_feat=8)
    batch = common.batch_from_edges(
        40, np.stack([mb["src"], mb["dst"]], 1), mb["x"], edge_attr=mb["dist"][:, None]
    )._replace(graph_ids=jnp.asarray(mb["graph_ids"]))
    p = schnet.init(jax.random.PRNGKey(0), 8, cfg.d_hidden, cfg.n_layers, cfg.n_rbf)
    atom_out = schnet.forward(p, batch, cfg.cutoff)
    assert atom_out.shape == (40, 1) and bool(jnp.isfinite(atom_out).all())
    loss = schnet.loss_fn(p, batch, jnp.asarray(mb["targets"]), 4)
    assert np.isfinite(float(loss))


def test_graphcast_smoke():
    from repro.models.gnn import common, graphcast

    cfg = registry.get("graphcast").reduced
    # its own config: run on the real icosahedral multimesh
    mm = graphcast.build_multimesh(cfg.mesh_refinement)
    n = int(mm.max()) + 1
    x = np.random.default_rng(0).standard_normal((n, cfg.n_vars)).astype(np.float32)
    batch = common.batch_from_edges(n, mm, x)
    p = graphcast.init(jax.random.PRNGKey(0), cfg.n_vars, cfg.d_hidden, cfg.n_layers, cfg.n_classes)
    out = graphcast.forward(p, batch)
    assert out.shape == (n, cfg.n_classes) and bool(jnp.isfinite(out).all())
    loss = graphcast.loss_fn(p, batch, jnp.zeros((n, cfg.n_classes), jnp.float32))
    assert np.isfinite(float(loss))


def test_dcn_v2_smoke_all_heads():
    from repro.data.pipeline import recsys_batch
    from repro.models.recsys import dcn_v2

    cfg = registry.get("dcn-v2").reduced
    p = dcn_v2.init(
        jax.random.PRNGKey(0), n_dense=cfg.n_dense, n_sparse=cfg.n_sparse,
        embed_dim=cfg.embed_dim, vocab_per_field=cfg.vocab_per_field,
        n_cross=cfg.n_cross, mlp_dims=cfg.mlp_dims, n_candidates=cfg.n_candidates,
    )
    b = recsys_batch(0, 0, 16, cfg.n_dense, cfg.n_sparse, cfg.vocab_per_field)
    dense, sids = jnp.asarray(b["dense"]), jnp.asarray(b["sparse_ids"])
    logits = dcn_v2.forward(p, dense, sids)
    assert logits.shape == (16,) and bool(jnp.isfinite(logits).all())
    scores = dcn_v2.serve(p, dense, sids)
    assert bool(((scores >= 0) & (scores <= 1)).all())
    loss = dcn_v2.loss_fn(p, dense, sids, jnp.asarray(b["labels"]))
    assert np.isfinite(float(loss))
    ts, ti = dcn_v2.retrieval(p, dense[:1], sids[:1], top_k=8)
    assert ts.shape == (1, 8) and int(ti.max()) < cfg.n_candidates


def test_aspen_stream_smoke():
    """The paper's own config: streaming update + query on the flat level."""
    from repro.core import flat_graph as fg
    from repro.core.traversal.jax_backend import bfs_levels
    from repro.data.rmat import rmat_edges, symmetrize

    edges = symmetrize(rmat_edges(8, 1000, seed=0))
    g = fg.from_edges(256, edges[:-100])
    g2 = fg.insert_edges_host(g, edges[-100:])
    levels = np.asarray(bfs_levels(g2, int(edges[0, 0])))
    assert levels.shape == (256,)
    assert levels[int(edges[0, 0])] == 0


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_registry_complete(arch):
    spec = registry.get(arch)
    assert spec.arch_id == arch
    assert spec.full is not None and spec.reduced is not None
    assert len(spec.shapes) >= 3


def test_all_cells_is_40():
    cells = list(registry.all_cells())
    assert len(cells) == 40


def test_lm_param_counts_match_names():
    """Param counts should be in the ballpark the arch names claim."""
    import math

    expect = {
        "smollm-360m": (0.25e9, 0.5e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "starcoder2-7b": (6e9, 8.5e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "deepseek-moe-16b": (14e9, 20e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = registry.get(arch).full
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"
    # MoE active counts ~ names: a3b => ~3B active
    q = registry.get("qwen3-moe-30b-a3b").full
    assert 2e9 <= q.active_param_count() <= 4.5e9
