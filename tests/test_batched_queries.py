"""Batched multi-source query engine (DESIGN.md §7).

Pins the PR's contract: (1) ``bfs_batch`` over B=64 sources issues O(1)
host syncs total (HOST_SYNCS spy, analogous to FLAT_REBUILDS) and its
parents/depths match 64 serial ``bfs()`` calls on BOTH backends;
(2) the generic batched edgeMap step agrees with per-lane serial steps
in every direction mode; (3) ``bc_multi`` / ``pagerank_multi`` /
``landmark_distances`` agree across backends and with their serial
texts; (4) ``AspenStream.query_batch`` coalesces queries against one
version-pinned engine and tracks versions; (5) ``run_concurrent``
reports batched query throughput via ``queries_per_call``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import flat_graph as fg
from repro.core import graph as G
from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
from repro.core.traversal import HOST_SYNCS, NumpyEngine, make_engine
from repro.core.traversal import algorithms as talg
from repro.data.rmat import rmat_edges, symmetrize


@pytest.fixture(scope="module")
def rmat_graph():
    edges = symmetrize(rmat_edges(8, 2000, seed=11))  # 256 vertices
    return 256, edges


@pytest.fixture(scope="module")
def engines(rmat_graph):
    n, edges = rmat_graph
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
    eng_jx = make_engine(fg.from_edges(n, edges))
    return eng_np, eng_jx


@pytest.fixture(scope="module")
def sources(rmat_graph):
    n, _ = rmat_graph
    return np.random.default_rng(3).integers(0, n, 64)


# ---------------------------------------------------------------------------
# bfs_batch: O(1) syncs, exact parity with serial on both backends
# ---------------------------------------------------------------------------


def test_bfs_batch_matches_serial_both_backends(rmat_graph, engines, sources):
    eng_np, eng_jx = engines
    p_jx, d_jx = talg.bfs_multi(eng_jx, sources)
    p_np, d_np = talg.bfs_multi(eng_np, sources)  # serial-loop fallback
    assert p_jx.shape == p_np.shape == (64, eng_np.n)
    np.testing.assert_array_equal(p_np, p_jx)  # same max-parent rule
    np.testing.assert_array_equal(d_np, d_jx)
    # and against 64 serial bfs() calls on the jax engine itself
    for i, s in enumerate(sources):
        p_ser = talg.bfs(eng_jx, int(s))
        np.testing.assert_array_equal(p_ser, p_jx[i])
        np.testing.assert_array_equal(talg.bfs_depths(p_ser, int(s)), d_jx[i])


def test_bfs_batch_constant_syncs(engines, sources):
    """The whole B-source traversal costs a CONSTANT number of host
    syncs (one dispatch + result fetches), independent of B — the
    serial loop pays one per round per source."""
    _, eng_jx = engines
    talg.bfs_multi(eng_jx, sources)  # warm the jit at B=64
    talg.bfs_multi(eng_jx, sources[:8])  # ... and at B=8

    base = HOST_SYNCS.count
    talg.bfs_multi(eng_jx, sources[:8])
    syncs_b8 = HOST_SYNCS.count - base
    base = HOST_SYNCS.count
    talg.bfs_multi(eng_jx, sources)
    syncs_b64 = HOST_SYNCS.count - base
    assert syncs_b64 == syncs_b8 <= 4  # O(1), not O(D * B)

    base = HOST_SYNCS.count
    for s in sources[:8]:
        talg.bfs(eng_jx, int(s))
    serial_syncs = HOST_SYNCS.count - base
    assert serial_syncs > 8 * syncs_b8  # the loop the batch engine kills


def test_batch_size_quantization(rmat_graph, engines, sources):
    """Ragged batch sizes pad to power-of-two lanes (the serving path
    must not recompile the while_loop driver per distinct B); the pad
    lanes are sliced off and never leak into results."""
    import repro.core.traversal.jax_backend as jb

    _, eng_jx = engines
    for B, pad in ((3, 4), (5, 8), (7, 8)):  # 5 and 7 share the B=8 trace
        padded, b = jb.JaxEngine._quantized_sources(sources[:B])
        assert padded.shape[0] == pad and b == B
        p, d = talg.bfs_multi(eng_jx, sources[:B])
        assert p.shape == d.shape == (B, eng_jx.n)
        for i in range(B):
            np.testing.assert_array_equal(p[i], talg.bfs(eng_jx, int(sources[i])))
    dep = talg.bc_multi(eng_jx, sources[:3])
    assert dep.shape == (3, eng_jx.n)
    np.testing.assert_allclose(
        dep[1], talg.bc(eng_jx, int(sources[1])), rtol=1e-4, atol=1e-4
    )


def test_bfs_batch_duplicate_and_isolated_sources(rmat_graph):
    n = 16
    gf = fg.from_edges(n, np.array([[0, 1], [1, 2], [2, 3]]))
    eng = make_engine(gf)
    parents, depths = talg.bfs_multi(eng, [0, 0, 5])
    np.testing.assert_array_equal(parents[0], parents[1])
    assert depths[2][5] == 0 and (depths[2] >= 0).sum() == 1  # isolated lane
    np.testing.assert_array_equal(depths[0][:4], [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# the generic batched step: per-lane direction optimization
# ---------------------------------------------------------------------------


def _count_F(ops, state, us, vs, ws, valid):
    out = ops.scatter_or(ops.xp.zeros(state.shape[0], dtype=bool), vs, valid)
    return state, out


def _all_C(ops, state, vs):
    return ops.xp.ones(vs.shape, dtype=bool)


@pytest.mark.parametrize("mode", ["auto", "sparse", "dense"])
def test_edge_map_batch_matches_per_lane_serial(rmat_graph, engines, mode):
    """Mixed lanes (one tiny sparse-routed frontier, one full frontier)
    through the batched step equal each lane's serial edge_map."""
    n, edges = rmat_graph
    _, eng_jx = engines
    U_small = eng_jx.frontier_from_ids([int(edges[0, 0])])
    U_all = eng_jx.frontier_all()
    U_b = jnp.stack([U_small.dense, U_all.dense])
    state_b = jnp.zeros((2, n))
    out_b, _ = eng_jx.edge_map_batch(U_b, _count_F, _all_C, state_b, mode=mode)
    for i, U in enumerate((U_small, U_all)):
        out, _ = eng_jx.edge_map(U, _count_F, _all_C, jnp.zeros(n), mode=mode)
        np.testing.assert_array_equal(np.asarray(out_b[i]), np.asarray(out.to_dense()))


def test_engine_cc_labels_unified(rmat_graph, engines):
    """The engine-level in-trace CC entry point reuses the prebuilt aux
    and agrees with both the module-level jit loop and the generic
    round-looped text (symmetric graph: labels are exact)."""
    from repro.core.traversal.jax_backend import cc_labels

    _, eng_jx = engines
    labels = np.asarray(eng_jx.cc_labels())
    np.testing.assert_array_equal(labels, np.asarray(cc_labels(eng_jx.g)))
    np.testing.assert_array_equal(labels, talg.connected_components(eng_jx))


# ---------------------------------------------------------------------------
# bc_multi / landmark_distances / pagerank_multi
# ---------------------------------------------------------------------------


def test_bc_multi_parity(rmat_graph, engines, sources):
    eng_np, eng_jx = engines
    dep_jx = talg.bc_multi(eng_jx, sources[:8])
    dep_np = talg.bc_multi(eng_np, sources[:8])  # serial-loop fallback
    # batched pull reduces via segmented scans: f32 summation order
    # differs from the serial scatter-adds — parity to f32 tolerance
    np.testing.assert_allclose(dep_jx, dep_np, rtol=1e-4, atol=1e-4)
    # and against the serial text on the jax engine itself
    np.testing.assert_allclose(
        dep_jx[0], talg.bc(eng_jx, int(sources[0])), rtol=1e-4, atol=1e-4
    )


def test_landmark_distances(engines, sources):
    eng_np, eng_jx = engines
    lm = sources[:4]
    dist = talg.landmark_distances(eng_jx, lm)
    assert dist.shape == (4, eng_jx.n)
    np.testing.assert_array_equal(dist, talg.bfs_multi(eng_np, lm)[1])
    for i, s in enumerate(lm):
        assert dist[i][int(s)] == 0


def test_pagerank_multi_parity(engines):
    eng_np, eng_jx = engines
    n = eng_np.n
    # uniform row == the serial global pagerank
    np.testing.assert_allclose(
        talg.pagerank_multi(eng_jx, iters=8)[0],
        talg.pagerank(eng_jx, iters=8),
        atol=1e-7,
    )
    # personalized rows: mass conserved per lane, backends agree
    resets = np.zeros((3, n))
    resets[0, 1] = 1.0
    resets[1, 7] = 1.0
    resets[2] = 1.0 / n
    pp_jx = talg.pagerank_multi(eng_jx, resets=resets, iters=8)
    pp_np = talg.pagerank_multi(eng_np, resets=resets, iters=8)
    np.testing.assert_allclose(pp_jx.sum(axis=1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(pp_jx, pp_np, atol=1e-6)
    assert not np.allclose(pp_jx[0], pp_jx[1])  # personalization matters


def test_edge_map_reduce_batch_parity(rmat_graph, engines):
    n, _ = rmat_graph
    eng_np, eng_jx = engines
    vals = np.random.default_rng(0).standard_normal((5, n))
    out_np = eng_np.edge_map_reduce_batch(vals)  # base-class loop
    out_jx = np.asarray(eng_jx.edge_map_reduce_batch(vals.astype(np.float32)))
    assert out_np.shape == out_jx.shape == (5, n)
    np.testing.assert_allclose(out_np, out_jx, rtol=1e-4, atol=1e-4)
    # each batched row equals the scalar reduce of that row
    np.testing.assert_allclose(
        out_jx[2],
        np.asarray(eng_jx.edge_map_reduce(jnp.asarray(vals[2], jnp.float32))),
        rtol=1e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# streaming: query_batch coalesces against one version-pinned engine
# ---------------------------------------------------------------------------


def test_query_batch_serves_pending_queries(rmat_graph):
    n, edges = rmat_graph
    s = AspenStream(G.build_graph(n, edges[:-200]))
    srcs = np.random.default_rng(1).integers(0, n, 16)
    parents = s.query_batch(srcs, kind="bfs")
    eng = s.engine("jax")
    for i, src in enumerate(srcs):
        np.testing.assert_array_equal(parents[i], talg.bfs(eng, int(src)))
    dist = s.query_batch(srcs[:4], kind="distances")
    np.testing.assert_array_equal(dist, talg.bfs_multi(eng, srcs[:4])[1])
    dep = s.query_batch(srcs[:4], kind="bc")
    np.testing.assert_allclose(dep, talg.bc_multi(eng, srcs[:4]))
    pr = s.query_batch(kind="pagerank", iters=4)
    assert pr.shape == (1, s.engine("jax").n)
    with pytest.raises(ValueError):
        s.query_batch(srcs, kind="nope")


def test_query_batch_tracks_versions(rmat_graph):
    """A batch served after an update sees the new version (the engine
    is version-pinned, re-resolved per batch)."""
    n, edges = rmat_graph
    keep, batch = edges[:-100], edges[-100:]
    s = AspenStream(G.build_graph(n, keep))
    src = int(batch[0, 0])
    before = s.query_batch([src], kind="bfs")[0]
    s.insert_edges(batch)
    after = s.query_batch([src], kind="bfs")[0]
    assert (after >= 0).sum() >= (before >= 0).sum()
    np.testing.assert_array_equal(after, talg.bfs(s.engine("jax"), src))


def test_run_concurrent_batched_throughput(rmat_graph):
    n, edges = rmat_graph
    keep, stream = make_update_stream(edges, 150, seed=8)
    s = AspenStream(G.build_graph(n, keep))
    srcs = np.random.default_rng(2).integers(0, n, 16)
    s.query_batch(srcs, kind="bfs")  # warm the batch jit
    stats = run_concurrent(
        s,
        stream,
        query_fn=lambda eng: talg.bfs_multi(eng, srcs),
        duration_s=1.0,
        batch_size=25,
        engine_backend="jax",
        queries_per_call=len(srcs),
    )
    assert stats.n_queries > 0 and stats.n_queries % len(srcs) == 0
    assert stats.queries_per_sec > 0
