"""Perf-regression gate: fresh bench rows vs the committed trajectory.

    PYTHONPATH=src python -m benchmarks.compare \
        --baseline BENCH_BYTES.json --current rows.json \
        [--threshold 0.25] [--warn-only] [--units ms,us,s,B/edge] \
        [--benefit-units hit%]

``--baseline`` is a trajectory file (``benchmarks.trajectory``; the LAST
run record is the baseline) or a plain ``benchmarks.run --json`` row
list.  ``--current`` is either form too.  Rows are matched by exact
name; a row regresses when its value grows more than ``--threshold``
(default 25%) over baseline, counted only for cost-like units (time and
bytes — bigger is worse; dimensionless "x" ratio rows are reported but
never gate, their targets live in the bench notes).  ``--benefit-units``
names units that gate in the OPPOSITE direction — bigger is better, a
DROP past the threshold regresses (e.g. the serve bench's deterministic
replay hit-rate, unit ``hit%``).  Exit 1 on any regression unless
``--warn-only``; missing/new rows are reported but never gate (bench
row names carry graph sizes and may legitimately shift when a generator
changes).
"""
from __future__ import annotations

import argparse
import json
import sys

COST_UNITS = ("s", "ms", "us", "ns", "B/edge", "B", "MB")
# units where bigger is BETTER: a drop past the threshold regresses
BENEFIT_UNITS = ("hit%",)


def load_rows(path: str) -> dict:
    """name -> row dict, from a trajectory file (last record) or a plain
    row list."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list) or not data:
        return {}
    if isinstance(data[0], dict) and "rows" in data[0]:
        data = data[-1]["rows"]  # trajectory: newest record gates
    return {r["name"]: r for r in data if isinstance(r, dict) and "name" in r}


def compare(
    baseline: dict,
    current: dict,
    threshold: float = 0.25,
    units: tuple = COST_UNITS,
    benefit_units: tuple = (),
) -> tuple[list, list, list]:
    """(regressions, improvements, informational) row comparisons."""
    regressions, improvements, info = [], [], []
    for name, cur in sorted(current.items()):
        base = baseline.get(name)
        if base is None:
            info.append((name, None, cur.get("value"), "new row"))
            continue
        bv, cv = float(base["value"]), float(cur["value"])
        unit = cur.get("unit", "")
        if (unit not in units and unit not in benefit_units) or bv <= 0:
            info.append((name, bv, cv, f"not gated ({unit or 'no unit'})"))
            continue
        rel = (cv - bv) / bv
        if unit in benefit_units:
            # bigger is better: gate the drop
            if rel < -threshold:
                regressions.append((name, bv, cv, f"{rel:.0%} ({unit}, benefit)"))
            elif rel > threshold:
                improvements.append((name, bv, cv, f"+{rel:.0%} ({unit}, benefit)"))
        elif rel > threshold:
            regressions.append((name, bv, cv, f"+{rel:.0%} ({unit})"))
        elif rel < -threshold:
            improvements.append((name, bv, cv, f"{rel:.0%} ({unit})"))
    for name in sorted(set(baseline) - set(current)):
        info.append((name, baseline[name].get("value"), None, "missing row"))
    return regressions, improvements, info


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument(
        "--warn-only",
        action="store_true",
        help="report regressions but always exit 0 (noisy CI machines)",
    )
    ap.add_argument(
        "--units",
        default=",".join(COST_UNITS),
        help="comma-separated units that gate (bigger value = worse)",
    )
    ap.add_argument(
        "--benefit-units",
        default="",
        help="comma-separated units that gate the other way "
             "(bigger value = better; a drop past the threshold fails)",
    )
    args = ap.parse_args()

    baseline = load_rows(args.baseline)
    current = load_rows(args.current)
    if not baseline:
        print(f"no baseline rows in {args.baseline}; nothing to gate")
        return
    benefit = tuple(u for u in args.benefit_units.split(",") if u)
    regs, imps, info = compare(
        baseline, current, args.threshold, tuple(args.units.split(",")),
        benefit,
    )

    def show(tag, items):
        for name, bv, cv, why in items:
            b = "-" if bv is None else f"{bv:.6g}"
            c = "-" if cv is None else f"{cv:.6g}"
            print(f"{tag} {name}: {b} -> {c}  [{why}]")

    show("REGRESSION", regs)
    show("improved  ", imps)
    show("info      ", info)
    gated_units = set(args.units.split(",")) | set(benefit)
    n_gated = sum(
        1 for r in current.values() if r.get("unit", "") in gated_units
    )
    print(
        f"# {len(regs)} regression(s), {len(imps)} improvement(s) over "
        f"{n_gated} gated rows at +{args.threshold:.0%}"
    )
    if regs and not args.warn_only:
        sys.exit(1)


if __name__ == "__main__":
    main()
