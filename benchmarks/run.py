"""Benchmark entrypoint: one function per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only T2,T7,...]
                                            [--json out.json]

Prints ``name,value,unit,notes`` CSV and a summary block comparing
measured ratios against the paper's claimed ranges.  ``--json`` also
writes the rows as a JSON list (one object per row) so CI runs can
archive the measurement trajectory across commits.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    from benchmarks.tables import ALL_BENCHES

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--json", default=None, help="also write rows as JSON here")
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else None
    print("name,value,unit,notes")
    claims = []
    all_rows = []
    for name, fn in ALL_BENCHES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name},NaN,error,{type(e).__name__}: {e}", flush=True)
            continue
        for rname, value, unit, notes in rows:
            print(f"{rname},{value:.6g},{unit},{notes}", flush=True)
            all_rows.append(
                {"name": rname, "value": value, "unit": unit, "notes": notes}
            )
            if "paper:" in notes:
                claims.append((rname, value, notes))
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=1)
        print(f"# wrote {len(all_rows)} rows to {args.json}")

    if claims:
        print("#\n# --- paper-claim checkpoints ---")
        for rname, value, notes in claims:
            print(f"# {rname}: measured {value:.3g} ({notes})")


if __name__ == "__main__":
    main()
