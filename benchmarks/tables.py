"""Paper-table benchmarks (one function per table, DESIGN.md §5).

All run at laptop scale (rMAT graphs; the paper's machine had 72 cores +
1TB, this container has 1 core) — the paper's *claims* are ratios and
trends, which are scale-portable: memory-savings factors (T2), chunk-size
tradeoff shape (T5), flat-snapshot speedup (T6), <3% query-latency impact
(T7), batch-throughput scaling (T8), and order-of-magnitude wins over the
Stinger/LLAMA designs (T10/11) all reproduce at this scale.

Output rows: (name, value, unit, notes).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np

Row = Tuple[str, float, str, str]


def _timeit(fn: Callable, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _test_graph(log_n: int = 13, m: int = 120_000, seed: int = 0):
    from repro.data.rmat import rmat_edges, symmetrize

    edges = symmetrize(rmat_edges(log_n, m, seed=seed))
    return 1 << log_n, edges


# ---------------------------------------------------------------------------
# Table 2: memory usage across formats
# ---------------------------------------------------------------------------


def bench_memory_usage(quick: bool = False) -> List[Row]:
    from repro.core import graph as G

    rows: List[Row] = []
    scales = [(12, 60_000)] if quick else [(12, 60_000), (14, 250_000)]
    for log_n, m in scales:
        n, edges = _test_graph(log_n, m)
        g = G.build_graph(n, edges)
        uncomp = G.graph_nbytes(g, chunked=False)
        node = G.graph_nbytes(g, compressed=False)
        de = G.graph_nbytes(g, compressed=True)
        snap = G.snapshot_nbytes(G.flat_snapshot(g))
        tag = f"n=2^{log_n},m={edges.shape[0]}"
        rows += [
            (f"T2/uncompressed/{tag}", uncomp / edges.shape[0], "B/edge", "plain functional tree"),
            (f"T2/ctree_node/{tag}", node / edges.shape[0], "B/edge", "C-tree no diff-encode"),
            (f"T2/ctree_de/{tag}", de / edges.shape[0], "B/edge", "C-tree + diff encode"),
            (f"T2/flat_snapshot/{tag}", snap / edges.shape[0], "B/edge", "8B/vertex array"),
            (f"T2/savings/{tag}", uncomp / de, "x", "paper: 4.7-11.3x"),
        ]
    return rows


# ---------------------------------------------------------------------------
# Table 5: chunk-size tradeoff
# ---------------------------------------------------------------------------


def bench_chunk_size(quick: bool = False) -> List[Row]:
    from repro.core import algorithms as alg
    from repro.core import graph as G

    n, edges = _test_graph(12, 60_000)
    src = int(edges[0, 0])
    rows: List[Row] = []
    bs = [2, 8, 32, 128, 512] if quick else [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
    for b in bs:
        g = G.build_graph(n, edges, b=b)
        mem = G.graph_nbytes(g)
        snap = G.flat_snapshot(g)
        t_bfs = _timeit(lambda: alg.bfs(snap, src), repeats=2)
        rows += [
            (f"T5/memory/b={b}", mem / edges.shape[0], "B/edge", ""),
            (f"T5/bfs/b={b}", t_bfs * 1e3, "ms", ""),
        ]
    return rows


# ---------------------------------------------------------------------------
# Tables 3/4: algorithm runtimes
# ---------------------------------------------------------------------------


def bench_algorithms(quick: bool = False) -> List[Row]:
    from repro.core import algorithms as alg
    from repro.core import graph as G

    rows: List[Row] = []
    scales = [(12, 60_000)] if quick else [(12, 60_000), (14, 250_000)]
    for log_n, m in scales:
        n, edges = _test_graph(log_n, m)
        g = G.build_graph(n, edges)
        snap = G.flat_snapshot(g)
        src = int(edges[0, 0])
        tag = f"2^{log_n}"
        rows += [
            (f"T3/bfs/{tag}", _timeit(lambda: alg.bfs(snap, src)) * 1e3, "ms", ""),
            (f"T3/bc/{tag}", _timeit(lambda: alg.bc(snap, src)) * 1e3, "ms", ""),
            (f"T3/mis/{tag}", _timeit(lambda: alg.mis(snap)) * 1e3, "ms", ""),
            (f"T3/2hop/{tag}", _timeit(lambda: alg.two_hop(g, src)) * 1e3, "ms", "local, tree access"),
            (f"T3/local_cluster/{tag}", _timeit(lambda: alg.local_cluster(g, src)) * 1e3, "ms", "Nibble-serial"),
        ]
    return rows


# ---------------------------------------------------------------------------
# Table 6: flat snapshots
# ---------------------------------------------------------------------------


class _TreeView:
    """FlatSnapshot-compatible access that hits the vertex-tree each time
    (the 'Without FS' column of Table 6)."""

    def __init__(self, g):
        from repro.core import graph as G

        self._g = g
        self.n = G.num_vertices(g)

    def neighbors(self, v: int):
        from repro.core import ctree as ct
        from repro.core import graph as G

        et = G.find_vertex(self._g, v)
        return ct.to_array(et) if et is not None else np.empty(0, np.int64)

    def degree(self, v: int) -> int:
        from repro.core import ctree as ct
        from repro.core import graph as G

        et = G.find_vertex(self._g, v)
        return ct.ctree_size(et) if et is not None else 0


def bench_flat_snapshot(quick: bool = False) -> List[Row]:
    from repro.core import algorithms as alg
    from repro.core import graph as G

    n, edges = _test_graph(13, 120_000)
    g = G.build_graph(n, edges)
    src = int(edges[0, 0])
    t_snap = _timeit(lambda: G.flat_snapshot(g))
    snap = G.flat_snapshot(g)
    t_with = _timeit(lambda: alg.bfs(snap, src), repeats=2)
    view = _TreeView(g)
    t_without = _timeit(lambda: alg.bfs(view, src), repeats=2)
    return [
        ("T6/bfs_without_fs", t_without * 1e3, "ms", "vertex-tree Find per access"),
        ("T6/bfs_with_fs", (t_with + t_snap) * 1e3, "ms", "incl. snapshot build"),
        ("T6/fs_build", t_snap * 1e3, "ms", ""),
        ("T6/speedup", t_without / (t_with + t_snap), "x", "paper: 1.12-1.34x"),
    ]


# ---------------------------------------------------------------------------
# Table 7: concurrent updates + queries
# ---------------------------------------------------------------------------


def bench_concurrent(quick: bool = False) -> List[Row]:
    """Two measurements:
      * structural impact — queries alternating with updates on one
        thread: does a freshly-updated structure slow queries?  This is
        the paper's <3% claim, portable to 1 core.
      * threaded — writer + reader threads; on this 1-core container the
        threads contend for the core itself (the paper had 72), so the
        wall-clock number carries that caveat.
    """
    from repro.core import algorithms as alg
    from repro.core import graph as G
    from repro.core.streaming import AspenStream, make_update_stream, run_concurrent

    n, edges = _test_graph(12, 60_000)
    keep, stream = make_update_stream(edges, 3_000, seed=1)
    src = int(edges[0, 0])

    # --- structural: alternate update/query on one thread ------------------
    # mirror=False: T7 reproduces the paper's tree-level experiment; the
    # dual-representation serve path has its own STREAM table.
    s0 = AspenStream(G.build_graph(n, keep), mirror=False)
    iso = []
    snap = s0.flat_snapshot()
    for _ in range(5):
        t0 = time.perf_counter()
        alg.bfs(snap, src)
        iso.append(time.perf_counter() - t0)
    inter = []
    for i in range(5):
        s0.insert_edges(stream[i * 20 : (i + 1) * 20, :2])
        snap_i = s0.flat_snapshot()
        t0 = time.perf_counter()
        alg.bfs(snap_i, src)
        inter.append(time.perf_counter() - t0)
    structural = (np.median(inter) - np.median(iso)) / np.median(iso)

    # --- threaded (core-contended on this box) ------------------------------
    s = AspenStream(G.build_graph(n, keep), mirror=False)
    stats = run_concurrent(
        s, stream, query_fn=lambda snap: alg.bfs(snap, src),
        duration_s=1.5 if quick else 4.0, batch_size=1,
    )
    return [
        ("T7/updates_per_sec", stats.updates_per_sec, "edges/s", "single-edge batches"),
        ("T7/update_latency", stats.mean_update_latency_s * 1e6, "us", "visibility latency"),
        ("T7/query_structural_impact", structural * 100, "%", "paper: <3%"),
        ("T7/query_concurrent", stats.query_latency_concurrent_s * 1e3, "ms", "BFS, threaded"),
        ("T7/query_isolated", stats.query_latency_isolated_s * 1e3, "ms", "BFS"),
        ("T7/query_threaded_impact",
         100 * (stats.query_latency_concurrent_s / max(stats.query_latency_isolated_s, 1e-12) - 1),
         "%", "1-core contention caveat (paper: 72 cores)"),
    ]


# ---------------------------------------------------------------------------
# Table 8 / Fig 5: batch update throughput
# ---------------------------------------------------------------------------


def bench_batch_updates(quick: bool = False) -> List[Row]:
    from repro.core import graph as G
    from repro.core import flat_graph as fg
    from repro.data.rmat import rmat_edges

    n, edges = _test_graph(13, 120_000)
    g = G.build_graph(n, edges)
    gf = fg.from_edges(n, edges)
    rows: List[Row] = []
    sizes = [10, 1000, 100_000] if quick else [10, 100, 1000, 10_000, 100_000, 1_000_000]
    for bsz in sizes:
        batch = rmat_edges(13, bsz, seed=42)
        t_ins = _timeit(lambda: G.insert_edges(g, batch), repeats=2)
        t_del = _timeit(lambda: G.delete_edges(G.insert_edges(g, batch), batch), repeats=1)
        rows += [
            (f"T8/insert/b={bsz}", bsz / t_ins, "edges/s", "faithful C-tree"),
            (f"T8/delete/b={bsz}", bsz / t_del, "edges/s", "faithful C-tree"),
        ]
        # flat (TPU-native) level, jit-compiled
        fb = fg.batch_from_edges(batch)
        cap = max(gf.edge_capacity, fg.fct.grown_capacity(int(gf.m) + bsz))
        fg.insert_edges(gf, fb, cap)  # warm compile
        t_flat = _timeit(lambda: jax_block(fg.insert_edges(gf, fb, cap)), repeats=3)
        rows.append((f"T8/insert_flat/b={bsz}", bsz / t_flat, "edges/s", "flat pool rank-merge (jit)"))
    return rows


def jax_block(x):
    import jax

    return jax.block_until_ready(x)


# ---------------------------------------------------------------------------
# Tables 10/11/13: vs baselines
# ---------------------------------------------------------------------------


def bench_vs_baselines(quick: bool = False) -> List[Row]:
    from repro.core import algorithms as alg
    from repro.core import baselines as bl
    from repro.core import graph as G
    from repro.data.rmat import rmat_edges

    import jax

    from repro.core import flat_graph as fg

    n, edges = _test_graph(12, 60_000)
    rows: List[Row] = []
    # --- batch insert throughput on an empty store (Table 10 setup).
    # Both our levels reported: the faithful C-tree carries Python-constant
    # overhead the paper's C++ doesn't; the flat (jit) level is the
    # system's real update path and is where the order-of-magnitude
    # claim should (and does) reproduce at large batches.
    for bsz in ([1000] if quick else [1000, 10_000, 100_000]):
        batch = rmat_edges(12, bsz, seed=7)
        st = bl.StingerLike(n)
        t_st = _timeit(lambda: st.insert_edges(batch), repeats=1)
        g0 = G.empty()
        t_asp = _timeit(lambda: G.insert_edges(g0, batch), repeats=1)
        gf0 = fg.from_edges(n, batch[:1])
        fb = fg.batch_from_edges(batch)
        cap = fg.fct.grown_capacity(bsz + 8)
        ins = jax.jit(lambda g, b: fg.insert_edges(g, b, cap))
        jax.block_until_ready(ins(gf0, fb))
        t_flat = _timeit(lambda: jax.block_until_ready(ins(gf0, fb)), repeats=3)
        rows += [
            (f"T10/stinger_ins/b={bsz}", bsz / t_st, "edges/s", "blocked adj list"),
            (f"T10/aspen_ins/b={bsz}", bsz / t_asp, "edges/s", "C-tree MultiInsert (Python)"),
            (f"T10/aspen_flat_ins/b={bsz}", bsz / t_flat, "edges/s", "flat pool (jit)"),
            (f"T10/flat_over_stinger/b={bsz}", t_st / t_flat, "x", "paper: ~100-300x"),
        ]
    # --- BFS runtime (Table 11)
    g = G.build_graph(n, edges)
    snap = G.flat_snapshot(g)
    src = int(edges[0, 0])
    st = bl.StingerLike(n)
    st.insert_edges(edges)
    ll = bl.LlamaLike(n, edges[: len(edges) // 2])
    for i in range(2, 6):  # llama accumulates delta snapshots
        k = len(edges) // 2 + (i - 2) * len(edges) // 8
        ll.insert_edges(edges[k : k + len(edges) // 8])
    csr = bl.StaticCSR(n, edges)
    ccsr = bl.CompressedCSR(n, edges)
    t_asp = _timeit(lambda: alg.bfs(snap, src), repeats=2)
    t_st = _timeit(lambda: bl.bfs_adjacency(st, src), repeats=1)
    t_ll = _timeit(lambda: bl.bfs_adjacency(ll, src), repeats=1)
    t_csr = _timeit(lambda: bl.bfs_adjacency(csr, src), repeats=1)
    rows += [
        ("T11/bfs_aspen", t_asp * 1e3, "ms", "flat snapshot + vectorized"),
        ("T11/bfs_stinger", t_st * 1e3, "ms", "block chains"),
        ("T11/bfs_llama", t_ll * 1e3, "ms", "multi-snapshot chains"),
        ("T11/bfs_static_csr", t_csr * 1e3, "ms", "Ligra-like upper bound"),
        ("T11/mem_stinger_over_aspen", st.nbytes() / G.graph_nbytes(g), "x", "paper: 8.5-11.4x"),
        ("T11/mem_llama_over_aspen", ll.nbytes() / G.graph_nbytes(g), "x", "paper: 1.9-3.5x"),
        ("T11/mem_aspen_over_compressed_csr", G.graph_nbytes(g) / ccsr.nbytes(), "x",
         "paper: 1.8-2.3x (vs Ligra+)"),
        ("T11/mem_aspen_over_csr", G.graph_nbytes(g) / csr.nbytes(), "x",
         "vs uncompressed CSR (Aspen is smaller)"),
    ]
    # --- Table 13: C-tree vs uncompressed functional tree (b=1).
    # BFS wall-time at this scale is dominated by the (shared) frontier
    # machinery; the structure-sensitive metric is raw adjacency *scan
    # throughput*, the paper's locality argument distilled.
    g1 = G.build_graph(n, edges, b=1)  # every element a head = plain treap
    snap1 = G.flat_snapshot(g1)
    t_unc = _timeit(lambda: alg.bfs(snap1, src), repeats=2)

    # locality distilled: scan throughput over ONE high-degree adjacency
    # set (per-vertex dispatch overhead amortized away, as on the paper's
    # high-average-degree graphs)
    from repro.core import ctree as ct

    big = np.unique(np.random.default_rng(3).integers(0, 1 << 24, 500_000))
    cbig = ct.build(big, b=256)
    ubig = ct.build(big, b=1)
    t_scan_c = _timeit(lambda: ct.to_array(cbig), repeats=2)
    t_scan_u = _timeit(lambda: ct.to_array(ubig), repeats=2)
    rows += [
        ("T13/bfs_uncompressed", t_unc * 1e3, "ms", "b=1 plain functional tree"),
        ("T13/bfs_ctree", t_asp * 1e3, "ms", "b=256"),
        ("T13/scan_ctree", big.size / t_scan_c / 1e6, "Medges/s", "chunk decode, 500k-elem set"),
        ("T13/scan_uncompressed", big.size / t_scan_u / 1e6, "Medges/s", "tree walk"),
        ("T13/scan_speedup", t_scan_u / t_scan_c, "x", "paper: 2.5-2.8x (BFS wall)"),
    ]
    return rows


# ---------------------------------------------------------------------------
# dual-representation streaming: resident mirror vs rebuild-per-query
# ---------------------------------------------------------------------------


def bench_streaming(quick: bool = False) -> List[Row]:
    """The serve-path numbers the resident FlatGraph mirror buys:

      * updates/s through the dual write path (tree + on-device
        rank-merge) vs the tree-only stream;
      * time-to-first-query after a batch lands: rebuild-per-query
        (mirror=False, O(m) host rebuild + host->device transfer) vs the
        incremental mirror (jit merge + cached, version-pinned engine);
      * concurrent query latency over the mirror engine via
        ``run_concurrent`` (paper §7.3 with the jax substrate).
    """
    import jax

    from repro.core import graph as G
    from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
    from repro.core.traversal import algorithms as talg

    n, edges = _test_graph(12, 60_000)
    keep, stream = make_update_stream(edges, 4_000, seed=5)
    src = int(edges[0, 0])
    g0 = G.build_graph(n, keep)
    bsz = 200
    batches = [stream[i : i + bsz, :2] for i in range(0, 2_000, bsz)]

    rows: List[Row] = []

    # -- updates/s through the dual write path vs tree-only -----------------
    s_tree = AspenStream(g0, mirror=False)
    s_dual = AspenStream(g0)
    s_dual.insert_edges(batches[-1])  # warm the merge jit at this shape

    def dual_run():
        for b in batches[:4]:
            s_dual.insert_edges(b)
        # jit dispatch is async: charge the merge itself, not its enqueue
        jax.block_until_ready(s_dual.flat_graph().keys)

    t_tree = _timeit(lambda: [s_tree.insert_edges(b) for b in batches[:4]], repeats=1)
    t_dual = _timeit(dual_run, repeats=1)
    n_dir = 4 * bsz * 2
    rows += [
        (f"STREAM/updates_tree_only/b={bsz}", n_dir / t_tree, "edges/s", "no mirror"),
        (f"STREAM/updates_dual/b={bsz}", n_dir / t_dual, "edges/s",
         "tree + on-device rank-merge"),
        (f"STREAM/dual_write_overhead/b={bsz}", t_dual / t_tree, "x",
         "mirror maintenance cost"),
    ]

    # -- time-to-first-query after an update batch --------------------------
    def ttfq(s: AspenStream, batch) -> float:
        t0 = time.perf_counter()
        s.insert_edges(batch)
        talg.bfs(s.engine("jax"), src)  # first query on the fresh version
        return time.perf_counter() - t0

    s_rebuild = AspenStream(g0, mirror=False)
    s_mirror = AspenStream(g0)
    ttfq(s_rebuild, batches[0])  # warm both paths (compiles, caches)
    ttfq(s_mirror, batches[0])
    reps = 2 if quick else 4
    t_rebuild = min(ttfq(s_rebuild, batches[1 + i]) for i in range(reps))
    t_mirror = min(ttfq(s_mirror, batches[1 + i]) for i in range(reps))
    rows += [
        ("STREAM/ttfq_rebuild", t_rebuild * 1e3, "ms",
         "O(m) host rebuild per version"),
        ("STREAM/ttfq_mirror", t_mirror * 1e3, "ms",
         f"incremental mirror, backend={jax.default_backend()}"),
        ("STREAM/ttfq_speedup", t_rebuild / max(t_mirror, 1e-12), "x",
         "rebuild/mirror"),
    ]

    # -- concurrent updates + mirror-engine queries (§7.3, jax substrate) ---
    s = AspenStream(g0)
    s.engine("jax")
    stats = run_concurrent(
        s, stream, query_fn=lambda eng: talg.bfs(eng, src),
        duration_s=1.5 if quick else 4.0, batch_size=bsz,
        engine_backend="jax",
    )
    rows += [
        ("STREAM/concurrent_updates", stats.updates_per_sec, "edges/s",
         f"batch={bsz}, dual write"),
        ("STREAM/query_concurrent", stats.query_latency_concurrent_s * 1e3, "ms",
         "BFS on mirror engine, threaded"),
        ("STREAM/query_isolated", stats.query_latency_isolated_s * 1e3, "ms",
         "BFS on mirror engine"),
    ]
    return rows


# ---------------------------------------------------------------------------
# unified traversal engine: numpy vs jax backend (parity + speed)
# ---------------------------------------------------------------------------


def bench_traversal(quick: bool = False) -> List[Row]:
    """Same algorithm text on both substrates: NumpyEngine(FlatSnapshot)
    vs JaxEngine(FlatGraph).  On this CPU container the jax backend runs
    jit-on-CPU with Pallas in interpret mode, so the absolute ratio is
    NOT the TPU story — the parity columns are the point (1.0 = the two
    backends agree)."""
    import jax

    from repro.core import flat_graph as fg
    from repro.core import graph as G
    from repro.core.traversal import NumpyEngine, make_engine
    from repro.core.traversal import algorithms as talg

    n, edges = _test_graph(12, 60_000)
    src = int(edges[0, 0])
    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
    eng_jx = make_engine(fg.from_edges(n, edges))
    tag = f"n=2^12,m={edges.shape[0]}"

    runs = [
        ("bfs", lambda e: talg.bfs(e, src)),
        ("pagerank", lambda e: talg.pagerank(e, iters=5)),
        ("cc", lambda e: talg.connected_components(e)),
    ]
    if not quick:
        runs.append(("bc", lambda e: talg.bc(e, src)))

    rows: List[Row] = []
    for name, run in runs:
        out_np = run(eng_np)  # also warms any jit caches
        out_jx = run(eng_jx)
        t_np = _timeit(lambda: run(eng_np), repeats=2)
        t_jx = _timeit(lambda: run(eng_jx), repeats=2)
        if name == "bfs":
            parity = float(
                np.array_equal(
                    talg.bfs_depths(out_np, src), talg.bfs_depths(out_jx, src)
                )
            )
        elif name == "cc":
            parity = float(np.array_equal(out_np, out_jx))
        else:
            parity = float(np.allclose(out_np, out_jx, atol=1e-5))
        rows += [
            (f"TRAV/{name}_numpy/{tag}", t_np * 1e3, "ms", "NumpyEngine(FlatSnapshot)"),
            (f"TRAV/{name}_jax/{tag}", t_jx * 1e3, "ms",
             f"JaxEngine(FlatGraph) backend={jax.default_backend()}"),
            (f"TRAV/{name}_parity/{tag}", parity, "bool", "1.0 = backends agree"),
            (f"TRAV/{name}_speedup/{tag}", t_np / max(t_jx, 1e-12), "x",
             "numpy/jax (interpret-mode caveat on CPU)"),
        ]
    return rows


# ---------------------------------------------------------------------------
# QBATCH: batched multi-source query serving vs serial (DESIGN.md §7)
# ---------------------------------------------------------------------------


def bench_query_batch(quick: bool = False) -> List[Row]:
    """Queries/s and p50 latency serving B BFS queries per dispatch:

      * serial — B independent ``bfs()`` calls on the jax engine, each
        paying one dispatch + one host sync per frontier round;
      * batched — ONE in-trace ``bfs_batch`` dispatch via
        ``AspenStream.query_batch`` (one final sync for the whole batch).

    Also wires ``run_concurrent`` with ``queries_per_call`` to compare
    batched vs. serial reader throughput under a live update stream.
    The headline claim: batched queries/s strictly above serial at B=64
    even on CPU (on TPU the gap widens — per-round dispatch latency
    dominates the tiny dense rounds)."""
    from repro.core import graph as G
    from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
    from repro.core.traversal import algorithms as talg

    n, edges = _test_graph(11, 30_000)
    s = AspenStream(G.build_graph(n, edges))
    eng = s.engine("jax")
    rng = np.random.default_rng(0)
    reps = 2 if quick else 4
    rows: List[Row] = []
    for B in (1, 8, 64):
        srcs = rng.integers(0, n, B)
        s.query_batch(srcs, kind="bfs")  # warm the batch jit at this B
        talg.bfs(eng, int(srcs[0]))  # warm the serial path
        lats = []
        for _ in range(max(3, reps)):
            t0 = time.perf_counter()
            s.query_batch(srcs, kind="bfs")
            lats.append(time.perf_counter() - t0)
        t_batch = float(np.median(lats))
        t_serial = _timeit(
            lambda: [talg.bfs(eng, int(x)) for x in srcs], repeats=reps
        )
        rows += [
            (f"QBATCH/serial_qps/B={B}", B / t_serial, "queries/s",
             "B serial bfs() on the jax engine"),
            (f"QBATCH/batched_qps/B={B}", B / t_batch, "queries/s",
             "one in-trace bfs_batch dispatch"),
            (f"QBATCH/batched_p50_ms/B={B}", t_batch * 1e3, "ms", "p50 batch latency"),
            (f"QBATCH/speedup/B={B}", t_serial / t_batch, "x",
             "paper: >1x at B=64" if B == 64 else ""),
        ]

    # -- batched vs serial reader under a live update stream ----------------
    # each reader gets a FRESH stream from the same initial state (a
    # shared stream would leave the second run replaying already-applied
    # updates) and its own jit warm-up outside the measured window
    B = 64
    srcs = rng.integers(0, n, B)
    keep, stream = make_update_stream(edges, 2_000, seed=9)
    g_keep = G.build_graph(n, keep)
    dur = 1.0 if quick else 2.5
    s_ser = AspenStream(g_keep)
    talg.bfs(s_ser.engine("jax"), int(srcs[0]))  # warm the serial path
    stats_ser = run_concurrent(
        s_ser, stream, query_fn=lambda e: talg.bfs(e, int(srcs[0])),
        duration_s=dur, batch_size=100, engine_backend="jax",
    )
    s_bat = AspenStream(g_keep)
    s_bat.query_batch(srcs, kind="bfs")  # warm the batch jit
    stats_bat = run_concurrent(
        s_bat, stream, query_fn=lambda e: talg.bfs_multi(e, srcs),
        duration_s=dur, batch_size=100, engine_backend="jax",
        queries_per_call=B,
    )
    rows += [
        ("QBATCH/concurrent_serial_qps", stats_ser.queries_per_sec, "queries/s",
         "1 query per reader call, live updates"),
        (f"QBATCH/concurrent_batched_qps/B={B}", stats_bat.queries_per_sec,
         "queries/s", f"{B} queries per reader call, live updates"),
    ]
    return rows


# ---------------------------------------------------------------------------
# WEIGHT: weighted edgeMap (SSSP + weighted PageRank, DESIGN.md §8)
# ---------------------------------------------------------------------------


def bench_weighted(quick: bool = False) -> List[Row]:
    """The property-graph v2 serve path on both substrates:

      * SSSP (Bellman–Ford through the weighted edgeMap; jax runs the
        serial round loop AND the one-dispatch ``sssp_batch`` driver)
        and weighted PageRank (weighted Pallas segment-sum reduce) —
        numpy-vs-jax parity columns are the point on CPU, same caveat
        as the TRAV table;
      * weighted-vs-unweighted ``edge_map_reduce`` overhead per
        backend: what carrying the value array costs the hot reduce
        (the unweighted side compiles the exact pre-v2 trace)."""
    import jax

    from repro.core import flat_graph as fg
    from repro.core import graph as G
    from repro.core.traversal import NumpyEngine, make_engine
    from repro.core.traversal import algorithms as talg

    n, edges = _test_graph(12, 60_000)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    w = ((lo * 1000003 + hi) % 7 + 1).astype(np.float64)  # symmetric, integer
    src = int(edges[0, 0])
    tag = f"n=2^12,m={edges.shape[0]}"

    eng_np = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges, weights=w)))
    eng_jx = make_engine(fg.from_edges(n, edges, weights=w))
    eng_npu = NumpyEngine(G.flat_snapshot(G.build_graph(n, edges)))
    eng_jxu = make_engine(fg.from_edges(n, edges))

    rows: List[Row] = []
    runs = [
        ("sssp", lambda e: talg.sssp(e, src),
         lambda a, b: np.array_equal(np.asarray(a, np.float64), np.asarray(b, np.float64))),
        ("wpagerank", lambda e: talg.weighted_pagerank(e, iters=5),
         lambda a, b: np.allclose(a, b, atol=1e-5)),
    ]
    for name, run, check in runs:
        out_np = run(eng_np)  # warms CSR caches / jit
        out_jx = run(eng_jx)
        t_np = _timeit(lambda: run(eng_np), repeats=2)
        t_jx = _timeit(lambda: run(eng_jx), repeats=2)
        rows += [
            (f"WEIGHT/{name}_numpy/{tag}", t_np * 1e3, "ms", "NumpyEngine(weighted FlatSnapshot)"),
            (f"WEIGHT/{name}_jax/{tag}", t_jx * 1e3, "ms",
             f"JaxEngine(weighted FlatGraph) backend={jax.default_backend()}"),
            (f"WEIGHT/{name}_parity/{tag}", float(check(out_np, out_jx)), "bool",
             "1.0 = backends agree" + (" (exact, integer weights)" if name == "sssp" else "")),
        ]

    # one-dispatch batched SSSP vs B serial calls (the QBATCH story, weighted)
    B = 4 if quick else 16
    srcs = np.random.default_rng(0).integers(0, n, B)
    talg.sssp_multi(eng_jx, srcs)  # warm the while_loop driver at this B
    t_batch = _timeit(lambda: talg.sssp_multi(eng_jx, srcs), repeats=2)
    t_serial = _timeit(lambda: [talg.sssp(eng_jx, int(x)) for x in srcs], repeats=2)
    rows += [
        (f"WEIGHT/sssp_serial_qps/B={B}", B / t_serial, "queries/s", "B serial sssp()"),
        (f"WEIGHT/sssp_batched_qps/B={B}", B / t_batch, "queries/s",
         "one in-trace sssp_batch dispatch"),
        (f"WEIGHT/sssp_batch_speedup/B={B}", t_serial / t_batch, "x", ""),
    ]

    # weighted-vs-unweighted reduce overhead (the hot PageRank step)
    vals64 = np.random.default_rng(1).standard_normal(n)
    vals32 = jax_asarray_f32(vals64)
    for name, ew, eu, v in (
        ("numpy", eng_np, eng_npu, vals64),
        ("jax", eng_jx, eng_jxu, vals32),
    ):
        ew.edge_map_reduce(v), eu.edge_map_reduce(v)  # warm
        t_w = _timeit(lambda: jax_block(ew.edge_map_reduce(v)), repeats=3)
        t_u = _timeit(lambda: jax_block(eu.edge_map_reduce(v)), repeats=3)
        rows += [
            (f"WEIGHT/reduce_weighted_{name}/{tag}", t_w * 1e6, "us",
             "edge_map_reduce, weighted (+,x) semiring"),
            (f"WEIGHT/reduce_unweighted_{name}/{tag}", t_u * 1e6, "us",
             "edge_map_reduce, pre-v2 trace"),
            (f"WEIGHT/reduce_overhead_{name}/{tag}", t_w / max(t_u, 1e-12), "x",
             "weighted/unweighted"),
        ]
    return rows


def jax_asarray_f32(x):
    import jax.numpy as jnp

    return jnp.asarray(x, jnp.float32)


# ---------------------------------------------------------------------------
# SHARD: the sharded traversal/update substrate vs n_shards (DESIGN.md §9)
# ---------------------------------------------------------------------------


def bench_sharded(quick: bool = False) -> List[Row]:
    """Queries/s and updates/s on the range-sharded substrate as the
    shard count grows:

      * batched BFS (`bfs_multi` through the in-trace sharded driver)
        and PageRank (shard-local segsum + psum_scatter reduce) on
        ``ShardedEngine``;
      * the shard-local rank-merge update step (edges/s per batch).

    On a 1-device CPU container the multi-shard rows measure the
    block-per-device overhead, NOT mesh scaling — run this table under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or real
    hardware) for the scaling story; the jax single-chip engine row is
    the S-independent baseline."""
    import jax
    import jax.numpy as jnp

    from repro.core import flat_graph as fg
    from repro.core import sharded_pool as sp
    from repro.core.traversal import make_engine
    from repro.core.traversal import algorithms as talg
    from repro.data.rmat import rmat_edges

    n, edges = _test_graph(11, 30_000)
    rng = np.random.default_rng(0)
    B = 8 if quick else 16
    srcs = rng.integers(0, n, B)
    nd = jax.device_count()
    shard_counts = [1, 2] if quick else [1, 2, 4, 8]
    rows: List[Row] = []

    # S-independent single-chip baseline
    eng_jx = make_engine(fg.from_edges(n, edges))
    talg.bfs_multi(eng_jx, srcs)
    t_base = _timeit(lambda: talg.bfs_multi(eng_jx, srcs), repeats=2)
    rows.append(
        ("SHARD/bfs_batch_qps/jax", B / t_base, "queries/s",
         "single-chip JaxEngine baseline")
    )

    bat = rmat_edges(11, 1024, seed=1)
    bkeys = np.unique((bat[:, 0].astype(np.int64) << 32) | bat[:, 1])
    pad = int(2 ** np.ceil(np.log2(bkeys.size + 1)))
    batch = np.full(pad, sp.SENT, np.int64)
    batch[: bkeys.size] = bkeys
    batch_j = jnp.asarray(batch)

    for S in shard_counts:
        tag = f"S={S}"
        sg = sp.graph_from_edges(n, edges, n_shards=S)
        eng = make_engine(sg)
        talg.bfs_multi(eng, srcs)  # warm the driver jit at this S
        t_q = _timeit(lambda: talg.bfs_multi(eng, srcs), repeats=2)
        talg.pagerank(eng, iters=3)
        t_pr = _timeit(lambda: talg.pagerank(eng, iters=3), repeats=2)

        mesh = sp.pool_mesh(S)
        step = sp.make_insert_step(mesh, ("shard",))
        pool = sp.from_array(
            sp.to_array(sg.pool), S, cap_per=int(sg.pool.data.shape[1] * 2)
        )
        jax_block(step(pool, batch_j).data)  # warm
        t_u = _timeit(lambda: jax_block(step(pool, batch_j).data), repeats=3)
        rows += [
            (f"SHARD/bfs_batch_qps/{tag}", B / t_q, "queries/s",
             f"sharded engine, devices={nd}"),
            (f"SHARD/pagerank_ms/{tag}", t_pr * 1e3, "ms",
             "3-iter power iteration, psum_scatter reduce"),
            (f"SHARD/insert_eps/{tag}", bkeys.size / t_u, "edges/s",
             "shard-local rank-merge, one batch all-gather"),
        ]
    return rows


# ---------------------------------------------------------------------------
# kernel micro-benchmarks (§Perf support; CPU = oracle timings only)
# ---------------------------------------------------------------------------


def bench_kernels(quick: bool = False) -> List[Row]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    rows: List[Row] = []
    # delta decode
    deltas = jnp.asarray(rng.integers(0, 100, (256, 256)), jnp.int32).at[:, 0].set(0)
    anchors = jnp.asarray(rng.integers(0, 1 << 20, 256), jnp.int32)
    f = jax.jit(ref.delta_decode_ref)
    jax.block_until_ready(f(anchors, deltas))
    t = _timeit(lambda: jax.block_until_ready(f(anchors, deltas)))
    rows.append(("K/delta_decode_ref", t * 1e6, "us", "jnp oracle, 64k elems"))
    # segment sum
    E, D = 8192, 128
    dst = jnp.asarray(np.sort(rng.integers(0, 1024, E)), jnp.int32)
    msg = jnp.asarray(rng.standard_normal((E, D)), jnp.float32)
    f = jax.jit(lambda d, m: ref.segment_sum_sorted_ref(d, m, 1024))
    jax.block_until_ready(f(dst, msg))
    t = _timeit(lambda: jax.block_until_ready(f(dst, msg)))
    rows.append(("K/segment_sum_ref", t * 1e6, "us", f"E={E},D={D}"))
    # flash decode
    q = jnp.asarray(rng.standard_normal((8, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((8, 4096, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((8, 4096, 64)), jnp.float32)
    lens = jnp.full((8,), 4096, jnp.int32)
    f = jax.jit(ref.flash_decode_ref)
    jax.block_until_ready(f(q, k, v, lens))
    t = _timeit(lambda: jax.block_until_ready(f(q, k, v, lens)))
    rows.append(("K/flash_decode_ref", t * 1e6, "us", "BH=8,S=4k,d=64"))
    return rows



# ---------------------------------------------------------------------------
# BYTES: compressed device pool — bytes/edge + fused-decode throughput
# ---------------------------------------------------------------------------


def bench_bytes(quick: bool = False) -> List[Row]:
    """DESIGN.md §10: the paper's headline metric (a few bytes per edge,
    T2) on the DEVICE pool.  Compares the raw packed-key FlatGraph
    against the chunk-compressed ``CompressedPool`` at several rMAT
    scales: pool-only bytes/edge, whole-engine resident bytes/edge
    (pool + traversal aux), and the edgeMap (+, x) reduce throughput of
    the fused-decode Pallas kernel vs the raw kernel (PageRank's inner
    loop).  The compressed pool uses the adaptive per-chunk width
    (int8 lanes with an int16 hi-plane only on wide chunks, §12); a
    fixed int16-wide row pins how much the width tags buy, and an
    ``ideal_gap`` row checks the resident bytes against the
    ``chunk_stats.bytes_ideal`` prediction.  One sharded-engine
    residency row pins the per-shard variant.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import compressed as cz
    from repro.core import flat_graph as fg
    from repro.core import sharded_pool as sp
    from repro.core.traversal import make_engine

    rng = np.random.default_rng(0)
    rows: List[Row] = []
    scales = [(12, 60_000)] if quick else [(12, 60_000), (13, 120_000)]
    B = 4 if quick else 8
    for log_n, m in scales:
        n, edges = _test_graph(log_n, m)
        g = fg.from_edges(n, edges)
        cg = fg.compress_host(g)  # adaptive per-chunk widths (§12)
        cg2 = fg.compress_host(g, width=2)
        e_raw = make_engine(g)
        e_cmp = make_engine(cg)
        me = int(g.m)
        tag = f"n=2^{log_n},m={me}"
        pool_raw = g.keys.nbytes / me
        pool_cmp = cz.stream_nbytes(cg.dst) / me
        pool_f2 = cz.stream_nbytes(cg2.dst) / me
        ideal = fg.chunk_stats(g)["bytes_ideal"] / me
        rows += [
            (f"BYTES/pool_raw/{tag}", pool_raw, "B/edge", "packed int64 keys"),
            (f"BYTES/pool_comp/{tag}", pool_cmp, "B/edge", "adaptive-width delta chunks"),
            (f"BYTES/pool_fixed2/{tag}", pool_f2, "B/edge", "fixed int16 delta chunks"),
            (
                f"BYTES/pool_adaptive_gain/{tag}",
                pool_f2 / pool_cmp,
                "x",
                "fixed-int16 / adaptive bytes; >= 1 by construction",
            ),
            (
                f"BYTES/pool_ideal_gap/{tag}",
                pool_cmp / ideal,
                "x",
                "resident / bytes_ideal; target <= 1.05",
            ),
            (f"BYTES/pool_ratio/{tag}", pool_raw / pool_cmp, "x", "paper: 4.7-11.3x (T2)"),
            (
                f"BYTES/resident_raw/{tag}",
                e_raw.resident_nbytes / me,
                "B/edge",
                "pool + EngineAux",
            ),
            (
                f"BYTES/resident_comp/{tag}",
                e_cmp.resident_nbytes / me,
                "B/edge",
                "pool + CompressedAux",
            ),
            (
                f"BYTES/resident_ratio/{tag}",
                e_raw.resident_nbytes / e_cmp.resident_nbytes,
                "x",
                "whole-engine reduction",
            ),
        ]
        vals = jnp.asarray(rng.random((B, n)), jnp.float32)
        t_raw = _timeit(
            lambda: jax.block_until_ready(e_raw.edge_map_reduce_batch(vals)),
            repeats=2,
        )
        t_cmp = _timeit(
            lambda: jax.block_until_ready(e_cmp.edge_map_reduce_batch(vals)),
            repeats=2,
        )
        rows += [
            (f"BYTES/reduce_raw/{tag}", t_raw * 1e3, "ms", f"B={B} segment-sum"),
            (f"BYTES/reduce_comp/{tag}", t_cmp * 1e3, "ms", "fused in-kernel decode"),
            (
                f"BYTES/reduce_ratio/{tag}",
                t_cmp / t_raw,
                "x",
                "comp/raw time; target <= ~1.2",
            ),
        ]
    # sharded residency at the smallest scale (the per-shard variant)
    n, edges = _test_graph(11, 30_000, seed=1)
    sg = sp.graph_from_edges(n, edges, n_shards=2)
    csg = sp.compress_sharded(sg)  # adaptive per-chunk widths
    es_raw = make_engine(sg)
    es_cmp = make_engine(csg)
    me = sp.graph_num_edges(sg)
    tag = f"sharded,n=2^11,m={me}"
    rows.append(
        (
            f"BYTES/resident_ratio/{tag}",
            es_raw.resident_nbytes / es_cmp.resident_nbytes,
            "x",
            "per-shard pool + aux reduction",
        )
    )
    return rows


# ---------------------------------------------------------------------------
# INCR: incremental (delta-aware) queries vs full recompute (DESIGN.md §11)
# ---------------------------------------------------------------------------


def bench_incremental(quick: bool = False) -> List[Row]:
    """Time-to-fresh-result after a small edge batch: the delta-aware
    incremental path (warm-start PageRank, dirty-subtree BFS) against a
    full recompute on the same new snapshot, at 0.1% and 1%-of-edges
    batch sizes, plus subscriber staleness under a live writer.

    The headline claim (ROADMAP item #2): time-to-fresh scales with the
    batch, not the graph — incremental beats full recompute at both
    batch sizes, and a live ``Subscription`` stays within a version or
    two of the writer while serving via the incremental path.  (BFS is
    pinned exact in tests but not timed here: its warm relax win is
    offset by the standalone parents pass at this scale, so the table
    features PageRank / CC / SSSP where the win is unambiguous.)"""
    from repro.core import graph as G
    from repro.core.streaming import AspenStream, make_update_stream, run_concurrent
    from repro.core.traversal import algorithms as talg

    n, edges = _test_graph(11, 30_000)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    w = ((lo * 1000003 + hi) % 7 + 1).astype(np.float64)
    reps = 2 if quick else 4
    tol = 1e-5
    rng = np.random.default_rng(0)
    src = rng.integers(0, n, 4)
    rows: List[Row] = []

    fracs = [0.01] if quick else [0.001, 0.01]
    for frac in fracs:
        k = max(1, int(edges.shape[0] * frac))
        batch = rng.integers(0, n, size=(4 * k, 2)).astype(np.int64)
        batch = batch[batch[:, 0] != batch[:, 1]][:k]
        blo = np.minimum(batch[:, 0], batch[:, 1])
        bhi = np.maximum(batch[:, 0], batch[:, 1])
        bw = ((blo * 1000003 + bhi) % 7 + 1).astype(np.float64)
        s = AspenStream(G.build_graph(n, edges, weights=w))
        v1 = s.vg.acquire()
        e1 = s._engine_for(v1, "jax")
        prev_pr = talg.pagerank(e1, tol=tol)
        prev_cc = np.asarray(talg.connected_components(e1), np.int64)
        prev_dist = np.asarray(talg.sssp_multi(e1, src), np.float64)
        prev_tree = talg.shortest_path_parents(e1, prev_dist, src)
        s.insert_edges(batch, weights=bw)
        v2 = s.vg.acquire()
        delta = s.vg.delta_between(v1, v2)
        assert delta is not None
        e2 = s._engine_for(v2, "jax")
        tag = f"frac={frac:g},k={k}"

        # warm the jits outside the measured window
        talg.incremental_sssp(e2, src, prev_dist, prev_tree, delta)
        t_pr_full = _timeit(lambda: talg.pagerank(e2, tol=tol), repeats=reps)
        t_pr_warm = _timeit(
            lambda: talg.pagerank(e2, tol=tol, init=prev_pr), repeats=reps
        )
        t_cc_full = _timeit(
            lambda: np.asarray(talg.connected_components(e2)), repeats=reps
        )
        t_cc_incr = _timeit(
            lambda: talg.incremental_connected_components(e2, prev_cc, delta),
            repeats=reps,
        )
        t_ss_full = _timeit(lambda: np.asarray(talg.sssp_multi(e2, src)), repeats=reps)
        t_ss_incr = _timeit(
            lambda: talg.incremental_sssp(e2, src, prev_dist, prev_tree, delta),
            repeats=reps,
        )
        rows += [
            (f"INCR/pr_full_ms/{tag}", t_pr_full * 1e3, "ms", "full recompute to tol"),
            (f"INCR/pr_warm_ms/{tag}", t_pr_warm * 1e3, "ms",
             "warm-start from prev scores, same tol"),
            (f"INCR/pr_speedup/{tag}", t_pr_full / max(t_pr_warm, 1e-9), "x",
             "target > 1x"),
            (f"INCR/cc_full_ms/{tag}", t_cc_full * 1e3, "ms", "full label prop"),
            (f"INCR/cc_incr_ms/{tag}", t_cc_incr * 1e3, "ms",
             "label prop seeded from delta endpoints"),
            (f"INCR/cc_speedup/{tag}", t_cc_full / max(t_cc_incr, 1e-9), "x",
             "target > 1x"),
            (f"INCR/sssp_full_ms/{tag}", t_ss_full * 1e3, "ms",
             f"full sssp_multi, B={src.size}"),
            (f"INCR/sssp_incr_ms/{tag}", t_ss_incr * 1e3, "ms",
             "dirty-subtree warm relaxation"),
            (f"INCR/sssp_speedup/{tag}", t_ss_full / max(t_ss_incr, 1e-9), "x",
             "target > 1x"),
        ]
        s.vg.release(v1)
        s.vg.release(v2)

    # -- subscriber staleness under a live writer ---------------------------
    # insert-only updates: one publish per writer batch, so a subscriber
    # that keeps pace sees intact one-hop delta chains (a delete batch
    # publishes a second hop back-to-back, which collects the insert hop
    # before any reader can catch it — that path is the full-recompute
    # fallback, pinned in tests)
    keep, stream = make_update_stream(edges, 2_000, seed=9, delete_frac=0.0)
    s = AspenStream(G.build_graph(n, keep))
    sub = s.subscribe("cc", backend="jax")
    stats = run_concurrent(
        s, stream, query_fn=lambda h: h.refresh(),
        duration_s=1.0 if quick else 2.5, batch_size=50,
        subscription=sub,
    )
    total = max(sub.n_full + sub.n_incremental, 1)
    rows += [
        ("INCR/sub_staleness", stats.subscriber_staleness, "versions",
         "mean versions-behind right after refresh"),
        ("INCR/sub_refresh_qps", stats.queries_per_sec, "refresh/s",
         "live-writer subscriber refresh rate"),
        ("INCR/sub_incremental_frac", sub.n_incremental / total, "frac",
         "refreshes served by the delta path"),
    ]
    sub.close()
    return rows


# ---------------------------------------------------------------------------
# SERVE: the GraphQueryService front end (DESIGN.md §13)
# ---------------------------------------------------------------------------


def bench_serve(quick: bool = False) -> List[Row]:
    """The serving claim: coalescing heterogeneous client queries into
    power-of-two lane batches sustains >= 1.5x the throughput of
    batch-size-1 serving at comparable tail latency, under a LIVE
    writer — measured closed-loop (C client threads submitting
    back-to-back, Zipfian source mix, ~70/30 bfs/sssp) against two
    service configs that differ only in ``max_batch``.

    Also reports the deadline-miss rate (the CI hard gate: compare.py
    fails a >25%-point regression via ``--units pct``), achieved batch
    size, writer update throughput under query load, and the
    post-warmup retrace count (must be 0).

    The third config layers the version-keyed result cache + delta
    carry-forward (DESIGN.md §14) on the batch=B service (claim: >= 2x
    the cache-off qps under the same Zipf load, p99 no worse), and a
    single-threaded DETERMINISTIC Zipf replay measures the cache hit
    rate reproducibly — the ``hit%`` row compare.py hard-gates via
    ``--benefit-units`` (a drop regresses)."""
    import threading as _threading

    from repro.core import graph as G
    from repro.core.streaming import AspenStream
    from repro.serve.graph import GraphQueryService, QueueFull

    log_n = 10 if quick else 11
    n, edges = _test_graph(log_n, 15_000 if quick else 30_000, seed=5)
    dur = 1.5 if quick else 4.0
    # enough closed-loop clients that lanes actually fill: coalescing
    # only pays when the pending set outruns a single dispatch
    n_clients = 24 if quick else 48
    deadline_s = 2.0

    def run_config(max_batch: int, cache: bool = False):
        stream = AspenStream(G.build_graph(n, edges))
        svc = GraphQueryService(
            stream,
            backend="jax",
            max_batch=max_batch,
            default_deadline_s=deadline_s,
            work_conserving=True,
            max_inflight_total=max(4 * n_clients, 64),
            result_cache=cache,
            fastpath=cache,
        )
        svc.start()
        svc.warmup(kinds=("bfs", "sssp"))
        stop = _threading.Event()
        lats: List[List[float]] = [[] for _ in range(n_clients)]
        cached_lats: List[List[float]] = [[] for _ in range(n_clients)]
        cold_lats: List[List[float]] = [[] for _ in range(n_clients)]
        misses = [0] * n_clients

        def client(idx: int) -> None:
            rng = np.random.default_rng(100 + idx)
            while not stop.is_set():
                kind = "bfs" if rng.random() < 0.8 else "sssp"
                # hot-query skew (zipf s=2: top source ~60% of traffic) —
                # the dedup inside each lane flush turns repeats into
                # free qps, which batch-size-1 serving cannot exploit
                src = int(min(rng.zipf(2.0) - 1, n - 1))
                try:
                    t = svc.submit(kind, source=src, tenant=f"t{idx % 2}")
                except (QueueFull, RuntimeError):
                    time.sleep(0.001)
                    continue
                try:
                    t.result(timeout=30)
                except Exception:
                    continue
                lats[idx].append(t.latency_s)
                (cached_lats if t.cached else cold_lats)[idx].append(t.latency_s)
                misses[idx] += bool(t.deadline_missed)

        def feeder() -> None:
            # ~200 updates/s offered in bursts: the writer drains each
            # burst as ONE batched publish (drain_updates), so update
            # cost amortizes instead of one full mirror-merge per edge
            rng = np.random.default_rng(99)
            while not stop.is_set():
                for _ in range(20):
                    svc.enqueue_update(
                        int(rng.integers(n)), int(rng.integers(n)), block=False
                    )
                time.sleep(0.1)

        threads = [
            _threading.Thread(target=client, args=(i,)) for i in range(n_clients)
        ] + [_threading.Thread(target=feeder)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        time.sleep(dur)
        stop.set()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        st = svc.stats()
        svc.stop()
        all_lats = np.asarray([x for l in lats for x in l], np.float64)
        warm = np.asarray([x for l in cached_lats for x in l], np.float64)
        cold = np.asarray([x for l in cold_lats for x in l], np.float64)
        total = max(len(all_lats), 1)
        lanes = st["lanes"]
        flushed_b = sum(l["flushed_batches"] for l in lanes.values())
        flushed_r = sum(l["flushed_requests"] for l in lanes.values())
        cache_st = st.get("cache") or {}
        return {
            "qps": len(all_lats) / elapsed,
            "p50_ms": float(np.percentile(all_lats, 50)) * 1e3 if len(all_lats) else 0.0,
            "p99_ms": float(np.percentile(all_lats, 99)) * 1e3 if len(all_lats) else 0.0,
            "warm_p50_ms": float(np.percentile(warm, 50)) * 1e3 if len(warm) else 0.0,
            "cold_p50_ms": float(np.percentile(cold, 50)) * 1e3 if len(cold) else 0.0,
            "miss_pct": 100.0 * sum(misses) / total,
            "mean_batch": flushed_r / max(flushed_b, 1),
            "retraces": sum(l["retraces"] for l in lanes.values()),
            "updates_per_s": st["updates"]["drained"] / elapsed,
            "publishes": st["publishes"],
            "hit_rate_pct": 100.0 * cache_st.get("hit_rate", 0.0),
        }

    def run_replay():
        # deterministic single-threaded Zipf replay: fixed seed,
        # sequential queries, synchronous publish + promotion barriers —
        # the hit-rate it reports is bit-reproducible run to run, so CI
        # can hard-gate it (benefit unit: a DROP fails)
        stream = AspenStream(G.build_graph(n, edges))
        svc = GraphQueryService(
            stream, backend="jax", max_batch=8,
            default_deadline_s=deadline_s, fastpath=True,
        )
        svc.start()
        svc.warmup(kinds=("bfs", "sssp"))
        rng = np.random.default_rng(1234)
        n_q = 400 if quick else 1500
        t0 = time.perf_counter()
        for i in range(n_q):
            kind = "bfs" if rng.random() < 0.8 else "sssp"
            src = int(min(rng.zipf(2.0) - 1, n - 1))
            svc.query(kind, source=src, timeout=30)
            if i % 100 == 99:
                svc.insert_edges(
                    np.array([[int(rng.integers(n)), int(rng.integers(n))]])
                )
                svc.flush_updates()
                svc.flush_promotions()
        elapsed = time.perf_counter() - t0
        st = svc.stats()
        svc.stop()
        return {
            "hit_rate_pct": 100.0 * st["cache"]["hit_rate"],
            "qps": n_q / elapsed,
        }

    r1 = run_config(1)
    rb = run_config(16 if quick else 64)
    rc = run_config(16 if quick else 64, cache=True)
    rp = run_replay()
    B = 16 if quick else 64
    return [
        ("SERVE/qps/batch=1", r1["qps"], "queries/s",
         f"{n_clients} closed-loop clients, live writer"),
        (f"SERVE/qps/batch={B}", rb["qps"], "queries/s",
         "same load, coalescing lanes"),
        (f"SERVE/speedup/batch={B}", rb["qps"] / max(r1["qps"], 1e-9), "x",
         "claim: >= 1.5x over batch-size-1 serving"),
        ("SERVE/p50_ms/batch=1", r1["p50_ms"], "ms", ""),
        (f"SERVE/p50_ms/batch={B}", rb["p50_ms"], "ms", ""),
        ("SERVE/p99_ms/batch=1", r1["p99_ms"], "ms", ""),
        (f"SERVE/p99_ms/batch={B}", rb["p99_ms"], "ms",
         "comparable tail to batch=1 at higher qps"),
        (f"SERVE/mean_batch_size/batch={B}", rb["mean_batch"], "req/flush",
         "achieved coalescing under this load"),
        (f"SERVE/deadline_miss_pct/batch={B}", rb["miss_pct"], "pct",
         "CI hard gate: fail if this regresses > 25 points"),
        (f"SERVE/retraces/batch={B}", float(rb["retraces"]), "count",
         "must stay 0 after warmup"),
        (f"SERVE/writer_updates_per_s/batch={B}", rb["updates_per_s"], "up/s",
         "update throughput under full query load"),
        (f"SERVE/publishes/batch={B}", float(rb["publishes"]), "count",
         "versions published during the window"),
        ("SERVE/qps/cached", rc["qps"], "queries/s",
         f"batch={B} + result cache + carry-forward, same load"),
        ("SERVE/speedup/cache", rc["qps"] / max(rb["qps"], 1e-9), "x",
         "claim: >= 2x over the cache-off run"),
        ("SERVE/p50_ms/cached", rc["p50_ms"], "ms", ""),
        ("SERVE/p99_ms/cached", rc["p99_ms"], "ms",
         "tail no worse than cache-off: misses ride the same lanes"),
        ("SERVE/warm_p50_ms/cached", rc["warm_p50_ms"], "ms",
         "cache-hit latency (no lane, no executor hop)"),
        ("SERVE/cold_p50_ms/cached", rc["cold_p50_ms"], "ms",
         "miss latency (full admission + lane + dispatch path)"),
        ("SERVE/hit_rate_pct/cached", rc["hit_rate_pct"], "pct",
         "closed-loop hit rate under the live writer"),
        ("SERVE/deadline_miss_pct/cached", rc["miss_pct"], "pct",
         "CI hard gate: fail if this regresses > 25 points"),
        ("SERVE/retraces/cached", float(rc["retraces"]), "count",
         "must stay 0 after warmup (shrunk batches stay on the ladder)"),
        ("SERVE/replay_hit_rate", rp["hit_rate_pct"], "hit%",
         "deterministic Zipf replay; CI benefit gate: a >25% drop fails"),
        ("SERVE/replay_qps", rp["qps"], "queries/s",
         "single-threaded replay throughput (fastpath + cache)"),
    ]


ALL_BENCHES = {
    "memory_usage": bench_memory_usage,
    "chunk_size": bench_chunk_size,
    "algorithms": bench_algorithms,
    "flat_snapshot": bench_flat_snapshot,
    "concurrent": bench_concurrent,
    "batch_updates": bench_batch_updates,
    "vs_baselines": bench_vs_baselines,
    "traversal": bench_traversal,
    "streaming": bench_streaming,
    "query_batch": bench_query_batch,
    "weighted": bench_weighted,
    "sharded": bench_sharded,
    "kernels": bench_kernels,
    "bytes": bench_bytes,
    "incremental": bench_incremental,
    "serve": bench_serve,
}
