"""Roofline report generator: dryrun.jsonl -> EXPERIMENTS.md tables.

Terms per (arch x shape x mesh), all per-device per-step:
    compute_s    = HLO_FLOPs / 197e12
    memory_s     = HLO_bytes / 819e9
    collective_s = collective_bytes / (4 x 50e9)
t_bound = max(terms); MFU_bound = MODEL_FLOPS / (chips * peak * t_bound).

    PYTHONPATH=src python -m benchmarks.roofline [--jsonl PATH] [--md PATH]
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict
from typing import Dict

PEAK = 197e12


def load(path: str) -> "OrderedDict[tuple, dict]":
    out: "OrderedDict[tuple, dict]" = OrderedDict()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            out[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    return out


def mfu_bound(r: dict) -> float:
    t = max(r["compute_s_term"], r["memory_s_term"], r["collective_s_term"])
    if t <= 0 or not r.get("model_flops"):
        return 0.0
    return r["model_flops"] / (r["n_chips"] * PEAK * t)


def advice(r: dict) -> str:
    dom = r["dominant"]
    kind = r["meta"].get("kind", "")
    if dom == "collective":
        return "cut cross-device traffic (resharding/collective schedule)"
    if dom == "memory":
        if "decode" in kind:
            return "KV-cache traffic bound: quantize KV or widen batch"
        if "stream" in kind:
            return "pool-rebuild traffic: touch only affected ranges"
        return "fuse elementwise chains / drop f32 intermediates (bf16)"
    return "compute-bound: raise MXU utilization (larger tiles, less remat)"


def fmt_row(r: dict) -> str:
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {r['compute_s_term']:.3e} | {r['memory_s_term']:.3e} "
        f"| {r['collective_s_term']:.3e} | **{r['dominant']}** "
        f"| {r.get('model_flops', 0):.3g} | {r.get('useful_compute_frac', 0):.3f} "
        f"| {mfu_bound(r):.4f} | {advice(r)} |"
    )


HEADER = (
    "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant "
    "| MODEL_FLOPS | useful | MFU_bound | to improve |\n"
    "|---|---|---|---|---|---|---|---|---|---|---|"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="experiments/dryrun.jsonl")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    rows = load(args.jsonl)
    ok = [r for r in rows.values() if r.get("ok")]
    fails = [r for r in rows.values() if not r.get("ok")]
    single = [r for r in ok if r["mesh"] == "16x16"]
    multi = [r for r in ok if r["mesh"] == "2x16x16"]

    lines = []
    lines.append(f"{len(ok)} cells OK, {len(fails)} failed "
                 f"({len(single)} single-pod, {len(multi)} multi-pod).\n")
    lines.append("### Single-pod (16x16 = 256 chips) roofline — all cells\n")
    lines.append(HEADER)
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        lines.append(fmt_row(r))
    lines.append("\n### Multi-pod (2x16x16 = 512 chips) — dry-run pass + terms\n")
    lines.append(HEADER)
    for r in sorted(multi, key=lambda r: (r["arch"], r["shape"])):
        lines.append(fmt_row(r))
    if fails:
        lines.append("\n### Failures\n")
        for r in fails:
            lines.append(f"- {r['arch']}/{r['shape']}/{r['mesh']}: {r['error'][:200]}")

    # hillclimb candidate selection
    def worst_mfu(rs):
        cand = [r for r in rs if r.get("model_flops", 0) > 0]
        return min(cand, key=mfu_bound) if cand else None

    coll = [r for r in single if r["dominant"] == "collective"]
    most_coll = max(coll, key=lambda r: r["collective_s_term"]) if coll else None
    lines.append("\n### Hillclimb candidates (per assignment: worst fraction, "
                 "most collective-bound, most paper-representative)\n")
    w = worst_mfu(single)
    if w:
        lines.append(f"- worst MFU_bound: {w['arch']}/{w['shape']} ({mfu_bound(w):.4f})")
    if most_coll:
        lines.append(f"- most collective-bound: {most_coll['arch']}/{most_coll['shape']} "
                     f"(collective_s={most_coll['collective_s_term']:.3e})")
    lines.append("- paper-representative: aspen-stream/update_2m (the streaming "
                 "batch-union step itself)")

    text = "\n".join(lines)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.md}")
    else:
        print(text)


if __name__ == "__main__":
    main()
