"""Perf-trajectory appender: one committed JSON file per tracked bench.

    PYTHONPATH=src python -m benchmarks.trajectory --bench bytes \
        --out BENCH_BYTES.json [--quick] [--rows rows.json]

Each tracked bench (BYTES, SHARD, INCR today) keeps an append-per-run
file at the repo root: a JSON list of run records, newest last, so the
measurement history travels with the code and ``benchmarks.compare``
can gate a fresh run against the last committed record.

Run record schema::

    {
      "sha":   "<git HEAD at measurement time, 'unknown' outside git>",
      "date":  "<UTC ISO-8601>",
      "quick": true,
      "bench": "bytes",
      "rows":  [{"name": ..., "value": ..., "unit": ..., "notes": ...}]
    }

``--rows`` appends pre-computed rows (the ``--json`` output of
``benchmarks.run``) instead of re-running the bench — CI measures once
and both archives and compares the same numbers.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys


def git_sha(cwd: str | None = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd,
            capture_output=True,
            text=True,
            timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:  # noqa: BLE001
        return "unknown"


def load_trajectory(path: str) -> list:
    """The run list at ``path`` ([] when absent); tolerates a legacy
    plain-rows file by wrapping it as one sha-less record."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"{path}: expected a JSON list of run records")
    if data and isinstance(data[0], dict) and "rows" not in data[0]:
        # plain benchmarks.run --json row list
        return [{"sha": "unknown", "date": "", "quick": True, "rows": data}]
    return data


def append_run(path: str, rows: list, *, bench: str, quick: bool, sha: str | None = None) -> dict:
    runs = load_trajectory(path)
    record = {
        "sha": sha if sha is not None else git_sha(os.path.dirname(os.path.abspath(path)) or None),
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds"),
        "quick": bool(quick),
        "bench": bench,
        "rows": rows,
    }
    runs.append(record)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(runs, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return record


def run_bench(bench: str, quick: bool) -> list:
    from benchmarks.tables import ALL_BENCHES

    if bench not in ALL_BENCHES:
        raise SystemExit(f"unknown bench {bench!r}; one of {sorted(ALL_BENCHES)}")
    rows = ALL_BENCHES[bench](quick=quick)
    return [
        {"name": n, "value": v, "unit": u, "notes": notes}
        for n, v, u, notes in rows
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", required=True, help="bench name from ALL_BENCHES")
    ap.add_argument("--out", required=True, help="trajectory JSON to append to")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--rows", default=None, help="pre-computed rows JSON (skip re-running)"
    )
    args = ap.parse_args()

    if args.rows:
        with open(args.rows) as f:
            rows = json.load(f)
    else:
        rows = run_bench(args.bench, args.quick)
    rec = append_run(args.out, rows, bench=args.bench, quick=args.quick)
    print(
        f"appended {len(rows)} rows for {args.bench} @ {rec['sha'][:12]} -> {args.out}",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
