"""§Perf hillclimb driver: run baseline + variants for the three chosen
cells, record hypothesis -> change -> before -> after.

    PYTHONPATH=src python -m benchmarks.perf_iterate [--out experiments/perf.jsonl]

Cells (per the assignment: worst roofline fraction, most collective-bound,
most paper-representative):
  A. aspen-stream/update_2m   — the paper's own streaming batch-union
  B. qwen3-moe-30b-a3b/prefill_32k — most collective-bound assigned cell
  C. smollm-360m/train_4k     — worst useful-compute fraction (dense LM)
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

ITERATIONS = [
    # (cell tag, arch, shape, build_kw, hypothesis)
    ("A0", "aspen-stream", "update_2m", {},
     "baseline: global rank-merge; searchsorted across the sharded pool "
     "forces all-gathers -> collective-bound (predicted x ~ pool_bytes/links)"),
    ("A1", "aspen-stream", "update_2m", {"variant": "shardmap", "extrapolate": False},
     "range-shard the pool; shard-local merge; only the 16MB batch crosses "
     "links -> predict collective drops ~400x, memory term becomes dominant"),
    ("A2", "aspen-stream", "update_2m", {"variant": "overlay", "extrapolate": False},
     "LSM overlay: merge batch into an 8x-batch overlay instead of the "
     "pool -> predict memory term drops ~16x vs A1 (traffic O(overlay), "
     "amortized compaction), at +1 probe per query"),
    ("B0", "qwen3-moe-30b-a3b", "prefill_32k", {},
     "baseline MoE dispatch: scatter into (E*C, D) buffer makes GSPMD "
     "all-gather token activations -> collective-bound"),
    ("B1", "qwen3-moe-30b-a3b", "prefill_32k",
     {"overrides": {"moe_shard_dispatch": True}},
     "pin dispatch shardings (tokens batch-sharded, expert buffer "
     "model-sharded) -> GSPMD should emit all-to-alls; predict collective "
     "term falls by ~E_shards, compute unchanged"),
    ("C0", "smollm-360m", "train_4k", {},
     "baseline chunked attention visits all (q,kv) blocks and masks above "
     "the diagonal: ~2x wasted attention flops+bytes (useful frac 0.19)"),
    ("C1", "smollm-360m", "train_4k", {"overrides": {"attn_impl": "tri"}},
     "triangular block schedule: visit only j<=i kv-blocks, mask only the "
     "diagonal -> predict attention flops/bytes fall ~1.8x; useful frac up"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/perf.jsonl")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--only", default=None, help="comma list of tags, e.g. A0,A1")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    multi = args.mesh == "multi"

    for tag, arch, shape, kw, hypothesis in ITERATIONS:
        if only and tag not in only:
            continue
        try:
            res = run_cell(arch, shape, multi, **kw)
            res["perf_tag"] = tag
            res["hypothesis"] = hypothesis
            print(
                f"[{tag}] {arch}/{shape}: c={res['compute_s_term']:.3e} "
                f"m={res['memory_s_term']:.3e} x={res['collective_s_term']:.3e} "
                f"dom={res['dominant']} useful={res['useful_compute_frac']:.3f}"
            )
        except Exception as e:  # noqa: BLE001
            res = {"perf_tag": tag, "arch": arch, "shape": shape, "ok": False,
                   "hypothesis": hypothesis, "error": f"{type(e).__name__}: {e}"}
            print(f"[{tag}] FAIL: {str(e)[:300]}")
        with open(args.out, "a") as f:
            f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()
