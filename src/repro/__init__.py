"""repro: Aspen-JAX — compressed purely-functional trees for graph
streaming (PLDI'19) as a multi-pod JAX framework.

x64 is enabled globally: the flat C-tree packs (src, dst) vertex pairs
into int64 keys, which JAX would silently truncate to int32 otherwise.
All model code states dtypes explicitly (bf16/f32/int32), so numerics are
unaffected; only index/key arithmetic gains true 64-bit.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
