"""Transformer building blocks: norms, RoPE, GQA attention, gated MLPs.

Pure-JAX functional style: params are nested dicts of arrays; every layer
is ``init(rng, ...) -> params`` + ``apply(params, x, ...) -> y``.  Dtypes
are explicit everywhere (bf16 compute / f32 accumulation & norms) because
the package enables x64 globally for the C-tree key arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * rms) * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, d_head); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    causal: bool = True
    # "chunked": scan all (q, kv) block pairs, mask above-diagonal.
    # "tri": triangular schedule — per q-block only kv-blocks j <= i are
    #        visited and only the diagonal block pays the mask (the §Perf
    #        iteration: ~1.8x less attention FLOPs/bytes for causal).
    attn_impl: str = "chunked"


def attention_init(key, cfg: AttnConfig, dtype=jnp.bfloat16) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = cfg.d_model ** -0.5
    p = {
        "wq": _normal(kq, (cfg.d_model, cfg.n_heads, cfg.d_head), s, dtype),
        "wk": _normal(kk, (cfg.d_model, cfg.n_kv_heads, cfg.d_head), s, dtype),
        "wv": _normal(kv, (cfg.d_model, cfg.n_kv_heads, cfg.d_head), s, dtype),
        "wo": _normal(ko, (cfg.n_heads, cfg.d_head, cfg.d_model), s, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, cfg.d_head), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, cfg.d_head), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, cfg.d_head), dtype)
    return p


def _qkv(params: Params, cfg: AttnConfig, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


CHUNKED_ATTN_THRESHOLD = 2048  # direct S^2 softmax above this is untenable
Q_BLOCK = 512
KV_BLOCK = 1024


def attention(params: Params, cfg: AttnConfig, x: jax.Array,
              positions: Optional[jax.Array] = None) -> jax.Array:
    """Training/prefill attention. x: (B, S, D).

    Short sequences use the direct softmax; long ones the chunked
    online-softmax (flash-attention-in-jnp) so peak memory is
    O(S * block) instead of O(S^2) — mandatory for the 32k shapes."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, cfg, x, positions)
    g = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.d_head ** -0.5
    if S <= CHUNKED_ATTN_THRESHOLD:
        qh = q.reshape(B, S, cfg.n_kv_heads, g, cfg.d_head)
        logits = jnp.einsum("bshgk,bthk->bhgst", qh, k).astype(jnp.float32) * scale
        if cfg.causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhgst,bthk->bshgk", w, v)
        o = o.reshape(B, S, cfg.n_heads, cfg.d_head)
    else:
        impl = cfg.attn_impl
        triangular = impl.startswith("tri") and cfg.causal
        unroll = impl.endswith("_u")
        o = _blockwise_attention(q, k, v, cfg, scale, triangular, unroll)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def _blockwise_attention(q, k, v, cfg: AttnConfig, scale: float,
                         triangular: bool, unroll: bool) -> jax.Array:
    """Blockwise online-softmax attention (flash-attention-in-jnp).

    triangular: skip kv-blocks wholly above the causal diagonal and mask
      only the diagonal block (~(nq+1)/2nq of the full-schedule work).
    unroll: python-unroll BOTH block loops.  Functionally identical, but
      XLA cost_analysis counts a while-loop body once, so only unrolled
      lowerings report true FLOPs/bytes — the dry-run cost probes use
      this; production uses the scan form (same math, small HLO).
    """
    B, S, H, dh = q.shape
    Kv = cfg.n_kv_heads
    g = H // Kv
    nq, nk = S // Q_BLOCK, S // KV_BLOCK
    r = KV_BLOCK // Q_BLOCK
    assert S % Q_BLOCK == 0 and S % KV_BLOCK == 0 and KV_BLOCK % Q_BLOCK == 0
    qb = q.reshape(B, nq, Q_BLOCK, Kv, g, dh)
    kb_t = k.reshape(B, nk, KV_BLOCK, Kv, dh).transpose(1, 0, 2, 3, 4)
    vb_t = v.reshape(B, nk, KV_BLOCK, Kv, dh).transpose(1, 0, 2, 3, 4)

    def make_step(q_i, i, j_hi):
        @jax.checkpoint
        def kv_step(carry, j):
            m_p, l_p, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kb_t, j, axis=0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb_t, j, axis=0, keepdims=False)
            s = jnp.einsum("bqhgk,bthk->bhgqt", q_i, k_j).astype(jnp.float32) * scale
            if cfg.causal:
                # triangular: only the diagonal block needs the mask
                need = (j == j_hi) if triangular else True
                qpos = i * Q_BLOCK + jnp.arange(Q_BLOCK)
                kpos = j * KV_BLOCK + jnp.arange(KV_BLOCK)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(
                    jnp.logical_or(jnp.logical_not(need), mask)[None, None, None],
                    s, -jnp.inf)
            m_c = jnp.max(s, axis=-1, keepdims=True)
            m_n = jnp.maximum(m_p, m_c)
            m_safe = jnp.where(jnp.isfinite(m_n), m_n, 0.0)
            p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
            alpha = jnp.where(jnp.isfinite(m_p), jnp.exp(m_p - m_safe), 0.0)
            l_n = l_p * alpha[..., 0] + p.sum(-1)
            acc = acc * alpha.astype(acc.dtype) + jnp.einsum(
                "bhgqt,bthk->bhgqk", p.astype(v_j.dtype), v_j)
            return (m_n, l_n, acc), None
        return kv_step

    out_blocks = []
    for i in range(nq):
        q_i = qb[:, i]
        j_hi = (i // r) if triangular else (nk - 1)
        n_steps = j_hi + 1 if (triangular and cfg.causal) else nk
        kv_step = make_step(q_i, i, j_hi if triangular else 10**9)
        m0 = jnp.full((B, Kv, g, Q_BLOCK, 1), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Kv, g, Q_BLOCK), jnp.float32)
        a0 = jnp.zeros((B, Kv, g, Q_BLOCK, dh), q.dtype)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(n_steps):
                carry, _ = kv_step(carry, jnp.int32(j))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_steps))
        o_i = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        out_blocks.append(o_i.transpose(0, 3, 1, 2, 4))
    return jnp.stack(out_blocks, axis=1).reshape(B, S, H, dh)


def attention_decode(
    params: Params,
    cfg: AttnConfig,
    x: jax.Array,  # (B, 1, D) current token
    k_cache: jax.Array,  # (B, S_max, n_kv, d_head)
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,) int32
    use_flash_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a KV cache; returns (out, k_cache', v_cache')."""
    B, _, D = x.shape
    positions = cache_len[:, None]
    q, k_new, v_new = _qkv(params, cfg, x, positions)
    # append to cache at cache_len
    b_idx = jnp.arange(B)
    k_cache = k_cache.at[b_idx, cache_len].set(k_new[:, 0])
    v_cache = v_cache.at[b_idx, cache_len].set(v_new[:, 0])
    g = cfg.n_heads // cfg.n_kv_heads
    if use_flash_kernel:
        from repro.kernels import ops as kops

        # (B, n_kv, g, d) query rows grouped per kv head
        qh = q.reshape(B, cfg.n_kv_heads, g, cfg.d_head)
        qf = qh.reshape(B * cfg.n_kv_heads, g, cfg.d_head)
        kf = k_cache.transpose(0, 2, 1, 3).reshape(B * cfg.n_kv_heads, -1, cfg.d_head)
        vf = v_cache.transpose(0, 2, 1, 3).reshape(B * cfg.n_kv_heads, -1, cfg.d_head)
        lens = jnp.repeat(cache_len + 1, cfg.n_kv_heads)
        o = kops.flash_decode_attn(qf, kf, vf, lens)
        o = o.reshape(B, cfg.n_kv_heads, g, cfg.d_head).reshape(B, 1, cfg.n_heads, cfg.d_head)
    else:
        qh = q.reshape(B, 1, cfg.n_kv_heads, g, cfg.d_head)
        scale = cfg.d_head ** -0.5
        logits = jnp.einsum("bqhgk,bthk->bhgqt", qh, k_cache).astype(jnp.float32) * scale
        S_max = k_cache.shape[1]
        valid = jnp.arange(S_max)[None, None, None, None, :] <= cache_len[:, None, None, None, None]
        logits = jnp.where(valid, logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhgqt,bthk->bqhgk", w, v_cache)
        o = o.reshape(B, 1, cfg.n_heads, cfg.d_head)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "w_gate": _normal(k1, (d_model, d_ff), s_in, dtype),
        "w_up": _normal(k2, (d_model, d_ff), s_in, dtype),
        "w_down": _normal(k3, (d_ff, d_model), s_out, dtype),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, params["w_down"])


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    """Plain 2-matrix GELU MLP (GPT/starcoder2 style)."""
    k1, k2 = jax.random.split(key)
    return {
        "w_up": _normal(k1, (d_model, d_ff), d_model ** -0.5, dtype),
        "w_down": _normal(k2, (d_ff, d_model), d_ff ** -0.5, dtype),
    }


def gelu_mlp(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_up"]))
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def mlp_init(key, d_in: int, dims, dtype=jnp.float32, bias: bool = True) -> Params:
    keys = jax.random.split(key, len(dims))
    ws, bs = [], []
    d_prev = d_in
    for k, d in zip(keys, dims):
        ws.append(_normal(k, (d_prev, d), d_prev ** -0.5, dtype))
        bs.append(jnp.zeros((d,), dtype))
        d_prev = d
    return {"ws": ws, "bs": bs if bias else None}


def mlp(params: Params, x: jax.Array, act=jax.nn.relu, final_act: bool = False) -> jax.Array:
    n = len(params["ws"])
    for i, w in enumerate(params["ws"]):
        x = jnp.einsum("...d,df->...f", x, w)
        if params["bs"] is not None:
            x = x + params["bs"][i]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# embeddings & logits
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> Params:
    return {"table": _normal(key, (vocab, d_model), 0.02, dtype)}  # GPT-2 init


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Tied logits: (B, S, D) @ (V, D)^T in f32."""
    return jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), params["table"].astype(jnp.float32)
    )


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
