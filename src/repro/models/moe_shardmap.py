"""shard_map MoE: shard-local routing + explicit collective schedule.

Why: the einsum/scatter MoE (moe.py) routes with a GLOBAL argsort over
batch-sharded tokens; GSPMD lowers the resulting data-dependent
gathers/scatters as masked-select + full-buffer all-reduces — measured
346 GB/layer/device on qwen3-moe prefill (EXPERIMENTS.md §Perf B0-B2).

Here every (data, model) device runs a LOCAL program:

  1. route + sort + capacity-assign ONLY its own T/nd tokens
     (C_local = C/nd slots per expert per data shard);
  2. build the local dispatch buffer (E, C_local, D), slice out the
     E/nm experts this model-column owns;
  3. all_gather over "data": (nd, E/nm, C_local, D) == the full capacity
     for my experts — 2 orders of magnitude less traffic than the
     GSPMD-inferred all-reduces;
  4. local grouped GEMMs with my expert weights (E/nm, D, F);
  5. all_gather over "model": every data shard gets all experts' outputs
     for ITS C_local slots; local combine-gather back to (T/nd, D).

Token order, capacity-drop policy, and numerics match moe.py exactly
when capacities don't overflow (property-tested in tests/test_moe.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6: top-level shard_map, replication check kwarg check_vma
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # 0.4.x: experimental namespace, kwarg check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

# set by launch/cells.py before tracing (mesh objects cannot live in a
# hashable LMConfig)
ACTIVE_MESH: Mesh | None = None


def _local_dispatch(xt, router, m, C_local):
    """Everything token-local: returns (buf (E, C_local, D), combine info)."""
    T, D = xt.shape
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), m.top_k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    first_of_e = jnp.searchsorted(e_sorted, jnp.arange(m.n_experts))
    rank = jnp.arange(T * m.top_k) - first_of_e[e_sorted]
    keep = rank < C_local
    slot = e_sorted * C_local + rank
    src_tok = flat_t[order]
    buf = jnp.zeros((m.n_experts * C_local, D), xt.dtype)
    buf = buf.at[jnp.where(keep, slot, m.n_experts * C_local)].set(
        xt[src_tok], mode="drop"
    )
    return buf.reshape(m.n_experts, C_local, D), (slot, keep, src_tok, flat_p, order)


def moe_apply_shardmap(params: Dict[str, Any], cfg, x: jax.Array, mesh: Mesh) -> jax.Array:
    """x: (B, S, D) sharded P(('pod','data'), None, None)."""
    m = cfg.moe
    B, S, D = x.shape
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    nd = 1
    for a in data_axes:
        nd *= mesh.shape[a]
    nm = mesh.shape["model"]
    assert m.n_experts % nm == 0
    T_local = (B * S) // nd
    C_local = max(8, -(-int(m.capacity_factor * T_local * m.top_k / m.n_experts) // 8) * 8)
    e_per = m.n_experts // nm

    def local(x_loc, router, w_gate, w_up, w_down, shared):
        # x_loc: (B/nd, S, D); weights already model-sharded: (E/nm, D, F)
        xt = x_loc.reshape(-1, D)
        buf, (slot, keep, src_tok, flat_p, order) = _local_dispatch(
            xt, router, m, C_local
        )
        # my model-column's experts
        mi = jax.lax.axis_index("model")
        mine = jax.lax.dynamic_slice_in_dim(buf, mi * e_per, e_per, axis=0)
        # (nd, E/nm, C_local, D): full capacity for my experts
        full = jax.lax.all_gather(mine, data_axes, axis=0, tiled=False)
        full = full.reshape(nd * 1 if full.ndim == 4 else -1, e_per, C_local, D) \
            if full.ndim == 4 else full
        full = full.reshape(-1, e_per, C_local, D)  # (nd, E/nm, C_local, D)
        h = full.transpose(1, 0, 2, 3).reshape(e_per, nd * C_local, D)
        g = jnp.einsum("ecd,edf->ecf", h, w_gate)
        u = jnp.einsum("ecd,edf->ecf", h, w_up)
        o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)
        # back to (nd, E/nm, C_local, D), pick my data shard's slots
        o = o.reshape(e_per, nd, C_local, D).transpose(1, 0, 2, 3)
        di = jax.lax.axis_index(data_axes)
        o_mine = jax.lax.dynamic_index_in_dim(o, di, axis=0, keepdims=False)
        # gather all experts' outputs for MY slots: (E, C_local, D)
        o_all = jax.lax.all_gather(o_mine, "model", axis=0, tiled=True)
        o_flat = o_all.reshape(m.n_experts * C_local, D)
        gathered = o_flat[jnp.where(keep, slot, 0)] * jnp.where(
            keep, flat_p[order], 0.0
        )[:, None].astype(x.dtype)
        out = jnp.zeros((xt.shape[0], D), x.dtype).at[src_tok].add(gathered)
        if shared is not None:
            from . import layers as L

            out = out + L.swiglu(shared, xt)
        return out.reshape(x_loc.shape)

    shared = params.get("shared")
    in_specs = (
        P(data_axes, None, None),  # x
        P(None, None),  # router (replicated)
        P("model", None, None),  # w_gate
        P("model", None, None),  # w_up
        P("model", None, None),  # w_down
        (jax.tree.map(lambda _: P(None, None), shared) if shared is not None else None),
    )
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=P(data_axes, None, None),
        **{_CHECK_KW: False},
    )
    return fn(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"], shared)
