"""Mixture-of-Experts MLP block (qwen3-moe, deepseek-moe configs).

Sort-based capacity dispatch (the standard fixed-shape JAX MoE):
  1. router logits -> top-k experts per token (+ optional shared experts);
  2. flatten (token, slot) pairs, sort by expert id;
  3. rank-within-expert gives each pair a capacity slot; overflow drops
     (capacity_factor bounds the padded per-expert batch);
  4. gather tokens into (E, C, D), run per-expert SwiGLU as one batched
     einsum over the expert axis (MXU-friendly grouped GEMM), scatter
     back weighted by router probabilities.

Expert-parallelism: the (E, C, D) activations and (E, ...) weights shard
naturally over the "model" mesh axis (see dist/shardings.py); the
gather/scatter become all-to-alls under GSPMD.

DeepSeek-style shared experts run densely beside the routed ones.
Router uses aux-loss-free sigmoid bias balancing (deepseek-v3 style) as
an option; default is softmax top-k with load-balance loss returned via
an accumulator (kept simple: loss term computed but folded in by caller).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import layers as L


def moe_init(key, cfg, dtype=jnp.bfloat16) -> Dict[str, Any]:
    m = cfg.moe
    kr, ke, ks = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    s_in, s_out = d ** -0.5, ff ** -0.5
    ek = jax.random.split(ke, 3)
    p = {
        "router": L._normal(kr, (d, m.n_experts), s_in, jnp.float32),
        "w_gate": L._normal(ek[0], (m.n_experts, d, ff), s_in, dtype),
        "w_up": L._normal(ek[1], (m.n_experts, d, ff), s_in, dtype),
        "w_down": L._normal(ek[2], (m.n_experts, ff, d), s_out, dtype),
    }
    if m.n_shared > 0:
        p["shared"] = L.swiglu_init(ks, d, m.shared_d_ff * m.n_shared, dtype)
    return p


def _capacity(n_tokens: int, m) -> int:
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 (sublane alignment)


def moe_apply(params: Dict[str, Any], cfg, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    C = _capacity(T, m)

    # --- routing ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # --- capacity assignment via sort by expert ---
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), m.top_k)
    if m.dispatch_shards > 1:
        # hierarchical: rank within (expert, source-shard); each shard owns
        # a contiguous C_local slice of every expert's capacity, so the
        # dispatch scatter never crosses shards (§Perf B-series).
        ns = m.dispatch_shards
        C_local = max(8, -(-C // ns))
        C = C_local * ns
        shard_of = flat_t // max(T // ns, 1)
        group = flat_e * ns + shard_of
        order = jnp.argsort(group, stable=True)
        g_sorted = group[order]
        e_sorted = flat_e[order]
        first_of_g = jnp.searchsorted(g_sorted, jnp.arange(m.n_experts * ns))
        rank = jnp.arange(T * m.top_k) - first_of_g[g_sorted]
        keep = rank < C_local
        slot = e_sorted * C + (g_sorted % ns) * C_local + rank
    else:
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        # rank within expert: position - first-position-of-expert
        first_of_e = jnp.searchsorted(e_sorted, jnp.arange(m.n_experts))
        rank = jnp.arange(T * m.top_k) - first_of_e[e_sorted]
        keep = rank < C
        slot = e_sorted * C + rank  # (T*k,) destination slot in (E*C)

    # --- dispatch: gather token vectors into (E*C, D) ---
    buf = jnp.zeros((m.n_experts * C, D), x.dtype)
    src_tok = flat_t[order]
    gathered_in = xt[src_tok]
    if m.shard_dispatch:
        from jax.sharding import PartitionSpec as P

        gathered_in = jax.lax.with_sharding_constraint(gathered_in, P(None, None))
    buf = buf.at[jnp.where(keep, slot, m.n_experts * C)].set(
        gathered_in, mode="drop"
    )
    h = buf.reshape(m.n_experts, C, D)
    if m.shard_dispatch:
        from jax.sharding import PartitionSpec as P

        h = jax.lax.with_sharding_constraint(h, P("model", None, None))

    # --- grouped expert GEMMs ---
    g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])
    o = o.reshape(m.n_experts * C, D)

    # --- combine: scatter back weighted by router prob ---
    gathered = o[jnp.where(keep, slot, 0)] * jnp.where(keep, flat_p[order], 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[src_tok].add(gathered)

    # --- shared experts (dense) ---
    if "shared" in params:
        out = out + L.swiglu(params["shared"], xt)
    return out.reshape(B, S, D)


def load_balance_loss(router_logits: jax.Array, top_e: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    p_mean = probs.mean(axis=0)
    counts = jnp.zeros(n_experts).at[top_e.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    return n_experts * jnp.sum(f * p_mean)
