"""EmbeddingBag substrate for recsys (kernel_taxonomy §RecSys).

JAX has no nn.EmbeddingBag — built here from ``jnp.take`` +
``jax.ops.segment_sum``.  Two layouts:

  * one-hot fields (DCN/criteo): per-field tables stacked into one
    (n_fields, vocab, dim) array — lookup is a single fused gather,
    sharded over the model axis (row-wise table sharding -> the lookup
    becomes an all-to-all under GSPMD, the TPU analogue of FBGEMM TBE);
  * multi-hot bags: flat (ids, offsets) CSR-style bags reduced by
    segment_sum — and the bag indices can come straight from an Aspen
    flat C-tree pool (a streaming user->item interaction log), which is
    the paper's §9 "other applications" use-case made concrete.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .. import layers as L


def init_field_tables(key, n_fields: int, vocab_per_field: int, dim: int,
                      dtype=jnp.float32) -> Dict[str, Any]:
    scale = dim ** -0.5
    return {
        "tables": L._normal(key, (n_fields, vocab_per_field, dim), scale, dtype)
    }


def lookup_onehot(params, ids: jax.Array) -> jax.Array:
    """ids: (B, F) one id per field -> (B, F, dim).

    vmap over fields: each field gathers its own table rows; under a
    row-sharded table this lowers to an all-to-all exchange."""
    tables = params["tables"]  # (F, V, D)

    def per_field(tab, idx):
        return tab[idx]  # (B, D)

    return jax.vmap(per_field, in_axes=(0, 1), out_axes=1)(tables, ids)


def lookup_bags(params, flat_ids: jax.Array, bag_offsets: jax.Array,
                field_of_bag: jax.Array, n_bags: int, op: str = "sum") -> jax.Array:
    """Multi-hot EmbeddingBag.

    flat_ids: (L,) item ids; bag_offsets: (n_bags+1,); field_of_bag:
    (n_bags,) which table each bag reads. Returns (n_bags, D).
    """
    tables = params["tables"]
    lens = jnp.diff(bag_offsets)
    bag_of_id = jnp.repeat(
        jnp.arange(n_bags), lens, total_repeat_length=flat_ids.shape[0]
    )
    field_of_id = field_of_bag[bag_of_id]
    vecs = tables[field_of_id, flat_ids]  # (L, D)
    s = jax.ops.segment_sum(vecs, bag_of_id, num_segments=n_bags)
    if op == "mean":
        s = s / jnp.maximum(lens[:, None], 1).astype(s.dtype)
    return s


def bags_from_ctree_pool(pool_keys: jax.Array, m: jax.Array, n_users: int):
    """Interpret an Aspen flat C-tree pool of packed (user<<32|item) keys
    as per-user bags: returns (flat_item_ids, bag_offsets).

    This is the zero-copy bridge: the streaming interaction log IS the
    EmbeddingBag input (paper §9: C-trees for dynamically-maintained
    ordered integer sets)."""
    items = (pool_keys & 0xFFFFFFFF).astype(jnp.int32)
    bounds = jnp.arange(n_users + 1, dtype=jnp.int64) << 32
    offs = jnp.minimum(jnp.searchsorted(pool_keys, bounds), m).astype(jnp.int32)
    return items, offs
