"""DCN-v2 (arXiv:2008.13535): dcn-v2 config.

13 dense + 26 sparse(16-dim) features -> explicit cross layers
``x_{l+1} = x0 ⊙ (W_l x_l + b_l) + x_l`` (full-rank) stacked with a deep
MLP (1024-1024-512) -> logit.  Heads for all four assigned shapes:
train (BCE loss), serve_p99/serve_bulk (sigmoid scores), retrieval_cand
(one user vector against 10^6 candidate embeddings — a single batched
dot + top-k, never a loop).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .. import layers as L
from .embedding import init_field_tables, lookup_onehot


def init(
    key,
    n_dense: int = 13,
    n_sparse: int = 26,
    embed_dim: int = 16,
    vocab_per_field: int = 100_000,
    n_cross: int = 3,
    mlp_dims: Tuple[int, ...] = (1024, 1024, 512),
    n_candidates: int = 0,
    dtype=jnp.float32,
) -> Dict[str, Any]:
    ke, kc, km, kl, kr = jax.random.split(key, 5)
    d0 = n_dense + n_sparse * embed_dim
    p: Dict[str, Any] = {
        "embed": init_field_tables(ke, n_sparse, vocab_per_field, embed_dim, dtype),
        "cross": [],
        "mlp": L.mlp_init(km, d0, list(mlp_dims), dtype),
        "logit": L.mlp_init(kl, mlp_dims[-1] + d0, [1], dtype),
    }
    ck = jax.random.split(kc, n_cross)
    for i in range(n_cross):
        p["cross"].append(
            {
                "w": L._normal(ck[i], (d0, d0), d0 ** -0.5, dtype),
                "b": jnp.zeros((d0,), dtype),
            }
        )
    if n_candidates:
        p["candidates"] = L._normal(kr, (n_candidates, mlp_dims[-1]), 1.0, dtype)
    return p


def trunk(params, dense: jax.Array, sparse_ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (cross_out (B, d0), deep_out (B, mlp[-1]))."""
    emb = lookup_onehot(params["embed"], sparse_ids)  # (B, F, D)
    x0 = jnp.concatenate([dense, emb.reshape(emb.shape[0], -1)], axis=-1)
    x = x0
    for cp in params["cross"]:
        x = x0 * (jnp.einsum("bd,de->be", x, cp["w"]) + cp["b"]) + x
    deep = L.mlp(params["mlp"], x0, act=jax.nn.relu, final_act=True)
    return x, deep


def forward(params, dense: jax.Array, sparse_ids: jax.Array) -> jax.Array:
    """CTR logits (B,)."""
    cross, deep = trunk(params, dense, sparse_ids)
    both = jnp.concatenate([cross, deep], axis=-1)
    return L.mlp(params["logit"], both)[:, 0]


def loss_fn(params, dense, sparse_ids, labels) -> jax.Array:
    """Binary cross entropy (the train_batch shape)."""
    logits = forward(params, dense, sparse_ids)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def serve(params, dense, sparse_ids) -> jax.Array:
    """CTR scores (serve_p99 / serve_bulk shapes)."""
    return jax.nn.sigmoid(forward(params, dense, sparse_ids))


def retrieval(params, dense, sparse_ids, top_k: int = 100):
    """retrieval_cand: score 1 query against n_candidates via one GEMV
    (batched dot), return top-k ids+scores."""
    _, user_vec = trunk(params, dense, sparse_ids)  # (1, d)
    scores = jnp.einsum("bd,cd->bc", user_vec, params["candidates"])
    top_scores, top_ids = jax.lax.top_k(scores, top_k)
    return top_scores, top_ids
