"""Dense decoder-only LM (llama/qwen/starcoder families).

scan-over-layers with stacked parameters: HLO stays O(1) in depth (vital
for 48-layer dry-run compile times) and remat policy plugs into the scan.
Covers: train forward+loss, prefill, and single-token decode with a KV
cache (the ``decode_*`` / ``long_*`` shapes lower ``serve_step``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"  # swiglu (3-matrix) | gelu (2-matrix)
    attn_impl: str = "chunked"  # chunked | tri (triangular block schedule)
    moe_impl: str = "einsum"  # einsum (GSPMD-inferred) | shardmap (explicit a2a)
    norm: str = "rmsnorm"
    tie_embeddings: bool = True
    # MoE fields (None => dense)
    moe: Optional["MoEFields"] = None
    remat: str = "none"  # none | full | dots (activation checkpoint policy)
    # scan-over-layers keeps HLO O(1) in depth (production default), but
    # XLA cost_analysis counts a while-loop body ONCE — the dry-run
    # unrolls so FLOPs/bytes are the true per-step totals (DESIGN.md §7).
    unroll_layers: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def attn_config(self) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.head_dim,
            rope_theta=self.rope_theta,
            qkv_bias=self.qkv_bias,
            attn_impl=self.attn_impl,
        )

    def param_count(self) -> int:
        """Exact parameter count (for 6ND roofline math)."""
        d, h, kv, dh, ff = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim, self.d_ff
        attn = d * (h + 2 * kv) * dh + h * dh * d
        if self.moe is None:
            mlp = (3 if self.mlp_kind == "swiglu" else 2) * d * ff
        else:
            m = self.moe
            mlp = m.n_experts * 3 * d * ff + m.n_shared * 3 * d * m.shared_d_ff + d * m.n_experts
        per_layer = attn + mlp + 2 * d
        return self.n_layers * per_layer + self.vocab * d + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, h, kv, dh, ff = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim, self.d_ff
        m = self.moe
        attn = d * (h + 2 * kv) * dh + h * dh * d
        mlp = m.top_k * 3 * d * ff + m.n_shared * 3 * d * m.shared_d_ff + d * m.n_experts
        per_layer = attn + mlp + 2 * d
        return self.n_layers * per_layer + self.vocab * d + d


@dataclasses.dataclass(frozen=True)
class MoEFields:
    n_experts: int
    top_k: int
    n_shared: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    # §Perf: pin dispatch/combine intermediate shardings so GSPMD emits
    # all-to-alls instead of all-gathering token activations.
    shard_dispatch: bool = False
    # §Perf v2: hierarchical dispatch — capacity slots are partitioned by
    # source data-shard (slot = e*C + shard*C_local + local_rank), so the
    # dispatch scatter is shard-local and the only cross-device movement
    # is ONE data->model all-to-all of the (E, C, D) buffer.
    dispatch_shards: int = 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: LMConfig, dtype) -> Dict[str, Any]:
    ka, km, kn1, kn2 = jax.random.split(key, 4)
    p = {
        "attn": L.attention_init(ka, cfg.attn_config, dtype),
        "ln1": L.rmsnorm_init(cfg.d_model) if cfg.norm == "rmsnorm" else L.layernorm_init(cfg.d_model),
        "ln2": L.rmsnorm_init(cfg.d_model) if cfg.norm == "rmsnorm" else L.layernorm_init(cfg.d_model),
    }
    if cfg.moe is None:
        if cfg.mlp_kind == "gelu":
            p["mlp"] = L.gelu_mlp_init(km, cfg.d_model, cfg.d_ff, dtype)
        else:
            p["mlp"] = L.swiglu_init(km, cfg.d_model, cfg.d_ff, dtype)
    else:
        from .moe import moe_init

        p["mlp"] = moe_init(km, cfg, dtype)
    return p


def init_params(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    # stacked layers: vmap init over the leading layer axis
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys)
    return {
        "embed": L.embedding_init(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model) if cfg.norm == "rmsnorm" else L.layernorm_init(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _norm(cfg: LMConfig, p, x):
    return L.rmsnorm(p, x) if cfg.norm == "rmsnorm" else L.layernorm(p, x)


def _block(cfg: LMConfig, lp, x, positions):
    h = x + L.attention(lp["attn"], cfg.attn_config, _norm(cfg, lp["ln1"], x), positions)
    if cfg.moe is None:
        f = L.gelu_mlp if cfg.mlp_kind == "gelu" else L.swiglu
        return h + f(lp["mlp"], _norm(cfg, lp["ln2"], h))
    if cfg.moe_impl == "shardmap":
        from . import moe_shardmap as MS

        return h + MS.moe_apply_shardmap(lp["mlp"], cfg, _norm(cfg, lp["ln2"], h),
                                         MS.ACTIVE_MESH)
    from .moe import moe_apply

    return h + moe_apply(lp["mlp"], cfg, _norm(cfg, lp["ln2"], h))


def forward(params, cfg: LMConfig, tokens: jax.Array) -> jax.Array:
    """(B, S) tokens -> (B, S, V) f32 logits."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        return _block(cfg, lp, x, positions), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    if cfg.unroll_layers:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(body, x, params["layers"])
    x = _norm(cfg, params["ln_f"], x)
    return L.unembed(params["embed"], x)


def loss_fn(params, cfg: LMConfig, tokens: jax.Array, labels: jax.Array) -> jax.Array:
    logits = forward(params, cfg, tokens)
    return L.cross_entropy(logits, labels)


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cfg: LMConfig, cache, token: jax.Array,
                use_flash_kernel: bool = False):
    """One token for every sequence: (B,) token ids -> (B, V) logits.

    scan-over-layers carrying the cache slices; cache updated functionally.
    """
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None])

    def body(carry, inputs):
        x, cache_len = carry
        lp, kc, vc = inputs
        h = _norm(cfg, lp["ln1"], x)
        a, kc, vc = L.attention_decode(
            lp["attn"], cfg.attn_config, h, kc, vc, cache_len,
            use_flash_kernel=use_flash_kernel,
        )
        x = x + a
        if cfg.moe is None:
            f = L.gelu_mlp if cfg.mlp_kind == "gelu" else L.swiglu
            x = x + f(lp["mlp"], _norm(cfg, lp["ln2"], x))
        else:
            from .moe import moe_apply

            x = x + moe_apply(lp["mlp"], cfg, _norm(cfg, lp["ln2"], x))
        return (x, cache_len), (kc, vc)

    if cfg.unroll_layers:
        k_list, v_list = [], []
        carry = (x, cache["len"])
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a: a[i], (params["layers"], cache["k"], cache["v"]))
            carry, (kc, vc) = body(carry, sl)
            k_list.append(kc)
            v_list.append(vc)
        x, _ = carry
        k_new = jnp.stack(k_list)
        v_new = jnp.stack(v_list)
    else:
        (x, _), (k_new, v_new) = jax.lax.scan(
            body, (x, cache["len"]), (params["layers"], cache["k"], cache["v"])
        )
    x = _norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["embed"], x)[:, 0]
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    return logits, new_cache


def prefill(params, cfg: LMConfig, tokens: jax.Array):
    """Prefill logits for a full prompt (the ``prefill_*`` shapes)."""
    return forward(params, cfg, tokens)
