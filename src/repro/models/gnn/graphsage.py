"""GraphSAGE (arXiv:1706.02216): graphsage-reddit config.

Two regimes, matching the assigned shapes:
  * full-graph (``full_graph_sm``/``ogb_products``): mean aggregation by
    segment-sum over the whole edge set;
  * sampled minibatch (``minibatch_lg``): fixed-fanout neighbor tensors
    (B, S1, d), (B, S1, S2, d) from data/sampler.py, aggregated with the
    fanout Pallas kernel — the real neighbor sampler feeds this.

W_self / W_neigh concatenation form, per the paper.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from .. import layers as L
from .common import GraphBatch, aggregate


def init(key, d_in: int, d_hidden: int, n_classes: int, n_layers: int = 2) -> Dict[str, Any]:
    dims = [d_hidden] * (n_layers - 1) + [n_classes]
    layers = []
    d_prev = d_in
    for i, d in enumerate(dims):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        layers.append(
            {
                "w_self": L._normal(k1, (d_prev, d), d_prev ** -0.5, jnp.float32),
                "w_neigh": L._normal(k2, (d_prev, d), d_prev ** -0.5, jnp.float32),
            }
        )
        d_prev = d
    return {"layers": layers}


def forward_full(params, batch: GraphBatch) -> jax.Array:
    """Full-graph forward: mean-aggregate all neighbors each layer."""
    h = batch.x
    n_layers = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        msg = h[batch.src]
        agg = aggregate(msg, batch.dst, batch.n_nodes, "mean", batch.edge_mask)
        h = jnp.einsum("nd,df->nf", h, lp["w_self"]) + jnp.einsum(
            "nd,df->nf", agg, lp["w_neigh"]
        )
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def forward_sampled(params, x_self: jax.Array, neigh_feats: Sequence[jax.Array],
                    neigh_masks: Sequence[jax.Array], use_kernel: bool = False) -> jax.Array:
    """Sampled minibatch forward (2-layer case).

    x_self: (B, d); neigh_feats = [(B, S1, d), (B, S1, S2, d)];
    neigh_masks = [(B, S1), (B, S1, S2)].
    """
    assert len(params["layers"]) == 2, "sampled path implements 2 hops"
    l1, l2 = params["layers"]

    def agg_mean(f, m):
        if use_kernel:
            from repro.kernels import ops as kops

            flat_f = f.reshape((-1,) + f.shape[-2:])
            flat_m = m.reshape((-1, m.shape[-1]))
            out = kops.fanout_aggregate(flat_f, flat_m.astype(jnp.float32), "mean")
            return out.reshape(f.shape[:-2] + (f.shape[-1],))
        mm = m[..., None].astype(f.dtype)
        return (f * mm).sum(-2) / jnp.maximum(mm.sum(-2), 1.0)

    # layer 1 applied at depth-1 nodes: aggregate their (depth-2) neighbors
    agg2 = agg_mean(neigh_feats[1], neigh_masks[1])  # (B, S1, d)
    h1 = jnp.einsum("bsd,df->bsf", neigh_feats[0], l1["w_self"]) + jnp.einsum(
        "bsd,df->bsf", agg2, l1["w_neigh"]
    )
    h1 = jax.nn.relu(h1)
    # layer 1 at the batch nodes themselves
    agg1_self = agg_mean(neigh_feats[0], neigh_masks[0])  # (B, d)
    h0 = jnp.einsum("bd,df->bf", x_self, l1["w_self"]) + jnp.einsum(
        "bd,df->bf", agg1_self, l1["w_neigh"]
    )
    h0 = jax.nn.relu(h0)
    # layer 2 at batch nodes: aggregate depth-1 hidden states
    agg_h1 = agg_mean(h1, neigh_masks[0])  # (B, f)
    return jnp.einsum("bf,fg->bg", h0, l2["w_self"]) + jnp.einsum(
        "bf,fg->bg", agg_h1, l2["w_neigh"]
    )


def loss_fn_full(params, batch: GraphBatch, labels, label_mask):
    logits = forward_full(params, batch)
    return L.cross_entropy(logits, labels, label_mask.astype(jnp.float32))


def loss_fn_sampled(params, x_self, neigh_feats, neigh_masks, labels):
    logits = forward_sampled(params, x_self, neigh_feats, neigh_masks)
    return L.cross_entropy(logits, labels)
