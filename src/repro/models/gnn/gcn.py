"""GCN (Kipf & Welling, arXiv:1609.02907): gcn-cora config.

Propagation: H' = sigma(D^-1/2 (A+I) D^-1/2 H W) — the SpMM regime.  Two
execution paths: segment-sum (default, any graph) and the block-dense
Pallas SpMM kernel (full-graph shapes on TPU).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .. import layers as L
from .common import GraphBatch, aggregate, sym_norm_coeff


def init(key, d_in: int, d_hidden: int, n_classes: int, n_layers: int = 2) -> Dict[str, Any]:
    dims = [d_hidden] * (n_layers - 1) + [n_classes]
    keys = jax.random.split(key, n_layers)
    ws: List[jax.Array] = []
    d_prev = d_in
    for k, d in zip(keys, dims):
        ws.append(L._normal(k, (d_prev, d), d_prev ** -0.5, jnp.float32))
        d_prev = d
    return {"ws": ws}


def forward(params, batch: GraphBatch, use_spmm_kernel: bool = False) -> jax.Array:
    h = batch.x
    coeff = sym_norm_coeff(batch)
    deg = None
    for i, w in enumerate(params["ws"]):
        h = jnp.einsum("nd,df->nf", h, w)
        msg = h[batch.src] * coeff[:, None]
        agg = aggregate(msg, batch.dst, batch.n_nodes, "sum", batch.edge_mask)
        # self loop with 1/deg normalization
        from .common import degrees

        if deg is None:
            deg = degrees(batch) + 1.0
        h = agg + h / deg[:, None]
        if i < len(params["ws"]) - 1:
            h = jax.nn.relu(h)
    return h


def loss_fn(params, batch: GraphBatch, labels: jax.Array, label_mask: jax.Array) -> jax.Array:
    logits = forward(params, batch)
    return L.cross_entropy(logits, labels, label_mask.astype(jnp.float32))
