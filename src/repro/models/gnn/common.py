"""Shared GNN substrate: GraphBatch + segment aggregation.

JAX has no native sparse message-passing (BCOO only) — aggregation IS
``jnp.take`` + ``jax.ops.segment_sum`` over an edge index, built here once
and reused by every GNN (kernel_taxonomy §GNN).  The edge arrays come
straight from the Aspen flat graph pool (core/flat_graph.py): a streaming
graph update produces a new GraphBatch by reusing the same (offsets,
keys) arrays — the paper's technique feeding the models.

Fixed shapes: edges are padded (mask carries validity) so one compiled
step serves a stream of graphs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class GraphBatch(NamedTuple):
    """A (possibly batched) graph in padded edge-list form."""

    x: jax.Array  # (N, d_feat) node features
    src: jax.Array  # (E,) int32 edge sources (padding -> N-1, masked)
    dst: jax.Array  # (E,) int32 edge destinations
    edge_mask: jax.Array  # (E,) bool
    node_mask: jax.Array  # (N,) bool
    edge_attr: Optional[jax.Array] = None  # (E, d_edge) e.g. distances
    graph_ids: Optional[jax.Array] = None  # (N,) for batched-small-graphs

    @property
    def n_nodes(self) -> int:
        return self.x.shape[0]

    @property
    def n_edges(self) -> int:
        return self.src.shape[0]


def aggregate(msg: jax.Array, dst: jax.Array, n: int, op: str = "sum",
              edge_mask: Optional[jax.Array] = None) -> jax.Array:
    """Segment-reduce messages to nodes: the message-passing primitive."""
    if edge_mask is not None:
        if op == "max":
            neg = jnp.finfo(msg.dtype).min
            msg = jnp.where(edge_mask[:, None], msg, neg)
        else:
            msg = msg * edge_mask[:, None].astype(msg.dtype)
    if op == "sum":
        return jax.ops.segment_sum(msg, dst, num_segments=n)
    if op == "mean":
        s = jax.ops.segment_sum(msg, dst, num_segments=n)
        ones = edge_mask.astype(msg.dtype) if edge_mask is not None else jnp.ones(dst.shape, msg.dtype)
        cnt = jax.ops.segment_sum(ones, dst, num_segments=n)
        return s / jnp.maximum(cnt[:, None], 1.0)
    if op == "max":
        return jax.ops.segment_max(msg, dst, num_segments=n)
    raise ValueError(op)


def degrees(batch: GraphBatch) -> jax.Array:
    ones = batch.edge_mask.astype(jnp.float32)
    return jax.ops.segment_sum(ones, batch.dst, num_segments=batch.n_nodes)


def sym_norm_coeff(batch: GraphBatch) -> jax.Array:
    """GCN symmetric normalization 1/sqrt(d_i d_j) per edge (+self loops
    handled by callers)."""
    deg = degrees(batch) + 1.0  # +1 for self loop
    inv_sqrt = jax.lax.rsqrt(deg)
    return inv_sqrt[batch.src] * inv_sqrt[batch.dst]


# ---------------------------------------------------------------------------
# host-side construction
# ---------------------------------------------------------------------------


def batch_from_edges(
    n: int,
    edges: np.ndarray,
    x: np.ndarray,
    edge_capacity: Optional[int] = None,
    edge_attr: Optional[np.ndarray] = None,
) -> GraphBatch:
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    E = e.shape[0]
    cap = edge_capacity or E
    src = np.full(cap, n - 1, dtype=np.int32)
    dst = np.full(cap, n - 1, dtype=np.int32)
    src[:E], dst[:E] = e[:, 0], e[:, 1]
    mask = np.zeros(cap, dtype=bool)
    mask[:E] = True
    ea = None
    if edge_attr is not None:
        ea_np = np.zeros((cap,) + edge_attr.shape[1:], dtype=np.float32)
        ea_np[:E] = edge_attr
        ea = jnp.asarray(ea_np)
    return GraphBatch(
        x=jnp.asarray(x, jnp.float32),
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        edge_mask=jnp.asarray(mask),
        node_mask=jnp.ones((n,), bool),
        edge_attr=ea,
    )


def batch_from_flat_graph(g, x: jax.Array) -> GraphBatch:
    """Zero-copy view of an Aspen flat graph as a GraphBatch: the
    streaming store feeds the GNN directly (the paper's technique as the
    framework's data plane)."""
    from repro.core import flat_graph as fg

    src, dst = fg.unpack(g.keys)
    n = g.n
    valid = jnp.arange(g.keys.shape[0]) < g.m
    return GraphBatch(
        x=x,
        src=jnp.where(valid, src, n - 1).astype(jnp.int32),
        dst=jnp.where(valid, dst, n - 1).astype(jnp.int32),
        edge_mask=valid,
        node_mask=jnp.ones((n,), bool),
    )


def random_batch(key, n: int, e: int, d_feat: int, batched: int = 0) -> GraphBatch:
    """Synthetic graph for smoke tests/benchmarks."""
    k1, k2, k3 = jax.random.split(key, 3)
    src = jax.random.randint(k1, (e,), 0, n, jnp.int32)
    dst = jax.random.randint(k2, (e,), 0, n, jnp.int32)
    x = jax.random.normal(k3, (n, d_feat), jnp.float32)
    gid = None
    if batched:
        gid = jnp.arange(n) // (n // batched)
    return GraphBatch(
        x=x, src=src, dst=dst,
        edge_mask=jnp.ones((e,), bool), node_mask=jnp.ones((n,), bool),
        graph_ids=gid,
    )
