"""SchNet (arXiv:1706.08566): continuous-filter convolutions.

schnet config: n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.
Edges carry interatomic distances; filters are MLPs over a Gaussian RBF
expansion; messages are elementwise-filtered neighbor states — the
triplet-free molecular regime (kernel_taxonomy §GNN).

The molecule shape batches many small graphs: graph_ids drive a final
segment-sum readout per molecule.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .. import layers as L
from .common import GraphBatch, aggregate


def rbf_expand(dist: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Gaussian radial basis: (E,) -> (E, n_rbf)."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


def init(key, d_in: int, d_hidden: int = 64, n_interactions: int = 3,
         n_rbf: int = 300, n_out: int = 1) -> Dict[str, Any]:
    keys = jax.random.split(key, n_interactions + 2)
    p: Dict[str, Any] = {
        "embed": L.mlp_init(keys[0], d_in, [d_hidden], jnp.float32),
        "interactions": [],
    }
    for i in range(n_interactions):
        k1, k2, k3 = jax.random.split(keys[i + 1], 3)
        p["interactions"].append(
            {
                "filter": L.mlp_init(k1, n_rbf, [d_hidden, d_hidden], jnp.float32),
                "in_proj": L.mlp_init(k2, d_hidden, [d_hidden], jnp.float32, bias=False),
                "out_proj": L.mlp_init(k3, d_hidden, [d_hidden, d_hidden], jnp.float32),
            }
        )
    p["readout"] = L.mlp_init(keys[-1], d_hidden, [d_hidden // 2, n_out], jnp.float32)
    return p


def forward(params, batch: GraphBatch, cutoff: float = 10.0) -> jax.Array:
    """Returns per-molecule predictions (n_graphs, n_out) if graph_ids
    given, else a global readout (1, n_out)."""
    assert batch.edge_attr is not None, "SchNet needs distances in edge_attr"
    dist = batch.edge_attr[..., 0]
    h = L.mlp(params["embed"], batch.x, act=shifted_softplus)
    # n_rbf is structural: the filter MLP's input width
    n_rbf = params["interactions"][0]["filter"]["ws"][0].shape[0]
    rbf = rbf_expand(dist, n_rbf, cutoff)
    # smooth cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cutoff, 0, 1)) + 1.0)
    for ip in params["interactions"]:
        w = L.mlp(ip["filter"], rbf, act=shifted_softplus) * env[:, None]
        hin = L.mlp(ip["in_proj"], h)
        msg = hin[batch.src] * w  # continuous-filter conv
        agg = aggregate(msg, batch.dst, batch.n_nodes, "sum", batch.edge_mask)
        h = h + L.mlp(ip["out_proj"], agg, act=shifted_softplus)
    # per-atom outputs; molecule readout via readout_per_molecule (the
    # molecule count is static, supplied by the caller)
    return L.mlp(params["readout"], h, act=shifted_softplus)


def readout_per_molecule(atom_out: jax.Array, graph_ids: jax.Array, n_graphs: int,
                         node_mask: jax.Array) -> jax.Array:
    m = node_mask[:, None].astype(atom_out.dtype)
    return jax.ops.segment_sum(atom_out * m, graph_ids, num_segments=n_graphs)


def loss_fn(params, batch: GraphBatch, targets: jax.Array, n_graphs: int) -> jax.Array:
    atom_out = forward(params, batch)
    pred = readout_per_molecule(atom_out, batch.graph_ids, n_graphs, batch.node_mask)
    return jnp.mean((pred[:, 0] - targets) ** 2)
