"""GraphCast-style encoder-processor-decoder mesh GNN (arXiv:2212.12794).

graphcast config: 16 processor layers, d_hidden=512, sum aggregation,
n_vars=227 input channels, mesh_refinement=6.

For its own (weather) configuration the model runs on an icosahedral
multimesh (built by ``build_multimesh``); for the assigned generic graph
shapes the encoder/processor/decoder run over the given GraphBatch (the
mesh IS the input graph) — the architecture is the interaction-network
stack either way.  Edge and node update MLPs with residuals, LayerNorm
as in the paper.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .. import layers as L
from .common import GraphBatch, aggregate


def _mlp2(key, d_in: int, d_hidden: int, d_out: int) -> Dict[str, Any]:
    return L.mlp_init(key, d_in, [d_hidden, d_out], jnp.float32)


def init(key, d_in: int, d_hidden: int = 512, n_layers: int = 16, d_out: int = 227,
         d_edge_in: int = 4) -> Dict[str, Any]:
    keys = jax.random.split(key, n_layers + 3)
    p: Dict[str, Any] = {
        "enc_node": _mlp2(keys[0], d_in, d_hidden, d_hidden),
        "enc_edge": _mlp2(keys[1], d_edge_in, d_hidden, d_hidden),
        "layers": [],
        "dec": _mlp2(keys[2], d_hidden, d_hidden, d_out),
    }
    for i in range(n_layers):
        k1, k2 = jax.random.split(keys[i + 3])
        p["layers"].append(
            {
                # edge MLP([e, h_src, h_dst]); node MLP([h, agg_e])
                "edge": _mlp2(k1, 3 * d_hidden, d_hidden, d_hidden),
                "node": _mlp2(k2, 2 * d_hidden, d_hidden, d_hidden),
                "ln_e": L.layernorm_init(d_hidden),
                "ln_n": L.layernorm_init(d_hidden),
            }
        )
    return p


def forward(params, batch: GraphBatch) -> jax.Array:
    n = batch.n_nodes
    h = L.mlp(params["enc_node"], batch.x, act=jax.nn.silu)
    if batch.edge_attr is not None:
        e = L.mlp(params["enc_edge"], batch.edge_attr, act=jax.nn.silu)
    else:
        # structural edge features: normalized degree difference
        from .common import degrees

        deg = degrees(batch)
        ea = jnp.stack(
            [
                deg[batch.src],
                deg[batch.dst],
                deg[batch.src] - deg[batch.dst],
                jnp.ones_like(deg[batch.src]),
            ],
            axis=-1,
        )
        e = L.mlp(params["enc_edge"], ea / (1.0 + jnp.abs(ea)), act=jax.nn.silu)
    for lp in params["layers"]:
        # edge update (interaction network)
        e_in = jnp.concatenate([e, h[batch.src], h[batch.dst]], axis=-1)
        e = e + L.layernorm(lp["ln_e"], L.mlp(lp["edge"], e_in, act=jax.nn.silu))
        # node update
        agg = aggregate(e, batch.dst, n, "sum", batch.edge_mask)
        n_in = jnp.concatenate([h, agg], axis=-1)
        h = h + L.layernorm(lp["ln_n"], L.mlp(lp["node"], n_in, act=jax.nn.silu))
    return L.mlp(params["dec"], h)


def loss_fn(params, batch: GraphBatch, targets: jax.Array) -> jax.Array:
    pred = forward(params, batch)
    m = batch.node_mask[:, None].astype(pred.dtype)
    return jnp.sum(((pred - targets) * m) ** 2) / jnp.maximum(m.sum(), 1.0)


# ---------------------------------------------------------------------------
# icosahedral multimesh (the model's own configuration)
# ---------------------------------------------------------------------------


def build_multimesh(refinement: int) -> np.ndarray:
    """Icosahedron refined ``refinement`` times; returns the multimesh
    edge list (union of all refinement levels' edges, both directions).

    Nodes at level r: 10*4^r + 2.  The multimesh keeps coarse edges
    alongside fine ones (GraphCast §3.2).
    """
    phi = (1 + 5 ** 0.5) / 2
    verts = np.array(
        [
            [-1, phi, 0], [1, phi, 0], [-1, -phi, 0], [1, -phi, 0],
            [0, -1, phi], [0, 1, phi], [0, -1, -phi], [0, 1, -phi],
            [phi, 0, -1], [phi, 0, 1], [-phi, 0, -1], [-phi, 0, 1],
        ],
        dtype=np.float64,
    )
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = np.array(
        [
            [0, 11, 5], [0, 5, 1], [0, 1, 7], [0, 7, 10], [0, 10, 11],
            [1, 5, 9], [5, 11, 4], [11, 10, 2], [10, 7, 6], [7, 1, 8],
            [3, 9, 4], [3, 4, 2], [3, 2, 6], [3, 6, 8], [3, 8, 9],
            [4, 9, 5], [2, 4, 11], [6, 2, 10], [8, 6, 7], [9, 8, 1],
        ]
    )
    all_edges = []

    def face_edges(fs):
        e = np.concatenate([fs[:, [0, 1]], fs[:, [1, 2]], fs[:, [2, 0]]])
        return e

    all_edges.append(face_edges(faces))
    vlist = [v for v in verts]
    for _ in range(refinement):
        new_faces = []
        midpoint_cache: Dict = {}

        def midpoint(i, j):
            key = (min(i, j), max(i, j))
            if key not in midpoint_cache:
                m = vlist[i] + vlist[j]
                vlist.append(m / np.linalg.norm(m))
                midpoint_cache[key] = len(vlist) - 1
            return midpoint_cache[key]

        for a, b, c in faces:
            ab, bc, ca = midpoint(a, b), midpoint(b, c), midpoint(c, a)
            new_faces += [[a, ab, ca], [ab, b, bc], [ca, bc, c], [ab, bc, ca]]
        faces = np.asarray(new_faces)
        all_edges.append(face_edges(faces))
    e = np.concatenate(all_edges)
    e = np.concatenate([e, e[:, ::-1]])
    keys = np.unique((e[:, 0].astype(np.int64) << 32) | e[:, 1].astype(np.int64))
    return np.stack([keys >> 32, keys & 0xFFFFFFFF], axis=1)
