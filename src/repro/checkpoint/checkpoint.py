"""Sharded, async, resharding-safe checkpoints with atomic commit.

Layout (one directory per step):
    <dir>/step_000123.tmp/          # written first
        manifest.json               # pytree structure + specs + shapes
        arr_00000.npy ...           # one file per leaf (logical, unsharded)
    <dir>/step_000123/              # atomic rename on completion
        ... + COMMITTED             # marker file: restore ignores uncommitted

Arrays are saved *logically* (fully assembled) with their PartitionSpecs
recorded in the manifest; restore re-shards onto whatever mesh is current
— this is what makes restarts ELASTIC: a checkpoint from a (16, 16) mesh
restores onto (8, 16) or (2, 16, 16) unchanged (test_fault_tolerance).

Async: `save_async` snapshots device arrays to host (jax.device_get — a
consistent cut) and writes on a background thread so the train loop
continues; `wait()` joins before the next save or exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree: Any, specs: Optional[Any] = None) -> str:
    """Synchronous checkpoint write with atomic commit."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = jax.device_get(leaves)
    spec_list: List[Optional[List]] = [None] * len(leaves)
    if specs is not None:
        spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        if len(spec_leaves) == len(leaves):
            spec_list = [list(s) if isinstance(s, P) else None for s in spec_leaves]
    manifest = {"step": step, "leaves": []}
    for i, (path, arr) in enumerate(zip(paths, host_leaves)):
        arr = np.asarray(arr)
        fn = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {
                "path": path,
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "spec": spec_list[i],
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # atomic commit: marker then rename
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-on-device-get + background write; at most one in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved: List[str] = []

    def save_async(self, step: int, tree: Any, specs: Optional[Any] = None):
        self.wait()
        # consistent cut NOW (device_get blocks until values ready)
        paths, leaves, treedef = _flatten_with_paths(tree)
        host = jax.device_get(leaves)
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            p = save(self.directory, step, snapshot, specs)
            self.saved.append(p)
            self._gc()

        self._thread = threading.Thread(target=work)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(list_steps(self.directory))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        full = os.path.join(directory, d)
        if d.startswith("step_") and not d.endswith(".tmp") and os.path.exists(
            os.path.join(full, "COMMITTED")
        ):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(
    directory: str,
    step: Optional[int] = None,
    mesh=None,
    target_specs: Optional[Any] = None,
    template: Optional[Any] = None,
) -> Tuple[int, Any]:
    """Load a committed checkpoint; re-shard onto `mesh` if given.

    If `template` (a pytree with the same structure) is provided, the
    result is unflattened into that structure; otherwise a flat
    path->array dict is returned.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: Dict[str, np.ndarray] = {}
    specs: Dict[str, Optional[P]] = {}
    for leaf in manifest["leaves"]:
        arr = np.load(os.path.join(d, leaf["file"]))
        arrays[leaf["path"]] = arr
        specs[leaf["path"]] = P(*leaf["spec"]) if leaf["spec"] is not None else None
    if template is not None:
        paths, leaves, treedef = _flatten_with_paths(template)
        ordered = [arrays[p] for p in paths]
        if mesh is not None:
            spec_leaves = (
                jax.tree.leaves(target_specs, is_leaf=lambda x: isinstance(x, P))
                if target_specs is not None
                else [specs[p] or P() for p in paths]
            )
            ordered = [
                jax.device_put(a, NamedSharding(mesh, s or P()))
                for a, s in zip(ordered, spec_leaves)
            ]
        return step, jax.tree_util.tree_unflatten(treedef, ordered)
    return step, arrays
