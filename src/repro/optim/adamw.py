"""AdamW + gradient clipping + WSD schedule (pure pytree transforms).

ZeRO-1: the optimizer state is a pytree with the same structure as the
params, so sharding it over the "data" axis is purely a PartitionSpec
choice (dist/shardings.zero1_specs) — no optimizer code changes.  States
are kept in f32 regardless of param dtype (mixed-precision master
weights live in the m/v moments' dtype policy).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any  # first moment (f32 pytree)
    v: Any  # second moment (f32 pytree)


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(
    state: AdamWState,
    grads,
    params,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)


def wsd_schedule(warmup: int, stable: int, decay: int, peak_lr: float, floor: float = 0.1):
    """Warmup-Stable-Decay: the production LR schedule."""

    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        total = warmup + stable
        frac = jnp.clip((s - total) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = peak_lr * (1.0 - (1.0 - floor) * frac)
        return jnp.where(s < total, warm, dec)

    return lr
