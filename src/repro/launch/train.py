"""Training launcher: end-to-end driver over any registered arch.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Runs on whatever devices exist (1 CPU here, a pod elsewhere): the mesh
folds to (1, 1) locally.  Checkpoint/restore, deterministic data, and
straggler/heartbeat hooks are all wired; on a real fleet the same script
runs under multi-host jax.distributed initialization.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import recsys_batch, token_batch
from repro.dist.fault_tolerance import ResumableRun
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import train_step as TS


def make_lm_run(cfg, args):
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg, dtype=jnp.float32)
    step_fn = jax.jit(
        TS.make_train_step(
            TS.lm_loss(cfg),
            adamw.wsd_schedule(args.warmup, args.steps, max(args.steps // 10, 1), args.lr),
            n_micro=args.n_micro,
        )
    )

    def batch_fn(step):
        b = token_batch(args.seed, step, args.batch, args.seq, cfg.vocab)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return params, step_fn, batch_fn


def make_dcn_run(cfg, args):
    from repro.models.recsys import dcn_v2

    params = dcn_v2.init(
        jax.random.PRNGKey(args.seed), n_dense=cfg.n_dense, n_sparse=cfg.n_sparse,
        embed_dim=cfg.embed_dim, vocab_per_field=cfg.vocab_per_field,
        n_cross=cfg.n_cross, mlp_dims=cfg.mlp_dims,
    )
    step_fn = jax.jit(
        TS.make_train_step(
            TS.dcn_loss(), adamw.wsd_schedule(args.warmup, args.steps, 10, args.lr)
        )
    )

    def batch_fn(step):
        b = recsys_batch(args.seed, step, args.batch, cfg.n_dense, cfg.n_sparse,
                         cfg.vocab_per_field)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return params, step_fn, batch_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.reduced if args.reduced else spec.full
    if spec.family == "lm":
        params, step_fn, batch_fn = make_lm_run(cfg, args)
    elif spec.family == "recsys":
        params, step_fn, batch_fn = make_dcn_run(cfg, args)
    else:
        raise SystemExit(
            f"--arch {args.arch}: use examples/train_gnn.py for the GNN family"
        )

    start_step = 0
    state = TS.init_state(params)
    run = None
    if args.ckpt_dir:
        run = ResumableRun(
            args.ckpt_dir, make_state=lambda: TS.init_state(params),
            save_every=args.ckpt_every,
        )
        start_step, state = run.restore_or_init()
        if start_step:
            print(f"[restore] resumed from step {start_step}")

    t0 = time.time()
    for step in range(start_step, args.steps):
        state, metrics = step_fn(state, batch_fn(step))
        if step % args.log_every == 0:
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e} "
                f"({(time.time() - t0) / max(step - start_step + 1, 1):.3f} s/step)"
            )
        if run is not None:
            run.maybe_save(step, state)
    if run is not None:
        run.finish()
    print(f"done: {args.steps - start_step} steps in {time.time() - t0:.1f}s; "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
