"""HLO text analysis: collective-byte accounting (no jax side effects).

Separated from dryrun.py so tests and tools can import the parsers
without inheriting dryrun's 512-placeholder-device XLA_FLAGS.
"""
import re

# HLO ops whose operand bytes cross links
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?"
    r"((?:\w+\[[^\]]*\]|\([^)]*\))\{?[^=]*)?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b",
    re.M,
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _bytes_of_shape_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the compiled HLO
    (post-SPMD: shapes are per-device shards).  Returns (total, per-kind)."""
    per_kind = {}
    total = 0
    for line in hlo_text.splitlines():
        m = re.search(
            r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
            line,
        )
        if not m or "-start" in line and False:
            continue
        kind = m.group(1)
        # result shape: text before the '=' sign
        lhs = line.split("=")[0]
        b = _bytes_of_shape_str(lhs)
        if b == 0:  # fallback: first shape on the line
            b = _bytes_of_shape_str(line)
        total += b
        per_kind[kind] = per_kind.get(kind, 0) + b
    return total, per_kind


