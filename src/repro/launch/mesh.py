"""Production mesh factory (per the multi-pod dry-run contract).

A FUNCTION, not a module constant: importing this module never touches
jax device state.  Single pod: (16, 16) = 256 chips ("data", "model");
multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model") — the pod
axis carries pure DP (one gradient all-reduce crosses the DCI), model
parallelism stays inside a pod's ICI domain.
"""
from __future__ import annotations

import jax

# TPU v5e hardware model (per chip) — the roofline constants.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s per link
ICI_LINKS = 4  # torus links per chip usable concurrently


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the same axis names (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
