"""Cell builders: (architecture x input-shape) -> lowerable step.

Each cell yields:
  * ``step_fn``      — the jax function the shape dictates (train_step,
                       prefill, serve_step, GNN train, recsys serve, ...)
  * ``args``         — abstract inputs (ShapeDtypeStruct pytree; nothing
                       is ever allocated: params come from eval_shape)
  * ``in_shardings`` / ``out_shardings`` — NamedSharding pytrees
  * ``meta``         — MODEL_FLOPS & friends for the roofline report.

Padding policy: dynamic dims (edge counts, node counts) are padded to
multiples of 512 so every mesh in play (16 / 256 / 512 devices) divides
them evenly; padding is masked (GraphBatch.edge_mask etc.).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.dist import shardings as SH
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.models.gnn.common import GraphBatch
from repro.optim import adamw
from repro.train import train_step as TS


class Cell(NamedTuple):
    step_fn: Callable
    args: Tuple
    in_shardings: Any
    out_shardings: Any  # may be None (compiler-chosen)
    meta: Dict[str, Any]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _named(mesh, tree):
    return SH.named(mesh, tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_state_specs(cfg, mesh, params_shape):
    p_specs = SH.spec_tree_like(SH.lm_param_specs(cfg, mesh), params_shape)
    z_m = SH.zero1_specs(p_specs, params_shape, mesh)
    z_v = SH.zero1_specs(p_specs, params_shape, mesh)
    return TS.TrainState(p_specs, adamw.AdamWState(P(), z_m, z_v))


def _lm_mem_estimate(cfg, mesh, B, S, kind: str) -> Dict[str, float]:
    """Analytic per-device memory model for TPU v5e (bytes).

    The CPU-backend buffer assignment cannot reflect TPU fusion/remat, so
    the fits-on-chip proof uses this model (recorded next to the raw CPU
    number in EXPERIMENTS.md §Dry-run; formulas below are standard
    accounting — params/grads/opt exact, activations = remat-saved
    residuals + one layer's transient working set).
    """
    n_model = mesh.shape["model"]
    n_data = int(np.prod([v for k, v in mesh.shape.items() if k != "model"]))
    P_total = cfg.param_count()
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    # params: embed shards over model (vocab), mlp/moe shard over model;
    # attn shards only when heads divide — approximate with the exact
    # replicated-attn correction.
    h_div = cfg.n_heads % n_model == 0
    attn_p = L * (d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                  + cfg.n_heads * cfg.head_dim * d)
    sharded_p = P_total - (0 if h_div else attn_p)
    p_dev = (sharded_p / n_model + (0 if h_div else attn_p)) * 2  # bf16
    if kind == "train":
        g_dev = p_dev * 2  # f32 grads, same sharding
        o_dev = (sharded_p / n_model + (0 if h_div else attn_p)) / max(n_data, 1) * 8
        toks_dev = B * S / n_data
        resid = L * toks_dev * d * 2  # remat=full: one bf16 residual/layer
        logits = toks_dev * V / n_model * 4
        transient = toks_dev * max(3 * cfg.d_ff / n_model, 4 * d) * 4
        total = p_dev + g_dev + o_dev + resid + logits + transient
        parts = dict(params=p_dev, grads=g_dev, opt=o_dev, resid=resid,
                     logits=logits, transient=transient)
    else:
        toks_dev = B * S / n_data if kind == "prefill" else B / n_data
        kv = 2 * L * B * S * cfg.n_kv_heads * cfg.head_dim * 2  # bf16 k+v
        kv_dev = kv / (n_data * n_model) if kind == "decode" else 0
        act = toks_dev * d * 2 * 4
        logits = (B / max(n_data, 1)) * V / n_model * 4
        total = p_dev + kv_dev + act + logits
        parts = dict(params=p_dev, kv=kv_dev, act=act, logits=logits)
    parts["total"] = total
    return {k: float(v) for k, v in parts.items()}


def _lm_train_cell(cfg, shape, mesh, remat: Optional[str] = None,
                   n_micro: int = 1, unroll: bool = False) -> Cell:
    B, S = shape["global_batch"], shape["seq_len"]
    cfg = dataclasses.replace(
        cfg, unroll_layers=unroll, remat=remat if remat is not None else "full"
    )
    params_shape = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    state_shape = jax.eval_shape(TS.init_state, params_shape)
    state_specs = _lm_state_specs(cfg, mesh, params_shape)
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    b_specs = SH.lm_data_specs(mesh)
    step = TS.make_train_step(
        TS.lm_loss(cfg), adamw.wsd_schedule(100, 10_000, 1_000, 3e-4),
        n_micro=n_micro,
    )
    tokens = B * S
    n_active = cfg.active_param_count()
    meta = {
        "model_flops": 6.0 * n_active * tokens,
        "tokens": tokens,
        "params": cfg.param_count(),
        "active_params": n_active,
        "kind": "train",
        "n_layers": cfg.n_layers,
        "mem_model": _lm_mem_estimate(cfg, mesh, B, S, "train"),
    }
    metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
    return Cell(
        step, (state_shape, batch),
        _named(mesh, (state_specs, b_specs)),
        _named(mesh, (state_specs, metrics_specs)),
        meta,
    )


def _lm_prefill_cell(cfg, shape, mesh, unroll: bool = False) -> Cell:
    from repro.serve import decode as SD

    B, S = shape["global_batch"], shape["seq_len"]
    cfg = dataclasses.replace(cfg, unroll_layers=unroll)
    params_shape = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = SH.spec_tree_like(SH.lm_param_specs(cfg, mesh), params_shape)
    tokens = _sds((B, S), jnp.int32)
    step = SD.make_prefill(cfg)
    meta = {
        "model_flops": 2.0 * cfg.active_param_count() * B * S,
        "tokens": B * S,
        "params": cfg.param_count(),
        "kind": "prefill",
        "n_layers": cfg.n_layers,
        "mem_model": _lm_mem_estimate(cfg, mesh, B, S, "prefill"),
    }
    return Cell(
        step, (params_shape, tokens),
        _named(mesh, (p_specs, P(SH.batch_axes(mesh), None))),
        None,
        meta,
    )


def _lm_decode_cell(cfg, shape, mesh, seq_axes=("model",), unroll: bool = False) -> Cell:
    from repro.serve import decode as SD

    B, S = shape["global_batch"], shape["seq_len"]
    cfg = dataclasses.replace(cfg, unroll_layers=unroll)
    params_shape = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = SH.spec_tree_like(SH.lm_param_specs(cfg, mesh), params_shape)
    cache_shape = jax.eval_shape(lambda: T.init_kv_cache(cfg, B, S))
    # sequence-shard the cache when kv heads don't divide the model axis,
    # and always for the long-context single-sequence shape
    kv_div = cfg.n_kv_heads % mesh.shape["model"] == 0
    seq_shard = (not kv_div) or (B == 1)
    cache_specs = SH.lm_cache_specs(
        cfg, mesh, seq_shard=seq_shard, batch_size=B, seq_axes=seq_axes
    )
    token = _sds((B,), jnp.int32)
    step = SD.make_serve_step(cfg)
    meta = {
        "model_flops": 2.0 * cfg.active_param_count() * B,
        "tokens": B,
        "params": cfg.param_count(),
        "kv_bytes": int(np.prod(cache_shape["k"].shape)) * 2 * 2,
        "kind": "decode",
        "seq_shard": seq_shard,
        "n_layers": cfg.n_layers,
        "mem_model": _lm_mem_estimate(cfg, mesh, B, S, "decode"),
    }
    b = SH.batch_axes(mesh)
    b_tok = b if B % int(np.prod([mesh.shape[a] for a in b])) == 0 else None
    return Cell(
        step, (params_shape, cache_shape, token),
        _named(mesh, (p_specs, cache_specs, P(b_tok))),
        None,
        meta,
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_init(cfg: registry.GNNConfig, d_feat: int):
    key = jax.random.PRNGKey(0)
    if cfg.kind == "gcn":
        from repro.models.gnn import gcn

        return lambda: gcn.init(key, d_feat, cfg.d_hidden, cfg.n_classes, cfg.n_layers)
    if cfg.kind == "graphsage":
        from repro.models.gnn import graphsage

        return lambda: graphsage.init(key, d_feat, cfg.d_hidden, cfg.n_classes, cfg.n_layers)
    if cfg.kind == "schnet":
        from repro.models.gnn import schnet

        return lambda: schnet.init(key, d_feat, cfg.d_hidden, cfg.n_layers, cfg.n_rbf)
    if cfg.kind == "graphcast":
        from repro.models.gnn import graphcast

        return lambda: graphcast.init(key, d_feat, cfg.d_hidden, cfg.n_layers, cfg.n_classes)
    raise ValueError(cfg.kind)


def _gnn_loss(cfg: registry.GNNConfig, n_graphs: int = 0):
    if cfg.kind == "gcn":
        return TS.gcn_loss(None)
    if cfg.kind == "graphsage":
        return TS.sage_full_loss()
    if cfg.kind == "schnet":
        return TS.schnet_loss(n_graphs)
    if cfg.kind == "graphcast":
        return TS.graphcast_loss()
    raise ValueError(cfg.kind)


def _gnn_flops(cfg: registry.GNNConfig, n: int, e: int, d_feat: int) -> float:
    """Matmul-dominated estimate (forward): node transforms + edge MLPs."""
    d = cfg.d_hidden
    if cfg.kind == "gcn":
        f = 2 * n * d_feat * d + (cfg.n_layers - 1) * 2 * n * d * d + 2 * e * d
    elif cfg.kind == "graphsage":
        f = cfg.n_layers * (4 * n * d * d) + 2 * n * d_feat * d + 2 * e * d
    elif cfg.kind == "schnet":
        # filter MLP per edge (rbf->d->d) + node projections
        f = cfg.n_layers * (2 * e * (cfg.n_rbf * d + d * d) + 4 * n * d * d)
    else:  # graphcast: edge MLP(3d->d->d) + node MLP(2d->d->d) per layer
        f = cfg.n_layers * (2 * e * (3 * d * d + d * d) + 2 * n * (2 * d * d + d * d))
        f += 2 * n * (d_feat * d + d * cfg.n_classes)
    return float(f)


def _gnn_batch_abstract(n: int, e: int, d_feat: int, with_dist: bool,
                        batched: int = 0) -> GraphBatch:
    return GraphBatch(
        x=_sds((n, d_feat), jnp.float32),
        src=_sds((e,), jnp.int32),
        dst=_sds((e,), jnp.int32),
        edge_mask=_sds((e,), jnp.bool_),
        node_mask=_sds((n,), jnp.bool_),
        edge_attr=_sds((e, 1), jnp.float32) if with_dist else None,
        graph_ids=_sds((n,), jnp.int32) if batched else None,
    )


def _gnn_cell(cfg: registry.GNNConfig, shape, mesh, arch_id: str) -> Cell:
    kind = shape["kind"]
    if kind == "sampled" and cfg.kind == "graphsage":
        return _sage_sampled_cell(cfg, shape, mesh)
    d_feat = shape["d_feat"]
    if kind == "sampled":
        # non-sampling archs: train on the sampler-induced padded subgraph
        bn = shape["batch_nodes"]
        f1, f2 = shape["fanout"]
        n = _pad_to(bn * (1 + f1 + f1 * f2), 512)
        e = _pad_to(bn * (f1 + f1 * f2), 512)
        batched = 0
    elif kind == "batched_small":
        bsz = shape["batch"]
        n = _pad_to(shape["n_nodes"] * bsz, 512)
        e = _pad_to(shape["n_edges"] * bsz, 512)
        batched = bsz
    else:
        n = _pad_to(shape["n_nodes"], 512)
        e = _pad_to(shape["n_edges"], 512)
        batched = 0

    with_dist = cfg.kind == "schnet"
    if cfg.kind == "schnet":
        batched = max(batched, 1)  # molecule readout needs graph_ids
    batch_abs = _gnn_batch_abstract(n, e, d_feat, with_dist, batched)
    params_shape = jax.eval_shape(_gnn_init(cfg, d_feat))
    state_shape = jax.eval_shape(TS.init_state, params_shape)
    p_specs = jax.tree.map(lambda _: P(), params_shape)
    state_specs = TS.TrainState(p_specs, adamw.AdamWState(P(), p_specs, p_specs))

    shard_nodes = kind == "full_large"
    g_specs_d = SH.gnn_batch_specs(mesh, shard_nodes=shard_nodes)
    node_p = g_specs_d["x"]
    g_specs = GraphBatch(
        x=g_specs_d["x"], src=g_specs_d["src"], dst=g_specs_d["dst"],
        edge_mask=g_specs_d["edge_mask"], node_mask=g_specs_d["node_mask"],
        edge_attr=g_specs_d["edge_attr"] if with_dist else None,
        graph_ids=g_specs_d["graph_ids"] if batched else None,
    )

    if cfg.kind == "schnet":
        batch = {"graph": batch_abs, "targets": _sds((batched or 1,), jnp.float32)}
        b_specs = {"graph": g_specs, "targets": P(None)}
        loss = TS.schnet_loss(batched or 1)
    elif cfg.kind == "graphcast":
        batch = {"graph": batch_abs, "targets": _sds((n, cfg.n_classes), jnp.float32)}
        b_specs = {"graph": g_specs, "targets": node_p}
        loss = TS.graphcast_loss()
    else:
        batch = {
            "graph": batch_abs,
            "labels": _sds((n,), jnp.int32),
            "label_mask": _sds((n,), jnp.bool_),
        }
        lbl_p = P("model") if shard_nodes else P(None)
        b_specs = {"graph": g_specs, "labels": lbl_p, "label_mask": lbl_p}
        loss = TS.gcn_loss(None) if cfg.kind == "gcn" else TS.sage_full_loss()

    step = TS.make_train_step(loss, adamw.wsd_schedule(100, 10_000, 1_000, 1e-3))
    meta = {
        "model_flops": 3.0 * _gnn_flops(cfg, n, e, d_feat),  # fwd+bwd ~ 3x fwd
        "n_nodes": n,
        "n_edges": e,
        "kind": f"train_{kind}",
    }
    metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
    return Cell(
        step, (state_shape, batch),
        _named(mesh, (state_specs, b_specs)),
        _named(mesh, (state_specs, metrics_specs)),
        meta,
    )


def _sage_sampled_cell(cfg, shape, mesh) -> Cell:
    bn = shape["batch_nodes"]
    f1, f2 = shape["fanout"]
    d = shape["d_feat"]
    params_shape = jax.eval_shape(_gnn_init(cfg, d))
    state_shape = jax.eval_shape(TS.init_state, params_shape)
    p_specs = jax.tree.map(lambda _: P(), params_shape)
    state_specs = TS.TrainState(p_specs, adamw.AdamWState(P(), p_specs, p_specs))
    batch = {
        "x_self": _sds((bn, d), jnp.float32),
        "neigh_feats": [_sds((bn, f1, d), jnp.float32), _sds((bn, f1, f2, d), jnp.float32)],
        "neigh_masks": [_sds((bn, f1), jnp.bool_), _sds((bn, f1, f2), jnp.bool_)],
        "labels": _sds((bn,), jnp.int32),
    }
    b_specs = SH.sage_sampled_specs(mesh)
    step = TS.make_train_step(TS.sage_sampled_loss(), adamw.wsd_schedule(100, 10_000, 1_000, 1e-3))
    dh = cfg.d_hidden
    fwd = bn * (1 + f1 + f1 * f2) * 2 * d * dh * 2 + bn * 2 * dh * cfg.n_classes
    meta = {"model_flops": 3.0 * fwd, "kind": "train_sampled", "batch_nodes": bn}
    metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
    return Cell(
        step, (state_shape, batch),
        _named(mesh, (state_specs, b_specs)),
        _named(mesh, (state_specs, metrics_specs)),
        meta,
    )


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------


def _dcn_cell(cfg: registry.DCNConfig, shape, mesh) -> Cell:
    from repro.models.recsys import dcn_v2

    kind = shape["kind"]
    B = shape["batch"]
    n_cand = shape.get("n_candidates", 0)
    init = lambda: dcn_v2.init(  # noqa: E731
        jax.random.PRNGKey(0),
        n_dense=cfg.n_dense, n_sparse=cfg.n_sparse, embed_dim=cfg.embed_dim,
        vocab_per_field=cfg.vocab_per_field, n_cross=cfg.n_cross,
        mlp_dims=cfg.mlp_dims, n_candidates=n_cand if kind == "retrieval" else 0,
    )
    params_shape = jax.eval_shape(init)
    p_specs = SH.dcn_param_specs(params_shape, mesh)
    b = SH.batch_axes(mesh)
    bspec = b if B % 512 == 0 or B % int(np.prod([mesh.shape[a] for a in b])) == 0 else None
    dense = _sds((B, cfg.n_dense), jnp.float32)
    sparse = _sds((B, cfg.n_sparse), jnp.int32)
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    # dense-path flops per example: cross (n_cross * d0^2) + MLP + embed
    mlp_f = 0
    dims = [d0] + list(cfg.mlp_dims)
    for a, bb in zip(dims[:-1], dims[1:]):
        mlp_f += 2 * a * bb
    per_ex = cfg.n_cross * 2 * d0 * d0 + mlp_f + 2 * (cfg.mlp_dims[-1] + d0)

    if kind == "train":
        state_shape = jax.eval_shape(TS.init_state, params_shape)
        z = SH.zero1_specs(p_specs, params_shape, mesh)
        state_specs = TS.TrainState(p_specs, adamw.AdamWState(P(), z, z))
        batch = {"dense": dense, "sparse_ids": sparse, "labels": _sds((B,), jnp.float32)}
        b_specs = {"dense": P(bspec, None), "sparse_ids": P(bspec, None), "labels": P(bspec)}
        step = TS.make_train_step(TS.dcn_loss(), adamw.wsd_schedule(100, 10_000, 1_000, 1e-3))
        metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
        meta = {"model_flops": 3.0 * per_ex * B, "batch": B, "kind": "train"}
        return Cell(
            step, (state_shape, batch),
            _named(mesh, (state_specs, b_specs)),
            _named(mesh, (state_specs, metrics_specs)),
            meta,
        )
    if kind == "serve":
        step = dcn_v2.serve
        meta = {"model_flops": per_ex * B, "batch": B, "kind": "serve"}
        return Cell(
            step, (params_shape, dense, sparse),
            _named(mesh, (p_specs, P(bspec, None), P(bspec, None))),
            None,
            meta,
        )
    # retrieval: 1 query x n_candidates
    step = partial(dcn_v2.retrieval, top_k=128)
    meta = {
        "model_flops": per_ex * B + 2.0 * n_cand * cfg.mlp_dims[-1],
        "batch": B,
        "kind": "retrieval",
    }
    return Cell(
        step, (params_shape, dense, sparse),
        _named(mesh, (p_specs, P(None, None), P(None, None))),
        None,
        meta,
    )


# ---------------------------------------------------------------------------
# aspen-stream cells (the paper's own configuration at scale)
# ---------------------------------------------------------------------------


def _stream_cell(cfg: registry.StreamConfig, shape, mesh, variant: str = "baseline") -> Cell:
    from repro.core import flat_ctree as fct
    from repro.core import flat_graph as fg

    kind = shape["kind"]
    cap = shape["pool_edges"]
    n = shape["n_nodes"]
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    if kind == "update" and variant == "shardmap":
        return _stream_update_shardmap_cell(shape, mesh, all_axes)
    if kind == "update" and variant == "overlay":
        return _stream_update_overlay_cell(shape, mesh, all_axes)
    g_abs = fg.FlatGraph(
        offsets=_sds((n + 1,), jnp.int32),
        keys=_sds((cap,), jnp.int64),
        m=_sds((), jnp.int32),
    )
    g_specs = fg.FlatGraph(offsets=P(None), keys=P(all_axes), m=P())
    if kind == "update":
        bcap = shape["batch_edges"]
        batch_abs = fct.FlatCTree(data=_sds((bcap,), jnp.int64), n=_sds((), jnp.int32))
        batch_specs = fct.FlatCTree(data=P(all_axes), n=P())
        step = partial(fg.insert_edges, out_cap=cap, optimized=True)
        meta = {
            "model_flops": 0.0,  # pure data movement: memory/collective-bound
            "pool_bytes": cap * 8,
            "batch_edges": bcap,
            "kind": "stream_update",
        }
        return Cell(
            step, (g_abs, batch_abs),
            _named(mesh, (g_specs, batch_specs)),
            _named(mesh, g_specs),
            meta,
        )
    if kind == "query":
        from repro.core.traversal.jax_backend import EngineAux, bfs_levels

        # the query cell consumes the version-pinned EngineAux (the
        # stream's mirror cache precomputes it once per version), so the
        # lowered program never re-derives the endpoint clipping per call
        aux_abs = EngineAux(
            src_c=_sds((cap,), jnp.int32),
            dst_c=_sds((cap,), jnp.int32),
            evalid=_sds((cap,), jnp.bool_),
            degrees=_sds((n,), jnp.int32),
            dst_sorted=_sds((cap,), jnp.int32),
            src_by_dst=_sds((cap,), jnp.int32),
            valid_by_dst=_sds((cap,), jnp.bool_),
            dst_offsets=_sds((n + 1,), jnp.int32),
        )
        aux_specs = EngineAux(
            src_c=P(all_axes),
            dst_c=P(all_axes),
            evalid=P(all_axes),
            degrees=P(None),
            dst_sorted=P(all_axes),
            src_by_dst=P(all_axes),
            valid_by_dst=P(all_axes),
            dst_offsets=P(None),
        )
        step = bfs_levels
        src = _sds((), jnp.int32)
        meta = {"model_flops": 0.0, "pool_bytes": cap * 8, "kind": "stream_bfs"}
        return Cell(
            step, (g_abs, src, aux_abs),
            _named(mesh, (g_specs, P(), aux_specs)),
            None,
            meta,
        )
    # decode_pool: delta-decode the compressed pool (jnp formulation — the
    # Pallas kernel is the single-chip version; this is the sharded one)
    def decode_step(deltas, anchors_at, head_mask):
        # segmented cumsum over the flat pool: cumsum(d) - carry(chunk)
        c = jnp.cumsum(deltas)
        chunk_id = jnp.cumsum(head_mask.astype(jnp.int64)) - head_mask.astype(jnp.int64)
        base = c - deltas  # exclusive cumsum
        # anchor-relative reconstruction: value = anchor[chunk] + (c - base_at_chunk_start)
        starts = jnp.where(head_mask, base, 0)
        per_chunk_base = jax.ops.segment_max(
            jnp.where(head_mask, base, -1), chunk_id, num_segments=deltas.shape[0]
        )
        return anchors_at[chunk_id] + (c - per_chunk_base[chunk_id])

    deltas = _sds((cap,), jnp.int64)
    anchors = _sds((cap,), jnp.int64)
    hm = _sds((cap,), jnp.bool_)
    meta = {"model_flops": 0.0, "pool_bytes": cap * 8, "kind": "stream_decode"}
    return Cell(
        decode_step, (deltas, anchors, hm),
        _named(mesh, (P(all_axes), P(all_axes), P(all_axes))),
        None,
        meta,
    )


def _stream_update_shardmap_cell(shape, mesh, all_axes) -> Cell:
    """§Perf v1: range-sharded pool, shard-local merge (sharded_pool.py).
    Collective drops from O(pool) all-gathers to ONE batch all-gather."""
    from repro.core import sharded_pool as sp

    cap = shape["pool_edges"]
    bcap = shape["batch_edges"]
    n_shards = int(np.prod(list(mesh.shape.values())))
    cap_per = 2 * cap // n_shards
    pool_abs = sp.ShardedPool(
        data=_sds((n_shards, cap_per), jnp.int64),
        n=_sds((n_shards,), jnp.int32),
        lo=_sds((n_shards,), jnp.int64),
    )
    pool_specs = sp.ShardedPool(data=P(all_axes, None), n=P(all_axes), lo=P(all_axes))
    batch_abs = _sds((bcap,), jnp.int64)
    step = sp.make_insert_step(mesh, all_axes)
    meta = {"model_flops": 0.0, "pool_bytes": cap * 8, "batch_edges": bcap,
            "kind": "stream_update", "variant": "shardmap"}
    return Cell(
        step, (pool_abs, batch_abs),
        _named(mesh, (pool_specs, P(None))),
        _named(mesh, pool_specs),
        meta,
    )


def _stream_update_overlay_cell(shape, mesh, all_axes) -> Cell:
    """§Perf v2: LSM-style overlay — updates merge into a small overlay
    pool (compacted into the base pool asynchronously); per-step traffic
    is O(overlay + batch), not O(pool)."""
    from repro.core import flat_ctree as fct

    bcap = shape["batch_edges"]
    overlay_cap = 8 * bcap  # overlay compacted every ~8 batches
    o_abs = fct.FlatCTree(data=_sds((overlay_cap,), jnp.int64), n=_sds((), jnp.int32))
    b_abs = fct.FlatCTree(data=_sds((bcap,), jnp.int64), n=_sds((), jnp.int32))
    o_specs = fct.FlatCTree(data=P(all_axes), n=P())
    b_specs = fct.FlatCTree(data=P(all_axes), n=P())
    step = partial(fct.union_merge, out_cap=overlay_cap)
    meta = {"model_flops": 0.0, "pool_bytes": shape["pool_edges"] * 8,
            "batch_edges": bcap, "kind": "stream_update", "variant": "overlay"}
    return Cell(
        step, (o_abs, b_abs),
        _named(mesh, (o_specs, b_specs)),
        _named(mesh, o_specs),
        meta,
    )


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh: Mesh, reduced: bool = False,
               unroll: bool = False, n_layers_override: Optional[int] = None,
               overrides: Optional[Dict[str, Any]] = None,
               variant: str = "baseline") -> Cell:
    """``unroll``/``n_layers_override`` implement the dry-run's per-layer
    cost extrapolation: XLA cost_analysis counts a while-loop body once,
    so the roofline compiles L=1 and L=2 *unrolled* probes and scales —
    the full-config scan compile stays the pass/fail + memory gate."""
    spec = registry.get(arch_id)
    cfg = spec.reduced if reduced else spec.full
    if n_layers_override is not None and spec.family == "lm":
        cfg = dataclasses.replace(cfg, n_layers=n_layers_override)
    if overrides and spec.family == "lm":
        overrides = dict(overrides)
        if "moe_shard_dispatch" in overrides:
            flag = overrides.pop("moe_shard_dispatch")
            if cfg.moe is not None:
                cfg = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, shard_dispatch=flag)
                )
        if "moe_dispatch_shards" in overrides:
            ns = overrides.pop("moe_dispatch_shards")
            if cfg.moe is not None:
                cfg = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, dispatch_shards=ns)
                )
        if overrides.pop("moe_impl", None) == "shardmap":
            from repro.models import moe_shardmap as MS

            MS.ACTIVE_MESH = mesh
            cfg = dataclasses.replace(cfg, moe_impl="shardmap")
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
    shape = spec.shapes[shape_name]
    if spec.family == "lm":
        kind = shape["kind"]
        if kind == "train":
            return _lm_train_cell(cfg, shape, mesh, unroll=unroll)
        if kind == "prefill":
            return _lm_prefill_cell(cfg, shape, mesh, unroll=unroll)
        return _lm_decode_cell(cfg, shape, mesh, unroll=unroll)
    if spec.family == "gnn":
        return _gnn_cell(cfg, shape, mesh, arch_id)
    if spec.family == "recsys":
        return _dcn_cell(cfg, shape, mesh)
    if spec.family == "stream":
        return _stream_cell(cfg, shape, mesh, variant=variant)
    raise ValueError(spec.family)
