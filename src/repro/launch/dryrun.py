"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import side effect: 512 placeholder host devices so
jax.make_mesh can build the production meshes.  Do not move these lines.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402

from repro.launch.hlo_analysis import collective_bytes  # noqa: E402


def _compile_cell(arch, shape, mesh, **kw):
    cell = build_cell(arch, shape, mesh, **kw)
    with mesh:
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return cell, compiled


def _costs(compiled):
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll, kinds = collective_bytes(hlo)
    return (
        float(ca.get("flops", 0.0)),
        float(ca.get("bytes accessed", 0.0)),
        float(coll),
        kinds,
    )


def run_cell(arch: str, shape: str, multi_pod: bool,
             extrapolate: bool = True, **build_kw) -> dict:
    """Three-compile methodology (DESIGN.md §7):

      1. FULL config, production scan-over-layers: the pass/fail gate +
         compile time + CPU-backend memory_analysis;
      2. (LM only) unrolled L=1 and L=2 probes: per-layer FLOPs/bytes/
         collective bytes, extrapolated to the full depth — XLA
         cost_analysis counts while-loop bodies once, so scan compiles
         systematically undercount by ~n_layers;
      3. analytic memory model (cell.meta['mem_model']) = the fits-on-
         v5e proof (the CPU backend cannot reflect TPU remat/fusion).
    """
    from repro.configs import registry as _reg

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    cell, compiled = _compile_cell(arch, shape, mesh, **build_kw)
    t3 = time.time()
    t1 = t2 = t0  # full build+lower+compile time lands in compile_s

    ma = compiled.memory_analysis()
    flops, bytes_accessed, coll_total, coll_kinds = _costs(compiled)

    extrap = None
    if extrapolate and _reg.get(arch).family == "lm":
        L = cell.meta["n_layers"]
        _, c1 = _compile_cell(arch, shape, mesh, unroll=True,
                              n_layers_override=1, **build_kw)
        _, c2 = _compile_cell(arch, shape, mesh, unroll=True,
                              n_layers_override=2, **build_kw)
        f1, b1, x1, _ = _costs(c1)
        f2, b2, x2, _ = _costs(c2)
        flops = max(flops, (f2 - f1) * (L - 1) + f1)
        bytes_accessed = max(bytes_accessed, (b2 - b1) * (L - 1) + b1)
        coll_total = max(coll_total, (x2 - x1) * (L - 1) + x1)
        extrap = {"f1": f1, "f2": f2, "b1": b1, "b2": b2, "x1": x1, "x2": x2}
    # roofline terms (per device; cost_analysis is post-SPMD per-device)
    compute_s = flops / mesh_lib.PEAK_FLOPS_BF16
    memory_s = bytes_accessed / mesh_lib.HBM_BW
    collective_s = coll_total / (mesh_lib.ICI_LINKS * mesh_lib.ICI_BW_PER_LINK)
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    model_flops = cell.meta.get("model_flops", 0.0)
    useful = model_flops / (n_chips * flops) if flops else 0.0

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "ok": True,
        "build_s": round(t1 - t0, 2),
        "lower_s": round(t2 - t1, 2),
        "compile_s": round(t3 - t2, 2),
        "flops_per_dev": flops,
        "bytes_per_dev": bytes_accessed,
        "collective_bytes_per_dev": coll_total,
        "collective_kinds": coll_kinds,
        "compute_s_term": compute_s,
        "memory_s_term": memory_s,
        "collective_s_term": collective_s,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "useful_compute_frac": useful,
        "mem_argument_bytes": ma.argument_size_in_bytes,
        "mem_output_bytes": ma.output_size_in_bytes,
        "mem_temp_bytes": ma.temp_size_in_bytes,
        "mem_peak_bytes_est": ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes,
        "mem_model": cell.meta.get("mem_model"),
        "extrap": extrap,
        "meta": {k: v for k, v in cell.meta.items() if isinstance(v, (int, float, str, bool))},
    }
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--include-stream", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON lines here")
    args = ap.parse_args()

    if args.all:
        cells = list(registry.all_cells(include_stream=args.include_stream))
    else:
        assert args.arch, "--arch required unless --all"
        spec = registry.get(args.arch)
        shapes = [args.shape] if args.shape else list(spec.shapes)
        cells = [(args.arch, s) for s in shapes]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}"
            try:
                res = run_cell(arch, shape, mp)
                print(
                    f"[OK] {tag}: compile={res['compile_s']}s "
                    f"dominant={res['dominant']} "
                    f"terms(c/m/x)=({res['compute_s_term']:.2e},"
                    f"{res['memory_s_term']:.2e},{res['collective_s_term']:.2e}) "
                    f"peak={res['mem_peak_bytes_est']/2**30:.2f}GiB/dev "
                    f"useful={res['useful_compute_frac']:.3f}"
                )
            except Exception as e:  # noqa: BLE001 - report and continue
                failures += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}")
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(res) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
