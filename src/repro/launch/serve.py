"""Serving launcher: batched generation with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import transformer as T
from repro.serve.decode import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = registry.get(args.arch)
    assert spec.family == "lm", "serving driver is for the LM family"
    cfg = spec.reduced if args.reduced else spec.full
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    t0 = time.time()
    out = generate(
        params, cfg, prompt, args.max_new,
        temperature=args.temperature, key=jax.random.PRNGKey(args.seed),
    )
    dt = time.time() - t0
    n_tok = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s  ({n_tok / dt:.1f} tok/s)")
    print("sample:", np.asarray(out[0, args.prompt_len:]).tolist()[:16])


if __name__ == "__main__":
    main()
