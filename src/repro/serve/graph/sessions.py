"""Snapshot-pinned sessions: strict serializability as an API.

The stream's versioned reads already guarantee each individual query a
consistent snapshot; a ``Session`` extends that to a SEQUENCE of reads.
Opening the session acquires (refcounts) the version current at open
time; every query submitted through it is routed to session-pinned
lanes and served against that exact version no matter how many
publishes land in between — so a multi-query read (e.g. bfs then sssp
then pagerank over "the same graph") is strictly serializable at the
open instant.  ``close()`` waits for in-flight session queries and
releases the reference, letting the version (and its cached engines
and cached RESULTS — the result cache stores payloads on the version
itself) be reclaimed; the ref-leak tests pin that 1k open/close cycles
under a live writer leave zero extra live versions.

The result cache composes with pinning for free: cached answers live
on ``Version.cache``, and ``service.submit`` looks them up against the
session's OWN pinned version — so a session hit can only ever return a
result computed on its snapshot, never a newer version's (pinned by
test), while repeated identical session queries hit without a dispatch.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from .request import QueryTicket


class Session:
    """A pinned read handle; use as a context manager:

        with service.session(tenant="alice") as s:
            parents = s.query("bfs", source=0).result()
            dist = s.query("sssp", source=0).result()
        # both answers reflect the SAME version, s.stamp
    """

    def __init__(self, service, tenant: str):
        self._service = service
        self.tenant = tenant
        self._v = service.stream.acquire()
        self._lock = threading.Lock()
        self._pending = 0
        self._idle = threading.Condition(self._lock)
        self._closed = False

    @property
    def stamp(self) -> int:
        """The version stamp every query in this session reads."""
        return self._v.stamp

    @property
    def version(self):
        """The held version (service internals dispatch engines off it)."""
        return self._v

    @property
    def closed(self) -> bool:
        return self._closed

    def query(
        self,
        kind: str,
        source: Optional[int] = None,
        deadline_s: Optional[float] = None,
        **params: Any,
    ) -> QueryTicket:
        """Submit a query pinned to this session's version.  Same
        admission path as ``service.submit`` (the session does not jump
        the tenant's queue); only the serving version differs."""
        with self._lock:
            if self._closed:
                raise RuntimeError("session is closed")
            self._pending += 1
        try:
            ticket = self._service.submit(
                kind,
                source=source,
                tenant=self.tenant,
                deadline_s=deadline_s,
                session=self,
                **params,
            )
        except BaseException:
            with self._lock:
                self._pending -= 1
                self._idle.notify_all()
            raise
        return ticket

    # called by the service when a session ticket completes or fails
    def _query_done(self, ticket: QueryTicket) -> None:
        with self._lock:
            self._pending -= 1
            self._idle.notify_all()

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Wait out in-flight session queries, then release the pinned
        version.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            deadline = None if timeout is None else timeout
            if not self._idle.wait_for(lambda: self._pending == 0, timeout=deadline):
                raise TimeoutError(
                    f"session for tenant {self.tenant!r} still has "
                    f"{self._pending} queries in flight after {timeout}s"
                )
            self._closed = True
        self._service.stream.release(self._v)
        self._service._forget_session(self)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self):
        state = "closed" if self._closed else f"stamp={self.stamp}"
        return f"Session(tenant={self.tenant!r}, {state})"
