"""Tenant admission: weighted fair queuing with in-flight caps.

The admission queue sits between ``submit()`` and the query lanes.
Each tenant owns a FIFO backlog; the dispatcher drains backlogs into
lanes by *stride scheduling* — tenant ``t`` carries a virtual pass
``t.vpass`` advanced by ``1 / weight`` per admitted request, and every
admission picks the eligible tenant with the smallest pass.  Over any
saturated interval tenant throughput is therefore proportional to
weight (weight 4 admits 4 requests per weight-1 request), without
starving anyone: a tenant that went idle re-enters at the current
minimum pass (never banks credit).

Eligibility enforces the caps: a tenant with ``in_flight`` (admitted
but not completed) at its ``max_inflight`` — or the service at its
global cap — stays backlogged until completions free slots.  Backlogs
are bounded too: past ``max_backlog`` the submit is REJECTED
(``QueueFull``), the service's explicit backpressure surface.

NOT thread-safe by itself: every method is called under the service's
dispatch lock (single-writer discipline, like the version list).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

from .metrics import TenantMetrics
from .request import QueryTicket


class QueueFull(RuntimeError):
    """Submission rejected: the tenant's backlog is at capacity."""


class Tenant:
    __slots__ = ("name", "weight", "max_inflight", "vpass", "backlog",
                 "in_flight", "metrics")

    def __init__(self, name: str, weight: float, max_inflight: int):
        if weight <= 0:
            raise ValueError(f"tenant weight must be positive; got {weight}")
        self.name = name
        self.weight = float(weight)
        self.max_inflight = int(max_inflight)
        self.vpass = 0.0
        self.backlog: Deque[QueryTicket] = deque()
        self.in_flight = 0
        self.metrics = TenantMetrics()


class AdmissionQueue:
    def __init__(
        self,
        weights: Optional[Dict[str, float]] = None,
        default_weight: float = 1.0,
        max_inflight_per_tenant: int = 64,
        max_inflight_total: int = 256,
        max_backlog: int = 8192,
    ):
        self._tenants: Dict[str, Tenant] = {}
        self._default_weight = default_weight
        self._max_inflight_per_tenant = max_inflight_per_tenant
        self.max_inflight_total = max_inflight_total
        self.max_backlog = max_backlog
        self.in_flight_total = 0
        for name, w in (weights or {}).items():
            self.tenant(name, weight=w)

    def tenant(self, name: str, weight: Optional[float] = None) -> Tenant:
        """Get-or-create; ``weight`` only applies at creation (redefining
        a live tenant's weight mid-flight would skew in-progress
        accounting — create tenants up front for custom weights)."""
        t = self._tenants.get(name)
        if t is None:
            t = Tenant(
                name,
                self._default_weight if weight is None else weight,
                self._max_inflight_per_tenant,
            )
            # a fresh tenant starts at the current minimum pass so it
            # competes fairly from now on instead of replaying history
            live = [x.vpass for x in self._tenants.values()]
            t.vpass = min(live) if live else 0.0
            self._tenants[name] = t
        return t

    # -- submit side --------------------------------------------------------
    def submit(self, ticket: QueryTicket) -> None:
        t = self.tenant(ticket.tenant)
        t.metrics.submitted += 1
        if len(t.backlog) >= self.max_backlog:
            t.metrics.rejected += 1
            raise QueueFull(
                f"tenant {t.name!r} backlog at capacity ({self.max_backlog})"
            )
        t.backlog.append(ticket)

    # -- dispatcher side ----------------------------------------------------
    def _eligible(self) -> List[Tenant]:
        return [
            t for t in self._tenants.values()
            if t.backlog and t.in_flight < t.max_inflight
        ]

    def admit(self, max_n: Optional[int] = None) -> List[QueryTicket]:
        """Stride-scheduled admission: repeatedly pop one request from
        the smallest-pass eligible tenant until caps bind (or ``max_n``
        admitted).  Returns the admitted tickets in admission order."""
        out: List[QueryTicket] = []
        while max_n is None or len(out) < max_n:
            if self.in_flight_total >= self.max_inflight_total:
                break
            elig = self._eligible()
            if not elig:
                break
            t = min(elig, key=lambda x: (x.vpass, x.name))
            out.append(t.backlog.popleft())
            t.vpass += 1.0 / t.weight
            t.in_flight += 1
            t.metrics.admitted += 1
            self.in_flight_total += 1
        return out

    def complete(self, ticket: QueryTicket) -> None:
        t = self._tenants[ticket.tenant]
        t.in_flight -= 1
        t.metrics.completed += 1
        self.in_flight_total -= 1

    # -- introspection ------------------------------------------------------
    def backlog_depth(self) -> int:
        return sum(len(t.backlog) for t in self._tenants.values())

    def snapshot(self) -> dict:
        return {
            name: t.metrics.snapshot(
                weight=t.weight, in_flight=t.in_flight, backlog=len(t.backlog)
            )
            for name, t in sorted(self._tenants.items())
        }
