"""Query lanes: coalescing admitted requests into batched dispatches.

A *lane* is one homogeneous pending set — requests that can legally
ride a single ``query_batch``-style dispatch.  The lane key is

    (kind, pin, params_key, backend)

where ``pin`` is None for freshest-version lanes (served against the
stream's current version at flush time) or the owning ``Session`` (all
of whose queries must hit its pinned version).  Mixed kinds never
batch; mixed parameters (e.g. two dampings) never batch; pinned and
freshest traffic never batch.

Flush policy (DESIGN.md §13) — a lane flushes when EITHER
  * it holds ``max_batch`` requests (full flush), or
  * the oldest request's deadline budget is half spent:
    now >= t_submit + 0.5 * (deadline - t_submit).
The half-budget rule leaves the other half for the dispatch itself, so
coalescing opportunistically trades latency headroom for batch size but
never spends headroom it doesn't have.

Execution pads each dispatch to the next power of two so the jitted
drivers see O(log max_batch) distinct shapes per (kind, engine
signature): after one warmup ladder, steady-state serving replays
compiled code only (``traversal.TRACES`` pins this in tests).
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

from .metrics import LaneMetrics
from .request import QueryTicket

# how much of a request's deadline budget may be spent waiting in a
# lane before the flush is forced
FLUSH_BUDGET_FRACTION = 0.5


def next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def engine_signature(engine) -> Optional[Tuple]:
    """The trace-relevant identity of an engine: everything that, if it
    changes, legitimately forces the jitted drivers to recompile —
    vertex count, pool capacity (array shapes), weightedness.  Returns
    None for engines with no jit path (numpy), which never trace."""
    g = getattr(engine, "g", None)
    if g is not None and hasattr(g, "edge_capacity"):  # JaxEngine / FlatGraph
        return ("jax", engine.n, int(g.edge_capacity), engine.weighted)
    sg = getattr(engine, "sg", None)
    if sg is not None:  # ShardedEngine / ShardedGraph
        return ("sharded", engine.n, tuple(sg.pool.data.shape), engine.weighted)
    return None


class Lane:
    """One coalescing point: the pending tickets for a single
    (kind, pin, params, backend) combination, plus the per-KIND metrics
    they report into (lanes of one kind share a ``LaneMetrics``)."""

    __slots__ = ("kind", "pin", "pkey", "backend", "pending", "metrics")

    def __init__(self, kind: str, pin, pkey, backend: str, metrics: LaneMetrics):
        self.kind = kind
        self.pin = pin
        self.pkey = pkey
        self.backend = backend
        self.pending: List[QueryTicket] = []
        self.metrics = metrics

    def add(self, ticket: QueryTicket) -> None:
        self.pending.append(ticket)
        self.metrics.queued += 1

    def flush_at(self) -> float:
        """The instant the half-budget rule forces a flush (+inf when
        empty).  Oldest ticket governs: tickets behind it only ever
        flush earlier than their own budget demands."""
        if not self.pending:
            return float("inf")
        t = self.pending[0]
        return t.t_submit + FLUSH_BUDGET_FRACTION * (t.deadline - t.t_submit)

    def due(self, now: float, max_batch: int) -> bool:
        if not self.pending:
            return False
        return len(self.pending) >= max_batch or now >= self.flush_at()

    def take(self, max_batch: int) -> List[QueryTicket]:
        batch, self.pending = self.pending[:max_batch], self.pending[max_batch:]
        return batch


# ---------------------------------------------------------------------------
# batch execution (runs on the service's executor, engine already pinned)
# ---------------------------------------------------------------------------


def trace_key(kind: str, engine, batch_pow2: int, pkey) -> Optional[Tuple]:
    sig = engine_signature(engine)
    if sig is None:
        return None
    # cc is a whole-graph computation: batch size is not a trace axis
    b = 1 if kind == "cc" else batch_pow2
    return (kind, sig, b, pkey)


def dispatch_pow2(kind: str, tickets: List[QueryTicket]) -> int:
    """The padded batch size this flush will actually trace at."""
    if kind == "cc":
        return 1
    if kind == "pagerank":
        srcs = {t.source for t in tickets}
        return next_pow2(len(srcs))
    uniq = len({t.source for t in tickets})
    return next_pow2(uniq)


def serve_cached(
    cache, version, kind: str, tickets: List[QueryTicket]
) -> List[QueryTicket]:
    """Flush-time cache consult: complete every ticket whose answer is
    already cached on the batch's serving version and return the
    remaining misses.  This is the lane dedup generalized across TIME —
    a source computed by an earlier flush on the same version shrinks
    this dispatch exactly like a duplicate inside it would.  Cached
    tickets report ``batch_size == 0`` (they rode no dispatch)."""
    if cache is None or version is None:
        return tickets
    now = time.perf_counter()
    misses: List[QueryTicket] = []
    for t in tickets:
        ent = cache.get(version, kind, t.pkey, None if kind == "cc" else t.source)
        if ent is None:
            misses.append(t)
            continue
        t.t_flush = now
        t.batch_size = 0
        t.cached = True
        t._complete(ent.value)
    return misses


def execute_batch(
    engine,
    kind: str,
    tickets: List[QueryTicket],
    params: dict,
    cache=None,
    version=None,
) -> None:
    """Serve one flushed batch against an already-acquired engine,
    completing every ticket (the caller fails them all if this raises).

    bfs / sssp dedup identical sources and fan the unique rows back out
    (the engines' own ``_quantized_sources`` pads the unique set to a
    power of two, so the trace ladder is O(log max_batch)).  pagerank
    builds one personalization row per distinct source (one-hot; None =
    the global uniform row) and pads the row count to a power of two
    itself, since ``pagerank_multi`` takes ``resets`` verbatim.  cc runs
    the global computation once and every rider shares the labels.

    With ``cache``/``version`` set, every unique answer is also recorded
    on the serving version (the fill side of ``serve_cached``; bfs
    stashes its depths rows too — the warm state the carry-forward
    ``incremental_bfs`` needs, computed for free by ``bfs_multi``)."""
    from repro.core.traversal import algorithms as talg

    now = time.perf_counter()
    for t in tickets:
        t.t_flush = now
        t.batch_size = len(tickets)
    fill = cache is not None and version is not None
    pkey = tickets[0].pkey

    if kind == "cc":
        labels = np.asarray(talg.connected_components(engine, **params), np.int64)
        if fill:
            cache.put(version, kind, pkey, None, labels)
        for t in tickets:
            t._complete(labels)
        return

    if kind == "pagerank":
        order: List[Optional[int]] = []
        row_of = {}
        for t in tickets:
            if t.source not in row_of:
                row_of[t.source] = len(order)
                order.append(t.source)
        n = engine.n
        b = len(order)
        resets = np.zeros((next_pow2(b), n), dtype=np.float64)
        for i, s in enumerate(order):
            if s is None:
                resets[i, :] = 1.0 / n
            else:
                resets[i, s] = 1.0
        # padding rows replay row 0 (a real row: no degenerate all-zero
        # reset reaches the driver)
        resets[b:, :] = resets[0, :]
        scores = np.asarray(talg.pagerank_multi(engine, resets=resets, **params))
        if fill:
            for s, i in row_of.items():
                cache.put(version, kind, pkey, s, scores[i])
        for t in tickets:
            t._complete(scores[row_of[t.source]])
        return

    sources = np.asarray([t.source for t in tickets], dtype=np.int64)
    uniq, inv = np.unique(sources, return_inverse=True)
    if kind == "bfs":
        rows, depths = talg.bfs_multi(engine, uniq, **params)
        rows = np.asarray(rows, np.int64)
        depths = np.asarray(depths, np.int64)
        if fill:
            for i, s in enumerate(uniq):
                cache.put(version, kind, pkey, int(s), rows[i], state=depths[i])
    elif kind == "sssp":
        rows = np.asarray(talg.sssp_multi(engine, uniq, **params), np.float64)
        if fill:
            for i, s in enumerate(uniq):
                cache.put(version, kind, pkey, int(s), rows[i])
    else:  # pragma: no cover - guarded by QueryTicket validation
        raise ValueError(f"unknown lane kind {kind!r}")
    for t, i in zip(tickets, inv):
        t._complete(rows[i])
