"""Version-keyed, delta-aware cross-request result cache (DESIGN.md §14).

Aspen's snapshots make every query a pure function of
``(version, kind, params, source)`` — so once one tenant has paid for
an answer on a version, every identical request against that SAME
version can be served from memory.  The cache exploits exactly that and
nothing more:

  * **Key contract.**  The logical key is ``(kind, canonical params,
    source)``; the FULL key includes the version, because entries are
    stored *on* the version: the payload dict lives in
    ``Version.cache[RESULTS]``, so a lookup hands the service a
    ``Version`` object and can, by construction, only ever see results
    computed against that exact snapshot.  A pinned session therefore
    can never read a newer version's cached answer (pinned by test),
    and a freshest read can never resurrect a stale one.

  * **Lifecycle.**  Entries pin nothing.  The payload rides the
    version's own cache dict and is garbage-collected with it through
    the existing ``core.versioning`` refcount hooks; the LRU index here
    holds only ``weakref``s to versions, pruned lazily.  Capacity
    eviction walks the index oldest-first and deletes the payload from
    its (still-live) version.

  * **Delta carry-forward.**  On publish, *hot* entries (ever re-read)
    are promoted to the new version through the PR 7 incremental paths
    instead of being dropped: ``incremental_bfs`` / ``incremental_sssp``
    / ``incremental_connected_components`` driven by
    ``vg.delta_between``, and warm-started ``pagerank(init=prev)`` when
    the request carries the fixed-point ``tol`` contract.  A broken
    delta chain (``None``) — or fixed-iteration pagerank, whose answer
    is *defined* by the iteration count — falls back to a full
    recompute, run off the request path, so the promoted entry is
    always bit-identical to what a cold serve at the new version would
    have produced (tolerance-identical for ``tol``-pagerank).  A
    publish thus downgrades a hit to a warm-start, not a cold miss.

Thread-safe: one internal lock around the index and the per-version
payload dicts (the service calls in from client threads, executor
threads, and the promotion thread).
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# key of the payload dict on Version.cache — everything under it dies
# with the version, like the engine cache next to it
RESULTS = "results"

# widest single promotion dispatch: same-(kind, params) entries are
# carried forward in pow2-padded batches up to this, so promoting N hot
# entries costs ceil(N / 16) driver replays instead of N — and the
# trace ladder warmup (service._warm_promotion) only has to cover 1..16.
# The whole pass bounds the post-publish blind window (entries are warm
# on the old version, cold on the new one until promoted), so fewer,
# wider dispatches matter more than per-dispatch efficiency
PROMOTE_BATCH = 16

# per-kind parameter allowlists the carry-forward path understands; an
# entry whose params fall outside is dropped on publish (never promoted
# wrong), it simply recomputes as a cold miss when next asked for
_PROMOTABLE_PARAMS = {
    "bfs": frozenset(),
    "sssp": frozenset(),
    "cc": frozenset({"direction_optimize", "max_iters"}),
    "pagerank": frozenset({"iters", "damping", "tol", "max_iters"}),
}


class CacheEntry:
    """One cached answer: the host result row plus whatever warm state
    the incremental promotion for its kind needs (bfs keeps the depths
    row computed for free by ``bfs_multi``)."""

    __slots__ = ("value", "state", "hits")

    def __init__(self, value, state=None):
        self.value = value
        self.state = state
        self.hits = 0


class ResultCache:
    """LRU index over version-resident result entries.  See module
    docstring for the key/lifecycle/carry-forward contracts."""

    def __init__(self, capacity: int = 512):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        # (stamp, kind, pkey, source) -> weakref to the owning Version;
        # insertion order is recency (move_to_end on hit)
        self._lru: "OrderedDict[Tuple, weakref.ref]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.fills = 0
        self.evictions = 0
        self.promoted_incremental = 0
        self.promoted_full = 0
        self.promoted_dropped = 0

    # -- request path --------------------------------------------------------
    def get(self, v, kind: str, pkey: Tuple, source) -> Optional[CacheEntry]:
        """Exact hit against an already-acquired version, else None.
        The payload lookup goes through ``v.cache`` itself, so the hit
        is version-exact by construction."""
        key = (kind, pkey, source)
        with self._lock:
            slot = v.cache.get(RESULTS)
            ent = None if slot is None else slot.get(key)
            if ent is None:
                self.misses += 1
                return None
            self.hits += 1
            ent.hits += 1
            lk = (v.stamp,) + key
            if lk in self._lru:
                self._lru.move_to_end(lk)
            return ent

    def peek(self, v, kind: str, pkey: Tuple, source) -> Optional[CacheEntry]:
        """Presence probe: the entry on ``v`` for this key, without
        counting a hit/miss or touching recency.  The service's capture
        path uses it to ask whether an in-flight promotion pass is
        about to re-derive the very answer a post-publish miss would
        otherwise recompute through the full dispatch path."""
        with self._lock:
            slot = v.cache.get(RESULTS)
            return None if slot is None else slot.get((kind, pkey, source))

    def put(self, v, kind: str, pkey: Tuple, source, value, state=None,
            hits: int = 0) -> None:
        """Record one answer on ``v`` (idempotent per key: a racing
        duplicate fill keeps the first entry's hit count).  ``hits``
        seeds the entry's heat — carry-forward passes the promoted
        entry's count through so a hot entry stays hot across a chain
        of publishes instead of dying one hop in."""
        key = (kind, pkey, source)
        with self._lock:
            slot = v.cache.setdefault(RESULTS, {})
            if key not in slot:
                ent = CacheEntry(value, state)
                ent.hits = hits
                slot[key] = ent
                self.fills += 1
            lk = (v.stamp,) + key
            self._lru[lk] = weakref.ref(v)
            self._lru.move_to_end(lk)
            while len(self._lru) > self.capacity:
                old_lk, vref = self._lru.popitem(last=False)
                owner = vref()
                if owner is not None:
                    owner_slot = owner.cache.get(RESULTS)
                    if owner_slot is not None:
                        owner_slot.pop(old_lk[1:], None)
                    self.evictions += 1
                # a dead weakref's payload died with its version: the
                # index entry is just pruned, not counted as an eviction

    # -- carry-forward -------------------------------------------------------
    def promotable(self, v_old, limit: int) -> List[Tuple[Tuple, CacheEntry]]:
        """The hot entries on ``v_old`` worth carrying across a publish:
        entries that have served at least one hit, most-recently-used
        first, capped at ``limit`` (publish-time work must be bounded)."""
        with self._lock:
            slot = v_old.cache.get(RESULTS)
            if not slot:
                return []
            order = [
                lk[1:] for lk in reversed(self._lru) if lk[0] == v_old.stamp
            ]
            out: List[Tuple[Tuple, CacheEntry]] = []
            for key in order:
                ent = slot.get(key)
                if ent is not None and ent.hits > 0:
                    out.append((key, ent))
                    if len(out) >= limit:
                        break
            return out

    def carry_forward(self, stream, v_old, v_new, backend: str,
                      limit: int = 32) -> int:
        """Promote hot ``v_old`` entries onto ``v_new`` through the
        incremental paths (module docstring).  Runs on the service's
        promotion thread — never the writer's publish callback, whose
        contract forbids compute.  Returns the number promoted."""
        entries = self.promotable(v_old, limit)
        if not entries:
            return 0
        delta = stream.vg.delta_between(v_old, v_new)
        eng_new = stream._engine_for(v_new, backend)
        eng_old = None  # fetched lazily: only sssp promotion needs it
        promoted = 0

        def land(key_ents, results):
            nonlocal promoted
            for (key, ent), (value, state, incr) in zip(key_ents, results):
                kind, pkey, source = key
                self.put(v_new, kind, pkey, source, value, state,
                         hits=ent.hits)
                promoted += 1
                if incr:
                    self.promoted_incremental += 1
                else:
                    self.promoted_full += 1

        # bfs/sssp promote as pow2-padded batched dispatches grouped by
        # params — one driver replay per PROMOTE_BATCH entries, the same
        # shape discipline as serving; cc/pagerank go one at a time
        groups: "OrderedDict[Tuple, List]" = OrderedDict()
        singles: List[Tuple[Tuple, CacheEntry]] = []
        for (kind, pkey, source), ent in entries:
            if set(dict(pkey)) - _PROMOTABLE_PARAMS.get(kind, frozenset()):
                self.promoted_dropped += 1
                continue
            if kind in ("bfs", "sssp"):
                groups.setdefault((kind, pkey), []).append(
                    ((kind, pkey, source), ent)
                )
            else:
                singles.append(((kind, pkey, source), ent))

        for (kind, pkey), grp in groups.items():
            if (kind == "sssp" and delta is not None and eng_old is None
                    and (eng_new.weighted or delta.has_deletions)):
                eng_old = stream._engine_for(v_old, backend)
            for i in range(0, len(grp), PROMOTE_BATCH):
                chunk = grp[i:i + PROMOTE_BATCH]
                try:
                    results = _promote_batch(
                        eng_old, eng_new, kind, chunk, delta
                    )
                except Exception:
                    # a failed promotion is a dropped chunk, never a
                    # wrong answer (the next request recomputes cold)
                    self.promoted_dropped += len(chunk)
                    continue
                land(chunk, results)

        for (kind, pkey, source), ent in singles:
            try:
                res = _promote_one(
                    eng_new, kind, dict(pkey), source, ent, delta
                )
            except Exception:
                self.promoted_dropped += 1
                continue
            land([((kind, pkey, source), ent)], [res])
        return promoted

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "capacity": self.capacity,
                "entries": len(self._lru),
                "hits": self.hits,
                "misses": self.misses,
                "fills": self.fills,
                "evictions": self.evictions,
                "hit_rate": self.hits / max(self.hits + self.misses, 1),
                "promoted_incremental": self.promoted_incremental,
                "promoted_full": self.promoted_full,
                "promoted_dropped": self.promoted_dropped,
            }


def _pad_b(rows: np.ndarray, m: int) -> np.ndarray:
    """Pad a [k, n] stack to [m, n] by repeating the last row (the
    batch analogue of lane pow2 padding: duplicate lanes are redundant
    work the padded dispatch discards)."""
    k = rows.shape[0]
    if k == m:
        return rows
    return np.concatenate([rows, np.repeat(rows[-1:], m - k, axis=0)])


def _promote_batch(
    eng_old, eng_new, kind: str,
    chunk: List[Tuple[Tuple, CacheEntry]], delta,
) -> List[Tuple[Any, Any, bool]]:
    """Promote one chunk of same-(kind, params) bfs/sssp entries in a
    SINGLE batched dispatch, sources padded to the next power of two so
    promotion replays the warmed trace ladder (service._warm_promotion
    covers 1..PROMOTE_BATCH).  Incremental when the delta supports it,
    batched full recompute otherwise; exact either way."""
    from repro.core.traversal import algorithms as talg

    sources = [key[2] for key, _ in chunk]
    k = len(sources)
    m = 1
    while m < k:
        m <<= 1
    pad = sources + [sources[-1]] * (m - k)

    if kind == "bfs":
        if delta is None:
            parents, depths = talg.bfs_multi(eng_new, pad)
            incr = False
        else:
            prev_p = _pad_b(np.stack([ent.value for _, ent in chunk]), m)
            prev_d = _pad_b(np.stack([ent.state for _, ent in chunk]), m)
            parents, depths = talg.incremental_bfs(
                eng_new, pad, prev_p, prev_d, delta
            )
            incr = True
        return [
            (np.asarray(parents[i], np.int64),
             np.asarray(depths[i], np.int64), incr)
            for i in range(k)
        ]

    if kind == "sssp":
        if delta is None:
            dist = talg.sssp_multi(eng_new, pad)
            incr = False
        else:
            prev = _pad_b(np.stack([ent.value for _, ent in chunk]), m)
            if eng_new.weighted or delta.has_deletions:
                # tree derivation is a per-row host pass on the OLD
                # engine: run it on the k real rows only, pad after
                tree = _pad_b(
                    talg.shortest_path_parents(eng_old, prev[:k], sources),
                    m,
                )
            else:
                # unit weights + insert-only delta: the dirty closure
                # is empty no matter what the tree says (inserts only
                # lower distances — prev rows are valid upper bounds
                # the warm relaxation improves), so skip the k dense
                # tree passes and hand the closure a placeholder
                tree = np.full((m, 1), -1, np.int64)
            dist = talg.incremental_sssp(eng_new, pad, prev, tree, delta)
            incr = True
        return [
            (np.asarray(dist[i], np.float64), None, incr) for i in range(k)
        ]

    raise ValueError(f"kind {kind!r} does not batch-promote")


def _promote_one(
    eng_new, kind: str, params: Dict[str, Any], source,
    ent: CacheEntry, delta,
) -> Tuple[Any, Any, bool]:
    """Compute one cc/pagerank entry's value at the new version:
    incremental when the delta supports it, full otherwise — in both
    cases producing exactly what a cold serve at the new version would
    (incremental cc is exact; fixed-iteration pagerank recomputes)."""
    from repro.core.traversal import algorithms as talg

    if kind == "cc":
        incremental = delta is not None and not delta.has_deletions
        labels = talg.incremental_connected_components(
            eng_new, ent.value, delta, **params
        )
        return np.asarray(labels, np.int64), None, incremental

    if kind == "pagerank":
        n = eng_new.n
        reset = np.zeros((1, n), np.float64)
        if source is None:
            reset[0, :] = 1.0 / n
        else:
            reset[0, int(source)] = 1.0
        if "tol" in params:
            # fixed-point contract: any init converges to the same
            # scores, so the warm start is tolerance-identical
            scores = talg.pagerank_multi(
                eng_new, resets=reset, init=ent.value[None], **params
            )
            return np.asarray(scores[0]), None, True
        # fixed-iteration pagerank is DEFINED by its iteration count: a
        # warm start would change the answer, so promotion recomputes —
        # still a win: the cost moves off the request path
        scores = talg.pagerank_multi(eng_new, resets=reset, **params)
        return np.asarray(scores[0]), None, False

    raise ValueError(f"unknown kind {kind!r}")
