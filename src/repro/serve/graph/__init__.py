"""Multi-tenant graph-query serving over a live ``AspenStream``.

Public surface:

  ``GraphQueryService`` — the server: writer thread (batched update
      publishing), weighted-fair admission, deadline-driven per-kind
      query lanes, pow2-padded batched dispatch, ``stats()``.
  ``Session``      — snapshot-pinned handle: strictly-serializable
      multi-query reads against one version.
  ``QueryTicket``  — the per-request future ``submit()`` returns.
  ``QueueFull``    — backpressure signal on a saturated tenant backlog.
  ``ResultCache``  — version-keyed, delta-aware cross-request result
      cache (on by default inside the service; exposed for tests and
      standalone use).

See DESIGN.md §13 for the admission / flush / pinning contracts,
DESIGN.md §14 for the result-cache key / carry-forward contracts, and
``examples/serve_graph.py`` for a walkthrough.
"""
from .admission import QueueFull
from .request import KINDS, QueryTicket
from .result_cache import ResultCache
from .service import GraphQueryService
from .sessions import Session

__all__ = [
    "GraphQueryService", "Session", "QueryTicket", "QueueFull", "KINDS",
    "ResultCache",
]
