"""Service observability: per-lane and per-tenant counters.

All counters are plain ints mutated under the service's dispatch lock
(one writer at a time), snapshotted into dicts by ``service.stats()``.
The retrace accounting rides two spies:

  * ``LaneMetrics.trace_keys`` — the set of (engine-signature, pow2
    batch size) shapes this lane has dispatched.  A flush whose key is
    already in the set compiles nothing new; a NEW key after
    ``mark_warm()`` counts as a retrace (the steady-state contract:
    zero after warmup).
  * ``traversal.TRACES`` — the trace-time counter inside the jitted
    drivers themselves, the ground truth the service-level key
    accounting is validated against in tests.
"""
from __future__ import annotations

from typing import Dict, Set, Tuple


class LaneMetrics:
    """Counters for one query kind (aggregated across pinned/freshest
    lane instances of that kind)."""

    __slots__ = (
        "queued", "flushed_batches", "flushed_requests", "batch_hist",
        "deadline_misses", "errors", "trace_keys", "retraces",
        "deadline_flushes", "full_flushes", "idle_flushes",
        "cache_hits", "fastpath_hits", "fastpath_syncs", "capture_hits",
    )

    def __init__(self):
        self.queued = 0              # requests ever placed in a lane
        self.flushed_batches = 0     # lane flushes executed
        self.flushed_requests = 0    # requests those flushes served
        self.batch_hist: Dict[int, int] = {}  # flush size -> count
        self.deadline_misses = 0     # tickets completed past their SLO
        self.errors = 0              # tickets failed by an executor error
        self.trace_keys: Set[Tuple] = set()  # shapes ever dispatched
        self.retraces = 0            # NEW shapes seen after mark_warm()
        self.deadline_flushes = 0    # flushes forced by the half-budget rule
        self.full_flushes = 0        # flushes forced by a full lane
        self.idle_flushes = 0        # work-conserving flushes (idle executor)
        self.cache_hits = 0          # tickets served from the result cache
        self.fastpath_hits = 0       # ...of which at submit time (no lane hop)
        self.fastpath_syncs = 0      # singleton misses served on the caller
        self.capture_hits = 0        # ...hits landed by riding a promotion

    def record_flush(self, size: int, *, reason: str) -> None:
        self.flushed_batches += 1
        self.flushed_requests += size
        self.batch_hist[size] = self.batch_hist.get(size, 0) + 1
        if reason == "deadline":
            self.deadline_flushes += 1
        elif reason == "idle":
            self.idle_flushes += 1
        else:
            self.full_flushes += 1

    def record_trace_key(self, key: Tuple, warm: bool) -> bool:
        """Note a dispatched shape; returns True (and counts a retrace
        when past warmup) if the shape was new."""
        if key in self.trace_keys:
            return False
        self.trace_keys.add(key)
        if warm:
            self.retraces += 1
        return True

    def snapshot(self) -> dict:
        return {
            "queued": self.queued,
            "flushed_batches": self.flushed_batches,
            "flushed_requests": self.flushed_requests,
            "batch_size_hist": dict(sorted(self.batch_hist.items())),
            "deadline_misses": self.deadline_misses,
            "deadline_flushes": self.deadline_flushes,
            "full_flushes": self.full_flushes,
            "idle_flushes": self.idle_flushes,
            "errors": self.errors,
            "trace_keys": len(self.trace_keys),
            "retraces": self.retraces,
            "cache_hits": self.cache_hits,
            "fastpath_hits": self.fastpath_hits,
            "fastpath_syncs": self.fastpath_syncs,
            "capture_hits": self.capture_hits,
        }


class TenantMetrics:
    """``cached`` counts exact-hit requests served without admission:
    those still bump submitted/admitted/completed together (keeping the
    per-tenant accounting identity ``submitted == admitted + rejected +
    backlog`` and ``admitted == completed + in_flight`` snapshot-exact)
    but never advance the tenant's WFQ pass — admission meters MISSES,
    so fairness is arbitrated over real engine work only."""

    __slots__ = ("submitted", "admitted", "completed", "rejected", "cached")

    def __init__(self):
        self.submitted = 0
        self.admitted = 0
        self.completed = 0
        self.rejected = 0
        self.cached = 0

    def snapshot(self, *, weight: float, in_flight: int, backlog: int) -> dict:
        return {
            "weight": weight,
            "submitted": self.submitted,
            "admitted": self.admitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "cached": self.cached,
            "in_flight": in_flight,
            "backlog": backlog,
        }
