"""GraphQueryService: continuous graph updates + SLO-aware batched reads.

The service runs the paper's single-writer / many-reader regime as a
long-lived server over one ``AspenStream``:

  * a dedicated WRITER thread drains the bounded update queue in
    batches through ``core.streaming.drain_updates`` — the same loop
    body ``run_concurrent`` uses — publishing each batch atomically as
    one new version;
  * a DISPATCHER thread admits client requests (weighted-fair across
    tenants, in-flight caps) into per-(kind, pin, params) lanes and
    flushes due lanes as power-of-two batched dispatches;
  * an executor pool runs the flushes: freshest-version lanes acquire
    the CURRENT version at flush time (reads never block the writer,
    writer never blocks reads — the paper's snapshot guarantee), while
    session lanes run against their ``Session``'s pinned version.

Flush timing is deadline-driven (lanes.FLUSH_BUDGET_FRACTION): a lane
goes out when full, or when its oldest request has spent half its SLO
budget waiting — so light load degrades to latency-optimal batch size
1 and heavy load coalesces toward ``max_batch`` without ever blowing
deadlines on purpose.  Batches are padded to powers of two, so after
``warmup()`` steady-state serving replays compiled traces only
(``stats()["lanes"][kind]["retraces"]`` == 0, cross-checked against
``traversal.TRACES`` in tests).

Cross-request result cache (DESIGN.md §14): queries on an unchanged
version are pure functions of (version, kind, params, source), so the
service keeps a version-keyed ``ResultCache`` between the dispatcher
and the engines.  Exact hits are served AT SUBMIT TIME without touching
admission (misses still meter WFQ fairness — cache luck must not starve
anyone's real work), lanes consult the cache at flush time to shrink
the dispatched batch, and a PROMOTION thread carries hot entries across
publishes through the delta-aware incremental paths (the ``on_publish``
listener itself only sets an event — the writer never computes).  The
opt-in ``fastpath`` mode additionally serves singleton misses
synchronously on the caller thread when the executor is idle (batch=1
without the lane/ticket/executor hop); like ``work_conserving`` it is
off by default to keep flush accounting deterministic.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.streaming import AspenStream, UpdateQueue, drain_updates
from repro.core.traversal import TRACES

from . import lanes as L
from .admission import AdmissionQueue, QueueFull
from .metrics import LaneMetrics
from .request import KINDS, QueryTicket, params_key
from .result_cache import PROMOTE_BATCH, ResultCache
from .sessions import Session

__all__ = ["GraphQueryService", "QueueFull"]


class GraphQueryService:
    """See module docstring.  Lifecycle::

        service = GraphQueryService(stream, max_batch=64)
        service.start()          # or: with GraphQueryService(stream) as s:
        service.warmup()
        t = service.submit("bfs", source=0, tenant="alice")
        parents = t.result(timeout=5.0)
        service.stop()
    """

    def __init__(
        self,
        stream: AspenStream,
        backend: Optional[str] = None,
        max_batch: int = 64,
        n_workers: int = 1,
        default_deadline_s: float = 0.25,
        update_batch: int = 256,
        update_queue_size: Optional[int] = 65536,
        symmetric_updates: bool = True,
        tenant_weights: Optional[Dict[str, float]] = None,
        max_inflight_per_tenant: int = 256,
        max_inflight_total: int = 1024,
        max_backlog: int = 8192,
        poll_interval_s: float = 0.010,
        work_conserving: bool = False,
        result_cache: bool = True,
        cache_capacity: int = 512,
        carry_forward: bool = True,
        carry_limit: int = 32,
        fastpath: bool = False,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.stream = stream
        self.backend = backend if backend is not None else stream._default_backend()
        self.max_batch = int(max_batch)
        self.default_deadline_s = float(default_deadline_s)
        self.update_batch = int(update_batch)
        self.symmetric_updates = symmetric_updates
        self.updates = UpdateQueue(maxsize=update_queue_size)
        self._poll = poll_interval_s
        # work-conserving mode: when the executor sits idle, flush
        # whatever is pending instead of waiting out the half-budget
        # timer (continuous batching a la the decode server — batch
        # size adapts to arrival rate; the deadline rule still bounds
        # queueing when the executor is busy).  Off by default: the
        # strict policy gives deterministic flush accounting.
        self.work_conserving = work_conserving
        self._active_flushes = 0
        # cross-request result cache + delta carry-forward (DESIGN.md §14)
        self._cache = ResultCache(cache_capacity) if result_cache else None
        self._carry = bool(carry_forward) and result_cache
        self._carry_limit = int(carry_limit)
        self._fastpath = bool(fastpath)
        self._anchor = None  # the promotion thread's held previous version

        self._lock = threading.RLock()
        self._admission = AdmissionQueue(
            weights=tenant_weights,
            max_inflight_per_tenant=max_inflight_per_tenant,
            max_inflight_total=max_inflight_total,
            max_backlog=max_backlog,
        )
        self._lanes: Dict[Tuple, L.Lane] = {}
        self._kind_metrics: Dict[str, LaneMetrics] = {k: LaneMetrics() for k in KINDS}
        self._sessions: set = set()
        self._warm = False
        self._publishes = 0
        self._unsubscribe = None

        self._running = False
        self._draining = False
        self._writer_busy = False
        self._stop_writer = threading.Event()
        self._stop_dispatcher = threading.Event()
        self._wake = threading.Event()
        self._idle = threading.Condition(self._lock)
        self._executor: Optional[ThreadPoolExecutor] = None
        self._writer: Optional[threading.Thread] = None
        self._dispatcher: Optional[threading.Thread] = None
        self._promoter: Optional[threading.Thread] = None
        self._stop_promoter = threading.Event()
        self._promote_wake = threading.Event()
        self._promoting = False
        # capture waiters: post-publish misses whose key the in-flight
        # promotion pass is about to re-derive park here briefly
        # instead of re-entering the dispatch path (leaf lock)
        self._promo_cv = threading.Condition(threading.Lock())
        self._n_workers = int(n_workers)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "GraphQueryService":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._draining = False
        self._stop_writer.clear()
        self._stop_dispatcher.clear()
        self._unsubscribe = self.stream.on_publish(self._on_publish)
        self._executor = ThreadPoolExecutor(
            max_workers=self._n_workers, thread_name_prefix="graph-serve"
        )
        self._writer = threading.Thread(
            target=self._writer_loop, name="graph-serve-writer", daemon=True
        )
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="graph-serve-dispatch", daemon=True
        )
        self._writer.start()
        self._dispatcher.start()
        if self._cache is not None and self._carry:
            # the anchor is the version whose cached answers the next
            # carry-forward reads from; the promotion thread rotates it
            # publish by publish (never the writer's callback)
            self._anchor = self.stream.acquire()
            self._stop_promoter.clear()
            self._promote_wake.clear()
            self._promoter = threading.Thread(
                target=self._promote_loop, name="graph-serve-promote", daemon=True
            )
            self._promoter.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        """Graceful shutdown: stop accepting work, flush every queued
        ticket to completion, stop the writer after its current batch
        (leftover update-queue depth stays visible in ``stats()``),
        join the threads.  Idempotent."""
        with self._lock:
            if not self._running:
                return
            self._running = False     # submissions now rejected
            self._draining = True     # dispatcher flushes all lanes eagerly
        self._wake.set()
        deadline = time.perf_counter() + timeout
        with self._lock:
            self._idle.wait_for(
                self._drained_locked, timeout=max(0.0, deadline - time.perf_counter())
            )
        self._stop_dispatcher.set()
        self._stop_writer.set()
        self._wake.set()
        if self._dispatcher is not None:
            self._dispatcher.join(timeout=5.0)
        if self._writer is not None:
            self._writer.join(timeout=5.0)
        self._stop_promoter.set()
        self._promote_wake.set()
        if self._promoter is not None:
            self._promoter.join(timeout=5.0)
            self._promoter = None
        if self._anchor is not None:
            self.stream.release(self._anchor)
            self._anchor = None
        with self._promo_cv:  # capture waiters must not sit out the cap
            self._promo_cv.notify_all()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None

    def __enter__(self) -> "GraphQueryService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _drained_locked(self) -> bool:
        return (
            self._admission.backlog_depth() == 0
            and self._admission.in_flight_total == 0
        )

    # -- update side ---------------------------------------------------------
    def enqueue_update(
        self,
        src: int,
        dst: int,
        delete: bool = False,
        weight: Optional[float] = None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> bool:
        """Queue one edge mutation for the writer thread (the bounded
        queue is the backpressure surface: ``block=False`` on a full
        queue rejects and returns False)."""
        ok = self.updates.put(
            src, dst, delete=delete, weight=weight, block=block, timeout=timeout
        )
        return ok

    def insert_edges(self, edges: np.ndarray, block: bool = True) -> int:
        n = 0
        for s, d in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            n += bool(self.enqueue_update(int(s), int(d), block=block))
        return n

    def delete_edges(self, edges: np.ndarray, block: bool = True) -> int:
        n = 0
        for s, d in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            n += bool(self.enqueue_update(int(s), int(d), delete=True, block=block))
        return n

    def _writer_loop(self) -> None:
        while not self._stop_writer.is_set():
            # the busy flag must go up BEFORE the drain pops (a popped-
            # but-unpublished batch is invisible in queue depth, and the
            # first apply can sit in a jit compile for a while) — it is
            # what makes flush_updates a real publish barrier
            self._writer_busy = True
            k = drain_updates(
                self.updates, self.stream, self.update_batch,
                symmetric=self.symmetric_updates,
            )
            self._writer_busy = False
            if k == 0:
                self.updates.wait_nonempty(timeout=0.005)

    def _on_publish(self, v) -> None:
        # runs on the WRITER thread: the on_publish contract forbids
        # compute here, so carry-forward work only gets SIGNALLED
        with self._lock:
            self._publishes += 1
        if self._carry:
            self._promote_wake.set()

    def flush_updates(self, timeout: float = 30.0) -> None:
        """Block until every update queued so far has been PUBLISHED
        (writer catch-up barrier for tests / benchmarks).  Queue depth
        alone is not enough — the writer pops a batch before applying
        it — so this also waits out the busy flag the writer raises
        around each drain."""
        deadline = time.perf_counter() + timeout
        while len(self.updates) > 0 or self._writer_busy:
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"writer did not drain {len(self.updates)} updates in {timeout}s"
                )
            time.sleep(0.001)

    # -- carry-forward promotion ---------------------------------------------
    def _promote_loop(self) -> None:
        """Promotion thread: after each publish, carry hot cache
        entries from the held anchor version onto the current one
        through the incremental paths, then rotate the anchor.  At most
        one superseded version stays alive per rotation, so
        ``live_versions`` stays bounded under a continuous writer."""
        while not self._stop_promoter.is_set():
            self._promote_wake.wait(timeout=0.05)
            self._promote_wake.clear()
            if self._stop_promoter.is_set():
                break
            self._promote_once()

    def _promote_once(self) -> None:
        anchor = self._anchor
        if anchor is None or self._cache is None:
            return
        cur = self.stream.acquire()
        if cur.stamp == anchor.stamp:
            self.stream.release(cur)
            return
        self._promoting = True
        try:
            self._cache.carry_forward(
                self.stream, anchor, cur, self.backend, limit=self._carry_limit
            )
        except Exception:
            pass  # a failed round degrades hot entries to cold misses
        finally:
            self._anchor = cur
            self.stream.release(anchor)
            self._promoting = False
            # release the capture waiters first (their retry lookup is
            # the cheapest path to completion), then wake the
            # dispatcher so miss tickets that raced into lanes get
            # rescued by the flush-time consult instead of waiting out
            # the flush policy
            with self._promo_cv:
                self._promo_cv.notify_all()
            self._wake.set()

    def flush_promotions(self, timeout: float = 30.0) -> None:
        """Block until carry-forward has caught up with the writer's
        current version — the cache-side sibling of ``flush_updates``
        (promotion barrier for tests / deterministic replays).  No-op
        when the cache or carry-forward is off."""
        if self._cache is None or not self._carry:
            return
        deadline = time.perf_counter() + timeout
        while True:
            anchor = self._anchor
            if (
                anchor is not None
                and anchor.stamp >= self.stream.vg.current_stamp
                and not self._promoting
            ):
                return
            if time.perf_counter() > deadline:
                raise TimeoutError("carry-forward did not catch up in time")
            self._promote_wake.set()
            time.sleep(0.001)

    # -- query side ----------------------------------------------------------
    def submit(
        self,
        kind: str,
        source: Optional[int] = None,
        tenant: str = "default",
        deadline_s: Optional[float] = None,
        session: Optional[Session] = None,
        **params: Any,
    ) -> QueryTicket:
        """Submit one query; returns the ticket to block on.  Raises
        ``QueueFull`` when the tenant's backlog is at capacity (the
        client-visible backpressure signal).

        An exact result-cache hit (same version, kind, params, source)
        completes the ticket right here — no admission, no lane, no
        executor hop (``ticket.cached`` / ``ticket.fastpath``, batch
        size 0).  Misses are metered through admission as before; with
        ``fastpath=True`` a singleton miss on a fully idle service is
        additionally served synchronously on the calling thread."""
        budget = self.default_deadline_s if deadline_s is None else float(deadline_s)
        ticket = QueryTicket(
            tenant, kind, source, params,
            deadline=time.perf_counter() + budget,
            session=session,
        )
        hit_value = None
        sync = False
        capture = 0
        with self._lock:
            if not self._running:
                raise RuntimeError("service is not running")
            if self._cache is not None:
                ent = self._cache_lookup_locked(ticket, session)
                if ent is not None:
                    self._meter_hit_locked(ticket)
                    hit_value = ent.value
                elif session is None and self._carry:
                    # post-publish blind window: if the key this miss
                    # wants is hot on the promotion anchor, the pass in
                    # flight is about to re-derive it — park on the
                    # pass instead of recomputing through dispatch
                    anchor = self._anchor
                    cur_stamp = self.stream.vg.current_stamp
                    if anchor is not None and (
                        anchor.stamp < cur_stamp or self._promoting
                    ):
                        skey = (
                            None if ticket.kind == "cc" else ticket.source
                        )
                        prev = self._cache.peek(
                            anchor, ticket.kind, ticket.pkey, skey
                        )
                        if prev is not None and prev.hits > 0:
                            capture = cur_stamp
            if hit_value is None and not capture:
                sync = self._admit_locked(ticket)
        if hit_value is not None:
            self._finish_hit(ticket, hit_value, session)
            return ticket
        if capture:
            return self._capture_wait(ticket, session, capture)
        if sync:
            self._run_sync(ticket)
            return ticket
        self._wake.set()
        return ticket

    def _meter_hit_locked(self, ticket: QueryTicket) -> None:
        # meter the tenant ledger (the TenantMetrics identity
        # invariants stay snapshot-exact) but never its WFQ pass:
        # admission arbitrates real engine work only
        tm = self._admission.tenant(ticket.tenant).metrics
        tm.submitted += 1
        tm.admitted += 1
        tm.completed += 1
        tm.cached += 1
        m = self._kind_metrics[ticket.kind]
        m.cache_hits += 1
        m.fastpath_hits += 1

    def _admit_locked(self, ticket: QueryTicket) -> bool:
        """Meter the miss through admission; True when the fastpath
        claimed it for synchronous execution on the caller thread."""
        self._admission.submit(ticket)
        if (
            self._fastpath
            and self._admission.in_flight_total == 0
            and self._active_flushes == 0
            and self._admission.backlog_depth() == 1
        ):
            # idle service, our ticket is the whole backlog: admit it
            # (vpass advances — it IS real work) and run it on this
            # thread, skipping the executor hop
            if self._admission.admit(max_n=1):
                return True
        return False

    @staticmethod
    def _finish_hit(ticket: QueryTicket, value, session) -> None:
        ticket.t_flush = time.perf_counter()
        ticket.batch_size = 0
        ticket.cached = True
        ticket.fastpath = True
        ticket._complete(value)
        if session is not None:
            session._query_done(ticket)

    # longest a captured miss parks on an in-flight promotion pass
    # before giving up and dispatching normally — the common wait is
    # one batched incremental dispatch, a few ms
    CAPTURE_WAIT_S = 0.1

    def _capture_wait(
        self, ticket: QueryTicket, session, stamp: int
    ) -> QueryTicket:
        """Park a post-publish miss until the in-flight carry-forward
        pass lands, then retry the lookup.  Without this, every publish
        turns the whole hot set cold at once and every closed-loop
        client recomputes its hot key through the full dispatch path —
        duplicating the promotion work and convoying the executor; with
        it, the storm rides ONE batched promotion."""
        end = min(time.perf_counter() + self.CAPTURE_WAIT_S, ticket.deadline)
        with self._promo_cv:
            while True:
                a = self._anchor
                if a is None or (a.stamp >= stamp and not self._promoting):
                    break
                left = end - time.perf_counter()
                if left <= 0:
                    break
                self._promo_cv.wait(left)
        hit_value = None
        sync = False
        with self._lock:
            if not self._running:
                raise RuntimeError("service is not running")
            ent = (
                None if self._cache is None
                else self._cache_lookup_locked(ticket, session)
            )
            if ent is not None:
                self._meter_hit_locked(ticket)
                self._kind_metrics[ticket.kind].capture_hits += 1
                hit_value = ent.value
            else:
                sync = self._admit_locked(ticket)
        if hit_value is not None:
            self._finish_hit(ticket, hit_value, session)
            return ticket
        if sync:
            self._run_sync(ticket)
            return ticket
        self._wake.set()
        return ticket

    def _cache_lookup_locked(self, ticket: QueryTicket, session):
        """Exact-hit lookup against the version this ticket would be
        served on: the session's pinned version, or the stream's current
        one — so a pinned session can never see a newer version's cached
        answer, and a freshest read never a stale one."""
        skey = None if ticket.kind == "cc" else ticket.source
        if session is not None:
            return self._cache.get(session.version, ticket.kind, ticket.pkey, skey)
        a = self._anchor
        if a is not None and a is self.stream.vg._current:
            # the promotion anchor IS the current version and the
            # service already holds a ref: skip the acquire/release
            # round trip through the version-graph lock (the hot hit
            # path runs per request; a publish racing past the
            # identity check linearizes the same way it would racing
            # past an acquire)
            return self._cache.get(a, ticket.kind, ticket.pkey, skey)
        v = self.stream.acquire()
        try:
            return self._cache.get(v, ticket.kind, ticket.pkey, skey)
        finally:
            self.stream.release(v)

    def _run_sync(self, ticket: QueryTicket) -> None:
        """Opt-in batch=1 fast path: the executor is idle and nothing
        else is queued, so serve the singleton miss on the CALLER
        thread.  The ticket went through admission normally; only the
        lane wait and the executor handoff are skipped."""
        session = ticket.session
        m = self._kind_metrics[ticket.kind]
        v = None
        error: Optional[BaseException] = None
        try:
            if session is not None:
                ver = session.version
            else:
                v = self.stream.acquire()
                ver = v
            eng = self.stream._engine_for(ver, self.backend)
            key = L.trace_key(
                ticket.kind, eng, L.dispatch_pow2(ticket.kind, [ticket]),
                ticket.pkey,
            )
            with self._lock:
                m.fastpath_syncs += 1
                if key is not None:
                    m.record_trace_key(key, warm=self._warm)
            ticket.fastpath = True
            L.execute_batch(
                eng, ticket.kind, [ticket], dict(ticket.params),
                cache=self._cache, version=ver,
            )
        except BaseException as exc:  # noqa: BLE001 - surfaces at result()
            error = exc
            if not ticket.done():
                ticket._fail(exc)
        finally:
            if v is not None:
                self.stream.release(v)
            with self._lock:
                self._admission.complete(ticket)
                if error is None and ticket.deadline_missed:
                    m.deadline_misses += 1
                if error is not None:
                    m.errors += 1
                self._idle.notify_all()
            if session is not None:
                session._query_done(ticket)

    def query(self, kind: str, source: Optional[int] = None, timeout: float = 30.0,
              **kw) -> np.ndarray:
        """Blocking convenience: submit + wait."""
        return self.submit(kind, source=source, **kw).result(timeout=timeout)

    def session(self, tenant: str = "default") -> Session:
        """Open a snapshot-pinned session (see ``sessions.Session``)."""
        with self._lock:
            if not self._running:
                raise RuntimeError("service is not running")
            s = Session(self, tenant)
            self._sessions.add(s)
        return s

    def _forget_session(self, s: Session) -> None:
        with self._lock:
            self._sessions.discard(s)

    # -- dispatcher ----------------------------------------------------------
    def _lane_for(self, ticket: QueryTicket) -> L.Lane:
        key = (ticket.kind, ticket.session, ticket.pkey, self.backend)
        lane = self._lanes.get(key)
        if lane is None:
            lane = L.Lane(
                ticket.kind, ticket.session, ticket.pkey, self.backend,
                self._kind_metrics[ticket.kind],
            )
            self._lanes[key] = lane
        return lane

    def _dispatch_loop(self) -> None:
        while not self._stop_dispatcher.is_set():
            batches: List[Tuple[L.Lane, List[QueryTicket]]] = []
            with self._lock:
                for t in self._admission.admit():
                    self._lane_for(t).add(t)
                now = time.perf_counter()
                next_due = float("inf")
                for key in list(self._lanes):
                    lane = self._lanes[key]
                    if not lane.pending:
                        del self._lanes[key]
                        continue
                    if self._draining or lane.due(now, self.max_batch):
                        reason = (
                            "full"
                            if len(lane.pending) >= self.max_batch
                            else "deadline"
                        )
                        batch = lane.take(self.max_batch)
                        lane.metrics.record_flush(len(batch), reason=reason)
                        batches.append((lane, batch))
                        if lane.pending:
                            next_due = min(next_due, lane.flush_at())
                    else:
                        next_due = min(next_due, lane.flush_at())
                if self.work_conserving and not self._draining:
                    # fill free executor slots with the oldest waiting
                    # lanes: batch size adapts to arrival rate instead
                    # of stalling on the half-budget timer
                    while self._active_flushes + len(batches) < self._n_workers:
                        waiting = [l for l in self._lanes.values() if l.pending]
                        if not waiting:
                            break
                        lane = min(waiting, key=lambda l: l.pending[0].t_submit)
                        batch = lane.take(self.max_batch)
                        lane.metrics.record_flush(len(batch), reason="idle")
                        batches.append((lane, batch))
                self._active_flushes += len(batches)
            for lane, batch in batches:
                self._executor.submit(self._run_flush, lane, batch)
            if batches:
                continue  # more work may be admissible right away
            wait = self._poll
            if next_due != float("inf"):
                wait = min(wait, max(0.0, next_due - time.perf_counter()))
            self._wake.wait(timeout=max(wait, 0.0005))
            self._wake.clear()

    def _run_flush(self, lane: L.Lane, batch: List[QueryTicket]) -> None:
        """Executor job: pin the serving version (freshest or session),
        consult the result cache (flush-time dedup across time: hits
        drop out of the dispatch), note the trace key for the SHRUNK
        batch, execute, then settle accounting."""
        params = dict(batch[0].params)
        v = None
        n_cached = 0
        error: Optional[BaseException] = None
        try:
            if lane.pin is not None:
                ver = lane.pin.version
            else:
                v = self.stream.acquire()
                ver = v
            live = L.serve_cached(self._cache, ver, lane.kind, batch)
            n_cached = len(batch) - len(live)
            if live:
                eng = self.stream._engine_for(ver, self.backend)
                key = L.trace_key(
                    lane.kind, eng, L.dispatch_pow2(lane.kind, live), lane.pkey
                )
                if key is not None:
                    with self._lock:
                        lane.metrics.record_trace_key(key, warm=self._warm)
                L.execute_batch(
                    eng, lane.kind, live, params,
                    cache=self._cache, version=ver,
                )
        except BaseException as exc:  # noqa: BLE001 - fail the tickets, not the service
            error = exc
            for t in batch:
                if not t.done():
                    t._fail(exc)
        finally:
            if v is not None:
                self.stream.release(v)
            with self._lock:
                self._active_flushes -= 1
                lane.metrics.cache_hits += n_cached
                for t in batch:
                    self._admission.complete(t)
                    if error is None and t.deadline_missed:
                        lane.metrics.deadline_misses += 1
                if error is not None:
                    lane.metrics.errors += len(batch)
                self._idle.notify_all()
            for t in batch:
                if t.session is not None:
                    t.session._query_done(t)
            self._wake.set()

    def wait_idle(self, timeout: float = 30.0) -> None:
        """Block until no queued or in-flight queries remain."""
        deadline = time.perf_counter() + timeout
        with self._lock:
            if not self._idle.wait_for(
                self._drained_locked, timeout=max(0.0, deadline - time.perf_counter())
            ):
                raise TimeoutError("service did not go idle in time")

    # -- warmup & observability ---------------------------------------------
    def warmup(self, kinds=KINDS, **params: Any) -> None:
        """Pre-compile the power-of-two trace ladder: one synthetic
        dispatch per (kind, pow2 size <= max_batch) against the current
        version, then flip warm — from here on any NEW trace key counts
        as a retrace in ``stats()``.  Covers the default-params lanes
        (``params`` here must match what clients will send)."""
        pkey = params_key(params)
        sizes: List[int] = []
        b = 1
        while b < self.max_batch:
            sizes.append(b)
            b <<= 1
        sizes.append(L.next_pow2(self.max_batch))
        v = self.stream.acquire()
        try:
            eng = self.stream._engine_for(v, self.backend)
            n = eng.n
            for kind in kinds:
                ladder = [1] if kind == "cc" else sizes
                for size in ladder:
                    srcs = [i % max(n, 1) for i in range(size)]
                    tickets = [
                        QueryTicket(
                            "_warmup", kind,
                            None if kind == "cc" else srcs[i],
                            params, deadline=time.perf_counter() + 60.0,
                        )
                        for i in range(size)
                    ]
                    L.execute_batch(eng, kind, tickets, dict(params))
                    key = L.trace_key(
                        kind, eng, L.dispatch_pow2(kind, tickets), pkey
                    )
                    if key is not None:
                        with self._lock:
                            self._kind_metrics[kind].record_trace_key(
                                key, warm=False
                            )
            if self._carry and n:
                self._warm_promotion(eng, kinds)
        finally:
            self.stream.release(v)
        self.mark_warm()

    def _warm_promotion(self, eng, kinds) -> None:
        """Pre-trace the carry-forward path: promotion replays the
        incremental drivers (warm-seeded ``sssp_batch_from``,
        depth→parents, the dense shortest-path-tree pass) the moment
        the first publish lands, and a compile there stalls the
        promotion thread exactly while the hot entries sit stale on
        the old version.  Results are discarded; a self-loop insert is
        a no-op delta, so every call converges instantly once traced."""
        from repro.core.traversal import algorithms as talg
        from repro.core.versioning import Delta

        d = Delta(ins=np.asarray([[0, 0]], np.int64))
        sizes: List[int] = [1]
        while sizes[-1] * 2 <= PROMOTE_BATCH:
            sizes.append(sizes[-1] * 2)
        for b in sizes:
            srcs = [0] * b
            if "bfs" in kinds:
                parents, depths = talg.bfs_multi(eng, srcs)
                talg.incremental_bfs(eng, srcs, parents, depths, d)
            if "sssp" in kinds:
                dist = talg.sssp_multi(eng, srcs)
                if b == 1:  # per-lane host loop: shape is B-independent
                    tree = talg.shortest_path_parents(eng, dist, srcs)
                else:
                    tree = np.repeat(tree[:1], b, axis=0)
                talg.incremental_sssp(eng, srcs, dist, tree, d)
        if "cc" in kinds:
            labels = talg.connected_components(eng)
            talg.incremental_connected_components(eng, labels, d)
        if "pagerank" in kinds:
            reset = np.full((1, eng.n), 1.0 / max(eng.n, 1))
            pr = talg.pagerank_multi(eng, resets=reset)
            # the tol path is the only promotion variant with its own
            # trace (fixed-iters promotion recomputes on the ladder)
            talg.pagerank_multi(eng, resets=reset, init=pr,
                                tol=1e-6, max_iters=4)

    def mark_warm(self) -> None:
        """Flip the steady-state flag: every trace key first seen after
        this counts as a retrace."""
        with self._lock:
            self._warm = True

    def stats(self) -> dict:
        with self._lock:
            return {
                "running": self._running,
                "warm": self._warm,
                "backend": self.backend,
                "max_batch": self.max_batch,
                "publishes": self._publishes,
                "version_stamp": self.stream.vg.current_stamp,
                "live_versions": self.stream.vg.live_versions(),
                "sessions_open": len(self._sessions),
                "lanes": {
                    k: m.snapshot() for k, m in self._kind_metrics.items()
                },
                "tenants": self._admission.snapshot(),
                "admission": {
                    "backlog": self._admission.backlog_depth(),
                    "in_flight": self._admission.in_flight_total,
                    "max_inflight_total": self._admission.max_inflight_total,
                    "active_flushes": self._active_flushes,
                    "work_conserving": self.work_conserving,
                },
                "updates": self.updates.stats(),
                "cache": None if self._cache is None else dict(
                    self._cache.snapshot(),
                    carry_forward=self._carry,
                    carry_limit=self._carry_limit,
                    fastpath=self._fastpath,
                    anchor_stamp=(
                        None if self._anchor is None else self._anchor.stamp
                    ),
                ),
                "jit_traces": TRACES.count,
            }
