"""GraphQueryService: continuous graph updates + SLO-aware batched reads.

The service runs the paper's single-writer / many-reader regime as a
long-lived server over one ``AspenStream``:

  * a dedicated WRITER thread drains the bounded update queue in
    batches through ``core.streaming.drain_updates`` — the same loop
    body ``run_concurrent`` uses — publishing each batch atomically as
    one new version;
  * a DISPATCHER thread admits client requests (weighted-fair across
    tenants, in-flight caps) into per-(kind, pin, params) lanes and
    flushes due lanes as power-of-two batched dispatches;
  * an executor pool runs the flushes: freshest-version lanes acquire
    the CURRENT version at flush time (reads never block the writer,
    writer never blocks reads — the paper's snapshot guarantee), while
    session lanes run against their ``Session``'s pinned version.

Flush timing is deadline-driven (lanes.FLUSH_BUDGET_FRACTION): a lane
goes out when full, or when its oldest request has spent half its SLO
budget waiting — so light load degrades to latency-optimal batch size
1 and heavy load coalesces toward ``max_batch`` without ever blowing
deadlines on purpose.  Batches are padded to powers of two, so after
``warmup()`` steady-state serving replays compiled traces only
(``stats()["lanes"][kind]["retraces"]`` == 0, cross-checked against
``traversal.TRACES`` in tests).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.streaming import AspenStream, UpdateQueue, drain_updates
from repro.core.traversal import TRACES

from . import lanes as L
from .admission import AdmissionQueue, QueueFull
from .metrics import LaneMetrics
from .request import KINDS, QueryTicket, params_key
from .sessions import Session

__all__ = ["GraphQueryService", "QueueFull"]


class GraphQueryService:
    """See module docstring.  Lifecycle::

        service = GraphQueryService(stream, max_batch=64)
        service.start()          # or: with GraphQueryService(stream) as s:
        service.warmup()
        t = service.submit("bfs", source=0, tenant="alice")
        parents = t.result(timeout=5.0)
        service.stop()
    """

    def __init__(
        self,
        stream: AspenStream,
        backend: Optional[str] = None,
        max_batch: int = 64,
        n_workers: int = 1,
        default_deadline_s: float = 0.25,
        update_batch: int = 256,
        update_queue_size: Optional[int] = 65536,
        symmetric_updates: bool = True,
        tenant_weights: Optional[Dict[str, float]] = None,
        max_inflight_per_tenant: int = 256,
        max_inflight_total: int = 1024,
        max_backlog: int = 8192,
        poll_interval_s: float = 0.010,
        work_conserving: bool = False,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.stream = stream
        self.backend = backend if backend is not None else stream._default_backend()
        self.max_batch = int(max_batch)
        self.default_deadline_s = float(default_deadline_s)
        self.update_batch = int(update_batch)
        self.symmetric_updates = symmetric_updates
        self.updates = UpdateQueue(maxsize=update_queue_size)
        self._poll = poll_interval_s
        # work-conserving mode: when the executor sits idle, flush
        # whatever is pending instead of waiting out the half-budget
        # timer (continuous batching a la the decode server — batch
        # size adapts to arrival rate; the deadline rule still bounds
        # queueing when the executor is busy).  Off by default: the
        # strict policy gives deterministic flush accounting.
        self.work_conserving = work_conserving
        self._active_flushes = 0

        self._lock = threading.RLock()
        self._admission = AdmissionQueue(
            weights=tenant_weights,
            max_inflight_per_tenant=max_inflight_per_tenant,
            max_inflight_total=max_inflight_total,
            max_backlog=max_backlog,
        )
        self._lanes: Dict[Tuple, L.Lane] = {}
        self._kind_metrics: Dict[str, LaneMetrics] = {k: LaneMetrics() for k in KINDS}
        self._sessions: set = set()
        self._warm = False
        self._publishes = 0
        self._unsubscribe = None

        self._running = False
        self._draining = False
        self._writer_busy = False
        self._stop_writer = threading.Event()
        self._stop_dispatcher = threading.Event()
        self._wake = threading.Event()
        self._idle = threading.Condition(self._lock)
        self._executor: Optional[ThreadPoolExecutor] = None
        self._writer: Optional[threading.Thread] = None
        self._dispatcher: Optional[threading.Thread] = None
        self._n_workers = int(n_workers)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "GraphQueryService":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._draining = False
        self._stop_writer.clear()
        self._stop_dispatcher.clear()
        self._unsubscribe = self.stream.on_publish(self._on_publish)
        self._executor = ThreadPoolExecutor(
            max_workers=self._n_workers, thread_name_prefix="graph-serve"
        )
        self._writer = threading.Thread(
            target=self._writer_loop, name="graph-serve-writer", daemon=True
        )
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="graph-serve-dispatch", daemon=True
        )
        self._writer.start()
        self._dispatcher.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        """Graceful shutdown: stop accepting work, flush every queued
        ticket to completion, stop the writer after its current batch
        (leftover update-queue depth stays visible in ``stats()``),
        join the threads.  Idempotent."""
        with self._lock:
            if not self._running:
                return
            self._running = False     # submissions now rejected
            self._draining = True     # dispatcher flushes all lanes eagerly
        self._wake.set()
        deadline = time.perf_counter() + timeout
        with self._lock:
            self._idle.wait_for(
                self._drained_locked, timeout=max(0.0, deadline - time.perf_counter())
            )
        self._stop_dispatcher.set()
        self._stop_writer.set()
        self._wake.set()
        if self._dispatcher is not None:
            self._dispatcher.join(timeout=5.0)
        if self._writer is not None:
            self._writer.join(timeout=5.0)
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None

    def __enter__(self) -> "GraphQueryService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _drained_locked(self) -> bool:
        return (
            self._admission.backlog_depth() == 0
            and self._admission.in_flight_total == 0
        )

    # -- update side ---------------------------------------------------------
    def enqueue_update(
        self,
        src: int,
        dst: int,
        delete: bool = False,
        weight: Optional[float] = None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> bool:
        """Queue one edge mutation for the writer thread (the bounded
        queue is the backpressure surface: ``block=False`` on a full
        queue rejects and returns False)."""
        ok = self.updates.put(
            src, dst, delete=delete, weight=weight, block=block, timeout=timeout
        )
        return ok

    def insert_edges(self, edges: np.ndarray, block: bool = True) -> int:
        n = 0
        for s, d in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            n += bool(self.enqueue_update(int(s), int(d), block=block))
        return n

    def delete_edges(self, edges: np.ndarray, block: bool = True) -> int:
        n = 0
        for s, d in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            n += bool(self.enqueue_update(int(s), int(d), delete=True, block=block))
        return n

    def _writer_loop(self) -> None:
        while not self._stop_writer.is_set():
            # the busy flag must go up BEFORE the drain pops (a popped-
            # but-unpublished batch is invisible in queue depth, and the
            # first apply can sit in a jit compile for a while) — it is
            # what makes flush_updates a real publish barrier
            self._writer_busy = True
            k = drain_updates(
                self.updates, self.stream, self.update_batch,
                symmetric=self.symmetric_updates,
            )
            self._writer_busy = False
            if k == 0:
                self.updates.wait_nonempty(timeout=0.005)

    def _on_publish(self, v) -> None:
        with self._lock:
            self._publishes += 1

    def flush_updates(self, timeout: float = 30.0) -> None:
        """Block until every update queued so far has been PUBLISHED
        (writer catch-up barrier for tests / benchmarks).  Queue depth
        alone is not enough — the writer pops a batch before applying
        it — so this also waits out the busy flag the writer raises
        around each drain."""
        deadline = time.perf_counter() + timeout
        while len(self.updates) > 0 or self._writer_busy:
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"writer did not drain {len(self.updates)} updates in {timeout}s"
                )
            time.sleep(0.001)

    # -- query side ----------------------------------------------------------
    def submit(
        self,
        kind: str,
        source: Optional[int] = None,
        tenant: str = "default",
        deadline_s: Optional[float] = None,
        session: Optional[Session] = None,
        **params: Any,
    ) -> QueryTicket:
        """Submit one query; returns the ticket to block on.  Raises
        ``QueueFull`` when the tenant's backlog is at capacity (the
        client-visible backpressure signal)."""
        budget = self.default_deadline_s if deadline_s is None else float(deadline_s)
        ticket = QueryTicket(
            tenant, kind, source, params,
            deadline=time.perf_counter() + budget,
            session=session,
        )
        with self._lock:
            if not self._running:
                raise RuntimeError("service is not running")
            self._admission.submit(ticket)
        self._wake.set()
        return ticket

    def query(self, kind: str, source: Optional[int] = None, timeout: float = 30.0,
              **kw) -> np.ndarray:
        """Blocking convenience: submit + wait."""
        return self.submit(kind, source=source, **kw).result(timeout=timeout)

    def session(self, tenant: str = "default") -> Session:
        """Open a snapshot-pinned session (see ``sessions.Session``)."""
        with self._lock:
            if not self._running:
                raise RuntimeError("service is not running")
            s = Session(self, tenant)
            self._sessions.add(s)
        return s

    def _forget_session(self, s: Session) -> None:
        with self._lock:
            self._sessions.discard(s)

    # -- dispatcher ----------------------------------------------------------
    def _lane_for(self, ticket: QueryTicket) -> L.Lane:
        key = (ticket.kind, ticket.session, ticket.pkey, self.backend)
        lane = self._lanes.get(key)
        if lane is None:
            lane = L.Lane(
                ticket.kind, ticket.session, ticket.pkey, self.backend,
                self._kind_metrics[ticket.kind],
            )
            self._lanes[key] = lane
        return lane

    def _dispatch_loop(self) -> None:
        while not self._stop_dispatcher.is_set():
            batches: List[Tuple[L.Lane, List[QueryTicket]]] = []
            with self._lock:
                for t in self._admission.admit():
                    self._lane_for(t).add(t)
                now = time.perf_counter()
                next_due = float("inf")
                for key in list(self._lanes):
                    lane = self._lanes[key]
                    if not lane.pending:
                        del self._lanes[key]
                        continue
                    if self._draining or lane.due(now, self.max_batch):
                        reason = (
                            "full"
                            if len(lane.pending) >= self.max_batch
                            else "deadline"
                        )
                        batch = lane.take(self.max_batch)
                        lane.metrics.record_flush(len(batch), reason=reason)
                        batches.append((lane, batch))
                        if lane.pending:
                            next_due = min(next_due, lane.flush_at())
                    else:
                        next_due = min(next_due, lane.flush_at())
                if self.work_conserving and not self._draining:
                    # fill free executor slots with the oldest waiting
                    # lanes: batch size adapts to arrival rate instead
                    # of stalling on the half-budget timer
                    while self._active_flushes + len(batches) < self._n_workers:
                        waiting = [l for l in self._lanes.values() if l.pending]
                        if not waiting:
                            break
                        lane = min(waiting, key=lambda l: l.pending[0].t_submit)
                        batch = lane.take(self.max_batch)
                        lane.metrics.record_flush(len(batch), reason="idle")
                        batches.append((lane, batch))
                self._active_flushes += len(batches)
            for lane, batch in batches:
                self._executor.submit(self._run_flush, lane, batch)
            if batches:
                continue  # more work may be admissible right away
            wait = self._poll
            if next_due != float("inf"):
                wait = min(wait, max(0.0, next_due - time.perf_counter()))
            self._wake.wait(timeout=max(wait, 0.0005))
            self._wake.clear()

    def _run_flush(self, lane: L.Lane, batch: List[QueryTicket]) -> None:
        """Executor job: pin an engine (freshest or session version),
        note the trace key, execute, then settle accounting."""
        params = dict(batch[0].params)
        v = None
        error: Optional[BaseException] = None
        try:
            if lane.pin is not None:
                eng = self.stream._engine_for(lane.pin.version, self.backend)
            else:
                v = self.stream.acquire()
                eng = self.stream._engine_for(v, self.backend)
            key = L.trace_key(
                lane.kind, eng, L.dispatch_pow2(lane.kind, batch), lane.pkey
            )
            if key is not None:
                with self._lock:
                    lane.metrics.record_trace_key(key, warm=self._warm)
            L.execute_batch(eng, lane.kind, batch, params)
        except BaseException as exc:  # noqa: BLE001 - fail the tickets, not the service
            error = exc
            for t in batch:
                if not t.done():
                    t._fail(exc)
        finally:
            if v is not None:
                self.stream.release(v)
            with self._lock:
                self._active_flushes -= 1
                for t in batch:
                    self._admission.complete(t)
                    if error is None and t.deadline_missed:
                        lane.metrics.deadline_misses += 1
                if error is not None:
                    lane.metrics.errors += len(batch)
                self._idle.notify_all()
            for t in batch:
                if t.session is not None:
                    t.session._query_done(t)
            self._wake.set()

    def wait_idle(self, timeout: float = 30.0) -> None:
        """Block until no queued or in-flight queries remain."""
        deadline = time.perf_counter() + timeout
        with self._lock:
            if not self._idle.wait_for(
                self._drained_locked, timeout=max(0.0, deadline - time.perf_counter())
            ):
                raise TimeoutError("service did not go idle in time")

    # -- warmup & observability ---------------------------------------------
    def warmup(self, kinds=KINDS, **params: Any) -> None:
        """Pre-compile the power-of-two trace ladder: one synthetic
        dispatch per (kind, pow2 size <= max_batch) against the current
        version, then flip warm — from here on any NEW trace key counts
        as a retrace in ``stats()``.  Covers the default-params lanes
        (``params`` here must match what clients will send)."""
        pkey = params_key(params)
        sizes: List[int] = []
        b = 1
        while b < self.max_batch:
            sizes.append(b)
            b <<= 1
        sizes.append(L.next_pow2(self.max_batch))
        v = self.stream.acquire()
        try:
            eng = self.stream._engine_for(v, self.backend)
            n = eng.n
            for kind in kinds:
                ladder = [1] if kind == "cc" else sizes
                for size in ladder:
                    srcs = [i % max(n, 1) for i in range(size)]
                    tickets = [
                        QueryTicket(
                            "_warmup", kind,
                            None if kind == "cc" else srcs[i],
                            params, deadline=time.perf_counter() + 60.0,
                        )
                        for i in range(size)
                    ]
                    L.execute_batch(eng, kind, tickets, dict(params))
                    key = L.trace_key(
                        kind, eng, L.dispatch_pow2(kind, tickets), pkey
                    )
                    if key is not None:
                        with self._lock:
                            self._kind_metrics[kind].record_trace_key(
                                key, warm=False
                            )
        finally:
            self.stream.release(v)
        self.mark_warm()

    def mark_warm(self) -> None:
        """Flip the steady-state flag: every trace key first seen after
        this counts as a retrace."""
        with self._lock:
            self._warm = True

    def stats(self) -> dict:
        with self._lock:
            return {
                "running": self._running,
                "warm": self._warm,
                "backend": self.backend,
                "max_batch": self.max_batch,
                "publishes": self._publishes,
                "version_stamp": self.stream.vg.current_stamp,
                "live_versions": self.stream.vg.live_versions(),
                "sessions_open": len(self._sessions),
                "lanes": {
                    k: m.snapshot() for k, m in self._kind_metrics.items()
                },
                "tenants": self._admission.snapshot(),
                "admission": {
                    "backlog": self._admission.backlog_depth(),
                    "in_flight": self._admission.in_flight_total,
                    "max_inflight_total": self._admission.max_inflight_total,
                    "active_flushes": self._active_flushes,
                    "work_conserving": self.work_conserving,
                },
                "updates": self.updates.stats(),
                "jit_traces": TRACES.count,
            }
