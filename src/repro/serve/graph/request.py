"""Request plumbing for the graph-query service.

A ``QueryTicket`` is both the internal request record (timestamps the
admission / flush pipeline stamps as it moves through) and the handle
the client blocks on.  Results are host numpy arrays: one row of the
lane's batched answer (bfs parents / sssp distances / pagerank scores),
or the shared whole-graph array for global kinds (cc).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional, Tuple

# lane kinds the service batches (ISSUE 9 / DESIGN.md §13):
#   bfs      source required  -> int64[n] parent row
#   sssp     source required  -> float64[n] distance row
#   pagerank source optional  -> float[n] scores (one-hot personalization
#            at ``source``; None = the global uniform reset row)
#   cc       no source        -> int64[n] component labels (global; every
#            request in the flush shares one computation)
KINDS = ("bfs", "sssp", "pagerank", "cc")
SOURCE_REQUIRED = ("bfs", "sssp")


def params_key(params: Dict[str, Any]) -> Tuple:
    """Hashable lane-splitting key: requests batch together only when
    their extra algorithm parameters agree (mixing e.g. two dampings in
    one pagerank flush would silently answer one of them wrong)."""
    return tuple(sorted(params.items()))


class QueryTicket:
    """One admitted query: the client-facing future plus the service's
    internal pipeline record.

    Lifecycle timestamps (``time.perf_counter`` seconds) are stamped by
    the pipeline: ``t_submit`` at submission, ``t_flush`` when its lane
    batch left for the executor, ``t_done`` at completion.  ``deadline``
    is the absolute SLO instant; ``deadline_missed`` is judged at
    completion time.  ``batch_size`` records how many requests rode the
    flush that served this ticket (the coalescing the bench reports).
    """

    __slots__ = (
        "tenant", "kind", "source", "params", "pkey", "session",
        "deadline", "t_submit", "t_flush", "t_done", "batch_size",
        "cached", "fastpath", "_event", "_result", "_error",
    )

    def __init__(
        self,
        tenant: str,
        kind: str,
        source: Optional[int],
        params: Dict[str, Any],
        deadline: float,
        session=None,
    ):
        if kind not in KINDS:
            raise ValueError(f"unknown query kind {kind!r}; one of {KINDS}")
        if source is None and kind in SOURCE_REQUIRED:
            raise ValueError(f"{kind!r} queries need a source vertex")
        self.tenant = tenant
        self.kind = kind
        self.source = None if source is None else int(source)
        self.params = params
        self.pkey = params_key(params)
        self.session = session
        self.deadline = deadline
        self.t_submit = time.perf_counter()
        self.t_flush: Optional[float] = None
        self.t_done: Optional[float] = None
        self.batch_size: Optional[int] = None
        self.cached = False    # served from the result cache (batch_size 0)
        self.fastpath = False  # served at submit time, no lane/executor hop
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    # -- service side -------------------------------------------------------
    def _complete(self, result) -> None:
        self.t_done = time.perf_counter()
        self._result = result
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self.t_done = time.perf_counter()
        self._error = exc
        self._event.set()

    # -- client side --------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block for the answer (re-raises a service-side failure)."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"{self.kind} query for tenant {self.tenant!r} not served "
                f"within {timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def deadline_missed(self) -> Optional[bool]:
        """None until completed; then whether the answer landed past the
        SLO instant."""
        return None if self.t_done is None else self.t_done > self.deadline

    def __repr__(self):
        state = "done" if self.done() else "pending"
        return (
            f"QueryTicket({self.kind}, tenant={self.tenant!r}, "
            f"source={self.source}, {state})"
        )
