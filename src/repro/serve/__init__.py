"""Serving layer.

``repro.serve.decode`` — batched prefill + decode for the transformer
models (the original continuous-batching exemplar).

``repro.serve.graph`` — the SLO-aware multi-tenant graph-query service
over a live ``AspenStream`` (DESIGN.md §13): per-kind query lanes with
deadline-based flush, weighted-fair tenant admission, and
snapshot-pinned sessions exposing the paper's strict-serializability
guarantee as an API.
"""
