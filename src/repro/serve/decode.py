"""Serving loop: batched prefill + decode with a KV cache.

``serve_step`` (one new token per sequence) is the function the
``decode_*`` / ``long_*`` dry-run shapes lower; ``generate`` drives it
host-side with greedy/temperature sampling.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T


def make_serve_step(cfg, use_flash_kernel: bool = False):
    """Returns serve_step(params, cache, token) -> (logits, cache')."""

    def serve_step(params, cache, token):
        return T.decode_step(params, cfg, cache, token, use_flash_kernel=use_flash_kernel)

    return serve_step


def make_prefill(cfg):
    def prefill_fn(params, tokens):
        logits = T.prefill(params, cfg, tokens)
        return logits[:, -1]  # next-token logits

    return prefill_fn


def generate(
    params,
    cfg,
    prompt: jax.Array,  # (B, S0)
    max_new: int,
    max_len: Optional[int] = None,
    temperature: float = 0.0,
    key=None,
    use_flash_kernel: bool = False,
) -> jax.Array:
    """Greedy (or sampled) generation; returns (B, S0 + max_new)."""
    B, S0 = prompt.shape
    max_len = max_len or (S0 + max_new)
    cache = T.init_kv_cache(cfg, B, max_len)
    serve_step = jax.jit(make_serve_step(cfg, use_flash_kernel))

    # prefill token-by-token through the cache (simple, exact) — batched
    # prefill via forward() is available for latency-critical paths.
    tokens = prompt
    logits = None
    for s in range(S0):
        logits, cache = serve_step(params, cache, tokens[:, s])
    out = [tokens]
    cur = None
    for i in range(max_new):
        if temperature > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            cur = jnp.argmax(logits, axis=-1)
        out.append(cur[:, None])
        if i < max_new - 1:
            logits, cache = serve_step(params, cache, cur)
    return jnp.concatenate(out, axis=1)


def batched_request_server(params, cfg, requests, max_new: int = 16):
    """Toy batched server: pad requests to one batch, generate, split.

    requests: list of 1-D token arrays."""
    B = len(requests)
    S0 = max(r.shape[0] for r in requests)
    prompt = jnp.stack(
        [jnp.pad(r, (S0 - r.shape[0], 0), constant_values=0) for r in requests]
    )
    out = generate(params, cfg, prompt, max_new)
    return [out[i, S0:] for i in range(B)]
