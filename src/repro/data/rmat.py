"""rMAT edge-stream generator (Chakrabarti et al. [20]; paper §7.4 uses
a=0.5, b=c=0.1, d=0.3).  Fully vectorized: each of the log2(n) bit levels
draws one quadrant choice per edge."""
from __future__ import annotations

import numpy as np


def rmat_edges(
    log_n: int,
    n_edges: int,
    a: float = 0.5,
    b: float = 0.1,
    c: float = 0.1,
    seed: int = 0,
) -> np.ndarray:
    """Returns (n_edges, 2) int64 directed edges over 2**log_n vertices.
    May contain duplicates (as the paper notes for its generator)."""
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    p_right = b + (1.0 - a - b - c)  # P(dst bit = 1)
    for level in range(log_n):
        u = rng.random(n_edges)
        v = rng.random(n_edges)
        src_bit = (u < (c + (1.0 - a - b - c))).astype(np.int64)
        # correlated quadrant draw: pick quadrant by joint probabilities
        r = rng.random(n_edges)
        q_ab = a + b
        src_bit = (r >= q_ab).astype(np.int64)  # rows c,d
        dst_bit = np.where(
            src_bit == 0,
            (r >= a).astype(np.int64),  # within top: a vs b
            (r >= q_ab + c).astype(np.int64),  # within bottom: c vs d
        )
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return np.stack([src, dst], axis=1)


def rmat_update_stream(log_n: int, n_updates: int, seed: int = 1) -> np.ndarray:
    """Directed insert stream, duplicates allowed (paper §7.4 methodology)."""
    return rmat_edges(log_n, n_updates, seed=seed)


def symmetrize(edges: np.ndarray) -> np.ndarray:
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    both = np.concatenate([e, e[:, ::-1]])
    keys = np.unique((both[:, 0] << 32) | both[:, 1])
    out = np.stack([keys >> 32, keys & 0xFFFFFFFF], axis=1)
    return out[out[:, 0] != out[:, 1]]  # drop self loops
