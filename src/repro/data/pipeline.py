"""Data pipelines: deterministic, restart-safe, per-shape batch builders.

Every batch is a pure function of (seed, step) — the fault-tolerance
contract: after restore at step k the pipeline re-produces exactly the
batch it would have produced, with no stateful iterators to checkpoint
(dist/fault_tolerance.py relies on this).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def token_batch(seed: int, step: int, batch: int, seq_len: int, vocab: int,
                host_id: int = 0, n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """Synthetic LM batch (markov-ish stream so loss is learnable).

    Each host draws its own slice — the multi-host sharding contract."""
    rng = np.random.default_rng((seed * 1_000_003 + step) * 64 + host_id)
    shard = batch // n_hosts
    base = rng.integers(0, vocab, size=(shard, seq_len + 1), dtype=np.int64)
    # inject local structure: next token correlated with current
    corr = (base[:, :-1] * 31 + 7) % vocab
    take = rng.random((shard, seq_len)) < 0.5
    base[:, 1:][take] = corr[take]
    return {"tokens": base[:, :-1], "labels": base[:, 1:]}


def recsys_batch(seed: int, step: int, batch: int, n_dense: int = 13,
                 n_sparse: int = 26, vocab: int = 100_000) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((seed * 999_983 + step))
    return {
        "dense": rng.standard_normal((batch, n_dense)).astype(np.float32),
        "sparse_ids": rng.integers(0, vocab, size=(batch, n_sparse)),
        "labels": (rng.random(batch) < 0.25).astype(np.float32),
    }


def molecule_batch(seed: int, step: int, n_mols: int, atoms_per_mol: int = 30,
                   edges_per_mol: int = 64, d_feat: int = 16):
    """Batched small molecular graphs with distances (SchNet regime)."""
    rng = np.random.default_rng(seed * 7919 + step)
    N = n_mols * atoms_per_mol
    E = n_mols * edges_per_mol
    src = np.zeros(E, dtype=np.int64)
    dst = np.zeros(E, dtype=np.int64)
    for m in range(n_mols):
        base = m * atoms_per_mol
        s = rng.integers(0, atoms_per_mol, edges_per_mol) + base
        d = rng.integers(0, atoms_per_mol, edges_per_mol) + base
        src[m * edges_per_mol : (m + 1) * edges_per_mol] = s
        dst[m * edges_per_mol : (m + 1) * edges_per_mol] = d
    dists = rng.random(E).astype(np.float32) * 10.0
    x = rng.standard_normal((N, d_feat)).astype(np.float32)
    graph_ids = np.repeat(np.arange(n_mols), atoms_per_mol)
    targets = rng.standard_normal(n_mols).astype(np.float32)
    return {
        "x": x, "src": src, "dst": dst, "dist": dists,
        "graph_ids": graph_ids, "targets": targets, "n_mols": n_mols,
    }


# ---------------------------------------------------------------------------
# neighbor sampler (GraphSAGE minibatch_lg: a REAL sampler over CSR)
# ---------------------------------------------------------------------------


class NeighborSampler:
    """Uniform fixed-fanout k-hop sampling over a CSR graph.

    Works against a numpy CSR (offsets, nbrs) — which is exactly the Aspen
    flat-graph pool layout, so the streaming store is sampleable in place.
    Deterministic per (seed, step): restart-safe.
    """

    def __init__(self, offsets: np.ndarray, nbrs: np.ndarray, feats: np.ndarray):
        self.offsets = np.asarray(offsets)
        self.nbrs = np.asarray(nbrs)
        self.feats = np.asarray(feats)
        self.n = self.offsets.size - 1

    def _sample_neighbors(self, rng, nodes: np.ndarray, fanout: int):
        """(len(nodes), fanout) neighbor ids + mask (vectorized)."""
        deg = self.offsets[nodes + 1] - self.offsets[nodes]
        picks = rng.integers(0, np.maximum(deg, 1)[:, None], size=(nodes.size, fanout))
        idx = self.offsets[nodes][:, None] + picks
        out = self.nbrs[np.minimum(idx, self.nbrs.size - 1)]
        mask = (deg > 0)[:, None] & np.ones((1, fanout), bool)
        out = np.where(mask, out, 0)
        return out.astype(np.int64), mask

    def sample_batch(self, seed: int, step: int, batch_nodes: int, fanouts):
        """Returns GraphSAGE-style tensors:
        x_self (B, d), neigh_feats [(B, f1, d), (B, f1, f2, d)],
        neigh_masks [(B, f1), (B, f1, f2)], seeds (B,)."""
        rng = np.random.default_rng(seed * 104_729 + step)
        seeds = rng.integers(0, self.n, size=batch_nodes)
        f1, f2 = fanouts
        n1, m1 = self._sample_neighbors(rng, seeds, f1)
        n2_flat, m2_flat = self._sample_neighbors(rng, n1.reshape(-1), f2)
        n2 = n2_flat.reshape(batch_nodes, f1, f2)
        m2 = m2_flat.reshape(batch_nodes, f1, f2) & m1[:, :, None]
        return {
            "x_self": self.feats[seeds],
            "neigh_feats": [self.feats[n1], self.feats[n2]],
            "neigh_masks": [m1, m2],
            "seeds": seeds,
        }


def power_law_graph(n: int, m: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """CSR power-law graph (reddit/products stand-in) via rMAT."""
    from .rmat import rmat_edges, symmetrize

    log_n = int(np.ceil(np.log2(n)))
    e = symmetrize(rmat_edges(log_n, m, seed=seed))
    e = e[(e[:, 0] < n) & (e[:, 1] < n)]
    keys = np.unique((e[:, 0] << 32) | e[:, 1])
    srcs, nbrs = keys >> 32, keys & 0xFFFFFFFF
    offsets = np.searchsorted(srcs, np.arange(n + 1))
    return offsets, nbrs.astype(np.int64)
