"""train_step builders: loss -> grads -> clip -> AdamW, with optional
microbatching (gradient accumulation via lax.scan) and remat from the
model config.  One builder per architecture family; all return pure
functions ready for jax.jit(in_shardings=..., out_shardings=...).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_state(params) -> TrainState:
    return TrainState(params, adamw.init(params))


def _accumulate(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation: split the batch into n_micro slices along
    axis 0 and scan, averaging grads — memory drops n_micro-fold."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def reshape(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def body(acc, mb):
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        acc_loss, acc_g = acc
        return (acc_loss + loss, jax.tree.map(jnp.add, acc_g, grads)), None

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero_g), micro)
    inv = 1.0 / n_micro
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def make_train_step(
    loss_of_batch: Callable[[Any, Dict[str, jax.Array]], jax.Array],
    lr_schedule: Callable[[jax.Array], jax.Array],
    clip_norm: float = 1.0,
    weight_decay: float = 0.1,
    n_micro: int = 1,
):
    """Generic: loss_of_batch(params, batch) -> scalar."""

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        loss, grads = _accumulate(loss_of_batch, state.params, batch, n_micro)
        grads, gnorm = adamw.clip_by_global_norm(grads, clip_norm)
        lr = lr_schedule(state.opt.step)
        new_params, new_opt = adamw.update(
            state.opt, grads, state.params, lr, weight_decay=weight_decay
        )
        return TrainState(new_params, new_opt), {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr,
        }

    return train_step


# -- per-family batch adapters ------------------------------------------------


def lm_loss(cfg):
    from repro.models import transformer as T

    def f(params, batch):
        return T.loss_fn(params, cfg, batch["tokens"], batch["labels"])

    return f


def gcn_loss(batch_static):
    from repro.models.gnn import gcn

    def f(params, batch):
        return gcn.loss_fn(params, batch["graph"], batch["labels"], batch["label_mask"])

    return f


def sage_full_loss():
    from repro.models.gnn import graphsage

    def f(params, batch):
        return graphsage.loss_fn_full(
            params, batch["graph"], batch["labels"], batch["label_mask"]
        )

    return f


def sage_sampled_loss():
    from repro.models.gnn import graphsage

    def f(params, batch):
        return graphsage.loss_fn_sampled(
            params, batch["x_self"], batch["neigh_feats"], batch["neigh_masks"], batch["labels"]
        )

    return f


def schnet_loss(n_graphs: int):
    from repro.models.gnn import schnet

    def f(params, batch):
        return schnet.loss_fn(params, batch["graph"], batch["targets"], n_graphs)

    return f


def graphcast_loss():
    from repro.models.gnn import graphcast

    def f(params, batch):
        return graphcast.loss_fn(params, batch["graph"], batch["targets"])

    return f


def dcn_loss():
    from repro.models.recsys import dcn_v2

    def f(params, batch):
        return dcn_v2.loss_fn(params, batch["dense"], batch["sparse_ids"], batch["labels"])

    return f
