"""dcn-v2 [recsys]: 13 dense + 26 sparse(16d), 3 cross layers,
MLP 1024-1024-512 [arXiv:2008.13535].  Embedding tables row-sharded over
the model axis; retrieval head scores 10^6 candidates in one GEMM."""
from repro.configs.registry import ArchSpec, RECSYS_SHAPES, DCNConfig

FULL = DCNConfig(name="dcn-v2")
REDUCED = DCNConfig(
    name="dcn-v2-smoke", n_dense=4, n_sparse=6, embed_dim=8, n_cross=2,
    mlp_dims=(32, 16), vocab_per_field=1000, n_candidates=512,
)
SPEC = ArchSpec("dcn-v2", "recsys", FULL, REDUCED, RECSYS_SHAPES)
