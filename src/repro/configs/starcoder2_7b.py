"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE [arXiv:2402.19173].  36 heads do not divide the
16-way model axis: attention replicates; the 4d FFN carries the TP."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152, mlp_kind="gelu",
)
REDUCED = LMConfig(
    name="starcoder2-7b-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab=512, mlp_kind="gelu",
)
SPEC = ArchSpec("starcoder2-7b", "lm", FULL, REDUCED, LM_SHAPES)
