"""gcn-cora [gnn]: 2 layers, d_hidden=16, mean/symmetric normalization
[arXiv:1609.02907] — the SpMM regime (block-dense Pallas kernel on TPU)."""
from repro.configs.registry import ArchSpec, GNN_SHAPES, GNNConfig

FULL = GNNConfig(
    name="gcn-cora", kind="gcn", n_layers=2, d_hidden=16,
    aggregator="mean", n_classes=7,
)
REDUCED = GNNConfig(
    name="gcn-smoke", kind="gcn", n_layers=2, d_hidden=8,
    aggregator="mean", n_classes=4,
)
SPEC = ArchSpec("gcn-cora", "gnn", FULL, REDUCED, GNN_SHAPES)
