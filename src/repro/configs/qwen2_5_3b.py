"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA with QKV bias [hf:Qwen/Qwen2.5; hf]."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936, qkv_bias=True,
)
REDUCED = LMConfig(
    name="qwen2.5-3b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=172, vocab=512, qkv_bias=True,
)
SPEC = ArchSpec("qwen2.5-3b", "lm", FULL, REDUCED, LM_SHAPES)
