"""graphsage-reddit [gnn]: 2 layers, d_hidden=128, mean aggregator,
sample sizes 25-10 [arXiv:1706.02216].  minibatch_lg uses the real
neighbor sampler (data/pipeline.NeighborSampler)."""
from repro.configs.registry import ArchSpec, GNN_SHAPES, GNNConfig

FULL = GNNConfig(
    name="graphsage-reddit", kind="graphsage", n_layers=2, d_hidden=128,
    aggregator="mean", sample_sizes=(25, 10), n_classes=41,
)
REDUCED = GNNConfig(
    name="graphsage-smoke", kind="graphsage", n_layers=2, d_hidden=16,
    aggregator="mean", sample_sizes=(5, 3), n_classes=7,
)
SPEC = ArchSpec("graphsage-reddit", "gnn", FULL, REDUCED, GNN_SHAPES)
