"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

llama-arch small [hf:HuggingFaceTB/SmolLM; hf].  15 heads do not divide a
16-way model axis: attention params replicate under TP (DP carries this
small model) — see DESIGN.md §Arch-applicability.
"""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="smollm-360m", n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152,
)
REDUCED = LMConfig(
    name="smollm-360m-smoke", n_layers=2, d_model=64, n_heads=5, n_kv_heads=5,
    d_ff=160, vocab=512,
)
SPEC = ArchSpec("smollm-360m", "lm", FULL, REDUCED, LM_SHAPES)
