"""graphcast [gnn]: encoder-processor-decoder, 16 layers, d_hidden=512,
mesh_refinement=6, sum aggregation, n_vars=227 [arXiv:2212.12794].
On the assigned generic shapes the processor runs over the given graph;
build_multimesh(6) provides its own icosahedral multimesh."""
from repro.configs.registry import ArchSpec, GNN_SHAPES, GNNConfig

FULL = GNNConfig(
    name="graphcast", kind="graphcast", n_layers=16, d_hidden=512,
    aggregator="sum", mesh_refinement=6, n_vars=227, n_classes=227,
)
REDUCED = GNNConfig(
    name="graphcast-smoke", kind="graphcast", n_layers=2, d_hidden=32,
    aggregator="sum", mesh_refinement=1, n_vars=8, n_classes=8,
)
SPEC = ArchSpec("graphcast", "gnn", FULL, REDUCED, GNN_SHAPES)
