"""aspen-stream: the paper's own configuration — the Aspen streaming
step (flat C-tree batch union + offsets rebuild) and global queries
(BFS/CC edgeMap steps) lowered at production scale on the mesh."""
from repro.configs.registry import ArchSpec, STREAM_SHAPES, StreamConfig

FULL = StreamConfig(name="aspen-stream", b=256)
REDUCED = StreamConfig(name="aspen-stream-smoke", b=8)
SPEC = ArchSpec("aspen-stream", "stream", FULL, REDUCED, STREAM_SHAPES)
