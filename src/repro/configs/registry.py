"""Architecture registry: 10 assigned archs + the paper's own config.

Each config module defines FULL (exact assigned numbers), REDUCED (smoke
scale), and the shape set for its family.  ``get(arch_id)`` returns an
ArchSpec the launcher and dryrun drive uniformly.

Families:
  lm      — 4 shapes: train_4k, prefill_32k, decode_32k, long_500k
  gnn     — 4 shapes: full_graph_sm, minibatch_lg, ogb_products, molecule
  recsys  — 4 shapes: train_batch, serve_p99, serve_bulk, retrieval_cand
  stream  — the paper's own: Aspen streaming update/query steps
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

LM_SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}

GNN_SHAPES: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": {
        "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "kind": "full",
    },
    "minibatch_lg": {
        "n_nodes": 232_965, "n_edges": 114_615_892, "batch_nodes": 1024,
        "fanout": (15, 10), "d_feat": 602, "kind": "sampled",
    },
    "ogb_products": {
        "n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100, "kind": "full_large",
    },
    "molecule": {
        "n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16, "kind": "batched_small",
    },
}

RECSYS_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_batch": {"batch": 65_536, "kind": "train"},
    "serve_p99": {"batch": 512, "kind": "serve"},
    "serve_bulk": {"batch": 262_144, "kind": "serve"},
    "retrieval_cand": {"batch": 1, "n_candidates": 1_000_000, "kind": "retrieval"},
}

STREAM_SHAPES: Dict[str, Dict[str, Any]] = {
    "update_2m": {"pool_edges": 1 << 28, "batch_edges": 1 << 21, "n_nodes": 1 << 25, "kind": "update"},
    "query_bfs": {"pool_edges": 1 << 28, "n_nodes": 1 << 25, "kind": "query"},
    "decode_pool": {"pool_edges": 1 << 28, "n_nodes": 1 << 25, "kind": "decode"},
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | stream
    full: Any  # family config object (exact assigned numbers)
    reduced: Any  # smoke-scale config
    shapes: Dict[str, Dict[str, Any]]
    notes: str = ""


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # gcn | graphsage | schnet | graphcast
    n_layers: int
    d_hidden: int
    aggregator: str = "mean"
    # arch-specific extras
    sample_sizes: Tuple[int, ...] = ()
    n_rbf: int = 0
    cutoff: float = 0.0
    mesh_refinement: int = 0
    n_vars: int = 0
    n_classes: int = 64


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross: int = 3
    mlp_dims: Tuple[int, ...] = (1024, 1024, 512)
    vocab_per_field: int = 1_000_000
    n_candidates: int = 1_000_000


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    name: str
    b: int = 256
    seed: int = 0x9E3779B9


ARCH_IDS = [
    "smollm-360m",
    "qwen2.5-3b",
    "starcoder2-7b",
    "qwen3-moe-30b-a3b",
    "deepseek-moe-16b",
    "graphsage-reddit",
    "gcn-cora",
    "schnet",
    "graphcast",
    "dcn-v2",
    "aspen-stream",  # the paper's own configuration (extra, not a cell)
]

_MODULE_OF = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch_id]}")
    return mod.SPEC


def all_cells(include_stream: bool = False):
    """Yield every (arch_id, shape_name) dry-run cell (40 assigned)."""
    for a in ARCH_IDS:
        if a == "aspen-stream" and not include_stream:
            continue
        spec = get(a)
        for s in spec.shapes:
            yield a, s
