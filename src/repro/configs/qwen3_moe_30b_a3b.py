"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
(per expert) vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
Experts shard 128/16 = 8 per device on the model axis (EP)."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig, MoEFields

FULL = LMConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_ff=768, vocab=151936,
    moe=MoEFields(n_experts=128, top_k=8),
    remat="full",
)
REDUCED = LMConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=512, moe=MoEFields(n_experts=8, top_k=2),
)
SPEC = ArchSpec("qwen3-moe-30b-a3b", "lm", FULL, REDUCED, LM_SHAPES)
