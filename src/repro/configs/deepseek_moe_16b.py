"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16: MHA) d_ff=1408
vocab=102400, 64 routed experts top-6 + 2 shared (fine-grained)
[arXiv:2401.06066]."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig, MoEFields

FULL = LMConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=102400,
    moe=MoEFields(n_experts=64, top_k=6, n_shared=2, shared_d_ff=1408),
    remat="full",
)
REDUCED = LMConfig(
    name="deepseek-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=512, moe=MoEFields(n_experts=8, top_k=2, n_shared=1, shared_d_ff=32),
)
SPEC = ArchSpec("deepseek-moe-16b", "lm", FULL, REDUCED, LM_SHAPES)
