"""schnet [gnn]: 3 interactions, d_hidden=64, 300 RBF, cutoff=10
[arXiv:1706.08566] — continuous-filter conv; edges carry distances
(synthetic unit distances on non-molecular shapes, see DESIGN.md)."""
from repro.configs.registry import ArchSpec, GNN_SHAPES, GNNConfig

FULL = GNNConfig(
    name="schnet", kind="schnet", n_layers=3, d_hidden=64,
    aggregator="sum", n_rbf=300, cutoff=10.0, n_classes=1,
)
REDUCED = GNNConfig(
    name="schnet-smoke", kind="schnet", n_layers=2, d_hidden=16,
    aggregator="sum", n_rbf=20, cutoff=10.0, n_classes=1,
)
SPEC = ArchSpec("schnet", "gnn", FULL, REDUCED, GNN_SHAPES)
