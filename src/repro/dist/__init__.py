# Distributed-execution substrate: sharding rules (shardings.py) and
# fault-tolerance policies (fault_tolerance.py) shared by the launch
# layer, the dry-run, and the training entrypoints.
