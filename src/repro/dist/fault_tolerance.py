"""Fault-tolerance policies for multi-host runs.

Three small, deterministic, host-side components (no jax deps):

* ``HeartbeatMonitor`` — liveness bookkeeping: hosts beat, the
  coordinator asks who's dead.
* ``StragglerPolicy``  — per-step accept/reject of gradient shards:
  persistent stragglers are flagged for reassignment, accepted steps
  rescale the gradient by n/(n - late) (drop-and-rescale), and a step
  with too few timely shards is rejected outright (grad_scale 0).
* ``ResumableRun``     — checkpoint-backed resume loop glue over
  ``repro.checkpoint.checkpoint`` (restore-or-init, save-every-k).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float):
        self.n_hosts = n_hosts
        self.timeout_s = float(timeout_s)
        self._last: Dict[int, float] = {}

    def beat(self, host_id: int, now: float) -> None:
        self._last[host_id] = float(now)

    def dead_hosts(self, now: float) -> List[int]:
        """Hosts whose last beat is older than the timeout (hosts that
        never beat count as dead)."""
        return [
            h
            for h in range(self.n_hosts)
            if now - self._last.get(h, float("-inf")) > self.timeout_s
        ]


class StragglerPolicy:
    def __init__(
        self,
        n_shards: int,
        min_shards: int,
        deadline_s: float,
        strikes_out: int = 3,
    ):
        self.n_shards = n_shards
        self.min_shards = min_shards
        self.deadline_s = float(deadline_s)
        self.strikes_out = strikes_out
        self._strikes: Dict[int, int] = {s: 0 for s in range(n_shards)}

    def step(self, durations_s: Dict[int, float]) -> Dict[str, Any]:
        """One training step's verdict given per-shard durations.

        Returns ``{accepted, late, grad_scale, reassign}``:
        late shards are excluded; if enough timely shards remain the
        step is accepted with gradients rescaled by n/(n - late);
        shards late ``strikes_out`` steps in a row are reassigned.
        """
        late = sorted(
            s for s, d in durations_s.items() if d > self.deadline_s
        )
        for s in range(self.n_shards):
            if s in late:
                self._strikes[s] = self._strikes.get(s, 0) + 1
            else:
                self._strikes[s] = 0
        reassign = sorted(
            s for s in late if self._strikes[s] >= self.strikes_out
        )
        timely = self.n_shards - len(late)
        accepted = timely >= self.min_shards
        grad_scale = (self.n_shards / timely) if accepted and timely else 0.0
        return {
            "accepted": accepted,
            "late": late,
            "grad_scale": grad_scale,
            "reassign": reassign,
        }


class ResumableRun:
    """Restore-or-init + periodic-save glue for a training loop.

    ``make_state`` builds a fresh state (also used as the restore
    template).  A falsy ``directory`` disables checkpointing entirely
    (restore_or_init returns a fresh state; saves are no-ops).
    """

    def __init__(
        self,
        directory: Optional[str],
        make_state: Callable[[], Any],
        save_every: int = 100,
    ):
        self.directory = directory
        self.make_state = make_state
        self.save_every = max(1, int(save_every))

    def restore_or_init(self) -> Tuple[int, Any]:
        template = self.make_state()
        if not self.directory:
            return 0, template
        from repro.checkpoint import checkpoint as ckpt

        if not ckpt.list_steps(self.directory):
            return 0, template
        return ckpt.restore(self.directory, template=template)

    def maybe_save(self, step: int, state: Any) -> bool:
        if not self.directory or step <= 0 or step % self.save_every != 0:
            return False
        from repro.checkpoint import checkpoint as ckpt

        ckpt.save(self.directory, step, state)
        return True

    def finish(self) -> None:
        """Flush point for symmetry with async savers (sync saves need
        no teardown)."""
