"""Sharding rules: config -> PartitionSpec trees for every cell family.

Pure functions from (config, mesh-shape) to PartitionSpec pytrees; the
only mesh property consulted is ``mesh.shape`` (an axis-name -> size
mapping), so the rules are testable with fake meshes and reusable by
the dry-run's 256/512-chip lowerings and the in-process 1x1 tests
alike.

Conventions
-----------
* data-parallel ("batch") axes are ``pod`` and ``data`` when present;
  ``model`` is the tensor-parallel axis.
* every rule guards on divisibility: a dimension that does not divide
  by its target axis size is left replicated rather than producing an
  uneven shard (GSPMD would pad; the memory model would lie).
* specs are plain ``jax.sharding.PartitionSpec``; ``named`` turns a
  spec tree into NamedShardings for jit in/out_shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _is_spec_leaf(x) -> bool:
    return x is None or isinstance(x, P)


def batch_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of ``mesh`` (everything but ``model``)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _batch_size_of(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)], dtype=np.int64)) or 1


def _batch_entry(mesh):
    """Spec entry for a batch-sharded dim, or None if no batch axes."""
    bax = batch_axes(mesh)
    return tuple(bax) if bax else None


def named(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree (None passes through)."""
    if tree is None:
        return None
    return jax.tree.map(
        lambda s: s if s is None else NamedSharding(mesh, s),
        tree,
        is_leaf=_is_spec_leaf,
    )


def spec_tree_like(specs, tree):
    """Reconcile a (possibly partial) spec tree against a param tree:
    keys missing from ``specs`` are replicated; keys in ``specs`` that
    the params don't have are dropped (e.g. optional qkv biases)."""

    def rec(sp, t):
        if isinstance(t, dict):
            sub = sp if isinstance(sp, dict) else {}
            return {k: rec(sub.get(k), v) for k, v in t.items()}
        if isinstance(t, (list, tuple)) and not hasattr(t, "shape"):
            if isinstance(sp, (list, tuple)) and len(sp) == len(t):
                out = [rec(s, v) for s, v in zip(sp, t)]
            else:
                out = [rec(None, v) for v in t]
            return type(t)(out) if isinstance(t, tuple) else out
        return sp if isinstance(sp, P) else P()

    return rec(specs, tree)


def zero1_specs(specs, params, mesh):
    """ZeRO-1 optimizer-state sharding: additionally shard each leaf's
    largest *free* (currently-replicated) dim over the batch axes, when
    it divides evenly; otherwise leave the spec unchanged."""
    bax = batch_axes(mesh)
    nb = _batch_size_of(mesh)
    if not bax:
        return specs
    entry = bax[0] if len(bax) == 1 else tuple(bax)

    def one(sp, p):
        shape = tuple(p.shape)
        entries = list(sp) + [None] * (len(shape) - len(sp))
        free = [i for i, e in enumerate(entries) if e is None and shape[i] % nb == 0]
        if not free or nb <= 1:
            return sp
        i = max(free, key=lambda i: shape[i])
        entries[i] = entry
        return P(*entries)

    return jax.tree.map(one, specs, params, is_leaf=_is_spec_leaf)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def lm_param_specs(cfg, mesh) -> Dict[str, Any]:
    """Megatron-style tensor parallelism over the ``model`` axis, with
    divisibility guards (a head/ff/vocab count that doesn't divide the
    axis stays replicated).  Layer params carry a leading stacked-layer
    dim (scan-over-layers), hence the extra None."""
    nm = mesh.shape["model"]
    h_ok = cfg.n_heads % nm == 0
    kv_ok = cfg.n_kv_heads % nm == 0
    ff_ok = cfg.d_ff % nm == 0

    def r(k):
        return P(*([None] * k))

    attn = {
        "wq": P(None, None, "model", None) if h_ok else r(4),
        "wk": P(None, None, "model", None) if kv_ok else r(4),
        "wv": P(None, None, "model", None) if kv_ok else r(4),
        "wo": P(None, "model", None, None) if h_ok else r(4),
        # optional biases (dropped by spec_tree_like when absent)
        "bq": P(None, "model", None) if h_ok else r(3),
        "bk": P(None, "model", None) if kv_ok else r(3),
        "bv": P(None, "model", None) if kv_ok else r(3),
    }
    if cfg.moe is None:
        mlp = {
            "w_up": P(None, None, "model") if ff_ok else r(3),
            "w_down": P(None, "model", None) if ff_ok else r(3),
        }
        if cfg.mlp_kind != "gelu":
            mlp["w_gate"] = P(None, None, "model") if ff_ok else r(3)
    else:
        e_ok = cfg.moe.n_experts % nm == 0
        mlp = {
            "router": r(3),
            "w_gate": P(None, "model", None, None) if e_ok else r(4),
            "w_up": P(None, "model", None, None) if e_ok else r(4),
            "w_down": P(None, "model", None, None) if e_ok else r(4),
        }
        if cfg.moe.n_shared > 0:
            sh_ok = (cfg.moe.shared_d_ff * cfg.moe.n_shared) % nm == 0
            mlp["shared"] = {
                "w_gate": P(None, None, "model") if sh_ok else r(3),
                "w_up": P(None, None, "model") if sh_ok else r(3),
                "w_down": P(None, "model", None) if sh_ok else r(3),
            }
    norm = {"scale": P(None), "bias": P(None)}
    return {
        "embed": {"table": P("model", None) if cfg.vocab % nm == 0 else r(2)},
        "layers": {"attn": attn, "ln1": norm, "ln2": norm, "mlp": mlp},
        "ln_f": norm,
    }


def lm_data_specs(mesh) -> Dict[str, P]:
    b = _batch_entry(mesh)
    return {"tokens": P(b, None), "labels": P(b, None)}


def lm_cache_specs(
    cfg,
    mesh,
    seq_shard: bool = False,
    batch_size: Optional[int] = None,
    seq_axes: Sequence[str] = ("model",),
) -> Dict[str, P]:
    """KV-cache specs for decode: (L, B, S, KV, HD).

    Batch shards over the data axes only when it divides (and B > 1);
    ``seq_shard`` moves the model axis onto the sequence dim for configs
    whose kv-head count doesn't divide it (or single-sequence shapes).
    """
    bax = batch_axes(mesh)
    nb = _batch_size_of(mesh)
    b = None
    if bax and batch_size is not None and batch_size > 1 and batch_size % nb == 0:
        b = tuple(bax)
    nm = mesh.shape["model"]
    kv_ok = cfg.n_kv_heads % nm == 0
    if seq_shard:
        kv = P(None, b, tuple(seq_axes), None, None)
    else:
        kv = P(None, b, None, "model" if kv_ok else None, None)
    return {"k": kv, "v": kv, "len": P(b)}


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_batch_specs(mesh, shard_nodes: bool = False) -> Dict[str, P]:
    """Full-graph GNN batches: edges shard over the batch axes (they're
    padded to 512-multiples by the cell builders); node arrays shard
    over ``model`` only for the large-graph cells."""
    e = _batch_entry(mesh)
    node = P("model", None) if shard_nodes else P(None, None)
    nmask = P("model") if shard_nodes else P(None)
    return {
        "x": node,
        "src": P(e),
        "dst": P(e),
        "edge_mask": P(e),
        "node_mask": nmask,
        "edge_attr": P(e, None),
        "graph_ids": nmask,
    }


def sage_sampled_specs(mesh) -> Dict[str, Any]:
    b = _batch_entry(mesh)
    return {
        "x_self": P(b, None),
        "neigh_feats": [P(b, None, None), P(b, None, None, None)],
        "neigh_masks": [P(b, None), P(b, None, None)],
        "labels": P(b),
    }


# ---------------------------------------------------------------------------
# recsys family
# ---------------------------------------------------------------------------


def dcn_param_specs(params_shape, mesh):
    """DCN-v2: the embedding tables (n_fields, vocab, dim) dominate —
    shard the vocab dim over ``model`` when it divides; everything else
    (cross layers, MLPs) is small and stays replicated."""
    nm = mesh.shape.get("model", 1)

    def one(p):
        shape = tuple(p.shape)
        if len(shape) == 3 and shape[1] >= 1024:
            return P(None, "model", None) if shape[1] % nm == 0 else P()
        return P()

    return jax.tree.map(one, params_shape)
