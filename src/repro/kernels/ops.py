"""jit'd public wrappers around the Pallas kernels.

Handles: interpret-mode selection (CPU container -> interpret=True; real
TPU -> compiled), padding to block multiples, and the ragged->padded
layout conversions the kernels require.  Models and the Aspen flat level
call these, never pl.pallas_call directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import autotune, csr_spmm, delta_decode, flash_decode, segment_reduce


def _interpret() -> bool:
    """Pallas interpret mode unless running on real TPU hardware."""
    return jax.default_backend() != "tpu"


def _gather_hi(deltas: jax.Array, hi: jax.Array | None, wide: jax.Array | None):
    """Resolve the compacted hi-byte plane to a per-chunk-aligned plane.

    Adaptive streams store hi bytes only for wide chunks (compacted to
    ``hi[cumsum(wide) - 1]``); Pallas block specs cannot express that
    data-dependent gather, so the wrapper materialises the aligned
    ``(R, C)`` plane as an XLA temporary before the kernel launch — the
    resident operand stays the compacted plane.  Narrow rows gather
    zeros, so the kernel's width select is safe without masking."""
    if hi is None:
        return jnp.zeros_like(deltas, dtype=jnp.int8)
    H = hi.shape[-2]
    if H == 0:
        return jnp.zeros_like(deltas, dtype=jnp.int8)
    idx = jnp.clip(jnp.cumsum(wide.astype(jnp.int32)) - 1, 0, H - 1)
    return jnp.where(wide[:, None], hi[idx], jnp.int8(0))


def _pad_to(x: np.ndarray | jax.Array, mult: int, axis: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# delta decode (C-tree chunk decompression)
# ---------------------------------------------------------------------------


def decode_chunks(anchors: jax.Array, deltas: jax.Array) -> jax.Array:
    """Decode padded chunk deltas -> absolute values.

    anchors: (n_chunks,) int32; deltas: (n_chunks, max_len) int32.  The
    kernel's chunk layout defines column 0 as the anchor position, i.e.
    ``deltas[:, 0] == 0`` so that ``out[:, 0] == anchors``.  Rather than
    silently assuming it, this boundary NORMALIZES column 0 to zero:
    whatever a caller left in that slot (e.g. a scatter artifact from a
    ragged->padded conversion) is dropped, and the decode of well-formed
    inputs is unchanged.  Pads both axes to kernel tiles.
    """
    n, L = deltas.shape
    deltas = deltas.at[:, 0].set(0)  # enforce the anchor-column invariant
    a = _pad_to(anchors, delta_decode.DEFAULT_ROW_BLOCK, 0)
    d = _pad_to(
        _pad_to(deltas, delta_decode.DEFAULT_ROW_BLOCK, 0),
        delta_decode.DEFAULT_COL_BLOCK,
        1,
    )
    out = delta_decode.delta_decode_padded(a, d, interpret=_interpret())
    return out[:n, :L]


def decode_chunked_stream(
    anchors: jax.Array,
    deltas: jax.Array,
    ovf_pos: jax.Array,
    ovf_add: jax.Array,
    hi: jax.Array | None = None,
    wide: jax.Array | None = None,
) -> jax.Array:
    """Decode escape-lane chunks (core/compressed.ChunkedStream arrays)
    via the Pallas kernel; pads chunk rows to the dtype-aware row block.

    Kernels take the raw arrays, not the ChunkedStream NamedTuple, so
    this package never imports from ``repro.core`` (no cycle); engine
    callers unpack the stream.  Pass ``hi``/``wide`` for adaptive-width
    streams; the compacted hi plane is pre-gathered in-trace
    (``_gather_hi``) and the width select runs inside the kernel.  Row
    padding uses anchor 0 / empty escape slots (pos = chunk_len), which
    decode to benign zeros and are sliced off."""
    n, L = deltas.shape
    rb = delta_decode._row_block_for(deltas.dtype)
    a = _pad_to(anchors, rb, 0)
    d = _pad_to(deltas, rb, 0)
    p = _pad_to(ovf_pos, rb, 0, value=L)
    v = _pad_to(ovf_add, rb, 0)
    if hi is not None:
        hg = _pad_to(_gather_hi(deltas, hi, wide), rb, 0)
        wp = _pad_to(wide.astype(jnp.int32), rb, 0)
        out = delta_decode.delta_decode_chunked_adaptive(
            a, d, hg, wp, p, v, interpret=_interpret()
        )
    else:
        out = delta_decode.delta_decode_chunked(a, d, p, v, interpret=_interpret())
    return out[:n]


def decode_pool(packed, total_len: int | None = None) -> np.ndarray:
    """Decode a chunks.PackedDeltas pool via the kernel (host convenience).

    Converts the ragged chunk layout to padded rows, runs the kernel,
    scatters rows back into the flat pool order.
    """
    from repro.core.chunks import PackedDeltas  # local import, avoids cycle

    assert isinstance(packed, PackedDeltas)
    offs = np.asarray(packed.chunk_off)
    lens = np.diff(offs)
    n_chunks = lens.size
    if n_chunks == 0:
        return np.empty(0, dtype=np.int64)
    L = int(lens.max())
    esc = np.iinfo(np.dtype(packed.dtype)).max
    d = np.asarray(packed.deltas, dtype=np.int64)
    d_full = d.copy()
    d_full[d == esc] = packed.overflow
    rows = np.zeros((n_chunks, L), dtype=np.int32)
    idx = np.arange(offs[-1])
    chunk_of = np.repeat(np.arange(n_chunks), lens)
    col_of = idx - offs[chunk_of]
    rows[chunk_of, col_of] = d_full
    rows[:, 0] = 0
    out = np.asarray(decode_chunks(jnp.asarray(packed.anchors, jnp.int32), jnp.asarray(rows)))
    flat = out[chunk_of, col_of].astype(np.int64)
    return flat


# ---------------------------------------------------------------------------
# segment reduce
# ---------------------------------------------------------------------------


def _sweep_segment_sum(E: int, n_out: int, weighted: bool):
    """sweep_fn factory: synthetic sorted segment-sum of the real shape.

    The thunk passes explicit block params, so candidate timings bypass
    the autotune consult (no recursion) and each candidate compiles its
    own specialization."""
    kernel = "segment_sum_weighted" if weighted else "segment_sum"

    def make(params):
        dst = jnp.sort(
            jax.random.randint(
                jax.random.PRNGKey(0), (max(E, 1),), 0, max(n_out, 1), dtype=jnp.int32
            )
        )
        msg = jnp.ones((max(E, 1), 8), jnp.float32)
        w = jnp.ones((max(E, 1),), jnp.float32)

        def thunk():
            if weighted:
                return segment_sum_weighted(dst, w, msg, n_out, **params)
            return segment_sum(dst, msg, n_out, **params)

        return thunk

    return kernel, make


def segment_sum(
    dst: jax.Array,
    msg: jax.Array,
    n_out: int,
    edge_block: int | None = None,
    dst_block: int | None = None,
) -> jax.Array:
    """Sorted segment-sum; pads edges with OOB dst and n_out to tile.

    Block shapes default to the autotuned winner for this (backend,
    shape-bucket) — consult happens at Python trace time since blocks
    are static kernel arguments."""
    E = dst.shape[0]
    if edge_block is None or dst_block is None:
        kernel, make = _sweep_segment_sum(E, n_out, weighted=False)
        tuned = autotune.get_params("segment_sum", {"E": E, "n": n_out}, sweep_fn=make)
        edge_block = edge_block or tuned["edge_block"]
        dst_block = dst_block or tuned["dst_block"]
    n_pad = n_out + (-n_out) % dst_block
    d = _pad_to(dst, edge_block, 0, value=n_pad)
    m = _pad_to(msg, edge_block, 0)
    # one extra dst block swallows padding edges
    n_with_pad = n_pad + dst_block
    out = segment_reduce.segment_sum_sorted(
        d, m, n_with_pad, edge_block=edge_block, dst_block=dst_block,
        interpret=_interpret(),
    )
    return out[:n_out]


def segment_sum_weighted(
    dst: jax.Array,
    w: jax.Array,
    msg: jax.Array,
    n_out: int,
    edge_block: int | None = None,
    dst_block: int | None = None,
) -> jax.Array:
    """Weighted sorted segment-sum (out[d] = sum w[e] * msg[e]); same
    padding contract as ``segment_sum`` (weight pads are 0, so padding
    edges contribute nothing even before the OOB dst drop)."""
    E = dst.shape[0]
    if edge_block is None or dst_block is None:
        _, make = _sweep_segment_sum(E, n_out, weighted=True)
        tuned = autotune.get_params(
            "segment_sum_weighted", {"E": E, "n": n_out}, sweep_fn=make
        )
        edge_block = edge_block or tuned["edge_block"]
        dst_block = dst_block or tuned["dst_block"]
    n_pad = n_out + (-n_out) % dst_block
    d = _pad_to(dst, edge_block, 0, value=n_pad)
    wp = _pad_to(w, edge_block, 0)
    m = _pad_to(msg, edge_block, 0)
    n_with_pad = n_pad + dst_block
    out = segment_reduce.segment_sum_weighted_sorted(
        d, wp, m, n_with_pad, edge_block=edge_block, dst_block=dst_block,
        interpret=_interpret(),
    )
    return out[:n_out]


def _pad_chunked_dst(
    anchors, deltas, ovf_pos, ovf_add, msg, w, n_out,
    hi=None, wide=None, edge_block=None, dst_block=None,
):
    """Shared padding for the chunked segment sums.

    Pads chunk rows to whole edge blocks; padding chunks carry anchor
    ``n_pad`` with zero deltas and empty escape slots, so every padded
    slot decodes to the same OOB dst that the raw path pads with — the
    extra DST_BLOCK swallows them identically.  Adaptive streams
    additionally carry the pre-gathered hi plane and the wide tag; pad
    rows are narrow (wide=0, hi=0), decoding identically to fixed pads."""
    edge_block = edge_block or segment_reduce.EDGE_BLOCK
    dst_block = dst_block or segment_reduce.DST_BLOCK
    R, C = deltas.shape
    rpb = edge_block // C
    n_pad = n_out + (-n_out) % dst_block
    a = _pad_to(anchors, rpb, 0, value=n_pad)
    d = _pad_to(deltas, rpb, 0)
    p = _pad_to(ovf_pos, rpb, 0, value=C)
    v = _pad_to(ovf_add, rpb, 0)
    m = _pad_to(msg, edge_block, 0)
    wp = None if w is None else _pad_to(w, edge_block, 0)
    if hi is not None:
        hg = _pad_to(_gather_hi(deltas, hi, wide), rpb, 0)
        wd = _pad_to(wide.astype(jnp.int32), rpb, 0)
    else:
        hg = wd = None
    assert m.shape[0] == a.shape[0] * C, "msg rows must cover the padded stream"
    n_with_pad = n_pad + dst_block
    return a, d, p, v, m, wp, hg, wd, n_with_pad


def _sweep_segment_sum_chunked(R: int, C: int, n_out: int, weighted: bool, adaptive: bool):
    """sweep_fn factory for the chunked reduces (synthetic stream of the
    real chunk geometry; explicit block params bypass the consult)."""

    def make(params):
        anch = jnp.arange(max(R, 1), dtype=jnp.int32) % max(n_out, 1)
        lane = jnp.zeros((max(R, 1), C), jnp.int8)
        pos = jnp.full((max(R, 1), 8), C, jnp.int32)
        add = jnp.zeros((max(R, 1), 8), jnp.int32)
        msg = jnp.ones((max(R, 1) * C, 8), jnp.float32)
        w = jnp.ones((max(R, 1) * C,), jnp.float32)
        hi = jnp.zeros((1, C), jnp.int8) if adaptive else None
        wd = jnp.zeros((max(R, 1),), bool) if adaptive else None

        def thunk():
            if weighted:
                return segment_sum_weighted_chunked(
                    anch, lane, pos, add, w, msg, n_out, hi=hi, wide=wd, **params
                )
            return segment_sum_chunked(
                anch, lane, pos, add, msg, n_out, hi=hi, wide=wd, **params
            )

        return thunk

    return make


def segment_sum_chunked(
    anchors: jax.Array,
    deltas: jax.Array,
    ovf_pos: jax.Array,
    ovf_add: jax.Array,
    msg: jax.Array,
    n_out: int,
    hi: jax.Array | None = None,
    wide: jax.Array | None = None,
    edge_block: int | None = None,
    dst_block: int | None = None,
) -> jax.Array:
    """``segment_sum`` with a chunk-compressed dst operand; the delta
    decode fuses into the reduce kernel.  msg row ``r*CHUNK + c`` pairs
    with chunk ``r`` column ``c``; msg rows past the valid prefix must be
    zero (the compressed aux masks them).  Pass ``hi``/``wide`` for
    adaptive-width streams (branch-free width select inside the grid)."""
    R, C = deltas.shape
    if edge_block is None or dst_block is None:
        make = _sweep_segment_sum_chunked(R, C, n_out, False, hi is not None)
        tuned = autotune.get_params(
            "segment_sum_chunked", {"R": R, "n": n_out}, sweep_fn=make
        )
        edge_block = edge_block or tuned["edge_block"]
        dst_block = dst_block or tuned["dst_block"]
    a, d, p, v, m, _, hg, wd, n_with_pad = _pad_chunked_dst(
        anchors, deltas, ovf_pos, ovf_add, msg, None, n_out,
        hi=hi, wide=wide, edge_block=edge_block, dst_block=dst_block,
    )
    if hg is not None:
        out = segment_reduce.segment_sum_sorted_chunked_adaptive(
            a, d, hg, wd, p, v, m, n_with_pad,
            edge_block=edge_block, dst_block=dst_block, interpret=_interpret(),
        )
    else:
        out = segment_reduce.segment_sum_sorted_chunked(
            a, d, p, v, m, n_with_pad,
            edge_block=edge_block, dst_block=dst_block, interpret=_interpret(),
        )
    return out[:n_out]


def segment_sum_weighted_chunked(
    anchors: jax.Array,
    deltas: jax.Array,
    ovf_pos: jax.Array,
    ovf_add: jax.Array,
    w: jax.Array,
    msg: jax.Array,
    n_out: int,
    hi: jax.Array | None = None,
    wide: jax.Array | None = None,
    edge_block: int | None = None,
    dst_block: int | None = None,
) -> jax.Array:
    """Weighted chunked segment-sum; same contract as ``segment_sum_chunked``
    (weight pads are 0)."""
    R, C = deltas.shape
    if edge_block is None or dst_block is None:
        make = _sweep_segment_sum_chunked(R, C, n_out, True, hi is not None)
        tuned = autotune.get_params(
            "segment_sum_weighted_chunked", {"R": R, "n": n_out}, sweep_fn=make
        )
        edge_block = edge_block or tuned["edge_block"]
        dst_block = dst_block or tuned["dst_block"]
    a, d, p, v, m, wp, hg, wd, n_with_pad = _pad_chunked_dst(
        anchors, deltas, ovf_pos, ovf_add, msg, w, n_out,
        hi=hi, wide=wide, edge_block=edge_block, dst_block=dst_block,
    )
    if hg is not None:
        out = segment_reduce.segment_sum_weighted_chunked_adaptive(
            a, d, hg, wd, p, v, wp, m, n_with_pad,
            edge_block=edge_block, dst_block=dst_block, interpret=_interpret(),
        )
    else:
        out = segment_reduce.segment_sum_weighted_chunked(
            a, d, p, v, wp, m, n_with_pad,
            edge_block=edge_block, dst_block=dst_block, interpret=_interpret(),
        )
    return out[:n_out]


def fanout_aggregate(feats: jax.Array, mask: jax.Array, op: str = "mean") -> jax.Array:
    B = feats.shape[0]
    f = _pad_to(feats, 8, 0)
    m = _pad_to(mask, 8, 0)
    out = segment_reduce.fanout_aggregate(f, m, op=op, interpret=_interpret())
    return out[:B]


# ---------------------------------------------------------------------------
# attention decode
# ---------------------------------------------------------------------------


def flash_decode_attn(q, k, v, lengths, seq_block: int = flash_decode.SEQ_BLOCK):
    S = k.shape[1]
    kp = _pad_to(k, seq_block, 1)
    vp = _pad_to(v, seq_block, 1)
    return flash_decode.flash_decode(
        q, kp, vp, lengths, seq_block=seq_block, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# block SpMM
# ---------------------------------------------------------------------------


def spmm(tile_mask, a_tiles, x):
    C = a_tiles.shape[3]
    xp = _pad_to(x, C, 0)
    return csr_spmm.block_spmm(tile_mask, a_tiles, xp, interpret=_interpret())


def _sweep_spmm(n: int, m: int):
    """sweep_fn factory for the block-dense SpMM tiles."""

    def make(params):
        rng = np.random.default_rng(0)
        src = rng.integers(0, max(n, 1), size=max(m, 1))
        dst = rng.integers(0, max(n, 1), size=max(m, 1))
        x = jnp.ones((n, 8), jnp.float32)

        def thunk():
            return spmm_from_edges(n, src, dst, x, **params)

        return thunk

    return make


def spmm_from_edges(
    n: int, src, dst, x, vals=None,
    row_tile: int | None = None, col_tile: int | None = None,
):
    if row_tile is None or col_tile is None:
        m = int(np.asarray(src).shape[0])
        tuned = autotune.get_params("spmm", {"n": n, "m": m}, sweep_fn=_sweep_spmm(n, m))
        row_tile = row_tile or tuned["row_tile"]
        col_tile = col_tile or tuned["col_tile"]
    mask, tiles, n_pad = csr_spmm.tiles_from_edges(
        n, src, dst, vals, row_tile=row_tile, col_tile=col_tile
    )
    out = spmm(mask, tiles, x)
    return out[:n]
