"""Kernel block-shape autotuner: per-(backend, shape-bucket) winners.

The Pallas kernels expose their block shapes as static parameters
(``segment_reduce.segment_sum_sorted(edge_block=, dst_block=)``, the
csr_spmm tile sizes) but ``kernels/ops.py`` historically pinned the
module defaults.  The right shapes depend on the backend (CPU interpret
mode has no tiling cost model at all; on TPU the trade is VMEM residency
vs grid overhead) and on the problem shape — so dispatch consults this
table instead.

Design (DESIGN.md §12):

* **Cache key** = (kernel name, backend, sorted shape dims bucketed to
  the next power of two).  Bucketing keeps the table small and makes a
  whole stream of similar problem sizes hit one entry.
* **Process-level memo** — dispatch consults the table at Python trace
  time (block shapes are static arguments), and the memo guarantees
  exactly ONE consult per (kernel, backend, bucket): repeated dispatches
  are a dict hit (``CONSULTS`` counts the cold consults; tests spy it).
* **On-disk table** — set ``REPRO_AUTOTUNE_CACHE=/path/table.json`` to
  persist winners across processes (atomic tmp+rename writes, merged on
  load, corruption-tolerant).  Unset, the table is process-local only —
  the library never writes outside paths the user named.
* **Sweeping** runs real timings over ``CANDIDATES[kernel]`` and is OFF
  unless the backend is a real TPU or ``REPRO_AUTOTUNE=1`` forces it
  (interpret-mode timings on CPU measure the emulator, not the kernel —
  still useful as a smoke of the sweep machinery, which is why the env
  override exists).  With sweeping off, a cache miss returns
  ``DEFAULTS[kernel]``.  Invalidation is by key: a new jax backend or a
  different shape bucket is a different entry; bump ``TABLE_VERSION`` to
  invalidate a persisted table wholesale.

Callers pass a ``sweep_fn(params) -> thunk`` factory building the kernel
launch on synthetic inputs of the real shape; ``sweep`` times each
candidate (min over repeats, block_until_ready) and records the winner.
"""
from __future__ import annotations

import collections
import json
import os
import tempfile
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

TABLE_VERSION = 1

DEFAULTS: Dict[str, Dict[str, int]] = {
    "segment_sum": {"edge_block": 512, "dst_block": 128},
    "segment_sum_weighted": {"edge_block": 512, "dst_block": 128},
    "segment_sum_chunked": {"edge_block": 512, "dst_block": 128},
    "segment_sum_weighted_chunked": {"edge_block": 512, "dst_block": 128},
    "spmm": {"row_tile": 128, "col_tile": 128},
}

# Small grids on purpose: every candidate costs a compile during a sweep.
# edge/dst blocks stay multiples of compressed.CHUNK (128) so the chunked
# kernels' whole-chunks-per-block invariant holds for every candidate.
CANDIDATES: Dict[str, List[Dict[str, int]]] = {
    "segment_sum": [
        {"edge_block": e, "dst_block": d}
        for e in (256, 512, 1024)
        for d in (128, 256)
    ],
    "segment_sum_chunked": [
        {"edge_block": e, "dst_block": d}
        for e in (256, 512, 1024)
        for d in (128, 256)
    ],
    "spmm": [{"row_tile": t, "col_tile": t} for t in (128, 256)],
}
CANDIDATES["segment_sum_weighted"] = CANDIDATES["segment_sum"]
CANDIDATES["segment_sum_weighted_chunked"] = CANDIDATES["segment_sum_chunked"]

_memo: Dict[Tuple, Dict[str, int]] = {}
# cold-consult spy: bumped once per key the first time dispatch asks
CONSULTS: collections.Counter = collections.Counter()
# test hook: when set, overrides CANDIDATES (e.g. pinned single-candidate
# grids for determinism tests)
_candidate_override: Optional[Dict[str, List[Dict[str, int]]]] = None


def _bucket(x: int) -> int:
    """Next power of two >= x (shape bucket)."""
    return 1 << max(0, int(x - 1).bit_length())


def cache_key(kernel: str, backend: str, shape: Dict[str, int]) -> Tuple:
    return (
        TABLE_VERSION,
        kernel,
        backend,
        tuple(sorted((k, _bucket(int(v))) for k, v in shape.items())),
    )


def _key_str(key: Tuple) -> str:
    ver, kernel, backend, dims = key
    dim_s = ",".join(f"{k}={v}" for k, v in dims)
    return f"v{ver}|{kernel}|{backend}|{dim_s}"


def cache_path() -> Optional[str]:
    return os.environ.get("REPRO_AUTOTUNE_CACHE") or None


def _load_disk() -> Dict[str, Dict[str, int]]:
    path = cache_path()
    if not path or not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            table = json.load(f)
        return table if isinstance(table, dict) else {}
    except (OSError, ValueError):
        return {}  # corrupt/partial table == empty table


def _save_disk(key: Tuple, params: Dict[str, int]) -> None:
    path = cache_path()
    if not path:
        return
    table = _load_disk()  # merge-on-load: keep other processes' winners
    table[_key_str(key)] = params
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(table, f, indent=0, sort_keys=True)
        os.replace(tmp, path)  # atomic on POSIX
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def sweep_enabled(backend: str) -> bool:
    return backend == "tpu" or os.environ.get("REPRO_AUTOTUNE") == "1"


def candidates_for(kernel: str) -> List[Dict[str, int]]:
    if _candidate_override is not None and kernel in _candidate_override:
        return _candidate_override[kernel]
    return CANDIDATES[kernel]


def set_candidates(override: Optional[Dict[str, List[Dict[str, int]]]]) -> None:
    """Pin the candidate grids (tests: determinism under a known grid).
    Pass None to restore the built-in grids."""
    global _candidate_override
    _candidate_override = override


def reset() -> None:
    """Drop the process memo + consult counters (tests)."""
    _memo.clear()
    CONSULTS.clear()


def sweep(
    kernel: str,
    make_thunk: Callable[[Dict[str, int]], Callable[[], object]],
    key: Tuple,
    repeats: int = 3,
) -> Dict[str, int]:
    """Time every candidate and record the winner under ``key``.

    ``make_thunk(params)`` returns a 0-arg callable running the kernel on
    representative inputs; it may raise to veto a candidate (e.g. a block
    larger than the problem).  Timing is min-over-repeats of a
    block_until_ready'd call, after one warmup/compile call.
    """
    best: Optional[Dict[str, int]] = None
    best_t = float("inf")
    for params in candidates_for(kernel):
        try:
            thunk = make_thunk(params)
            jax.block_until_ready(thunk())  # compile + warm
            t = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(thunk())
                t = min(t, time.perf_counter() - t0)
        except Exception:
            continue  # candidate infeasible for this shape/backend
        if t < best_t:
            best, best_t = dict(params), t
    if best is None:
        best = dict(DEFAULTS[kernel])
    _memo[key] = best
    _save_disk(key, best)
    return best


def get_params(
    kernel: str,
    shape: Dict[str, int],
    sweep_fn: Optional[Callable[[Dict[str, int]], Callable[[], object]]] = None,
    backend: Optional[str] = None,
) -> Dict[str, int]:
    """The dispatch entry point: winner for (kernel, backend, bucket).

    Order: process memo -> on-disk table -> sweep (if enabled and a
    ``sweep_fn`` is given) -> ``DEFAULTS``.  Exactly one cold consult per
    key; everything after is a memo hit.
    """
    backend = backend or jax.default_backend()
    key = cache_key(kernel, backend, shape)
    hit = _memo.get(key)
    if hit is not None:
        return hit
    CONSULTS[key] += 1
    params = _load_disk().get(_key_str(key))
    if params is None and sweep_fn is not None and sweep_enabled(backend):
        return sweep(kernel, sweep_fn, key)
    if params is None:
        params = dict(DEFAULTS[kernel])
    _memo[key] = params
    return params
