"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function computes the same math as its kernel with plain jax.numpy —
no tiling, no scratch, no grid.  Tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def delta_decode_ref(anchors: jax.Array, deltas: jax.Array) -> jax.Array:
    """out[i, j] = anchors[i] + sum(deltas[i, :j+1]) (col 0 of deltas = 0)."""
    return anchors[:, None].astype(jnp.int32) + jnp.cumsum(
        deltas.astype(jnp.int32), axis=1
    )


def delta_decode_chunked_ref(
    anchors: jax.Array, deltas: jax.Array, ovf_pos: jax.Array, ovf_add: jax.Array
) -> jax.Array:
    """Escape-lane decode oracle (core/compressed ChunkedStream rows):
    anchor + lane cumsum, then each escape k adds ovf_add[i, k] to every
    column >= ovf_pos[i, k] (unused slots carry pos == chunk_len, which
    never triggers)."""
    base = anchors[:, None].astype(jnp.int32) + jnp.cumsum(
        deltas.astype(jnp.int32), axis=1
    )
    cols = jax.lax.broadcasted_iota(jnp.int32, deltas.shape, 1)
    corr = jnp.sum(
        jnp.where(cols[:, :, None] >= ovf_pos[:, None, :], ovf_add[:, None, :], 0),
        axis=-1,
    )
    return base + corr


def segment_sum_sorted_ref(dst: jax.Array, msg: jax.Array, n_out: int) -> jax.Array:
    """Scatter-add oracle (jax.ops.segment_sum)."""
    return jax.ops.segment_sum(msg, dst.astype(jnp.int32), num_segments=n_out)


def fanout_aggregate_ref(feats: jax.Array, mask: jax.Array, op: str = "mean") -> jax.Array:
    m = mask[..., None].astype(feats.dtype)
    if op == "sum":
        return jnp.sum(feats * m, axis=1)
    if op == "mean":
        s = jnp.sum(feats * m, axis=1)
        return s / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    neg = jnp.finfo(feats.dtype).min
    return jnp.max(jnp.where(m > 0, feats, neg), axis=1)


def flash_decode_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, lengths: jax.Array
) -> jax.Array:
    """Masked softmax attention oracle, fp32 internally."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bsd->bqs", qf, kf) * scale
    pos = jnp.arange(k.shape[1])[None, None, :]
    s = jnp.where(pos < lengths[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqs,bsd->bqd", p, vf).astype(q.dtype)


def block_spmm_ref(tile_mask: jax.Array, a_tiles: jax.Array, x: jax.Array) -> jax.Array:
    """Un-tile A and do the dense matmul."""
    nr, nc, R, C = a_tiles.shape
    a = a_tiles.transpose(0, 2, 1, 3).reshape(nr * R, nc * C)
    return (a * 1.0) @ x
