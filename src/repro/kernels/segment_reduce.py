"""Pallas TPU kernel: sorted segment-sum as a one-hot MXU matmul.

GNN aggregation / Aspen edgeMap reduce over CSR-sorted edges:
``out[d] = sum_{e: dst[e]=d} msg[e]``.  Random scatter is hostile to the
TPU; but with edges sorted by destination (which the C-tree pool
guarantees — the pool IS sorted by (dst-major) key), the scatter becomes
a *block-banded* matmul: for an edge block E and a destination-row block
R, ``out[R] += M @ msg[E]`` where ``M[r, e] = 1[dst[e] == r]`` is built
in-register from an iota comparison.  The MXU multiplies the one-hot
matrix at full throughput — this is the TPU-native scatter.

Grid: (dst_blocks, edge_blocks) with the edge axis sequential-minor; a
block mask (precomputed, tiny) skips (R, E) pairs whose dst ranges do not
intersect, so work is O(nnz-blocks) not O(n_blocks * e_blocks) in the
lowered loop body (blocks outside the band multiply by an all-zero
one-hot: still correct, just masked early).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EDGE_BLOCK = 512
DST_BLOCK = 128


def _segsum_kernel(dst_ref, msg_ref, out_ref):
    """One (DST_BLOCK out-rows) x (EDGE_BLOCK edges) tile."""
    i = pl.program_id(0)  # dst block
    j = pl.program_id(1)  # edge block

    @pl.when(j == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[...]  # (1, E) int32 destination ids of this edge block
    d0 = i * out_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dst.shape[1]), 0)
    onehot = (dst - d0 == rows).astype(msg_ref.dtype)  # (R, E)
    # fp32 accumulation across edge blocks (MXU-accumulator semantics)
    out_ref[...] += jax.lax.dot(
        onehot, msg_ref[...], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("n_out", "edge_block", "dst_block", "interpret")
)
def segment_sum_sorted(
    dst: jax.Array,  # int32 (E,) sorted ascending; pad with n_out (OOB)
    msg: jax.Array,  # (E, D) messages
    n_out: int,
    edge_block: int = EDGE_BLOCK,
    dst_block: int = DST_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """out[d, :] = sum of msg rows with dst == d.  E, D, n_out must be
    multiples of the block sizes (ops.py pads)."""
    E, D = msg.shape
    assert E % edge_block == 0 and n_out % dst_block == 0
    grid = (n_out // dst_block, E // edge_block)
    return pl.pallas_call(
        _segsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, edge_block), lambda i, j: (0, j)),
            pl.BlockSpec((edge_block, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((dst_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, D), jnp.float32),
        interpret=interpret,
    )(dst.reshape(1, -1).astype(jnp.int32), msg).astype(msg.dtype)


def _segsum_weighted_kernel(dst_ref, w_ref, msg_ref, out_ref):
    """One (DST_BLOCK out-rows) x (EDGE_BLOCK edges) tile of the
    WEIGHTED segment sum: out[d] = sum_{e: dst[e]=d} w[e] * msg[e].

    The per-edge weight is folded into the one-hot selection matrix
    (``M[r, e] = w[e] * 1[dst[e] == r]``) so the weighting rides the
    same MXU matmul — no extra pass over the message block, and the
    unweighted kernel above stays untouched (unweighted graphs never
    build or dispatch this kernel)."""
    i = pl.program_id(0)  # dst block
    j = pl.program_id(1)  # edge block

    @pl.when(j == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[...]  # (1, E) int32 destination ids of this edge block
    w = w_ref[...]  # (1, E) per-edge weights
    d0 = i * out_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dst.shape[1]), 0)
    onehot_w = jnp.where(dst - d0 == rows, w, 0.0).astype(msg_ref.dtype)  # (R, E)
    out_ref[...] += jax.lax.dot(
        onehot_w, msg_ref[...], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("n_out", "edge_block", "dst_block", "interpret")
)
def segment_sum_weighted_sorted(
    dst: jax.Array,  # int32 (E,) sorted ascending; pad with n_out (OOB)
    w: jax.Array,  # float (E,) per-edge weights; pad 0
    msg: jax.Array,  # (E, D) messages
    n_out: int,
    edge_block: int = EDGE_BLOCK,
    dst_block: int = DST_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """out[d, :] = sum of w[e] * msg[e, :] over edges with dst == d.
    Same layout contract as ``segment_sum_sorted`` (ops.py pads)."""
    E, D = msg.shape
    assert E % edge_block == 0 and n_out % dst_block == 0
    grid = (n_out // dst_block, E // edge_block)
    return pl.pallas_call(
        _segsum_weighted_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, edge_block), lambda i, j: (0, j)),
            pl.BlockSpec((1, edge_block), lambda i, j: (0, j)),
            pl.BlockSpec((edge_block, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((dst_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, D), jnp.float32),
        interpret=interpret,
    )(
        dst.reshape(1, -1).astype(jnp.int32),
        w.reshape(1, -1).astype(msg.dtype),
        msg,
    ).astype(msg.dtype)


# ---------------------------------------------------------------------------
# chunk-compressed operands: delta decode fused as an in-kernel prologue
# ---------------------------------------------------------------------------
#
# The compressed pool (core/compressed.py) stores the dst-sorted edge ids
# as (anchor, narrow fixed-width deltas, escape lane) chunks of CHUNK=128
# slots.  CHUNK divides EDGE_BLOCK, so one edge block is exactly
# EDGE_BLOCK // CHUNK whole chunk rows and the decode never needs a
# cross-block carry here: each chunk row decodes self-contained
# (anchor + row cumsum + escape-step corrections), is flattened to the
# (1, EDGE_BLOCK) dst lane, and feeds the identical one-hot MXU matmul.
# Compressed dst ids therefore never round-trip through HBM decoded —
# the decode lives in the same kernel as the reduce.
#
# Note: the in-kernel (rows, CHUNK) -> (1, EDGE_BLOCK) reshape is a relayout
# on real TPU hardware; this repo's acceptance target is CPU interpret
# mode where it is free.  On TPU the reshape is sublane->lane shuffling of
# a VMEM-resident tile — cheap relative to the HBM bytes saved, but worth
# re-measuring before flipping the compressed path on for TPU runs.


def _decode_dst_tile(anch, deltas, pos, add):
    """Decode (rows, CHUNK) chunk tiles -> (1, rows * CHUNK) int32 dst lane.

    Escape positions are per-chunk columns, so the correction mask uses
    the LOCAL column iota (every chunk row sits whole inside this tile).
    """
    d = deltas.astype(jnp.int32)
    rows, C = d.shape
    dec = anch + jnp.cumsum(d, axis=1)  # anch is (rows, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (rows, C), 1)
    for k in range(pos.shape[1]):  # static K, unrolled
        dec = dec + jnp.where(cols >= pos[:, k : k + 1], add[:, k : k + 1], 0)
    return dec.reshape(1, rows * C)


def _segsum_chunked_kernel(anch_ref, del_ref, pos_ref, add_ref, msg_ref, out_ref):
    i = pl.program_id(0)  # dst block
    j = pl.program_id(1)  # edge block

    @pl.when(j == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = _decode_dst_tile(anch_ref[...], del_ref[...], pos_ref[...], add_ref[...])
    d0 = i * out_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dst.shape[1]), 0)
    onehot = (dst - d0 == rows).astype(msg_ref.dtype)
    out_ref[...] += jax.lax.dot(
        onehot, msg_ref[...], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )


def _segsum_chunked_weighted_kernel(
    anch_ref, del_ref, pos_ref, add_ref, w_ref, msg_ref, out_ref
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = _decode_dst_tile(anch_ref[...], del_ref[...], pos_ref[...], add_ref[...])
    w = w_ref[...]
    d0 = i * out_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dst.shape[1]), 0)
    onehot_w = jnp.where(dst - d0 == rows, w, 0.0).astype(msg_ref.dtype)
    out_ref[...] += jax.lax.dot(
        onehot_w, msg_ref[...], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )


def _chunked_specs(chunk_len: int, K: int, edge_block: int, D: int):
    rpb = edge_block // chunk_len  # whole chunk rows per edge block
    return rpb, [
        pl.BlockSpec((rpb, 1), lambda i, j: (j, 0)),  # anchors
        pl.BlockSpec((rpb, chunk_len), lambda i, j: (j, 0)),  # deltas
        pl.BlockSpec((rpb, K), lambda i, j: (j, 0)),  # ovf_pos
        pl.BlockSpec((rpb, K), lambda i, j: (j, 0)),  # ovf_add
    ]


@functools.partial(
    jax.jit, static_argnames=("n_out", "edge_block", "dst_block", "interpret")
)
def segment_sum_sorted_chunked(
    anchors: jax.Array,  # int32 (R,) chunk anchors of the sorted dst lane
    deltas: jax.Array,  # int8|int16 (R, CHUNK); col 0 == 0
    ovf_pos: jax.Array,  # int32 (R, K) escape columns (CHUNK = unused)
    ovf_add: jax.Array,  # int32 (R, K) escaped deltas
    msg: jax.Array,  # (R * CHUNK, D) messages, edge order
    n_out: int,
    edge_block: int = EDGE_BLOCK,
    dst_block: int = DST_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """``segment_sum_sorted`` with the dst operand chunk-compressed; the
    delta decode runs as a prologue inside the same kernel.  R * CHUNK
    must be a multiple of edge_block and CHUNK must divide edge_block
    (kernels/ops.py pads; padding chunks decode to OOB dst)."""
    R, chunk_len = deltas.shape
    E, D = msg.shape
    K = ovf_pos.shape[1]
    assert E == R * chunk_len
    assert edge_block % chunk_len == 0 and E % edge_block == 0
    assert n_out % dst_block == 0
    grid = (n_out // dst_block, E // edge_block)
    rpb, chunk_specs = _chunked_specs(chunk_len, K, edge_block, D)
    return pl.pallas_call(
        _segsum_chunked_kernel,
        grid=grid,
        in_specs=chunk_specs + [pl.BlockSpec((edge_block, D), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((dst_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, D), jnp.float32),
        interpret=interpret,
    )(
        anchors.reshape(-1, 1).astype(jnp.int32),
        deltas,
        ovf_pos.astype(jnp.int32),
        ovf_add.astype(jnp.int32),
        msg,
    ).astype(msg.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_out", "edge_block", "dst_block", "interpret")
)
def segment_sum_weighted_chunked(
    anchors: jax.Array,
    deltas: jax.Array,
    ovf_pos: jax.Array,
    ovf_add: jax.Array,
    w: jax.Array,  # float (R * CHUNK,) per-edge weights; pad 0
    msg: jax.Array,
    n_out: int,
    edge_block: int = EDGE_BLOCK,
    dst_block: int = DST_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Weighted variant of ``segment_sum_sorted_chunked`` (same fused
    in-kernel decode; weights fold into the one-hot as in the raw path)."""
    R, chunk_len = deltas.shape
    E, D = msg.shape
    K = ovf_pos.shape[1]
    assert E == R * chunk_len
    assert edge_block % chunk_len == 0 and E % edge_block == 0
    assert n_out % dst_block == 0
    grid = (n_out // dst_block, E // edge_block)
    rpb, chunk_specs = _chunked_specs(chunk_len, K, edge_block, D)
    return pl.pallas_call(
        _segsum_chunked_weighted_kernel,
        grid=grid,
        in_specs=chunk_specs
        + [
            pl.BlockSpec((1, edge_block), lambda i, j: (0, j)),
            pl.BlockSpec((edge_block, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((dst_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, D), jnp.float32),
        interpret=interpret,
    )(
        anchors.reshape(-1, 1).astype(jnp.int32),
        deltas,
        ovf_pos.astype(jnp.int32),
        ovf_add.astype(jnp.int32),
        w.reshape(1, -1).astype(msg.dtype),
        msg,
    ).astype(msg.dtype)


# ---------------------------------------------------------------------------
# adaptive-width chunks: per-chunk int8/int16 width tag (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# The adaptive stream stores ONE int8 lane plus a compacted hi-byte plane
# holding only the wide chunks' rows.  The compaction index
# (cumsum(wide) - 1) is a data-dependent gather, which Pallas block specs
# cannot express — so the ops.py wrapper pre-gathers the hi plane to a
# per-chunk transient ``hi_g[r] = wide[r] ? hi[cumsum-1] : 0`` IN-TRACE
# (an XLA temporary that never lives in the resident pool) and the kernel
# receives aligned (rpb, CHUNK) blocks of it next to the lane.  HBM
# traffic for the resident operand stays ~1 byte/slot + the wide rows;
# the width select is a branch-free per-element where() in the prologue:
#
#   delta = wide ? hi * 256 + (lane & 0xFF) : lane
#
# after which decode is the identical cumsum + escape corrections.


def _decode_dst_tile_adaptive(anch, lane, hi, wide, pos, add):
    """Adaptive variant of ``_decode_dst_tile``: branch-free width select
    between the int8 lane and the (pre-gathered) hi-byte plane, then the
    same cumsum + escape-step corrections.  ``wide`` is (rows, 1) int32
    (nonzero = wide chunk)."""
    lane32 = lane.astype(jnp.int32)
    d = jnp.where(wide > 0, hi.astype(jnp.int32) * 256 + (lane32 & 0xFF), lane32)
    rows, C = d.shape
    dec = anch + jnp.cumsum(d, axis=1)  # anch is (rows, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (rows, C), 1)
    for k in range(pos.shape[1]):  # static K, unrolled
        dec = dec + jnp.where(cols >= pos[:, k : k + 1], add[:, k : k + 1], 0)
    return dec.reshape(1, rows * C)


def _segsum_chunked_adaptive_kernel(
    anch_ref, del_ref, hi_ref, wide_ref, pos_ref, add_ref, msg_ref, out_ref
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = _decode_dst_tile_adaptive(
        anch_ref[...], del_ref[...], hi_ref[...], wide_ref[...],
        pos_ref[...], add_ref[...],
    )
    d0 = i * out_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dst.shape[1]), 0)
    onehot = (dst - d0 == rows).astype(msg_ref.dtype)
    out_ref[...] += jax.lax.dot(
        onehot, msg_ref[...], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )


def _segsum_chunked_adaptive_weighted_kernel(
    anch_ref, del_ref, hi_ref, wide_ref, pos_ref, add_ref, w_ref, msg_ref, out_ref
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = _decode_dst_tile_adaptive(
        anch_ref[...], del_ref[...], hi_ref[...], wide_ref[...],
        pos_ref[...], add_ref[...],
    )
    w = w_ref[...]
    d0 = i * out_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_ref.shape[0], dst.shape[1]), 0)
    onehot_w = jnp.where(dst - d0 == rows, w, 0.0).astype(msg_ref.dtype)
    out_ref[...] += jax.lax.dot(
        onehot_w, msg_ref[...], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )


def _chunked_specs_adaptive(chunk_len: int, K: int, edge_block: int, D: int):
    rpb = edge_block // chunk_len
    return rpb, [
        pl.BlockSpec((rpb, 1), lambda i, j: (j, 0)),  # anchors
        pl.BlockSpec((rpb, chunk_len), lambda i, j: (j, 0)),  # int8 lane
        pl.BlockSpec((rpb, chunk_len), lambda i, j: (j, 0)),  # gathered hi
        pl.BlockSpec((rpb, 1), lambda i, j: (j, 0)),  # wide tags
        pl.BlockSpec((rpb, K), lambda i, j: (j, 0)),  # ovf_pos
        pl.BlockSpec((rpb, K), lambda i, j: (j, 0)),  # ovf_add
    ]


@functools.partial(
    jax.jit, static_argnames=("n_out", "edge_block", "dst_block", "interpret")
)
def segment_sum_sorted_chunked_adaptive(
    anchors: jax.Array,  # int32 (R,)
    deltas: jax.Array,  # int8 (R, CHUNK) lane (low bytes on wide chunks)
    hi_g: jax.Array,  # int8 (R, CHUNK) pre-gathered hi plane (0 on narrow)
    wide: jax.Array,  # int32 (R, 1) per-chunk width tag
    ovf_pos: jax.Array,  # int32 (R, K)
    ovf_add: jax.Array,  # int32 (R, K)
    msg: jax.Array,  # (R * CHUNK, D)
    n_out: int,
    edge_block: int = EDGE_BLOCK,
    dst_block: int = DST_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """``segment_sum_sorted_chunked`` over the adaptive-width layout; the
    per-chunk width select + delta decode fuse into the reduce kernel."""
    R, chunk_len = deltas.shape
    E, D = msg.shape
    K = ovf_pos.shape[1]
    assert E == R * chunk_len
    assert edge_block % chunk_len == 0 and E % edge_block == 0
    assert n_out % dst_block == 0
    grid = (n_out // dst_block, E // edge_block)
    rpb, chunk_specs = _chunked_specs_adaptive(chunk_len, K, edge_block, D)
    return pl.pallas_call(
        _segsum_chunked_adaptive_kernel,
        grid=grid,
        in_specs=chunk_specs + [pl.BlockSpec((edge_block, D), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((dst_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, D), jnp.float32),
        interpret=interpret,
    )(
        anchors.reshape(-1, 1).astype(jnp.int32),
        deltas,
        hi_g,
        wide.reshape(-1, 1).astype(jnp.int32),
        ovf_pos.astype(jnp.int32),
        ovf_add.astype(jnp.int32),
        msg,
    ).astype(msg.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_out", "edge_block", "dst_block", "interpret")
)
def segment_sum_weighted_chunked_adaptive(
    anchors: jax.Array,
    deltas: jax.Array,
    hi_g: jax.Array,
    wide: jax.Array,
    ovf_pos: jax.Array,
    ovf_add: jax.Array,
    w: jax.Array,  # float (R * CHUNK,); pad 0
    msg: jax.Array,
    n_out: int,
    edge_block: int = EDGE_BLOCK,
    dst_block: int = DST_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Weighted adaptive chunked segment-sum (weights fold into the
    one-hot as in every other variant)."""
    R, chunk_len = deltas.shape
    E, D = msg.shape
    K = ovf_pos.shape[1]
    assert E == R * chunk_len
    assert edge_block % chunk_len == 0 and E % edge_block == 0
    assert n_out % dst_block == 0
    grid = (n_out // dst_block, E // edge_block)
    rpb, chunk_specs = _chunked_specs_adaptive(chunk_len, K, edge_block, D)
    return pl.pallas_call(
        _segsum_chunked_adaptive_weighted_kernel,
        grid=grid,
        in_specs=chunk_specs
        + [
            pl.BlockSpec((1, edge_block), lambda i, j: (0, j)),
            pl.BlockSpec((edge_block, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((dst_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, D), jnp.float32),
        interpret=interpret,
    )(
        anchors.reshape(-1, 1).astype(jnp.int32),
        deltas,
        hi_g,
        wide.reshape(-1, 1).astype(jnp.int32),
        ovf_pos.astype(jnp.int32),
        ovf_add.astype(jnp.int32),
        w.reshape(1, -1).astype(msg.dtype),
        msg,
    ).astype(msg.dtype)


# ---------------------------------------------------------------------------
# fixed-fanout aggregation (sampled GNN regime: GraphSAGE minibatch)
# ---------------------------------------------------------------------------


def _fanout_kernel(feats_ref, mask_ref, out_ref, *, op):
    """(B_blk, K, D) neighbor features -> (B_blk, D) masked reduce."""
    f = feats_ref[...]
    m = mask_ref[...].astype(f.dtype)  # (B, K, 1)
    if op == "mean":
        s = jnp.sum(f * m, axis=1)
        cnt = jnp.maximum(jnp.sum(m, axis=1), 1.0)
        out_ref[...] = s / cnt
    elif op == "sum":
        out_ref[...] = jnp.sum(f * m, axis=1)
    else:  # max
        neg = jnp.finfo(f.dtype).min
        out_ref[...] = jnp.max(jnp.where(m > 0, f, neg), axis=1)


@functools.partial(jax.jit, static_argnames=("op", "batch_block", "interpret"))
def fanout_aggregate(
    feats: jax.Array,  # (B, K, D) gathered neighbor features
    mask: jax.Array,  # (B, K) validity (sampled < degree)
    op: str = "mean",
    batch_block: int = 8,
    interpret: bool = False,
) -> jax.Array:
    B, K, D = feats.shape
    assert B % batch_block == 0
    grid = (B // batch_block,)
    return pl.pallas_call(
        functools.partial(_fanout_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch_block, K, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((batch_block, K, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((batch_block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), feats.dtype),
        interpret=interpret,
    )(feats, mask[..., None])
