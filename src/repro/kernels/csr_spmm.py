"""Pallas TPU kernel: blocked SpMM  (A_sparse @ X) for full-graph GNNs.

GCN-family propagation is ``Ã @ X`` with Ã the (normalized) adjacency.
GPU frameworks run CSR SpMM with per-row warps; the TPU has no warps and
hates row-wise gather, but its MXU eats dense (128, 128) tiles.  The
TPU-native formulation (DESIGN.md §2) is *block-dense* SpMM:

  1. partition A into (R, C) tiles; store only the values of every tile
     (dense layout, zeros included) — for power-law graphs most tiles are
     empty, so ops.py keeps a per-tile nonzero mask and the kernel skips
     empty tiles with @pl.when (the MegaBlocks trade: padding FLOPs for
     layout regularity);
  2. grid (row_tiles, col_tiles) accumulates out[i] += A[i, j] @ X[j]
     over the sequential col axis in VMEM.

This kernel is the 'fuse' point the paper's flat-snapshot idea maps to:
the C-tree pool decodes (delta_decode kernel) straight into A-tiles, and
aggregation never round-trips through HBM scatter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128
COL_TILE = 128


def _spmm_kernel(mask_ref, a_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(mask_ref[0, 0] > 0)
    def _accum():
        o_ref[...] += jax.lax.dot(
            a_ref[...], x_ref[...], precision=jax.lax.Precision.HIGHEST
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_spmm(
    tile_mask: jax.Array,  # int32 (nr, nc): 1 if tile has nonzeros
    a_tiles: jax.Array,  # (nr, nc, R, C) dense tile values
    x: jax.Array,  # (nc * C, D) features
    interpret: bool = False,
) -> jax.Array:
    nr, nc, R, C = a_tiles.shape
    D = x.shape[1]
    grid = (nr, nc)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((None, None, R, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((C, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((R, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * R, D), x.dtype),
        interpret=interpret,
    )(tile_mask.astype(jnp.int32), a_tiles, x)


def tiles_from_edges(
    n: int, src, dst, vals=None, row_tile: int = ROW_TILE, col_tile: int = COL_TILE
):
    """Host-side: build (tile_mask, a_tiles) from an edge list.

    A[dst, src] layout (messages flow src -> dst).  Returns padded n_pad.
    """
    import numpy as np

    n_pad = int(np.ceil(n / row_tile)) * row_tile
    nr, nc = n_pad // row_tile, n_pad // col_tile
    a = np.zeros((nr, nc, row_tile, col_tile), dtype=np.float32)
    v = np.ones(len(src), dtype=np.float32) if vals is None else np.asarray(vals, np.float32)
    r, c = np.asarray(dst), np.asarray(src)
    # np.add.at: duplicate (dst, src) pairs must accumulate
    np.add.at(a, (r // row_tile, c // col_tile, r % row_tile, c % col_tile), v)
    mask = (np.abs(a).sum(axis=(2, 3)) > 0).astype(np.int32)
    return jnp.asarray(mask), jnp.asarray(a), n_pad
