"""Pallas TPU kernel: segmented delta-decode (C-tree chunk decompression).

The C-tree stores chunks as (anchor, fixed-width deltas).  Decoding chunk
``i`` is ``anchor[i] + inclusive_cumsum(deltas[i, :])`` — after the
ragged->padded layout change (ops.py), the whole decode is a batched row
cumsum: the TPU-native replacement for the paper's sequential per-chunk
byte-code decode (§3.2).  The paper already traded compression ratio for
decode speed (byte codes over bit codes); we take the same trade one step
further (fixed-width deltas over byte codes) to make decode a pure
vector op with *zero* serial dependence between chunks.

Tiling: grid = (row_blocks, col_blocks); the column dimension is the
sequential minor axis, carrying each row-block's running sum in a VMEM
scratch accumulator of shape (ROWS, 1) — the standard TPU scan-carry
pattern.  Block shapes are (8k, 128k) multiples to match the VPU (8, 128)
vector registers and keep MXU-aligned layouts downstream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_ROW_BLOCK = 8
DEFAULT_COL_BLOCK = 128


def _decode_kernel(anchors_ref, deltas_ref, out_ref, carry_ref):
    """One (R, C) tile: out = carry + cumsum(deltas, axis=1); carry update.

    anchors are folded into the carry at the first column block.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = anchors_ref[...]  # (R, 1) absolute anchors

    d = deltas_ref[...].astype(jnp.int32)  # (R, C)
    c = jnp.cumsum(d, axis=1)
    out_ref[...] = carry_ref[...] + c
    carry_ref[...] = carry_ref[...] + c[:, -1:]


@functools.partial(jax.jit, static_argnames=("row_block", "col_block", "interpret"))
def delta_decode_padded(
    anchors: jax.Array,  # int32 (n_chunks,)
    deltas: jax.Array,  # int32 (n_chunks, max_len); col 0 MUST be 0
    row_block: int = DEFAULT_ROW_BLOCK,
    col_block: int = DEFAULT_COL_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Decode padded chunks: out[i, j] = anchors[i] + sum(deltas[i, :j+1]).

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    n_chunks, max_len = deltas.shape
    assert n_chunks % row_block == 0 and max_len % col_block == 0
    grid = (n_chunks // row_block, max_len // col_block)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, max_len), jnp.int32),
        scratch_shapes=[pltpu.VMEM((row_block, 1), jnp.int32)],
        interpret=interpret,
    )(anchors.reshape(-1, 1).astype(jnp.int32), deltas.astype(jnp.int32))
