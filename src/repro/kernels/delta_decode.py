"""Pallas TPU kernel: segmented delta-decode (C-tree chunk decompression).

The C-tree stores chunks as (anchor, fixed-width deltas).  Decoding chunk
``i`` is ``anchor[i] + inclusive_cumsum(deltas[i, :])`` — after the
ragged->padded layout change (ops.py), the whole decode is a batched row
cumsum: the TPU-native replacement for the paper's sequential per-chunk
byte-code decode (§3.2).  The paper already traded compression ratio for
decode speed (byte codes over bit codes); we take the same trade one step
further (fixed-width deltas over byte codes) to make decode a pure
vector op with *zero* serial dependence between chunks.

Tiling: grid = (row_blocks, col_blocks); the column dimension is the
sequential minor axis, carrying each row-block's running sum in a VMEM
scratch accumulator of shape (ROWS, 1) — the standard TPU scan-carry
pattern.  Block shapes are (8k, 128k) multiples to match the VPU (8, 128)
vector registers and keep MXU-aligned layouts downstream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_ROW_BLOCK = 8
DEFAULT_COL_BLOCK = 128


def _decode_kernel(anchors_ref, deltas_ref, out_ref, carry_ref):
    """One (R, C) tile: out = carry + cumsum(deltas, axis=1); carry update.

    anchors are folded into the carry at the first column block.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = anchors_ref[...]  # (R, 1) absolute anchors

    d = deltas_ref[...].astype(jnp.int32)  # (R, C)
    c = jnp.cumsum(d, axis=1)
    out_ref[...] = carry_ref[...] + c
    carry_ref[...] = carry_ref[...] + c[:, -1:]


def _row_block_for(deltas_dtype) -> int:
    """Dtype-aware default row block: narrow delta lanes need taller tiles
    to meet the TPU minimum sublane counts (int8 -> (32, 128), int16 ->
    (16, 128) per the Mosaic tiling table); interpret mode accepts any."""
    return {1: 32, 2: 16}.get(jnp.dtype(deltas_dtype).itemsize, DEFAULT_ROW_BLOCK)


def _decode_chunked_kernel(anchors_ref, deltas_ref, pos_ref, add_ref, out_ref, carry_ref):
    """One (R, C) tile of the escape-lane decode (core/compressed layout).

    Same scan-carry cumsum as ``_decode_kernel`` over the narrow delta
    lane, plus the per-chunk overflow corrections: escape ``k`` of a row
    adds ``ovf_add[r, k]`` to every column >= ``ovf_pos[r, k]`` (a step
    function of the GLOBAL column), so the correction is applied per tile
    from global column indices and the carry tracks only the raw lane
    cumsum — corrections never enter the carry, keeping it branch-free.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = anchors_ref[...]  # (R, 1) absolute anchors

    d = deltas_ref[...].astype(jnp.int32)  # (R, C) narrow lane
    c = jnp.cumsum(d, axis=1)
    out = carry_ref[...] + c
    R, C = d.shape
    cols = j * C + jax.lax.broadcasted_iota(jnp.int32, (R, C), 1)
    for k in range(pos_ref.shape[1]):  # static K, unrolled
        out = out + jnp.where(cols >= pos_ref[:, k : k + 1], add_ref[:, k : k + 1], 0)
    out_ref[...] = out
    carry_ref[...] = carry_ref[...] + c[:, -1:]


@functools.partial(jax.jit, static_argnames=("row_block", "col_block", "interpret"))
def delta_decode_chunked(
    anchors: jax.Array,  # int32 (n_chunks,)
    deltas: jax.Array,  # int8|int16 (n_chunks, chunk_len); col 0 MUST be 0
    ovf_pos: jax.Array,  # int32 (n_chunks, K) escape columns, pad chunk_len
    ovf_add: jax.Array,  # int32 (n_chunks, K) escaped delta values
    row_block: int | None = None,
    col_block: int = DEFAULT_COL_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Decode fixed-width chunks with an escape lane (ChunkedStream rows):

      out[i, j] = anchors[i] + sum(lane deltas[i, :j+1])
                  + sum_k ovf_add[i, k] * 1[j >= ovf_pos[i, k]]

    Shapes must be multiples of the block sizes (kernels/ops.py pads).
    The escape tables ride whole (K columns) in every grid step — K is
    tiny and static, so they live comfortably in VMEM next to the tile.
    """
    if row_block is None:
        row_block = _row_block_for(deltas.dtype)
    n_chunks, max_len = deltas.shape
    K = ovf_pos.shape[1]
    assert n_chunks % row_block == 0 and max_len % col_block == 0
    grid = (n_chunks // row_block, max_len // col_block)
    return pl.pallas_call(
        _decode_chunked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, max_len), jnp.int32),
        scratch_shapes=[pltpu.VMEM((row_block, 1), jnp.int32)],
        interpret=interpret,
    )(
        anchors.reshape(-1, 1).astype(jnp.int32),
        deltas,
        ovf_pos.astype(jnp.int32),
        ovf_add.astype(jnp.int32),
    )


def _decode_chunked_adaptive_kernel(
    anchors_ref, deltas_ref, hi_ref, wide_ref, pos_ref, add_ref, out_ref, carry_ref
):
    """Adaptive-width variant of ``_decode_chunked_kernel``: the per-chunk
    width select happens per element before the scan-carry cumsum —

      delta = wide ? hi * 256 + (lane & 0xFF) : lane

    with ``hi`` the pre-gathered hi-byte plane (ops.py resolves the
    compacted plane's cumsum(wide)-1 row index in-trace; block specs
    cannot express that data-dependent gather) and ``wide`` a (R, 1)
    int32 tag riding every column block of its row.  Escape corrections
    are unchanged."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = anchors_ref[...]  # (R, 1) absolute anchors

    lane = deltas_ref[...].astype(jnp.int32)  # (R, C) int8 lane
    hi = hi_ref[...].astype(jnp.int32)
    wide = wide_ref[...]  # (R, 1) int32
    d = jnp.where(wide > 0, hi * 256 + (lane & 0xFF), lane)
    c = jnp.cumsum(d, axis=1)
    out = carry_ref[...] + c
    R, C = d.shape
    cols = j * C + jax.lax.broadcasted_iota(jnp.int32, (R, C), 1)
    for k in range(pos_ref.shape[1]):  # static K, unrolled
        out = out + jnp.where(cols >= pos_ref[:, k : k + 1], add_ref[:, k : k + 1], 0)
    out_ref[...] = out
    carry_ref[...] = carry_ref[...] + c[:, -1:]


@functools.partial(jax.jit, static_argnames=("row_block", "col_block", "interpret"))
def delta_decode_chunked_adaptive(
    anchors: jax.Array,  # int32 (n_chunks,)
    deltas: jax.Array,  # int8 (n_chunks, chunk_len) lane; col 0 MUST be 0
    hi_g: jax.Array,  # int8 (n_chunks, chunk_len) pre-gathered hi bytes
    wide: jax.Array,  # int32 (n_chunks,) nonzero = wide chunk
    ovf_pos: jax.Array,  # int32 (n_chunks, K)
    ovf_add: jax.Array,  # int32 (n_chunks, K)
    row_block: int | None = None,
    col_block: int = DEFAULT_COL_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Decode adaptive-width chunks (ChunkedStream rows with width tags):
    branch-free per-chunk int8/int16 select inside the grid, then the
    same scan-carry cumsum + escape corrections as
    ``delta_decode_chunked``.  Shapes must be block multiples (ops.py
    pads)."""
    if row_block is None:
        row_block = _row_block_for(deltas.dtype)
    n_chunks, max_len = deltas.shape
    K = ovf_pos.shape[1]
    assert n_chunks % row_block == 0 and max_len % col_block == 0
    grid = (n_chunks // row_block, max_len // col_block)
    return pl.pallas_call(
        _decode_chunked_adaptive_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
            pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
            pl.BlockSpec((row_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, max_len), jnp.int32),
        scratch_shapes=[pltpu.VMEM((row_block, 1), jnp.int32)],
        interpret=interpret,
    )(
        anchors.reshape(-1, 1).astype(jnp.int32),
        deltas,
        hi_g,
        wide.reshape(-1, 1).astype(jnp.int32),
        ovf_pos.astype(jnp.int32),
        ovf_add.astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("row_block", "col_block", "interpret"))
def delta_decode_padded(
    anchors: jax.Array,  # int32 (n_chunks,)
    deltas: jax.Array,  # int32 (n_chunks, max_len); col 0 MUST be 0
    row_block: int = DEFAULT_ROW_BLOCK,
    col_block: int = DEFAULT_COL_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Decode padded chunks: out[i, j] = anchors[i] + sum(deltas[i, :j+1]).

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    n_chunks, max_len = deltas.shape
    assert n_chunks % row_block == 0 and max_len % col_block == 0
    grid = (n_chunks // row_block, max_len // col_block)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, max_len), jnp.int32),
        scratch_shapes=[pltpu.VMEM((row_block, 1), jnp.int32)],
        interpret=interpret,
    )(anchors.reshape(-1, 1).astype(jnp.int32), deltas.astype(jnp.int32))
