"""Pallas TPU kernel: split-K flash-decode attention (long-context serve).

``long_500k`` decodes one token against a 524,288-entry KV cache: the
work is a (1, d) @ (d, S) @ (S, d) chain — pure HBM-bandwidth streaming
of K/V.  The kernel tiles S into blocks, keeps the online-softmax
running (max, denominator, accumulator) in VMEM scratch across the
sequential S-grid axis, and never materializes the (1, S) score row in
HBM (FlashDecoding; adapted to TPU: (8, 128)-aligned tiles, fp32
accumulators, no warp-level primitives needed since the grid axis is the
sequential scan).

GQA layout: queries are grouped so each KV head serves q_per_kv query
rows — the q tile is (q_per_kv, d), turning the MXU matmuls into skinny
GEMMs instead of degenerate (1, d) dots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SEQ_BLOCK = 512


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref):
    """Grid (batch*kv_head, seq_blocks); seq axis sequential-minor.

    q: (Q, d) query rows for this kv head; k/v: (S_blk, d); len: (1, 1)
    valid cache length. Scratch m/l/acc carry the online softmax."""
    j = pl.program_id(1)
    s_blk = k_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)  # (Q, d)
    k = k_ref[...].astype(jnp.float32)  # (S, d)
    v = v_ref[...].astype(jnp.float32)  # (S, d)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), precision=jax.lax.Precision.HIGHEST
    ) * scale  # (Q, S)
    # mask beyond valid cache length
    pos = j * s_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0, 0], s, -jnp.inf)

    m_prev = m_ref[...]  # (Q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard: all -inf block (fully masked) -> exp(0)*0 contributions
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)  # (Q, S)
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, precision=jax.lax.Precision.HIGHEST
    )
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("seq_block", "interpret"))
def flash_decode(
    q: jax.Array,  # (BH, Q, d)   BH = batch*kv_heads, Q = q_per_kv
    k: jax.Array,  # (BH, S, d)   KV cache (padded to seq_block multiple)
    v: jax.Array,  # (BH, S, d)
    lengths: jax.Array,  # (BH,) valid cache lengths
    seq_block: int = SEQ_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    BH, Q, d = q.shape
    S = k.shape[1]
    assert S % seq_block == 0
    grid = (BH, S // seq_block)
    return pl.pallas_call(
        _flash_decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, Q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, seq_block, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, seq_block, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((None, Q, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Q, 1), jnp.float32),
            pltpu.VMEM((Q, 1), jnp.float32),
            pltpu.VMEM((Q, d), jnp.float32),
        ],
        interpret=interpret,
    )(
        q,
        k,
        v,
        lengths.reshape(-1, 1).astype(jnp.int32),
    )
