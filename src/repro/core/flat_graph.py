"""TPU-native Aspen graph: CSR over a hash-chunked sorted edge pool.

The faithful level (graph.py) is a tree of C-trees.  Here the whole edge
set is ONE flat C-tree over packed 64-bit keys ``(src << 32) | dst`` —
CSR's edge array *is* the sorted pool, and per-vertex adjacency lists are
contiguous key ranges.  This is exact, not an approximation: a C-tree's
in-order traversal is the sorted pool, and headness is canonical, so the
chunk boundaries (for delta compression) are recomputable by one hash
pass (paper §3.1's key insight, vectorized).

Batch updates are the flat C-tree rank-merge over packed keys followed by
an O(n) offsets rebuild (one searchsorted).  On TPU this linear rebuild is
*bandwidth-optimal* and beats pointer-chasing by orders of magnitude; the
paper's O(k log n) tree update is the CPU-optimal point of the same
design space (DESIGN.md §2, §8).

Everything here is fixed-shape jit: graphs carry static (n, edge_capacity)
and a dynamic valid count, so the same compiled update/query step serves a
whole stream.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compressed as cz
from . import flat_ctree as fct
from .hash import is_head_jnp

SENT64 = fct.sentinel_for(jnp.int64)


class FlatGraph(NamedTuple):
    """Immutable graph snapshot; a jax pytree (shardable over edges).

    ``weights`` optionally carries one float32 per pool slot, parallel
    to ``keys`` (the property-graph value array, DESIGN.md §8): every
    rank-merge / compaction permutes it alongside the keys, inserting a
    duplicate key overwrites its weight, deleting a key drops it.
    ``weights is None`` is the unweighted layout — no value array is
    allocated and every kernel traces exactly as before.
    """

    offsets: jax.Array  # int32[n+1] CSR offsets (valid prefix of pool)
    keys: jax.Array  # int64[cap] sorted packed (src<<32|dst); pad SENT64
    m: jax.Array  # int32 scalar: valid edge count
    weights: jax.Array | None = None  # float32[cap] per-edge values (pad 0)

    @property
    def n(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def edge_capacity(self) -> int:
        return self.keys.shape[0]


def pack(src: jax.Array, dst: jax.Array) -> jax.Array:
    return (src.astype(jnp.int64) << 32) | dst.astype(jnp.int64)


def unpack(keys: jax.Array):
    return (keys >> 32).astype(jnp.int32), (keys & 0xFFFFFFFF).astype(jnp.int32)


def _offsets_from_keys(keys: jax.Array, m: jax.Array, n: int) -> jax.Array:
    """offsets[v] = #edges with src < v; one vectorized searchsorted."""
    bounds = (jnp.arange(n + 1, dtype=jnp.int64) << 32)
    offs = jnp.searchsorted(keys, bounds).astype(jnp.int32)
    return jnp.minimum(offs, m.astype(jnp.int32))


def from_edges(
    n: int,
    edges: np.ndarray,
    edge_capacity: int | None = None,
    weights: np.ndarray | None = None,
) -> FlatGraph:
    """Host build from a (k, 2) directed edge array (dedups; a
    duplicated edge keeps the FIRST occurrence's weight)."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    packed = (edges[:, 0] << 32) | edges[:, 1]
    if weights is None:
        keys = np.unique(packed)
        w = None
    else:
        keys, first = np.unique(packed, return_index=True)
        w = np.asarray(weights, dtype=np.float32).reshape(-1)[first]
    if edge_capacity is None:
        edge_capacity = fct.grown_capacity(keys.size)
    assert keys.size <= edge_capacity
    pool = np.full(edge_capacity, SENT64, dtype=np.int64)
    pool[: keys.size] = keys
    keys_j = jnp.asarray(pool)
    m = jnp.int32(keys.size)
    wpool = None
    if w is not None:
        wbuf = np.zeros(edge_capacity, dtype=np.float32)
        wbuf[: keys.size] = w
        wpool = jnp.asarray(wbuf)
    return FlatGraph(_offsets_from_keys(keys_j, m, n), keys_j, m, wpool)


def with_unit_weights(g: FlatGraph) -> FlatGraph:
    """Attach a unit value array to an unweighted graph (the upgrade an
    unweighted pool takes when its first weighted batch arrives)."""
    if g.weights is not None:
        return g
    return g._replace(weights=jnp.ones(g.edge_capacity, jnp.float32))


def to_edge_array(g: FlatGraph) -> np.ndarray:
    k = np.asarray(g.keys)[: int(g.m)]
    return np.stack([k >> 32, k & 0xFFFFFFFF], axis=1)


def to_weight_array(g: FlatGraph) -> np.ndarray | None:
    """Per-edge weights aligned with ``to_edge_array`` (None when
    unweighted)."""
    return None if g.weights is None else np.asarray(g.weights)[: int(g.m)]


# ---------------------------------------------------------------------------
# queries (jit, fixed shape)
# ---------------------------------------------------------------------------


@jax.jit
def degrees(g: FlatGraph) -> jax.Array:
    return jnp.diff(g.offsets)


@jax.jit
def edge_endpoints(g: FlatGraph):
    """(src, dst) per pool slot (padding slots give n-off-range ids)."""
    return unpack(g.keys)


@jax.jit
def has_edge(g: FlatGraph, src: jax.Array, dst: jax.Array) -> jax.Array:
    q = pack(src, dst)
    idx = jnp.minimum(jnp.searchsorted(g.keys, q), g.keys.shape[0] - 1)
    return g.keys[idx] == q


@functools.partial(jax.jit, static_argnums=(1, 2))
def chunk_structure(g: FlatGraph, b: int, seed: int):
    """Canonical chunk boundaries over the pool: head iff hash(dst) mod b
    == 0 OR first edge of a vertex (every adjacency list restarts its
    prefix, mirroring the per-vertex C-trees of the faithful level)."""
    src, dst = unpack(g.keys)
    valid = jnp.arange(g.keys.shape[0]) < g.m
    hm = is_head_jnp(dst.astype(jnp.uint32), b, seed) & valid
    first_of_vertex = jnp.zeros_like(hm).at[g.offsets[:-1]].set(True) & valid
    return hm | first_of_vertex


# ---------------------------------------------------------------------------
# batch updates (jit): the streaming hot path
# ---------------------------------------------------------------------------


def _insert_edges_impl(
    g: FlatGraph, batch: fct.FlatCTree, out_cap: int, optimized: bool, n_out: int | None
) -> FlatGraph:
    pool = fct.FlatCTree(g.keys, g.m, g.weights)
    fn = fct.union_merge if optimized else fct.union_sort
    merged = fn(pool, batch, out_cap)
    n = g.offsets.shape[0] - 1 if n_out is None else n_out
    return FlatGraph(
        _offsets_from_keys(merged.data, merged.n, n), merged.data, merged.n, merged.vals
    )


def _delete_edges_impl(
    g: FlatGraph, batch: fct.FlatCTree, out_cap: int
) -> FlatGraph:
    pool = fct.FlatCTree(g.keys, g.m, g.weights)
    out = fct.difference(pool, batch, out_cap)
    n = g.offsets.shape[0] - 1
    return FlatGraph(_offsets_from_keys(out.data, out.n, n), out.data, out.n, out.vals)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def insert_edges(
    g: FlatGraph,
    batch: fct.FlatCTree,
    out_cap: int,
    optimized: bool = True,
    n_out: int | None = None,
) -> FlatGraph:
    """InsertEdges: rank-merge batch keys into the pool, rebuild offsets.

    ``batch`` is a FlatCTree of packed keys (sorted, deduped, padded).
    ``n_out`` grows the vertex count (offsets array) when the batch
    introduces vertex ids past the current range.
    """
    return _insert_edges_impl(g, batch, out_cap, optimized, n_out)


@functools.partial(jax.jit, static_argnums=(2,))
def delete_edges(g: FlatGraph, batch: fct.FlatCTree, out_cap: int) -> FlatGraph:
    return _delete_edges_impl(g, batch, out_cap)


# donating variants: the old pool buffer is handed back to XLA so the
# merge can reuse it in place (streaming pipelines that own the sole
# reference; versioned mirrors shared with live readers must NOT donate).
_insert_edges_donating = functools.partial(
    jax.jit, static_argnums=(2, 3, 4), donate_argnums=(0,)
)(_insert_edges_impl)
_delete_edges_donating = functools.partial(
    jax.jit, static_argnums=(2,), donate_argnums=(0,)
)(_delete_edges_impl)


def insert_edges_device(
    g: FlatGraph,
    batch: fct.FlatCTree,
    out_cap: int | None = None,
    *,
    optimized: bool = True,
    n_out: int | None = None,
    donate: bool = False,
) -> FlatGraph:
    """Host-free InsertEdges: ``batch`` is already device-resident (see
    ``fct.from_device``), no edge data is copied through numpy, and with
    ``donate=True`` the old pool buffer is donated to the merge.

    NOTE: the ``out_cap=None`` convenience reads two device scalars
    (``g.m``, ``batch.n``) to size the output pool exactly, which blocks
    on the previous merge.  Fully-async pipelines must pass ``out_cap``
    from host-tracked counts, as ``AspenStream`` does.  (Sizing from
    static shapes instead would grow the pool on every call.)

    Donation invalidates ``g``'s buffers — only pass it when the caller
    holds the sole reference (NOT for pools shared across live versions;
    backends without donation support silently copy instead).
    """
    if out_cap is None:
        out_cap = max(g.edge_capacity, fct.grown_capacity(int(g.m) + int(batch.n)))
    fn = _insert_edges_donating if donate else insert_edges
    return fn(g, batch, out_cap, optimized, n_out)


def delete_edges_device(
    g: FlatGraph, batch: fct.FlatCTree, out_cap: int | None = None, *, donate: bool = False
) -> FlatGraph:
    """Host-free DeleteEdges (see ``insert_edges_device`` for donation)."""
    if out_cap is None:
        out_cap = g.edge_capacity
    fn = _delete_edges_donating if donate else delete_edges
    return fn(g, batch, out_cap)


# ---------------------------------------------------------------------------
# compressed pool: the paper's bytes-per-edge layout, device-resident
# ---------------------------------------------------------------------------


class CompressedPool(NamedTuple):
    """FlatGraph with the dst lane chunk-compressed (paper §3.2 on device).

    Same CSR contract as FlatGraph — ``offsets`` indexes the sorted pool,
    ``m`` counts the valid prefix — but the pool itself is factored:

    * src ids are IMPLIED by ``offsets`` (a src-major run never needs its
      src stored per edge; one searchsorted recovers it), and
    * dst ids are delta-chunked (``core/compressed.ChunkedStream``): an
      int32 anchor plus int8/int16 deltas per 128-slot chunk with an
      escape lane for overflow deltas.

    At int16 lane width this is ~2.6 resident bytes/edge against the raw
    pool's 8 (the packed int64 key), before the O(n) offsets both layouts
    share.  ``weights`` stays an uncompressed float32 lane (values are
    not delta-friendly), padded to the chunked capacity.

    Updates decompress -> rank-merge -> recompress inside ONE jit
    (``insert_edges_compressed``): the uncompressed pool exists only as a
    transient inside the update step, the *resident* state is always
    compressed — the CPMA-style contract for batch updates on compressed
    flat layouts.
    """

    offsets: jax.Array  # int32[n+1] CSR offsets (valid prefix of pool)
    dst: cz.ChunkedStream  # chunked dst per pool slot; length = capacity
    m: jax.Array  # int32 scalar: valid edge count
    weights: jax.Array | None = None  # float32[cap] per-edge values (pad 0)

    @property
    def n(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def edge_capacity(self) -> int:
        return self.dst.length


def src_from_offsets(offsets: jax.Array, cap: int) -> jax.Array:
    """Recover per-slot src ids from CSR offsets (slot j belongs to the
    vertex whose offset range contains j); slots past offsets[n] map to n."""
    slots = jnp.arange(cap, dtype=offsets.dtype)
    return (jnp.searchsorted(offsets, slots, side="right") - 1).astype(jnp.int32)


def _compress_impl(
    g: FlatGraph, width: int, k: int, hi_cap: int | None = None
) -> CompressedPool:
    cap = g.edge_capacity
    _, dst = unpack(g.keys)
    # Pad slots hold SENT64 (dst lane decodes to -1); encoding that cliff
    # would waste an escape slot per boundary chunk, so carry the last
    # valid dst forward instead — decompress masks pad slots to SENT64
    # from ``m`` anyway, the encoded pad content is never observed.
    last = dst[jnp.maximum(g.m - 1, 0)]
    dst_enc = jnp.where(jnp.arange(cap) < g.m, dst, last)
    if hi_cap is None:
        stream = cz.encode_stream(dst_enc, width=width, k=k)
    else:  # adaptive per-chunk widths; ``width`` is ignored
        stream = cz.encode_stream_adaptive(dst_enc, hi_cap=hi_cap, k=k)
    w = g.weights
    if w is not None and stream.length > cap:
        w = jnp.pad(w, (0, stream.length - cap))
    return CompressedPool(g.offsets, stream, g.m.astype(jnp.int32), w)


compress = functools.partial(
    jax.jit, static_argnames=("width", "k", "hi_cap")
)(lambda g, width=2, k=cz.OVF_SLOTS, hi_cap=None: _compress_impl(g, width, k, hi_cap))
compress.__doc__ = (
    "jit FlatGraph -> CompressedPool (static lane width/escape capacity;"
    " hi_cap selects the adaptive per-chunk-width layout)."
)


def _decompress_impl(cg: CompressedPool) -> FlatGraph:
    cap = cg.edge_capacity
    dst = cz.decode_stream(cg.dst)
    src = src_from_offsets(cg.offsets, cap)
    packed = (src.astype(jnp.int64) << 32) | (dst.astype(jnp.int64) & 0xFFFFFFFF)
    keys = jnp.where(jnp.arange(cap) < cg.m, packed, SENT64)
    return FlatGraph(cg.offsets, keys, cg.m, cg.weights)


decompress = jax.jit(_decompress_impl)
decompress.__doc__ = (
    "jit CompressedPool -> FlatGraph (exact inverse of ``compress`` for"
    " non-spilled streams; pad slots come back as SENT64)."
)


def compress_host(
    g: FlatGraph,
    width: int | None = None,
    k: int = cz.OVF_SLOTS,
    hi_headroom: float = 0.0,
) -> CompressedPool:
    """Host build: compress with width selection and a one-time spill
    check (the one place a host sync is acceptable — builds and
    rebuilds, not the streaming hot path).

    ``width=None`` (the default) builds the ADAPTIVE per-chunk-width
    layout: encode once with a full-capacity hi plane, then slice the
    plane to exactly the wide-chunk count — resident bytes match
    ``chunk_stats(g)["bytes_ideal"]`` by construction.  ``hi_headroom``
    reserves extra hi rows as a fraction of the chunk count so streaming
    updates can widen chunks in place without spilling (0.0 = exact
    fit).  ``width=1|2`` pins the fixed-width layout.  Raises if the
    stream spills its escape lane either way — the caller keeps the raw
    layout; silent corruption is never an option.
    """
    if width is None:
        R = (max(g.edge_capacity, 1) + cz.CHUNK - 1) // cz.CHUNK
        cg = compress(g, k=k, hi_cap=R)
        if bool(cg.dst.spill):
            raise ValueError(
                f"graph spills the k={k} escape lane even at adaptive "
                "(int16-wide) chunks; keep the raw pool (delta gaps "
                "exceed the chunk escape budget)"
            )
        n_wide = int(np.asarray(cg.dst.wide).sum())
        hi_cap = n_wide
        if hi_headroom > 0.0:
            hi_cap = min(R, n_wide + max(4, int(np.ceil(hi_headroom * R))))
        hi = jnp.asarray(np.asarray(cg.dst.hi)[:hi_cap])
        return cg._replace(dst=cg.dst._replace(hi=hi))
    cg = compress(g, width=width, k=k)
    if bool(cg.dst.spill):
        raise ValueError(
            f"graph spills the k={k} escape lane at width={width} deltas; "
            "keep the raw pool (delta gaps exceed the chunk escape budget)"
        )
    return cg


def with_unit_weights_compressed(cg: CompressedPool) -> CompressedPool:
    """Compressed counterpart of ``with_unit_weights``."""
    if cg.weights is not None:
        return cg
    return cg._replace(weights=jnp.ones(cg.edge_capacity, jnp.float32))


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def insert_edges_compressed(
    cg: CompressedPool,
    batch: fct.FlatCTree,
    out_cap: int,
    optimized: bool = True,
    n_out: int | None = None,
) -> CompressedPool:
    """InsertEdges on the compressed pool: decompress -> rank-merge ->
    recompress, one jit.  Lane width (or the adaptive layout's hi-plane
    capacity) and escape capacity are inherited from the input stream
    (static via its dtypes/shapes) — adaptive streams re-select each
    chunk's width on recompress — so a whole update stream reuses one
    compiled step.  The output spill flag ORs in the input's — once a
    stream spills it stays flagged until rebuilt."""
    g = _decompress_impl(cg)
    g2 = _insert_edges_impl(g, batch, out_cap, optimized, n_out)
    hi_cap = cg.dst.hi.shape[-2] if cg.dst.hi is not None else None
    out = _compress_impl(g2, cg.dst.width, cg.dst.k, hi_cap)
    return out._replace(dst=out.dst._replace(spill=out.dst.spill | cg.dst.spill))


@functools.partial(jax.jit, static_argnums=(2,))
def delete_edges_compressed(
    cg: CompressedPool, batch: fct.FlatCTree, out_cap: int
) -> CompressedPool:
    """DeleteEdges on the compressed pool (see ``insert_edges_compressed``)."""
    g = _decompress_impl(cg)
    g2 = _delete_edges_impl(g, batch, out_cap)
    hi_cap = cg.dst.hi.shape[-2] if cg.dst.hi is not None else None
    out = _compress_impl(g2, cg.dst.width, cg.dst.k, hi_cap)
    return out._replace(dst=out.dst._replace(spill=out.dst.spill | cg.dst.spill))


def chunk_stats(
    g: FlatGraph, *, b: int = cz.CHUNK, seed: int = 0, k: int = cz.OVF_SLOTS
) -> dict:
    """Host-side reference statistics for the compressed layout.

    Wires the canonical ``chunk_structure`` boundaries (hash heads — the
    paper's recomputable chunking) alongside the fixed-geometry chunks the
    device layout actually uses, and reports per-chunk delta widths and
    escape counts.  ``bytes_ideal`` is the EXACT resident byte count of
    the adaptive per-chunk-width layout (``compress_host(g)``): the stat
    and the encoder agree by construction — a chunk goes wide iff more
    than ``k`` of its deltas overflow int8, and the layout pays
    anchors(4) + lane(CHUNK) + wide tag(1) + escape slots(8k) per chunk
    plus CHUNK hi-plane bytes per wide chunk.  ``tests/test_compressed.py``
    pins ``bytes_ideal == stream_nbytes`` of the built pool on RMAT
    streams; the BYTES bench reports it next to the fixed-width layouts.
    """
    heads = np.asarray(chunk_structure(g, b, seed))
    m = int(g.m)
    cap = g.edge_capacity
    # low 32 bits viewed as int32 (matching device ``unpack``), widened
    dst = (np.asarray(g.keys) & 0xFFFFFFFF).astype(np.uint32).view(np.int32).astype(np.int64)
    if m > 0:
        dst[m:] = dst[m - 1]  # encoder's carry-forward pad convention
    else:
        dst[:] = 0
    capC = ((max(cap, 1) + cz.CHUNK - 1) // cz.CHUNK) * cz.CHUNK
    dstp = np.concatenate([dst, np.full(capC - cap, dst[-1] if cap else 0, np.int64)])
    rows = dstp.reshape(-1, cz.CHUNK)
    deltas = np.diff(rows, axis=1, prepend=rows[:, :1])
    absd = np.abs(deltas)
    chunk_max = absd.max(axis=1) if rows.size else np.zeros(0, np.int64)
    width_per_chunk = np.where(chunk_max <= 127, 1, np.where(chunk_max <= 32767, 2, 4))
    esc8 = (absd > 127).sum(axis=1)
    esc16 = (absd > 32767).sum(axis=1)
    R = rows.shape[0]
    ovf_bytes = 2 * 4 * k  # pos + add lanes, int32
    bytes_fixed = {
        w: R * (4 + w * cz.CHUNK + ovf_bytes) for w in (1, 2)
    }
    # the adaptive encoder's exact width rule + byte accounting
    wide = esc8 > k
    n_wide = int(wide.sum())
    bytes_ideal = R * (4 + cz.CHUNK + 1 + ovf_bytes) + n_wide * cz.CHUNK
    return {
        "canonical_chunks": int(heads.sum()),
        "fixed_chunks": R,
        "max_abs_delta": int(chunk_max.max()) if R else 0,
        "width_per_chunk": width_per_chunk,
        "escapes_i8": int(esc8.sum()),
        "escapes_i16": int(esc16.sum()),
        "spill_i8": bool((esc8 > k).any()),
        "spill_i16": bool((esc16 > k).any()),
        "bytes_fixed": bytes_fixed,
        "n_wide": n_wide,
        "bytes_ideal": int(bytes_ideal),
    }


def batch_from_edges(
    edges: np.ndarray, cap: int | None = None, weights: np.ndarray | None = None
) -> fct.FlatCTree:
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    keys = (edges[:, 0] << 32) | edges[:, 1]
    return fct.from_array(keys, cap=cap, dtype=jnp.int64, vals=weights)


def insert_edges_host(
    g: FlatGraph,
    edges: np.ndarray,
    optimized: bool = True,
    weights: np.ndarray | None = None,
) -> FlatGraph:
    """Host-driven insert with capacity policy (quantized growth).  A
    weighted batch against an unweighted pool upgrades the pool to unit
    weights first (insert overwrites the weight of an existing edge)."""
    if weights is not None and g.weights is None:
        g = with_unit_weights(g)
    batch = batch_from_edges(edges, weights=weights)
    need = int(g.m) + int(batch.n)
    cap = max(g.edge_capacity, fct.grown_capacity(need))
    return insert_edges(g, batch, cap, optimized)


def delete_edges_host(g: FlatGraph, edges: np.ndarray) -> FlatGraph:
    batch = batch_from_edges(edges)
    return delete_edges(g, batch, g.edge_capacity)
