"""TPU-native Aspen graph: CSR over a hash-chunked sorted edge pool.

The faithful level (graph.py) is a tree of C-trees.  Here the whole edge
set is ONE flat C-tree over packed 64-bit keys ``(src << 32) | dst`` —
CSR's edge array *is* the sorted pool, and per-vertex adjacency lists are
contiguous key ranges.  This is exact, not an approximation: a C-tree's
in-order traversal is the sorted pool, and headness is canonical, so the
chunk boundaries (for delta compression) are recomputable by one hash
pass (paper §3.1's key insight, vectorized).

Batch updates are the flat C-tree rank-merge over packed keys followed by
an O(n) offsets rebuild (one searchsorted).  On TPU this linear rebuild is
*bandwidth-optimal* and beats pointer-chasing by orders of magnitude; the
paper's O(k log n) tree update is the CPU-optimal point of the same
design space (DESIGN.md §2, §8).

Everything here is fixed-shape jit: graphs carry static (n, edge_capacity)
and a dynamic valid count, so the same compiled update/query step serves a
whole stream.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import flat_ctree as fct
from .hash import is_head_jnp

SENT64 = fct.sentinel_for(jnp.int64)


class FlatGraph(NamedTuple):
    """Immutable graph snapshot; a jax pytree (shardable over edges).

    ``weights`` optionally carries one float32 per pool slot, parallel
    to ``keys`` (the property-graph value array, DESIGN.md §8): every
    rank-merge / compaction permutes it alongside the keys, inserting a
    duplicate key overwrites its weight, deleting a key drops it.
    ``weights is None`` is the unweighted layout — no value array is
    allocated and every kernel traces exactly as before.
    """

    offsets: jax.Array  # int32[n+1] CSR offsets (valid prefix of pool)
    keys: jax.Array  # int64[cap] sorted packed (src<<32|dst); pad SENT64
    m: jax.Array  # int32 scalar: valid edge count
    weights: jax.Array | None = None  # float32[cap] per-edge values (pad 0)

    @property
    def n(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def edge_capacity(self) -> int:
        return self.keys.shape[0]


def pack(src: jax.Array, dst: jax.Array) -> jax.Array:
    return (src.astype(jnp.int64) << 32) | dst.astype(jnp.int64)


def unpack(keys: jax.Array):
    return (keys >> 32).astype(jnp.int32), (keys & 0xFFFFFFFF).astype(jnp.int32)


def _offsets_from_keys(keys: jax.Array, m: jax.Array, n: int) -> jax.Array:
    """offsets[v] = #edges with src < v; one vectorized searchsorted."""
    bounds = (jnp.arange(n + 1, dtype=jnp.int64) << 32)
    offs = jnp.searchsorted(keys, bounds).astype(jnp.int32)
    return jnp.minimum(offs, m.astype(jnp.int32))


def from_edges(
    n: int,
    edges: np.ndarray,
    edge_capacity: int | None = None,
    weights: np.ndarray | None = None,
) -> FlatGraph:
    """Host build from a (k, 2) directed edge array (dedups; a
    duplicated edge keeps the FIRST occurrence's weight)."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    packed = (edges[:, 0] << 32) | edges[:, 1]
    if weights is None:
        keys = np.unique(packed)
        w = None
    else:
        keys, first = np.unique(packed, return_index=True)
        w = np.asarray(weights, dtype=np.float32).reshape(-1)[first]
    if edge_capacity is None:
        edge_capacity = fct.grown_capacity(keys.size)
    assert keys.size <= edge_capacity
    pool = np.full(edge_capacity, SENT64, dtype=np.int64)
    pool[: keys.size] = keys
    keys_j = jnp.asarray(pool)
    m = jnp.int32(keys.size)
    wpool = None
    if w is not None:
        wbuf = np.zeros(edge_capacity, dtype=np.float32)
        wbuf[: keys.size] = w
        wpool = jnp.asarray(wbuf)
    return FlatGraph(_offsets_from_keys(keys_j, m, n), keys_j, m, wpool)


def with_unit_weights(g: FlatGraph) -> FlatGraph:
    """Attach a unit value array to an unweighted graph (the upgrade an
    unweighted pool takes when its first weighted batch arrives)."""
    if g.weights is not None:
        return g
    return g._replace(weights=jnp.ones(g.edge_capacity, jnp.float32))


def to_edge_array(g: FlatGraph) -> np.ndarray:
    k = np.asarray(g.keys)[: int(g.m)]
    return np.stack([k >> 32, k & 0xFFFFFFFF], axis=1)


def to_weight_array(g: FlatGraph) -> np.ndarray | None:
    """Per-edge weights aligned with ``to_edge_array`` (None when
    unweighted)."""
    return None if g.weights is None else np.asarray(g.weights)[: int(g.m)]


# ---------------------------------------------------------------------------
# queries (jit, fixed shape)
# ---------------------------------------------------------------------------


@jax.jit
def degrees(g: FlatGraph) -> jax.Array:
    return jnp.diff(g.offsets)


@jax.jit
def edge_endpoints(g: FlatGraph):
    """(src, dst) per pool slot (padding slots give n-off-range ids)."""
    return unpack(g.keys)


@jax.jit
def has_edge(g: FlatGraph, src: jax.Array, dst: jax.Array) -> jax.Array:
    q = pack(src, dst)
    idx = jnp.minimum(jnp.searchsorted(g.keys, q), g.keys.shape[0] - 1)
    return g.keys[idx] == q


@functools.partial(jax.jit, static_argnums=(1, 2))
def chunk_structure(g: FlatGraph, b: int, seed: int):
    """Canonical chunk boundaries over the pool: head iff hash(dst) mod b
    == 0 OR first edge of a vertex (every adjacency list restarts its
    prefix, mirroring the per-vertex C-trees of the faithful level)."""
    src, dst = unpack(g.keys)
    valid = jnp.arange(g.keys.shape[0]) < g.m
    hm = is_head_jnp(dst.astype(jnp.uint32), b, seed) & valid
    first_of_vertex = jnp.zeros_like(hm).at[g.offsets[:-1]].set(True) & valid
    return hm | first_of_vertex


# ---------------------------------------------------------------------------
# batch updates (jit): the streaming hot path
# ---------------------------------------------------------------------------


def _insert_edges_impl(
    g: FlatGraph, batch: fct.FlatCTree, out_cap: int, optimized: bool, n_out: int | None
) -> FlatGraph:
    pool = fct.FlatCTree(g.keys, g.m, g.weights)
    fn = fct.union_merge if optimized else fct.union_sort
    merged = fn(pool, batch, out_cap)
    n = g.offsets.shape[0] - 1 if n_out is None else n_out
    return FlatGraph(
        _offsets_from_keys(merged.data, merged.n, n), merged.data, merged.n, merged.vals
    )


def _delete_edges_impl(
    g: FlatGraph, batch: fct.FlatCTree, out_cap: int
) -> FlatGraph:
    pool = fct.FlatCTree(g.keys, g.m, g.weights)
    out = fct.difference(pool, batch, out_cap)
    n = g.offsets.shape[0] - 1
    return FlatGraph(_offsets_from_keys(out.data, out.n, n), out.data, out.n, out.vals)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def insert_edges(
    g: FlatGraph,
    batch: fct.FlatCTree,
    out_cap: int,
    optimized: bool = True,
    n_out: int | None = None,
) -> FlatGraph:
    """InsertEdges: rank-merge batch keys into the pool, rebuild offsets.

    ``batch`` is a FlatCTree of packed keys (sorted, deduped, padded).
    ``n_out`` grows the vertex count (offsets array) when the batch
    introduces vertex ids past the current range.
    """
    return _insert_edges_impl(g, batch, out_cap, optimized, n_out)


@functools.partial(jax.jit, static_argnums=(2,))
def delete_edges(g: FlatGraph, batch: fct.FlatCTree, out_cap: int) -> FlatGraph:
    return _delete_edges_impl(g, batch, out_cap)


# donating variants: the old pool buffer is handed back to XLA so the
# merge can reuse it in place (streaming pipelines that own the sole
# reference; versioned mirrors shared with live readers must NOT donate).
_insert_edges_donating = functools.partial(
    jax.jit, static_argnums=(2, 3, 4), donate_argnums=(0,)
)(_insert_edges_impl)
_delete_edges_donating = functools.partial(
    jax.jit, static_argnums=(2,), donate_argnums=(0,)
)(_delete_edges_impl)


def insert_edges_device(
    g: FlatGraph,
    batch: fct.FlatCTree,
    out_cap: int | None = None,
    *,
    optimized: bool = True,
    n_out: int | None = None,
    donate: bool = False,
) -> FlatGraph:
    """Host-free InsertEdges: ``batch`` is already device-resident (see
    ``fct.from_device``), no edge data is copied through numpy, and with
    ``donate=True`` the old pool buffer is donated to the merge.

    NOTE: the ``out_cap=None`` convenience reads two device scalars
    (``g.m``, ``batch.n``) to size the output pool exactly, which blocks
    on the previous merge.  Fully-async pipelines must pass ``out_cap``
    from host-tracked counts, as ``AspenStream`` does.  (Sizing from
    static shapes instead would grow the pool on every call.)

    Donation invalidates ``g``'s buffers — only pass it when the caller
    holds the sole reference (NOT for pools shared across live versions;
    backends without donation support silently copy instead).
    """
    if out_cap is None:
        out_cap = max(g.edge_capacity, fct.grown_capacity(int(g.m) + int(batch.n)))
    fn = _insert_edges_donating if donate else insert_edges
    return fn(g, batch, out_cap, optimized, n_out)


def delete_edges_device(
    g: FlatGraph, batch: fct.FlatCTree, out_cap: int | None = None, *, donate: bool = False
) -> FlatGraph:
    """Host-free DeleteEdges (see ``insert_edges_device`` for donation)."""
    if out_cap is None:
        out_cap = g.edge_capacity
    fn = _delete_edges_donating if donate else delete_edges
    return fn(g, batch, out_cap)


def batch_from_edges(
    edges: np.ndarray, cap: int | None = None, weights: np.ndarray | None = None
) -> fct.FlatCTree:
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    keys = (edges[:, 0] << 32) | edges[:, 1]
    return fct.from_array(keys, cap=cap, dtype=jnp.int64, vals=weights)


def insert_edges_host(
    g: FlatGraph,
    edges: np.ndarray,
    optimized: bool = True,
    weights: np.ndarray | None = None,
) -> FlatGraph:
    """Host-driven insert with capacity policy (quantized growth).  A
    weighted batch against an unweighted pool upgrades the pool to unit
    weights first (insert overwrites the weight of an existing edge)."""
    if weights is not None and g.weights is None:
        g = with_unit_weights(g)
    batch = batch_from_edges(edges, weights=weights)
    need = int(g.m) + int(batch.n)
    cap = max(g.edge_capacity, fct.grown_capacity(need))
    return insert_edges(g, batch, cap, optimized)


def delete_edges_host(g: FlatGraph, edges: np.ndarray) -> FlatGraph:
    batch = batch_from_edges(edges)
    return delete_edges(g, batch, g.edge_capacity)
