"""Sharded flat C-tree pool: the beyond-paper distributed substrate.

The baseline flat union (flat_ctree.union_merge) is a *global* rank-merge:
under GSPMD, the cross-shard searchsorteds force all-gathers of the whole
pool — collective-bound at pod scale (EXPERIMENTS.md §Perf baseline).

Here each device owns a contiguous KEY RANGE of the pool (range-sharded,
like a distributed LSM level).  A batch update becomes:

  1. all-gather the (small) batch — k << n bytes on the wire;
  2. every shard slices the batch rows falling in its key range
     (two searchsorteds against its own boundaries);
  3. shard-LOCAL rank-merge into its own slack capacity.

Collective traffic drops from O(pool) to O(batch); the merge itself stays
bandwidth-optimal locally.  Queries (member) need one searchsorted against
the shard boundary table (replicated, n_shards entries) then a local
binary-search probe over flat index math — O(queries · log cap) scalar
gathers on the wire, never a cross-shard row gather.

Rebalancing: shards fill unevenly; when any shard exceeds its capacity
the host triggers a REBALANCE (an O(n) all-to-all redistribution to equal
counts — amortized over many updates, like LSM compaction).  The
imbalance statistics and trigger live here; the dry run lowers the
steady-state update step.

Graph substrate (DESIGN.md §9)
------------------------------
Beyond the bare sorted-int64 set, the pool is a full graph substrate:
keys are the packed ``(src << 32) | dst`` edge encoding of
``flat_graph``, an optional VALUE LANE carries one float32 per slot
(the property-graph weight array, permuted by the same shard-local
rank-merge; insert overwrites, delete drops), ``make_delete_step``
is the shard-local MultiDelete, and ``shard_aux`` derives the
per-shard CSR auxiliary state (src offsets, clipped endpoints,
dst-major permutation — the shard-local ``EngineAux``) that the
sharded traversal backend (``traversal/sharded_backend.py``) runs
edgeMap over.  ``ShardedGraph`` pairs the pool with its static vertex
count; ``AspenStream(mirror="sharded")`` maintains one per version.

Implemented with shard_map so the collective schedule is explicit, not
GSPMD-inferred.  ``n_shards`` may exceed the mesh size (each device then
owns a BLOCK of shard rows); it must be a multiple of the mesh size.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import compressed as cz
from .flat_ctree import sentinel_for

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

SENT = sentinel_for(jnp.int64)


class ShardedPool(NamedTuple):
    """Range-sharded sorted pool; a jax pytree.

    data  : (n_shards, cap_per) sorted within each shard; pad = SENT
    n     : (n_shards,) valid counts
    lo    : (n_shards,) inclusive lower key boundary of each shard
    vals  : optional (n_shards, cap_per) per-slot values (pad 0),
            permuted by every shard-local merge / compaction alongside
            the keys (insert overwrites a duplicate key's value, delete
            drops it — the flat_ctree.FlatCTree.vals semantics, sharded)
    """

    data: jax.Array
    n: jax.Array
    lo: jax.Array
    vals: Optional[jax.Array] = None


def from_array(
    values: np.ndarray,
    n_shards: int,
    cap_per: int | None = None,
    vals: np.ndarray | None = None,
) -> ShardedPool:
    """Host build: dedup + range-partition to equal counts.  ``vals``
    optionally attaches one value per element (a duplicated key keeps
    the FIRST occurrence's value, matching ``flat_ctree.from_array``)."""
    raw = np.asarray(values, dtype=np.int64)
    if vals is None:
        v = np.unique(raw)
        w = None
    else:
        v, first = np.unique(raw, return_index=True)
        w = np.asarray(vals, dtype=np.float32).reshape(-1)[first]
    per = -(-v.size // n_shards) if v.size else 1
    if cap_per is None:
        cap_per = max(8, int(2 ** np.ceil(np.log2(per * 2 + 1))))
    data = np.full((n_shards, cap_per), SENT, dtype=np.int64)
    wdata = np.zeros((n_shards, cap_per), dtype=np.float32) if w is not None else None
    n = np.zeros((n_shards,), dtype=np.int32)
    lo = np.full((n_shards,), np.iinfo(np.int64).min, dtype=np.int64)
    # An EMPTY shard's lo must start strictly past every key stored
    # before it (last key + 1, not a copy of the previous lo): with
    # duplicated boundaries, a query equal to the boundary key routes —
    # by the searchsorted(side="right") convention — to the LAST shard
    # claiming that lo, an empty one, and membership misses; worse, the
    # insert step would re-insert that key there as a duplicate.
    next_lo = 0
    for s in range(n_shards):
        chunk = v[s * per : (s + 1) * per]
        data[s, : chunk.size] = chunk
        n[s] = chunk.size
        if chunk.size:
            lo[s] = chunk[0]
            next_lo = int(chunk[-1]) + 1
        else:
            lo[s] = next_lo
        if wdata is not None:
            wdata[s, : chunk.size] = w[s * per : (s + 1) * per]
    lo[0] = np.iinfo(np.int64).min
    return ShardedPool(
        jnp.asarray(data),
        jnp.asarray(n),
        jnp.asarray(lo),
        None if wdata is None else jnp.asarray(wdata),
    )


def to_array(p: ShardedPool) -> np.ndarray:
    data = np.asarray(p.data)
    n = np.asarray(p.n)
    return np.concatenate([data[s, : n[s]] for s in range(data.shape[0])])


def to_val_array(p: ShardedPool) -> np.ndarray | None:
    """Valid-prefix values aligned with ``to_array`` (None on plain sets)."""
    if p.vals is None:
        return None
    vals = np.asarray(p.vals)
    n = np.asarray(p.n)
    return np.concatenate([vals[s, : n[s]] for s in range(vals.shape[0])])


def with_unit_vals(p: ShardedPool) -> ShardedPool:
    """Attach a unit value lane (the upgrade an unweighted pool takes
    when its first weighted batch arrives)."""
    if p.vals is not None:
        return p
    return p._replace(vals=jnp.ones(p.data.shape, jnp.float32))


def _local_merge(
    pool_row: jax.Array,
    n_valid: jax.Array,
    batch: jax.Array,
    b_lo: jax.Array,
    b_hi: jax.Array,
    vrow: jax.Array | None = None,
    bvals: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array | None]:
    """Merge batch[b_lo:b_hi) into one shard row (fixed shapes, O(n+k)).

    The value lane, when present, rides the same two scatters as
    ``flat_ctree.union_merge``: a duplicate batch key lands its value on
    the matched pool slot (insert overwrites)."""
    cap = pool_row.shape[0]
    kcap = batch.shape[0]
    # mask the batch to this shard's range
    idx = jnp.arange(kcap)
    mine = (idx >= b_lo) & (idx < b_hi)
    masked = jnp.where(mine, batch, SENT)
    if bvals is None:
        b = jnp.sort(masked)  # my rows to the front (already sorted among themselves)
        bv = None
    else:
        order = jnp.argsort(masked)  # stable: value lane rides along
        b = masked[order]
        bv = bvals[order]
    n_mine = (b_hi - b_lo).astype(jnp.int32)
    valid_a = jnp.arange(cap) < n_valid
    valid_b = jnp.arange(kcap) < n_mine
    # dedup b against a
    ia = jnp.minimum(jnp.searchsorted(pool_row, b), cap - 1)
    dup_b = (pool_row[ia] == b) & valid_b
    keep_b = valid_b & ~dup_b
    kb_excl = jnp.cumsum(keep_b.astype(jnp.int32)) - keep_b
    ra = jnp.searchsorted(b, pool_row)
    kept_below_a = jnp.where(
        ra > 0,
        kb_excl[jnp.minimum(ra - 1, kcap - 1)] + keep_b[jnp.minimum(ra - 1, kcap - 1)],
        0,
    )
    pos_a = jnp.arange(cap, dtype=jnp.int32) + kept_below_a.astype(jnp.int32)
    pos_a = jnp.where(valid_a, pos_a, cap)
    rb = jnp.searchsorted(pool_row, b)
    pos_b = rb.astype(jnp.int32) + kb_excl.astype(jnp.int32)
    pos_b = jnp.where(keep_b, pos_b, cap)
    out = jnp.full((cap,), SENT, dtype=pool_row.dtype)
    out = out.at[pos_a].set(pool_row, mode="drop")
    out = out.at[pos_b].set(b, mode="drop")
    n_new = n_valid + keep_b.sum().astype(jnp.int32)
    if vrow is None:
        return out, n_new, None
    vout = jnp.zeros((cap,), dtype=vrow.dtype)
    vout = vout.at[pos_a].set(vrow, mode="drop")
    vout = vout.at[pos_b].set(bv, mode="drop")
    pos_dup = jnp.where(dup_b, pos_a[ia], cap)  # insert overwrites
    vout = vout.at[pos_dup].set(bv, mode="drop")
    return out, n_new, vout


def make_insert_step(mesh: Mesh, axis_names: Tuple[str, ...]):
    """Build the shard_map'd update step for a given mesh.

    axis_names: the mesh axes the shard dimension is split over (all of
    them: every chip owns one BLOCK of shard rows — n_shards must be a
    multiple of the mesh size; the common case is one row per chip).

    The returned ``step(pool, batch, batch_vals=None)`` merges a sorted,
    deduped, SENT-padded batch into every shard's key range.  A value
    lane on either side upgrades the other to unit values at trace time
    (the flat_ctree ``_aligned_vals`` semantics)."""
    flat_axes = axis_names
    spec_sharded = P(flat_axes)
    spec_sharded2 = P(flat_axes, None)

    def local_plain(data, n, lo, hi, batch):
        # shapes inside shard_map: data (rows, cap), n/lo/hi (rows,),
        # batch (kcap,) REPLICATED (this is the one collective: GSPMD
        # all-gathers the batch operand once).
        def row(drow, nrow, lorow, hirow):
            b_lo = jnp.searchsorted(batch, lorow)
            b_hi = jnp.searchsorted(batch, hirow)
            out, n_new, _ = _local_merge(drow, nrow, batch, b_lo, b_hi)
            return out, n_new

        return jax.vmap(row)(data, n, lo, hi)

    def local_vals(data, n, vals, lo, hi, batch, bvals):
        def row(drow, nrow, vrow, lorow, hirow):
            b_lo = jnp.searchsorted(batch, lorow)
            b_hi = jnp.searchsorted(batch, hirow)
            return _local_merge(drow, nrow, batch, b_lo, b_hi, vrow, bvals)

        return jax.vmap(row)(data, n, vals, lo, hi)

    step_plain = _shard_map(
        local_plain,
        mesh=mesh,
        in_specs=(spec_sharded2, spec_sharded, spec_sharded, spec_sharded, P()),
        out_specs=(spec_sharded2, spec_sharded),
    )
    step_vals = _shard_map(
        local_vals,
        mesh=mesh,
        in_specs=(
            spec_sharded2, spec_sharded, spec_sharded2,
            spec_sharded, spec_sharded, P(), P(),
        ),
        out_specs=(spec_sharded2, spec_sharded, spec_sharded2),
    )

    @jax.jit  # retraces only on shape / weightedness change
    def step(
        pool: ShardedPool, batch: jax.Array, batch_vals: jax.Array | None = None
    ) -> ShardedPool:
        hi = jnp.concatenate(
            [pool.lo[1:], jnp.asarray([jnp.iinfo(jnp.int64).max], jnp.int64)]
        )
        if pool.vals is None and batch_vals is None:
            out, n_new = step_plain(pool.data, pool.n, pool.lo, hi, batch)
            return ShardedPool(out, n_new, pool.lo)
        vals = pool.vals if pool.vals is not None else jnp.ones(
            pool.data.shape, batch_vals.dtype
        )
        bv = batch_vals if batch_vals is not None else jnp.ones(
            batch.shape, vals.dtype
        )
        out, n_new, vout = step_vals(pool.data, pool.n, vals, pool.lo, hi, batch, bv)
        return ShardedPool(out, n_new, pool.lo, vout)

    return step


def make_delete_step(mesh: Mesh, axis_names: Tuple[str, ...]):
    """Shard-local MultiDelete: each shard drops its elements found in
    the (replicated, sorted, SENT-padded) batch and compacts in place.
    Shard boundaries are unchanged — deletion never moves keys across
    ranges.  A dropped key drops its value-lane entry."""
    flat_axes = axis_names
    spec_sharded = P(flat_axes)
    spec_sharded2 = P(flat_axes, None)

    def _rows(data, n, batch, vals=None):
        kcap = batch.shape[0]

        def row(drow, nrow, vrow):
            cap = drow.shape[0]
            idx = jnp.minimum(jnp.searchsorted(batch, drow), kcap - 1)
            hit = (batch[idx] == drow) & (drow != SENT)
            keep = (jnp.arange(cap) < nrow) & ~hit
            pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
            pos = jnp.where(keep, pos, cap)
            out = jnp.full((cap,), SENT, jnp.int64).at[pos].set(drow, mode="drop")
            n_new = keep.sum().astype(jnp.int32)
            if vrow is None:
                return out, n_new, None
            vout = jnp.zeros((cap,), vrow.dtype).at[pos].set(vrow, mode="drop")
            return out, n_new, vout

        if vals is None:
            out, n_new, _ = jax.vmap(lambda d, c: row(d, c, None))(data, n)
            return out, n_new, None
        return jax.vmap(row)(data, n, vals)

    def local_plain(data, n, batch):
        out, n_new, _ = _rows(data, n, batch)
        return out, n_new

    def local_vals(data, n, vals, batch):
        return _rows(data, n, batch, vals)

    step_plain = _shard_map(
        local_plain,
        mesh=mesh,
        in_specs=(spec_sharded2, spec_sharded, P()),
        out_specs=(spec_sharded2, spec_sharded),
    )
    step_vals = _shard_map(
        local_vals,
        mesh=mesh,
        in_specs=(spec_sharded2, spec_sharded, spec_sharded2, P()),
        out_specs=(spec_sharded2, spec_sharded, spec_sharded2),
    )

    @jax.jit
    def step(pool: ShardedPool, batch: jax.Array) -> ShardedPool:
        if pool.vals is None:
            out, n_new = step_plain(pool.data, pool.n, batch)
            return ShardedPool(out, n_new, pool.lo)
        out, n_new, vout = step_vals(pool.data, pool.n, pool.vals, batch)
        return ShardedPool(out, n_new, pool.lo, vout)

    return step


# ---------------------------------------------------------------------------
# queries + rebalance policy (host-driven)
# ---------------------------------------------------------------------------


@jax.jit
def member(p: ShardedPool, queries: jax.Array) -> jax.Array:
    """shard id via the (replicated) boundary table, then a LOCAL probe
    by flat index math: an unrolled binary search over
    ``data.reshape(-1)[s * cap + mid]`` — O(queries · log cap) scalar
    gathers, never a cross-shard ``p.data[s]`` row gather (which would
    put a (queries, cap) block on the wire under GSPMD)."""
    S, cap = p.data.shape
    q = queries.astype(jnp.int64)
    flat = p.data.reshape(-1)
    s = jnp.clip(jnp.searchsorted(p.lo, q, side="right") - 1, 0, S - 1)
    base = s.astype(jnp.int64) * cap
    ns = p.n[s].astype(jnp.int64)
    lo = jnp.zeros(q.shape, jnp.int64)
    hi = ns
    for _ in range(int(np.ceil(np.log2(cap))) + 1):  # static unroll
        active = lo < hi
        mid = (lo + hi) // 2
        v = flat[base + mid]
        go_right = active & (v < q)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    probe = flat[base + jnp.minimum(lo, cap - 1)]
    return (lo < ns) & (probe == q)


def needs_rebalance(p: ShardedPool, slack: float = 0.9) -> bool:
    return bool((np.asarray(p.n) >= slack * p.data.shape[1]).any())


def rebalance(p: ShardedPool, cap_per: int | None = None) -> ShardedPool:
    """O(n) redistribution to equal counts (the amortized compaction);
    the value lane, when present, is preserved through the round-trip."""
    return from_array(
        to_array(p),
        p.data.shape[0],
        cap_per=p.data.shape[1] if cap_per is None else cap_per,
        vals=to_val_array(p),
    )


# ---------------------------------------------------------------------------
# graph substrate: packed-key pool + per-shard CSR aux (DESIGN.md §9)
# ---------------------------------------------------------------------------


class ShardedGraph(NamedTuple):
    """A graph over the range-sharded pool: keys are the packed
    ``(src << 32) | dst`` encoding, ``n`` is the STATIC vertex count
    (host-known; never passed through jit as a tracer).  The pool's
    value lane, when present, is the per-edge weight array."""

    pool: ShardedPool
    n: int

    @property
    def n_shards(self) -> int:
        return self.pool.data.shape[0]

    @property
    def weighted(self) -> bool:
        return self.pool.vals is not None


class ShardAux(NamedTuple):
    """Per-shard CSR auxiliary state: the shard-local ``EngineAux``.

    Every field is laid out (n_shards, ...) so a ``P('shard', ...)``
    in_spec hands each device exactly its own rows; refreshing it is ONE
    fixed-shape jit call over the pool (``shard_aux``), the sharded
    analogue of ``jax_backend.engine_aux``.

    offsets      : int32[S, n+1] CSR into each shard's OWN row (vertex
                   v's local adjacency occupies row[offsets[s, v] :
                   offsets[s, v+1]]; empty for vertices outside the
                   shard's key range)
    src_c, dst_c : int32[S, cap] clipped endpoints per slot
    evalid       : bool[S, cap] slot holds a real edge with a real dst
    degrees      : int32[S, n] per-shard out-degree contribution
    deg_total    : int32[n] global out-degrees (the one cross-shard
                   reduction, done once per refresh, not per query)
    dst_sorted   : int32[S, cap] destinations ascending per row (pad n)
    src_by_dst   : int32[S, cap] sources permuted dst-major per row
    valid_by_dst : bool[S, cap]
    dst_offsets  : int32[S, n+1] segment bounds into dst_sorted per row
    w_by_dst     : float32[S, cap] values dst-major, or None
    """

    offsets: jax.Array
    src_c: jax.Array
    dst_c: jax.Array
    evalid: jax.Array
    degrees: jax.Array
    deg_total: jax.Array
    dst_sorted: jax.Array
    src_by_dst: jax.Array
    valid_by_dst: jax.Array
    dst_offsets: jax.Array
    w_by_dst: Optional[jax.Array] = None


@functools.partial(jax.jit, static_argnums=(1,))
def shard_aux(p: ShardedPool, n: int) -> ShardAux:
    """Derive the per-shard CSR aux from the pool: one fixed-shape jit
    call, vmapped over shard rows (each row's computation touches only
    that row, so under GSPMD it stays shard-local)."""
    cap = p.data.shape[1]
    bounds = jnp.arange(n + 1, dtype=jnp.int64) << 32

    def row(drow, nrow, vrow):
        src = (drow >> 32).astype(jnp.int32)
        dst = (drow & 0xFFFFFFFF).astype(jnp.int32)
        valid = jnp.arange(cap) < nrow
        evalid = valid & (dst >= 0) & (dst < n)
        src_c = jnp.clip(src, 0, max(n - 1, 0))
        dst_c = jnp.clip(dst, 0, max(n - 1, 0))
        offsets = jnp.searchsorted(drow, bounds).astype(jnp.int32)
        offsets = jnp.minimum(offsets, nrow.astype(jnp.int32))
        degrees = jnp.diff(offsets)
        dst_key = jnp.where(evalid, dst_c, jnp.int32(n))
        order = jnp.argsort(dst_key)  # stable in jax
        dst_sorted = dst_key[order]
        dst_offsets = jnp.searchsorted(
            dst_sorted, jnp.arange(n + 1, dtype=jnp.int32)
        ).astype(jnp.int32)
        w_by_dst = None if vrow is None else vrow[order]
        return (
            offsets, src_c, dst_c, evalid, degrees,
            dst_sorted, src_c[order], evalid[order], dst_offsets, w_by_dst,
        )

    if p.vals is None:
        outs = jax.vmap(lambda d, c: row(d, c, None))(p.data, p.n)
    else:
        outs = jax.vmap(row)(p.data, p.n, p.vals)
    (offsets, src_c, dst_c, evalid, degrees,
     dst_sorted, src_by_dst, valid_by_dst, dst_offsets, w_by_dst) = outs
    return ShardAux(
        offsets=offsets,
        src_c=src_c,
        dst_c=dst_c,
        evalid=evalid,
        degrees=degrees,
        deg_total=degrees.sum(axis=0),
        dst_sorted=dst_sorted,
        src_by_dst=src_by_dst,
        valid_by_dst=valid_by_dst,
        dst_offsets=dst_offsets,
        w_by_dst=w_by_dst,
    )


def default_n_shards() -> int:
    return jax.device_count()


def pool_mesh(n_shards: int) -> Mesh:
    """A 1-axis mesh whose size divides ``n_shards``: all devices when
    possible, else the largest divisor of n_shards that fits (a 1-device
    run degenerates to a single-chip mesh, which is still correct —
    every collective becomes a local no-op)."""
    nd = jax.device_count()
    size = 1
    for d in range(min(n_shards, nd), 0, -1):
        if n_shards % d == 0:
            size = d
            break
    return jax.make_mesh((size,), ("shard",))


def graph_from_edges(
    n: int,
    edges: np.ndarray,
    n_shards: int | None = None,
    weights: np.ndarray | None = None,
    cap_per: int | None = None,
) -> ShardedGraph:
    """Host build from a (k, 2) directed edge array (dedups; a
    duplicated edge keeps the FIRST occurrence's weight)."""
    if n_shards is None:
        n_shards = default_n_shards()
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    packed = (edges[:, 0] << 32) | edges[:, 1]
    w = None if weights is None else np.asarray(weights, np.float32).reshape(-1)
    return ShardedGraph(from_array(packed, n_shards, cap_per=cap_per, vals=w), n)


def graph_to_edge_array(sg: ShardedGraph) -> np.ndarray:
    k = to_array(sg.pool)
    return np.stack([k >> 32, k & 0xFFFFFFFF], axis=1)


def graph_to_weight_array(sg: ShardedGraph) -> np.ndarray | None:
    return to_val_array(sg.pool)


def graph_num_edges(sg) -> int:
    """Global edge count; works on both ShardedGraph and
    CompressedShardedGraph (both pools carry per-shard counts)."""
    return int(np.asarray(sg.pool.n).sum())


# ---------------------------------------------------------------------------
# compressed sharded pool: per-shard chunk-compressed dst lane (paper §3.2,
# sharded).  The per-shard variant of flat_graph.CompressedPool.
# ---------------------------------------------------------------------------


class CompressedShardedPool(NamedTuple):
    """ShardedPool with each shard row's dst lane chunk-compressed.

    Same range-sharding contract (``n`` counts, ``lo`` boundaries) but
    the packed-key rows are factored exactly like the flat
    ``CompressedPool``: src ids implied by a per-shard CSR ``offsets``
    row, dst ids delta-chunked per row (``ChunkedStream`` with
    (S, ...)-batched leaves; ``spill`` becomes bool[S]).  Every leaf is
    laid out (n_shards, ...) so a ``P('shard', ...)`` spec hands each
    device its own rows, same as the raw pool.

    offsets : int32[S, n+1] per-shard CSR over each row's valid prefix
    dst     : ChunkedStream, anchors (S, R) / deltas (S, R, CHUNK) /
              ovf_* (S, R, K) / spill (S,); row capacity = R * CHUNK
    n       : (S,) valid counts (the raw pool's counts, unchanged)
    lo      : (S,) inclusive lower key boundary per shard
    vals    : optional (S, cap) float32 value lane, uncompressed (pad 0)
    """

    offsets: jax.Array
    dst: cz.ChunkedStream
    n: jax.Array
    lo: jax.Array
    vals: Optional[jax.Array] = None

    @property
    def n_shards(self) -> int:
        return self.offsets.shape[0]

    @property
    def cap_per(self) -> int:
        return self.dst.length


class CompressedShardedGraph(NamedTuple):
    """ShardedGraph over a CompressedShardedPool; ``n`` is the STATIC
    vertex count, same contract as ``ShardedGraph``."""

    pool: CompressedShardedPool
    n: int

    @property
    def n_shards(self) -> int:
        return self.pool.n_shards

    @property
    def weighted(self) -> bool:
        return self.pool.vals is not None


def _compress_pool_impl(
    p: ShardedPool, n: int, width: int, k: int, hi_cap: int | None = None
) -> CompressedShardedPool:
    S, cap = p.data.shape
    bounds = jnp.arange(n + 1, dtype=jnp.int64) << 32

    def row(drow, nrow):
        offs = jnp.minimum(jnp.searchsorted(drow, bounds), nrow).astype(jnp.int32)
        dst = (drow & 0xFFFFFFFF).astype(jnp.int32)
        # Pad slots hold SENT (dst lane -1): carry the last valid dst
        # forward instead of encoding that cliff (same trick as the flat
        # ``_compress_impl``; decompress re-masks pad slots from ``n``).
        last = dst[jnp.maximum(nrow - 1, 0)]
        dst_enc = jnp.where(jnp.arange(cap) < nrow, dst, last)
        if hi_cap is not None:
            return offs, cz._encode_adaptive_impl(dst_enc, hi_cap, k)
        return offs, cz._encode_impl(dst_enc, width, k)

    offsets, stream = jax.vmap(row)(p.data, p.n)
    vals = p.vals
    if vals is not None and stream.length > cap:
        vals = jnp.pad(vals, ((0, 0), (0, stream.length - cap)))
    return CompressedShardedPool(offsets, stream, p.n, p.lo, vals)


compress_pool = functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))(
    _compress_pool_impl
)
compress_pool.__doc__ = (
    "jit ShardedPool -> CompressedShardedPool (static n / lane width /"
    " escape capacity); vmapped per-shard encode, shard-local under GSPMD."
)


def _decompress_pool_impl(cp: CompressedShardedPool) -> ShardedPool:
    capC = cp.cap_per
    dst = cz.decode_stream(cp.dst)  # (S, capC) int32, batched decode

    def row(offs, dst_row, nrow):
        slots = jnp.arange(capC, dtype=offs.dtype)
        src = (jnp.searchsorted(offs, slots, side="right") - 1).astype(jnp.int32)
        packed = (src.astype(jnp.int64) << 32) | (
            dst_row.astype(jnp.int64) & 0xFFFFFFFF
        )
        return jnp.where(jnp.arange(capC) < nrow, packed, SENT)

    data = jax.vmap(row)(cp.offsets, dst, cp.n)
    return ShardedPool(data, cp.n, cp.lo, cp.vals)


decompress_pool = jax.jit(_decompress_pool_impl)
decompress_pool.__doc__ = (
    "jit CompressedShardedPool -> ShardedPool (exact inverse of"
    " ``compress_pool`` for non-spilled rows; pad slots come back as SENT)."
    "  Row capacity is the chunked capacity, a CHUNK multiple >= the input"
    " pool's, so a compress/decompress round-trip is capacity-stable."
)


def compress_sharded(
    sg: ShardedGraph,
    width: int | None = None,
    k: int = cz.OVF_SLOTS,
    hi_headroom: float = 0.0,
) -> CompressedShardedGraph:
    """Host build mirroring ``flat_graph.compress_host``: the default is
    the ADAPTIVE per-chunk-width layout (one int8 lane + a compacted
    hi-byte plane sized by the widest shard's wide-chunk count, plus
    ``hi_headroom`` slack rows for streaming growth); pass an explicit
    ``width`` (1 or 2) for the fixed layouts.  Raises if any shard row
    spills even at the widest encoding (keep the raw layout)."""
    if width is None:
        S, cap = sg.pool.data.shape
        R = (max(cap, 1) + cz.CHUNK - 1) // cz.CHUNK
        cp = compress_pool(sg.pool, sg.n, 0, k, R)
        if bool(np.asarray(cp.dst.spill).any()):
            raise ValueError(
                f"sharded pool spills the k={k} escape lane even at "
                "adaptive (int16-wide) chunks; keep the raw layout"
            )
        # Exact-fit slice of the hi plane: the leaf is one (S, H, CHUNK)
        # array, so H is the max wide-chunk count over shards (+ slack).
        n_wide = int(np.asarray(cp.dst.wide).sum(axis=-1).max())
        slack = 0 if hi_headroom <= 0 else max(4, int(np.ceil(hi_headroom * R)))
        hc = min(R, n_wide + slack)
        hi = jnp.asarray(np.asarray(cp.dst.hi)[:, :hc])
        cp = cp._replace(dst=cp.dst._replace(hi=hi))
        return CompressedShardedGraph(cp, sg.n)
    cp = compress_pool(sg.pool, sg.n, width, k)
    if bool(np.asarray(cp.dst.spill).any()):
        raise ValueError(
            f"sharded pool spills the k={k} escape lane at the requested "
            "fixed width; keep the raw layout"
        )
    return CompressedShardedGraph(cp, sg.n)


def decompress_sharded(csg: CompressedShardedGraph) -> ShardedGraph:
    return ShardedGraph(decompress_pool(csg.pool), csg.n)


def _or_spill(out: CompressedShardedPool, cp: CompressedShardedPool):
    # once a row spills it stays flagged until the pool is rebuilt
    return out._replace(dst=out.dst._replace(spill=out.dst.spill | cp.dst.spill))


def make_insert_step_compressed(mesh: Mesh, axis_names: Tuple[str, ...]):
    """Compressed counterpart of ``make_insert_step``: decompress ->
    shard-local rank-merge -> recompress, ONE jit per (shapes, n).  The
    uncompressed rows exist only as a transient inside the step; the
    resident state stays compressed (the flat
    ``insert_edges_compressed`` contract, sharded).  ``n`` is static
    (the offsets rows are (n+1)-wide); lane width / escape capacity are
    inherited from the input stream's dtypes, so one compiled step
    serves a whole update stream."""
    raw_step = make_insert_step(mesh, axis_names)

    @functools.partial(jax.jit, static_argnames=("n",))
    def step(
        cpool: CompressedShardedPool,
        batch: jax.Array,
        batch_vals: jax.Array | None = None,
        *,
        n: int,
    ) -> CompressedShardedPool:
        p = _decompress_pool_impl(cpool)
        p2 = raw_step(p, batch, batch_vals)
        hi_cap = cpool.dst.hi.shape[-2] if cpool.dst.hi is not None else None
        out = _compress_pool_impl(p2, n, cpool.dst.width, cpool.dst.k, hi_cap)
        return _or_spill(out, cpool)

    return step


def make_delete_step_compressed(mesh: Mesh, axis_names: Tuple[str, ...]):
    """Compressed counterpart of ``make_delete_step`` (see
    ``make_insert_step_compressed``)."""
    raw_step = make_delete_step(mesh, axis_names)

    @functools.partial(jax.jit, static_argnames=("n",))
    def step(
        cpool: CompressedShardedPool, batch: jax.Array, *, n: int
    ) -> CompressedShardedPool:
        p = _decompress_pool_impl(cpool)
        p2 = raw_step(p, batch)
        hi_cap = cpool.dst.hi.shape[-2] if cpool.dst.hi is not None else None
        out = _compress_pool_impl(p2, n, cpool.dst.width, cpool.dst.k, hi_cap)
        return _or_spill(out, cpool)

    return step


def needs_rebalance_compressed(
    cp: CompressedShardedPool, slack: float = 0.9
) -> bool:
    return bool((np.asarray(cp.n) >= slack * cp.cap_per).any())


def rebalance_compressed(
    cp: CompressedShardedPool, n: int, cap_per: int | None = None
) -> CompressedShardedPool:
    """Host-side O(m) redistribution (decompress -> rebalance ->
    recompress).  Only sound on non-spilled streams — a spilled pool no
    longer round-trips and must be rebuilt from its source edges."""
    p = rebalance(decompress_pool(cp), cap_per=cap_per)
    hi_cap = None
    if cp.dst.hi is not None:
        # Capacity may have grown: re-derive the plane bound from the new
        # row capacity, keeping at least the old plane's slack.
        new_cap = p.data.shape[1]
        R = (max(new_cap, 1) + cz.CHUNK - 1) // cz.CHUNK
        hi_cap = min(R, max(cp.dst.hi.shape[-2], 1))
    return compress_pool(p, n, cp.dst.width, cp.dst.k, hi_cap)


# ---------------------------------------------------------------------------
# shard auto-tuning: imbalance stats -> rebalance policy + shard-count hint
# ---------------------------------------------------------------------------


def imbalance_stats(p) -> dict:
    """Shard occupancy skew summary from the counts the pool already
    tracks (``p.n``): max/mean ratio is the load-balance figure the
    range partition degrades toward under skewed key streams.  Accepts a
    ShardedPool, CompressedShardedPool, or a raw counts array."""
    counts = np.asarray(getattr(p, "n", p), dtype=np.float64).reshape(-1)
    if counts.size == 0 or counts.sum() == 0:
        return {"max": 0.0, "mean": 0.0, "imbalance": 1.0}
    mean = float(counts.mean())
    mx = float(counts.max())
    return {"max": mx, "mean": mean, "imbalance": mx / mean if mean else 1.0}


def recommend_n_shards(m_total: int, target_per_shard: int = 1 << 16) -> int:
    """Shard-count hint: enough shards to keep ~``target_per_shard``
    edges per shard, snapped to a mesh-friendly count (a multiple of the
    device count when more than one round is needed, so every device
    carries equal rows)."""
    nd = max(1, jax.device_count())
    want = max(1, -(-int(m_total) // int(target_per_shard)))
    if want <= nd:
        return want
    return -(-want // nd) * nd  # round up to a device-count multiple


def should_rebalance(
    p, *, imbalance_threshold: float = 2.0, slack: float = 0.9
) -> bool:
    """Auto-rebalance trigger: any shard nears capacity (the existing
    ``needs_rebalance`` criterion, capacity read off either layout) OR
    the max/mean occupancy ratio exceeds ``imbalance_threshold`` — skew
    wastes the per-shard compute budget long before capacity overflows.
    Works on both raw and compressed pools (counts + capacity are plain
    attributes of each)."""
    cap = p.cap_per if hasattr(p, "cap_per") else p.data.shape[1]
    near_cap = bool((np.asarray(p.n) >= slack * cap).any())
    return near_cap or imbalance_stats(p)["imbalance"] > imbalance_threshold


def maybe_rebalance(
    p: ShardedPool, *, imbalance_threshold: float = 2.0, slack: float = 0.9
):
    """``should_rebalance`` + the rebalance itself for raw pools; returns
    ``(pool, rebalanced)``.  Compressed pools go through
    ``rebalance_compressed`` (the caller holds the static ``n``)."""
    if not should_rebalance(
        p, imbalance_threshold=imbalance_threshold, slack=slack
    ):
        return p, False
    return rebalance(p), True
