"""Sharded flat C-tree pool: the beyond-paper distributed optimization.

The baseline flat union (flat_ctree.union_merge) is a *global* rank-merge:
under GSPMD, the cross-shard searchsorteds force all-gathers of the whole
pool — collective-bound at pod scale (EXPERIMENTS.md §Perf baseline).

Here each device owns a contiguous KEY RANGE of the pool (range-sharded,
like a distributed LSM level).  A batch update becomes:

  1. all-gather the (small) batch — k << n bytes on the wire;
  2. every shard slices the batch rows falling in its key range
     (two searchsorteds against its own boundaries);
  3. shard-LOCAL rank-merge into its own slack capacity.

Collective traffic drops from O(pool) to O(batch); the merge itself stays
bandwidth-optimal locally.  Queries (member) need one searchsorted against
the shard boundary table (replicated, n_shards entries) then a local
probe — same depth as before.

Rebalancing: shards fill unevenly; when any shard exceeds its capacity
the host triggers a REBALANCE (an O(n) all-to-all redistribution to equal
counts — amortized over many updates, like LSM compaction).  The
imbalance statistics and trigger live here; the dry run lowers the
steady-state update step.

Implemented with shard_map so the collective schedule is explicit, not
GSPMD-inferred.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .flat_ctree import sentinel_for

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

SENT = sentinel_for(jnp.int64)


class ShardedPool(NamedTuple):
    """Range-sharded sorted pool; a jax pytree.

    data  : (n_shards, cap_per) sorted within each shard; pad = SENT
    n     : (n_shards,) valid counts
    lo    : (n_shards,) inclusive lower key boundary of each shard
    """

    data: jax.Array
    n: jax.Array
    lo: jax.Array


def from_array(values: np.ndarray, n_shards: int, cap_per: int | None = None) -> ShardedPool:
    v = np.unique(np.asarray(values, dtype=np.int64))
    per = -(-v.size // n_shards)
    if cap_per is None:
        cap_per = max(8, int(2 ** np.ceil(np.log2(per * 2 + 1))))
    data = np.full((n_shards, cap_per), SENT, dtype=np.int64)
    n = np.zeros((n_shards,), dtype=np.int32)
    lo = np.full((n_shards,), np.iinfo(np.int64).min, dtype=np.int64)
    for s in range(n_shards):
        chunk = v[s * per : (s + 1) * per]
        data[s, : chunk.size] = chunk
        n[s] = chunk.size
        lo[s] = chunk[0] if chunk.size else (lo[s - 1] if s else 0)
    # boundaries must be monotone even for empty shards
    for s in range(1, n_shards):
        if n[s] == 0:
            lo[s] = max(lo[s - 1], lo[s])
    lo[0] = np.iinfo(np.int64).min
    return ShardedPool(jnp.asarray(data), jnp.asarray(n), jnp.asarray(lo))


def to_array(p: ShardedPool) -> np.ndarray:
    data = np.asarray(p.data)
    n = np.asarray(p.n)
    return np.concatenate([data[s, : n[s]] for s in range(data.shape[0])])


def _local_merge(pool_row: jax.Array, n_valid: jax.Array, batch: jax.Array,
                 b_lo: jax.Array, b_hi: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Merge batch[b_lo:b_hi) into one shard row (fixed shapes, O(n+k))."""
    cap = pool_row.shape[0]
    kcap = batch.shape[0]
    # mask the batch to this shard's range
    idx = jnp.arange(kcap)
    mine = (idx >= b_lo) & (idx < b_hi)
    b = jnp.where(mine, batch, SENT)
    b = jnp.sort(b)  # my rows to the front (already sorted among themselves)
    n_mine = (b_hi - b_lo).astype(jnp.int32)
    valid_a = jnp.arange(cap) < n_valid
    valid_b = jnp.arange(kcap) < n_mine
    # dedup b against a
    ia = jnp.minimum(jnp.searchsorted(pool_row, b), cap - 1)
    dup_b = (pool_row[ia] == b) & valid_b
    keep_b = valid_b & ~dup_b
    kb_excl = jnp.cumsum(keep_b.astype(jnp.int32)) - keep_b
    ra = jnp.searchsorted(b, pool_row)
    kept_below_a = jnp.where(
        ra > 0,
        kb_excl[jnp.minimum(ra - 1, kcap - 1)] + keep_b[jnp.minimum(ra - 1, kcap - 1)],
        0,
    )
    pos_a = jnp.arange(cap, dtype=jnp.int32) + kept_below_a.astype(jnp.int32)
    pos_a = jnp.where(valid_a, pos_a, cap)
    rb = jnp.searchsorted(pool_row, b)
    pos_b = rb.astype(jnp.int32) + kb_excl.astype(jnp.int32)
    pos_b = jnp.where(keep_b, pos_b, cap)
    out = jnp.full((cap,), SENT, dtype=pool_row.dtype)
    out = out.at[pos_a].set(pool_row, mode="drop")
    out = out.at[pos_b].set(b, mode="drop")
    return out, n_valid + keep_b.sum().astype(jnp.int32)


def make_insert_step(mesh: Mesh, axis_names: Tuple[str, ...]):
    """Build the shard_map'd update step for a given mesh.

    axis_names: the mesh axes the shard dimension is split over (all of
    them: every chip owns one key range)."""
    flat_axes = axis_names

    def local(data, n, lo, hi, batch):
        # shapes inside shard_map: data (1, cap), n (1,), lo/hi (1,),
        # batch (kcap,) REPLICATED (this is the one collective: GSPMD
        # all-gathers the batch operand once).
        b_lo = jnp.searchsorted(batch, lo[0])
        b_hi = jnp.searchsorted(batch, hi[0])
        out, n_new = _local_merge(data[0], n[0], batch, b_lo, b_hi)
        return out[None], n_new[None]

    spec_sharded = P(flat_axes)
    spec_sharded2 = P(flat_axes, None)

    def step(pool: ShardedPool, batch: jax.Array) -> ShardedPool:
        n_shards = pool.data.shape[0]
        hi = jnp.concatenate([pool.lo[1:], jnp.asarray([jnp.iinfo(jnp.int64).max], jnp.int64)])
        out, n_new = _shard_map(
            local,
            mesh=mesh,
            in_specs=(spec_sharded2, spec_sharded, spec_sharded, spec_sharded, P()),
            out_specs=(spec_sharded2, spec_sharded),
        )(pool.data, pool.n, pool.lo, hi, batch)
        return ShardedPool(out, n_new, pool.lo)

    return step


# ---------------------------------------------------------------------------
# queries + rebalance policy (host-driven)
# ---------------------------------------------------------------------------


@jax.jit
def member(p: ShardedPool, queries: jax.Array) -> jax.Array:
    """shard id via boundary table, then local probe (vectorized)."""
    s = jnp.clip(jnp.searchsorted(p.lo, queries, side="right") - 1, 0, p.lo.shape[0] - 1)
    rows = p.data[s]
    j = jnp.clip(jax.vmap(jnp.searchsorted)(rows, queries), 0, p.data.shape[1] - 1)
    return jnp.take_along_axis(rows, j[:, None], axis=1)[:, 0] == queries


def needs_rebalance(p: ShardedPool, slack: float = 0.9) -> bool:
    return bool((np.asarray(p.n) >= slack * p.data.shape[1]).any())


def rebalance(p: ShardedPool) -> ShardedPool:
    """O(n) redistribution to equal counts (the amortized compaction)."""
    return from_array(to_array(p), p.data.shape[0], cap_per=p.data.shape[1])
