"""Aspen streaming interface (paper §6 + §7.3): updates ∥ queries.

``AspenStream`` is the top-level object: a VersionedGraph plus the
Ligra-style update API (InsertEdges / DeleteEdges / InsertVertices /
DeleteVertices).  Updates are functional: each batch produces a new
version published with SET; readers ACQUIRE snapshots and never block.

``run_concurrent`` reproduces the paper's §7.3 experiment: one writer
thread applying a stream of edge updates while reader threads run global
queries; reports update throughput, per-edge visibility latency, and
query latencies (concurrent vs isolated).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, NamedTuple, Optional, Tuple

import numpy as np

from . import graph as G
from .versioning import VersionedGraph


class AspenStream:
    def __init__(self, initial: Optional[G.Graph] = None, b: int = 256, seed: int = 0x9E3779B9):
        self.vg: VersionedGraph[G.Graph] = VersionedGraph(
            initial if initial is not None else G.empty(b, seed)
        )

    # -- update API (paper Appendix 10.4) ---------------------------------
    def insert_edges(self, edges: np.ndarray, symmetric: bool = True):
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if symmetric:
            edges = np.concatenate([edges, edges[:, ::-1]])
        return self.vg.update(lambda g: G.insert_edges(g, edges))

    def delete_edges(self, edges: np.ndarray, symmetric: bool = True):
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if symmetric:
            edges = np.concatenate([edges, edges[:, ::-1]])
        return self.vg.update(lambda g: G.delete_edges(g, edges))

    def insert_vertices(self, vs: np.ndarray):
        return self.vg.update(lambda g: G.insert_vertices(g, vs))

    def delete_vertices(self, vs: np.ndarray):
        return self.vg.update(lambda g: G.delete_vertices(g, vs))

    # -- read API -----------------------------------------------------------
    def acquire(self):
        return self.vg.acquire()

    def release(self, v):
        return self.vg.release(v)

    def flat_snapshot(self) -> G.FlatSnapshot:
        v = self.acquire()
        try:
            return G.flat_snapshot(v.graph)
        finally:
            self.release(v)

    def engine(self, backend: str = "numpy"):
        """Traversal engine over the current version: the caller picks
        the query substrate at snapshot time.

        backend="numpy" -> NumpyEngine over a FlatSnapshot (CPU);
        backend="jax"   -> JaxEngine over a FlatGraph rebuilt from the
                           snapshot (jit / Pallas query path).
        """
        from .traversal import make_engine

        return make_engine(self.flat_snapshot(), backend=backend)


class ConcurrentStats(NamedTuple):
    updates_per_sec: float
    mean_update_latency_s: float
    query_latency_concurrent_s: float
    query_latency_isolated_s: float
    n_updates: int
    n_queries: int


def run_concurrent(
    stream: AspenStream,
    updates: np.ndarray,  # (k, 3): src, dst, is_delete
    query_fn: Callable[[G.FlatSnapshot], object],
    duration_s: float = 5.0,
    batch_size: int = 1,
    symmetric: bool = True,
) -> ConcurrentStats:
    """Paper §7.3: writer applies updates one batch at a time while a
    reader repeatedly runs query_fn against fresh snapshots.

    ``symmetric`` is forwarded to the insert/delete calls; the reported
    throughput counts the directed edges actually applied (2x the batch
    only when symmetric), not a hard-coded doubling.
    """
    stop = threading.Event()
    upd_lat: List[float] = []
    n_upd = [0]
    n_directed = [0]
    per_update = 2 if symmetric else 1

    def updater():
        i = 0
        while not stop.is_set() and i < updates.shape[0]:
            batch = updates[i : i + batch_size]
            ins = batch[batch[:, 2] == 0][:, :2]
            dels = batch[batch[:, 2] == 1][:, :2]
            t0 = time.perf_counter()
            if ins.size:
                stream.insert_edges(ins, symmetric=symmetric)
            if dels.size:
                stream.delete_edges(dels, symmetric=symmetric)
            upd_lat.append(time.perf_counter() - t0)
            n_upd[0] += batch.shape[0]
            n_directed[0] += batch.shape[0] * per_update
            i += batch_size

    q_lat: List[float] = []

    def reader():
        while not stop.is_set():
            snap = stream.flat_snapshot()
            t0 = time.perf_counter()
            query_fn(snap)
            q_lat.append(time.perf_counter() - t0)

    tu = threading.Thread(target=updater)
    tq = threading.Thread(target=reader)
    tu.start()
    tq.start()
    time.sleep(duration_s)
    stop.set()
    tu.join()
    tq.join()

    # isolated query latency on the final version
    snap = stream.flat_snapshot()
    iso: List[float] = []
    for _ in range(max(3, min(10, len(q_lat)))):
        t0 = time.perf_counter()
        query_fn(snap)
        iso.append(time.perf_counter() - t0)

    total_upd_time = sum(upd_lat) if upd_lat else 1e-9
    return ConcurrentStats(
        updates_per_sec=n_directed[0] / total_upd_time,  # directed edges/s
        mean_update_latency_s=float(np.mean(upd_lat)) if upd_lat else 0.0,
        query_latency_concurrent_s=float(np.mean(q_lat)) if q_lat else 0.0,
        query_latency_isolated_s=float(np.mean(iso)),
        n_updates=n_upd[0],
        n_queries=len(q_lat),
    )


def make_update_stream(
    edges: np.ndarray, n_updates: int, seed: int = 0, delete_frac: float = 0.1
) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §7.3 methodology: sample updates from the input graph.

    Returns (graph_edges_after_removal, update_stream[k,3]) where 90% of
    the sampled edges are first removed from the graph and re-inserted by
    the stream; 10% stay and get deleted by the stream.
    """
    rng = np.random.default_rng(seed)
    m = edges.shape[0]
    k = min(n_updates, m)
    pick = rng.choice(m, size=k, replace=False)
    sampled = edges[pick]
    n_ins = int(k * (1 - delete_frac))
    ins, dels = sampled[:n_ins], sampled[n_ins:]
    keep_mask = np.ones(m, dtype=bool)
    keep_mask[pick[:n_ins]] = False  # insertions start absent
    stream = np.concatenate(
        [
            np.concatenate([ins, np.zeros((ins.shape[0], 1), np.int64)], axis=1),
            np.concatenate([dels, np.ones((dels.shape[0], 1), np.int64)], axis=1),
        ]
    )
    rng.shuffle(stream)
    return edges[keep_mask], stream
