"""Aspen streaming interface (paper §6 + §7.3): updates ∥ queries.

``AspenStream`` is the top-level object: a VersionedGraph plus the
Ligra-style update API (InsertEdges / DeleteEdges / InsertVertices /
DeleteVertices).  Updates are functional: each batch produces a new
version published with SET; readers ACQUIRE snapshots and never block.

Dual representation (DESIGN.md §6): alongside the faithful C-tree
``Graph``, every version carries a device-resident ``FlatGraph`` mirror
kept current *incrementally* — each edge batch is applied to the tree
(functional, faithful) AND rank-merged into the mirror on device
(O(n+k), amortized capacity doubling), then both are published
atomically as ONE version.  ``engine("jax")`` over an unchanged version
is O(1): engines are cached on the version itself (version-pinned, so
the cache dies with the version), and a fresh version's engine refresh
is one jit ``engine_aux`` call over the already-merged mirror — no O(m)
host rebuild, no host argsort.  Streams opened with ``mirror=False``
keep the historical rebuild-per-query path.

Sharded mirror (DESIGN.md §9): ``mirror="sharded"`` maintains a
range-sharded ``ShardedGraph`` mirror instead — updates go through the
shard-local rank-merge / delete steps of ``sharded_pool`` (O(batch)
collective traffic, amortized host-driven rebalance), queries through
``engine("sharded")``, the mesh-parallel edgeMap backend.  Both are
published atomically next to the tree exactly like the flat mirror, and
``query_batch`` routes to the sharded engine by default on such
streams.

Incremental queries (DESIGN.md §11): every edge publish records its
batch as a ``versioning.Delta`` in the version's aux, and
``stream.subscribe(kind, ...)`` returns a ``Subscription`` whose
``refresh()`` advances a standing result (pagerank / cc / bfs / sssp)
across publishes through the delta-aware warm-start path instead of
recomputing — time-to-fresh-result scales with the batch, not the
graph.

``run_concurrent`` reproduces the paper's §7.3 experiment: one writer
thread applying a stream of edge updates while reader threads run global
queries; reports update throughput, per-edge visibility latency, and
query latencies (concurrent vs isolated) — plus subscriber staleness
when the reader is a live ``Subscription``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, List, NamedTuple, Optional, Tuple

import numpy as np

from . import graph as G
from .versioning import DELTA, Delta, Version, VersionedGraph

MIRROR = "flat"  # aux key of the FlatGraph mirror on a Version
SHARDED_MIRROR = "sharded"  # aux key of the ShardedGraph mirror

# hi-plane slack for adaptive compressed mirrors: fraction of chunk rows
# reserved beyond the build-time wide-chunk count, so incremental
# recompression absorbs width drift between full rebuilds
HI_HEADROOM = 1 / 16


class UpdateQueue:
    """Bounded thread-safe queue of pending edge updates feeding a
    writer loop — the backpressure surface of the serving layer
    (DESIGN.md §13).

    One entry per directed-or-symmetric *update request*: ``(src, dst,
    delete, weight)``.  Producers ``put`` (blocking while full unless
    ``block=False``, which rejects instead — the caller's admission
    decision); the single writer drains with ``drain_updates`` below.
    ``stats()`` exposes queue depth, high-water mark, and the
    accepted / drained / rejected totals, so a service can report how
    hard its writer is backpressuring producers.  ``maxsize=None``
    makes the queue unbounded (the replay use in ``run_concurrent``)."""

    def __init__(self, maxsize: Optional[int] = 65536):
        self.maxsize = maxsize
        self._q: deque = deque()
        self._cond = threading.Condition()
        self._high_water = 0
        self._enqueued = 0
        self._drained = 0
        self._rejected = 0

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    def put(
        self,
        src: int,
        dst: int,
        *,
        delete: bool = False,
        weight: Optional[float] = None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> bool:
        """Enqueue one update; returns False (and counts a rejection)
        instead of enqueueing when the queue stays full — on
        ``block=False`` immediately, else after ``timeout``."""
        with self._cond:
            if self.maxsize is not None:
                if not block and len(self._q) >= self.maxsize:
                    self._rejected += 1
                    return False
                if not self._cond.wait_for(
                    lambda: len(self._q) < self.maxsize, timeout=timeout
                ):
                    self._rejected += 1
                    return False
            self._q.append((int(src), int(dst), bool(delete), weight))
            self._enqueued += 1
            self._high_water = max(self._high_water, len(self._q))
            self._cond.notify_all()
            return True

    def pop_batch(self, k: int) -> list:
        """Dequeue up to ``k`` pending updates (possibly empty; never
        blocks) in FIFO order."""
        with self._cond:
            out = []
            while self._q and len(out) < k:
                out.append(self._q.popleft())
            if out:
                self._drained += len(out)
                self._cond.notify_all()  # wake producers blocked on full
            return out

    def wait_nonempty(self, timeout: Optional[float] = None) -> bool:
        """Park until at least one update is pending (the writer loop's
        idle wait); True when woken non-empty."""
        with self._cond:
            return self._cond.wait_for(lambda: len(self._q) > 0, timeout=timeout)

    def stats(self) -> dict:
        with self._cond:
            return {
                "depth": len(self._q),
                "maxsize": self.maxsize,
                "high_water": self._high_water,
                "enqueued": self._enqueued,
                "drained": self._drained,
                "rejected": self._rejected,
            }


def drain_updates(
    queue: UpdateQueue,
    stream: "AspenStream",
    max_batch: int,
    symmetric: bool = True,
) -> int:
    """Drain up to ``max_batch`` pending updates from ``queue`` and
    apply them to ``stream`` as (at most) one ``insert_edges`` plus one
    ``delete_edges`` publish; returns how many updates were applied
    (0 = queue empty; never blocks).

    This is THE writer-loop body — ``run_concurrent``'s updater thread
    and ``GraphQueryService``'s writer thread both call it, so update
    batching semantics (inserts applied before deletes within a drain,
    symmetrization forwarded to both calls, the weight lane riding
    inserts with unit fill for weight-less rows in a mixed batch) live
    in exactly one place and cannot drift between the bench harness and
    the serving path."""
    rows = queue.pop_batch(max_batch)
    if not rows:
        return 0
    ins = [(s, d, w) for s, d, dl, w in rows if not dl]
    dels = [(s, d) for s, d, dl, w in rows if dl]
    if ins:
        edges = np.asarray([(s, d) for s, d, _ in ins], dtype=np.int64)
        if any(w is not None for _, _, w in ins):
            weights = np.asarray(
                [1.0 if w is None else float(w) for _, _, w in ins], np.float64
            )
        else:
            weights = None
        stream.insert_edges(edges, symmetric=symmetric, weights=weights)
    if dels:
        stream.delete_edges(np.asarray(dels, dtype=np.int64), symmetric=symmetric)
    return len(rows)


class AspenStream:
    def __init__(
        self,
        initial: Optional[G.Graph] = None,
        b: int = 256,
        seed: int = 0x9E3779B9,
        mirror: "bool | str" = True,
        donate_buffers: bool = False,
        n_shards: Optional[int] = None,
        compressed: bool = False,
    ):
        """``mirror=True`` (default, = ``"flat"``) maintains the resident
        FlatGraph alongside the tree; ``mirror="sharded"`` maintains a
        range-sharded ``ShardedGraph`` mirror instead (updates via the
        shard-local rank-merge, queries via ``engine("sharded")``;
        ``n_shards`` defaults to the device count).  ``mirror=False``
        keeps the rebuild-per-query path.  ``donate_buffers=True``
        additionally donates the old flat-mirror pool to each merge —
        ONLY safe when no reader can still hold a previous version
        (single-reader pipelines), since donation invalidates the shared
        buffer.

        ``compressed=True`` keeps the mirror in the chunk-compressed
        layout (``flat_graph.CompressedPool`` /
        ``sharded_pool.CompressedShardedPool``, DESIGN.md §10): each
        edge batch runs the decompress -> rank-merge -> recompress jit,
        so the RESIDENT state is always a few bytes/edge, and
        ``engine()`` serves the matching compressed engine.  Donation is
        unavailable on compressed mirrors (the merge's uncompressed pool
        is a transient, never a reusable buffer)."""
        g0 = initial if initial is not None else G.empty(b, seed)
        kind = {True: MIRROR, False: None}.get(mirror, mirror)
        if kind not in (None, MIRROR, SHARDED_MIRROR):
            raise ValueError(
                f"mirror must be bool, 'flat' or 'sharded'; got {mirror!r}"
            )
        if compressed and kind is None:
            raise ValueError("compressed=True requires a resident mirror")
        if compressed and donate_buffers:
            raise ValueError("donate_buffers is unavailable on compressed mirrors")
        self._mirror_kind = kind
        self._mirror_enabled = kind is not None
        self._compressed = compressed
        self._donate = donate_buffers
        if kind == SHARDED_MIRROR:
            from . import sharded_pool as sp

            self._n_shards = n_shards if n_shards is not None else sp.default_n_shards()
            self._smesh = sp.pool_mesh(self._n_shards)
            self._s_insert = sp.make_insert_step(self._smesh, ("shard",))
            self._s_delete = sp.make_delete_step(self._smesh, ("shard",))
            if compressed:
                self._s_insert_c = sp.make_insert_step_compressed(
                    self._smesh, ("shard",)
                )
                self._s_delete_c = sp.make_delete_step_compressed(
                    self._smesh, ("shard",)
                )
        aux = {kind: self._mirror_from_tree(g0)} if kind else None
        self.vg: VersionedGraph[G.Graph] = VersionedGraph(g0, aux=aux)
        self._wlock = threading.Lock()  # serializes writers (incl. mirror merge)
        self._publish_listeners: List[Callable[[Version[G.Graph]], None]] = []
        self._listener_lock = threading.Lock()

    # -- publish notification ----------------------------------------------
    def on_publish(self, fn: Callable[[Version[G.Graph]], None]) -> Callable[[], None]:
        """Register a non-blocking publish listener: ``fn(version)`` is
        called on the WRITER thread after each version becomes current
        (outside the write lock, so listeners can acquire/query).  The
        contract is fire-and-forget: listeners must be fast — set an
        event, bump a counter — never compute; exceptions are swallowed
        so a broken listener cannot take down the writer.  Returns an
        unsubscribe callable (idempotent)."""
        with self._listener_lock:
            self._publish_listeners.append(fn)

        def unsubscribe() -> None:
            with self._listener_lock:
                if fn in self._publish_listeners:
                    self._publish_listeners.remove(fn)

        return unsubscribe

    def _notify_publish(self, v: Version[G.Graph]) -> None:
        with self._listener_lock:
            listeners = list(self._publish_listeners)
        for fn in listeners:
            try:
                fn(v)
            except Exception:  # noqa: BLE001 — listener bugs never block the writer
                pass

    # -- mirror maintenance -------------------------------------------------
    @staticmethod
    def _flat_from_tree(g: G.Graph):
        """Full FlatGraph rebuild (O(m) host): construction and the rare
        vertex-set operations; edge batches take the incremental path."""
        from .traversal import flat_graph_of

        return flat_graph_of(G.flat_snapshot(g))

    def _mirror_from_tree(self, g: G.Graph):
        """Full mirror rebuild in the stream's configured representation.
        On compressed streams the rebuild is also the spill recovery
        point: ``compress_host`` / ``compress_sharded`` re-check the
        escape-lane flag from scratch and raise rather than publish a
        mis-decoding mirror."""
        flat = self._flat_from_tree(g)
        if self._mirror_kind == SHARDED_MIRROR:
            from .traversal import sharded_graph_of_flat

            sg = sharded_graph_of_flat(flat, self._n_shards)
            if self._compressed:
                from . import sharded_pool as sp

                # Adaptive per-chunk widths with hi-plane headroom: the
                # mirror keeps slack wide-chunk rows so incremental
                # recompression absorbs width drift between rebuilds.
                return sp.compress_sharded(sg, hi_headroom=HI_HEADROOM)
            return sg
        if self._compressed:
            from . import flat_graph as fg

            return fg.compress_host(flat, hi_headroom=HI_HEADROOM)
        return flat

    @staticmethod
    def _device_batch(edges: np.ndarray, weights: Optional[np.ndarray] = None):
        """Pack an edge batch and ship it to device at a *quantized*
        shape (padded with the pool sentinel, which ``fct.from_device``
        drops): batch sizes 1..k all share O(log k) jit traces instead
        of one per distinct size.  ``weights`` rides along as the batch
        pool's value array (pad 0; dropped with the sentinel keys)."""
        import jax.numpy as jnp

        from . import flat_ctree as fct

        keys = (edges[:, 0] << 32) | edges[:, 1]
        cap = fct.grown_capacity(keys.size)
        padded = np.full(cap, fct.SENTINEL64, dtype=np.int64)
        padded[: keys.size] = keys
        if weights is None:
            return fct.from_device(jnp.asarray(padded), cap)
        wpad = np.zeros(cap, dtype=np.float32)
        wpad[: keys.size] = weights
        return fct.from_device(jnp.asarray(padded), cap, vals=jnp.asarray(wpad))

    def _mirror_insert(
        self,
        mirror,
        g_old: G.Graph,
        edges: np.ndarray,
        weights: Optional[np.ndarray] = None,
    ):
        """Apply an insert batch to the mirror on device: pack keys, build
        the batch pool with the jit sort/dedup, rank-merge.  Capacity and
        vertex growth are decided from host-known counts (tree edge count
        via the O(1) augmentation; max source id from the batch), so no
        device->host sync is needed.

        A weighted batch against an unweighted mirror upgrades the
        mirror to unit weights first (the rank-merge then permutes the
        value array alongside the keys; an existing edge's weight is
        overwritten).  Unweighted streams never take these branches —
        no value array is allocated, and the merge compiles the exact
        pre-v2 traces."""
        from . import flat_ctree as fct
        from . import flat_graph as fg

        if edges.shape[0] == 0:
            return mirror
        compressed = isinstance(mirror, fg.CompressedPool)
        if weights is not None and mirror.weights is None:
            mirror = (
                fg.with_unit_weights_compressed(mirror)
                if compressed
                else fg.with_unit_weights(mirror)
            )
        batch = self._device_batch(edges, weights)
        # vertices are created by their first out-edge (matching the
        # tree, whose vertex set is the set of inserted sources)
        n_out = max(mirror.n, int(edges[:, 0].max()) + 1)
        need = G.num_edges(g_old) + edges.shape[0]
        cap = max(mirror.edge_capacity, fct.grown_capacity(need))
        if compressed:
            # decompress -> merge -> recompress, one jit; no donation
            # (the uncompressed pool is a transient of the trace, not a
            # reusable buffer)
            return fg.insert_edges_compressed(
                mirror, batch, cap, True,
                None if n_out == mirror.n else n_out,
            )
        return fg.insert_edges_device(
            mirror, batch, cap,
            n_out=None if n_out == mirror.n else n_out,
            donate=self._donate,
        )

    def _mirror_delete(self, mirror, edges: np.ndarray):
        from . import flat_graph as fg

        if edges.shape[0] == 0:
            return mirror
        if isinstance(mirror, fg.CompressedPool):
            return fg.delete_edges_compressed(
                mirror, self._device_batch(edges), mirror.edge_capacity
            )
        return fg.delete_edges_device(
            mirror, self._device_batch(edges), donate=self._donate
        )

    def _sharded_insert(
        self,
        mirror,
        g_old: G.Graph,
        edges: np.ndarray,
        weights: Optional[np.ndarray] = None,
    ):
        """Apply an insert batch to the sharded mirror: pack keys, build
        the batch pool with the jit sort/dedup, shard-local rank-merge
        (ONE batch all-gather on the wire — O(batch), not O(pool)).

        Capacity policy: one host read of the per-shard counts per
        batch; when the fullest shard could overflow, the pool takes an
        amortized REBALANCE (O(n) redistribution to equal counts, the
        LSM-compaction analogue) at a grown per-shard capacity first.
        A weighted batch against an unweighted mirror upgrades the pool
        to unit values (the value lane then rides every merge)."""
        from . import flat_ctree as fct
        from . import sharded_pool as sp

        if edges.shape[0] == 0:
            return mirror
        pool = mirror.pool
        compressed = isinstance(pool, sp.CompressedShardedPool)
        batch = self._device_batch(edges, weights)
        counts = np.asarray(pool.n)
        k = int(edges.shape[0])
        n_out = max(mirror.n, int(edges[:, 0].max()) + 1)
        if compressed:
            import jax.numpy as jnp

            if weights is not None and pool.vals is None:
                pool = pool._replace(
                    vals=jnp.ones(
                        (pool.n_shards, pool.cap_per), jnp.float32
                    )
                )
            if int(counts.max()) + k > pool.cap_per:
                per = -(-int(counts.sum()) // self._n_shards)
                pool = sp.rebalance_compressed(
                    pool, mirror.n,
                    cap_per=max(pool.cap_per, fct.grown_capacity(per + k)),
                )
            elif sp.should_rebalance(pool):
                # Auto-rebalance policy: the per-batch host read of the
                # counts doubles as the imbalance probe — rebalance when
                # skew (max/mean occupancy) crosses the threshold, long
                # before any shard hits capacity.
                pool = sp.rebalance_compressed(pool, mirror.n)
            pool = self._s_insert_c(pool, batch.data, batch.vals, n=n_out)
            return sp.CompressedShardedGraph(pool, n_out)
        if weights is not None and pool.vals is None:
            pool = sp.with_unit_vals(pool)
        cap_per = pool.data.shape[1]
        if int(counts.max()) + k > cap_per:
            per = -(-int(counts.sum()) // self._n_shards)
            pool = sp.rebalance(
                pool, cap_per=max(cap_per, fct.grown_capacity(per + k))
            )
        elif sp.should_rebalance(pool):
            pool = sp.rebalance(pool)
        pool = self._s_insert(pool, batch.data, batch.vals)
        return sp.ShardedGraph(pool, n_out)

    def _sharded_delete(self, mirror, edges: np.ndarray):
        from . import sharded_pool as sp

        if edges.shape[0] == 0:
            return mirror
        batch = self._device_batch(edges)
        if isinstance(mirror.pool, sp.CompressedShardedPool):
            return sp.CompressedShardedGraph(
                self._s_delete_c(mirror.pool, batch.data, n=mirror.n), mirror.n
            )
        return sp.ShardedGraph(self._s_delete(mirror.pool, batch.data), mirror.n)

    def _apply_insert(self, mirror, g_old, edges, weights=None):
        if self._mirror_kind == SHARDED_MIRROR:
            return self._sharded_insert(mirror, g_old, edges, weights)
        return self._mirror_insert(mirror, g_old, edges, weights)

    def _apply_delete(self, mirror, edges):
        if self._mirror_kind == SHARDED_MIRROR:
            return self._sharded_delete(mirror, edges)
        return self._mirror_delete(mirror, edges)

    def _heal_spill(self, m, g2: G.Graph):
        """Compressed-mirror self-heal: incremental recompression can
        overflow the escape lane or (adaptive streams) the hi plane —
        the step folds that into the sticky ``spill`` flag rather than
        branching in-trace.  One host flag-read per publish catches it
        here, and the mirror is rebuilt from the tree (which re-selects
        widths and re-sizes the hi plane from scratch) BEFORE the spilled
        state can be published — readers never observe a mis-decoding
        mirror."""
        if not self._compressed or m is None:
            return m
        from . import flat_graph as fg
        from . import sharded_pool as sp

        if isinstance(m, fg.CompressedPool):
            spilled = bool(np.asarray(m.dst.spill))
        elif isinstance(m, sp.CompressedShardedGraph):
            spilled = bool(np.asarray(m.pool.dst.spill).any())
        else:
            return m
        return self._mirror_from_tree(g2) if spilled else m

    def _publish(self, tree_fn, mirror_fn, delta: Optional[Delta] = None) -> Version[G.Graph]:
        """One writer transaction: update tree + mirror from the held
        version, publish both atomically as a single new version.

        ``delta`` — the applied edge batch as a ``versioning.Delta`` —
        rides the published aux under ``versioning.DELTA``: the update
        record is a first-class artifact of its version (GC'd with it),
        and ``vg.delta_between`` recovers the exact diff between any two
        still-live stamps for the incremental query path.  Vertex-set
        ops publish no delta (the full-recompute signal).

        Self-healing: if the held version carries no mirror (e.g. it was
        published through the raw ``vg`` writer API), the mirror is
        rebuilt from the new tree instead of merged incrementally."""

        def txn(v: Version[G.Graph]):
            g2 = tree_fn(v.graph)
            aux = {} if delta is None else {DELTA: delta}
            if self._mirror_enabled:
                m = v.aux.get(self._mirror_kind)
                m2 = (
                    mirror_fn(m, v.graph, g2) if m is not None else self._mirror_from_tree(g2)
                )
                aux[self._mirror_kind] = self._heal_spill(m2, g2)
            return g2, (aux or None)

        with self._wlock:
            v = self.vg.update_with_aux(txn)
        self._notify_publish(v)
        return v

    # -- update API (paper Appendix 10.4) ---------------------------------
    def insert_edges(
        self,
        edges: np.ndarray,
        symmetric: bool = True,
        weights: Optional[np.ndarray] = None,
    ):
        """InsertEdges, optionally weighted: ``weights`` is one value
        per batch edge (a symmetric insert carries the value on both
        directions).  Inserting an edge that already exists overwrites
        its weight; the tree and the device mirror are updated through
        their own value paths and published atomically as one version.
        The first weighted batch upgrades an unweighted stream (prior
        edges read as unit weight); weight-less batches on a weighted
        stream insert at unit weight."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if weights is not None:
            weights = np.asarray(weights, dtype=np.float64).reshape(-1)
            if weights.size != edges.shape[0]:
                raise ValueError("one weight per edge")
        if symmetric:
            edges = np.concatenate([edges, edges[:, ::-1]])
            if weights is not None:
                weights = np.concatenate([weights, weights])
        return self._publish(
            lambda g: G.insert_edges(g, edges, weights=weights),
            lambda m, g_old, g_new: self._apply_insert(m, g_old, edges, weights),
            delta=Delta(ins=edges, ins_w=weights),
        )

    def delete_edges(self, edges: np.ndarray, symmetric: bool = True):
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if symmetric:
            edges = np.concatenate([edges, edges[:, ::-1]])
        return self._publish(
            lambda g: G.delete_edges(g, edges),
            lambda m, g_old, g_new: self._apply_delete(m, edges),
            delta=Delta(dels=edges),
        )

    def insert_vertices(self, vs: np.ndarray):
        # vertex-set ops are control-plane-rare: the mirror takes the
        # rebuild path (vertex growth/shrink reshapes the offsets array)
        return self._publish(
            lambda g: G.insert_vertices(g, vs),
            lambda m, g_old, g_new: self._mirror_from_tree(g_new),
        )

    def delete_vertices(self, vs: np.ndarray):
        return self._publish(
            lambda g: G.delete_vertices(g, vs),
            lambda m, g_old, g_new: self._mirror_from_tree(g_new),
        )

    # -- read API -----------------------------------------------------------
    def acquire(self):
        return self.vg.acquire()

    def release(self, v):
        return self.vg.release(v)

    def flat_snapshot(self) -> G.FlatSnapshot:
        v = self.acquire()
        try:
            return G.flat_snapshot(v.graph)
        finally:
            self.release(v)

    def flat_graph(self):
        """The current version's FlatGraph: the resident mirror (zero
        work; a compressed mirror is decompressed on the way out), or,
        on mirror-less / sharded streams, a one-off rebuild."""
        from . import flat_graph as fg

        v = self.acquire()
        try:
            if MIRROR in v.aux:
                m = v.aux[MIRROR]
                return fg.decompress(m) if isinstance(m, fg.CompressedPool) else m
            return self._flat_from_tree(v.graph)
        finally:
            self.release(v)

    def sharded_graph(self):
        """The current version's ShardedGraph: the resident sharded
        mirror (zero work; a compressed mirror is decompressed on the
        way out), or, on other streams, a one-off rebuild."""
        from . import sharded_pool as sp
        from .traversal import sharded_graph_of_flat

        v = self.acquire()
        try:
            if SHARDED_MIRROR in v.aux:
                m = v.aux[SHARDED_MIRROR]
                if isinstance(m, sp.CompressedShardedGraph):
                    return sp.decompress_sharded(m)
                return m
            flat = v.aux.get(MIRROR)
            if flat is None:
                flat = self._flat_from_tree(v.graph)
            return sharded_graph_of_flat(flat)
        finally:
            self.release(v)

    def shard_stats(self) -> Optional[dict]:
        """Occupancy skew of the current sharded mirror plus the policy
        outputs derived from it: ``imbalance`` (max/mean shard counts),
        whether the auto-rebalance trigger would fire, and the
        recommended shard count for the current edge total (None on
        streams without a sharded mirror)."""
        from . import sharded_pool as sp

        v = self.acquire()
        try:
            m = v.aux.get(SHARDED_MIRROR) if v.aux else None
            if m is None:
                return None
            pool = m.pool
            stats = sp.imbalance_stats(pool)
            stats["n_shards"] = pool.n_shards if hasattr(pool, "n_shards") else pool.data.shape[0]
            stats["should_rebalance"] = sp.should_rebalance(pool)
            stats["recommended_n_shards"] = sp.recommend_n_shards(
                int(np.asarray(pool.n).sum())
            )
            return stats
        finally:
            self.release(v)

    def engine(self, backend: str = "numpy"):
        """Traversal engine over the current version: the caller picks
        the query substrate at snapshot time.

        backend="numpy"   -> NumpyEngine over a FlatSnapshot (CPU);
        backend="jax"     -> JaxEngine over the version's resident
                             FlatGraph mirror (jit / Pallas query path);
                             rebuilt from the tree snapshot only when
                             the stream keeps no flat mirror.
        backend="sharded" -> ShardedEngine over the version's resident
                             ShardedGraph mirror (mesh-parallel
                             shard_map query path, DESIGN.md §9);
                             rebuilt from the tree snapshot on streams
                             not opened with mirror="sharded".

        Engines are cached per (version, backend): repeated calls on an
        unchanged version are O(1) dict hits, and the cache dies with
        the version (version-pinned — it can never serve a stale graph).
        """
        v = self.acquire()
        try:
            return self._engine_for(v, backend)
        finally:
            self.release(v)

    def _default_backend(self) -> str:
        return "sharded" if self._mirror_kind == SHARDED_MIRROR else "jax"

    def _engine_for(self, v: Version[G.Graph], backend: str):
        """``engine`` for an ALREADY-ACQUIRED version (the caller holds
        the reference): subscriptions pin their engine to the version
        they hold, never the racy current one."""
        from .traversal import ENGINE_BUILDS, make_engine

        key = ("engine", backend)
        eng = v.cache.get(key)
        if eng is None:
            ENGINE_BUILDS.bump()
            if backend == "jax" and MIRROR in v.aux:
                eng = make_engine(v.aux[MIRROR])
            elif backend == "sharded" and SHARDED_MIRROR in v.aux:
                eng = make_engine(v.aux[SHARDED_MIRROR])
            else:
                eng = make_engine(G.flat_snapshot(v.graph), backend=backend)
            eng = v.cache.setdefault(key, eng)
        return eng

    def query_batch(
        self, sources=None, kind: str = "bfs", backend: Optional[str] = None, **kw
    ):
        """Serve a coalesced batch of queries against ONE version-pinned
        engine (DESIGN.md §7): many users' pending single-source queries
        ride a single engine acquire and — on the jax/sharded backends —
        a single in-trace multi-source dispatch, instead of K independent
        traversals each paying per-round host syncs.

        ``backend=None`` routes to the stream's resident mirror: the
        sharded engine on ``mirror="sharded"`` streams, the jax engine
        otherwise.

        kinds: ``"bfs"`` -> int64[B, n] parent rows; ``"distances"`` ->
        int64[B, n] hop counts (landmark rows); ``"bc"`` -> float[B, n]
        dependency scores; ``"sssp"`` -> float64[B, n] weighted
        shortest-path distances (+inf = unreached; the in-trace
        Bellman–Ford driver on jax); ``"pagerank"`` -> float[B, n]
        scores for the personalization rows passed as ``resets``
        (``sources`` unused).  Extra kwargs are forwarded to the
        traversal-layer ``*_multi``.

        Identical ``(kind, source)`` requests inside one batch compute
        ONCE: the engine sees the unique sources and the result rows fan
        back out to every caller's lane (Zipfian query mixes repeat hot
        sources constantly, so the dedup is free qps).

        An EMPTY request set — ``sources`` None/empty, or a pagerank
        ``resets`` with zero rows — returns ``[]`` without touching an
        engine: a serving lane whose pending set collapsed to nothing
        (dedup, cancellation) must flush as a no-op, not an error.
        """
        if kind not in ("bfs", "distances", "bc", "sssp", "pagerank"):
            raise ValueError(f"unknown query kind {kind!r}")
        if self._empty_request(kind, sources, kw):
            return []
        if backend is None:
            backend = self._default_backend()
        return self._serve_kind(self.engine(backend), kind, sources, kw)

    @staticmethod
    def _empty_request(kind: str, sources, kw) -> bool:
        """The no-op-flush check, applied BEFORE any engine is fetched
        (an empty request must not pay an acquire or a build)."""
        if kind == "pagerank":
            resets = kw.get("resets")
            return resets is not None and np.asarray(resets).shape[0] == 0
        if sources is None:
            return True
        return np.asarray(sources, dtype=np.int64).reshape(-1).size == 0

    @staticmethod
    def _serve_kind(eng, kind: str, sources, kw):
        """One kind's dispatch against an already-fetched engine: the
        shared tail of ``query_batch`` / ``query_multi`` (source dedup +
        fan-out; pagerank takes its ``resets`` rows verbatim)."""
        from .traversal import algorithms as talg

        if kind == "pagerank":
            return talg.pagerank_multi(eng, **kw)
        sources = np.asarray(sources, dtype=np.int64).reshape(-1)
        uniq, inv = np.unique(sources, return_inverse=True)
        if kind == "bfs":
            return talg.bfs_multi(eng, uniq, **kw)[0][inv]
        if kind == "distances":
            return talg.landmark_distances(eng, uniq, **kw)[inv]
        if kind == "bc":
            return talg.bc_multi(eng, uniq, **kw)[inv]
        if kind == "sssp":
            return talg.sssp_multi(eng, uniq, **kw)[inv]
        raise ValueError(f"unknown query kind {kind!r}")

    def query_multi(self, requests, backend: Optional[str] = None):
        """Serve a MIXED-kind batch against one version: a list of
        ``query_batch``-style request dicts (``{"kind": ..., "sources":
        ..., **kwargs}``) answered in order against a single acquired
        version and a single engine fetch.

        ``query_batch`` called K times pays K acquire/engine lookups
        and — worse — may straddle a publish, answering later requests
        on a newer graph.  ``query_multi`` hoists the per-version work:
        ONE acquire, ONE ``_engine_for`` (the engine-cache aux lookup
        happens once; ``traversal.ENGINE_BUILDS`` pins single
        construction in tests), and every answer reflects the same
        snapshot.  Empty requests return ``[]`` in place, and a batch of
        only-empty requests never fetches an engine at all."""
        if backend is None:
            backend = self._default_backend()
        out = []
        v = self.acquire()
        try:
            eng = None
            for req in requests:
                req = dict(req)
                kind = req.pop("kind", "bfs")
                sources = req.pop("sources", None)
                if kind not in ("bfs", "distances", "bc", "sssp", "pagerank"):
                    raise ValueError(f"unknown query kind {kind!r}")
                if self._empty_request(kind, sources, req):
                    out.append([])
                    continue
                if eng is None:
                    eng = self._engine_for(v, backend)
                out.append(self._serve_kind(eng, kind, sources, req))
        finally:
            self.release(v)
        return out

    def subscribe(
        self,
        kind: str,
        sources=None,
        backend: Optional[str] = None,
        **params,
    ) -> "Subscription":
        """Open a live subscription: a handle whose ``refresh()`` keeps
        the result of one standing query (``"pagerank"`` / ``"cc"`` /
        ``"bfs"`` / ``"sssp"``) continuously fresh across publishes by
        applying the delta-aware incremental path per new version
        instead of recomputing from scratch (see ``Subscription``)."""
        return Subscription(self, kind, sources=sources, backend=backend, **params)


class Subscription:
    """A standing query kept continuously fresh across publishes.

    The handle holds (acquires) the version its current result was
    computed against — version-pinned exactly like the engine cache, so
    the pinned version, its delta record and its cached engines are all
    GC'd together the moment the subscription advances past them or
    closes.  ``refresh()`` compares the held stamp with the writer's
    current one; when behind, it asks ``vg.delta_between`` for the
    composed update record and applies the *incremental* path over the
    new snapshot:

      pagerank  warm-start power iteration from the previous scores to
                the same fixed-point tolerance (valid for ANY change —
                damping < 1 gives a unique fixed point, the init only
                sets how far away iteration starts);
      cc        min-label propagation seeded from the delta endpoints
                (exact; deltas with deletions fall back to full);
      bfs/sssp  dirty-subtree revalidation seeded into the warm
                ``sssp_batch_from`` drivers (exact, see
                ``algorithms.incremental_bfs`` / ``incremental_sssp``).

    A broken delta chain (a hop GC'd before this subscriber caught up,
    or a version published without a delta record) downgrades that one
    refresh to a full recompute — never to a wrong answer.
    ``n_full`` / ``n_incremental`` count which path each refresh took.
    Thread-safe; at most one refresh runs at a time."""

    KINDS = ("pagerank", "cc", "bfs", "sssp")

    def __init__(
        self,
        stream: AspenStream,
        kind: str,
        sources=None,
        backend: Optional[str] = None,
        damping: float = 0.85,
        tol: float = 1e-6,
        max_iters: int = 200,
    ):
        if kind not in self.KINDS:
            raise ValueError(f"unknown subscription kind {kind!r}")
        if kind in ("bfs", "sssp"):
            if sources is None:
                raise ValueError(f"{kind!r} subscriptions need sources")
            self._sources = np.asarray(sources, dtype=np.int64).reshape(-1)
        else:
            self._sources = None
        self._stream = stream
        self.kind = kind
        self._backend = backend
        self._damping, self._tol, self._max_iters = damping, tol, max_iters
        self._lock = threading.Lock()
        self.n_full = 0
        self.n_incremental = 0
        self._closed = False
        self._v = stream.acquire()
        try:
            self._recompute(self._v)
        except BaseException:
            stream.release(self._v)
            raise

    @property
    def stamp(self) -> int:
        """The version stamp the current result reflects."""
        return self._v.stamp

    @property
    def value(self):
        """The current result, as of ``stamp`` (no refresh): pagerank ->
        scores (n,); cc -> labels (n,); bfs -> (parents, depths)
        int64[B, n]; sssp -> distances float64[B, n]."""
        if self.kind == "pagerank":
            return self._scores
        if self.kind == "cc":
            return self._labels
        if self.kind == "bfs":
            return self._parents, self._depths
        return self._dist

    def _engine(self, v: Version[G.Graph]):
        backend = self._backend
        if backend is None:
            backend = self._stream._default_backend()
        return self._stream._engine_for(v, backend)

    def _recompute(self, v: Version[G.Graph]) -> None:
        from .traversal import algorithms as talg

        eng = self._engine(v)
        if self.kind == "pagerank":
            self._scores = talg.pagerank(
                eng, damping=self._damping, tol=self._tol, max_iters=self._max_iters
            )
        elif self.kind == "cc":
            self._labels = np.asarray(talg.connected_components(eng), np.int64)
        elif self.kind == "bfs":
            parents, depths = talg.bfs_multi(eng, self._sources)
            self._parents = np.asarray(parents, np.int64)
            self._depths = np.asarray(depths, np.int64)
        else:
            self._dist = np.asarray(talg.sssp_multi(eng, self._sources), np.float64)
            # the shortest-path-tree parents are the state the NEXT
            # delta's dirty-subtree computation needs
            self._tree = talg.shortest_path_parents(eng, self._dist, self._sources)
        self.n_full += 1

    def _advance(self, v: Version[G.Graph], delta: Optional[Delta]) -> None:
        from .traversal import algorithms as talg

        if self.kind == "pagerank":
            eng = self._engine(v)
            self._scores = talg.pagerank(
                eng,
                damping=self._damping,
                tol=self._tol,
                max_iters=self._max_iters,
                init=self._scores,
            )
            self.n_incremental += 1
            return
        if delta is None or (self.kind == "cc" and delta.has_deletions):
            self._recompute(v)
            return
        eng = self._engine(v)
        if self.kind == "cc":
            self._labels = np.asarray(
                talg.incremental_connected_components(eng, self._labels, delta),
                np.int64,
            )
        elif self.kind == "bfs":
            self._parents, self._depths = talg.incremental_bfs(
                eng, self._sources, self._parents, self._depths, delta
            )
        else:
            self._dist = talg.incremental_sssp(
                eng, self._sources, self._dist, self._tree, delta
            )
            self._tree = talg.shortest_path_parents(eng, self._dist, self._sources)
        self.n_incremental += 1

    def refresh(self):
        """Bring the result up to the writer's current version (no-op
        when already fresh) and return it."""
        with self._lock:
            if self._closed:
                raise RuntimeError("subscription is closed")
            cur = self._stream.acquire()
            if cur.stamp == self._v.stamp:
                self._stream.release(cur)
                return self.value
            try:
                delta = self._stream.vg.delta_between(self._v, cur)
                self._advance(cur, delta)
            except BaseException:
                self._stream.release(cur)
                raise
            old, self._v = self._v, cur
            self._stream.release(old)
            return self.value

    def close(self) -> None:
        """Release the pinned version (idempotent).  The held version —
        and with it the delta record and cached engines — becomes
        collectible as soon as no other reader holds it."""
        with self._lock:
            if not self._closed:
                self._closed = True
                self._stream.release(self._v)

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ConcurrentStats(NamedTuple):
    updates_per_sec: float
    mean_update_latency_s: float
    query_latency_concurrent_s: float
    query_latency_isolated_s: float
    n_updates: int
    n_queries: int
    queries_per_sec: float = 0.0  # single-source queries served / reader-busy s
    subscriber_staleness: float = 0.0  # mean versions-behind after refresh


def run_concurrent(
    stream: AspenStream,
    updates: np.ndarray,  # (k, 3): src, dst, is_delete
    query_fn: Callable[[object], object],
    duration_s: float = 5.0,
    batch_size: int = 1,
    symmetric: bool = True,
    engine_backend: Optional[str] = None,
    queries_per_call: int = 1,
    subscription: Optional[Subscription] = None,
) -> ConcurrentStats:
    """Paper §7.3: writer applies updates one batch at a time while a
    reader repeatedly runs query_fn against fresh snapshots.

    ``query_fn`` receives a ``FlatSnapshot`` per query by default; pass
    ``engine_backend`` ("numpy"/"jax") to hand it the stream's cached
    traversal engine instead (the dual-representation serve path), or
    ``subscription`` to hand it a live ``Subscription`` handle (the
    incremental serve path: ``query_fn`` typically just calls
    ``refresh()``).  In subscriber mode the reader additionally samples
    *staleness* — how many versions the writer has published past the
    one the subscriber serves, measured right after each refresh —
    reported as ``subscriber_staleness``.

    ``queries_per_call`` declares how many user queries one ``query_fn``
    invocation serves (a batched reader passes e.g. a ``bfs_multi``
    over B sources and ``queries_per_call=B``), so the reported
    ``queries_per_sec`` measures batched vs. serial query throughput on
    equal terms.

    ``symmetric`` is forwarded to the insert/delete calls; the reported
    throughput counts the directed edges actually applied (2x the batch
    only when symmetric), not a hard-coded doubling.
    """
    stop = threading.Event()
    upd_lat: List[float] = []
    n_upd = [0]
    n_directed = [0]
    per_update = 2 if symmetric else 1

    # the writer loop is the SAME code path the serving layer runs
    # (``drain_updates`` over an ``UpdateQueue``), so batching semantics
    # measured here are the semantics a GraphQueryService writer has
    pending = UpdateQueue(maxsize=None)
    for row in updates:
        pending.put(int(row[0]), int(row[1]), delete=bool(row[2]), block=False)

    def updater():
        while not stop.is_set():
            t0 = time.perf_counter()
            k = drain_updates(pending, stream, batch_size, symmetric=symmetric)
            if k == 0:
                break
            upd_lat.append(time.perf_counter() - t0)
            n_upd[0] += k
            n_directed[0] += k * per_update

    q_lat: List[float] = []
    staleness: List[int] = []

    def _substrate():
        if subscription is not None:
            return subscription
        if engine_backend is not None:
            return stream.engine(engine_backend)
        return stream.flat_snapshot()

    def reader():
        while not stop.is_set():
            sub = _substrate()
            t0 = time.perf_counter()
            query_fn(sub)
            q_lat.append(time.perf_counter() - t0)
            if subscription is not None:
                staleness.append(stream.vg.current_stamp - subscription.stamp)

    tu = threading.Thread(target=updater)
    tq = threading.Thread(target=reader)
    tu.start()
    tq.start()
    time.sleep(duration_s)
    stop.set()
    tu.join()
    tq.join()

    # isolated query latency on the final version
    sub = _substrate()
    iso: List[float] = []
    for _ in range(max(3, min(10, len(q_lat)))):
        t0 = time.perf_counter()
        query_fn(sub)
        iso.append(time.perf_counter() - t0)

    total_upd_time = sum(upd_lat) if upd_lat else 1e-9
    return ConcurrentStats(
        updates_per_sec=n_directed[0] / total_upd_time,  # directed edges/s
        mean_update_latency_s=float(np.mean(upd_lat)) if upd_lat else 0.0,
        query_latency_concurrent_s=float(np.mean(q_lat)) if q_lat else 0.0,
        query_latency_isolated_s=float(np.mean(iso)),
        n_updates=n_upd[0],
        n_queries=len(q_lat) * queries_per_call,
        queries_per_sec=len(q_lat) * queries_per_call / max(sum(q_lat), 1e-9),
        subscriber_staleness=float(np.mean(staleness)) if staleness else 0.0,
    )


def make_update_stream(
    edges: np.ndarray, n_updates: int, seed: int = 0, delete_frac: float = 0.1
) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §7.3 methodology: sample updates from the input graph.

    Returns (graph_edges_after_removal, update_stream[k,3]) where 90% of
    the sampled edges are first removed from the graph and re-inserted by
    the stream; 10% stay and get deleted by the stream.
    """
    rng = np.random.default_rng(seed)
    m = edges.shape[0]
    k = min(n_updates, m)
    pick = rng.choice(m, size=k, replace=False)
    sampled = edges[pick]
    n_ins = int(k * (1 - delete_frac))
    ins, dels = sampled[:n_ins], sampled[n_ins:]
    keep_mask = np.ones(m, dtype=bool)
    keep_mask[pick[:n_ins]] = False  # insertions start absent
    stream = np.concatenate(
        [
            np.concatenate([ins, np.zeros((ins.shape[0], 1), np.int64)], axis=1),
            np.concatenate([dels, np.ones((dels.shape[0], 1), np.int64)], axis=1),
        ]
    )
    rng.shuffle(stream)
    return edges[keep_mask], stream
