"""Graph algorithms over Aspen snapshots (paper §7 "Algorithms").

Global: BFS, BC (single-source betweenness), MIS, plus PageRank and
label-propagation CC (extras beyond the paper's five).
Local:  2-hop, Local-Cluster (Nibble-Serial, [71, 72]).

The frontier-synchronous globals (BFS / BC / PageRank / CC) are thin
wrappers over the backend-generic implementations in
``repro.core.traversal.algorithms`` bound to the numpy engine — the
same algorithm text also runs on the jax/TPU backend (see
``traversal.make_engine``).  MIS and the local algorithms keep their
direct implementations here.

All globals take a FlatSnapshot (paper §5.1: global algorithms can afford
the O(n) flat-snapshot and then pay O(deg(v)) per vertex, as CSR would);
locals run directly against the tree to model the no-snapshot regime.
"""
from __future__ import annotations

import numpy as np

from . import ctree as ct
from .graph import FlatSnapshot, Graph, find_vertex
from .traversal import gather_csr
from .traversal import algorithms as talg
from .traversal.numpy_backend import engine_of as _engine_of


# ---------------------------------------------------------------------------
# frontier-synchronous globals: numpy engine bound to the generic text
# ---------------------------------------------------------------------------


def bfs(snap: FlatSnapshot, src: int, direction_optimize: bool = True) -> np.ndarray:
    """Returns the parent array (-1 = unreached; src's parent is itself)."""
    return talg.bfs(_engine_of(snap), src, direction_optimize=direction_optimize)


def bc(snap: FlatSnapshot, src: int) -> np.ndarray:
    """Single-source betweenness contributions (paper §7: BC computes the
    contributions for shortest paths from one vertex)."""
    return talg.bc(_engine_of(snap), src)


def pagerank(snap: FlatSnapshot, iters: int = 10, damping: float = 0.85) -> np.ndarray:
    return talg.pagerank(_engine_of(snap), iters=iters, damping=damping)


def connected_components(snap: FlatSnapshot, max_iters: int = 1000) -> np.ndarray:
    """Label propagation (min-label) to fixpoint.  Assumes a symmetric
    edge set (the paper's undirected model; AspenStream's default)."""
    return talg.connected_components(_engine_of(snap), max_iters=max_iters)


# ---------------------------------------------------------------------------
# Maximal independent set (rootset-based, Luby-style rounds)
# ---------------------------------------------------------------------------


def mis(snap: FlatSnapshot, seed: int = 0) -> np.ndarray:
    """Bool mask of a maximal independent set."""
    n = snap.n
    rng = np.random.default_rng(seed)
    pri = rng.permutation(n)  # random priorities
    in_set = np.zeros(n, dtype=bool)
    removed = np.zeros(n, dtype=bool)
    remaining = np.arange(n, dtype=np.int64)
    while remaining.size:
        offsets, nbrs = gather_csr(snap, remaining)
        srcs = np.repeat(remaining, np.diff(offsets))
        alive_e = ~removed[nbrs]
        # u is a local max if no alive neighbor has higher priority
        worse = np.zeros(n, dtype=bool)
        hi = alive_e & (pri[nbrs] > pri[srcs])
        np.logical_or.at(worse, srcs[hi], True)
        winners = remaining[~worse[remaining]]
        in_set[winners] = True
        removed[winners] = True
        # remove neighbors of winners
        w_off, w_nbrs = gather_csr(snap, winners)
        removed[w_nbrs] = True
        remaining = remaining[~removed[remaining]]
    return in_set


def verify_mis(snap: FlatSnapshot, in_set: np.ndarray) -> bool:
    n = snap.n
    for v in range(n):
        nbrs = snap.neighbors(v)
        if in_set[v]:
            if in_set[nbrs].any():
                return False
        else:
            if not in_set[nbrs].any() and nbrs.size:
                return False
    return True


# ---------------------------------------------------------------------------
# Local algorithms (run against the tree, no flat snapshot — paper §5.1)
# ---------------------------------------------------------------------------


def two_hop(g: Graph, src: int) -> np.ndarray:
    """Vertices within 2 hops of src (local query; tree access)."""
    et = find_vertex(g, src)
    if et is None:
        return np.empty(0, dtype=np.int64)
    one = ct.to_array(et)
    parts = [one]
    for u in one.tolist():
        eu = find_vertex(g, int(u))
        if eu is not None:
            parts.append(ct.to_array(eu))
    out = np.unique(np.concatenate(parts)) if parts else np.empty(0, np.int64)
    return out[out != src]


def local_cluster(
    g: Graph, src: int, eps: float = 1e-6, T: int = 10, alpha: float = 0.15
) -> np.ndarray:
    """Nibble-Serial ([71, 72]): truncated random-walk heat-kernel cluster.

    Sequential by design (paper runs many concurrently); returns the
    cluster's vertex ids.
    """
    p = {src: 1.0}
    for _ in range(T):
        nxt: dict = {}
        for v, mass in p.items():
            if mass < eps:
                continue
            et = find_vertex(g, int(v))
            nbrs = ct.to_array(et) if et is not None else np.empty(0, np.int64)
            keep = alpha * mass
            nxt[v] = nxt.get(v, 0.0) + keep
            if nbrs.size:
                share = (1 - alpha) * mass / nbrs.size
                for u in nbrs.tolist():
                    nxt[u] = nxt.get(u, 0.0) + share
        p = nxt
    verts = np.asarray(sorted(p, key=p.get, reverse=True), dtype=np.int64)
    mass = np.asarray([p[int(v)] for v in verts])
    cut = max(1, int((mass.cumsum() <= 0.9 * mass.sum()).sum()))
    return np.sort(verts[:cut])


