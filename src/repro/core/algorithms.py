"""Graph algorithms over Aspen snapshots (paper §7 "Algorithms").

Global: BFS, BC (single-source betweenness), MIS, plus PageRank and
label-propagation CC (extras beyond the paper's five).
Local:  2-hop, Local-Cluster (Nibble-Serial, [71, 72]).

All globals take a FlatSnapshot (paper §5.1: global algorithms can afford
the O(n) flat-snapshot and then pay O(deg(v)) per vertex, as CSR would);
locals run directly against the tree to model the no-snapshot regime.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from . import ctree as ct
from .edgemap import VertexSubset, edge_map, from_ids, gather_csr
from .graph import FlatSnapshot, Graph, find_vertex


def _total_edges(snap: FlatSnapshot) -> int:
    return sum(snap.degree(v) for v in range(snap.n))


# ---------------------------------------------------------------------------
# BFS (direction-optimized, paper §5.1)
# ---------------------------------------------------------------------------


def bfs(snap: FlatSnapshot, src: int, direction_optimize: bool = True) -> np.ndarray:
    """Returns the parent array (-1 = unreached; src's parent is itself)."""
    n = snap.n
    parents = np.full(n, -1, dtype=np.int64)
    parents[src] = src
    frontier = from_ids(n, [src])
    m = _total_edges(snap)

    def C(vs):
        return parents[vs] == -1

    def F(us, vs):
        # claim: first writer wins (vectorized CAS emulation: np unique)
        vs_u, first = np.unique(vs, return_index=True)
        unclaimed = parents[vs_u] == -1
        parents[vs_u[unclaimed]] = us[first][unclaimed]
        return np.zeros(us.shape, dtype=bool)  # outputs built from claims

    def F_sparse(us, vs):
        vs_u, first = np.unique(vs, return_index=True)
        unclaimed = parents[vs_u] == -1
        parents[vs_u[unclaimed]] = us[first][unclaimed]
        won = np.zeros(us.shape, dtype=bool)
        idx = first[unclaimed]
        won[idx] = True
        return won

    def F_dense(candidates, offsets, nbrs, nbr_in_u):
        """Dense direction: each unreached v scans in-neighbors for any in
        the frontier; takes the first as parent (Beamer bottom-up)."""
        seg = np.repeat(np.arange(candidates.size), np.diff(offsets))
        hit = nbr_in_u
        out_mask = np.zeros(candidates.size, dtype=bool)
        # first hit per segment
        if hit.any():
            hit_idx = np.flatnonzero(hit)
            seg_hit = seg[hit_idx]
            first_per_seg = np.unique(seg_hit, return_index=True)
            segs, firsts = first_per_seg
            parents[candidates[segs]] = nbrs[hit_idx[firsts]]
            out_mask[segs] = True
        return out_mask

    while not frontier.empty:
        frontier = edge_map(
            snap,
            frontier,
            F_sparse,
            C,
            m=m,
            direction_optimize=direction_optimize,
            F_dense=F_dense,
        )
    return parents


# ---------------------------------------------------------------------------
# Betweenness centrality (Brandes, single source; paper's BC)
# ---------------------------------------------------------------------------


def bc(snap: FlatSnapshot, src: int) -> np.ndarray:
    """Single-source betweenness contributions (paper §7: BC computes the
    contributions for shortest paths from one vertex)."""
    n = snap.n
    num_paths = np.zeros(n, dtype=np.float64)
    num_paths[src] = 1.0
    visited = np.zeros(n, dtype=bool)
    visited[src] = True
    levels = []
    frontier = np.asarray([src], dtype=np.int64)
    # forward: count shortest paths level by level
    while frontier.size:
        levels.append(frontier)
        offsets, nbrs = gather_csr(snap, frontier)
        srcs = np.repeat(frontier, np.diff(offsets))
        mask = ~visited[nbrs]
        if mask.any():
            np.add.at(num_paths, nbrs[mask], num_paths[srcs[mask]])
            nxt = np.unique(nbrs[mask])
        else:
            nxt = np.empty(0, dtype=np.int64)
        visited[nxt] = True
        frontier = nxt
    # backward: accumulate dependencies level by level (Brandes)
    dependencies = _bc_backward(snap, levels, num_paths)
    dependencies[src] = 0.0
    return dependencies


def _bc_backward(snap, levels, num_paths) -> np.ndarray:
    n = snap.n
    level_of = np.full(n, -1, dtype=np.int64)
    for d, lv in enumerate(levels):
        level_of[lv] = d
    dep = np.zeros(n, dtype=np.float64)
    for d in range(len(levels) - 2, -1, -1):
        frontier = levels[d]
        offsets, nbrs = gather_csr(snap, frontier)
        srcs = np.repeat(frontier, np.diff(offsets))
        succ = level_of[nbrs] == (d + 1)
        if succ.any():
            u, v = srcs[succ], nbrs[succ]
            contrib = (num_paths[u] / num_paths[v]) * (1.0 + dep[v])
            np.add.at(dep, u, contrib)
    return dep


# ---------------------------------------------------------------------------
# Maximal independent set (rootset-based, Luby-style rounds)
# ---------------------------------------------------------------------------


def mis(snap: FlatSnapshot, seed: int = 0) -> np.ndarray:
    """Bool mask of a maximal independent set."""
    n = snap.n
    rng = np.random.default_rng(seed)
    pri = rng.permutation(n)  # random priorities
    in_set = np.zeros(n, dtype=bool)
    removed = np.zeros(n, dtype=bool)
    remaining = np.arange(n, dtype=np.int64)
    while remaining.size:
        offsets, nbrs = gather_csr(snap, remaining)
        srcs = np.repeat(remaining, np.diff(offsets))
        alive_e = ~removed[nbrs]
        # u is a local max if no alive neighbor has higher priority
        worse = np.zeros(n, dtype=bool)
        hi = alive_e & (pri[nbrs] > pri[srcs])
        np.logical_or.at(worse, srcs[hi], True)
        winners = remaining[~worse[remaining]]
        in_set[winners] = True
        removed[winners] = True
        # remove neighbors of winners
        w_off, w_nbrs = gather_csr(snap, winners)
        removed[w_nbrs] = True
        remaining = remaining[~removed[remaining]]
    return in_set


def verify_mis(snap: FlatSnapshot, in_set: np.ndarray) -> bool:
    n = snap.n
    for v in range(n):
        nbrs = snap.neighbors(v)
        if in_set[v]:
            if in_set[nbrs].any():
                return False
        else:
            if not in_set[nbrs].any() and nbrs.size:
                return False
    return True


# ---------------------------------------------------------------------------
# Local algorithms (run against the tree, no flat snapshot — paper §5.1)
# ---------------------------------------------------------------------------


def two_hop(g: Graph, src: int) -> np.ndarray:
    """Vertices within 2 hops of src (local query; tree access)."""
    et = find_vertex(g, src)
    if et is None:
        return np.empty(0, dtype=np.int64)
    one = ct.to_array(et)
    parts = [one]
    for u in one.tolist():
        eu = find_vertex(g, int(u))
        if eu is not None:
            parts.append(ct.to_array(eu))
    out = np.unique(np.concatenate(parts)) if parts else np.empty(0, np.int64)
    return out[out != src]


def local_cluster(
    g: Graph, src: int, eps: float = 1e-6, T: int = 10, alpha: float = 0.15
) -> np.ndarray:
    """Nibble-Serial ([71, 72]): truncated random-walk heat-kernel cluster.

    Sequential by design (paper runs many concurrently); returns the
    cluster's vertex ids.
    """
    p = {src: 1.0}
    for _ in range(T):
        nxt: dict = {}
        for v, mass in p.items():
            if mass < eps:
                continue
            et = find_vertex(g, int(v))
            nbrs = ct.to_array(et) if et is not None else np.empty(0, np.int64)
            keep = alpha * mass
            nxt[v] = nxt.get(v, 0.0) + keep
            if nbrs.size:
                share = (1 - alpha) * mass / nbrs.size
                for u in nbrs.tolist():
                    nxt[u] = nxt.get(u, 0.0) + share
        p = nxt
    verts = np.asarray(sorted(p, key=p.get, reverse=True), dtype=np.int64)
    mass = np.asarray([p[int(v)] for v in verts])
    cut = max(1, int((mass.cumsum() <= 0.9 * mass.sum()).sum()))
    return np.sort(verts[:cut])


# ---------------------------------------------------------------------------
# extras: PageRank + connected components (beyond the paper's five)
# ---------------------------------------------------------------------------


def pagerank(snap: FlatSnapshot, iters: int = 10, damping: float = 0.85) -> np.ndarray:
    n = snap.n
    deg = np.asarray([snap.degree(v) for v in range(n)], dtype=np.float64)
    offsets, nbrs = gather_csr(snap, np.arange(n, dtype=np.int64))
    srcs = np.repeat(np.arange(n, dtype=np.int64), np.diff(offsets))
    pr = np.full(n, 1.0 / n)
    dangling = deg == 0
    for _ in range(iters):
        contrib = np.zeros(n)
        w = pr[srcs] / np.maximum(deg[srcs], 1)
        np.add.at(contrib, nbrs, w)
        contrib += pr[dangling].sum() / n  # redistribute dangling mass
        pr = (1 - damping) / n + damping * contrib
    return pr


def connected_components(snap: FlatSnapshot, max_iters: int = 1000) -> np.ndarray:
    """Label propagation (min-label) to fixpoint."""
    n = snap.n
    labels = np.arange(n, dtype=np.int64)
    offsets, nbrs = gather_csr(snap, np.arange(n, dtype=np.int64))
    srcs = np.repeat(np.arange(n, dtype=np.int64), np.diff(offsets))
    for _ in range(max_iters):
        new = labels.copy()
        np.minimum.at(new, nbrs, labels[srcs])
        np.minimum.at(new, srcs, labels[nbrs])
        if (new == labels).all():
            break
        labels = new
    return labels
