"""Uniform hash family for C-tree head selection (paper §3.1).

An element ``e`` is promoted to a *head* iff ``h(e) mod b == 0`` where ``h``
is drawn from a (approximately) uniformly random family.  The critical
property the paper exploits — and that we exploit even harder on TPU — is
that headness is a pure per-element predicate: it does not depend on the
tree shape, history, or neighbors, so re-chunking after a batch update is an
embarrassingly parallel map.

We use the murmur3 32-bit finalizer (a measured-good avalanche mix) with a
seed that selects the family member.  Identical results are produced by the
numpy path (faithful host C-tree) and the jnp path (flat TPU C-tree) so the
two levels chunk identically — property-tested in tests/test_hash.py.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

_DEFAULT_SEED = np.uint32(0x9E3779B9)


def hash32_np(x: np.ndarray, seed: int | np.uint32 = _DEFAULT_SEED) -> np.ndarray:
    """murmur3 fmix32 over uint32 lanes (numpy). uint32 wraparound is the
    point of the mix, so overflow warnings are suppressed locally."""
    with np.errstate(over="ignore"):
        h = (np.asarray(x).astype(np.uint64) & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        h ^= np.uint32(seed)
        h ^= h >> np.uint32(16)
        h = (h * np.uint32(0x85EBCA6B)).astype(np.uint32)
        h ^= h >> np.uint32(13)
        h = (h * np.uint32(0xC2B2AE35)).astype(np.uint32)
        h ^= h >> np.uint32(16)
    return h


def hash32_jnp(x: jnp.ndarray, seed: int = int(_DEFAULT_SEED)) -> jnp.ndarray:
    """murmur3 fmix32 over uint32 lanes (jax; identical to hash32_np)."""
    h = x.astype(jnp.uint32)
    h = h ^ jnp.uint32(seed)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def is_head_np(x: np.ndarray, b: int, seed: int | np.uint32 = _DEFAULT_SEED) -> np.ndarray:
    """Head predicate h(e) mod b == 0.  ``b`` need not be a power of two,
    but powers of two are cheapest (mask instead of mod)."""
    h = hash32_np(x, seed)
    if b & (b - 1) == 0:
        return (h & np.uint32(b - 1)) == 0
    return (h % np.uint32(b)) == 0


def is_head_jnp(x: jnp.ndarray, b: int, seed: int = int(_DEFAULT_SEED)) -> jnp.ndarray:
    h = hash32_jnp(x, seed)
    if b & (b - 1) == 0:
        return (h & jnp.uint32(b - 1)) == 0
    return (h % jnp.uint32(b)) == 0


def priority_np(x, seed: int | np.uint32 = _DEFAULT_SEED):
    """Treap priorities for the head tree (independent family member)."""
    return hash32_np(np.asarray(x), np.uint32(seed) ^ np.uint32(0xDEADBEEF))
