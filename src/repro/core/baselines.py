"""Comparison baselines (paper §7.5-§7.7).

The paper compares Aspen against Stinger (mutable blocked adjacency
lists), LLAMA (multi-versioned CSR deltas), and static CSR frameworks.
We implement the two *data-structure designs* those systems embody so the
benchmark tables have real competitors:

  * ``StingerLike``  — single mutable copy; per-vertex linked blocks of
    fixed size with in-place insert/delete (no snapshots, no concurrency
    with queries: updates and queries must phase, §8.1 category 1).
  * ``LlamaLike``    — base CSR + per-snapshot delta CSRs chained per
    vertex (multi-versioned arrays; queries walk snapshot chains).
  * ``StaticCSR``    — immutable CSR, the Ligra+/GAP memory & traversal
    model (rebuild-from-scratch on update).

All three expose neighbors()/degree()/insert_edges()/nbytes() so the
benchmarks drive them uniformly.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

STINGER_BLOCK = 64  # edges per block (Stinger default order of magnitude)


class StingerLike:
    """Mutable blocked adjacency list (Stinger's design, §7.5).

    Each vertex owns a Python list of numpy blocks; each block holds up to
    STINGER_BLOCK edges with a fill count.  Insert walks blocks to find a
    slot (O(deg) worst case, as the paper notes); delete marks slots."""

    def __init__(self, n: int):
        self.n = n
        self.blocks: List[List[np.ndarray]] = [[] for _ in range(n)]
        self.fill: List[List[int]] = [[] for _ in range(n)]
        self.m = 0

    def insert_edge(self, u: int, v: int) -> None:
        for bi, blk in enumerate(self.blocks[u]):
            f = self.fill[u][bi]
            if v in blk[:f]:
                return
            if f < STINGER_BLOCK:
                blk[f] = v
                self.fill[u][bi] = f + 1
                self.m += 1
                return
        nb = np.full(STINGER_BLOCK, -1, dtype=np.int64)
        nb[0] = v
        self.blocks[u].append(nb)
        self.fill[u].append(1)
        self.m += 1

    def delete_edge(self, u: int, v: int) -> None:
        for bi, blk in enumerate(self.blocks[u]):
            f = self.fill[u][bi]
            hits = np.flatnonzero(blk[:f] == v)
            if hits.size:
                i = hits[0]
                blk[i] = blk[f - 1]
                blk[f - 1] = -1
                self.fill[u][bi] = f - 1
                self.m -= 1
                return

    def insert_edges(self, edges: np.ndarray) -> None:
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            self.insert_edge(int(u), int(v))

    def delete_edges(self, edges: np.ndarray) -> None:
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            self.delete_edge(int(u), int(v))

    def neighbors(self, u: int) -> np.ndarray:
        parts = [blk[:f] for blk, f in zip(self.blocks[u], self.fill[u])]
        return np.concatenate(parts) if parts else np.empty(0, np.int64)

    def degree(self, u: int) -> int:
        return sum(self.fill[u])

    def nbytes(self) -> int:
        """Byte model faithful to STINGER's published struct [28]: each
        edge slot carries (neighbor, weight, timefirst, timerecent) =
        4x8B = 32B; each block a ~64B header (next ptr, high-water mark,
        etc.); the logical vertex array ~5x8B per vertex.  We store only
        ids here but *account* the real struct — consistent with the
        paper's reported ~145 B/edge on rMAT."""
        total = 5 * 8 * self.n  # LVA entry per vertex
        for u in range(self.n):
            total += len(self.blocks[u]) * (STINGER_BLOCK * 32 + 64)
        return total


class LlamaLike:
    """Multi-versioned CSR with per-batch delta snapshots (LLAMA, §7.6)."""

    def __init__(self, n: int, base_edges: np.ndarray):
        self.n = n
        base_edges = np.asarray(base_edges, dtype=np.int64).reshape(-1, 2)
        order = np.lexsort((base_edges[:, 1], base_edges[:, 0]))
        e = base_edges[order]
        self.snap_nbrs: List[np.ndarray] = []
        self.snap_offsets: List[np.ndarray] = []
        offs = np.zeros(n + 1, dtype=np.int64)
        np.add.at(offs[1:], e[:, 0], 1)
        np.cumsum(offs, out=offs)
        self.snap_offsets.append(offs)
        self.snap_nbrs.append(e[:, 1].copy())
        self.m = e.shape[0]

    def insert_edges(self, edges: np.ndarray) -> None:
        """Each batch appends a new snapshot delta (LLAMA's design)."""
        e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        order = np.lexsort((e[:, 1], e[:, 0]))
        e = e[order]
        offs = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(offs[1:], e[:, 0], 1)
        np.cumsum(offs, out=offs)
        self.snap_offsets.append(offs)
        self.snap_nbrs.append(e[:, 1].copy())
        self.m += e.shape[0]

    def neighbors(self, u: int) -> np.ndarray:
        """Walk the snapshot chain (the sequential cost §7.6 observes)."""
        parts = []
        for offs, nbrs in zip(self.snap_offsets, self.snap_nbrs):
            parts.append(nbrs[offs[u] : offs[u + 1]])
        return np.unique(np.concatenate(parts)) if parts else np.empty(0, np.int64)

    def degree(self, u: int) -> int:
        return sum(int(o[u + 1] - o[u]) for o in self.snap_offsets)

    def nbytes(self) -> int:
        total = 0
        for offs, nbrs in zip(self.snap_offsets, self.snap_nbrs):
            total += offs.nbytes + nbrs.nbytes
        return total


class StaticCSR:
    """Immutable CSR (Ligra+/GAP model): queries are optimal, updates
    rebuild everything."""

    def __init__(self, n: int, edges: np.ndarray):
        self.n = n
        e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        keys = np.unique((e[:, 0] << 32) | e[:, 1])
        self.nbrs = (keys & 0xFFFFFFFF).astype(np.int64)
        srcs = keys >> 32
        self.offsets = np.searchsorted(srcs, np.arange(n + 1))
        self.m = keys.size

    def insert_edges(self, edges: np.ndarray) -> "StaticCSR":
        old = np.stack(
            [np.repeat(np.arange(self.n), np.diff(self.offsets)), self.nbrs], axis=1
        )
        return StaticCSR(self.n, np.concatenate([old, edges]))

    def neighbors(self, u: int) -> np.ndarray:
        return self.nbrs[self.offsets[u] : self.offsets[u + 1]]

    def degree(self, u: int) -> int:
        return int(self.offsets[u + 1] - self.offsets[u])

    def nbytes(self) -> int:
        return self.offsets.nbytes + self.nbrs.nbytes


class CompressedCSR(StaticCSR):
    """Ligra+-style compressed CSR: per-vertex difference + byte coding.

    The static-framework memory baseline the paper's 1.8-2.3x claim is
    against (Table 9's L+ column)."""

    def __init__(self, n: int, edges: np.ndarray):
        super().__init__(n, edges)
        from .chunks import vbyte_encode

        self._bufs = [
            vbyte_encode(self.nbrs[self.offsets[u]: self.offsets[u + 1]])
            for u in range(n)
        ]

    def neighbors(self, u: int) -> np.ndarray:
        from .chunks import vbyte_decode

        return vbyte_decode(self._bufs[u])

    def nbytes(self) -> int:
        return self.offsets.nbytes + sum(len(b) for b in self._bufs)


def bfs_adjacency(store, src: int) -> np.ndarray:
    """BFS over any of the baseline stores (uniform neighbors() API)."""
    parents = np.full(store.n, -1, dtype=np.int64)
    parents[src] = src
    frontier = [src]
    while frontier:
        nxt = []
        for u in frontier:
            for v in store.neighbors(u).tolist():
                if parents[v] == -1:
                    parents[v] = u
                    nxt.append(v)
        frontier = nxt
    return parents
