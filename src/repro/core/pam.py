"""Purely-functional augmented search tree (the PAM [73] analogue).

The paper stores C-tree heads — and Aspen's vertex-tree — in a
purely-functional balanced search tree with join-based bulk operations
(Blelloch et al., "Just Join for Parallel Ordered Sets" [13]).  We use a
*treap with deterministic hash priorities*: the paper's w.h.p. bounds hold
for treaps, join/split/union are the textbook join-based algorithms, and —
crucially for testing — hash priorities make the tree **canonical**
(history-independent): any sequence of operations producing the same
key-set produces the *identical* structure.  Property tests exploit this.

Nodes are immutable 6-tuples ``(key, value, left, right, size, aug)``;
every update path-copies O(log n) nodes, so a snapshot is a root pointer —
exactly the property Aspen builds on (paper §1, §6).

Augmentation: a ``TreeModule`` carries ``aug_of(key, value) -> A`` and an
associative ``combine(A, A) -> A`` with identity ``zero``; each node caches
the aug-sum of its subtree, giving O(1) "total edges in graph" queries
(paper §5: "We augment the vertex-tree to store the number of edges").
"""
from __future__ import annotations

import sys
from typing import Any, Callable, Iterator, List, Optional, Tuple

sys.setrecursionlimit(1_000_000)

# Node = (key, value, left, right, size, aug).  None is the empty tree.
Node = Optional[Tuple]

KEY, VAL, LEFT, RIGHT, SIZE, AUG = range(6)

_M32 = 0xFFFFFFFF


def _pri(key: int) -> int:
    """Deterministic treap priority (murmur3 fmix32, pure-Python for speed);
    ties broken by key so the tree shape is canonical."""
    h = (key ^ 0xDEADBEEF) & _M32
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & _M32
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & _M32
    h ^= h >> 16
    return (h << 32) | (key & _M32)


def size(t: Node) -> int:
    return 0 if t is None else t[SIZE]


class TreeModule:
    """Factory for purely-functional treaps sharing one augmentation monoid."""

    def __init__(
        self,
        aug_of: Callable[[Any, Any], Any] = lambda k, v: 0,
        combine: Callable[[Any, Any], Any] = lambda a, b: a + b,
        zero: Any = 0,
    ):
        self.aug_of = aug_of
        self.combine = combine
        self.zero = zero

    # -- node construction ------------------------------------------------
    def node(self, key, value, left: Node, right: Node) -> Node:
        aug = self.aug_of(key, value)
        if left is not None:
            aug = self.combine(left[AUG], aug)
        if right is not None:
            aug = self.combine(aug, right[AUG])
        return (key, value, left, right, 1 + size(left) + size(right), aug)

    def aug(self, t: Node):
        return self.zero if t is None else t[AUG]

    # -- core join-based primitives ---------------------------------------
    def join(self, left: Node, key, value, right: Node) -> Node:
        """Treap join: assumes max(left) < key < min(right)."""
        pk = _pri(key)
        pl = _pri(left[KEY]) if left is not None else -1
        pr = _pri(right[KEY]) if right is not None else -1
        if pk >= pl and pk >= pr:
            return self.node(key, value, left, right)
        if pl >= pr:  # left root wins
            return self.node(
                left[KEY], left[VAL], left[LEFT], self.join(left[RIGHT], key, value, right)
            )
        return self.node(
            right[KEY], right[VAL], self.join(left, key, value, right[LEFT]), right[RIGHT]
        )

    def join2(self, left: Node, right: Node) -> Node:
        """Join without a middle key."""
        if left is None:
            return right
        if right is None:
            return left
        l2, k, v = self.split_last(left)
        return self.join(l2, k, v, right)

    def split_last(self, t: Node) -> Tuple[Node, Any, Any]:
        """Remove and return the largest entry."""
        if t[RIGHT] is None:
            return t[LEFT], t[KEY], t[VAL]
        r2, k, v = self.split_last(t[RIGHT])
        return self.node(t[KEY], t[VAL], t[LEFT], r2), k, v

    def split_first(self, t: Node) -> Tuple[Any, Any, Node]:
        if t[LEFT] is None:
            return t[KEY], t[VAL], t[RIGHT]
        k, v, l2 = self.split_first(t[LEFT])
        return k, v, self.node(t[KEY], t[VAL], l2, t[RIGHT])

    def expose(self, t: Node) -> Tuple[Node, Any, Any, Node]:
        """(left, key, value, right) of the root (paper §4.1 Expose)."""
        return t[LEFT], t[KEY], t[VAL], t[RIGHT]

    def split(self, t: Node, key) -> Tuple[Node, Optional[Any], Node]:
        """(tree < key, value if key present else None, tree > key)."""
        if t is None:
            return None, None, None
        if key < t[KEY]:
            ll, m, lr = self.split(t[LEFT], key)
            return ll, m, self.join(lr, t[KEY], t[VAL], t[RIGHT])
        if key > t[KEY]:
            rl, m, rr = self.split(t[RIGHT], key)
            return self.join(t[LEFT], t[KEY], t[VAL], rl), m, rr
        return t[LEFT], t[VAL] if t[VAL] is not None else True, t[RIGHT]

    # -- queries -----------------------------------------------------------
    def find(self, t: Node, key):
        while t is not None:
            if key < t[KEY]:
                t = t[LEFT]
            elif key > t[KEY]:
                t = t[RIGHT]
            else:
                return t[VAL]
        return None

    def find_le(self, t: Node, key):
        """Entry with the largest key' <= key (paper Find semantics)."""
        best = None
        while t is not None:
            if t[KEY] == key:
                return (t[KEY], t[VAL])
            if t[KEY] < key:
                best = (t[KEY], t[VAL])
                t = t[RIGHT]
            else:
                t = t[LEFT]
        return best

    def first(self, t: Node):
        if t is None:
            return None
        while t[LEFT] is not None:
            t = t[LEFT]
        return (t[KEY], t[VAL])

    def last(self, t: Node):
        if t is None:
            return None
        while t[RIGHT] is not None:
            t = t[RIGHT]
        return (t[KEY], t[VAL])

    def rank(self, t: Node, key) -> int:
        """# keys < key."""
        r = 0
        while t is not None:
            if key <= t[KEY]:
                t = t[LEFT]
            else:
                r += 1 + size(t[LEFT])
                t = t[RIGHT]
        return r

    def select(self, t: Node, i: int):
        """i-th (0-based) entry in key order."""
        while t is not None:
            sl = size(t[LEFT])
            if i < sl:
                t = t[LEFT]
            elif i == sl:
                return (t[KEY], t[VAL])
            else:
                i -= sl + 1
                t = t[RIGHT]
        raise IndexError(i)

    # -- traversal ---------------------------------------------------------
    def iter_entries(self, t: Node) -> Iterator[Tuple[Any, Any]]:
        """In-order iterator (iterative; no recursion-depth limits)."""
        stack: List = []
        while stack or t is not None:
            while t is not None:
                stack.append(t)
                t = t[LEFT]
            t = stack.pop()
            yield (t[KEY], t[VAL])
            t = t[RIGHT]

    def keys(self, t: Node) -> list:
        return [k for k, _ in self.iter_entries(t)]

    def map_values(self, t: Node, f: Callable[[Any, Any], Any]) -> Node:
        """Rebuild with value' = f(key, value) (structure preserved)."""
        if t is None:
            return None
        return self.node(
            t[KEY], f(t[KEY], t[VAL]), self.map_values(t[LEFT], f), self.map_values(t[RIGHT], f)
        )

    def foreach(self, t: Node, f: Callable[[Any, Any], None]) -> None:
        for k, v in self.iter_entries(t):
            f(k, v)

    # -- bulk construction / set algebra ------------------------------------
    def build_sorted(self, entries: List[Tuple[Any, Any]]) -> Node:
        """Build from strictly-increasing (key, value) pairs in O(n).

        Stack-based max-Cartesian-tree construction on the hash priorities
        produces exactly the canonical treap that repeated joins would."""
        n = len(entries)
        if n == 0:
            return None
        pris = [_pri(k) for k, _ in entries]
        left = [-1] * n
        right = [-1] * n
        stack: List[int] = []
        for i in range(n):
            last = -1
            while stack and pris[stack[-1]] < pris[i]:
                last = stack.pop()
            left[i] = last
            if stack:
                right[stack[-1]] = i
            stack.append(i)
        root = stack[0]
        # freeze bottom-up: iterative post-order so child tuples exist first
        frozen: List[Node] = [None] * n
        todo = [(root, False)]
        while todo:
            i, ready = todo.pop()
            if ready:
                k, v = entries[i]
                frozen[i] = self.node(
                    k,
                    v,
                    frozen[left[i]] if left[i] >= 0 else None,
                    frozen[right[i]] if right[i] >= 0 else None,
                )
            else:
                todo.append((i, True))
                if left[i] >= 0:
                    todo.append((left[i], False))
                if right[i] >= 0:
                    todo.append((right[i], False))
        return frozen[root]

    def insert(self, t: Node, key, value, combine_values=None) -> Node:
        l, m, r = self.split(t, key)
        if m is not None and combine_values is not None:
            value = combine_values(m, value)
        return self.join(l, key, value, r)

    def delete(self, t: Node, key) -> Node:
        l, m, r = self.split(t, key)
        return self.join2(l, r)

    def union(self, a: Node, b: Node, combine_values=None) -> Node:
        """Join-based Union [13]; values combined where keys collide."""
        if a is None:
            return b
        if b is None:
            return a
        bl, bk, bv, br = self.expose(b)
        al, m, ar = self.split(a, bk)
        if m is not None and m is not True and combine_values is not None:
            bv = combine_values(m, bv)
        return self.join(
            self.union(al, bl, combine_values), bk, bv, self.union(ar, br, combine_values)
        )

    def difference(self, a: Node, b: Node) -> Node:
        """Keys of a not present in b."""
        if a is None or b is None:
            return a
        bl, bk, _, br = self.expose(b)
        al, _, ar = self.split(a, bk)
        return self.join2(self.difference(al, bl), self.difference(ar, br))

    def intersect(self, a: Node, b: Node, combine_values=None) -> Node:
        if a is None or b is None:
            return None
        bl, bk, bv, br = self.expose(b)
        al, m, ar = self.split(a, bk)
        il, ir = self.intersect(al, bl, combine_values), self.intersect(ar, br, combine_values)
        if m is not None:
            if m is not True and combine_values is not None:
                bv = combine_values(m, bv)
            return self.join(il, bk, bv, ir)
        return self.join2(il, ir)

    def multi_insert(self, t: Node, entries, combine_values=None) -> Node:
        """MultiInsert(T, f, S): batch insert sorted-or-not entries."""
        entries = sorted(entries, key=lambda e: e[0])
        dedup: List = []
        for k, v in entries:
            if dedup and dedup[-1][0] == k:
                if combine_values is not None:
                    dedup[-1] = (k, combine_values(dedup[-1][1], v))
                else:
                    dedup[-1] = (k, v)
            else:
                dedup.append((k, v))
        return self.union(t, self.build_sorted(dedup), combine_values)

    def multi_delete(self, t: Node, keys) -> Node:
        ks = sorted(set(keys))
        return self.difference(t, self.build_sorted([(k, None) for k in ks]))

    # -- structural metrics (for the paper's memory model) ------------------
    def height(self, t: Node) -> int:
        if t is None:
            return 0
        return 1 + max(self.height(t[LEFT]), self.height(t[RIGHT]))

    def check_invariants(self, t: Node, lo=None, hi=None) -> bool:
        """BST order + heap priority + size/aug consistency (for tests)."""
        if t is None:
            return True
        k = t[KEY]
        if (lo is not None and k <= lo) or (hi is not None and k >= hi):
            return False
        for c in (t[LEFT], t[RIGHT]):
            if c is not None and _pri(c[KEY]) > _pri(k):
                return False
        if t[SIZE] != 1 + size(t[LEFT]) + size(t[RIGHT]):
            return False
        a = self.aug_of(k, t[VAL])
        if t[LEFT] is not None:
            a = self.combine(t[LEFT][AUG], a)
        if t[RIGHT] is not None:
            a = self.combine(a, t[RIGHT][AUG])
        if a != t[AUG]:
            return False
        return self.check_invariants(t[LEFT], lo, k) and self.check_invariants(
            t[RIGHT], k, hi
        )


# A plain set-like module (no augmentation) shared by C-tree internals.
SET_MODULE = TreeModule()
