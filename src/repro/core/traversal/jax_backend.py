"""JAX/TPU traversal backend over ``FlatGraph`` (the packed-key pool).

Maps Ligra's edgeMap onto the flat C-tree pool (flat_graph.py):

  * dense ("pull"/whole-pool) direction: every pool slot looks up
    whether its source is in the frontier — one gather + one masked
    scatter, the same shape as GNN aggregation.  The (+, x) semiring
    specialization ``edge_map_reduce`` (PageRank's inner loop) lowers
    to the Pallas one-hot-matmul segment sum in
    ``repro.kernels.segment_reduce`` via ``repro.kernels.ops`` (so it
    runs compiled on TPU and interpret-mode on CPU).

  * sparse ("push") direction: the frontier's adjacency lists are
    contiguous key ranges of the sorted pool, so expansion is a
    fixed-shape ragged gather: nonzero(size=K) frontier ids ->
    searchsorted over per-id degree prefix sums -> pool indices.  No
    dynamic shapes, so the whole push/pull step jits once per
    (F, C, mode) and is reused across iterations and engines.

Direction optimization (|U| + deg(U) > m/20, paper §5.1) runs inside
the jit step as a ``lax.cond``, so one compiled step serves both
directions; the sparse branch's static budgets are sized from the
threshold (a frontier routed sparse can never exceed cap/20 ids or
pool-capacity/20 edges).

Batched multi-source queries (DESIGN.md §7)
-------------------------------------------
``_edge_map_step_batch`` generalizes the step over a ``(B, n)``
frontier batch: the per-lane Beamer rule feeds a *batched* ``lax.cond``
(any over-threshold lane routes the whole round dense — dense is
correct for every frontier size, while the sparse budgets only hold for
under-threshold lanes), so exactly one branch executes per round.  The
in-trace drivers ``bfs_batch`` / ``bc_batch`` fuse whole frontier loops
into one ``lax.while_loop`` — a multi-source traversal is ONE device
dispatch with ONE final sync instead of D·B round-trip-synced steps —
and their pull rounds are the (or, and)/(+, x) semiring
specializations of the dense direction: a segmented row-cumsum over the
dst-major pool (scatter-free; the batched analogue of
``edge_map_reduce``).

Weighted graphs (contract v2, DESIGN.md §8)
-------------------------------------------
A ``FlatGraph`` carrying a value array threads it through every path:
the sparse branch gathers ``weights[eidx]`` alongside the expanded
edge lanes, the dense branch hands F the pool-parallel array directly,
``edge_map_reduce`` dispatches the WEIGHTED Pallas segment-sum
(``out[v] = sum w(u,v) * values[u]``), and the in-trace ``sssp_batch``
driver runs the (min, +) semiring via a segmented row-min scan over
the dst-major pool.  When ``g.weights is None`` every one of these
branches folds away at trace time: no value array is allocated or
read, and the compiled steps are byte-identical to the unweighted
engine's (tests spy on the kernel dispatch to pin this).

Precision contract: the engine computes in ``float32`` by default —
the TPU-native dtype, and what the kernel reduce always accumulated in
anyway (the old ``float_dtype = jnp.float64`` default contradicted the
hardcoded f32 cast in ``_reduce_msgs``, and outside this repo — which
enables ``jax_enable_x64`` globally for the packed int64 keys — it
would silently downcast to f32).  Pass ``float_dtype=jnp.float64`` to
``JaxEngine`` for double-precision state arrays AND reduce
accumulation (requires x64; repro enables it).  Cross-backend parity
versus the float64 numpy engine is to float32 tolerance by default.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

from .. import compressed as cz
from .. import flat_graph as _fg
from ..flat_graph import CompressedPool, FlatGraph, unpack
from .base import DENSE_THRESHOLD_DENOM, HOST_SYNCS, TRACES, ArrayOps, TraversalEngine


class JaxOps(ArrayOps):
    """Functional array helpers for jit-traced F/C callbacks.

    ``float_dtype`` defaults to float32 — the engine's explicit compute
    dtype (see the module docstring's precision contract).  Instances
    hash/compare by dtype so they can be jit-static arguments without
    fragmenting the trace cache across engines.
    """

    xp = jnp
    int_dtype = jnp.int32

    def __init__(self, float_dtype=jnp.float32):
        self.float_dtype = float_dtype

    def __eq__(self, other):
        return type(other) is type(self) and (
            np.dtype(other.float_dtype) == np.dtype(self.float_dtype)
        )

    def __hash__(self):
        return hash((type(self), np.dtype(self.float_dtype).name))

    def set_at(self, arr, idx, vals):
        return arr.at[idx].set(vals)

    def _safe_idx(self, target, idx, mask):
        # OOB indices are dropped by mode="drop": masking = index escape
        return jnp.where(mask, idx, target.shape[0])

    def scatter_max(self, target, idx, vals, mask):
        return target.at[self._safe_idx(target, idx, mask)].max(vals, mode="drop")

    def scatter_min(self, target, idx, vals, mask):
        return target.at[self._safe_idx(target, idx, mask)].min(vals, mode="drop")

    def scatter_add(self, target, idx, vals, mask):
        vals = jnp.where(mask, vals, jnp.zeros((), target.dtype))
        return target.at[self._safe_idx(target, idx, mask)].add(vals, mode="drop")

    def scatter_or(self, target, idx, mask):
        return target.at[self._safe_idx(target, idx, mask)].max(True, mode="drop")


JAX_OPS = JaxOps()


class JaxVertexSubset:
    """Dense bool[n] frontier.  ``size``/``empty`` force a device→host
    sync (python-level loop control); the count is computed ONCE per
    subset and cached — algorithms probe ``U.empty`` every round, and a
    per-access sync was a measurable serial cost inside traversal loops.
    """

    __slots__ = ("dense", "_size")

    def __init__(self, dense: jax.Array):
        self.dense = dense  # bool[n]
        self._size: Optional[int] = None

    @property
    def n(self) -> int:
        return self.dense.shape[0]

    @property
    def size(self) -> int:
        if self._size is None:
            HOST_SYNCS.bump()
            self._size = int(self.dense.sum())
        return self._size

    @property
    def empty(self) -> bool:
        return self.size == 0

    def to_dense(self) -> jax.Array:
        return self.dense

    def to_sparse(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.dense))


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# per-snapshot engine auxiliary state (one jit pytree, device-resident)
# ---------------------------------------------------------------------------


class EngineAux(NamedTuple):
    """Everything ``JaxEngine`` derives from a snapshot, as one pytree.

    Refreshing it is ONE fixed-shape jit call — no host loops, no host
    argsort — so an engine over a freshly-merged mirror costs O(cap)
    device work instead of the old O(m log m) host precompute, and the
    pytree itself can be version-pinned and reused across queries (the
    whole-graph loops and batched drivers below all accept it
    prebuilt).  ``w_by_dst`` is the per-edge value array permuted
    dst-major (for weighted pull rounds and the weighted kernel
    reduce); it is None — no array, no extra leaves, identical traces —
    on unweighted graphs.
    """

    src_c: jax.Array  # int32[cap] clipped sources
    dst_c: jax.Array  # int32[cap] clipped destinations
    evalid: jax.Array  # bool[cap] slot < m
    degrees: jax.Array  # int32[n]
    dst_sorted: jax.Array  # int32[cap] destinations ascending (pad=n)
    src_by_dst: jax.Array  # int32[cap] sources permuted dst-major
    valid_by_dst: jax.Array  # bool[cap]
    dst_offsets: jax.Array  # int32[n+1] segment bounds into dst_sorted
    w_by_dst: Optional[jax.Array] = None  # float32[cap] values dst-major


def _pool_endpoints(g: FlatGraph):
    """(src_c, dst_c, evalid): the clipped-endpoint subset of
    ``EngineAux`` (shared by ``engine_aux`` and, as a fallback when no
    prebuilt aux is supplied, by the whole-graph loops).  A slot is
    usable iff it holds a real edge AND its destination is a real
    vertex: an asymmetric stream can store an edge naming a
    never-source vertex id >= n, and every query direction must DROP it
    (not fold it into the clipped n-1)."""
    n = g.offsets.shape[0] - 1
    src, dst = unpack(g.keys)
    evalid = (jnp.arange(g.keys.shape[0]) < g.m) & (dst >= 0) & (dst < n)
    return (
        jnp.clip(src, 0, max(n - 1, 0)),
        jnp.clip(dst, 0, max(n - 1, 0)),
        evalid,
    )


@jax.jit
def engine_aux(g: FlatGraph) -> EngineAux:
    n = g.offsets.shape[0] - 1
    src_c, dst_c, evalid = _pool_endpoints(g)
    # dst-major permutation for the Pallas segment-sum and the batched
    # pull rounds (the pool is src-major): on-device sort-by-key
    # replaces the old host argsort.  valid => dst == dst_c, so the
    # clipped endpoints are exact here.
    dst_key = jnp.where(evalid, dst_c, jnp.int32(n))
    order = jnp.argsort(dst_key, stable=True)
    dst_sorted = dst_key[order]
    return EngineAux(
        src_c=src_c,
        dst_c=dst_c,
        evalid=evalid,
        degrees=jnp.diff(g.offsets),
        dst_sorted=dst_sorted,
        src_by_dst=src_c[order],
        valid_by_dst=evalid[order],
        dst_offsets=jnp.searchsorted(
            dst_sorted, jnp.arange(n + 1, dtype=jnp.int32)
        ).astype(jnp.int32),
        w_by_dst=None if g.weights is None else g.weights[order],
    )


# ---------------------------------------------------------------------------
# the jit-compiled edgeMap step (module-level: cache shared across engines)
# ---------------------------------------------------------------------------


def _sparse_expand(offsets, keys, U, n: int, ids_budget: int, edge_budget: int):
    """Fixed-shape push expansion of one bool[n] frontier:
    (us, vs, ev, eidx) edge lanes where ``ev`` masks the padded tail
    and edges naming nonexistent destination vertices; ``eidx`` is each
    lane's pool slot (for gathering per-edge values alongside)."""
    ids_raw = jnp.nonzero(U, size=ids_budget, fill_value=n)[0]
    vid = ids_raw < n
    ids = jnp.where(vid, ids_raw, 0).astype(jnp.int32)
    starts = offsets[ids].astype(jnp.int64)
    degs = jnp.where(vid, (offsets[ids + 1] - offsets[ids]), 0).astype(jnp.int64)
    cum = jnp.cumsum(degs)
    j = jnp.arange(edge_budget, dtype=jnp.int64)
    seg = jnp.searchsorted(cum, j, side="right")
    seg = jnp.clip(seg, 0, ids_budget - 1)
    prev = jnp.where(seg > 0, cum[jnp.maximum(seg - 1, 0)], 0)
    eidx = starts[seg] + (j - prev)
    ev = j < cum[-1]
    eidx = jnp.where(ev, eidx, 0)
    vs_raw = keys[eidx] & 0xFFFFFFFF  # int64: no wraparound
    ev = ev & (vs_raw < n)  # drop edges naming nonexistent vertices
    vs = jnp.clip(vs_raw.astype(jnp.int32), 0, n - 1)
    us = ids[seg]
    return us, vs, ev, eidx


@functools.partial(
    jax.jit,
    static_argnames=("F", "C", "mode", "n", "ids_budget", "edge_budget", "ops"),
)
def _edge_map_step(
    offsets,  # int32[n+1]
    keys,  # int64[cap] sorted packed (src<<32|dst)
    src_c,  # int32[cap] clipped sources
    dst_c,  # int32[cap] clipped destinations
    evalid,  # bool[cap] slot < m
    degrees,  # int32[n]
    m,  # int32 scalar
    weights,  # float32[cap] per-edge values, or None (unweighted)
    U,  # bool[n] frontier
    state,  # pytree
    *,
    F: Callable,
    C: Callable,
    mode: str,
    n: int,
    ids_budget: int,
    edge_budget: int,
    ops: JaxOps = JAX_OPS,
):
    cmask = C(ops, state, jnp.arange(n, dtype=jnp.int32))

    def dense_branch(state):
        valid = evalid & U[src_c] & cmask[dst_c]
        return F(ops, state, src_c, dst_c, weights, valid)

    def sparse_branch(state):
        us, vs, ev, eidx = _sparse_expand(offsets, keys, U, n, ids_budget, edge_budget)
        ws = None if weights is None else weights[eidx]
        return F(ops, state, us, vs, ws, ev & cmask[vs])

    if mode == "dense":
        state, out = dense_branch(state)
    elif mode == "sparse":
        state, out = sparse_branch(state)
    else:  # auto: Ligra/Beamer direction optimization, traced
        size = U.sum()
        deg_u = jnp.where(U, degrees, 0).sum()
        use_dense = (size + deg_u) > jnp.maximum(1, m // DENSE_THRESHOLD_DENOM)
        state, out = jax.lax.cond(use_dense, dense_branch, sparse_branch, state)
    return state, out


@functools.partial(
    jax.jit,
    static_argnames=("F", "C", "mode", "n", "ids_budget", "edge_budget", "ops"),
)
def _edge_map_step_batch(
    offsets,
    keys,
    src_c,
    dst_c,
    evalid,
    degrees,
    m,
    weights,  # float32[cap] per-edge values, or None (unweighted)
    U_b,  # bool[B, n] frontier batch (one lane per query)
    state_b,  # pytree with (B, ...) leaves
    *,
    F: Callable,
    C: Callable,
    mode: str,
    n: int,
    ids_budget: int,
    edge_budget: int,
    ops: JaxOps = JAX_OPS,
):
    """The edgeMap step vmapped over a (B, n) frontier batch.

    Direction optimization becomes a *batched* cond: the per-lane
    Beamer rule is evaluated for every lane, and the round routes dense
    iff ANY lane is over threshold — dense is correct for any frontier
    size, while the sparse budgets only bound under-threshold lanes, so
    this is the exact aggregate of the per-lane rule that still
    executes exactly one branch (a per-lane select would pay for both
    branches on every round)."""

    def dense_lane(U, state):
        cmask = C(ops, state, jnp.arange(n, dtype=jnp.int32))
        valid = evalid & U[src_c] & cmask[dst_c]
        return F(ops, state, src_c, dst_c, weights, valid)

    def sparse_lane(U, state):
        cmask = C(ops, state, jnp.arange(n, dtype=jnp.int32))
        us, vs, ev, eidx = _sparse_expand(offsets, keys, U, n, ids_budget, edge_budget)
        ws = None if weights is None else weights[eidx]
        return F(ops, state, us, vs, ws, ev & cmask[vs])

    if mode == "dense":
        return jax.vmap(dense_lane)(U_b, state_b)
    if mode == "sparse":
        return jax.vmap(sparse_lane)(U_b, state_b)
    size_b = U_b.sum(axis=1)
    deg_b = jnp.where(U_b, degrees[None, :], 0).sum(axis=1)
    use_dense = (size_b + deg_b) > jnp.maximum(1, m // DENSE_THRESHOLD_DENOM)
    return jax.lax.cond(
        use_dense.any(),
        lambda s: jax.vmap(dense_lane)(U_b, s),
        lambda s: jax.vmap(sparse_lane)(U_b, s),
        state_b,
    )


@functools.partial(jax.jit, static_argnames=("dtype",))
def _reduce_msgs(values, src_by_dst, valid_by_dst, dtype=jnp.float32):
    return jnp.where(valid_by_dst, values[src_by_dst], 0.0).astype(dtype)


@functools.partial(jax.jit, static_argnames=("dtype",))
def _reduce_msgs_batch(values_b, src_by_dst, valid_by_dst, dtype=jnp.float32):
    # (B, n) value rows -> (cap, B) dst-major message columns
    return jnp.where(valid_by_dst[None, :], values_b[:, src_by_dst], 0.0).T.astype(dtype)


# ---------------------------------------------------------------------------
# in-trace batched drivers: whole multi-source traversals, ONE dispatch
# ---------------------------------------------------------------------------


def _segsum_rows(msg_b: jax.Array, bounds: jax.Array) -> jax.Array:
    """Row-wise segmented sum over a contiguously-segmented axis:
    (B, cap) messages + int32[S+1] segment bounds -> (B, S) sums.

    cumsum + boundary-difference instead of a scatter: XLA scatters
    serialize per element (they are the batched drivers' bottleneck on
    CPU), while a row cumsum and two gathers vectorize on any backend.
    The pool IS the segmentation: src-major segments are ``g.offsets``,
    dst-major segments are ``aux.dst_offsets``."""
    csum = jnp.cumsum(msg_b, axis=1)
    z = jnp.zeros((msg_b.shape[0], 1), csum.dtype)
    padded = jnp.concatenate([z, csum], axis=1)
    return padded[:, bounds[1:]] - padded[:, bounds[:-1]]


def _segmin_rows(msg_b: jax.Array, bounds: jax.Array) -> jax.Array:
    """Row-wise segmented MIN over a contiguously-segmented axis:
    (B, cap) messages + int32[S+1] segment bounds -> (B, S) minima
    (+inf for empty segments).

    min has no inverse, so the cumsum/boundary-difference trick of
    ``_segsum_rows`` does not apply; instead this is the classic
    *segmented scan*: an ``associative_scan`` over (value, start-flag)
    pairs whose operator resets at segment starts, then one gather of
    each segment's last position.  Still scatter-free and one
    log-depth pass — the (min, +) analogue of the pull rounds'
    row-cumsum, used by ``sssp_batch``."""
    cap = msg_b.shape[1]
    flags = jnp.zeros(cap, dtype=bool).at[bounds[:-1]].set(True, mode="drop")
    flags_b = jnp.broadcast_to(flags, msg_b.shape)

    def op(x, y):
        mx, fx = x
        my, fy = y
        return jnp.where(fy, my, jnp.minimum(mx, my)), fx | fy

    scanned, _ = jax.lax.associative_scan(op, (msg_b, flags_b), axis=1)
    inf = jnp.asarray(jnp.inf, msg_b.dtype)
    ends = jnp.clip(bounds[1:] - 1, 0, cap - 1)
    return jnp.where(bounds[1:] > bounds[:-1], scanned[:, ends], inf)


@functools.partial(jax.jit, static_argnames=("ids_budget", "edge_budget"))
def bfs_batch(
    g: FlatGraph,
    aux: EngineAux,
    sources: jax.Array,  # int32[B], each in [0, n)
    *,
    ids_budget: int,
    edge_budget: int,
) -> Tuple[jax.Array, jax.Array]:
    """Multi-source direction-optimized BFS, fully in-trace.

    Returns ``(parents, depths)`` int32[B, n] (-1 = unreached; a
    source's parent is itself).  The whole frontier loop of all B lanes
    is one ``lax.while_loop`` — one device dispatch, zero per-round
    host syncs.  Per round the batched Beamer rule picks push
    (budget-bounded vmapped expand) or pull; the pull round is the
    (or, and) semiring specialization of the dense direction — a
    segmented row-cumsum over the dst-major pool, no scatter.  Parents
    are assigned in ONE masked scatter-max pass at the end
    (parent(v) = max u with depth(u) = depth(v) - 1 and u->v — exactly
    the per-round max-contention rule of ``_bfs_relax``), instead of a
    cap-sized scatter per round."""
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body
    n = g.offsets.shape[0] - 1
    cap = g.keys.shape[0]
    B = sources.shape[0]
    lane = jnp.arange(B)
    sources = sources.astype(jnp.int32)
    depths = jnp.full((B, n), -1, jnp.int32).at[lane, sources].set(0)
    frontier = jnp.zeros((B, n), bool).at[lane, sources].set(True)
    thresh = jnp.maximum(1, g.m // DENSE_THRESHOLD_DENOM)

    def push(f_b):
        def one(U):
            us, vs, ev, _ = _sparse_expand(g.offsets, g.keys, U, n, ids_budget, edge_budget)
            return jnp.zeros(n, bool).at[jnp.where(ev, vs, n)].max(True, mode="drop")

        return jax.vmap(one)(f_b)

    def pull(f_b):
        msg = (f_b[:, aux.src_by_dst] & aux.valid_by_dst[None, :]).astype(jnp.int32)
        return _segsum_rows(msg, aux.dst_offsets) > 0

    def cond(carry):
        return carry[0].any()

    def body(carry):
        f, dep, d = carry
        size_b = f.sum(axis=1)
        deg_b = jnp.where(f, aux.degrees[None, :], 0).sum(axis=1)
        reached = jax.lax.cond(((size_b + deg_b) > thresh).any(), pull, push, f)
        newly = reached & (dep < 0)
        return newly, jnp.where(newly, d + 1, dep), d + 1

    _, depths, _ = jax.lax.while_loop(cond, body, (frontier, depths, jnp.int32(0)))
    return _parents_pass(g, aux, depths), depths


def _segmax_rows(msg_b: jax.Array, bounds: jax.Array) -> jax.Array:
    """Row-wise segmented MAX over a contiguously-segmented axis:
    (B, cap) messages + int32[S+1] segment bounds -> (B, S) maxima
    (-1 for empty segments).  The (max) twin of ``_segmin_rows`` —
    same segmented associative_scan, no scatter."""
    cap = msg_b.shape[1]
    flags = jnp.zeros(cap, dtype=bool).at[bounds[:-1]].set(True, mode="drop")
    flags_b = jnp.broadcast_to(flags, msg_b.shape)

    def op(x, y):
        mx, fx = x
        my, fy = y
        return jnp.where(fy, my, jnp.maximum(mx, my)), fx | fy

    scanned, _ = jax.lax.associative_scan(op, (msg_b, flags_b), axis=1)
    neg = jnp.asarray(-1, msg_b.dtype)
    ends = jnp.clip(bounds[1:] - 1, 0, cap - 1)
    return jnp.where(bounds[1:] > bounds[:-1], scanned[:, ends], neg)


def _parents_pass(g: FlatGraph, aux: EngineAux, depths: jax.Array) -> jax.Array:
    """Assign BFS parents from final depths in ONE pass: parent(v) =
    max u with depth(u) = depth(v) - 1 and u->v — exactly the
    max-contention rule of the numpy backend.  Computed as a segmented
    max over the dst-major pool (each segment IS one vertex's in-edge
    list), because an XLA scatter-max serializes per element on CPU
    while the segmented scan vectorizes like the pull rounds.  Also the
    jitted ``parents_from_depths`` entry point, so incremental BFS
    (which recomputes depths through the warm ``sssp_batch_from`` path)
    derives parents bit-identical to a full ``bfs_batch``."""
    n = g.offsets.shape[0] - 1
    depths = depths.astype(jnp.int32)
    du = depths[:, aux.src_by_dst]
    dv = depths[:, aux.dst_sorted]  # pad slots (dst_sorted == n) clip; masked
    ok = aux.valid_by_dst[None, :] & (du >= 0) & (dv == du + 1)
    msg = jnp.where(ok, jnp.broadcast_to(aux.src_by_dst[None, :], du.shape), -1)
    cand = _segmax_rows(msg, aux.dst_offsets)
    vid = jnp.arange(n, dtype=jnp.int32)[None, :]
    return jnp.where(depths == 0, vid, jnp.where(depths > 0, cand, -1))


parents_from_depths = jax.jit(_parents_pass)


@functools.partial(jax.jit, static_argnames=("float_dtype",))
def bc_batch(
    g: FlatGraph,
    aux: EngineAux,
    sources: jax.Array,  # int32[B], each in [0, n)
    *,
    float_dtype=jnp.float32,
) -> jax.Array:
    """Multi-source Brandes betweenness contributions, fully in-trace.

    Returns dependency scores float[B, n].  Forward pass: sigma
    accumulates per-round shortest-path counts via the (+, x) segmented
    row-cumsum over the dst-major pool; backward pass walks depths from
    the deepest round down, accumulating dependencies per SOURCE — the
    src-major pool is already the CSR segmentation, so that reduce is
    scatter-free too.  Lanes with shallower BFS trees see empty
    frontiers on the extra rounds (no-ops), which keeps both loops as
    single ``lax.while_loop``s over the whole batch."""
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body
    n = g.offsets.shape[0] - 1
    B = sources.shape[0]
    lane = jnp.arange(B)
    sources = sources.astype(jnp.int32)
    sigma = jnp.zeros((B, n), float_dtype).at[lane, sources].set(1.0)
    depth = jnp.full((B, n), -1, jnp.int32).at[lane, sources].set(0)
    frontier = jnp.zeros((B, n), bool).at[lane, sources].set(True)

    def fcond(carry):
        return carry[0].any()

    def fbody(carry):
        f, sig, dep, d = carry
        w = jnp.where(
            f[:, aux.src_by_dst] & aux.valid_by_dst[None, :],
            sig[:, aux.src_by_dst],
            jnp.zeros((), float_dtype),
        )
        contrib = _segsum_rows(w, aux.dst_offsets)
        newly = (contrib > 0) & (dep < 0)
        sig = sig + jnp.where(newly, contrib, 0)
        return newly, sig, jnp.where(newly, d + 1, dep), d + 1

    _, sigma, depth, d_final = jax.lax.while_loop(
        fcond, fbody, (frontier, sigma, depth, jnp.int32(0))
    )

    du = depth[:, aux.src_c]
    dv = depth[:, aux.dst_c]

    def bcond(carry):
        return carry[1] >= 0

    def bbody(carry):
        dep_acc, dd = carry
        ok = aux.evalid[None, :] & (du == dd) & (dv == dd + 1)
        ratio = sigma[:, aux.src_c] / jnp.maximum(sigma[:, aux.dst_c], 1e-30)
        contrib = jnp.where(ok, ratio * (1.0 + dep_acc[:, aux.dst_c]), 0)
        return dep_acc + _segsum_rows(contrib, g.offsets), dd - 1

    dep, _ = jax.lax.while_loop(
        bcond, bbody, (jnp.zeros((B, n), float_dtype), d_final - 2)
    )
    return dep.at[lane, sources].set(0.0)


def _bellman_ford(
    g: FlatGraph,
    aux: EngineAux,
    dist: jax.Array,  # float[B, n] initial distances (+inf = unknown)
    frontier: jax.Array,  # bool[B, n] initial relax frontier
    *,
    ids_budget: int,
    edge_budget: int,
    float_dtype=jnp.float32,
    unit: bool = False,
) -> jax.Array:
    """The (min, +) relaxation loop shared by ``sssp_batch`` (point
    sources) and ``sssp_batch_from`` (warm start from a previous
    version's distances): one ``lax.while_loop`` to fixpoint from
    whatever (dist, frontier) it is seeded with.  ``unit=True`` forces
    unit weights — the hop metric on a weighted pool, which is how
    incremental BFS rides this driver."""
    n = g.offsets.shape[0] - 1
    cap = g.keys.shape[0]
    inf = jnp.asarray(jnp.inf, float_dtype)
    w_pool = (
        jnp.ones(cap, float_dtype)
        if (unit or g.weights is None)
        else g.weights.astype(float_dtype)
    )
    w_by_dst = (
        jnp.ones(cap, float_dtype)
        if (unit or aux.w_by_dst is None)
        else aux.w_by_dst.astype(float_dtype)
    )
    thresh = jnp.maximum(1, g.m // DENSE_THRESHOLD_DENOM)

    def push(args):
        f_b, d_b = args

        def one(U, d):
            us, vs, ev, eidx = _sparse_expand(
                g.offsets, g.keys, U, n, ids_budget, edge_budget
            )
            vals = d[us] + w_pool[eidx]
            return (
                jnp.full(n, inf, float_dtype)
                .at[jnp.where(ev, vs, n)]
                .min(vals, mode="drop")
            )

        return jax.vmap(one)(f_b, d_b)

    def pull(args):
        f_b, d_b = args
        msg = jnp.where(
            f_b[:, aux.src_by_dst] & aux.valid_by_dst[None, :],
            d_b[:, aux.src_by_dst] + w_by_dst[None, :],
            inf,
        )
        return _segmin_rows(msg, aux.dst_offsets)

    def cond(carry):
        return carry[0].any()

    def body(carry):
        f, d = carry
        size_b = f.sum(axis=1)
        deg_b = jnp.where(f, aux.degrees[None, :], 0).sum(axis=1)
        cand = jax.lax.cond(((size_b + deg_b) > thresh).any(), pull, push, (f, d))
        newly = cand < d
        return newly, jnp.where(newly, cand, d)

    _, dist = jax.lax.while_loop(cond, body, (frontier, dist))
    return dist


@functools.partial(
    jax.jit, static_argnames=("ids_budget", "edge_budget", "float_dtype")
)
def sssp_batch(
    g: FlatGraph,
    aux: EngineAux,
    sources: jax.Array,  # int32[B], each in [0, n)
    *,
    ids_budget: int,
    edge_budget: int,
    float_dtype=jnp.float32,
) -> jax.Array:
    """Multi-source Bellman–Ford over the weighted (min, +) semiring,
    fully in-trace: returns distances float[B, n] (+inf = unreached).

    The whole frontier loop (frontier = vertices whose distance
    improved last round) of all B lanes is one ``lax.while_loop`` —
    one device dispatch, zero per-round host syncs, exactly the
    ``bfs_batch`` contract.  Per round the batched Beamer rule picks
    push (budget-bounded vmapped expand + masked scatter-min) or pull;
    the pull round is the (min, +) semiring specialization of the
    dense direction — a segmented row-MIN scan over the dst-major pool
    (``_segmin_rows``), the weighted analogue of the BFS pull's
    row-cumsum.  An unweighted graph runs the same driver with unit
    weights (hop distances), so ``sssp_batch`` never changes what an
    unweighted stream compiles for BFS/BC/PageRank.
    """
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body
    n = g.offsets.shape[0] - 1
    B = sources.shape[0]
    lane = jnp.arange(B)
    sources = sources.astype(jnp.int32)
    inf = jnp.asarray(jnp.inf, float_dtype)
    dist = jnp.full((B, n), inf, float_dtype).at[lane, sources].set(0.0)
    frontier = jnp.zeros((B, n), bool).at[lane, sources].set(True)
    return _bellman_ford(
        g,
        aux,
        dist,
        frontier,
        ids_budget=ids_budget,
        edge_budget=edge_budget,
        float_dtype=float_dtype,
    )


@functools.partial(
    jax.jit,
    static_argnames=("ids_budget", "edge_budget", "float_dtype", "unit"),
)
def sssp_batch_from(
    g: FlatGraph,
    aux: EngineAux,
    dist0: jax.Array,  # float[B, n] (+inf = unknown/unreached)
    frontier0: jax.Array,  # bool[B, n] initial relax frontier
    *,
    ids_budget: int,
    edge_budget: int,
    float_dtype=jnp.float32,
    unit: bool = False,
) -> jax.Array:
    """``sssp_batch`` seeded from ARBITRARY initial state instead of
    point sources — the warm-start entry point of the incremental
    BFS/SSSP path (``traversal.algorithms.warm_distances``): the
    previous version's still-valid distances come in as ``dist0``, the
    clean reached set as ``frontier0``, and the same in-trace loop
    relaxes only what the update batch can have changed.  ``unit=True``
    runs the hop metric (incremental BFS) on a weighted pool."""
    TRACES.bump()  # trace-time only: a jit cache hit never runs this body
    return _bellman_ford(
        g,
        aux,
        dist0.astype(float_dtype),
        frontier0,
        ids_budget=ids_budget,
        edge_budget=edge_budget,
        float_dtype=float_dtype,
        unit=unit,
    )


class JaxEngine(TraversalEngine):
    """Engine over an (immutable) ``FlatGraph`` snapshot."""

    def __init__(
        self,
        g: FlatGraph,
        aux: Optional[EngineAux] = None,
        float_dtype=None,
    ):
        self.g = g
        self._n = g.n
        self._m = int(g.m)
        cap = g.edge_capacity
        # explicit compute dtype (float32 default — see the module
        # docstring's precision contract)
        self.ops = JAX_OPS if float_dtype is None else JaxOps(float_dtype)

        # all per-snapshot derived state is one jit call (device-resident;
        # no host loops / argsort) — or passed in, pre-refreshed, by a
        # version-pinned caller (AspenStream's engine cache).
        self.aux = engine_aux(g) if aux is None else aux
        self._src_c = self.aux.src_c
        self._dst_c = self.aux.dst_c
        self._evalid = self.aux.evalid
        self._degrees = self.aux.degrees
        self._dst_sorted = self.aux.dst_sorted
        self._src_by_dst = self.aux.src_by_dst
        self._valid_by_dst = self.aux.valid_by_dst
        self._dst_offsets = self.aux.dst_offsets
        self._w_by_dst = self.aux.w_by_dst  # None on unweighted graphs
        self._wdeg = None  # lazy weighted out-degree cache

        # static sparse budgets: a frontier routed sparse obeys
        # |U| + deg(U) <= m/20 <= cap/20, so cap-derived budgets bound
        # any runtime threshold.  Forced-sparse mode needs full budgets.
        self._auto_ids_budget = min(self._n, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._auto_edge_budget = min(cap, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._full_ids_budget = self._n
        self._full_edge_budget = max(cap, 1)

    # -- graph shape --------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        return self._m

    @property
    def degrees(self) -> jax.Array:
        return self._degrees

    @property
    def weights(self) -> Optional[jax.Array]:
        """The pool-parallel per-edge value array (float32[cap]), or
        None on unweighted graphs."""
        return self.g.weights

    @property
    def weighted_degrees(self) -> jax.Array:
        """Sum of out-edge weights per vertex.  The src-major pool is
        its own CSR segmentation, so this is one scatter-free segmented
        row-cumsum over ``g.offsets`` (cached per engine)."""
        if self.g.weights is None:
            return self._degrees.astype(self.ops.float_dtype)
        if self._wdeg is None:
            msg = jnp.where(
                self._evalid, self.g.weights.astype(self.ops.float_dtype), 0.0
            )
            self._wdeg = _segsum_rows(msg[None, :], self.g.offsets)[0]
        return self._wdeg

    @property
    def resident_nbytes(self) -> int:
        """Device bytes held per snapshot: raw pool + ``EngineAux`` (the
        BYTES bench's baseline numerator)."""
        return cz.pytree_nbytes(self.g) + cz.pytree_nbytes(self.aux)

    # -- frontiers ----------------------------------------------------------
    def frontier_from_ids(self, ids) -> JaxVertexSubset:
        mask = jnp.zeros(self._n, dtype=bool).at[jnp.asarray(ids)].set(True)
        return JaxVertexSubset(mask)

    def frontier_from_dense(self, mask) -> JaxVertexSubset:
        return JaxVertexSubset(jnp.asarray(mask, dtype=bool))

    def _budgets(self, mode: str) -> Tuple[int, int]:
        if mode == "sparse":
            return self._full_ids_budget, self._full_edge_budget
        return self._auto_ids_budget, self._auto_edge_budget

    # -- edgeMap ------------------------------------------------------------
    def edge_map(
        self,
        U: JaxVertexSubset,
        F: Callable,
        C: Callable,
        state,
        direction_optimize: bool = True,
        mode: str = "auto",
    ) -> Tuple[JaxVertexSubset, object]:
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        ids_b, edge_b = self._budgets(mode)
        state, out = _edge_map_step(
            self.g.offsets,
            self.g.keys,
            self._src_c,
            self._dst_c,
            self._evalid,
            self._degrees,
            self.g.m,
            self.g.weights,
            U.dense,
            state,
            F=F,
            C=C,
            mode=mode,
            n=self._n,
            ids_budget=ids_b,
            edge_budget=edge_b,
            ops=self.ops,
        )
        return JaxVertexSubset(out), state

    def edge_map_batch(
        self,
        U_b,  # bool[B, n] frontier batch
        F: Callable,
        C: Callable,
        state_b,  # pytree with (B, ...) leaves
        direction_optimize: bool = True,
        mode: str = "auto",
    ):
        """One edgeMap round for B independent frontier lanes: returns
        ``(out_b, state_b')`` where ``out_b`` is the bool[B, n] next
        frontier batch.  Frontiers and state are raw batched arrays
        (not VertexSubsets): batched callers thread them through
        in-trace loops and sync once at the end."""
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        ids_b, edge_b = self._budgets(mode)
        state_b, out = _edge_map_step_batch(
            self.g.offsets,
            self.g.keys,
            self._src_c,
            self._dst_c,
            self._evalid,
            self._degrees,
            self.g.m,
            self.g.weights,
            jnp.asarray(U_b, dtype=bool),
            state_b,
            F=F,
            C=C,
            mode=mode,
            n=self._n,
            ids_budget=ids_b,
            edge_budget=edge_b,
            ops=self.ops,
        )
        return out, state_b

    # -- in-trace batched drivers ------------------------------------------
    @staticmethod
    def _quantized_sources(sources) -> Tuple[jax.Array, int]:
        """Pad a source batch to power-of-two length (duplicating the
        first source into the pad lanes, whose rows the caller slices
        off) so a serving path with varying batch sizes shares
        O(log max_B) jit traces instead of recompiling the whole
        while_loop driver per distinct B — the same quantization the
        streaming write path applies to update batches."""
        sources = np.asarray(sources).reshape(-1)
        B = sources.size
        pad = max(1, int(2 ** np.ceil(np.log2(max(B, 1)))))
        padded = np.full(pad, sources[0] if B else 0, dtype=np.int32)
        padded[:B] = sources
        return jnp.asarray(padded), B

    def bfs_batch(self, sources) -> Tuple[jax.Array, jax.Array]:
        """(parents, depths) int32[B, n]; ONE dispatch for the whole
        multi-source traversal (see module-level ``bfs_batch``)."""
        padded, B = self._quantized_sources(sources)
        parents, depths = bfs_batch(
            self.g,
            self.aux,
            padded,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
        )
        return parents[:B], depths[:B]

    def bc_batch(self, sources) -> jax.Array:
        """Dependency scores float[B, n]; ONE dispatch per phase (see
        module-level ``bc_batch``)."""
        padded, B = self._quantized_sources(sources)
        return bc_batch(
            self.g, self.aux, padded, float_dtype=self.ops.float_dtype
        )[:B]

    def sssp_batch(self, sources) -> jax.Array:
        """Shortest-path distances float[B, n] (+inf = unreached); ONE
        dispatch for the whole multi-source Bellman–Ford (see
        module-level ``sssp_batch``)."""
        padded, B = self._quantized_sources(sources)
        return sssp_batch(
            self.g,
            self.aux,
            padded,
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            float_dtype=self.ops.float_dtype,
        )[:B]

    @staticmethod
    def _quantized_state(dist0, frontier0):
        """Row-pad warm-start state to power-of-two B (inf distances,
        empty frontiers: pad lanes are fixpoints the loop never
        touches) — the state analogue of ``_quantized_sources``."""
        dist0 = np.asarray(dist0, np.float64)
        frontier0 = np.asarray(frontier0, bool)
        B, n = dist0.shape
        pad = max(1, int(2 ** np.ceil(np.log2(max(B, 1)))))
        if pad != B:
            dist0 = np.concatenate([dist0, np.full((pad - B, n), np.inf)])
            frontier0 = np.concatenate(
                [frontier0, np.zeros((pad - B, n), bool)]
            )
        return dist0, frontier0, B

    def sssp_batch_from(self, dist0, frontier0, unit: bool = False) -> jax.Array:
        """Warm-start (min, +) relaxation from arbitrary initial state
        (see module-level ``sssp_batch_from``) — the incremental
        BFS/SSSP driver."""
        dist0, frontier0, B = self._quantized_state(dist0, frontier0)
        return sssp_batch_from(
            self.g,
            self.aux,
            jnp.asarray(dist0, self.ops.float_dtype),
            jnp.asarray(frontier0),
            ids_budget=self._auto_ids_budget,
            edge_budget=self._auto_edge_budget,
            float_dtype=self.ops.float_dtype,
            unit=unit,
        )[:B]

    def parents_from_depths(self, depths) -> jax.Array:
        """BFS parents from depth rows via the driver's one-pass
        scatter-max rule (see ``_parents_pass``)."""
        return parents_from_depths(
            self.g, self.aux, jnp.asarray(np.asarray(depths, np.int32))
        )

    def cc_labels(self) -> jax.Array:
        """Whole-graph min-label CC, fully in-trace over the prebuilt
        aux (the unified entry point for the jit fixpoint loop)."""
        return cc_labels(self.g, aux=self.aux)

    # -- dense semiring reduce (Pallas segment-sum) -------------------------
    # Weighted graphs dispatch the WEIGHTED kernel (out[v] = sum w(u,v)
    # * values[u], the per-edge weight multiplied on the MXU inside the
    # one-hot matmul); unweighted graphs compile exactly the pre-v2
    # trace — no value array is read, no weighted kernel is built.
    def edge_map_reduce(self, values: jax.Array) -> jax.Array:
        msg = _reduce_msgs(
            values, self._src_by_dst, self._valid_by_dst, dtype=self.ops.float_dtype
        )
        if self._w_by_dst is None:
            out = kops.segment_sum(self._dst_sorted, msg[:, None], self._n)
        else:
            out = kops.segment_sum_weighted(
                self._dst_sorted, self._w_by_dst, msg[:, None], self._n
            )
        return out[:, 0].astype(values.dtype)

    def edge_map_reduce_batch(self, values: jax.Array) -> jax.Array:
        """(B, n) value rows through ONE Pallas segment-sum call: the
        kernel's message feature dim carries the B query lanes."""
        msg = _reduce_msgs_batch(
            values, self._src_by_dst, self._valid_by_dst, dtype=self.ops.float_dtype
        )
        if self._w_by_dst is None:
            out = kops.segment_sum(self._dst_sorted, msg, self._n)
        else:
            out = kops.segment_sum_weighted(
                self._dst_sorted, self._w_by_dst, msg, self._n
            )
        return out.T.astype(values.dtype)

    # -- vertexMap ----------------------------------------------------------
    def vertex_map(self, U: JaxVertexSubset, P: Callable, state) -> JaxVertexSubset:
        keep = P(self.ops, state, jnp.arange(self._n, dtype=jnp.int32))
        return JaxVertexSubset(U.dense & keep)

    def to_host(self, x) -> np.ndarray:
        HOST_SYNCS.bump()
        return np.asarray(x)


# ---------------------------------------------------------------------------
# whole-graph jit traversals (single compiled step, no host round-trips) —
# the device-side counterparts of algorithms.py, used where the entire
# frontier loop must live inside one trace (launch cells, sharded pool).
# All accept a prebuilt ``EngineAux`` (version-pinned, from the stream's
# mirror cache) so repeated calls stop re-deriving the endpoint clipping.
# ---------------------------------------------------------------------------


def _ensure_flat(g):
    """Trace-time dispatch for chunked operands: the whole-graph loops
    accept a ``CompressedPool`` wherever they accept a ``FlatGraph``; the
    decode happens once inside the same trace (jit re-specializes per
    input pytree structure, so the raw path compiles exactly as before)."""
    return _fg.decompress(g) if isinstance(g, CompressedPool) else g


def _endpoints(g: FlatGraph, aux):
    if isinstance(aux, EngineAux):
        return aux.src_c, aux.dst_c, aux.evalid
    return _pool_endpoints(g)


@jax.jit
def dense_expand(g, frontier: jax.Array, aux: Optional[EngineAux] = None) -> jax.Array:
    """One dense edgeMap expansion: bool[n] frontier -> bool[n] reached.

    Every pool slot looks up whether its source is in the frontier; a
    segment-or over destinations (one gather + one masked scatter).
    ``g`` may be a ``CompressedPool`` (chunked operand): the dst decode
    fuses into this trace."""
    g = _ensure_flat(g)
    src_c, dst_c, evalid = _endpoints(g, aux)
    n = g.offsets.shape[0] - 1
    msg = frontier[src_c] & evalid
    return jnp.zeros(n, dtype=bool).at[dst_c].max(msg, mode="drop")


@jax.jit
def bfs_levels(g, source: jax.Array, aux: Optional[EngineAux] = None) -> jax.Array:
    """Full BFS levels via lax.while_loop (fixed-shape iterations).
    Accepts a ``CompressedPool`` (decode fused into the trace)."""
    g = _ensure_flat(g)
    endpoints = _endpoints(g, aux)
    n = g.offsets.shape[0] - 1
    levels = jnp.full(n, jnp.int32(-1))
    levels = levels.at[source].set(0)
    frontier = jnp.zeros(n, dtype=bool).at[source].set(True)

    def cond(state):
        frontier, levels, d = state
        return frontier.any()

    def body(state):
        frontier, levels, d = state
        src_c, dst_c, evalid = endpoints
        msg = frontier[src_c] & evalid
        nxt = jnp.zeros(n, dtype=bool).at[dst_c].max(msg, mode="drop")
        nxt = nxt & (levels < 0)
        levels = jnp.where(nxt, d + 1, levels)
        return nxt, levels, d + 1

    _, levels, _ = jax.lax.while_loop(cond, body, (frontier, levels, jnp.int32(0)))
    return levels


@jax.jit
def cc_labels(g, aux: Optional[EngineAux] = None) -> jax.Array:
    """Min-label propagation to fixpoint (jit while_loop).
    Accepts a ``CompressedPool`` (decode fused into the trace)."""
    g = _ensure_flat(g)
    src_c, dst_c, evalid = _endpoints(g, aux)
    n = g.offsets.shape[0] - 1
    labels0 = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        labels, changed = state
        return changed

    def body(state):
        labels, _ = state
        msg = jnp.where(evalid, labels[src_c], jnp.int32(np.iinfo(np.int32).max))
        new = labels.at[dst_c].min(msg, mode="drop")
        return new, (new != labels).any()

    labels, _ = jax.lax.while_loop(cond, body, (labels0, jnp.bool_(True)))
    return labels


# ---------------------------------------------------------------------------
# compressed engine: queries served from a chunk-compressed resident pool
# ---------------------------------------------------------------------------


class CompressedAux(NamedTuple):
    """Per-snapshot derived state for ``CompressedEngine`` — the
    compressed counterpart of ``EngineAux``.

    The two O(cap) int lanes of ``EngineAux`` (``dst_sorted``,
    ``src_by_dst``) are themselves chunk-compressed: ``dst_sorted`` is
    ascending (ideal delta profile), ``src_by_dst`` is ascending within
    each dst segment.  The O(n) arrays (degrees, segment bounds) and the
    float value lane stay raw — they are small, respectively not
    delta-friendly.  ``valid_by_dst`` collapses to one scalar: valid
    slots are exactly the sorted prefix ``[:m_valid]``.
    """

    dst_sorted_c: cz.ChunkedStream  # destinations ascending (pad = n)
    srcbd_c: cz.ChunkedStream  # sources permuted dst-major
    dst_offsets: jax.Array  # int32[n+1] segment bounds into dst_sorted
    degrees: jax.Array  # int32[n]
    m_valid: jax.Array  # int32 scalar: count of valid pool slots
    w_by_dst: Optional[jax.Array] = None  # float32[capC] values dst-major


@functools.partial(jax.jit, static_argnames=("aux_hi_cap",))
def engine_aux_compressed(
    cg: CompressedPool, aux_hi_cap: Optional[int] = None
) -> CompressedAux:
    """One jit: decompress -> ``engine_aux`` -> re-compress the big int
    lanes.  The uncompressed aux is a transient of this trace; resident
    state is the compressed pytree.  Lane width / escape capacity are
    inherited from the pool stream (static via dtypes/shapes): an
    adaptive pool gets adaptive aux lanes, with hi capacity inherited
    from the pool's plane unless ``aux_hi_cap`` overrides it (the engine
    retries at full capacity when only the aux lanes overflow — the aux
    permutations need not share the pool's wide-chunk profile)."""
    g = _fg.decompress(cg)
    aux = engine_aux(g)
    k = cg.dst.k
    if cg.dst.hi is not None:
        hi_cap = cg.dst.hi.shape[-2] if aux_hi_cap is None else aux_hi_cap
        dst_sorted_c = cz.encode_stream_adaptive(aux.dst_sorted, hi_cap=hi_cap, k=k)
        srcbd_c = cz.encode_stream_adaptive(aux.src_by_dst, hi_cap=hi_cap, k=k)
    else:
        width = cg.dst.width
        dst_sorted_c = cz.encode_stream(aux.dst_sorted, width=width, k=k)
        srcbd_c = cz.encode_stream(aux.src_by_dst, width=width, k=k)
    w = aux.w_by_dst
    if w is not None and dst_sorted_c.length > w.shape[0]:
        w = jnp.pad(w, (0, dst_sorted_c.length - w.shape[0]))
    return CompressedAux(
        dst_sorted_c=dst_sorted_c,
        srcbd_c=srcbd_c,
        dst_offsets=aux.dst_offsets,
        degrees=aux.degrees,
        m_valid=aux.evalid.sum().astype(jnp.int32),
        w_by_dst=w,
    )


def _inflate(cg: CompressedPool, caux: CompressedAux):
    """Trace-level inflate: (CompressedPool, CompressedAux) ->
    (FlatGraph, EngineAux) inside the caller's jit.  Every compressed
    query step is `inflate + the existing module-level step` in ONE
    trace: decoded arrays are transients XLA fuses into their consumers,
    the resident state stays compressed, and the raw steps' compiled
    logic is reused verbatim rather than forked."""
    g = _fg.decompress(cg)
    cap = g.edge_capacity
    src_c, dst_c, evalid = _pool_endpoints(g)
    dst_sorted = cz.decode_stream(caux.dst_sorted_c, cap)
    src_by_dst = cz.decode_stream(caux.srcbd_c, cap)
    valid_by_dst = jnp.arange(cap) < caux.m_valid
    w_by_dst = None if caux.w_by_dst is None else caux.w_by_dst[:cap]
    aux = EngineAux(
        src_c=src_c,
        dst_c=dst_c,
        evalid=evalid,
        degrees=caux.degrees,
        dst_sorted=dst_sorted,
        src_by_dst=src_by_dst,
        valid_by_dst=valid_by_dst,
        dst_offsets=caux.dst_offsets,
        w_by_dst=w_by_dst,
    )
    return g, aux


@functools.partial(
    jax.jit,
    static_argnames=("F", "C", "mode", "n", "ids_budget", "edge_budget", "ops"),
)
def _edge_map_step_compressed(cg, caux, U, state, *, F, C, mode, n, ids_budget, edge_budget, ops=JAX_OPS):
    g, aux = _inflate(cg, caux)
    return _edge_map_step(
        g.offsets, g.keys, aux.src_c, aux.dst_c, aux.evalid, aux.degrees,
        g.m, g.weights, U, state,
        F=F, C=C, mode=mode, n=n,
        ids_budget=ids_budget, edge_budget=edge_budget, ops=ops,
    )


@functools.partial(
    jax.jit,
    static_argnames=("F", "C", "mode", "n", "ids_budget", "edge_budget", "ops"),
)
def _edge_map_step_batch_compressed(cg, caux, U_b, state_b, *, F, C, mode, n, ids_budget, edge_budget, ops=JAX_OPS):
    g, aux = _inflate(cg, caux)
    return _edge_map_step_batch(
        g.offsets, g.keys, aux.src_c, aux.dst_c, aux.evalid, aux.degrees,
        g.m, g.weights, U_b, state_b,
        F=F, C=C, mode=mode, n=n,
        ids_budget=ids_budget, edge_budget=edge_budget, ops=ops,
    )


@functools.partial(jax.jit, static_argnames=("ids_budget", "edge_budget"))
def bfs_batch_compressed(cg, caux, sources, *, ids_budget, edge_budget):
    g, aux = _inflate(cg, caux)
    return bfs_batch(g, aux, sources, ids_budget=ids_budget, edge_budget=edge_budget)


@functools.partial(jax.jit, static_argnames=("float_dtype",))
def bc_batch_compressed(cg, caux, sources, *, float_dtype=jnp.float32):
    g, aux = _inflate(cg, caux)
    return bc_batch(g, aux, sources, float_dtype=float_dtype)


@functools.partial(jax.jit, static_argnames=("ids_budget", "edge_budget", "float_dtype"))
def sssp_batch_compressed(cg, caux, sources, *, ids_budget, edge_budget, float_dtype=jnp.float32):
    g, aux = _inflate(cg, caux)
    return sssp_batch(
        g, aux, sources,
        ids_budget=ids_budget, edge_budget=edge_budget, float_dtype=float_dtype,
    )


@functools.partial(
    jax.jit, static_argnames=("ids_budget", "edge_budget", "float_dtype", "unit")
)
def sssp_batch_from_compressed(
    cg, caux, dist0, frontier0, *, ids_budget, edge_budget,
    float_dtype=jnp.float32, unit=False,
):
    g, aux = _inflate(cg, caux)
    return sssp_batch_from(
        g, aux, dist0, frontier0,
        ids_budget=ids_budget, edge_budget=edge_budget,
        float_dtype=float_dtype, unit=unit,
    )


@jax.jit
def parents_from_depths_compressed(cg, caux, depths):
    g, aux = _inflate(cg, caux)
    return _parents_pass(g, aux, depths)


@functools.partial(jax.jit, static_argnames=("n", "dtype"))
def _edge_map_reduce_compressed(caux: CompressedAux, values_b, *, n, dtype):
    """The (+, x) semiring reduce on fully compressed operands — the one
    path where decode runs INSIDE the Pallas kernel itself: the chunked
    ``dst_sorted`` lane feeds ``segment_sum_*_chunked`` undecoded and the
    kernel's prologue decodes each tile next to the one-hot matmul.  The
    src gather lane still decodes in-trace (a gather needs materialized
    indices), fused by XLA with the message build."""
    src_by_dst = cz.decode_stream(caux.srcbd_c)  # int32[capC]
    valid = jnp.arange(src_by_dst.shape[0]) < caux.m_valid
    msg = jnp.where(valid[None, :], values_b[:, src_by_dst], 0.0).T.astype(dtype)
    s = caux.dst_sorted_c
    if caux.w_by_dst is None:
        return kops.segment_sum_chunked(
            s.anchors, s.deltas, s.ovf_pos, s.ovf_add, msg, n, hi=s.hi, wide=s.wide
        )
    return kops.segment_sum_weighted_chunked(
        s.anchors, s.deltas, s.ovf_pos, s.ovf_add, caux.w_by_dst, msg, n,
        hi=s.hi, wide=s.wide,
    )


@functools.partial(jax.jit, static_argnames=("dtype",))
def _weighted_degrees_compressed(cg: CompressedPool, *, dtype=jnp.float32):
    g = _fg.decompress(cg)
    _, _, evalid = _pool_endpoints(g)
    msg = jnp.where(evalid, g.weights.astype(dtype), 0.0)
    return _segsum_rows(msg[None, :], g.offsets)[0]


class CompressedEngine(JaxEngine):
    """``JaxEngine`` served from a chunk-compressed resident snapshot.

    Holds a ``CompressedPool`` + ``CompressedAux`` instead of the raw
    pool + ``EngineAux`` — the HBM-resident state is the compressed
    layout, and every query dispatches a jit whose prologue inflates (or,
    for ``edge_map_reduce``, a Pallas kernel that decodes in-tile).  The
    method surface, budgets, frontier helpers and batched-driver
    quantization are inherited; only the dispatch targets differ.
    """

    def __init__(
        self,
        cg: CompressedPool,
        aux: Optional[CompressedAux] = None,
        float_dtype=None,
    ):
        self.cg = cg
        self._n = cg.n
        self._m = int(cg.m)
        cap = cg.edge_capacity
        self.ops = JAX_OPS if float_dtype is None else JaxOps(float_dtype)
        self.caux = engine_aux_compressed(cg) if aux is None else aux
        self._degrees = self.caux.degrees
        self._wdeg = None
        # Aux spill check: engine construction already syncs (int(cg.m)
        # above), so reading three flag bytes here is free — and a
        # spilled aux stream would silently mis-decode every query.
        pool_spilled = bool(np.asarray(cg.dst.spill))
        aux_spilled = bool(np.asarray(self.caux.dst_sorted_c.spill)) or bool(
            np.asarray(self.caux.srcbd_c.spill)
        )
        if not pool_spilled and aux_spilled and aux is None and cg.dst.hi is not None:
            # Adaptive aux lanes inherited the pool's (exact-fit) hi
            # capacity but need more wide chunks than the pool did —
            # retry once at full capacity before declaring a genuine
            # escape-lane spill.
            R = cg.dst.deltas.shape[-2]
            self.caux = engine_aux_compressed(cg, aux_hi_cap=R)
            self._degrees = self.caux.degrees
            aux_spilled = bool(np.asarray(self.caux.dst_sorted_c.spill)) or bool(
                np.asarray(self.caux.srcbd_c.spill)
            )
        if pool_spilled or aux_spilled:
            raise ValueError(
                "compressed stream spilled its escape lane; rebuild the "
                "snapshot with a wider delta lane or keep the raw engine"
            )
        self._auto_ids_budget = min(self._n, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._auto_edge_budget = min(cap, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._full_ids_budget = self._n
        self._full_edge_budget = max(cap, 1)

    @property
    def weights(self) -> Optional[jax.Array]:
        return self.cg.weights

    @property
    def weighted_degrees(self) -> jax.Array:
        if self.cg.weights is None:
            return self._degrees.astype(self.ops.float_dtype)
        if self._wdeg is None:
            self._wdeg = _weighted_degrees_compressed(
                self.cg, dtype=self.ops.float_dtype
            )
        return self._wdeg

    @property
    def resident_nbytes(self) -> int:
        """Device bytes held per snapshot: compressed pool + compressed
        aux (the BYTES bench's numerator for this engine)."""
        return cz.pytree_nbytes(self.cg) + cz.pytree_nbytes(self.caux)

    def edge_map(self, U, F, C, state, direction_optimize=True, mode="auto"):
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        ids_b, edge_b = self._budgets(mode)
        state, out = _edge_map_step_compressed(
            self.cg, self.caux, U.dense, state,
            F=F, C=C, mode=mode, n=self._n,
            ids_budget=ids_b, edge_budget=edge_b, ops=self.ops,
        )
        return JaxVertexSubset(out), state

    def edge_map_batch(self, U_b, F, C, state_b, direction_optimize=True, mode="auto"):
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        ids_b, edge_b = self._budgets(mode)
        state_b, out = _edge_map_step_batch_compressed(
            self.cg, self.caux, jnp.asarray(U_b, dtype=bool), state_b,
            F=F, C=C, mode=mode, n=self._n,
            ids_budget=ids_b, edge_budget=edge_b, ops=self.ops,
        )
        return out, state_b

    def bfs_batch(self, sources):
        padded, B = self._quantized_sources(sources)
        parents, depths = bfs_batch_compressed(
            self.cg, self.caux, padded,
            ids_budget=self._auto_ids_budget, edge_budget=self._auto_edge_budget,
        )
        return parents[:B], depths[:B]

    def bc_batch(self, sources):
        padded, B = self._quantized_sources(sources)
        return bc_batch_compressed(
            self.cg, self.caux, padded, float_dtype=self.ops.float_dtype
        )[:B]

    def sssp_batch(self, sources):
        padded, B = self._quantized_sources(sources)
        return sssp_batch_compressed(
            self.cg, self.caux, padded,
            ids_budget=self._auto_ids_budget, edge_budget=self._auto_edge_budget,
            float_dtype=self.ops.float_dtype,
        )[:B]

    def sssp_batch_from(self, dist0, frontier0, unit: bool = False):
        dist0, frontier0, B = self._quantized_state(dist0, frontier0)
        return sssp_batch_from_compressed(
            self.cg, self.caux,
            jnp.asarray(dist0, self.ops.float_dtype), jnp.asarray(frontier0),
            ids_budget=self._auto_ids_budget, edge_budget=self._auto_edge_budget,
            float_dtype=self.ops.float_dtype, unit=unit,
        )[:B]

    def parents_from_depths(self, depths):
        return parents_from_depths_compressed(
            self.cg, self.caux, jnp.asarray(np.asarray(depths, np.int32))
        )

    def cc_labels(self) -> jax.Array:
        return cc_labels(self.cg)

    def edge_map_reduce(self, values: jax.Array) -> jax.Array:
        out = _edge_map_reduce_compressed(
            self.caux, values[None, :], n=self._n, dtype=self.ops.float_dtype
        )
        return out[:, 0].astype(values.dtype)

    def edge_map_reduce_batch(self, values: jax.Array) -> jax.Array:
        out = _edge_map_reduce_compressed(
            self.caux, values, n=self._n, dtype=self.ops.float_dtype
        )
        return out.T.astype(values.dtype)
