"""JAX/TPU traversal backend over ``FlatGraph`` (the packed-key pool).

Maps Ligra's edgeMap onto the flat C-tree pool (flat_graph.py):

  * dense ("pull"/whole-pool) direction: every pool slot looks up
    whether its source is in the frontier — one gather + one masked
    scatter, the same shape as GNN aggregation.  The (+, x) semiring
    specialization ``edge_map_reduce`` (PageRank's inner loop) lowers
    to the Pallas one-hot-matmul segment sum in
    ``repro.kernels.segment_reduce`` via ``repro.kernels.ops`` (so it
    runs compiled on TPU and interpret-mode on CPU).

  * sparse ("push") direction: the frontier's adjacency lists are
    contiguous key ranges of the sorted pool, so expansion is a
    fixed-shape ragged gather: nonzero(size=K) frontier ids ->
    searchsorted over per-id degree prefix sums -> pool indices.  No
    dynamic shapes, so the whole push/pull step jits once per
    (F, C, mode) and is reused across iterations and engines.

Direction optimization (|U| + deg(U) > m/20, paper §5.1) runs inside
the jit step as a ``lax.cond``, so one compiled step serves both
directions; the sparse branch's static budgets are sized from the
threshold (a frontier routed sparse can never exceed cap/20 ids or
pool-capacity/20 edges).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

from ..flat_graph import FlatGraph
from .base import DENSE_THRESHOLD_DENOM, ArrayOps, TraversalEngine


class JaxOps(ArrayOps):
    xp = jnp
    int_dtype = jnp.int32
    float_dtype = jnp.float64

    def set_at(self, arr, idx, vals):
        return arr.at[idx].set(vals)

    def _safe_idx(self, target, idx, mask):
        # OOB indices are dropped by mode="drop": masking = index escape
        return jnp.where(mask, idx, target.shape[0])

    def scatter_max(self, target, idx, vals, mask):
        return target.at[self._safe_idx(target, idx, mask)].max(vals, mode="drop")

    def scatter_min(self, target, idx, vals, mask):
        return target.at[self._safe_idx(target, idx, mask)].min(vals, mode="drop")

    def scatter_add(self, target, idx, vals, mask):
        vals = jnp.where(mask, vals, jnp.zeros((), target.dtype))
        return target.at[self._safe_idx(target, idx, mask)].add(vals, mode="drop")

    def scatter_or(self, target, idx, mask):
        return target.at[self._safe_idx(target, idx, mask)].max(True, mode="drop")


JAX_OPS = JaxOps()


class JaxVertexSubset(NamedTuple):
    dense: jax.Array  # bool[n]

    @property
    def n(self) -> int:
        return self.dense.shape[0]

    @property
    def size(self) -> int:
        return int(self.dense.sum())  # host sync: python-level loop control

    @property
    def empty(self) -> bool:
        return self.size == 0

    def to_dense(self) -> jax.Array:
        return self.dense

    def to_sparse(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.dense))


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# the jit-compiled edgeMap step (module-level: cache shared across engines)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("F", "C", "mode", "n", "ids_budget", "edge_budget"),
)
def _edge_map_step(
    offsets,  # int32[n+1]
    keys,  # int64[cap] sorted packed (src<<32|dst)
    src_c,  # int32[cap] clipped sources
    dst_c,  # int32[cap] clipped destinations
    evalid,  # bool[cap] slot < m
    degrees,  # int32[n]
    m,  # int32 scalar
    U,  # bool[n] frontier
    state,  # pytree
    *,
    F: Callable,
    C: Callable,
    mode: str,
    n: int,
    ids_budget: int,
    edge_budget: int,
):
    cmask = C(JAX_OPS, state, jnp.arange(n, dtype=jnp.int32))

    def dense_branch(state):
        valid = evalid & U[src_c] & cmask[dst_c]
        return F(JAX_OPS, state, src_c, dst_c, valid)

    def sparse_branch(state):
        ids_raw = jnp.nonzero(U, size=ids_budget, fill_value=n)[0]
        vid = ids_raw < n
        ids = jnp.where(vid, ids_raw, 0).astype(jnp.int32)
        starts = offsets[ids].astype(jnp.int64)
        degs = jnp.where(vid, (offsets[ids + 1] - offsets[ids]), 0).astype(jnp.int64)
        cum = jnp.cumsum(degs)
        j = jnp.arange(edge_budget, dtype=jnp.int64)
        seg = jnp.searchsorted(cum, j, side="right")
        seg = jnp.clip(seg, 0, ids_budget - 1)
        prev = jnp.where(seg > 0, cum[jnp.maximum(seg - 1, 0)], 0)
        eidx = starts[seg] + (j - prev)
        ev = j < cum[-1]
        eidx = jnp.where(ev, eidx, 0)
        vs = (keys[eidx] & 0xFFFFFFFF).astype(jnp.int32)
        vs = jnp.clip(vs, 0, n - 1)
        us = ids[seg]
        valid = ev & cmask[vs]
        return F(JAX_OPS, state, us, vs, valid)

    if mode == "dense":
        state, out = dense_branch(state)
    elif mode == "sparse":
        state, out = sparse_branch(state)
    else:  # auto: Ligra/Beamer direction optimization, traced
        size = U.sum()
        deg_u = jnp.where(U, degrees, 0).sum()
        use_dense = (size + deg_u) > jnp.maximum(1, m // DENSE_THRESHOLD_DENOM)
        state, out = jax.lax.cond(use_dense, dense_branch, sparse_branch, state)
    return state, out


@jax.jit
def _reduce_msgs(values, src_by_dst, valid_by_dst):
    return jnp.where(valid_by_dst, values[src_by_dst], 0.0).astype(jnp.float32)


class JaxEngine(TraversalEngine):
    """Engine over an (immutable) ``FlatGraph`` snapshot."""

    ops = JAX_OPS

    def __init__(self, g: FlatGraph):
        self.g = g
        self._n = g.n
        self._m = int(g.m)
        cap = g.edge_capacity

        keys = np.asarray(g.keys)
        evalid = np.arange(cap) < self._m
        src = (keys >> 32).astype(np.int64)
        dst = (keys & 0xFFFFFFFF).astype(np.int64)
        self._src_c = jnp.asarray(np.clip(src, 0, self._n - 1).astype(np.int32))
        self._dst_c = jnp.asarray(np.clip(dst, 0, self._n - 1).astype(np.int32))
        self._evalid = jnp.asarray(evalid)
        self._degrees = jnp.diff(g.offsets)

        # dst-major permutation: the pool is src-major, but the Pallas
        # segment-sum kernel wants destinations sorted — precompute once
        # per snapshot (host-side; O(m log m)).
        dst_key = np.where(evalid, dst, self._n)
        order = np.argsort(dst_key, kind="stable")
        self._dst_sorted = jnp.asarray(dst_key[order].astype(np.int32))
        self._src_by_dst = jnp.asarray(
            np.clip(src, 0, self._n - 1)[order].astype(np.int32)
        )
        self._valid_by_dst = jnp.asarray(evalid[order])

        # static sparse budgets: a frontier routed sparse obeys
        # |U| + deg(U) <= m/20 <= cap/20, so cap-derived budgets bound
        # any runtime threshold.  Forced-sparse mode needs full budgets.
        self._auto_ids_budget = min(self._n, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._auto_edge_budget = min(cap, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._full_ids_budget = self._n
        self._full_edge_budget = max(cap, 1)

    # -- graph shape --------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        return self._m

    @property
    def degrees(self) -> jax.Array:
        return self._degrees

    # -- frontiers ----------------------------------------------------------
    def frontier_from_ids(self, ids) -> JaxVertexSubset:
        mask = jnp.zeros(self._n, dtype=bool).at[jnp.asarray(ids)].set(True)
        return JaxVertexSubset(mask)

    def frontier_from_dense(self, mask) -> JaxVertexSubset:
        return JaxVertexSubset(jnp.asarray(mask, dtype=bool))

    # -- edgeMap ------------------------------------------------------------
    def edge_map(
        self,
        U: JaxVertexSubset,
        F: Callable,
        C: Callable,
        state,
        direction_optimize: bool = True,
        mode: str = "auto",
    ) -> Tuple[JaxVertexSubset, object]:
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        if mode == "sparse":
            ids_b, edge_b = self._full_ids_budget, self._full_edge_budget
        else:
            ids_b, edge_b = self._auto_ids_budget, self._auto_edge_budget
        state, out = _edge_map_step(
            self.g.offsets,
            self.g.keys,
            self._src_c,
            self._dst_c,
            self._evalid,
            self._degrees,
            self.g.m,
            U.dense,
            state,
            F=F,
            C=C,
            mode=mode,
            n=self._n,
            ids_budget=ids_b,
            edge_budget=edge_b,
        )
        return JaxVertexSubset(out), state

    # -- dense semiring reduce (Pallas segment-sum) -------------------------
    def edge_map_reduce(self, values: jax.Array) -> jax.Array:
        msg = _reduce_msgs(values, self._src_by_dst, self._valid_by_dst)
        out = kops.segment_sum(self._dst_sorted, msg[:, None], self._n)
        return out[:, 0].astype(values.dtype)

    # -- vertexMap ----------------------------------------------------------
    def vertex_map(self, U: JaxVertexSubset, P: Callable, state) -> JaxVertexSubset:
        keep = P(JAX_OPS, state, jnp.arange(self._n, dtype=jnp.int32))
        return JaxVertexSubset(U.dense & keep)
