"""JAX/TPU traversal backend over ``FlatGraph`` (the packed-key pool).

Maps Ligra's edgeMap onto the flat C-tree pool (flat_graph.py):

  * dense ("pull"/whole-pool) direction: every pool slot looks up
    whether its source is in the frontier — one gather + one masked
    scatter, the same shape as GNN aggregation.  The (+, x) semiring
    specialization ``edge_map_reduce`` (PageRank's inner loop) lowers
    to the Pallas one-hot-matmul segment sum in
    ``repro.kernels.segment_reduce`` via ``repro.kernels.ops`` (so it
    runs compiled on TPU and interpret-mode on CPU).

  * sparse ("push") direction: the frontier's adjacency lists are
    contiguous key ranges of the sorted pool, so expansion is a
    fixed-shape ragged gather: nonzero(size=K) frontier ids ->
    searchsorted over per-id degree prefix sums -> pool indices.  No
    dynamic shapes, so the whole push/pull step jits once per
    (F, C, mode) and is reused across iterations and engines.

Direction optimization (|U| + deg(U) > m/20, paper §5.1) runs inside
the jit step as a ``lax.cond``, so one compiled step serves both
directions; the sparse branch's static budgets are sized from the
threshold (a frontier routed sparse can never exceed cap/20 ids or
pool-capacity/20 edges).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

from ..flat_graph import FlatGraph, unpack
from .base import DENSE_THRESHOLD_DENOM, ArrayOps, TraversalEngine


class JaxOps(ArrayOps):
    xp = jnp
    int_dtype = jnp.int32
    float_dtype = jnp.float64

    def set_at(self, arr, idx, vals):
        return arr.at[idx].set(vals)

    def _safe_idx(self, target, idx, mask):
        # OOB indices are dropped by mode="drop": masking = index escape
        return jnp.where(mask, idx, target.shape[0])

    def scatter_max(self, target, idx, vals, mask):
        return target.at[self._safe_idx(target, idx, mask)].max(vals, mode="drop")

    def scatter_min(self, target, idx, vals, mask):
        return target.at[self._safe_idx(target, idx, mask)].min(vals, mode="drop")

    def scatter_add(self, target, idx, vals, mask):
        vals = jnp.where(mask, vals, jnp.zeros((), target.dtype))
        return target.at[self._safe_idx(target, idx, mask)].add(vals, mode="drop")

    def scatter_or(self, target, idx, mask):
        return target.at[self._safe_idx(target, idx, mask)].max(True, mode="drop")


JAX_OPS = JaxOps()


class JaxVertexSubset:
    """Dense bool[n] frontier.  ``size``/``empty`` force a device→host
    sync (python-level loop control); the count is computed ONCE per
    subset and cached — algorithms probe ``U.empty`` every round, and a
    per-access sync was a measurable serial cost inside traversal loops.
    """

    __slots__ = ("dense", "_size")

    def __init__(self, dense: jax.Array):
        self.dense = dense  # bool[n]
        self._size: Optional[int] = None

    @property
    def n(self) -> int:
        return self.dense.shape[0]

    @property
    def size(self) -> int:
        if self._size is None:
            self._size = int(self.dense.sum())
        return self._size

    @property
    def empty(self) -> bool:
        return self.size == 0

    def to_dense(self) -> jax.Array:
        return self.dense

    def to_sparse(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.dense))


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# per-snapshot engine auxiliary state (one jit pytree, device-resident)
# ---------------------------------------------------------------------------


class EngineAux(NamedTuple):
    """Everything ``JaxEngine`` derives from a snapshot, as one pytree.

    Refreshing it is ONE fixed-shape jit call — no host loops, no host
    argsort — so an engine over a freshly-merged mirror costs O(cap)
    device work instead of the old O(m log m) host precompute, and the
    pytree itself can be version-pinned and reused across queries.
    """

    src_c: jax.Array  # int32[cap] clipped sources
    dst_c: jax.Array  # int32[cap] clipped destinations
    evalid: jax.Array  # bool[cap] slot < m
    degrees: jax.Array  # int32[n]
    dst_sorted: jax.Array  # int32[cap] destinations ascending (pad=n)
    src_by_dst: jax.Array  # int32[cap] sources permuted dst-major
    valid_by_dst: jax.Array  # bool[cap]


@jax.jit
def engine_aux(g: FlatGraph) -> EngineAux:
    n = g.offsets.shape[0] - 1
    cap = g.keys.shape[0]
    src, dst = unpack(g.keys)
    # a slot is usable iff it holds a real edge AND its destination is a
    # real vertex: an asymmetric stream can store an edge naming a
    # never-source vertex id >= n, and every query direction must DROP
    # it (not fold it into the clipped n-1).
    evalid = (jnp.arange(cap) < g.m) & (dst >= 0) & (dst < n)
    src_c = jnp.clip(src, 0, max(n - 1, 0))
    dst_c = jnp.clip(dst, 0, max(n - 1, 0))
    # dst-major permutation for the Pallas segment-sum (the pool is
    # src-major): on-device sort-by-key replaces the old host argsort.
    dst_key = jnp.where(evalid, dst, jnp.int32(n))
    order = jnp.argsort(dst_key, stable=True)
    return EngineAux(
        src_c=src_c,
        dst_c=dst_c,
        evalid=evalid,
        degrees=jnp.diff(g.offsets),
        dst_sorted=dst_key[order],
        src_by_dst=src_c[order],
        valid_by_dst=evalid[order],
    )


# ---------------------------------------------------------------------------
# the jit-compiled edgeMap step (module-level: cache shared across engines)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("F", "C", "mode", "n", "ids_budget", "edge_budget"),
)
def _edge_map_step(
    offsets,  # int32[n+1]
    keys,  # int64[cap] sorted packed (src<<32|dst)
    src_c,  # int32[cap] clipped sources
    dst_c,  # int32[cap] clipped destinations
    evalid,  # bool[cap] slot < m
    degrees,  # int32[n]
    m,  # int32 scalar
    U,  # bool[n] frontier
    state,  # pytree
    *,
    F: Callable,
    C: Callable,
    mode: str,
    n: int,
    ids_budget: int,
    edge_budget: int,
):
    cmask = C(JAX_OPS, state, jnp.arange(n, dtype=jnp.int32))

    def dense_branch(state):
        valid = evalid & U[src_c] & cmask[dst_c]
        return F(JAX_OPS, state, src_c, dst_c, valid)

    def sparse_branch(state):
        ids_raw = jnp.nonzero(U, size=ids_budget, fill_value=n)[0]
        vid = ids_raw < n
        ids = jnp.where(vid, ids_raw, 0).astype(jnp.int32)
        starts = offsets[ids].astype(jnp.int64)
        degs = jnp.where(vid, (offsets[ids + 1] - offsets[ids]), 0).astype(jnp.int64)
        cum = jnp.cumsum(degs)
        j = jnp.arange(edge_budget, dtype=jnp.int64)
        seg = jnp.searchsorted(cum, j, side="right")
        seg = jnp.clip(seg, 0, ids_budget - 1)
        prev = jnp.where(seg > 0, cum[jnp.maximum(seg - 1, 0)], 0)
        eidx = starts[seg] + (j - prev)
        ev = j < cum[-1]
        eidx = jnp.where(ev, eidx, 0)
        vs_raw = keys[eidx] & 0xFFFFFFFF  # int64: no wraparound
        ev = ev & (vs_raw < n)  # drop edges naming nonexistent vertices
        vs = jnp.clip(vs_raw.astype(jnp.int32), 0, n - 1)
        us = ids[seg]
        valid = ev & cmask[vs]
        return F(JAX_OPS, state, us, vs, valid)

    if mode == "dense":
        state, out = dense_branch(state)
    elif mode == "sparse":
        state, out = sparse_branch(state)
    else:  # auto: Ligra/Beamer direction optimization, traced
        size = U.sum()
        deg_u = jnp.where(U, degrees, 0).sum()
        use_dense = (size + deg_u) > jnp.maximum(1, m // DENSE_THRESHOLD_DENOM)
        state, out = jax.lax.cond(use_dense, dense_branch, sparse_branch, state)
    return state, out


@jax.jit
def _reduce_msgs(values, src_by_dst, valid_by_dst):
    return jnp.where(valid_by_dst, values[src_by_dst], 0.0).astype(jnp.float32)


class JaxEngine(TraversalEngine):
    """Engine over an (immutable) ``FlatGraph`` snapshot."""

    ops = JAX_OPS

    def __init__(self, g: FlatGraph, aux: Optional[EngineAux] = None):
        self.g = g
        self._n = g.n
        self._m = int(g.m)
        cap = g.edge_capacity

        # all per-snapshot derived state is one jit call (device-resident;
        # no host loops / argsort) — or passed in, pre-refreshed, by a
        # version-pinned caller (AspenStream's engine cache).
        self.aux = engine_aux(g) if aux is None else aux
        self._src_c = self.aux.src_c
        self._dst_c = self.aux.dst_c
        self._evalid = self.aux.evalid
        self._degrees = self.aux.degrees
        self._dst_sorted = self.aux.dst_sorted
        self._src_by_dst = self.aux.src_by_dst
        self._valid_by_dst = self.aux.valid_by_dst

        # static sparse budgets: a frontier routed sparse obeys
        # |U| + deg(U) <= m/20 <= cap/20, so cap-derived budgets bound
        # any runtime threshold.  Forced-sparse mode needs full budgets.
        self._auto_ids_budget = min(self._n, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._auto_edge_budget = min(cap, _round_up(cap // DENSE_THRESHOLD_DENOM + 1, 64))
        self._full_ids_budget = self._n
        self._full_edge_budget = max(cap, 1)

    # -- graph shape --------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        return self._m

    @property
    def degrees(self) -> jax.Array:
        return self._degrees

    # -- frontiers ----------------------------------------------------------
    def frontier_from_ids(self, ids) -> JaxVertexSubset:
        mask = jnp.zeros(self._n, dtype=bool).at[jnp.asarray(ids)].set(True)
        return JaxVertexSubset(mask)

    def frontier_from_dense(self, mask) -> JaxVertexSubset:
        return JaxVertexSubset(jnp.asarray(mask, dtype=bool))

    # -- edgeMap ------------------------------------------------------------
    def edge_map(
        self,
        U: JaxVertexSubset,
        F: Callable,
        C: Callable,
        state,
        direction_optimize: bool = True,
        mode: str = "auto",
    ) -> Tuple[JaxVertexSubset, object]:
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        if mode == "sparse":
            ids_b, edge_b = self._full_ids_budget, self._full_edge_budget
        else:
            ids_b, edge_b = self._auto_ids_budget, self._auto_edge_budget
        state, out = _edge_map_step(
            self.g.offsets,
            self.g.keys,
            self._src_c,
            self._dst_c,
            self._evalid,
            self._degrees,
            self.g.m,
            U.dense,
            state,
            F=F,
            C=C,
            mode=mode,
            n=self._n,
            ids_budget=ids_b,
            edge_budget=edge_b,
        )
        return JaxVertexSubset(out), state

    # -- dense semiring reduce (Pallas segment-sum) -------------------------
    def edge_map_reduce(self, values: jax.Array) -> jax.Array:
        msg = _reduce_msgs(values, self._src_by_dst, self._valid_by_dst)
        out = kops.segment_sum(self._dst_sorted, msg[:, None], self._n)
        return out[:, 0].astype(values.dtype)

    # -- vertexMap ----------------------------------------------------------
    def vertex_map(self, U: JaxVertexSubset, P: Callable, state) -> JaxVertexSubset:
        keep = P(JAX_OPS, state, jnp.arange(self._n, dtype=jnp.int32))
        return JaxVertexSubset(U.dense & keep)


# ---------------------------------------------------------------------------
# whole-graph jit traversals (single compiled step, no host round-trips) —
# the device-side counterparts of algorithms.py, used where the entire
# frontier loop must live inside one trace (launch cells, sharded pool).
# Formerly ad-hoc copies at the bottom of flat_graph.py.
# ---------------------------------------------------------------------------


def _pool_endpoints(g: FlatGraph):
    """(src_c, dst_c, evalid) without the dst-major sort — the cheap
    subset of ``engine_aux`` the whole-graph loops need.  Like
    ``engine_aux``, edges naming a destination outside [0, n) are
    masked invalid (dropped), never folded into the clipped n-1."""
    n = g.offsets.shape[0] - 1
    src, dst = unpack(g.keys)
    evalid = (jnp.arange(g.keys.shape[0]) < g.m) & (dst >= 0) & (dst < n)
    return (
        jnp.clip(src, 0, max(n - 1, 0)),
        jnp.clip(dst, 0, max(n - 1, 0)),
        evalid,
    )


@jax.jit
def dense_expand(g: FlatGraph, frontier: jax.Array) -> jax.Array:
    """One dense edgeMap expansion: bool[n] frontier -> bool[n] reached.

    Every pool slot looks up whether its source is in the frontier; a
    segment-or over destinations (one gather + one masked scatter)."""
    src_c, dst_c, evalid = _pool_endpoints(g)
    n = g.offsets.shape[0] - 1
    msg = frontier[src_c] & evalid
    return jnp.zeros(n, dtype=bool).at[dst_c].max(msg, mode="drop")


@jax.jit
def bfs_levels(g: FlatGraph, source: jax.Array) -> jax.Array:
    """Full BFS levels via lax.while_loop (fixed-shape iterations)."""
    aux = _pool_endpoints(g)
    n = g.offsets.shape[0] - 1
    levels = jnp.full(n, jnp.int32(-1))
    levels = levels.at[source].set(0)
    frontier = jnp.zeros(n, dtype=bool).at[source].set(True)

    def cond(state):
        frontier, levels, d = state
        return frontier.any()

    def body(state):
        frontier, levels, d = state
        src_c, dst_c, evalid = aux
        msg = frontier[src_c] & evalid
        nxt = jnp.zeros(n, dtype=bool).at[dst_c].max(msg, mode="drop")
        nxt = nxt & (levels < 0)
        levels = jnp.where(nxt, d + 1, levels)
        return nxt, levels, d + 1

    _, levels, _ = jax.lax.while_loop(cond, body, (frontier, levels, jnp.int32(0)))
    return levels


@jax.jit
def cc_labels(g: FlatGraph) -> jax.Array:
    """Min-label propagation to fixpoint (jit while_loop)."""
    src_c, dst_c, evalid = _pool_endpoints(g)
    n = g.offsets.shape[0] - 1
    labels0 = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        labels, changed = state
        return changed

    def body(state):
        labels, _ = state
        msg = jnp.where(evalid, labels[src_c], jnp.int32(np.iinfo(np.int32).max))
        new = labels.at[dst_c].min(msg, mode="drop")
        return new, (new != labels).any()

    labels, _ = jax.lax.while_loop(cond, body, (labels0, jnp.bool_(True)))
    return labels
