"""Backend-generic global algorithms over the unified edgeMap engine.

One algorithm text per problem; the engine handle picks the substrate
(numpy over FlatSnapshot, jax over FlatGraph).  The F/C callbacks are
module-level so the jax backend's jit cache is keyed stably (a closure
redefined per call would recompile every invocation).

Contract v2: every F callback takes the per-edge value lane ``ws``
(None on unweighted engines).  BFS / CC / BC ignore it; SSSP
(Bellman–Ford over the (min, +) semiring) and the PageRank family
(weighted (+, x) semiring, normalized by ``engine.weighted_degrees``)
consume it — the same one-text-two-substrates style throughout.

All single-source algorithms python-loop over rounds; each round is one
engine ``edge_map`` (on jax: one compiled fixed-shape step), which is
the paper's frontier-synchronous model.  Results come back as host
numpy arrays.

The ``*_multi`` variants serve a BATCH of queries against one snapshot:
on backends with in-trace drivers (``engine.bfs_batch`` /
``engine.bc_batch`` / ``engine.edge_map_reduce_batch``, the jax
backend) the whole multi-source traversal is one device dispatch with
O(1) host syncs; elsewhere they fall back to a per-source python loop,
so the SAME call site serves both substrates (the one-algorithm-text
contract, extended to batches).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .base import Counter, TraversalEngine

# Spy counter over PageRank power-iteration rounds (one bump per
# edge_map_reduce sweep).  The warm-start acceptance tests pin
# "incremental converges in <= half the rounds of full recompute" on
# the difference of this count across calls.
PAGERANK_ROUNDS = Counter()


def _as_index(ops, v: int):
    return ops.xp.asarray([v], dtype=ops.int_dtype)


# ---------------------------------------------------------------------------
# BFS (direction-optimized, paper §5.1)
# ---------------------------------------------------------------------------


def _bfs_unvisited(ops, parents, vs):
    return parents[vs] < 0


def _bfs_relax(ops, parents, us, vs, ws, valid):
    """Claim parents: any in-frontier neighbor is a valid BFS parent;
    scatter-max resolves write contention deterministically."""
    cand = ops.scatter_max(ops.xp.full_like(parents, -1), vs, us.astype(parents.dtype), valid)
    newly = (parents < 0) & (cand >= 0)
    return ops.xp.where(newly, cand, parents), newly


def bfs(engine: TraversalEngine, src: int, direction_optimize: bool = True) -> np.ndarray:
    """Parent array (-1 = unreached; src's parent is itself)."""
    ops = engine.ops
    parents = ops.set_at(
        ops.xp.full(engine.n, -1, dtype=ops.int_dtype), _as_index(ops, src), src
    )
    U = engine.frontier_from_ids([src])
    while not U.empty:
        U, parents = engine.edge_map(
            U, _bfs_relax, _bfs_unvisited, parents,
            direction_optimize=direction_optimize,
        )
    return engine.to_host(parents)


def bfs_multi(
    engine: TraversalEngine, sources, direction_optimize: bool = True
) -> tuple:
    """Multi-source BFS: ``(parents, depths)``, each int64[B, n].

    With ``direction_optimize`` on an engine exposing ``bfs_batch``
    (jax), all B traversals run as ONE in-trace dispatch; otherwise B
    serial ``bfs`` calls (the numpy fallback).  Parents agree between
    the two paths: both resolve write contention with the same
    max-parent rule."""
    sources = np.asarray(sources, dtype=np.int64).reshape(-1)
    n = engine.n
    batch = getattr(engine, "bfs_batch", None)
    if batch is not None and direction_optimize and sources.size:
        parents, depths = batch(sources)
        return (
            engine.to_host(parents).astype(np.int64),
            engine.to_host(depths).astype(np.int64),
        )
    ps, ds = [], []
    for s in sources:
        p = bfs(engine, int(s), direction_optimize=direction_optimize)
        ps.append(np.asarray(p, dtype=np.int64))
        ds.append(bfs_depths(p, int(s)))
    empty = np.empty((0, n), np.int64)
    return (np.stack(ps) if ps else empty, np.stack(ds) if ds else empty)


def landmark_distances(
    engine: TraversalEngine, landmarks, direction_optimize: bool = True
) -> np.ndarray:
    """Hop-distance rows int64[B, n] from each landmark (-1 =
    unreached): the distance-sketch building block — B columns of a
    landmark/distance-oracle table in one batched traversal."""
    return bfs_multi(engine, landmarks, direction_optimize=direction_optimize)[1]


def bfs_depths(parents: np.ndarray, src: int) -> np.ndarray:
    """Derive BFS levels from a parent array (host-side helper; used by
    the cross-backend parity checks, where parents may legally differ
    but depths may not)."""
    parents = np.asarray(parents)
    n = parents.size
    depth = np.full(n, -1, dtype=np.int64)
    depth[src] = 0
    for _ in range(n):
        unknown = (depth < 0) & (parents >= 0)
        if not unknown.any():
            break
        ready = unknown & (depth[parents] >= 0)
        if not ready.any():
            break
        depth[ready] = depth[parents[ready]] + 1
    return depth


# ---------------------------------------------------------------------------
# Connected components (min-label propagation through edgeMap)
# ---------------------------------------------------------------------------


def _cc_any(ops, labels, vs):
    return ops.xp.ones(vs.shape, dtype=bool)


def _cc_relax(ops, labels, us, vs, ws, valid):
    """Min-label relax over BOTH endpoints of each touched edge (the
    graph is undirected; each stored direction carries labels both
    ways, like the pre-refactor implementation)."""
    n = labels.shape[0]
    cand = ops.scatter_min(
        ops.xp.full(n, n, dtype=labels.dtype), vs, labels[us], valid
    )
    cand = ops.scatter_min(cand, us, labels[vs], valid)
    changed = cand < labels
    return ops.xp.where(changed, cand, labels), changed


def connected_components(
    engine: TraversalEngine, direction_optimize: bool = True, max_iters: int = 1000
) -> np.ndarray:
    """Min-label propagation to fixpoint; the frontier is the changed
    set, so converged regions stop costing work.

    Assumes the paper's undirected model: the edge set is symmetric
    (both directions stored), as AspenStream maintains by default.
    Frontier expansion follows stored out-edges, so on an asymmetric
    edge set vertices reachable only against edge direction may keep
    stale labels."""
    ops = engine.ops
    labels = ops.xp.arange(engine.n, dtype=ops.int_dtype)
    U = engine.frontier_all()
    for _ in range(max_iters):
        if U.empty:
            break
        U, labels = engine.edge_map(
            U, _cc_relax, _cc_any, labels, direction_optimize=direction_optimize
        )
    return engine.to_host(labels)


# ---------------------------------------------------------------------------
# PageRank (dense edgeMap reduced over the weighted (+, x) semiring)
# ---------------------------------------------------------------------------


def pagerank(
    engine: TraversalEngine,
    iters: int = 10,
    damping: float = 0.85,
    init: Optional[np.ndarray] = None,
    tol: Optional[float] = None,
    max_iters: int = 200,
) -> np.ndarray:
    """Power iteration over the weighted (+, x) semiring; the push step
    out[v] = sum_{u->v} w(u,v) * pr[u] / wdeg[u] is
    ``engine.edge_map_reduce`` — on the jax backend that's the Pallas
    segment-sum kernel (weighted variant on weighted graphs), on numpy
    a vectorized scatter-add.  ``wdeg`` is the weighted out-degree,
    which equals the plain degree on unweighted graphs — so this IS
    classic PageRank there (identical floats: a dangling vertex's value
    is never read by the reduce), and transition-probability-correct
    weighted PageRank on weighted graphs (mass is conserved because
    each vertex's outgoing weight normalizes to 1).

    ``init`` warm-starts the iteration (the incremental path passes the
    previous version's scores; shorter/longer rows are padded with 1/n
    / truncated for vertex-count changes).  The fixed point is unique
    for damping < 1, so any init converges to the same scores — init
    only changes how many rounds that takes.  ``tol`` switches from
    fixed ``iters`` to the fixed-point contract both the full and
    warm-started paths share: iterate until the L1 score change drops
    below ``tol`` (one host sync per round for the check), up to
    ``max_iters``.  Every round bumps ``PAGERANK_ROUNDS``."""
    xp = engine.ops.xp
    n = engine.n
    wdeg = engine.weighted_degrees.astype(engine.ops.float_dtype)
    dangling = wdeg == 0
    if init is None:
        pr = xp.full(n, 1.0 / n, dtype=engine.ops.float_dtype)
    else:
        init = np.asarray(init).reshape(-1)
        if init.size < n:  # vertex growth since the init was computed
            init = np.concatenate([init, np.full(n - init.size, 1.0 / n)])
        pr = xp.asarray(init[:n], dtype=engine.ops.float_dtype)
    rounds = max_iters if tol is not None else iters
    for _ in range(rounds):
        w = xp.where(dangling, 0.0, pr / xp.where(dangling, 1.0, wdeg))
        contrib = engine.edge_map_reduce(w).astype(engine.ops.float_dtype)
        contrib = contrib + xp.where(dangling, pr, 0.0).sum() / n
        nxt = (1.0 - damping) / n + damping * contrib
        PAGERANK_ROUNDS.bump()
        if tol is not None and float(xp.abs(nxt - pr).sum()) < tol:
            pr = nxt
            break
        pr = nxt
    return engine.to_host(pr)


def weighted_pagerank(
    engine: TraversalEngine, iters: int = 10, damping: float = 0.85
) -> np.ndarray:
    """Weighted PageRank — the explicit name for the weighted (+, x)
    semiring text: ``pagerank`` above is already weight-aware (one
    algorithm text, both substrates, weighted or not), so this simply
    delegates; on an unweighted engine it returns exactly
    ``pagerank``'s output."""
    return pagerank(engine, iters=iters, damping=damping)


def pagerank_multi(
    engine: TraversalEngine,
    resets=None,
    iters: int = 10,
    damping: float = 0.85,
    init: Optional[np.ndarray] = None,
    tol: Optional[float] = None,
    max_iters: int = 200,
) -> np.ndarray:
    """B PageRank queries against one snapshot: float[B, n].

    ``resets`` is a (B, n) batch of personalization rows (each summing
    to 1); ``None`` runs one uniform row (global PageRank, matching
    ``pagerank``).  Dangling mass is redistributed by each lane's reset
    row — with the uniform row that reduces exactly to ``pagerank``'s
    ``/ n`` term.  Every iteration pushes ALL lanes through one
    ``edge_map_reduce_batch`` (on jax: one Pallas segment-sum whose
    feature dim carries the lanes; weighted graphs dispatch the
    weighted kernel and normalize by weighted out-degree, like
    ``pagerank``).

    ``init`` / ``tol`` / ``max_iters`` mirror ``pagerank``'s fixed-point
    contract batch-wide: ``init`` (B, n) warm-starts every lane (columns
    pad with 1/n / truncate on vertex-count changes; each lane's fixed
    point is unique for damping < 1, so any init converges to the same
    scores), and ``tol`` switches from fixed ``iters`` to iterating
    until EVERY lane's L1 change drops below ``tol`` (one host sync per
    round), up to ``max_iters`` — the contract the result cache's
    carry-forward warm start relies on."""
    xp = engine.ops.xp
    fdt = engine.ops.float_dtype
    n = engine.n
    wdeg = engine.weighted_degrees.astype(fdt)
    dangling = wdeg == 0
    if resets is None:
        resets = xp.full((1, n), 1.0 / n, dtype=fdt)
    else:
        resets = xp.asarray(resets, dtype=fdt)
    if init is None:
        pr = resets
    else:
        init = np.asarray(init, dtype=np.float64).reshape(len(resets), -1)
        if init.shape[1] < n:  # vertex growth since the init was computed
            pad = np.full((init.shape[0], n - init.shape[1]), 1.0 / n)
            init = np.concatenate([init, pad], axis=1)
        pr = xp.asarray(init[:, :n], dtype=fdt)
    denom = xp.where(dangling, 1.0, wdeg)[None, :]
    rounds = max_iters if tol is not None else iters
    for _ in range(rounds):
        w = xp.where(dangling[None, :], 0.0, pr / denom)
        contrib = engine.edge_map_reduce_batch(w).astype(fdt)
        dang = xp.where(dangling[None, :], pr, 0.0).sum(axis=1, keepdims=True)
        nxt = (1.0 - damping) * resets + damping * (contrib + dang * resets)
        if tol is not None:
            PAGERANK_ROUNDS.bump()
            if float(xp.abs(nxt - pr).sum(axis=1).max()) < tol:
                pr = nxt
                break
        pr = nxt
    return engine.to_host(pr)


# ---------------------------------------------------------------------------
# SSSP (Bellman–Ford over the (min, +) semiring; weighted edgeMap)
# ---------------------------------------------------------------------------


def _sssp_any(ops, dist, vs):
    return ops.xp.ones(vs.shape, dtype=bool)


def _sssp_relax(ops, dist, us, vs, ws, valid):
    """Relax every frontier edge: cand[v] = min dist[u] + w(u, v);
    scatter-min resolves write contention.  ``ws is None`` (an
    unweighted engine) runs unit weights — hop distances, the BFS
    metric — decided at trace time."""
    vals = dist[us] + (1.0 if ws is None else ws.astype(dist.dtype))
    cand = ops.scatter_min(ops.xp.full_like(dist, ops.xp.inf), vs, vals, valid)
    newly = cand < dist
    return ops.xp.where(newly, cand, dist), newly


def sssp(engine: TraversalEngine, src: int, direction_optimize: bool = True) -> np.ndarray:
    """Single-source shortest-path distances (float, +inf = unreached)
    by frontier-synchronous Bellman–Ford: the frontier is the set of
    vertices whose distance improved last round, each round is one
    ``edge_map`` with the Beamer rule intact (sparse relaxes only the
    frontier's out-edges; dense is the (min, +) pull over all
    candidates).  At most n-1 rounds for non-negative weights."""
    ops = engine.ops
    xp = ops.xp
    dist = ops.set_at(
        xp.full(engine.n, xp.inf, dtype=ops.float_dtype), _as_index(ops, src), 0.0
    )
    U = engine.frontier_from_ids([src])
    for _ in range(max(engine.n, 1)):
        if U.empty:
            break
        U, dist = engine.edge_map(
            U, _sssp_relax, _sssp_any, dist,
            direction_optimize=direction_optimize,
        )
    return engine.to_host(dist)


def sssp_multi(
    engine: TraversalEngine, sources, direction_optimize: bool = True
) -> np.ndarray:
    """B SSSP queries against one snapshot: distances float64[B, n].

    Uses the engine's in-trace ``sssp_batch`` driver when available
    (jax: the whole multi-source Bellman–Ford is ONE dispatch with O(1)
    host syncs, like ``bfs_batch``); otherwise B serial ``sssp`` calls
    (the numpy fallback) — same call site, both substrates."""
    sources = np.asarray(sources, dtype=np.int64).reshape(-1)
    batch = getattr(engine, "sssp_batch", None)
    if batch is not None and direction_optimize and sources.size:
        return engine.to_host(batch(sources)).astype(np.float64)
    if not sources.size:
        return np.empty((0, engine.n), np.float64)
    return np.stack(
        [
            np.asarray(
                sssp(engine, int(s), direction_optimize=direction_optimize),
                np.float64,
            )
            for s in sources
        ]
    )


# ---------------------------------------------------------------------------
# Betweenness centrality (Brandes, single source; paper §7 "BC")
# ---------------------------------------------------------------------------


def _bc_unvisited(ops, state, vs):
    sigma, visited = state
    return ~visited[vs]


def _bc_forward(ops, state, us, vs, ws, valid):
    """sigma[v] += sum of sigma over in-frontier predecessors."""
    sigma, visited = state
    contrib = ops.scatter_add(
        ops.xp.zeros_like(sigma), vs, sigma[us], valid
    )
    newly = (~visited) & (contrib > 0)
    sigma = sigma + ops.xp.where(newly, contrib, 0.0)
    visited = visited | newly
    return (sigma, visited), newly


def _bc_next_level(ops, state, vs):
    dep, sigma, level_of, tgt = state
    return level_of[vs] == tgt


def _bc_backward(ops, state, us, vs, ws, valid):
    """dep[u] += sigma[u]/sigma[v] * (1 + dep[v]) over u@d -> v@d+1."""
    dep, sigma, level_of, tgt = state
    contrib = (sigma[us] / ops.xp.maximum(sigma[vs], 1e-30)) * (1.0 + dep[vs])
    dep = ops.scatter_add(dep, us, contrib, valid)
    return (dep, sigma, level_of, tgt), ops.xp.zeros(dep.shape[0], dtype=bool)


def bc(engine: TraversalEngine, src: int, direction_optimize: bool = True) -> np.ndarray:
    """Single-source betweenness contributions (Brandes forward pass to
    count shortest paths, level-synchronous backward accumulation)."""
    ops = engine.ops
    xp = ops.xp
    n = engine.n
    fdt = ops.float_dtype
    sigma = ops.set_at(xp.zeros(n, dtype=fdt), _as_index(ops, src), 1.0)
    visited = ops.set_at(xp.zeros(n, dtype=bool), _as_index(ops, src), True)
    level_of = ops.set_at(xp.full(n, -1, dtype=ops.int_dtype), _as_index(ops, src), 0)
    levels: List[object] = []
    U = engine.frontier_from_ids([src])
    d = 0
    while not U.empty:
        levels.append(U)
        U, (sigma, visited) = engine.edge_map(
            U, _bc_forward, _bc_unvisited, (sigma, visited),
            direction_optimize=direction_optimize,
        )
        d += 1
        level_of = xp.where(U.to_dense(), d, level_of).astype(ops.int_dtype)
    dep = xp.zeros(n, dtype=fdt)
    for d in range(len(levels) - 2, -1, -1):
        tgt = xp.asarray(d + 1, dtype=ops.int_dtype)
        state = (dep, sigma, level_of, tgt)
        _, state = engine.edge_map(
            levels[d], _bc_backward, _bc_next_level, state,
            direction_optimize=direction_optimize,
        )
        dep = state[0]
    dep = ops.set_at(dep, _as_index(ops, src), 0.0)
    return engine.to_host(dep)


# ---------------------------------------------------------------------------
# Incremental (delta-aware) algorithms: warm-start from the previous
# version's result instead of recomputing from scratch.  The delta is the
# per-version update record ``versioning.Delta`` (captured by
# ``AspenStream._publish``); every function here relaxes over the NEW
# snapshot only, so conservative (superset) seed/dirty sets never cost
# correctness — only extra relaxation work.
# ---------------------------------------------------------------------------


def _hop_relax(ops, dist, us, vs, ws, valid):
    """``_sssp_relax`` at forced unit weight: the BFS hop metric on a
    weighted engine (incremental BFS ignores the value lane)."""
    vals = dist[us] + 1.0
    cand = ops.scatter_min(ops.xp.full_like(dist, ops.xp.inf), vs, vals, valid)
    newly = cand < dist
    return ops.xp.where(newly, cand, dist), newly


def _parent_claim(ops, state, us, vs, ws, valid):
    """One dense pass deriving BFS parents from final depths:
    parent(v) = max u with depth(u) = depth(v) - 1 and u->v — exactly
    the contention rule of ``_bfs_relax`` and the ``bfs_batch`` drivers,
    so post-hoc parents match the full-recompute parents bit-for-bit."""
    depths, cand = state
    ok = valid & (depths[us] >= 0) & (depths[vs] == depths[us] + 1)
    cand = ops.scatter_max(cand, vs, us.astype(cand.dtype), ok)
    return (depths, cand), ops.xp.zeros(depths.shape[0], dtype=bool)


def _sssp_parent_claim(ops, state, us, vs, ws, valid):
    """Shortest-path-tree parents from final distances: parent(v) =
    max u with dist(v) = dist(u) + w(u, v).  Equality is exact: dist(v)
    was produced by the same float op for the winning predecessor."""
    dist, cand = state
    w = 1.0 if ws is None else ws.astype(dist.dtype)
    ok = valid & ops.xp.isfinite(dist[us]) & (dist[vs] == dist[us] + w)
    cand = ops.scatter_max(cand, vs, us.astype(cand.dtype), ok)
    return (dist, cand), ops.xp.zeros(dist.shape[0], dtype=bool)


def _pad_rows(arr: np.ndarray, n: int, fill) -> np.ndarray:
    """Fit (B, n_prev) state rows to the current vertex count (edge
    inserts may grow the vertex set between versions)."""
    B, n_prev = arr.shape
    if n_prev == n:
        return arr
    if n_prev > n:
        return arr[:, :n]
    return np.concatenate([arr, np.full((B, n - n_prev), fill, arr.dtype)], axis=1)


def _dirty_closure(prev_parents: np.ndarray, pairs: np.ndarray) -> np.ndarray:
    """Vertices whose recorded shortest-path-tree edge is in ``pairs``
    (deleted, or weight-overwritten on weighted graphs), closed under
    tree descendants — the set whose previous distances can no longer
    be trusted.  Vertices OUTSIDE the closure keep exact distances:
    their recorded root path uses only clean tree edges (a broken tree
    edge dirties the whole subtree below it), deletions only ever
    increase distances, and the old distance stays achievable."""
    B, n = prev_parents.shape
    dirty = np.zeros((B, n), dtype=bool)
    pairs = pairs[(pairs[:, 0] < n) & (pairs[:, 1] < n) & (pairs[:, 0] != pairs[:, 1])]
    if pairs.size:
        for b in range(B):
            hit = prev_parents[b, pairs[:, 1]] == pairs[:, 0]
            dirty[b, pairs[hit, 1]] = True
    vid = np.arange(n, dtype=np.int64)[None, :]
    valid = (prev_parents >= 0) & (prev_parents != vid)
    par_safe = np.where(valid, prev_parents, 0)
    for _ in range(n):
        spread = np.take_along_axis(dirty, par_safe, axis=1) & valid & ~dirty
        if not spread.any():
            break
        dirty |= spread
    return dirty


def warm_distances(
    engine: TraversalEngine,
    dist0: np.ndarray,  # float[B, n], +inf = unknown/unreached
    frontier0: np.ndarray,  # bool[B, n] initial relax frontier
    unit: bool = False,
) -> np.ndarray:
    """(min, +) relaxation to fixpoint from ARBITRARY initial state —
    the warm-start engine under incremental BFS and SSSP.  Dispatches
    the in-trace ``sssp_batch_from`` driver when the backend has one
    (jax / sharded: the existing Bellman–Ford ``lax.while_loop`` seeded
    with ``(dist0, frontier0)`` instead of point sources, O(1) host
    syncs); otherwise runs the backend-generic per-lane edge_map loop.
    ``unit=True`` forces unit weights (the hop metric) on weighted
    engines."""
    dist0 = np.asarray(dist0, np.float64)
    frontier0 = np.asarray(frontier0, bool)
    drv = getattr(engine, "sssp_batch_from", None)
    if drv is not None and dist0.shape[0]:
        return engine.to_host(drv(dist0, frontier0, unit=unit)).astype(np.float64)
    ops = engine.ops
    F = _hop_relax if (unit and engine.weighted) else _sssp_relax
    rows: List[np.ndarray] = []
    for b in range(dist0.shape[0]):
        dist = ops.xp.asarray(dist0[b], dtype=ops.float_dtype)
        U = engine.frontier_from_dense(frontier0[b])
        for _ in range(max(engine.n, 1)):
            if U.empty:
                break
            U, dist = engine.edge_map(U, F, _sssp_any, dist)
        rows.append(np.asarray(engine.to_host(dist), np.float64))
    return np.stack(rows) if rows else np.empty((0, engine.n), np.float64)


def parents_from_depths(engine: TraversalEngine, depths: np.ndarray) -> np.ndarray:
    """Derive BFS parents int64[B, n] from depth rows with the drivers'
    max-contention rule.  Backends may expose a vectorized / in-trace
    ``parents_from_depths``; the fallback is one dense edge_map pass
    per lane (works on every backend, including sharded)."""
    depths = np.asarray(depths, np.int64)
    drv = getattr(engine, "parents_from_depths", None)
    if drv is not None:
        return engine.to_host(drv(depths)).astype(np.int64)
    ops = engine.ops
    n = engine.n
    vid = np.arange(n, dtype=np.int64)
    rows: List[np.ndarray] = []
    for row in depths:
        state = (
            ops.xp.asarray(row, dtype=ops.int_dtype),
            ops.xp.full(n, -1, dtype=ops.int_dtype),
        )
        _, state = engine.edge_map(
            engine.frontier_all(), _parent_claim, _cc_any, state, mode="dense"
        )
        cand = np.asarray(engine.to_host(state[1]), np.int64)
        rows.append(np.where(row == 0, vid, np.where(row > 0, cand, -1)))
    return np.stack(rows) if rows else np.empty((0, n), np.int64)


def shortest_path_parents(
    engine: TraversalEngine, dist: np.ndarray, sources
) -> np.ndarray:
    """Shortest-path-tree parents int64[B, n] for SSSP distance rows
    (one dense support-claim pass per lane): the state incremental SSSP
    keeps so the next delta can compute its dirty subtree."""
    dist = np.asarray(dist, np.float64)
    sources = np.asarray(sources, np.int64).reshape(-1)
    ops = engine.ops
    n = engine.n
    rows: List[np.ndarray] = []
    for b in range(dist.shape[0]):
        state = (
            ops.xp.asarray(dist[b], dtype=ops.float_dtype),
            ops.xp.full(n, -1, dtype=ops.int_dtype),
        )
        _, state = engine.edge_map(
            engine.frontier_all(), _sssp_parent_claim, _cc_any, state, mode="dense"
        )
        cand = np.asarray(engine.to_host(state[1]), np.int64)
        row = np.where(np.isfinite(dist[b]), cand, -1)
        row[sources[b]] = sources[b]
        rows.append(row)
    return np.stack(rows) if rows else np.empty((0, n), np.int64)


def incremental_bfs(
    engine: TraversalEngine,
    sources,
    prev_parents: np.ndarray,
    prev_depths: np.ndarray,
    delta,
) -> tuple:
    """BFS over the new snapshot, revalidating only what the delta can
    have changed: vertices whose recorded parent edge was deleted (plus
    their tree descendants) reset to unknown, everything else keeps its
    depth, and the warm relaxation runs from the clean reached set —
    new edges improve through relaxation, the dirty region recomputes
    from its boundary.  Exact: returns the same ``(parents, depths)``
    as a full ``bfs_multi`` on the new snapshot."""
    sources = np.asarray(sources, np.int64).reshape(-1)
    n = engine.n
    B = sources.size
    lane = np.arange(B)
    prev_parents = _pad_rows(np.asarray(prev_parents, np.int64), n, -1)
    prev_depths = _pad_rows(np.asarray(prev_depths, np.int64), n, -1)
    dirty = _dirty_closure(prev_parents, delta.dels)
    dist0 = np.where(
        dirty | (prev_depths < 0), np.inf, prev_depths.astype(np.float64)
    )
    dist0[lane, sources] = 0.0
    dist = warm_distances(engine, dist0, np.isfinite(dist0), unit=True)
    depths = np.where(np.isfinite(dist), dist, -1.0).astype(np.int64)
    return parents_from_depths(engine, depths), depths


def incremental_sssp(
    engine: TraversalEngine,
    sources,
    prev_dist: np.ndarray,
    prev_parents: np.ndarray,
    delta,
) -> np.ndarray:
    """SSSP distances float64[B, n] over the new snapshot, warm-started
    from the previous version's distances + shortest-path-tree parents
    (``shortest_path_parents``).  Dirty = subtrees under deleted tree
    edges — and, on weighted engines, under re-inserted tree edges
    (an insert may OVERWRITE an existing edge's weight upward, so the
    old support is no longer trustworthy; unit-weight graphs skip
    this).  Exact vs a full ``sssp_multi`` on the new snapshot."""
    sources = np.asarray(sources, np.int64).reshape(-1)
    n = engine.n
    lane = np.arange(sources.size)
    prev_dist = _pad_rows(np.asarray(prev_dist, np.float64), n, np.inf)
    prev_parents = _pad_rows(np.asarray(prev_parents, np.int64), n, -1)
    pairs = (
        np.concatenate([delta.dels, delta.ins]) if engine.weighted else delta.dels
    )
    dirty = _dirty_closure(prev_parents, pairs)
    dist0 = np.where(dirty, np.inf, prev_dist)
    dist0[lane, sources] = 0.0
    return warm_distances(engine, dist0, np.isfinite(dist0), unit=False)


def incremental_connected_components(
    engine: TraversalEngine,
    prev_labels: np.ndarray,
    delta,
    direction_optimize: bool = True,
    max_iters: int = 1000,
) -> np.ndarray:
    """Min-label propagation seeded ONLY from the delta's endpoint
    frontier over the new snapshot.  Exact for insert-only deltas:
    previous labels are per-component minima, new edges only merge
    components, and the only label disagreements in the initial state
    sit across inserted edges — so propagation from their endpoints
    reaches every vertex whose label must drop.  Deletions can split
    components (old labels become unverifiable), so a delta with
    deletions — or no delta at all — falls back to the full
    ``connected_components`` fixpoint."""
    if delta is None or delta.has_deletions:
        return connected_components(
            engine, direction_optimize=direction_optimize, max_iters=max_iters
        )
    n = engine.n
    prev = np.asarray(prev_labels, np.int64).reshape(-1)
    if prev.size < n:  # new vertices label themselves
        prev = np.concatenate([prev, np.arange(prev.size, n, dtype=np.int64)])
    prev = prev[:n]
    seeds = delta.endpoints
    seeds = seeds[seeds < n]
    if seeds.size == 0:
        return prev
    ops = engine.ops
    labels = ops.xp.asarray(prev, dtype=ops.int_dtype)
    U = engine.frontier_from_ids(seeds)
    for _ in range(max_iters):
        if U.empty:
            break
        U, labels = engine.edge_map(
            U, _cc_relax, _cc_any, labels, direction_optimize=direction_optimize
        )
    return engine.to_host(labels)


def bc_multi(engine: TraversalEngine, sources) -> np.ndarray:
    """B single-source BC queries: dependency scores float64[B, n].

    Uses the engine's in-trace ``bc_batch`` driver when available (jax:
    one dispatch per Brandes phase); otherwise B serial ``bc`` calls.
    The two paths agree to float32 tolerance (the batched pull rounds
    reduce via segmented scans rather than scatter-adds, so float
    summation order differs)."""
    sources = np.asarray(sources, dtype=np.int64).reshape(-1)
    batch = getattr(engine, "bc_batch", None)
    if batch is not None and sources.size:
        return engine.to_host(batch(sources)).astype(np.float64)
    if not sources.size:
        return np.empty((0, engine.n), np.float64)
    return np.stack([np.asarray(bc(engine, int(s)), np.float64) for s in sources])
