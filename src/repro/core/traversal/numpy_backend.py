"""Numpy traversal backend over ``FlatSnapshot`` (paper §5.1).

This is the CPU engine: the vertexSubset / edgeMap machinery formerly
in ``repro.core.edgemap`` plus the frontier loops formerly inlined in
``repro.core.algorithms``, refactored behind the backend contract in
``base.py``.  (The ``repro.core.edgemap`` re-export shim is gone;
import from ``repro.core.traversal``.)

The map/cond functions are vectorized over numpy arrays (the paper's
CPU parallel-for maps to vector lanes here).  Sparse ("push") direction
decodes only the frontier's adjacency lists from the snapshot; dense
("pull") direction scans candidates' in-neighbors via a reverse CSR
cached per snapshot, so it is direction-exact even on asymmetric edge
sets (matching the jax backend).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import numpy as np

from .base import DENSE_THRESHOLD_DENOM, ArrayOps, TraversalEngine, dense_threshold


class VertexSubset(NamedTuple):
    n: int
    ids: Optional[np.ndarray] = None  # sparse form (sorted, unique)
    dense: Optional[np.ndarray] = None  # bool[n]

    @property
    def size(self) -> int:
        return int(self.dense.sum()) if self.dense is not None else self.ids.size

    def to_sparse(self) -> np.ndarray:
        return self.ids if self.ids is not None else np.flatnonzero(self.dense)

    def to_dense(self) -> np.ndarray:
        if self.dense is not None:
            return self.dense
        d = np.zeros(self.n, dtype=bool)
        d[self.ids] = True
        return d

    @property
    def empty(self) -> bool:
        return self.size == 0


def from_ids(n: int, ids) -> VertexSubset:
    return VertexSubset(n, ids=np.unique(np.asarray(ids, dtype=np.int64)))


def from_dense(mask: np.ndarray) -> VertexSubset:
    return VertexSubset(mask.size, dense=mask)


def gather_csr(snap, vs: np.ndarray):
    """Concatenate neighbor lists of ``vs``: (offsets[len(vs)+1], nbrs).

    This is the chunk-decode work: O(sum deg) with O(log n + deg) per
    vertex on the tree level, O(deg) via the flat snapshot (paper §5.1).
    """
    lists = [snap.neighbors(int(v)) for v in vs]
    offsets = np.zeros(len(lists) + 1, dtype=np.int64)
    if lists:
        np.cumsum([l.size for l in lists], out=offsets[1:])
        nbrs = np.concatenate(lists) if offsets[-1] else np.empty(0, np.int64)
    else:
        nbrs = np.empty(0, np.int64)
    return offsets, nbrs


class NumpyOps(ArrayOps):
    xp = np
    int_dtype = np.int64
    float_dtype = np.float64

    def set_at(self, arr, idx, vals):
        out = arr.copy()
        out[idx] = vals
        return out

    def scatter_max(self, target, idx, vals, mask):
        out = target.copy()
        np.maximum.at(out, idx[mask], np.broadcast_to(vals, idx.shape)[mask])
        return out

    def scatter_min(self, target, idx, vals, mask):
        out = target.copy()
        np.minimum.at(out, idx[mask], np.broadcast_to(vals, idx.shape)[mask])
        return out

    def scatter_add(self, target, idx, vals, mask):
        out = target.copy()
        np.add.at(out, idx[mask], np.broadcast_to(vals, idx.shape)[mask])
        return out

    def scatter_or(self, target, idx, mask):
        out = target.copy()
        out[idx[mask]] = True
        return out


NP_OPS = NumpyOps()


class NumpyEngine(TraversalEngine):
    """Engine over any object with the FlatSnapshot protocol:
    ``.n``, ``.neighbors(v)``, ``.degree(v)`` (and optionally cached
    ``.degrees`` / ``.m``, which ``graph.FlatSnapshot`` provides).
    Weighted snapshots additionally expose ``.weighted`` and
    ``.edge_weights(srcs, dsts)`` (vectorized per-edge values), which
    the engine threads into the ``ws`` lane of every F callback and
    into the weighted ``edge_map_reduce`` semiring."""

    ops = NP_OPS

    def __init__(self, snap):
        self.snap = snap
        self._n = int(snap.n)
        degs = getattr(snap, "degrees", None)
        if degs is None:
            degs = np.fromiter(
                (snap.degree(v) for v in range(self._n)), np.int64, count=self._n
            )
        self._degrees = np.asarray(degs, dtype=np.int64)
        m = getattr(snap, "m", None)
        self._m = int(self._degrees.sum()) if m is None else int(m)
        self._full_csr = None
        self._rev_csr_cache = None
        self._weighted = bool(getattr(snap, "weighted", False))
        self._csr_w: Optional[np.ndarray] = None
        self._csr_starts_cache: Optional[np.ndarray] = None
        self._wdeg: Optional[np.ndarray] = None
        self.last_mode: Optional[str] = None  # "sparse" | "dense" (for tests)

    # -- graph shape --------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        return self._m

    @property
    def degrees(self) -> np.ndarray:
        return self._degrees

    @property
    def weights(self) -> Optional[np.ndarray]:
        """Per-edge weights aligned with the full CSR (``_csr()``), or
        None on unweighted snapshots.  Materialized ONCE per engine
        (one vectorized lookup against the snapshot's weight export);
        every edgeMap round slices this cache by CSR position instead
        of re-deriving weights per selected edge."""
        if not self._weighted:
            return None
        if self._csr_w is None:
            srcs, nbrs = self._csr()
            self._csr_w = self.snap.edge_weights(srcs, nbrs)
        return self._csr_w

    @property
    def weighted_degrees(self) -> np.ndarray:
        if not self._weighted:
            return self._degrees.astype(np.float64)
        if self._wdeg is None:
            srcs, _ = self._csr()
            wdeg = np.zeros(self._n, dtype=np.float64)
            np.add.at(wdeg, srcs, self.weights)
            self._wdeg = wdeg
        return self._wdeg

    def _csr_starts(self) -> np.ndarray:
        """offsets[v] of the full CSR: vertex v's adjacency list (in
        ``snap.neighbors(v)`` order, the same order every gather uses)
        occupies csr positions [starts[v], starts[v] + deg(v))."""
        if self._csr_starts_cache is None:
            starts = np.zeros(self._n + 1, dtype=np.int64)
            np.cumsum(self._degrees, out=starts[1:])
            self._csr_starts_cache = starts
        return self._csr_starts_cache

    def _csr(self):
        """Cached full CSR (srcs, nbrs) for whole-graph passes."""
        if self._full_csr is None:
            offsets, nbrs = gather_csr(self.snap, np.arange(self._n, dtype=np.int64))
            srcs = np.repeat(np.arange(self._n, dtype=np.int64), np.diff(offsets))
            self._full_csr = (srcs, nbrs)
        return self._full_csr

    def _rev_csr(self):
        """Cached reverse CSR (in_offsets[n+1], in_srcs sorted by dst,
        in_w weights in the same order or None): the dense ("pull")
        direction scans candidates' IN-neighbors, so it must be
        direction-exact even on asymmetric edge sets (the jax backend
        is; symmetric graphs make the two views coincide).  Built once
        per snapshot, amortized over every dense round."""
        if self._rev_csr_cache is None:
            srcs, nbrs = self._csr()
            order = np.argsort(nbrs, kind="stable")
            in_srcs = srcs[order]
            sorted_dst = nbrs[order]
            in_offsets = np.searchsorted(
                sorted_dst, np.arange(self._n + 1, dtype=np.int64)
            )
            in_w = self.weights[order] if self._weighted else None
            self._rev_csr_cache = (in_offsets, in_srcs, in_w)
        return self._rev_csr_cache

    def parents_from_depths(self, depths) -> np.ndarray:
        """BFS parents int64[B, n] from depth rows: one vectorized
        maximum.at pass per lane over the cached CSR, the same
        max-contention rule (parent(v) = max u with depth(u) =
        depth(v) - 1 and u->v) as the per-round ``_bfs_relax`` scatter
        and the jax drivers' post-hoc pass — so incremental BFS parents
        match a full recompute's exactly."""
        srcs, nbrs = self._csr()
        vid = np.arange(self._n, dtype=np.int64)
        rows = []
        for row in np.asarray(depths, np.int64):
            ok = (row[srcs] >= 0) & (row[nbrs] == row[srcs] + 1)
            cand = np.full(self._n, -1, np.int64)
            np.maximum.at(cand, nbrs[ok], srcs[ok])
            rows.append(np.where(row == 0, vid, np.where(row > 0, cand, -1)))
        return np.stack(rows) if rows else np.empty((0, self._n), np.int64)

    # -- frontiers ----------------------------------------------------------
    def frontier_from_ids(self, ids) -> VertexSubset:
        return from_ids(self._n, ids)

    def frontier_from_dense(self, mask) -> VertexSubset:
        return from_dense(np.asarray(mask, dtype=bool))

    # -- edgeMap ------------------------------------------------------------
    def edge_map(
        self,
        U: VertexSubset,
        F: Callable,
        C: Callable,
        state,
        direction_optimize: bool = True,
        mode: str = "auto",
    ) -> Tuple[VertexSubset, object]:
        if U.empty:
            return from_dense(np.zeros(self._n, dtype=bool)), state
        us = U.to_sparse()
        if mode == "auto" and not direction_optimize:
            mode = "sparse"
        if mode == "auto":
            deg_u = int(self._degrees[us].sum())
            mode = "dense" if (us.size + deg_u) > dense_threshold(self._m) else "sparse"
        self.last_mode = mode
        if mode == "dense":
            return self._edge_map_dense(U, F, C, state)
        return self._edge_map_sparse(us, F, C, state)

    def _edge_map_sparse(self, us, F, C, state):
        offsets, nbrs = gather_csr(self.snap, us)
        degs = np.diff(offsets)
        srcs = np.repeat(us, degs)
        keep = C(NP_OPS, state, nbrs) if nbrs.size else np.empty(0, bool)
        u_e, v_e = srcs[keep], nbrs[keep]
        ws = None
        if self._weighted:
            # the frontier gather lists each vertex's neighbors in the
            # same order as the full CSR, so weights are a slice of the
            # per-engine cache at csr_starts[u] + within-list position
            # (no per-round key lookups)
            within = np.arange(nbrs.size) - np.repeat(offsets[:-1], degs)
            ws = self.weights[np.repeat(self._csr_starts()[us], degs) + within][keep]
        state, out = F(NP_OPS, state, u_e, v_e, ws, np.ones(u_e.size, dtype=bool))
        return from_dense(out), state

    def _edge_map_dense(self, U, F, C, state):
        in_u = U.to_dense()
        candidates = np.flatnonzero(C(NP_OPS, state, np.arange(self._n, dtype=np.int64)))
        if candidates.size == 0:
            return from_dense(np.zeros(self._n, dtype=bool)), state
        in_offsets, in_srcs, in_w = self._rev_csr()
        counts = in_offsets[candidates + 1] - in_offsets[candidates]
        starts = in_offsets[candidates]
        dsts = np.repeat(candidates, counts)
        pos = np.arange(dsts.size) - np.repeat(np.cumsum(counts) - counts, counts)
        gidx = np.repeat(starts, counts) + pos
        srcs = in_srcs[gidx]
        sel = in_u[srcs] if srcs.size else np.empty(0, bool)
        u_e, v_e = srcs[sel], dsts[sel]
        ws = in_w[gidx][sel] if in_w is not None else None
        state, out = F(NP_OPS, state, u_e, v_e, ws, np.ones(u_e.size, dtype=bool))
        return from_dense(out), state

    # -- dense semiring reduce (weighted (+, x): w == 1 when unweighted) ----
    def edge_map_reduce(self, values: np.ndarray) -> np.ndarray:
        srcs, nbrs = self._csr()
        out = np.zeros(self._n, dtype=np.result_type(values.dtype, np.float64))
        contrib = values[srcs]
        if self._weighted:
            contrib = contrib * self.weights
        np.add.at(out, nbrs, contrib)
        return out

    # -- vertexMap ----------------------------------------------------------
    def vertex_map(self, U: VertexSubset, P: Callable, state) -> VertexSubset:
        ids = U.to_sparse()
        keep = P(NP_OPS, state, ids)
        return VertexSubset(self._n, ids=ids[keep])


def engine_of(snap) -> NumpyEngine:
    """Engine for a snapshot, cached on the snapshot when it allows
    attribute assignment (``graph.FlatSnapshot`` reserves an ``_engine``
    slot) so repeated algorithm calls share the CSR caches."""
    eng = getattr(snap, "_engine", None)
    if isinstance(eng, NumpyEngine):
        return eng
    eng = NumpyEngine(snap)
    try:
        snap._engine = eng
    except (AttributeError, TypeError):
        pass  # foreign snapshot type: engine is per-call
    return eng


# ---------------------------------------------------------------------------
# legacy Ligra-style API (paper §2 signature; kept for existing callers)
# ---------------------------------------------------------------------------


def edge_map(
    snap,
    U: VertexSubset,
    F: Callable[[np.ndarray, np.ndarray], np.ndarray],
    C: Callable[[np.ndarray], np.ndarray],
    m: Optional[int] = None,
    direction_optimize: bool = True,
    F_dense: Optional[Callable] = None,
) -> VertexSubset:
    """EDGEMAP(G, U, F, C) -> U' with the original mutate-in-closure
    callbacks: F(us, vs) -> per-edge bool, C(vs) -> bool.  Adapter over
    ``NumpyEngine.edge_map`` (``m`` is now read from the snapshot and
    accepted only for backward compatibility).

    ``F_dense(candidates, offsets, nbrs, nbr_in_u)`` keeps the original
    custom-dense-direction hook: when supplied and the Beamer rule
    picks dense, the legacy candidate-scan layout is reproduced.
    """
    eng = engine_of(snap)

    if F_dense is not None and direction_optimize and not U.empty:
        us = U.to_sparse()
        deg_u = int(eng.degrees[us].sum())
        if (us.size + deg_u) > dense_threshold(eng.m):
            in_u = U.to_dense()
            candidates = np.flatnonzero(C(np.arange(eng.n, dtype=np.int64)))
            if candidates.size == 0:
                return VertexSubset(eng.n, ids=np.empty(0, dtype=np.int64))
            offsets, nbrs = gather_csr(snap, candidates)
            nbr_in_u = in_u[nbrs] if nbrs.size else np.empty(0, bool)
            out_mask = F_dense(candidates, offsets, nbrs, nbr_in_u)
            return VertexSubset(eng.n, ids=candidates[out_mask])

    def C2(ops, state, vs):
        return C(vs)

    def F2(ops, state, us, vs, ws, valid):
        out = np.zeros(eng.n, dtype=bool)
        if us.size:
            hit = F(us, vs)
            out[vs[hit]] = True
        return state, out

    out, _ = eng.edge_map(U, F2, C2, None, direction_optimize=direction_optimize)
    return VertexSubset(eng.n, ids=np.flatnonzero(out.to_dense()))
