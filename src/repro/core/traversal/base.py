"""Backend contract for the unified edgeMap traversal engine.

The paper's query side is Ligra's ``vertexSubset`` / ``edgeMap`` with
direction optimization (paper §2, §5.1).  This package factors that
engine out of the numpy-only implementation so the SAME algorithm text
(BFS / PageRank / CC / SSSP / BC in ``algorithms.py``) runs on three
substrates:

  * ``numpy_backend.NumpyEngine``  — the CPU engine over a
    ``FlatSnapshot`` (per-vertex C-tree refs, paper §5.1);
  * ``jax_backend.JaxEngine``      — the TPU-native engine over a
    ``FlatGraph`` (CSR over the packed-key pool), where dense edgeMap
    lowers to the Pallas ``segment_reduce`` kernel and sparse frontier
    expansion is a fixed-shape searchsorted gather, all inside one
    ``jax.jit``-able step per (F, C, mode) triple;
  * ``sharded_backend.ShardedEngine`` — the mesh-parallel engine over a
    ``sharded_pool.ShardedGraph`` (range-sharded pool), where every
    step is an explicit ``shard_map``: shard-local edge gathers plus
    O(n)-word vertex-state collectives per round (DESIGN.md §9).

Backend contract
----------------
An engine exposes:

  n, m, degrees       graph shape: vertex count (int), directed edge
                      count (int), per-vertex out-degree (backend array)
  ops                 an ``ArrayOps`` namespace (numpy or jax flavour)
  frontier_from_ids / frontier_from_dense / frontier_all
                      VertexSubset constructors
  weights             per-edge value array, or None on an unweighted
                      graph (the property-graph contract, v2): the jax
                      engine exposes the pool-parallel float32[cap]
                      array, the numpy engine a per-CSR-edge float64
                      array.  ``weighted`` is the derived bool.
  weighted_degrees    sum of out-edge weights per vertex (backend float
                      array); equals ``degrees`` cast to float on an
                      unweighted graph, so weighted algorithm texts run
                      unchanged on both.
  edge_map(U, F, C, state, direction_optimize=True, mode="auto")
                      EDGEMAP(G, U, F, C) -> (U', state').  Dispatches
                      sparse (push) vs dense (pull) by the Ligra/Beamer
                      rule |U| + deg(U) > m / 20 when mode == "auto";
                      ``mode`` in {"auto", "sparse", "dense"} forces a
                      direction (tests, benchmarks).
  edge_map_reduce(values)
                      the dense edgeMap specialized to the weighted
                      (+, x) semiring: out[v] = sum_{u->v} w(u,v) *
                      values[u] (w == 1 on unweighted graphs).  This is
                      PageRank's whole inner loop; the jax backend
                      lowers it to kernels/segment_reduce.py — the
                      weighted form dispatches the weighted segment-sum
                      kernel, the unweighted form compiles exactly the
                      pre-v2 trace (no value array is touched).
  edge_map_reduce_batch(values)
                      the same reduce over a (B, n) batch of value rows
                      (one lane per query).  The base class loops over
                      ``edge_map_reduce``; the jax backend runs all B
                      lanes through ONE Pallas segment-sum call.
  vertex_map(U, P, state)
                      VERTEXMAP: filter U by predicate P.
  to_host(x)          any backend array -> np.ndarray

Batched multi-source queries
----------------------------
Backends MAY additionally expose in-trace batched drivers:

  bfs_batch(sources)  -> (parents, depths), each (B, n)
  bc_batch(sources)   -> dependency scores (B, n)
  sssp_batch(sources) -> shortest-path distances (B, n) (+inf = unreached)

and, for the incremental (delta-aware) query path:

  sssp_batch_from(dist0, frontier0, unit=False) -> distances (B, n)
      the same (min, +) loop seeded from arbitrary initial state (the
      previous version's still-valid distances + the clean frontier)
      instead of point sources; ``unit=True`` forces unit weights (the
      hop metric, how incremental BFS rides the driver)
  parents_from_depths(depths) -> parents (B, n)
      the drivers' post-hoc max-contention parent rule as a standalone
      pass, so warm-started BFS re-derives parents bit-identical to a
      full recompute

where a whole multi-source traversal (every frontier round of every
lane) runs as ONE device dispatch with O(1) host syncs total, instead
of D serial round-trip-synced steps per source.  The backend-generic
wrappers in ``algorithms.py`` (``bfs_multi`` / ``bc_multi`` /
``landmark_distances`` / ``pagerank_multi``, and ``warm_distances`` /
``incremental_bfs`` / ``incremental_sssp`` for the incremental path)
dispatch to these via ``getattr`` and fall back to a per-source python
loop, so the same call site serves both substrates.  ``HOST_SYNCS``
below is the spy counter tests use to pin the O(1)-sync contract.

F and C are *pure, functional* callbacks written against ``ops`` (which
is numpy-or-jnp, so one definition serves both backends).  Contract v2
adds the per-edge value lane ``ws`` between ``vs`` and ``valid``:

  C(ops, state, vs)                -> bool mask over vs (target filter)
  F(ops, state, us, vs, ws, valid) -> (state', out_mask) where out_mask
                                      is a dense bool[n] marking U'
                                      membership

``ws`` is the per-edge value array aligned with ``(us, vs)`` — or None
when the engine's graph is unweighted, so weight-agnostic callbacks
(BFS, CC, BC) simply ignore it and weighted callbacks (SSSP) branch on
``ws is None`` at trace time (v1 callbacks migrate by inserting the
``ws`` parameter; nothing else changes).  ``valid`` masks padding /
non-selected lanes: the numpy engine passes exactly the selected edges
(valid all-True); the jax engine passes fixed-shape arrays where
``valid`` carries the selection.  All state writes MUST go through the
masked ``ops.scatter_*`` helpers so the same callback is correct on
both.  State is an arbitrary pytree of backend arrays and is threaded
functionally (the jax engine jit-traces F/C, so closure mutation would
silently not happen).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional, Tuple

import numpy as np

# Ligra/Beamer direction-optimization threshold: dense when
# |U| + deg(U) > m / DENSE_THRESHOLD_DENOM (paper §5.1).
DENSE_THRESHOLD_DENOM = 20


class Counter:
    """A spy counter tests assert against (FLAT_REBUILDS, HOST_SYNCS).

    Thread-safe: ``bump`` is hit from ``run_concurrent`` reader threads
    (every jax frontier-size probe), and an unlocked ``count += 1`` is
    a racy read-modify-write that would undercount under concurrency.
    """

    __slots__ = ("count", "_lock")

    def __init__(self):
        self.count = 0
        self._lock = threading.Lock()

    def bump(self) -> None:
        with self._lock:
            self.count += 1


# Counts blocking device->host syncs issued by the traversal layer (jax
# frontier-size probes, result fetches).  A serial BFS pays one sync per
# round per query; the batched in-trace drivers pay O(1) per BATCH —
# tests spy on this to pin that contract.
HOST_SYNCS = Counter()

# Counts jit TRACES of the in-trace batched drivers (bfs/bc/sssp, flat
# and sharded): the bump sits inside the jitted function body, which
# Python executes only while jax traces — a cache hit never runs it.
# The serving layer pins its steady-state contract on this: after
# warmup (one flush per (kind, pow2 batch size) at a fixed pool
# capacity) serving MUST NOT retrace, i.e. this count must not grow.
TRACES = Counter()


class ArrayOps:
    """Functional array helpers shared by F/C callbacks.

    ``xp`` is the backend namespace (numpy or jax.numpy); the scatter
    helpers take an explicit ``mask`` and never mutate their inputs.
    """

    xp: Any
    int_dtype: Any
    float_dtype: Any

    def set_at(self, arr, idx, vals):  # pragma: no cover - interface
        raise NotImplementedError

    def scatter_max(self, target, idx, vals, mask):  # pragma: no cover
        raise NotImplementedError

    def scatter_min(self, target, idx, vals, mask):  # pragma: no cover
        raise NotImplementedError

    def scatter_add(self, target, idx, vals, mask):  # pragma: no cover
        raise NotImplementedError

    def scatter_or(self, target, idx, mask):  # pragma: no cover
        raise NotImplementedError


class TraversalEngine:
    """Abstract engine; see module docstring for the contract."""

    ops: ArrayOps

    @property
    def n(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    @property
    def m(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    @property
    def degrees(self):  # pragma: no cover - interface
        raise NotImplementedError

    @property
    def weights(self) -> Optional[Any]:
        """Per-edge value array (backend layout), or None when the
        underlying graph carries no edge values."""
        return None

    @property
    def weighted(self) -> bool:
        return self.weights is not None

    @property
    def weighted_degrees(self):
        """Sum of out-edge weights per vertex; == ``degrees`` (as float)
        on unweighted graphs, so one weighted algorithm text serves
        both.  Backends with real weights override."""
        return self.degrees.astype(self.ops.float_dtype)

    @property
    def resident_nbytes(self) -> Optional[int]:
        """Device (or host-array) bytes this engine keeps alive per
        snapshot — graph substrate plus derived aux.  The compression
        benchmarks compare raw vs compressed engines through this one
        number; backends that don't track it return None."""
        return None

    def frontier_from_ids(self, ids):  # pragma: no cover - interface
        raise NotImplementedError

    def frontier_from_dense(self, mask):  # pragma: no cover - interface
        raise NotImplementedError

    def frontier_all(self):
        return self.frontier_from_dense(np.ones(self.n, dtype=bool))

    def edge_map(
        self,
        U,
        F: Callable,
        C: Callable,
        state,
        direction_optimize: bool = True,
        mode: str = "auto",
    ) -> Tuple[Any, Any]:  # pragma: no cover - interface
        raise NotImplementedError

    def edge_map_reduce(self, values):  # pragma: no cover - interface
        raise NotImplementedError

    def edge_map_reduce_batch(self, values):
        """(B, n) value rows -> (B, n) reduced rows.  Default: loop the
        scalar reduce per lane (the numpy fallback); backends with a
        batched kernel path override this."""
        xp = self.ops.xp
        return xp.stack([self.edge_map_reduce(v) for v in values])

    def vertex_map(self, U, P: Callable, state):  # pragma: no cover
        raise NotImplementedError

    def to_host(self, x) -> np.ndarray:
        return np.asarray(x)


def dense_threshold(m: int) -> int:
    """The |U| + deg(U) cutoff above which edge_map goes dense."""
    return max(1, m // DENSE_THRESHOLD_DENOM)
